//! Chapter 4 bench: regenerates every Rodinia table/figure and times the
//! underlying simulation pipeline (run with `cargo bench`).
//!
//! One bench group per paper artefact (Tables 4-3 … 4-11, Fig. 4-2); each
//! measures the full regeneration — device models, fmax seed sweeps,
//! power model, table rendering — and prints the table once so the bench
//! log doubles as the reproduction record.

use fpga_hpc::benchutil::Bencher;
use fpga_hpc::report;

fn main() {
    let b = Bencher::quick();
    println!("=== chapter4 benches: table regeneration ===\n");
    for id in ["4-3", "4-4", "4-5", "4-6", "4-7", "4-8", "4-9", "4-10", "4-11", "fig4-2"] {
        let label = format!("table_{id}");
        b.bench(&label, || report::render(id).unwrap());
    }
    // print the artefacts once for the record
    for id in ["4-3", "4-4", "4-5", "4-6", "4-7", "4-8", "4-9", "4-10", "4-11"] {
        print!("{}", report::render(id).unwrap());
    }
}
