//! Runtime hot-path bench: the L3 request path in isolation.
//!
//! Measures per-block PJRT execution, literal marshalling, halo
//! extraction and the streamed end-to-end cell-update throughput for the
//! 2D/3D stencil compute units — the numbers the §Perf optimization loop
//! in EXPERIMENTS.md tracks.  Everything streamed runs through the
//! `Session` builder API (PR 4): the scheduler-lanes sweep drives the
//! same workload at 1/2/4 lanes under **both** inter-pass schedules —
//! `barrier` (drain between passes) and `pipelined` (dependency-tracked
//! cross-pass writeback) — and the wavefront-apps sweep does the same
//! for the Ch. 4 apps (Pathfinder / NW / SRAD / LUD) at lanes=4.  The
//! chain sweep at the end runs SRAD feeding a downstream stencil two
//! ways: back-to-back barriered (two separate runs, the reference) and
//! as one **fused** chain (`srad.then(stencil2d)`, a single spliced
//! wave graph with cross-app seam edges).  The locality sweep compares
//! the sharded work-stealing scheduler against the single global run
//! queue it replaced, and NUMA-pinned lanes against unpinned, both at
//! lanes=4.  Everything lands in `BENCH_runtime.json` for trajectory
//! tracking; CI gates each pipelined/barrier pair at lanes=4, the fused
//! chain, and the sharded scheduler at ≥ 0.95× their baselines.

use fpga_hpc::benchutil::{write_bench_json, BenchRow, Bencher};
use fpga_hpc::coordinator::grid::{Boundary, Grid2D};
use fpga_hpc::coordinator::session::{GridInput, Session, Workload};
use fpga_hpc::coordinator::{Metrics, PassMode};
use fpga_hpc::runtime::{Pinning, PoolConfig, Runtime, RuntimePool, Tensor};
use fpga_hpc::testutil::Rng;

fn main() {
    let rt = Runtime::open("artifacts").expect("run `make artifacts` first");
    rt.executable("diffusion2d_r1").unwrap();
    rt.executable("hotspot2d").unwrap();
    let b = Bencher::default();
    println!("=== runtime hot-path benches ===\n");

    let mut rng = Rng::new(3);
    let spec = rt.registry().get("diffusion2d_r1").unwrap().clone();
    let tile = spec.inputs[0].shape[0];
    let halo = spec.meta_u64("halo").unwrap() as usize;
    let tile_data = rng.vec_f32(tile * tile, 0.0, 1.0);
    let oob = Tensor::I32(vec![0, 0, 0, 0], vec![4]);

    b.bench(&format!("pjrt_execute_diffusion2d_block_{tile}"), || {
        rt.execute(
            "diffusion2d_r1",
            &[Tensor::F32(tile_data.clone(), vec![tile, tile]), oob.clone()],
        )
        .unwrap()
    });

    b.bench(&format!("pjrt_execute_f32_fastpath_{tile}"), || {
        rt.execute_f32(
            "diffusion2d_r1",
            &[Tensor::F32(tile_data.clone(), vec![tile, tile]), oob.clone()],
        )
        .unwrap()
    });

    b.bench(&format!("tensor_marshal_{tile}x{tile}"), || {
        Tensor::F32(tile_data.clone(), vec![tile, tile])
    });

    let grid = Grid2D { ny: 1024, nx: 1024, data: rng.vec_f32(1024 * 1024, 0.0, 1.0) };
    b.bench(&format!("halo_extract_{tile}x{tile}"), || {
        grid.extract_tile(256, 256, tile, tile, halo, Boundary::Zero)
    });

    let bufpool = fpga_hpc::coordinator::bufpool::TilePool::default();
    b.bench(&format!("halo_extract_pooled_{tile}x{tile}"), || {
        let v = grid.extract_tile_pooled(256, 256, tile, tile, halo, Boundary::Zero, &bufpool);
        bufpool.put(v);
    });

    // --- scheduler-lanes sweep: replicated compute units, barrier vs
    // --- cross-pass pipelined inter-pass schedules, via Session ---
    println!("\n=== scheduler-lanes sweep (streamed diffusion2d 1024^2 x16, Session) ===\n");
    let mut rows = Vec::new();
    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).expect("pool open");
        // one unmeasured run to warm per-lane compile caches and the
        // allocator (each run owns its tile pools: pass 1 fills the
        // shelves, later passes extract allocation-free)
        Session::over(&pool)
            .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 4))
            .unwrap();
        for (mode, tag) in [(PassMode::Barrier, "barrier"), (PassMode::Pipelined, "pipelined")] {
            let report = Session::over(&pool)
                .with_mode(mode)
                .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 16))
                .unwrap();
            let m = &report.metrics;
            println!("lanes={lanes} {tag}: {}", m.summary());
            rows.push(BenchRow {
                name: format!("streamed_diffusion2d_1024_16steps_{tag}"),
                lanes,
                gcells_per_sec: m.gcell_per_sec(),
                wall_secs: m.wall.as_secs_f64(),
                blocks: m.blocks,
                pool_hits: m.pool_hits,
                pool_misses: m.pool_misses,
            });
        }
    }
    let find = |tag: &str, lanes: usize| {
        rows.iter()
            .find(|r: &&BenchRow| r.lanes == lanes && r.name.ends_with(tag))
            .map(|r| r.gcells_per_sec)
    };
    if let (Some(one), Some(four)) = (find("pipelined", 1), find("pipelined", 4)) {
        println!("\n4-lane speedup over 1 lane (pipelined): {:.2}x", four / one.max(1e-12));
    }
    if let (Some(bar), Some(pipe)) = (find("barrier", 4), find("pipelined", 4)) {
        println!(
            "pipelined vs barrier at lanes=4: {:.2}x (CI gates at >= 0.90x)",
            pipe / bar.max(1e-12)
        );
    }

    // --- wavefront-apps sweep: the Ch. 4 apps on the wave pass driver,
    // --- wave-serial barrier vs dependency-edge pipelined, lanes=4 ---
    println!("\n=== wavefront-apps sweep (lanes=4, barrier vs pipelined, Session) ===\n");
    let lanes = 4usize;
    let pool = RuntimePool::open("artifacts", lanes).expect("pool open");

    let mut rng = Rng::new(5);
    let pf_rows = 257; // 1 + 32 fused chunks of 8
    let pf_cols = 16_384; // 4 column blocks of 4096
    let pf_wall: Vec<Vec<i32>> = (0..pf_rows).map(|_| rng.vec_i32(pf_cols, 0, 10)).collect();
    let nw_n = 512; // 8x8 blocks of 64: 15 anti-diagonal waves
    let nw_ref: Vec<Vec<i32>> = (0..=nw_n).map(|_| rng.vec_i32(nw_n + 1, -5, 15)).collect();
    let srad_img = Grid2D { ny: 512, nx: 512, data: rng.vec_f32(512 * 512, 0.5, 2.0) };
    let srad_steps = 4u64;
    let lud_n = 512; // 8x8 blocks of 64: 24 waves
    let lud_a: Vec<Vec<f32>> = (0..lud_n)
        .map(|i| {
            (0..lud_n)
                .map(|j| rng.f32_in(-1.0, 1.0) + if i == j { lud_n as f32 } else { 0.0 })
                .collect()
        })
        .collect();

    const MODES: [(PassMode, &str); 2] =
        [(PassMode::Barrier, "barrier"), (PassMode::Pipelined, "pipelined")];
    fn app_row(name: &str, tag: &str, lanes: usize, m: &Metrics) -> BenchRow {
        println!("{name} lanes={lanes} {tag}: {}", m.summary());
        BenchRow {
            name: format!("app_{name}_{tag}"),
            lanes,
            gcells_per_sec: m.gcell_per_sec(),
            wall_secs: m.wall.as_secs_f64(),
            blocks: m.blocks,
            pool_hits: m.pool_hits,
            pool_misses: m.pool_misses,
        }
    }
    let workload: &dyn Fn(&str) -> Workload = &|app| match app {
        "pathfinder" => Workload::pathfinder(pf_wall.clone()),
        "nw" => Workload::nw(nw_ref.clone(), 10),
        "srad" => Workload::srad(srad_img.clone(), srad_steps),
        _ => Workload::lud(lud_a.clone()),
    };
    for app in ["pathfinder", "nw", "srad", "lud"] {
        // one unmeasured run per app first: lane compile caches + allocator
        Session::over(&pool).run(workload(app)).unwrap();
        for (mode, tag) in MODES {
            let report = Session::over(&pool).with_mode(mode).run(workload(app)).unwrap();
            rows.push(app_row(app, tag, lanes, &report.metrics));
        }
    }

    for app in ["pathfinder", "nw", "srad", "lud"] {
        let get = |tag: &str| {
            rows.iter()
                .find(|r| r.lanes == lanes && r.name == format!("app_{app}_{tag}"))
                .map(|r| r.gcells_per_sec)
        };
        if let (Some(bar), Some(pipe)) = (get("barrier"), get("pipelined")) {
            println!(
                "{app}: pipelined vs barrier at lanes=4: {:.2}x (CI gates at >= 0.90x)",
                pipe / bar.max(1e-12)
            );
        }
    }

    // --- fused-chain sweep: SRAD feeding a downstream stencil, one
    // --- spliced wave graph vs the back-to-back barriered reference ---
    println!("\n=== fused-chain sweep (srad -> diffusion2d, lanes=4) ===\n");
    let chain_steps = 16u64;
    // warm both apps' caches on this pool once
    Session::over(&pool)
        .run(
            Workload::srad(srad_img.clone(), srad_steps)
                .then(Workload::stencil2d("diffusion2d_r1", GridInput::Upstream, None, chain_steps)),
        )
        .unwrap();
    // Back-to-back barriered reference: two separate runs, the second
    // only starting after the first fully drained.
    let barriered = Session::over(&pool).with_mode(PassMode::Barrier);
    let r1 = barriered.run(Workload::srad(srad_img.clone(), srad_steps)).unwrap();
    let mid = r1.into_output().into_grid2d().expect("srad yields a grid");
    let _ = barriered
        .run(Workload::stencil2d("diffusion2d_r1", mid, None, chain_steps))
        .unwrap();
    let back = barriered.metrics(); // cumulative across the two runs
    println!("back-to-back barriered: {}", back.summary());
    rows.push(BenchRow {
        name: "chain_srad_stencil_backtoback".into(),
        lanes,
        gcells_per_sec: back.gcell_per_sec(),
        wall_secs: back.wall.as_secs_f64(),
        blocks: back.blocks,
        pool_hits: back.pool_hits,
        pool_misses: back.pool_misses,
    });
    // Fused: one spliced wave graph, seam edges instead of a drain.
    let report = Session::over(&pool)
        .run(
            Workload::srad(srad_img.clone(), srad_steps)
                .then(Workload::stencil2d("diffusion2d_r1", GridInput::Upstream, None, chain_steps)),
        )
        .unwrap();
    let fused = &report.metrics;
    println!("fused chain:            {}", fused.summary());
    rows.push(BenchRow {
        name: "chain_srad_stencil_fused".into(),
        lanes,
        gcells_per_sec: fused.gcell_per_sec(),
        wall_secs: fused.wall.as_secs_f64(),
        blocks: fused.blocks,
        pool_hits: fused.pool_hits,
        pool_misses: fused.pool_misses,
    });
    println!(
        "fused vs back-to-back: {:.2}x (CI gates at >= 0.95x); fused depth={} overlap={}",
        fused.gcell_per_sec() / back.gcell_per_sec().max(1e-12),
        fused.pipeline_depth_max,
        fused.overlap_starts,
    );

    // --- locality sweep: sharded work-stealing queues vs the global
    // --- run queue, and NUMA-pinned lanes vs unpinned, lanes=4 ---
    println!("\n=== locality sweep (streamed diffusion2d 1024^2 x16, lanes=4) ===\n");
    let cases: [(&str, bool, Pinning); 4] = [
        ("sched_stencil_global", false, Pinning::None),
        ("sched_stencil_sharded", true, Pinning::None),
        ("pin_stencil_none", true, Pinning::None),
        ("pin_stencil_numa", true, Pinning::Numa),
    ];
    for (name, sharded, pinning) in cases {
        let pool = RuntimePool::open_with("artifacts", PoolConfig { lanes, pinning, sharded })
            .expect("pool open");
        // one unmeasured run: lane compile caches + per-lane shelves
        // (and, under Numa, first-touch of the warm arenas on-node)
        Session::over(&pool)
            .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 4))
            .unwrap();
        let report = Session::over(&pool)
            .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 16))
            .unwrap();
        let m = &report.metrics;
        println!("{name}: {}", m.summary());
        rows.push(BenchRow {
            name: name.into(),
            lanes,
            gcells_per_sec: m.gcell_per_sec(),
            wall_secs: m.wall.as_secs_f64(),
            blocks: m.blocks,
            pool_hits: m.pool_hits,
            pool_misses: m.pool_misses,
        });
    }
    let sched = |name: &str| {
        rows.iter()
            .find(|r| r.lanes == lanes && r.name == name)
            .map(|r| r.gcells_per_sec)
    };
    if let (Some(global), Some(shard)) =
        (sched("sched_stencil_global"), sched("sched_stencil_sharded"))
    {
        println!(
            "sharded vs global queue at lanes=4: {:.2}x (CI gates at >= 0.95x)",
            shard / global.max(1e-12)
        );
    }
    if let (Some(none), Some(numa)) = (sched("pin_stencil_none"), sched("pin_stencil_numa")) {
        println!(
            "numa-pinned vs unpinned at lanes=4: {:.2}x (informational; single-node hosts pin nothing)",
            numa / none.max(1e-12)
        );
    }

    write_bench_json("BENCH_runtime.json", &rows).expect("writing BENCH_runtime.json");
    println!("wrote BENCH_runtime.json");
}
