//! Runtime hot-path bench: the L3 request path in isolation.
//!
//! Measures per-block PJRT execution, literal marshalling, halo
//! extraction and the streamed end-to-end cell-update throughput for the
//! 2D/3D stencil compute units — the numbers the §Perf optimization loop
//! in EXPERIMENTS.md tracks.  The scheduler-lanes sweep runs the same
//! streamed workload through the multi-lane engine at 1/2/4 lanes under
//! **both** inter-pass schedules — `barrier` (drain between passes, the
//! PR 1 baseline) and `pipelined` (dependency-tracked cross-pass
//! writeback).  The wavefront-apps sweep at the end does the same for
//! the Ch. 4 apps (Pathfinder / NW / SRAD / LUD) at lanes=4 on the wave
//! pass driver — `barrier` (wave-serial) vs `pipelined`
//! (dependency-edge overlap).  Everything lands in `BENCH_runtime.json`
//! for trajectory tracking; CI gates each pipelined/barrier pair at
//! lanes=4.

use fpga_hpc::benchutil::{write_bench_json, BenchRow, Bencher};
use fpga_hpc::coordinator::grid::{Boundary, Grid2D};
use fpga_hpc::coordinator::{apps, stencil_runner, PassMode};
use fpga_hpc::runtime::{Runtime, RuntimePool, Tensor};
use fpga_hpc::testutil::Rng;

fn main() {
    let rt = Runtime::open("artifacts").expect("run `make artifacts` first");
    rt.executable("diffusion2d_r1").unwrap();
    rt.executable("hotspot2d").unwrap();
    let b = Bencher::default();
    println!("=== runtime hot-path benches ===\n");

    let mut rng = Rng::new(3);
    let spec = rt.registry().get("diffusion2d_r1").unwrap().clone();
    let tile = spec.inputs[0].shape[0];
    let halo = spec.meta_u64("halo").unwrap() as usize;
    let tile_data = rng.vec_f32(tile * tile, 0.0, 1.0);
    let oob = Tensor::I32(vec![0, 0, 0, 0], vec![4]);

    b.bench(&format!("pjrt_execute_diffusion2d_block_{tile}"), || {
        rt.execute(
            "diffusion2d_r1",
            &[Tensor::F32(tile_data.clone(), vec![tile, tile]), oob.clone()],
        )
        .unwrap()
    });

    b.bench(&format!("pjrt_execute_f32_fastpath_{tile}"), || {
        rt.execute_f32(
            "diffusion2d_r1",
            &[Tensor::F32(tile_data.clone(), vec![tile, tile]), oob.clone()],
        )
        .unwrap()
    });

    b.bench(&format!("tensor_marshal_{tile}x{tile}"), || {
        Tensor::F32(tile_data.clone(), vec![tile, tile])
    });

    let grid = Grid2D { ny: 1024, nx: 1024, data: rng.vec_f32(1024 * 1024, 0.0, 1.0) };
    b.bench(&format!("halo_extract_{tile}x{tile}"), || {
        grid.extract_tile(256, 256, tile, tile, halo, Boundary::Zero)
    });

    let bufpool = fpga_hpc::coordinator::bufpool::TilePool::default();
    b.bench(&format!("halo_extract_pooled_{tile}x{tile}"), || {
        let v = grid.extract_tile_pooled(256, 256, tile, tile, halo, Boundary::Zero, &bufpool);
        bufpool.put(v);
    });

    b.bench("streamed_diffusion2d_1024_4steps", || {
        let g = grid.clone();
        stencil_runner::run_stencil2d(&rt, "diffusion2d_r1", g, None, 4).unwrap()
    });

    // report end-to-end throughput once
    let (_, m) =
        stencil_runner::run_stencil2d(&rt, "diffusion2d_r1", grid.clone(), None, 16).unwrap();
    println!("\nstreamed diffusion2d 1024^2 x16 steps: {}", m.summary());
    let stats = rt.stats();
    println!(
        "runtime totals: {} executions, execute {:.1}ms, marshal {:.1}ms",
        stats.executions, stats.execute_ms, stats.marshal_ms
    );

    // --- scheduler-lanes sweep: replicated compute units, barrier vs
    // --- cross-pass pipelined inter-pass schedules ---
    println!("\n=== scheduler-lanes sweep (streamed diffusion2d 1024^2 x16) ===\n");
    let mut rows = Vec::new();
    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).expect("pool open");
        pool.warmup_artifact("diffusion2d_r1").unwrap();
        // one unmeasured run to warm per-lane compile caches and the
        // allocator (each run owns its tile pools: pass 1 fills the
        // shelves, later passes extract allocation-free)
        stencil_runner::run_stencil2d_lanes(&pool, "diffusion2d_r1", grid.clone(), None, 4)
            .unwrap();
        for (mode, tag) in [(PassMode::Barrier, "barrier"), (PassMode::Pipelined, "pipelined")] {
            let (_, m) = stencil_runner::run_stencil2d_lanes_mode(
                &pool, "diffusion2d_r1", grid.clone(), None, 16, mode,
            )
            .unwrap();
            println!("lanes={lanes} {tag}: {}", m.summary());
            rows.push(BenchRow {
                name: format!("streamed_diffusion2d_1024_16steps_{tag}"),
                lanes,
                gcells_per_sec: m.gcell_per_sec(),
                wall_secs: m.wall.as_secs_f64(),
                blocks: m.blocks,
                pool_hits: m.pool_hits,
                pool_misses: m.pool_misses,
            });
        }
    }
    let find = |tag: &str, lanes: usize| {
        rows.iter()
            .find(|r| r.lanes == lanes && r.name.ends_with(tag))
            .map(|r| r.gcells_per_sec)
    };
    if let (Some(one), Some(four)) = (find("pipelined", 1), find("pipelined", 4)) {
        println!("\n4-lane speedup over 1 lane (pipelined): {:.2}x", four / one.max(1e-12));
    }
    if let (Some(bar), Some(pipe)) = (find("barrier", 4), find("pipelined", 4)) {
        println!(
            "pipelined vs barrier at lanes=4: {:.2}x (CI gates at >= 0.90x)",
            pipe / bar.max(1e-12)
        );
    }

    // --- wavefront-apps sweep: the Ch. 4 apps on the wave pass driver,
    // --- wave-serial barrier vs dependency-edge pipelined, lanes=4 ---
    println!("\n=== wavefront-apps sweep (lanes=4, barrier vs pipelined) ===\n");
    let lanes = 4usize;
    let pool = RuntimePool::open("artifacts", lanes).expect("pool open");

    let mut rng = Rng::new(5);
    let pf_rows = 257; // 1 + 32 fused chunks of 8
    let pf_cols = 16_384; // 4 column blocks of 4096
    let pf_wall: Vec<Vec<i32>> = (0..pf_rows).map(|_| rng.vec_i32(pf_cols, 0, 10)).collect();
    let nw_n = 512; // 8x8 blocks of 64: 15 anti-diagonal waves
    let nw_ref: Vec<Vec<i32>> = (0..=nw_n).map(|_| rng.vec_i32(nw_n + 1, -5, 15)).collect();
    let srad_img = Grid2D { ny: 512, nx: 512, data: rng.vec_f32(512 * 512, 0.5, 2.0) };
    let srad_steps = 4u64;
    let lud_n = 512; // 8x8 blocks of 64: 24 waves
    let lud_a: Vec<Vec<f32>> = (0..lud_n)
        .map(|i| {
            (0..lud_n)
                .map(|j| rng.f32_in(-1.0, 1.0) + if i == j { lud_n as f32 } else { 0.0 })
                .collect()
        })
        .collect();

    const MODES: [(PassMode, &str); 2] =
        [(PassMode::Barrier, "barrier"), (PassMode::Pipelined, "pipelined")];
    fn app_row(name: &str, tag: &str, lanes: usize, m: &fpga_hpc::coordinator::Metrics) -> BenchRow {
        println!("{name} lanes={lanes} {tag}: {}", m.summary());
        BenchRow {
            name: format!("app_{name}_{tag}"),
            lanes,
            gcells_per_sec: m.gcell_per_sec(),
            wall_secs: m.wall.as_secs_f64(),
            blocks: m.blocks,
            pool_hits: m.pool_hits,
            pool_misses: m.pool_misses,
        }
    }

    // one unmeasured run per app first: lane compile caches + allocator
    apps::run_pathfinder_lanes(&pool, &pf_wall).unwrap();
    for (mode, tag) in MODES {
        let (_, m) = apps::run_pathfinder_lanes_mode(&pool, &pf_wall, mode).unwrap();
        rows.push(app_row("pathfinder", tag, lanes, &m));
    }
    apps::run_nw_lanes(&pool, &nw_ref, 10).unwrap();
    for (mode, tag) in MODES {
        let (_, m) = apps::run_nw_lanes_mode(&pool, &nw_ref, 10, mode).unwrap();
        rows.push(app_row("nw", tag, lanes, &m));
    }
    apps::run_srad_lanes(&pool, srad_img.clone(), srad_steps).unwrap();
    for (mode, tag) in MODES {
        let (_, m) =
            apps::run_srad_lanes_mode(&pool, srad_img.clone(), srad_steps, mode).unwrap();
        rows.push(app_row("srad", tag, lanes, &m));
    }
    apps::run_lud_lanes(&pool, &lud_a).unwrap();
    for (mode, tag) in MODES {
        let (_, m) = apps::run_lud_lanes_mode(&pool, &lud_a, mode).unwrap();
        rows.push(app_row("lud", tag, lanes, &m));
    }

    for app in ["pathfinder", "nw", "srad", "lud"] {
        let get = |tag: &str| {
            rows.iter()
                .find(|r| r.lanes == lanes && r.name == format!("app_{app}_{tag}"))
                .map(|r| r.gcells_per_sec)
        };
        if let (Some(bar), Some(pipe)) = (get("barrier"), get("pipelined")) {
            println!(
                "{app}: pipelined vs barrier at lanes=4: {:.2}x (CI gates at >= 0.90x)",
                pipe / bar.max(1e-12)
            );
        }
    }

    write_bench_json("BENCH_runtime.json", &rows).expect("writing BENCH_runtime.json");
    println!("wrote BENCH_runtime.json");
}
