//! Runtime hot-path bench: the L3 request path in isolation.
//!
//! Measures per-block PJRT execution, literal marshalling, halo
//! extraction and the streamed end-to-end cell-update throughput for the
//! 2D/3D stencil compute units — the numbers the §Perf optimization loop
//! in EXPERIMENTS.md tracks.

use fpga_hpc::benchutil::Bencher;
use fpga_hpc::coordinator::grid::{Boundary, Grid2D};
use fpga_hpc::coordinator::stencil_runner;
use fpga_hpc::runtime::{Runtime, Tensor};
use fpga_hpc::testutil::Rng;

fn main() {
    let rt = Runtime::open("artifacts").expect("run `make artifacts` first");
    rt.executable("diffusion2d_r1").unwrap();
    rt.executable("hotspot2d").unwrap();
    let b = Bencher::default();
    println!("=== runtime hot-path benches ===\n");

    let mut rng = Rng::new(3);
    let spec = rt.registry().get("diffusion2d_r1").unwrap().clone();
    let tile = spec.inputs[0].shape[0];
    let halo = spec.meta_u64("halo").unwrap() as usize;
    let tile_data = rng.vec_f32(tile * tile, 0.0, 1.0);
    let oob = Tensor::I32(vec![0, 0, 0, 0], vec![4]);

    b.bench(&format!("pjrt_execute_diffusion2d_block_{tile}"), || {
        rt.execute(
            "diffusion2d_r1",
            &[Tensor::F32(tile_data.clone(), vec![tile, tile]), oob.clone()],
        )
        .unwrap()
    });

    b.bench(&format!("tensor_marshal_{tile}x{tile}"), || {
        Tensor::F32(tile_data.clone(), vec![tile, tile])
    });

    let grid = Grid2D { ny: 1024, nx: 1024, data: rng.vec_f32(1024 * 1024, 0.0, 1.0) };
    b.bench(&format!("halo_extract_{tile}x{tile}"), || {
        grid.extract_tile(256, 256, tile, tile, halo, Boundary::Zero)
    });

    b.bench("streamed_diffusion2d_1024_4steps", || {
        let g = grid.clone();
        stencil_runner::run_stencil2d(&rt, "diffusion2d_r1", g, None, 4).unwrap()
    });

    // report end-to-end throughput once
    let (_, m) =
        stencil_runner::run_stencil2d(&rt, "diffusion2d_r1", grid.clone(), None, 16).unwrap();
    println!("\nstreamed diffusion2d 1024^2 x16 steps: {}", m.summary());
    let stats = rt.stats();
    println!(
        "runtime totals: {} executions, execute {:.1}ms, marshal {:.1}ms",
        stats.executions, stats.execute_ms, stats.marshal_ms
    );
}
