//! Chapter 5 bench: regenerates the stencil-accelerator tables/figures
//! (Tables 5-5 … 5-9, Figs. 5-7 … 5-10, model accuracy) and times the
//! tuner — the component whose job is replacing 8–30 h Quartus runs, so
//! its own latency is a paper-relevant number.

use fpga_hpc::benchutil::Bencher;
use fpga_hpc::device::arria_10;
use fpga_hpc::report;
use fpga_hpc::stencil::config::{default_workload, diffusion2d, diffusion3d};
use fpga_hpc::stencil::tuner::tune;

fn main() {
    let b = Bencher::quick();
    println!("=== chapter5 benches: tuner + table regeneration ===\n");
    let dev = arria_10();
    b.bench("tune_diffusion2d_r1_a10", || tune(&diffusion2d(1), &default_workload(2), &dev));
    b.bench("tune_diffusion3d_r4_a10", || tune(&diffusion3d(4), &default_workload(3), &dev));
    for id in ["5-5", "5-6", "5-7", "5-8", "5-9", "fig5-7", "fig5-8", "fig5-9", "fig5-10", "model-accuracy"] {
        let label = format!("table_{id}");
        b.bench(&label, || report::render(id).unwrap());
    }
    for id in ["5-5", "5-6", "5-7", "5-8", "5-9", "model-accuracy"] {
        print!("{}", report::render(id).unwrap());
    }
    print!("{}", report::render("fig5-7").unwrap());
    print!("{}", report::render("fig5-8").unwrap());
    print!("{}", report::render("fig5-9").unwrap());
    print!("{}", report::render("fig5-10").unwrap());
}
