//! Loom model checks for the wave engine's load-bearing concurrency
//! protocols (ISSUE 9 tentpole).  Build and run with:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --test loom --release
//! ```
//!
//! Under `--cfg loom` the crate's [`fpga_hpc::sync`] shim swaps every
//! `Mutex`/`Condvar`/atomic in `runtime::pool`, `coordinator::passdriver`
//! and `coordinator::bufpool` for loom's model-checked doubles, so the
//! models below drive the *real* `WaveTable` / `ReadyQueue` /
//! shard-queue code — not re-implementations — through every
//! interleaving loom's bounded-exhaustive explorer generates.
//!
//! Six protocols are modeled (see the runtime README § Verification
//! for the protocol → model table):
//!
//! 1. dispatch: counter decrement → ready-queue publish
//!    ([`dispatch_diamond_exactly_once_and_writeback_ordered`])
//! 2. cancel-cone sentinel vs. concurrent decrement
//!    ([`cancel_sentinel_vs_concurrent_decrement`],
//!    [`overlapping_cancel_cones_count_each_block_once`],
//!    [`cancel_releases_parked_poppers`])
//! 3. rearm vs. straggler completion under the drain + round-tag fence
//!    ([`rearm_after_drained_round_reseeds_failed_blocks`],
//!    [`round_tag_visible_to_any_callback_that_sees_new_seeds`])
//! 4. pool submit-epoch fence ([`epoch_fence_stale_job_must_skip`])
//! 5. stash/deque stealing ([`stealing_delivers_every_job_exactly_once`])
//! 6. heartbeat/reap handshake: watchdog reap vs. job finish is
//!    exactly-once ([`heartbeat_finish_vs_reap_is_exactly_once`],
//!    [`heartbeat_commit_fence_defeats_reap`])
//!
//! The straggler models deliberately encode the drain phasing the real
//! driver enforces (`wait_idle` completes every callback before
//! `rearm` runs — joins stand in for the drain): without it loom
//! rightly finds counter corruption, which is exactly why the fences
//! exist.  The fence properties themselves are checked as
//! happens-before-conditional assertions mediated by the queue mutex,
//! matching how `lane_main` and the drive-round callback actually
//! order their loads.

#![cfg(loom)]

use fpga_hpc::coordinator::passdriver::{PassMode, ReadyQueue, WaveGraph, WaveTable};
use fpga_hpc::runtime::pool::loom_model::{epoch_stale, ProbeBeat, ProbeQueue};
use fpga_hpc::runtime::pool::JobStatus;
use fpga_hpc::sync::atomic::{AtomicU64, Ordering};
use fpga_hpc::sync::{Arc, Mutex};
use loom::cell::UnsafeCell;
use loom::thread;

/// Run `f` under loom with a preemption bound (2 unless
/// `LOOM_MAX_PREEMPTIONS` overrides it): per loom's guidance, bounding
/// exploration to a few preemptions catches practically all ordering
/// bugs while keeping the search tractable in CI.
fn model<F: Fn() + Send + Sync + 'static>(f: F) {
    let mut b = loom::model::Builder::new();
    if b.preemption_bound.is_none() {
        b.preemption_bound = Some(2);
    }
    b.check(f);
}

/// A miniature [`WaveGraph`]: wave lengths plus explicit
/// `pred -> succ` edges.
struct MiniGraph {
    lens: Vec<usize>,
    /// `preds[gid(succ)]` = list of `(wave, idx)` predecessors.
    preds: Vec<Vec<(usize, usize)>>,
}

impl MiniGraph {
    fn new(lens: &[usize], edges: &[((usize, usize), (usize, usize))]) -> MiniGraph {
        let total: usize = lens.iter().sum();
        let mut g = MiniGraph { lens: lens.to_vec(), preds: vec![Vec::new(); total] };
        for &(p, s) in edges {
            let sid = g.gid(s.0, s.1);
            g.preds[sid].push(p);
        }
        g
    }

    fn gid(&self, w: usize, i: usize) -> usize {
        self.lens[..w].iter().sum::<usize>() + i
    }
}

impl WaveGraph for MiniGraph {
    fn waves(&self) -> usize {
        self.lens.len()
    }

    fn wave_len(&self, w: usize) -> usize {
        self.lens[w]
    }

    fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
        for &(v, j) in &self.preds[self.gid(w, i)] {
            f(v, j);
        }
    }
}

/// Protocol 1 — dispatch.  Diamond graph A -> {B, C} -> D driven by
/// two workers through the real `WaveTable::complete` →
/// `ReadyQueue::push_all` → `ReadyQueue::pop` chain.  Checks:
///
/// * every block is dispatched exactly once (no lost or duplicated
///   dispatch under any interleaving of the final-decrement publish);
/// * both workers' `pop` loops terminate (loom flags the deadlock
///   otherwise);
/// * the AcqRel decrement chain really publishes predecessor
///   write-backs: each worker writes its block's `UnsafeCell` before
///   `complete`, and readers assert the predecessor values — loom's
///   cell instrumentation turns any missing happens-before edge into a
///   detected data race.
#[test]
fn dispatch_diamond_exactly_once_and_writeback_ordered() {
    model(|| {
        let graph = MiniGraph::new(
            &[1, 2, 1],
            &[
                ((0, 0), (1, 0)),
                ((0, 0), (1, 1)),
                ((1, 0), (2, 0)),
                ((1, 1), (2, 0)),
            ],
        );
        let table = Arc::new(WaveTable::new(&graph, PassMode::Pipelined));
        let queue = Arc::new(ReadyQueue::new(table.total(), table.seed()));
        let cells: Arc<Vec<UnsafeCell<u32>>> =
            Arc::new((0..4).map(|_| UnsafeCell::new(0)).collect());
        let log = Arc::new(Mutex::new(Vec::<(usize, usize)>::new()));

        // gid layout: A=0, B=1, C=2, D=3; preds by gid.
        let preds_of = |gid: usize| -> &'static [usize] {
            match gid {
                0 => &[],
                1 | 2 => &[0],
                3 => &[1, 2],
                _ => unreachable!(),
            }
        };
        let gid_of = |(w, i): (usize, usize)| [0, 1, 3][w] + i;

        let worker = |table: Arc<WaveTable>,
                      queue: Arc<ReadyQueue>,
                      cells: Arc<Vec<UnsafeCell<u32>>>,
                      log: Arc<Mutex<Vec<(usize, usize)>>>| {
            move || {
                let mut newly = Vec::new();
                while let Some((w, i)) = queue.pop() {
                    let gid = gid_of((w, i));
                    for &p in preds_of(gid) {
                        // The pop's mutex acquire + the preds' AcqRel
                        // decrement chain must make this read race-free
                        // and show the predecessor's write.
                        let v = cells[p].with(|ptr| unsafe { *ptr });
                        assert_eq!(v, 100 + p as u32, "pred {p} write-back not visible");
                    }
                    cells[gid].with_mut(|ptr| unsafe { *ptr = 100 + gid as u32 });
                    log.lock().unwrap().push((w, i));
                    newly.clear();
                    table.complete(w, i, &mut newly);
                    queue.push_all(&newly);
                }
            }
        };

        let t1 = thread::spawn(worker(
            table.clone(),
            queue.clone(),
            cells.clone(),
            log.clone(),
        ));
        let t2 = thread::spawn(worker(table, queue, cells, log.clone()));
        t1.join().unwrap();
        t2.join().unwrap();

        let mut seen = log.lock().unwrap().clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![(0, 0), (1, 0), (1, 1), (2, 0)]);
    });
}

/// Protocol 2 — cancel-cone sentinel vs. concurrent decrement.
/// B depends on {A, F}; F fails and its cone is cancelled while A's
/// completion concurrently decrements B's counter.  In both
/// interleavings (decrement-then-swap, swap-then-decrement) B must
/// never become ready — a cone member always retains its failed
/// predecessor's incomplete count, and the `u32::MAX` sentinel absorbs
/// the straggling `fetch_sub` — and must be reported cancelled exactly
/// once.
#[test]
fn cancel_sentinel_vs_concurrent_decrement() {
    model(|| {
        let graph = MiniGraph::new(&[2, 1], &[((0, 0), (1, 0)), ((0, 1), (1, 0))]);
        let table = Arc::new(WaveTable::new(&graph, PassMode::Pipelined));

        let t_cancel = {
            let table = table.clone();
            thread::spawn(move || table.cancel(0, 1))
        };
        let t_complete = {
            let table = table.clone();
            thread::spawn(move || {
                let mut ready = Vec::new();
                table.complete(0, 0, &mut ready);
                ready
            })
        };
        let cancelled = t_cancel.join().unwrap();
        let ready = t_complete.join().unwrap();

        assert_eq!(cancelled, vec![(1, 0)], "cone is exactly {{B}}");
        assert!(ready.is_empty(), "B released despite an incomplete predecessor");
    });
}

/// Protocol 2 — overlapping cones.  B depends on {F1, F2}; both fail
/// and cancel concurrently.  The sentinel swap's `!= CANCELLED` test
/// must count B in exactly one of the two returned cones under every
/// interleaving (the queue's dispatch target shrinks by the sum).
#[test]
fn overlapping_cancel_cones_count_each_block_once() {
    model(|| {
        let graph = MiniGraph::new(&[2, 1], &[((0, 0), (1, 0)), ((0, 1), (1, 0))]);
        let table = Arc::new(WaveTable::new(&graph, PassMode::Pipelined));

        let c1 = {
            let table = table.clone();
            thread::spawn(move || table.cancel(0, 0))
        };
        let c2 = {
            let table = table.clone();
            thread::spawn(move || table.cancel(0, 1))
        };
        let n = c1.join().unwrap().len() + c2.join().unwrap().len();
        assert_eq!(n, 1, "B must be counted cancelled exactly once, got {n}");
    });
}

/// Protocol 2/3 — the queue side of cancellation: `cancel(n)` shrinks
/// the dispatch target and must wake a popper parked on an empty
/// queue.  Loom flags the lost-wakeup interleaving as a deadlock if
/// the notify is misplaced.
#[test]
fn cancel_releases_parked_poppers() {
    model(|| {
        let queue = Arc::new(ReadyQueue::new(2, [(0usize, 0usize)]));
        let popper = {
            let queue = queue.clone();
            thread::spawn(move || {
                let mut n = 0;
                while queue.pop().is_some() {
                    n += 1;
                }
                n
            })
        };
        queue.cancel(1);
        assert_eq!(popper.join().unwrap(), 1, "exactly the seeded block dispatches");
    });
}

/// Protocol 3 — rearm after a drained round.  Round 1: A completes
/// while F's terminal failure cancels its cone {B} (concurrently, as
/// in the real harvest).  The joins stand in for `wait_idle`'s drain —
/// the driver's guarantee that no callback is in flight when `rearm`
/// runs.  Then `rearm([F, B])` must reseed exactly the failed block F
/// (B retains its in-set predecessor), and replaying F must release B
/// through the normal completion chain.
#[test]
fn rearm_after_drained_round_reseeds_failed_blocks() {
    model(|| {
        let graph = MiniGraph::new(&[2, 1], &[((0, 0), (1, 0)), ((0, 1), (1, 0))]);
        let table = Arc::new(WaveTable::new(&graph, PassMode::Pipelined));

        let t_complete = {
            let table = table.clone();
            thread::spawn(move || {
                let mut ready = Vec::new();
                table.complete(0, 0, &mut ready);
                ready
            })
        };
        let t_cancel = {
            let table = table.clone();
            thread::spawn(move || table.cancel(0, 1))
        };
        let ready = t_complete.join().unwrap();
        let cancelled = t_cancel.join().unwrap();
        assert!(ready.is_empty());
        assert_eq!(cancelled, vec![(1, 0)]);

        // Drained: both round-1 threads joined.  members = failed ∪ cone.
        let members = [(0usize, 1usize), (1, 0)];
        let seeds = table.rearm(&members);
        assert_eq!(seeds, vec![(0, 1)], "replay reseeds exactly the failed block");

        let mut ready = Vec::new();
        table.complete(0, 1, &mut ready);
        assert_eq!(ready, vec![(1, 0)], "replayed F releases B");
    });
}

/// Protocol 3 — the round-tag fence.  `drive_round` stores the new
/// round tag (Release) *before* publishing the round's seeds through
/// the ready queue's mutex; a completion callback loads the tag
/// (Acquire) after popping.  Model: any popper that receives a
/// round-2 item must therefore observe `round_tag == 2` — the gate
/// `tag != my_round` can never misfire for a current-round callback,
/// and a straggler that sees the new seeds is guaranteed to see the
/// new tag and no-op.
#[test]
fn round_tag_visible_to_any_callback_that_sees_new_seeds() {
    model(|| {
        let tag = Arc::new(AtomicU64::new(1));
        let queue = Arc::new(ReadyQueue::new(2, [(1usize, 0usize)]));

        let driver = {
            let tag = tag.clone();
            let queue = queue.clone();
            thread::spawn(move || {
                // The drive_round order: fence first, then publish.
                tag.store(2, Ordering::Release);
                queue.push_all(&[(2, 0)]);
            })
        };

        while let Some((round, _)) = queue.pop() {
            let seen = tag.load(Ordering::Acquire);
            if round == 2 {
                assert_eq!(seen, 2, "popped round-2 seed but tag store not visible");
            }
            // round == 1: both 1 (gate passes, legitimate) and 2
            // (gate no-ops a straggler) are sound observations.
        }
        driver.join().unwrap();
    });
}

/// Protocol 4 — submit-epoch fence, the exact predicate `lane_main`
/// runs via [`epoch_stale`].  The driver advances the epoch and then
/// enqueues the new round's job; a lane concurrently pops and
/// stale-checks.  Conditional property: if the lane pops the old job
/// while the new job is already visible in the queue (`queued_after ≥
/// 1`, or the new job was popped first), the mutex's happens-before
/// edge forces the Acquire epoch load to see the advance — the old
/// job MUST test stale and be skipped.  The new-epoch job must never
/// test stale.
#[test]
fn epoch_fence_stale_job_must_skip() {
    model(|| {
        let epoch = Arc::new(AtomicU64::new(1));
        let queue = Arc::new(ProbeQueue::new(1));
        queue.push(None, 1); // round-1 job, submitted under epoch 1

        let driver = {
            let epoch = epoch.clone();
            let queue = queue.clone();
            thread::spawn(move || {
                epoch.fetch_add(1, Ordering::AcqRel); // advance_epoch
                queue.push(None, 2); // round-2 job under epoch 2
            })
        };

        let mut pops: Vec<(u64, bool, usize)> = Vec::new();
        for _ in 0..4 {
            if let Some((tag, _stolen, after)) = queue.pop_for(0) {
                let stale = epoch_stale(Some(tag), &epoch);
                pops.push((tag, stale, after));
                if pops.len() == 2 {
                    break;
                }
            } else {
                thread::yield_now();
            }
        }
        driver.join().unwrap();

        let mut tags: Vec<u64> = pops.iter().map(|p| p.0).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), pops.len(), "a job popped twice: {pops:?}");
        let mut saw_new = false;
        for &(tag, stale, after) in &pops {
            match tag {
                2 => {
                    assert!(!stale, "current-epoch job tested stale");
                    saw_new = true;
                }
                1 => {
                    if after >= 1 || saw_new {
                        // The epoch-2 job was already published when
                        // this pop's mutex section ran: the advance is
                        // in its happens-before past, so the fence
                        // must fire.
                        assert!(stale, "old-epoch job ran after the new round was queued");
                    }
                }
                t => panic!("unknown tag {t}"),
            }
        }
    });
}

/// Protocol 5 — stash/deque stealing.  Two shards; shard 0 receives
/// two hinted jobs (the second displaces the first from the one-slot
/// LIFO stash to the deque front) while a thief concurrently pushes a
/// third and pops from the other shard (stealing across).  Under
/// every interleaving each job must be delivered exactly once — no
/// loss from the displacement, no double-pop of the stash (the ABA
/// the one-slot design could hide), and the drain accounts for all
/// three.
#[test]
fn stealing_delivers_every_job_exactly_once() {
    model(|| {
        let queue = Arc::new(ProbeQueue::new(2));
        queue.push(Some(0), 1); // -> shard 0 slot
        queue.push(Some(0), 2); // -> slot, displacing tag 1 to fifo front

        let thief = {
            let queue = queue.clone();
            thread::spawn(move || {
                queue.push(Some(0), 3); // displaces again, concurrently
                let mut got = Vec::new();
                for _ in 0..2 {
                    if let Some((tag, _stolen, _after)) = queue.pop_for(1) {
                        got.push(tag);
                    }
                }
                got
            })
        };

        let mut got = Vec::new();
        for _ in 0..2 {
            if let Some((tag, _stolen, _after)) = queue.pop_for(0) {
                got.push(tag);
            }
        }
        got.extend(thief.join().unwrap());
        // Drain whatever the bounded pop attempts left behind.
        while let Some((tag, _stolen, _after)) = queue.pop_for(0) {
            got.push(tag);
        }

        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3], "lost or duplicated job under stealing");
    });
}

/// Protocol 6 — heartbeat/reap handshake, driven through the *real*
/// `Heartbeat` CAS protocol ([`ProbeBeat`] wraps it and parks the
/// tracked callback in the done-slot exactly like `arm_heartbeat`).
/// A lane finishing its job races the watchdog reaping it: every
/// transfer out of BUSY is a compare-exchange on the packed
/// `(seq, state)` word, so under every interleaving exactly one side
/// must win the callback — no double completion, no lost job — and
/// the loser must observe that it lost (`is_reaped` for the lane, a
/// `None` reap for the watchdog).
#[test]
fn heartbeat_finish_vs_reap_is_exactly_once() {
    model(|| {
        let beat = Arc::new(ProbeBeat::new());
        let fired = Arc::new(Mutex::new(0u32));
        let seq = beat.stamp({
            let fired = fired.clone();
            Box::new(move |_status| *fired.lock().unwrap() += 1)
        });

        let lane = {
            let beat = beat.clone();
            thread::spawn(move || match beat.finish(seq) {
                Some(done) => {
                    done(JobStatus::Ok { retries: 0 });
                    true
                }
                None => {
                    // Lost the claim: the watchdog owns the callback
                    // and the lane must see its ownership is gone.
                    assert!(beat.is_reaped(seq), "finish failed but claim not lost");
                    false
                }
            })
        };
        let watchdog = {
            let beat = beat.clone();
            thread::spawn(move || match beat.try_reap(seq) {
                Some(done) => {
                    done(JobStatus::Skipped);
                    true
                }
                None => false,
            })
        };

        let lane_won = lane.join().unwrap();
        let dog_won = watchdog.join().unwrap();
        assert!(
            lane_won ^ dog_won,
            "exactly one side must own the job (lane {lane_won}, watchdog {dog_won})"
        );
        assert_eq!(*fired.lock().unwrap(), 1, "callback must fire exactly once");
    });
}

/// Protocol 6 — the commit fence.  The lane commits
/// (BUSY -> COMMITTED, the step `commit_current_job` performs before
/// any grid write) and then finishes, while the watchdog races a
/// reap.  If the commit succeeds the job is immune: the reap must
/// return `None` and the lane must win the callback.  If the reap
/// lands first the commit must fail and the lane must back out
/// without finishing.  Either way the callback fires exactly once.
#[test]
fn heartbeat_commit_fence_defeats_reap() {
    model(|| {
        let beat = Arc::new(ProbeBeat::new());
        let fired = Arc::new(Mutex::new(0u32));
        let seq = beat.stamp({
            let fired = fired.clone();
            Box::new(move |_status| *fired.lock().unwrap() += 1)
        });

        let lane = {
            let beat = beat.clone();
            thread::spawn(move || {
                if !beat.try_commit(seq) {
                    // Reaped before the commit point: the job body
                    // backs out before writing anything.
                    assert!(beat.is_reaped(seq), "commit failed but claim not lost");
                    return false;
                }
                // Committed: the write-back is now safe and the finish
                // claim can no longer be contested.
                let done = beat
                    .finish(seq)
                    .expect("a committed job must win the finish claim");
                done(JobStatus::Ok { retries: 0 });
                true
            })
        };
        let watchdog = {
            let beat = beat.clone();
            thread::spawn(move || match beat.try_reap(seq) {
                Some(done) => {
                    done(JobStatus::Skipped);
                    true
                }
                None => false,
            })
        };

        let lane_won = lane.join().unwrap();
        let dog_won = watchdog.join().unwrap();
        assert!(lane_won ^ dog_won, "commit fence must keep ownership exclusive");
        assert_eq!(*fired.lock().unwrap(), 1, "callback must fire exactly once");
    });
}
