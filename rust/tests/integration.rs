//! End-to-end integration tests: AOT artifacts → PJRT runtime →
//! coordinator streaming, verified against the native-Rust oracles.
//!
//! These tests require `artifacts/` (run `make artifacts` first) and a
//! native XLA backend; each one opens with
//! [`fpga_hpc::require_backend!`] and skips when only the vendored
//! shim is linked, so plain `cargo test` stays green everywhere.  They
//! are the Rust-side counterpart of the pytest suite's kernel-vs-oracle
//! checks, now covering the *whole* request path: manifest parsing,
//! literal marshalling, halo extraction, block scheduling, temporal
//! blocking, write-back and reassembly.
//!
//! Every workload runs through the [`Session`] front door.  A lanes=1
//! session is the reference schedule for the lane-invariance tests:
//! with one execute lane the wave driver degenerates to a serial walk
//! in dependency order, so any lane count and either [`PassMode`] must
//! reproduce it bit for bit.

use fpga_hpc::coordinator::grid::{Grid2D, Grid3D};
use std::time::{Duration, Instant};

use fpga_hpc::coordinator::session::{
    Chain, GridInput, Session, Workload, WorkloadOutput, WorkloadStatus,
};
use fpga_hpc::coordinator::{reference, PassMode};
use fpga_hpc::runtime::{Pinning, PoolConfig, Runtime, RuntimePool, Tensor};
use fpga_hpc::testutil::{assert_allclose, max_abs_diff, Rng};

fn runtime() -> Runtime {
    Runtime::open("artifacts").expect("artifacts missing — run `make artifacts`")
}

/// Owning session over a fresh pool with `lanes` execute lanes.
fn session(lanes: usize) -> Session<'static> {
    Session::builder()
        .artifacts("artifacts")
        .lanes(lanes)
        .build()
        .expect("artifacts missing — run `make artifacts`")
}

fn rand_grid2d(ny: usize, nx: usize, seed: u64, lo: f32, hi: f32) -> Grid2D {
    let mut rng = Rng::new(seed);
    let data = rng.vec_f32(ny * nx, lo, hi);
    Grid2D { ny, nx, data }
}

fn rand_grid3d(nz: usize, ny: usize, nx: usize, seed: u64, lo: f32, hi: f32) -> Grid3D {
    let mut rng = Rng::new(seed);
    let data = rng.vec_f32(nz * ny * nx, lo, hi);
    Grid3D { nz, ny, nx, data }
}

fn coeffs_of(rt: &Runtime, artifact: &str) -> Vec<f32> {
    rt.registry()
        .get(artifact)
        .unwrap()
        .meta_f64_list("coeffs")
        .unwrap()
        .into_iter()
        .map(|v| v as f32)
        .collect()
}

#[test]
fn manifest_loads_all_artifacts() {
    fpga_hpc::require_backend!();
    let rt = runtime();
    assert!(rt.registry().len() >= 18, "expected full artifact set");
    for name in ["diffusion2d_r1", "hotspot3d", "nw", "srad", "lud_internal"] {
        assert!(rt.registry().get(name).is_some(), "{name}");
    }
}

#[test]
fn diffusion2d_streamed_matches_reference() {
    fpga_hpc::require_backend!();
    let rt = runtime();
    let s = session(1);
    for radius in [1u32, 2] {
        let artifact = format!("diffusion2d_r{radius}");
        let t = rt.registry().get(&artifact).unwrap().meta_u64("steps").unwrap();
        let coeffs = coeffs_of(&rt, &artifact);
        let grid = rand_grid2d(512, 512, 7 + radius as u64, 0.0, 1.0);
        let steps = 2 * t;
        let report = s
            .run(Workload::stencil2d(artifact.clone(), grid.clone(), None, steps))
            .unwrap();
        assert!(report.ok(), "clean run must report Ok statuses");
        let metrics = report.metrics.clone();
        let out = report.into_output().into_grid2d().unwrap();
        let want = reference::diffusion2d(grid, &coeffs, steps as usize);
        let err = max_abs_diff(&out.data, &want.data);
        assert!(err < 1e-5, "r={radius}: err {err}");
        assert!(metrics.blocks > 0 && metrics.cell_updates > 0);
        assert_eq!(metrics.jobs_failed, 0, "clean run must not count failures");
    }
}

#[test]
fn diffusion2d_partial_blocks_match_reference() {
    fpga_hpc::require_backend!();
    // Grid not a multiple of the 256-block: partial edge blocks extend
    // past the grid and must be clipped exactly.
    let rt = runtime();
    let coeffs = coeffs_of(&rt, "diffusion2d_r1");
    let grid = rand_grid2d(300, 520, 11, 0.0, 1.0);
    let out = session(1)
        .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 4))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    let want = reference::diffusion2d(grid, &coeffs, 4);
    assert!(max_abs_diff(&out.data, &want.data) < 1e-5);
}

#[test]
fn hotspot2d_streamed_matches_reference() {
    fpga_hpc::require_backend!();
    let temp = rand_grid2d(512, 512, 21, 60.0, 90.0);
    let power = rand_grid2d(512, 512, 22, 0.0, 1.0);
    let steps = 8; // 2 passes of T=4
    let out = session(1)
        .run(Workload::stencil2d("hotspot2d", temp.clone(), Some(power.clone()), steps))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    let want = reference::hotspot2d(temp, &power, reference::HotspotParams::default(), steps as usize);
    assert_allclose(&out.data, &want.data, 1e-4, 1e-3, "hotspot2d");
}

#[test]
fn diffusion3d_streamed_matches_reference() {
    fpga_hpc::require_backend!();
    let rt = runtime();
    let coeffs = coeffs_of(&rt, "diffusion3d_r1");
    let grid = rand_grid3d(64, 64, 64, 31, 0.0, 1.0);
    let steps = 4; // 2 passes of T=2
    let out = session(1)
        .run(Workload::stencil3d("diffusion3d_r1", grid.clone(), None, steps))
        .unwrap()
        .into_output()
        .into_grid3d()
        .unwrap();
    let want = reference::diffusion3d(grid, &coeffs, steps as usize);
    assert!(max_abs_diff(&out.data, &want.data) < 1e-5);
}

#[test]
fn hotspot3d_streamed_matches_reference() {
    fpga_hpc::require_backend!();
    let temp = rand_grid3d(48, 48, 48, 41, 60.0, 90.0);
    let power = rand_grid3d(48, 48, 48, 42, 0.0, 1.0);
    let steps = 4;
    let out = session(1)
        .run(Workload::stencil3d("hotspot3d", temp.clone(), Some(power.clone()), steps))
        .unwrap()
        .into_output()
        .into_grid3d()
        .unwrap();
    let want =
        reference::hotspot3d(temp, &power, reference::Hotspot3DParams::default(), steps as usize);
    assert_allclose(&out.data, &want.data, 1e-4, 1e-3, "hotspot3d");
}

#[test]
fn stencil2d_rejects_bad_step_counts() {
    fpga_hpc::require_backend!();
    let grid = rand_grid2d(256, 256, 1, 0.0, 1.0);
    // diffusion2d_r1 has T=4; 6 steps is not a multiple
    let r = session(1).run(Workload::stencil2d("diffusion2d_r1", grid, None, 6));
    assert!(r.is_err());
}

#[test]
fn pathfinder_app_matches_reference() {
    fpga_hpc::require_backend!();
    let mut rng = Rng::new(55);
    let rows = 17; // 1 + 2 fused chunks of 8
    let cols = 5_000; // exercises a partial final block (width 4096)
    let wall: Vec<Vec<i32>> = (0..rows).map(|_| rng.vec_i32(cols, 0, 10)).collect();
    let report = session(1).run(Workload::pathfinder(wall.clone())).unwrap();
    assert!(report.ok());
    let metrics = report.metrics.clone();
    let got = report.into_output().into_row().unwrap();
    let want = reference::pathfinder(&wall);
    assert_eq!(got, want);
    assert!(metrics.blocks >= 4);
}

#[test]
fn nw_app_matches_reference() {
    fpga_hpc::require_backend!();
    let mut rng = Rng::new(66);
    let n = 128; // 2x2 blocks of 64
    let reference_matrix: Vec<Vec<i32>> =
        (0..=n).map(|_| rng.vec_i32(n + 1, -5, 15)).collect();
    let got = session(1)
        .run(Workload::nw(reference_matrix.clone(), 10))
        .unwrap()
        .into_output()
        .into_score_matrix()
        .unwrap();
    let want = reference::nw(&reference_matrix, 10);
    assert_eq!(got, want);
}

#[test]
fn nw_app_rejects_wrong_penalty() {
    fpga_hpc::require_backend!();
    let refm = vec![vec![0i32; 65]; 65];
    assert!(session(1).run(Workload::nw(refm, 3)).is_err());
}

#[test]
fn srad_app_matches_reference() {
    fpga_hpc::require_backend!();
    let img = rand_grid2d(512, 512, 77, 0.5, 2.0);
    let steps = 2;
    let got = session(1)
        .run(Workload::srad(img.clone(), steps))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    let want = reference::srad(img, 0.5, steps as usize);
    assert_allclose(&got.data, &want.data, 5e-4, 5e-4, "srad");
}

#[test]
fn lud_app_matches_reference() {
    fpga_hpc::require_backend!();
    let mut rng = Rng::new(88);
    let n = 128; // 2x2 blocks of 64
    let a: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            (0..n)
                .map(|j| rng.f32_in(-1.0, 1.0) + if i == j { n as f32 } else { 0.0 })
                .collect()
        })
        .collect();
    let got = session(1)
        .run(Workload::lud(a.clone()))
        .unwrap()
        .into_output()
        .into_matrix()
        .unwrap();
    let want = reference::lud(&a);
    for i in 0..n {
        assert_allclose(&got[i], &want[i], 1e-3, 1e-3, &format!("lud row {i}"));
    }
}

#[test]
fn lane_count_invariance_hotspot2d() {
    fpga_hpc::require_backend!();
    // lanes=1 and lanes=4 must produce bit-identical grids: block
    // compute is identical per block and interiors are disjoint, so
    // writeback order is invisible.
    let temp = rand_grid2d(512, 512, 21, 60.0, 90.0);
    let power = rand_grid2d(512, 512, 22, 0.0, 1.0);
    let steps = 8;
    let r1 = session(1)
        .run(Workload::stencil2d("hotspot2d", temp.clone(), Some(power.clone()), steps))
        .unwrap();
    let r4 = session(4)
        .run(Workload::stencil2d("hotspot2d", temp.clone(), Some(power.clone()), steps))
        .unwrap();
    assert_eq!(r1.metrics.blocks, r4.metrics.blocks);
    let one = r1.into_output().into_grid2d().unwrap();
    let four = r4.into_output().into_grid2d().unwrap();
    assert_eq!(one.data, four.data, "hotspot2d: lanes=1 vs lanes=4 differ");
}

#[test]
fn lane_count_invariance_diffusion3d() {
    fpga_hpc::require_backend!();
    let grid = rand_grid3d(64, 64, 64, 31, 0.0, 1.0);
    let steps = 4;
    let one = session(1)
        .run(Workload::stencil3d("diffusion3d_r1", grid.clone(), None, steps))
        .unwrap()
        .into_output()
        .into_grid3d()
        .unwrap();
    let four = session(4)
        .run(Workload::stencil3d("diffusion3d_r1", grid.clone(), None, steps))
        .unwrap()
        .into_output()
        .into_grid3d()
        .unwrap();
    assert_eq!(one.data, four.data, "diffusion3d: lanes=1 vs lanes=4 differ");
}

#[test]
fn pipelined_matches_barrier_bitwise_at_lanes_1_2_4() {
    fpga_hpc::require_backend!();
    // The cross-pass pipelined schedule must be bitwise identical to
    // the drain-between-passes baseline at every lane count: per-block
    // compute is deterministic, interiors are disjoint, and the
    // dependency table only reorders execution, never inputs.  Hotspot
    // exercises the aux (power) stream through the shared read view.
    let temp = rand_grid2d(512, 512, 121, 60.0, 90.0);
    let power = rand_grid2d(512, 512, 122, 0.0, 1.0);
    let steps = 16; // 4 passes of T=4: real cross-pass overlap
    let single = session(1)
        .run(Workload::stencil2d("hotspot2d", temp.clone(), Some(power.clone()), steps))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).unwrap();
        let rb = Session::over(&pool)
            .with_mode(PassMode::Barrier)
            .run(Workload::stencil2d("hotspot2d", temp.clone(), Some(power.clone()), steps))
            .unwrap();
        let rp = Session::over(&pool)
            .with_mode(PassMode::Pipelined)
            .run(Workload::stencil2d("hotspot2d", temp.clone(), Some(power.clone()), steps))
            .unwrap();
        assert_eq!(rb.metrics.blocks, rp.metrics.blocks, "lanes={lanes}: block counts differ");
        let bar = rb.into_output().into_grid2d().unwrap();
        let pipe = rp.into_output().into_grid2d().unwrap();
        assert_eq!(bar.data, pipe.data, "lanes={lanes}: barrier vs pipelined differ");
        assert_eq!(pipe.data, single.data, "lanes={lanes}: pipelined vs lanes=1 differ");
    }
}

#[test]
fn pipelined_matches_barrier_bitwise_3d() {
    fpga_hpc::require_backend!();
    let grid = rand_grid3d(64, 64, 64, 131, 0.0, 1.0);
    let steps = 8; // 4 passes of T=2
    let pool = RuntimePool::open("artifacts", 4).unwrap();
    let bar = Session::over(&pool)
        .with_mode(PassMode::Barrier)
        .run(Workload::stencil3d("diffusion3d_r1", grid.clone(), None, steps))
        .unwrap()
        .into_output()
        .into_grid3d()
        .unwrap();
    let pipe = Session::over(&pool)
        .with_mode(PassMode::Pipelined)
        .run(Workload::stencil3d("diffusion3d_r1", grid.clone(), None, steps))
        .unwrap()
        .into_output()
        .into_grid3d()
        .unwrap();
    assert_eq!(bar.data, pipe.data, "3D barrier vs pipelined differ");
    let single = session(1)
        .run(Workload::stencil3d("diffusion3d_r1", grid, None, steps))
        .unwrap()
        .into_output()
        .into_grid3d()
        .unwrap();
    assert_eq!(pipe.data, single.data, "3D pipelined vs lanes=1 differ");
}

#[test]
fn pipelined_partial_blocks_match_reference() {
    fpga_hpc::require_backend!();
    // Odd geometry: partial edge blocks keep their clipping semantics
    // under the dependency-pipelined schedule.
    let rt = runtime();
    let coeffs = coeffs_of(&rt, "diffusion2d_r1");
    let grid = rand_grid2d(300, 520, 141, 0.0, 1.0);
    let steps = 16;
    let out = session(4)
        .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, steps))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    let want = reference::diffusion2d(grid, &coeffs, steps as usize);
    assert!(max_abs_diff(&out.data, &want.data) < 1e-5);
}

#[test]
fn pathfinder_lanes_matches_reference() {
    fpga_hpc::require_backend!();
    let mut rng = Rng::new(57);
    let rows = 17; // 1 + 2 fused chunks of 8
    let cols = 5_000; // partial final block (width 4096)
    let wall: Vec<Vec<i32>> = (0..rows).map(|_| rng.vec_i32(cols, 0, 10)).collect();
    let want = reference::pathfinder(&wall);
    for lanes in [1usize, 4] {
        let report = session(lanes).run(Workload::pathfinder(wall.clone())).unwrap();
        let metrics = report.metrics.clone();
        let got = report.into_output().into_row().unwrap();
        assert_eq!(got, want, "lanes={lanes}");
        assert!(metrics.blocks >= 4);
    }
}

#[test]
fn pathfinder_wave_pipelined_matches_barrier_at_lanes_1_2_4() {
    fpga_hpc::require_backend!();
    // Deeper run (8 waves) so the pipelined schedule really crosses
    // wave boundaries; results must be bit-identical to the
    // wave-serial baseline and the lanes=1 reference.
    let mut rng = Rng::new(59);
    let rows = 65; // 1 + 8 fused chunks of 8
    let cols = 9_000; // 3 column blocks, partial tail
    let wall: Vec<Vec<i32>> = (0..rows).map(|_| rng.vec_i32(cols, 0, 10)).collect();
    let single = session(1)
        .run(Workload::pathfinder(wall.clone()))
        .unwrap()
        .into_output()
        .into_row()
        .unwrap();
    assert_eq!(single, reference::pathfinder(&wall));
    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).unwrap();
        let rb = Session::over(&pool)
            .with_mode(PassMode::Barrier)
            .run(Workload::pathfinder(wall.clone()))
            .unwrap();
        let rp = Session::over(&pool)
            .with_mode(PassMode::Pipelined)
            .run(Workload::pathfinder(wall.clone()))
            .unwrap();
        let (mb, mp) = (rb.metrics.clone(), rp.metrics.clone());
        let bar = rb.into_output().into_row().unwrap();
        let pipe = rp.into_output().into_row().unwrap();
        assert_eq!(bar, pipe, "lanes={lanes}: barrier vs pipelined differ");
        assert_eq!(pipe, single, "lanes={lanes}: pipelined vs lanes=1 differ");
        assert_eq!(mb.blocks, mp.blocks);
        assert_eq!(mb.cell_updates, mp.cell_updates);
        assert!(mb.pipeline_depth_max <= 1, "barrier stayed wave-serial");
        assert_eq!(mb.overlap_starts, 0);
    }
}

#[test]
fn nw_wave_pipelined_matches_barrier_at_lanes_1_2_4() {
    fpga_hpc::require_backend!();
    let mut rng = Rng::new(67);
    let n = 256; // 4x4 blocks of 64: 7 anti-diagonal waves
    let reference_matrix: Vec<Vec<i32>> =
        (0..=n).map(|_| rng.vec_i32(n + 1, -5, 15)).collect();
    let single = session(1)
        .run(Workload::nw(reference_matrix.clone(), 10))
        .unwrap()
        .into_output()
        .into_score_matrix()
        .unwrap();
    assert_eq!(single, reference::nw(&reference_matrix, 10));
    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).unwrap();
        let rb = Session::over(&pool)
            .with_mode(PassMode::Barrier)
            .run(Workload::nw(reference_matrix.clone(), 10))
            .unwrap();
        let rp = Session::over(&pool)
            .with_mode(PassMode::Pipelined)
            .run(Workload::nw(reference_matrix.clone(), 10))
            .unwrap();
        assert_eq!(rb.metrics.blocks, 16);
        assert_eq!(rp.metrics.blocks, 16);
        let bar = rb.into_output().into_score_matrix().unwrap();
        let pipe = rp.into_output().into_score_matrix().unwrap();
        assert_eq!(bar, pipe, "lanes={lanes}: barrier vs pipelined differ");
        assert_eq!(pipe, single, "lanes={lanes}: pipelined vs lanes=1 differ");
    }
}

#[test]
fn nw_lanes_rejects_wrong_penalty() {
    fpga_hpc::require_backend!();
    let pool = RuntimePool::open("artifacts", 1).unwrap();
    let refm = vec![vec![0i32; 65]; 65];
    assert!(Session::over(&pool).run(Workload::nw(refm, 3)).is_err());
}

#[test]
fn srad_wave_pipelined_matches_barrier_at_lanes_1_2_4() {
    fpga_hpc::require_backend!();
    // The two-stage edge (full reduction→stencil, span stencil→next
    // reduction) must not change a single bit: q0 partials are summed
    // in tile order, stencil inputs are fixed by the dependency order.
    let img = rand_grid2d(512, 512, 79, 0.5, 2.0);
    let steps = 4;
    let single = session(1)
        .run(Workload::srad(img.clone(), steps))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).unwrap();
        let rb = Session::over(&pool)
            .with_mode(PassMode::Barrier)
            .run(Workload::srad(img.clone(), steps))
            .unwrap();
        let rp = Session::over(&pool)
            .with_mode(PassMode::Pipelined)
            .run(Workload::srad(img.clone(), steps))
            .unwrap();
        assert_eq!(rb.metrics.blocks, rp.metrics.blocks);
        assert_eq!(rb.metrics.cell_updates, 512 * 512 * steps);
        let bar = rb.into_output().into_grid2d().unwrap();
        let pipe = rp.into_output().into_grid2d().unwrap();
        assert_eq!(bar.data, pipe.data, "lanes={lanes}: barrier vs pipelined differ");
        assert_eq!(pipe.data, single.data, "lanes={lanes}: pipelined vs lanes=1 differ");
    }
    // And the oracle still agrees within tolerance.
    let want = reference::srad(img, 0.5, steps as usize);
    assert_allclose(&single.data, &want.data, 1e-3, 1e-3, "srad lanes");
}

#[test]
fn lud_wave_pipelined_matches_barrier_at_lanes_1_2_4() {
    fpga_hpc::require_backend!();
    let mut rng = Rng::new(89);
    let n = 256; // 4x4 blocks of 64: 12 waves
    let a: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            (0..n)
                .map(|j| rng.f32_in(-1.0, 1.0) + if i == j { n as f32 } else { 0.0 })
                .collect()
        })
        .collect();
    let single = session(1)
        .run(Workload::lud(a.clone()))
        .unwrap()
        .into_output()
        .into_matrix()
        .unwrap();
    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).unwrap();
        let rb = Session::over(&pool)
            .with_mode(PassMode::Barrier)
            .run(Workload::lud(a.clone()))
            .unwrap();
        let rp = Session::over(&pool)
            .with_mode(PassMode::Pipelined)
            .run(Workload::lud(a.clone()))
            .unwrap();
        assert_eq!(rb.metrics.blocks, rp.metrics.blocks);
        let bar = rb.into_output().into_matrix().unwrap();
        let pipe = rp.into_output().into_matrix().unwrap();
        assert_eq!(bar, pipe, "lanes={lanes}: barrier vs pipelined differ");
        assert_eq!(pipe, single, "lanes={lanes}: pipelined vs lanes=1 differ");
    }
    // Accuracy against the f64 oracle (blocked f32 vs f64 accumulation).
    let want = reference::lud(&a);
    for i in 0..n {
        assert_allclose(&single[i], &want[i], 1e-3, 1e-3, &format!("lud lanes row {i}"));
    }
}

#[test]
fn descriptor_pool_reuses_in_steady_state() {
    fpga_hpc::require_backend!();
    // The i32 boundary descriptors come from their own keyed pool:
    // after warm-up, passes allocate no descriptor buffers either.
    let grid = rand_grid2d(1024, 1024, 103, 0.0, 1.0);
    let report = session(1)
        .run(Workload::stencil2d("diffusion2d_r1", grid, None, 8))
        .unwrap();
    let m = &report.metrics;
    let blocks_per_pass = m.blocks / 2;
    assert!(blocks_per_pass > 0);
    assert!(
        m.desc_pool_misses <= blocks_per_pass,
        "descriptor misses {} exceed pass-1 requests {blocks_per_pass}",
        m.desc_pool_misses
    );
    assert!(
        m.desc_pool_hits >= blocks_per_pass,
        "pass 2 descriptors should be pool hits, got {} of {blocks_per_pass}",
        m.desc_pool_hits
    );
}

#[test]
fn steady_state_passes_reuse_tile_buffers() {
    fpga_hpc::require_backend!();
    // Two passes (T=4, steps=8): pass 1 may allocate (pool warm-up),
    // pass 2 must be served entirely from the recycle pool — zero
    // per-block heap allocations for tile extraction in steady state.
    let grid = rand_grid2d(1024, 1024, 99, 0.0, 1.0);
    let report = session(1)
        .run(Workload::stencil2d("diffusion2d_r1", grid, None, 8))
        .unwrap();
    let m = &report.metrics;
    let blocks_per_pass = m.blocks / 2;
    assert!(blocks_per_pass > 0);
    assert!(
        m.pool_misses <= blocks_per_pass,
        "misses {} exceed pass-1 tile requests {blocks_per_pass} — steady-state passes allocated",
        m.pool_misses
    );
    assert!(
        m.pool_hits >= blocks_per_pass,
        "pass 2 should be all pool hits, got {} of {blocks_per_pass}",
        m.pool_hits
    );
}

#[test]
fn pooled_runner_reuses_tile_buffers() {
    fpga_hpc::require_backend!();
    let grid = rand_grid2d(1024, 1024, 101, 0.0, 1.0);
    let pool = RuntimePool::open("artifacts", 2).unwrap();
    let report = Session::over(&pool)
        .run(Workload::stencil2d("diffusion2d_r1", grid, None, 8))
        .unwrap();
    let m = &report.metrics;
    let blocks_per_pass = m.blocks / 2;
    assert!(
        m.pool_misses <= blocks_per_pass,
        "lane path: steady-state passes allocated ({} misses)",
        m.pool_misses
    );
    assert!(m.pool_hits >= blocks_per_pass);
}

#[test]
fn runtime_pool_executes_and_aggregates_stats() {
    fpga_hpc::require_backend!();
    let pool = RuntimePool::open("artifacts", 2).unwrap();
    assert_eq!(pool.lanes(), 2);
    pool.warmup_artifact("sum_sumsq").unwrap();
    let spec = pool.registry().get("sum_sumsq").unwrap().clone();
    let n = spec.inputs[0].shape[0];
    let out = pool
        .execute("sum_sumsq", vec![Tensor::F32(vec![1.0; n * n], vec![n, n])])
        .unwrap();
    assert!((out[0].as_f32()[0] - (n * n) as f32).abs() < 1.0);
    let stats = pool.stats();
    assert!(stats.executions >= 1);
    assert!(stats.compile_ms > 0.0, "warmup compiles on every lane");
}

#[test]
fn runtime_pool_surfaces_lane_errors_and_recovers() {
    fpga_hpc::require_backend!();
    let pool = RuntimePool::open("artifacts", 2).unwrap();
    pool.submit(|_, rt| rt.execute("no_such_artifact", &[]).map(|_| ()));
    let err = pool.wait_idle().expect_err("lane error must surface");
    assert!(format!("{err}").contains("no_such_artifact"), "got: {err}");
    // The pool un-poisons after reporting and keeps working.
    pool.wait_idle().unwrap();
    let spec = pool.registry().get("sum_sumsq").unwrap().clone();
    let n = spec.inputs[0].shape[0];
    pool.execute("sum_sumsq", vec![Tensor::F32(vec![0.5; n * n], vec![n, n])])
        .unwrap();
}

#[test]
fn runtime_pool_surfaces_job_panics() {
    fpga_hpc::require_backend!();
    let pool = RuntimePool::open("artifacts", 1).unwrap();
    pool.submit(|_, _| panic!("job exploded"));
    let err = pool.wait_idle().expect_err("panic must surface as error");
    assert!(format!("{err}").contains("job exploded"), "got: {err}");
}

#[test]
fn runtime_rejects_shape_mismatch() {
    fpga_hpc::require_backend!();
    let rt = runtime();
    let bad = Tensor::F32(vec![0.0; 16], vec![4, 4]);
    assert!(rt.execute("diffusion2d_r1", &[bad]).is_err());
}

#[test]
fn runtime_stats_accumulate() {
    fpga_hpc::require_backend!();
    let rt = runtime();
    let spec = rt.registry().get("sum_sumsq").unwrap().clone();
    let n = spec.inputs[0].shape[0];
    let t = Tensor::F32(vec![1.0; n * n], vec![n, n]);
    let out = rt.execute("sum_sumsq", &[t]).unwrap();
    assert!((out[0].as_f32()[0] - (n * n) as f32).abs() < 1.0);
    let stats = rt.stats();
    assert_eq!(stats.executions, 1);
    assert!(stats.execute_ms > 0.0);
}

// ---------------------------------------------------------------------------
// Session API: the typed front door (PR 4)
// ---------------------------------------------------------------------------

#[test]
fn session_runs_every_workload_against_oracles() {
    fpga_hpc::require_backend!();
    // Every workload runs through Session against its native-Rust
    // oracle, and every clean run reports fault-free: all statuses Ok,
    // no cancellations, zero failed jobs.
    let session4 = session(4);
    let check_clean = |report: &fpga_hpc::coordinator::session::RunReport, what: &str| {
        assert!(report.ok(), "{what}: clean run must be Ok");
        assert!(report.cancelled.is_empty(), "{what}: clean run cancelled blocks");
        assert!(report.first_fault().is_none(), "{what}: clean run reported a fault");
        assert_eq!(report.metrics.jobs_failed, 0, "{what}: clean run counted failures");
    };

    // stencil2d (aux stream) + stencil3d
    let temp = rand_grid2d(512, 512, 21, 60.0, 90.0);
    let power = rand_grid2d(512, 512, 22, 0.0, 1.0);
    let r = session4
        .run(Workload::stencil2d("hotspot2d", temp.clone(), Some(power.clone()), 8))
        .unwrap();
    check_clean(&r, "hotspot2d");
    let got = r.into_output().into_grid2d().unwrap();
    let want = reference::hotspot2d(temp, &power, reference::HotspotParams::default(), 8);
    assert_allclose(&got.data, &want.data, 1e-4, 1e-3, "session hotspot2d");

    let g3 = rand_grid3d(48, 48, 48, 41, 60.0, 90.0);
    let p3 = rand_grid3d(48, 48, 48, 42, 0.0, 1.0);
    let r = session4
        .run(Workload::stencil3d("hotspot3d", g3.clone(), Some(p3.clone()), 4))
        .unwrap();
    check_clean(&r, "hotspot3d");
    let got3 = r.into_output().into_grid3d().unwrap();
    let want3 = reference::hotspot3d(g3, &p3, reference::Hotspot3DParams::default(), 4);
    assert_allclose(&got3.data, &want3.data, 1e-4, 1e-3, "session hotspot3d");

    // stencil2d_with_scalar (SRAD's inner stage): no standalone oracle,
    // so pin lane invariance — lanes=4 bitwise equals lanes=1.
    let img = rand_grid2d(512, 512, 23, 0.5, 2.0);
    let single_s = session(1)
        .run(Workload::stencil2d_with_scalar("srad", img.clone(), 0.25))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    let r = session4
        .run(Workload::stencil2d_with_scalar("srad", img.clone(), 0.25))
        .unwrap();
    check_clean(&r, "srad-scalar");
    let got_s = r.into_output().into_grid2d().unwrap();
    assert_eq!(got_s.data, single_s.data, "session srad-scalar pass != lanes=1");

    // the four Ch. 4 apps
    let mut rng = Rng::new(55);
    let wall: Vec<Vec<i32>> = (0..17).map(|_| rng.vec_i32(5_000, 0, 10)).collect();
    let r = session4.run(Workload::pathfinder(wall.clone())).unwrap();
    check_clean(&r, "pathfinder");
    let pf = r.into_output().into_row().unwrap();
    assert_eq!(pf, reference::pathfinder(&wall), "session pathfinder != oracle");

    let refm: Vec<Vec<i32>> = (0..=128).map(|_| rng.vec_i32(129, -5, 15)).collect();
    let r = session4.run(Workload::nw(refm.clone(), 10)).unwrap();
    check_clean(&r, "nw");
    let nw = r.into_output().into_score_matrix().unwrap();
    assert_eq!(nw, reference::nw(&refm, 10), "session nw != oracle");

    let r = session4.run(Workload::srad(img.clone(), 2)).unwrap();
    check_clean(&r, "srad");
    let srad = r.into_output().into_grid2d().unwrap();
    let srad_want = reference::srad(img, 0.5, 2);
    assert_allclose(&srad.data, &srad_want.data, 1e-3, 1e-3, "session srad");

    let a: Vec<Vec<f32>> = (0..128)
        .map(|i| {
            (0..128)
                .map(|j| rng.f32_in(-1.0, 1.0) + if i == j { 128.0 } else { 0.0 })
                .collect()
        })
        .collect();
    let r = session4.run(Workload::lud(a.clone())).unwrap();
    check_clean(&r, "lud");
    let lud = r.into_output().into_matrix().unwrap();
    let lud_want = reference::lud(&a);
    for i in 0..128 {
        assert_allclose(&lud[i], &lud_want[i], 1e-3, 1e-3, &format!("session lud row {i}"));
    }
}

#[test]
fn session_reports_per_run_metrics_and_accumulates_totals() {
    fpga_hpc::require_backend!();
    // The metrics-bleed fix: two identical runs on one session must
    // report identical per-run counters (not 1x then 2x), while the
    // session totals accumulate and reset on demand.
    let pool = RuntimePool::open("artifacts", 2).unwrap();
    let session = Session::over(&pool);
    let grid = rand_grid2d(512, 512, 31, 0.0, 1.0);
    let r1 = session
        .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 8))
        .unwrap();
    let r2 = session
        .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 8))
        .unwrap();
    assert_eq!(r1.metrics.blocks, r2.metrics.blocks, "per-run blocks must not accumulate");
    assert_eq!(
        r1.metrics.cell_updates, r2.metrics.cell_updates,
        "per-run cell updates must not accumulate"
    );
    assert!(r1.elapsed >= r1.metrics.wall, "elapsed includes warmup + lowering");
    let totals = session.metrics();
    assert_eq!(totals.blocks, r1.metrics.blocks + r2.metrics.blocks);
    assert_eq!(totals.cell_updates, r1.metrics.cell_updates * 2);
    session.reset_metrics();
    assert_eq!(session.metrics().blocks, 0, "reset zeroes the session totals");
}

#[test]
fn fused_srad_stencil_chain_matches_backtoback_at_lanes_1_2_4() {
    fpga_hpc::require_backend!();
    // Acceptance: a heterogeneous chain through a single spliced
    // WaveGraph with no inter-app wait_idle, bitwise identical to the
    // back-to-back barriered reference.
    let img = rand_grid2d(512, 512, 83, 0.5, 2.0);
    let srad_steps = 2u64;
    let sten_steps = 16u64;

    // Back-to-back barriered reference (two separate runs).
    let pool_ref = RuntimePool::open("artifacts", 4).unwrap();
    let barriered = Session::over(&pool_ref).with_mode(PassMode::Barrier);
    let mid = barriered
        .run(Workload::srad(img.clone(), srad_steps))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    let want = barriered
        .run(Workload::stencil2d("diffusion2d_r1", mid, None, sten_steps))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();

    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).unwrap();
        for mode in [PassMode::Barrier, PassMode::Pipelined] {
            let report = Session::over(&pool)
                .with_mode(mode)
                .run(Workload::srad(img.clone(), srad_steps).then(Workload::stencil2d(
                    "diffusion2d_r1",
                    GridInput::Upstream,
                    None,
                    sten_steps,
                )))
                .unwrap();
            assert!(report.ok(), "lanes={lanes} {mode:?}: fused chain must be fault-free");
            assert_eq!(report.outputs.len(), 2);
            assert_eq!(
                report.outputs[0],
                WorkloadOutput::Piped,
                "spliced stage's grid is consumed in place"
            );
            let got = report.into_output().into_grid2d().unwrap();
            assert_eq!(
                got.data, want.data,
                "lanes={lanes} {mode:?}: fused chain != back-to-back barriered"
            );
        }
    }
}

#[test]
fn fused_chain_overlaps_across_the_seam() {
    fpga_hpc::require_backend!();
    // pathfinder.then(nw) shares one wave graph with no seam edges at
    // all: NW's first anti-diagonal seeds immediately and must be
    // dispatched while Pathfinder waves are still incomplete — the
    // fused run reports pipeline depth > 1 across the seam, and both
    // results stay bitwise identical to their standalone runs.
    let mut rng = Rng::new(91);
    let wall: Vec<Vec<i32>> = (0..65).map(|_| rng.vec_i32(9_000, 0, 10)).collect();
    let refm: Vec<Vec<i32>> = (0..=256).map(|_| rng.vec_i32(257, -5, 15)).collect();

    // Back-to-back barriered reference: two separate wave-serial runs.
    let pool_ref = RuntimePool::open("artifacts", 4).unwrap();
    let barriered = Session::over(&pool_ref).with_mode(PassMode::Barrier);
    let pf_want = barriered
        .run(Workload::pathfinder(wall.clone()))
        .unwrap()
        .into_output()
        .into_row()
        .unwrap();
    let nw_want = barriered
        .run(Workload::nw(refm.clone(), 10))
        .unwrap()
        .into_output()
        .into_score_matrix()
        .unwrap();
    assert_eq!(pf_want, reference::pathfinder(&wall), "barriered pathfinder vs oracle");
    assert_eq!(nw_want, reference::nw(&refm, 10), "barriered nw vs oracle");

    for lanes in [1usize, 2, 4] {
        let pool = RuntimePool::open("artifacts", lanes).unwrap();
        let report = Session::over(&pool)
            .run(Workload::pathfinder(wall.clone()).then(Workload::nw(refm.clone(), 10)))
            .unwrap();
        assert!(
            report.metrics.pipeline_depth_max > 1,
            "lanes={lanes}: fused independent chain must overlap across the seam (depth {})",
            report.metrics.pipeline_depth_max
        );
        let mut outputs = report.outputs;
        let nw_got = outputs.pop().unwrap().into_score_matrix().unwrap();
        let pf_got = outputs.pop().unwrap().into_row().unwrap();
        assert_eq!(pf_got, pf_want, "lanes={lanes}: fused pathfinder != back-to-back");
        assert_eq!(nw_got, nw_want, "lanes={lanes}: fused nw != back-to-back");
    }
}

#[test]
fn fused_piped_chain_reports_depth_and_srad_stencil_accuracy() {
    fpga_hpc::require_backend!();
    // Depth observability on the data-dependent chain: the fused
    // pipelined run must report cross-wave depth > 1, and the final
    // grid still tracks the native oracle end to end.
    let img = rand_grid2d(512, 512, 97, 0.5, 2.0);
    let pool = RuntimePool::open("artifacts", 4).unwrap();
    let report = Session::over(&pool)
        .run(
            Workload::srad(img.clone(), 2)
                .then(Workload::stencil2d("diffusion2d_r1", GridInput::Upstream, None, 16)),
        )
        .unwrap();
    assert!(
        report.metrics.pipeline_depth_max > 1,
        "pipelined chain stayed wave-serial (depth {})",
        report.metrics.pipeline_depth_max
    );
    let got = report.into_output().into_grid2d().unwrap();
    let rt = runtime();
    let coeffs = coeffs_of(&rt, "diffusion2d_r1");
    let mid = reference::srad(img, 0.5, 2);
    let want = reference::diffusion2d(mid, &coeffs, 16);
    // srad tolerance dominates (the stencil only diffuses it further).
    assert_allclose(&got.data, &want.data, 1e-3, 1e-3, "fused srad->stencil vs oracle");
}

#[test]
fn session_rejects_upstream_without_producer() {
    fpga_hpc::require_backend!();
    let pool = RuntimePool::open("artifacts", 1).unwrap();
    let session = Session::over(&pool);
    let r = session.run(Workload::stencil2d("diffusion2d_r1", GridInput::Upstream, None, 4));
    assert!(r.is_err(), "Upstream on a chain head must be rejected");
    // A 9-row wall (8 = one fused chunk) lowers fine; the error must
    // come from srad trying to pipe off a grid-less producer.
    let r = session.run(
        Workload::pathfinder(vec![vec![0; 64]; 9]).then(Workload::srad(GridInput::Upstream, 1)),
    );
    assert!(r.is_err(), "piping from a grid-less producer must be rejected");
}

// ---------------------------------------------------------------------------
// Locality-aware scheduling (PR 7): sharded queues, affinity, pinning
// ---------------------------------------------------------------------------

/// Pool with an explicit scheduler engine: `sharded: false` is the
/// literal pre-PR 7 global-FIFO engine, kept as the identity baseline.
fn pool_with(lanes: usize, sharded: bool) -> RuntimePool {
    RuntimePool::open_with(
        "artifacts",
        PoolConfig { lanes, pinning: Pinning::None, sharded },
    )
    .expect("artifacts missing — run `make artifacts`")
}

#[test]
fn sharded_scheduler_matches_global_queue_bitwise() {
    fpga_hpc::require_backend!();
    // Acceptance: for every workload shape — both stencils, all four
    // Ch. 4 apps, and a piped heterogeneous chain — the sharded
    // work-stealing scheduler must reproduce the global-queue engine
    // bit for bit at lanes 1, 2 and 4 under both schedules.  Stealing
    // and affinity only move *where* a block runs, never its inputs.
    let temp = rand_grid2d(256, 256, 211, 60.0, 90.0);
    let power = rand_grid2d(256, 256, 212, 0.0, 1.0);
    let g3 = rand_grid3d(32, 32, 32, 213, 0.0, 1.0);
    let mut rng = Rng::new(214);
    let wall: Vec<Vec<i32>> = (0..17).map(|_| rng.vec_i32(5_000, 0, 10)).collect();
    let refm: Vec<Vec<i32>> = (0..=128).map(|_| rng.vec_i32(129, -5, 15)).collect();
    let img = rand_grid2d(256, 256, 215, 0.5, 2.0);
    let a: Vec<Vec<f32>> = (0..128)
        .map(|i| {
            (0..128)
                .map(|j| rng.f32_in(-1.0, 1.0) + if i == j { 128.0 } else { 0.0 })
                .collect()
        })
        .collect();

    let cases: Vec<(&str, Box<dyn Fn() -> Chain>)> = vec![
        ("hotspot2d", {
            let (t, p) = (temp.clone(), power.clone());
            Box::new(move || Workload::stencil2d("hotspot2d", t.clone(), Some(p.clone()), 8).into())
        }),
        ("diffusion3d", {
            let g = g3.clone();
            Box::new(move || Workload::stencil3d("diffusion3d_r1", g.clone(), None, 4).into())
        }),
        ("pathfinder", {
            let w = wall.clone();
            Box::new(move || Workload::pathfinder(w.clone()).into())
        }),
        ("nw", {
            let r = refm.clone();
            Box::new(move || Workload::nw(r.clone(), 10).into())
        }),
        ("srad", {
            let i = img.clone();
            Box::new(move || Workload::srad(i.clone(), 2).into())
        }),
        ("lud", {
            let m = a.clone();
            Box::new(move || Workload::lud(m.clone()).into())
        }),
        ("srad->stencil2d", {
            let i = img.clone();
            Box::new(move || {
                Workload::srad(i.clone(), 2).then(Workload::stencil2d(
                    "diffusion2d_r1",
                    GridInput::Upstream,
                    None,
                    8,
                ))
            })
        }),
    ];

    for lanes in [1usize, 2, 4] {
        let global = pool_with(lanes, false);
        let sharded = pool_with(lanes, true);
        for (name, mk) in &cases {
            for mode in [PassMode::Barrier, PassMode::Pipelined] {
                let rg = Session::over(&global).with_mode(mode).run(mk()).unwrap();
                let rs = Session::over(&sharded).with_mode(mode).run(mk()).unwrap();
                assert!(rg.ok() && rs.ok(), "{name} lanes={lanes} {mode:?}: runs must be clean");
                assert_eq!(
                    rg.metrics.blocks, rs.metrics.blocks,
                    "{name} lanes={lanes} {mode:?}: block counts differ"
                );
                // The global engine must not count scheduler locality:
                // its zero rows are what the bench baseline relies on.
                assert_eq!(
                    rg.metrics.local_pops + rg.metrics.queue_steals
                        + rg.metrics.affinity_hits + rg.metrics.affinity_misses,
                    0,
                    "{name} lanes={lanes} {mode:?}: global engine counted sharded-scheduler events"
                );
                assert_eq!(
                    rg.outputs, rs.outputs,
                    "{name} lanes={lanes} {mode:?}: sharded output != global-queue output"
                );
            }
        }
    }
}

#[test]
fn sharded_lanes_pop_mostly_local() {
    fpga_hpc::require_backend!();
    // Acceptance: with blocks affinity-hashed evenly across 4 lanes,
    // a lane finds its next job in its own shard almost always —
    // stealing is the exception that keeps lanes busy at wave tails,
    // not the steady state.
    let grid = rand_grid2d(1024, 1024, 221, 0.0, 1.0);
    let r = session(4)
        .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 16))
        .unwrap();
    let m = &r.metrics;
    assert!(m.local_pops > 0, "sharded session must count local pops");
    assert!(
        m.local_pops > m.queue_steals,
        "locality inverted: {} local pops vs {} steals",
        m.local_pops,
        m.queue_steals
    );
    assert!(m.affinity_hits > 0, "hinted blocks must land on their lane");

    // A lanes=1 session has one shard: nothing to localize or steal,
    // so every scheduler counter stays zero (same as the old engine).
    let r1 = session(1)
        .run(Workload::stencil2d("diffusion2d_r1", grid, None, 16))
        .unwrap();
    let m1 = &r1.metrics;
    assert_eq!(
        m1.local_pops + m1.queue_steals + m1.affinity_hits + m1.affinity_misses,
        0,
        "single-lane runs must not count sharded-scheduler events"
    );
}

#[test]
fn pinned_sessions_run_and_degrade_gracefully() {
    fpga_hpc::require_backend!();
    // Acceptance: pinning never changes results, and asking for more
    // pinned lanes than cores clamps instead of failing.  Numa on a
    // single-node machine (most CI) degrades to no-op pinning — the
    // run must still be clean and bit-identical.
    let grid = rand_grid2d(256, 256, 231, 0.0, 1.0);
    let want = session(1)
        .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 8))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    for pin in [Pinning::Cores, Pinning::Numa] {
        let s = Session::builder()
            .artifacts("artifacts")
            .lanes(2)
            .pinning(pin)
            .build()
            .unwrap();
        let r = s
            .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 8))
            .unwrap();
        assert!(r.ok(), "{pin:?}: pinned run must be clean");
        if pin == Pinning::Cores {
            assert!(
                r.metrics.pins_applied > 0,
                "Cores pinning must pin the extractor partners during the drive"
            );
        }
        let got = r.into_output().into_grid2d().unwrap();
        assert_eq!(got.data, want.data, "{pin:?}: pinned run differs from unpinned");
    }

    // Oversubscribed pinned request: clamped to the machine, still runs.
    let s = Session::builder()
        .artifacts("artifacts")
        .lanes(10_000)
        .pinning(Pinning::Cores)
        .build()
        .unwrap();
    assert!(
        s.lanes() <= fpga_hpc::runtime::topology::available_cores().max(1),
        "pinned lanes must clamp to the available cores"
    );
    let r = s
        .run(Workload::stencil2d("diffusion2d_r1", grid, None, 8))
        .unwrap();
    assert!(r.ok(), "clamped session must still run cleanly");
}

#[test]
fn property_streamed_equals_reference_random_geometry() {
    fpga_hpc::require_backend!();
    // Property test: random grid sizes and step counts (multiples of T)
    // always reproduce the oracle.
    let rt = runtime();
    let coeffs = coeffs_of(&rt, "diffusion2d_r1");
    let s = session(1);
    fpga_hpc::testutil::for_cases(4, |rng| {
        let ny = rng.usize_in(64, 400);
        let nx = rng.usize_in(64, 400);
        let steps = 4 * rng.u64_in(1, 2);
        let grid = rand_grid2d(ny, nx, rng.next_u64(), 0.0, 1.0);
        let out = s
            .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, steps))
            .unwrap()
            .into_output()
            .into_grid2d()
            .unwrap();
        let want = reference::diffusion2d(grid, &coeffs, steps as usize);
        let err = max_abs_diff(&out.data, &want.data);
        assert!(err < 1e-5, "{ny}x{nx} steps={steps}: err {err}");
    });
}

#[test]
fn expired_deadline_returns_deadline_exceeded_not_a_hang() {
    fpga_hpc::require_backend!();
    // Acceptance: a session whose deadline is already expired at run
    // entry must come back within the drain slack with a
    // DeadlineExceeded report — never a hang, never an Err.  The
    // deadline is anchored when `run` is entered, so `Duration::ZERO`
    // fires the watcher before the first round submits anything.
    let grid = rand_grid2d(512, 512, 61, 0.0, 1.0);
    let s = Session::builder()
        .artifacts("artifacts")
        .lanes(2)
        .deadline(Duration::ZERO)
        .build()
        .unwrap();
    let t0 = Instant::now();
    let report = s
        .run(Workload::stencil2d("diffusion2d_r1", grid, None, 8))
        .unwrap();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < fpga_hpc::coordinator::passdriver::DEADLINE_DRAIN_SLACK + Duration::from_secs(20),
        "expired deadline must return within budget + slack, took {elapsed:?}"
    );
    assert!(report.deadline_exceeded, "zero deadline must mark the run cut");
    assert!(!report.ok(), "a cut run is not ok");
    assert!(
        !report.unfinished.is_empty(),
        "cutting at t=0 must leave never-completed blocks"
    );
    assert!(
        report
            .statuses
            .iter()
            .any(|st| matches!(st, WorkloadStatus::DeadlineExceeded)),
        "per-stage statuses must surface the cut: {:?}",
        report.statuses
    );
    // No job budget was set, so nothing was reaped: the cut is a
    // deadline event, not a timeout fault.
    assert_eq!(report.metrics.job_timeouts, 0);
    assert_eq!(report.metrics.lanes_reaped, 0);
    assert!(report.first_fault().is_none(), "deadline cut is not a fault");
}

#[test]
fn generous_deadline_does_not_perturb_a_clean_run() {
    fpga_hpc::require_backend!();
    // Acceptance: deadlines and job budgets that never fire are
    // invisible — same statuses, same bits as an unbounded session.
    let grid = rand_grid2d(512, 512, 62, 0.0, 1.0);
    let want = session(1)
        .run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 8))
        .unwrap()
        .into_output()
        .into_grid2d()
        .unwrap();
    let s = Session::builder()
        .artifacts("artifacts")
        .lanes(2)
        .deadline(Duration::from_secs(600))
        .job_timeout(Duration::from_secs(600))
        .build()
        .unwrap();
    let report = s
        .run(Workload::stencil2d("diffusion2d_r1", grid, None, 8))
        .unwrap();
    assert!(report.ok(), "generous bounds must leave the run clean");
    assert!(!report.deadline_exceeded);
    assert!(report.unfinished.is_empty());
    assert_eq!(report.metrics.job_timeouts, 0);
    assert_eq!(report.metrics.lanes_reaped, 0);
    let got = report.into_output().into_grid2d().unwrap();
    assert_eq!(got.data, want.data, "bounded run differs from unbounded");
}

#[test]
fn cli_expired_deadline_exits_nonzero_with_report() {
    fpga_hpc::require_backend!();
    // Smoke test for the `--deadline-ms` flag: an already-expired
    // deadline must exit non-zero with a DeadlineExceeded report on
    // the way out — the one thing it must never do is hang.  The test
    // binary inherits the crate-root cwd, so `artifacts/` resolves
    // exactly as it does for the in-process sessions above.
    let t0 = Instant::now();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_fpga-hpc"))
        .args(["run", "diffusion2d", "128", "4", "--lanes", "2", "--deadline-ms", "0"])
        .output()
        .expect("spawn fpga-hpc");
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(60),
        "CLI with expired deadline must exit promptly, took {elapsed:?}"
    );
    assert!(
        !out.status.success(),
        "expired deadline must exit non-zero; stdout: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        text.contains("DeadlineExceeded"),
        "exit report must classify the cut, got:\n{text}"
    );
}
