//! Chaos-harness integration tests (`--features chaos`): deterministic
//! fault injection through the real artifact path.  Each test drives a
//! [`Session`] with a [`FaultPlan`] — faults keyed by
//! `(wave, block, attempt)`, no clocks, no seeds — and checks the three
//! fault-tolerance contracts end to end:
//!
//! 1. a `Transient` fault is retried in place and the run's output is
//!    bitwise identical to a fault-free run;
//! 2. an exhausted retry budget cancels exactly the failed block's
//!    dependency cone while independent work in the same fused graph
//!    completes `Ok`;
//! 3. a killed lane is respawned by the pool supervisor and the
//!    session keeps working.
//!
//! Requires `artifacts/` (run `make artifacts` first), like
//! `integration.rs`.

#![cfg(feature = "chaos")]

use std::sync::Arc;

use fpga_hpc::coordinator::grid::Grid2D;
use fpga_hpc::coordinator::passdriver::FaultPlan;
use fpga_hpc::coordinator::session::{Session, Workload, WorkloadStatus};
use fpga_hpc::runtime::{FaultKind, Pinning};
use fpga_hpc::testutil::Rng;

/// Owning session over a fresh pool with `lanes` execute lanes.
///
/// `FPGA_HPC_PIN=none|cores|numa` pins the lanes — CI runs the whole
/// chaos suite a second time under `cores` so fault-driven lane
/// respawns exercise the re-pin path.
fn session(lanes: usize) -> Session<'static> {
    let pin: Pinning = std::env::var("FPGA_HPC_PIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(Pinning::None);
    Session::builder()
        .artifacts("artifacts")
        .lanes(lanes)
        .pinning(pin)
        .build()
        .expect("artifacts missing — run `make artifacts`")
}

fn rand_grid2d(ny: usize, nx: usize, seed: u64, lo: f32, hi: f32) -> Grid2D {
    let mut rng = Rng::new(seed);
    let data = rng.vec_f32(ny * nx, lo, hi);
    Grid2D { ny, nx, data }
}

fn diffusion(grid: &Grid2D) -> Workload {
    Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 4)
}

#[test]
fn transient_fault_retries_to_bitwise_identical_output() {
    let grid = rand_grid2d(512, 512, 5, 0.0, 1.0);
    let s = session(2);
    let clean = s.run(diffusion(&grid)).unwrap();
    assert!(clean.ok());

    // One injected Transient on the first block's first attempt: the
    // retry (attempt 2) runs the identical job body on the identical
    // parked inputs, so the result must not drift by a single bit.
    let plan = Arc::new(FaultPlan::default().transient_at(0, 0, 1));
    let faulty = s.run_with_faults(diffusion(&grid), plan).unwrap();
    assert!(faulty.ok(), "retried run must report every stage Ok");
    assert!(faulty.cancelled.is_empty(), "a retried fault cancels nothing");
    assert!(faulty.first_fault().is_none());
    assert!(faulty.metrics.job_retries >= 1, "the retry must be counted");
    assert_eq!(faulty.metrics.jobs_failed, 0);
    assert_eq!(clean.metrics.blocks, faulty.metrics.blocks);

    let want = clean.into_output().into_grid2d().unwrap();
    let got = faulty.into_output().into_grid2d().unwrap();
    assert_eq!(want.data, got.data, "retry must be bitwise invisible");
}

#[test]
fn exhausted_retries_cancel_exactly_the_dependency_cone() {
    // Chain two *independent* stages into one fused graph: NW
    // (n=128 → 2×2 blocks of 64: waves 0..3 hold 1, 2, 1 blocks) and
    // a diffusion stencil with its own grid (no seam edges).  Killing
    // NW's root block (0,0) on every allowed attempt exhausts the
    // retry budget (3 attempts) and must cancel exactly the three
    // remaining NW blocks — the stencil chain flows to completion.
    let n = 128;
    let mut rng = Rng::new(66);
    let refm: Vec<Vec<i32>> = (0..=n).map(|_| rng.vec_i32(n + 1, -5, 15)).collect();
    let grid = rand_grid2d(300, 520, 11, 0.0, 1.0);
    let s = session(2);
    let want = s.run(diffusion(&grid)).unwrap().into_output().into_grid2d().unwrap();

    let plan = Arc::new(
        FaultPlan::default()
            .transient_at(0, 0, 1)
            .transient_at(0, 0, 2)
            .transient_at(0, 0, 3),
    );
    let report = s
        .run_with_faults(Workload::nw(refm, 10).then(diffusion(&grid)), plan)
        .unwrap();

    assert!(!report.ok());
    assert_eq!(report.statuses.len(), 2);
    match &report.statuses[0] {
        WorkloadStatus::Failed(f) => {
            assert_eq!(f.kind, FaultKind::Transient);
            assert_eq!(f.attempts, 3, "the whole retry budget was spent");
            assert_eq!((f.wave, f.block), (0, 0));
        }
        other => panic!("NW stage must be Failed, got {other:?}"),
    }
    assert_eq!(report.statuses[1], WorkloadStatus::Ok, "independent stage flows");
    assert_eq!(report.metrics.job_retries, 2);
    assert_eq!(report.metrics.jobs_failed, 1);

    // The cone oracle: every NW block transitively depends on (0,0),
    // so exactly NW waves 1 and 2 cancel — and nothing else.
    let mut cancelled = report.cancelled.clone();
    cancelled.sort_unstable();
    assert_eq!(cancelled, vec![(1, 0), (1, 1), (2, 0)]);

    let got = report.into_output().into_grid2d().unwrap();
    assert_eq!(got.data, want.data, "surviving chain must be bitwise clean");
}

#[test]
fn killed_lane_is_respawned_and_the_session_survives() {
    let grid = rand_grid2d(512, 512, 21, 0.0, 1.0);
    let s = session(2);
    let want = s.run(diffusion(&grid)).unwrap().into_output().into_grid2d().unwrap();

    // Kill the lane executing block (0,0): the job dies terminally
    // (Panic, no retry), its cone cancels, and the supervisor brings
    // the lane back — the run drains instead of deadlocking on a
    // one-lane pool.
    let plan = Arc::new(FaultPlan::default().lane_kill_at(0, 0, 1));
    let report = s.run_with_faults(diffusion(&grid), plan).unwrap();
    assert!(!report.ok());
    match report.first_fault() {
        Some(f) => {
            assert_eq!(f.kind, FaultKind::Panic);
            assert_eq!(f.attempts, 1, "a panic is terminal on first attempt");
        }
        None => panic!("lane kill must surface as a stage fault"),
    }
    assert_eq!(report.metrics.lane_restarts, 1, "exactly one lane respawn");
    assert_eq!(report.metrics.jobs_failed, 1);

    // The same session keeps working on the respawned lane set.
    let after = s.run(diffusion(&grid)).unwrap();
    assert!(after.ok(), "session must recover after a lane kill");
    assert_eq!(after.metrics.lane_restarts, 0);
    let got = after.into_output().into_grid2d().unwrap();
    assert_eq!(got.data, want.data, "post-recovery run must be bitwise clean");
}
