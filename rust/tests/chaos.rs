//! Chaos-harness integration tests (`--features chaos`): deterministic
//! fault injection through the real artifact path.  Each test drives a
//! [`Session`] with a [`FaultPlan`] — faults keyed by
//! `(wave, block, attempt)`, no clocks, no seeds; plan keys stay
//! cumulative across cone-replay rounds — and checks the
//! fault-tolerance contracts end to end:
//!
//! 1. a `Transient` fault is retried in place and the run's output is
//!    bitwise identical to a fault-free run;
//! 2. a terminal fault's cancelled dependency cone is re-armed and
//!    re-driven (`WorkloadStatus::Replayed`) with bitwise-identical
//!    output — including cones that cross a fused `Chain` seam;
//! 3. an exhausted replay budget falls back to the scoped
//!    `Failed`/`Cancelled` report while independent work in the same
//!    fused graph completes `Ok`;
//! 4. a killed lane is respawned by the pool supervisor — also
//!    mid-replay — and the session keeps working;
//! 5. a deterministically *hung* job (parked on the plan's gate, no
//!    clocks in the injection) is reaped by the pool watchdog under a
//!    real short `job_timeout`, fails as `FaultKind::Timeout`, and
//!    heals through cone replay bitwise-identically.
//!
//! Requires `artifacts/` and a native XLA backend, like
//! `integration.rs`; every test skips via [`fpga_hpc::require_backend!`]
//! when only the vendored shim is linked.
//! `replay_heals_exhausted_cone_bitwise` doubles as the CI replay
//! gate: it writes its counters to `CHAOS_replay.json` for the
//! workflow to assert on (a missing file means the suite skipped).
//! `hung_job_is_reaped_as_timeout_and_heals_bitwise` does the same for
//! the CI hang gate via `CHAOS_hang.json`.

#![cfg(feature = "chaos")]

use std::sync::Arc;
use std::time::{Duration, Instant};

use fpga_hpc::coordinator::grid::Grid2D;
use fpga_hpc::coordinator::passdriver::{ConeReplay, FaultPlan, ReplayPolicy};
use fpga_hpc::coordinator::session::{GridInput, Session, Workload, WorkloadStatus};
use fpga_hpc::runtime::{FaultKind, Pinning};
use fpga_hpc::testutil::Rng;

/// Owning session over a fresh pool with `lanes` execute lanes.
///
/// `FPGA_HPC_PIN=none|cores|numa` pins the lanes — CI runs the whole
/// chaos suite a second time under `cores` so fault-driven lane
/// respawns exercise the re-pin path.
fn session(lanes: usize) -> Session<'static> {
    let pin: Pinning = std::env::var("FPGA_HPC_PIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(Pinning::None);
    Session::builder()
        .artifacts("artifacts")
        .lanes(lanes)
        .pinning(pin)
        .build()
        .expect("artifacts missing — run `make artifacts`")
}

fn rand_grid2d(ny: usize, nx: usize, seed: u64, lo: f32, hi: f32) -> Grid2D {
    let mut rng = Rng::new(seed);
    let data = rng.vec_f32(ny * nx, lo, hi);
    Grid2D { ny, nx, data }
}

fn diffusion(grid: &Grid2D) -> Workload {
    Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 4)
}

#[test]
fn transient_fault_retries_to_bitwise_identical_output() {
    fpga_hpc::require_backend!();
    let grid = rand_grid2d(512, 512, 5, 0.0, 1.0);
    let s = session(2);
    let clean = s.run(diffusion(&grid)).unwrap();
    assert!(clean.ok());

    // One injected Transient on the first block's first attempt: the
    // retry (attempt 2) runs the identical job body on the identical
    // parked inputs, so the result must not drift by a single bit.
    let plan = Arc::new(FaultPlan::default().transient_at(0, 0, 1));
    let faulty = s.run_with_faults(diffusion(&grid), plan).unwrap();
    assert!(faulty.ok(), "retried run must report every stage Ok");
    assert!(faulty.cancelled.is_empty(), "a retried fault cancels nothing");
    assert!(faulty.first_fault().is_none());
    assert!(faulty.metrics.job_retries >= 1, "the retry must be counted");
    assert_eq!(faulty.metrics.jobs_failed, 0);
    assert_eq!(clean.metrics.blocks, faulty.metrics.blocks);

    let want = clean.into_output().into_grid2d().unwrap();
    let got = faulty.into_output().into_grid2d().unwrap();
    assert_eq!(want.data, got.data, "retry must be bitwise invisible");
}

#[test]
fn replay_heals_exhausted_cone_bitwise() {
    fpga_hpc::require_backend!();
    // The CI replay gate.  Exhaust the in-place retry budget (3
    // attempts) on the root block: PR 6 semantics would cancel its
    // whole cone and surface partial output.  The session's default
    // ReplayPolicy (one round) instead re-arms the cone and re-drives
    // it — attempt 4 (plan keys are cumulative across rounds) is
    // clean — so the stage heals to `Replayed` with output bitwise
    // identical to the fault-free run.
    let grid = rand_grid2d(512, 512, 9, 0.0, 1.0);
    let s = session(2);
    let clean = s.run(diffusion(&grid)).unwrap();
    assert!(clean.ok());

    let plan = Arc::new(
        FaultPlan::default()
            .transient_at(0, 0, 1)
            .transient_at(0, 0, 2)
            .transient_at(0, 0, 3),
    );
    let report = s.run_with_faults(diffusion(&grid), plan).unwrap();
    assert!(!report.ok(), "a healed run is not strictly fault-free");
    assert!(report.completed(), "a healed run's output is whole");
    assert_eq!(report.statuses, vec![WorkloadStatus::Replayed { attempts: 1 }]);
    assert!(report.cancelled.is_empty(), "the replay un-cancelled the cone");
    assert!(report.first_fault().is_none(), "the fault healed");
    assert_eq!(
        report.replays,
        vec![ConeReplay { wave: 0, index: 0, rounds: 1 }]
    );
    assert_eq!(report.metrics.cone_replays, 1);
    assert!(report.metrics.replay_blocks >= 1, "the cone was re-driven");
    assert_eq!(report.metrics.job_retries, 2, "round 0 spent the retry budget");
    assert_eq!(report.metrics.jobs_failed, 1, "one terminal fault, then healed");
    // Every block still completes exactly once: the cone's first-round
    // completions never happened (they were cancelled), only replayed.
    assert_eq!(clean.metrics.blocks, report.metrics.blocks);

    let cone_replays = report.metrics.cone_replays;
    let replay_blocks = report.metrics.replay_blocks;
    let job_retries = report.metrics.job_retries;
    let jobs_failed = report.metrics.jobs_failed;
    let lane_restarts = report.metrics.lane_restarts;
    let want = clean.into_output().into_grid2d().unwrap();
    let got = report.into_output().into_grid2d().unwrap();
    let bitwise = want.data == got.data;
    assert!(bitwise, "replayed output must be bitwise identical");

    // Artifact for the CI replay gate (parsed by .github/workflows):
    // plain-std JSON, written into the crate directory cargo runs from.
    std::fs::write(
        "CHAOS_replay.json",
        format!(
            "{{\n  \"cone_replays\": {cone_replays},\n  \"replay_blocks\": {replay_blocks},\n  \
             \"job_retries\": {job_retries},\n  \"jobs_failed\": {jobs_failed},\n  \
             \"lane_restarts\": {lane_restarts},\n  \"bitwise_identical\": {bitwise}\n}}\n"
        ),
    )
    .expect("writing CHAOS_replay.json");
}

#[test]
fn replay_exhaustion_falls_back_to_the_scoped_cancel_report() {
    fpga_hpc::require_backend!();
    // Chain two *independent* stages into one fused graph: NW
    // (n=128 → 2×2 blocks of 64: waves 0..3 hold 1, 2, 1 blocks) and
    // a diffusion stencil with its own grid (no seam edges).  Failing
    // NW's root block on attempts 1..=6 spends the 3-attempt retry
    // budget twice — the first round terminally, then again on the one
    // replay round — so the run falls back to PR 6's scoped report:
    // the NW stage `Failed` with its three remaining blocks
    // `cancelled`, the independent stencil chain `Ok` and bitwise
    // clean.
    let n = 128;
    let mut rng = Rng::new(66);
    let refm: Vec<Vec<i32>> = (0..=n).map(|_| rng.vec_i32(n + 1, -5, 15)).collect();
    let grid = rand_grid2d(300, 520, 11, 0.0, 1.0);
    let s = session(2);
    let want = s.run(diffusion(&grid)).unwrap().into_output().into_grid2d().unwrap();

    let mut plan = FaultPlan::default();
    for attempt in 1..=6 {
        plan = plan.transient_at(0, 0, attempt);
    }
    let report = s
        .run_with_faults(Workload::nw(refm, 10).then(diffusion(&grid)), Arc::new(plan))
        .unwrap();

    assert!(!report.ok());
    assert!(!report.completed());
    assert_eq!(report.statuses.len(), 2);
    match &report.statuses[0] {
        WorkloadStatus::Failed(f) => {
            assert_eq!(f.kind, FaultKind::Transient);
            assert_eq!(f.attempts, 6, "both rounds' retry budgets accumulate");
            assert_eq!((f.wave, f.block), (0, 0));
        }
        other => panic!("NW stage must be Failed, got {other:?}"),
    }
    assert_eq!(report.statuses[1], WorkloadStatus::Ok, "independent stage flows");
    assert!(report.replays.is_empty(), "nothing healed");
    assert_eq!(report.metrics.job_retries, 4, "two retries per round");
    assert_eq!(report.metrics.jobs_failed, 2, "one terminal fault per round");
    assert_eq!(report.metrics.cone_replays, 1, "the replay round was spent");
    assert_eq!(
        report.metrics.replay_blocks, 4,
        "the replay re-armed the failed block plus its 3-block cone"
    );

    // The cone oracle: every NW block transitively depends on (0,0),
    // so exactly NW waves 1 and 2 cancel — and nothing else.
    let mut cancelled = report.cancelled.clone();
    cancelled.sort_unstable();
    assert_eq!(cancelled, vec![(1, 0), (1, 1), (2, 0)]);

    let got = report.into_output().into_grid2d().unwrap();
    assert_eq!(got.data, want.data, "surviving chain must be bitwise clean");
}

#[test]
fn replay_crosses_a_chain_seam() {
    fpga_hpc::require_backend!();
    // A fused chain with a real seam: a 1-step diffusion feeding a
    // 2-step diffusion in place (`GridInput::Upstream`).  Stage 1 has a
    // single wave, so every successor of its block (0,0) is a
    // downstream stage-2 block reached through seam edges — the
    // cancelled cone (and therefore the replay) spans both stages.
    // After healing, stage 1 is `Replayed` and stage 2 — whose blocks
    // were only ever re-driven as cone members, never faulted — is
    // `Ok`, and the chained output is bitwise identical.
    let grid = rand_grid2d(256, 256, 33, 0.0, 1.0);
    let chain = |grid: &Grid2D| {
        Workload::stencil2d("diffusion2d_r1", grid.clone(), None, 1)
            .then(Workload::stencil2d("diffusion2d_r1", GridInput::Upstream, None, 2))
    };
    let s = session(2);
    let clean = s.run(chain(&grid)).unwrap();
    assert!(clean.ok());

    let plan = Arc::new(
        FaultPlan::default()
            .transient_at(0, 0, 1)
            .transient_at(0, 0, 2)
            .transient_at(0, 0, 3),
    );
    let report = s.run_with_faults(chain(&grid), plan).unwrap();
    assert!(report.completed());
    assert_eq!(
        report.statuses,
        vec![WorkloadStatus::Replayed { attempts: 1 }, WorkloadStatus::Ok],
        "the faulted stage heals; the seam-fed stage never faulted"
    );
    assert!(report.cancelled.is_empty());
    assert_eq!(report.metrics.cone_replays, 1);
    assert!(
        report.metrics.replay_blocks >= 2,
        "the cone must include at least one downstream seam-fed block, got {}",
        report.metrics.replay_blocks
    );
    assert_eq!(
        report.replays,
        vec![ConeReplay { wave: 0, index: 0, rounds: 1 }]
    );

    let want = clean.into_output().into_grid2d().unwrap();
    let got = report.into_output().into_grid2d().unwrap();
    assert_eq!(got.data, want.data, "seam-crossing replay must be bitwise clean");
}

#[test]
fn killed_lane_during_a_replay_attempt_is_respawned_and_heals() {
    fpga_hpc::require_backend!();
    let grid = rand_grid2d(512, 512, 21, 0.0, 1.0);
    let s = session(2).with_replay(ReplayPolicy::with_attempts(2));
    let want = s.run(diffusion(&grid)).unwrap().into_output().into_grid2d().unwrap();

    // Kill the lane executing block (0,0) — twice: once on the first
    // round (Panic is terminal, no in-place retry; the supervisor
    // respawns the lane and the cone re-arms) and once again on the
    // first replay attempt.  The second replay round (attempt 3) is
    // clean, so both `lane_restarts` and `cone_replays` count 2 and
    // the stage still heals to `Replayed { attempts: 2 }`.
    let plan = Arc::new(
        FaultPlan::default().lane_kill_at(0, 0, 1).lane_kill_at(0, 0, 2),
    );
    let report = s.run_with_faults(diffusion(&grid), plan).unwrap();
    assert!(!report.ok());
    assert!(report.completed(), "the second replay round healed the kill");
    assert_eq!(report.statuses, vec![WorkloadStatus::Replayed { attempts: 2 }]);
    assert!(report.first_fault().is_none());
    assert!(report.cancelled.is_empty());
    assert_eq!(
        report.replays,
        vec![ConeReplay { wave: 0, index: 0, rounds: 2 }]
    );
    assert_eq!(report.metrics.lane_restarts, 2, "one respawn per killed attempt");
    assert_eq!(report.metrics.cone_replays, 2, "the kill mid-replay re-armed again");
    assert_eq!(report.metrics.jobs_failed, 2);
    assert_eq!(report.metrics.job_retries, 0, "a panic is terminal on each attempt");

    // The healed output is whole, and the same session keeps working
    // on the respawned lane set.
    let got = report.into_output().into_grid2d().unwrap();
    assert_eq!(got.data, want.data, "healed run must be bitwise clean");
    let after = s.run(diffusion(&grid)).unwrap();
    assert!(after.ok(), "session must recover after the lane kills");
    assert_eq!(after.metrics.lane_restarts, 0);
    let got = after.into_output().into_grid2d().unwrap();
    assert_eq!(got.data, want.data, "post-recovery run must be bitwise clean");
}

#[test]
fn hung_job_is_reaped_as_timeout_and_heals_bitwise() {
    fpga_hpc::require_backend!();
    // The CI hang gate.  Park block (0,0)'s first attempt on the
    // plan's gate — a deterministic hang, no clock in the injection
    // itself — under a real 2s per-job budget (short enough to bound
    // the test, generous enough that no healthy block job can trip it
    // on a loaded CI box).  The pool watchdog must reap the stuck lane
    // (`Timeout`), the cancelled cone must re-arm, and the replay
    // round (attempt 2, no hang registered) must heal the stage to
    // output bitwise identical to a clean run.
    let grid = rand_grid2d(512, 512, 47, 0.0, 1.0);
    let s = session(2).with_job_timeout(Duration::from_secs(2));
    let clean = s.run(diffusion(&grid)).unwrap();
    assert!(clean.ok());
    assert_eq!(clean.metrics.job_timeouts, 0, "budget must not fire on healthy jobs");
    assert_eq!(clean.metrics.lanes_reaped, 0);

    let plan = Arc::new(FaultPlan::default().hang_at(0, 0, 1));
    let t0 = Instant::now();
    let report = s.run_with_faults(diffusion(&grid), plan.clone()).unwrap();
    let elapsed = t0.elapsed();

    assert!(!report.ok(), "a healed run is not strictly fault-free");
    assert!(report.completed(), "the replay must heal the reaped block");
    assert_eq!(report.statuses, vec![WorkloadStatus::Replayed { attempts: 1 }]);
    assert!(report.first_fault().is_none(), "the timeout healed");
    assert!(report.cancelled.is_empty(), "the replay un-cancelled the cone");
    assert_eq!(
        report.replays,
        vec![ConeReplay { wave: 0, index: 0, rounds: 1 }]
    );
    assert_eq!(report.metrics.job_timeouts, 1, "the hang must be classified Timeout");
    assert_eq!(report.metrics.lanes_reaped, 1, "the stuck lane must be reaped");
    assert_eq!(report.metrics.jobs_failed, 1, "one terminal Timeout fault, then healed");
    assert_eq!(report.metrics.cone_replays, 1);
    assert_eq!(
        report.metrics.lane_restarts, 0,
        "a reap spawns a replacement without burning a supervisor restart"
    );
    assert_eq!(clean.metrics.blocks, report.metrics.blocks);
    assert!(
        elapsed < Duration::from_secs(30),
        "watchdog must bound the hang (took {elapsed:?})"
    );

    let job_timeouts = report.metrics.job_timeouts;
    let lanes_reaped = report.metrics.lanes_reaped;
    let cone_replays = report.metrics.cone_replays;
    let jobs_failed = report.metrics.jobs_failed;
    let want = clean.into_output().into_grid2d().unwrap();
    let got = report.into_output().into_grid2d().unwrap();
    let bitwise = want.data == got.data;
    assert!(bitwise, "healed output must be bitwise identical");

    // Wake the reaped zombie parked on the gate so it can exit before
    // the pool tears down, then prove the session still works on the
    // replacement lane.
    plan.release_hangs();
    let after = s.run(diffusion(&grid)).unwrap();
    assert!(after.ok(), "session must keep working on the replacement lane");

    // Artifact for the CI hang gate (parsed by .github/workflows):
    // plain-std JSON, written into the crate directory cargo runs from.
    std::fs::write(
        "CHAOS_hang.json",
        format!(
            "{{\n  \"job_timeouts\": {job_timeouts},\n  \"lanes_reaped\": {lanes_reaped},\n  \
             \"cone_replays\": {cone_replays},\n  \"jobs_failed\": {jobs_failed},\n  \
             \"bitwise_identical\": {bitwise}\n}}\n"
        ),
    )
    .expect("writing CHAOS_hang.json");
}
