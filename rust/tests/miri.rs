//! Miri coverage for the crate's `unsafe` surfaces (ISSUE 9 tentpole):
//! the raw-pointer [`GridWriter2D`]/[`GridWriter3D`] writeback/extract
//! handles and the [`TensorPools`] first-touch / overflow-ring paths.
//!
//! Run with:
//!
//! ```text
//! cargo +nightly miri test --test miri
//! ```
//!
//! Everything here is pure Rust (the vendored xla shim has no C
//! library), so Miri's borrow-tracking and data-race detectors check
//! the real marshalling code: provenance of the `shared_writer` /
//! `shared_view` pointers, in-bounds raw row copies (including the
//! clipped partial-block and boundary-synthesis slow paths), and the
//! disjoint-block concurrent writeback the pass driver relies on.
//! The suite also runs under plain `cargo test` as ordinary
//! regression coverage.
//!
//! Sizes are deliberately tiny — Miri executes ~100x slower than
//! native, and the properties checked are per-access, not per-cell.

use fpga_hpc::coordinator::bufpool::{TensorPools, TilePool, SHELF_HIGH_WATER};
use fpga_hpc::coordinator::grid::{Boundary, Grid2D, Grid3D};
use fpga_hpc::runtime::Tensor;

// ---------------------------------------------------------------- 2D

/// Two threads write disjoint blocks through copies of one raw handle
/// — the pass driver's lane-parallel writeback shape.  Miri's race
/// detector validates the disjointness contract; the final readback
/// validates the data went where it should.
#[test]
fn writer2d_concurrent_disjoint_writeback() {
    let mut g = Grid2D::zeros(4, 8);
    // SAFETY: the handle copies are used only inside the scope below
    // (the grid outlives them); the two writes target block origins
    // (0,0) and (0,4) with 4x4 extents — pairwise disjoint — and the
    // grid is not accessed through any other path until the scope ends.
    let w = unsafe { g.shared_writer() };
    std::thread::scope(|s| {
        s.spawn(move || w.write_block(0, 0, 4, 4, &[1.0; 16]));
        s.spawn(move || w.write_block(0, 4, 4, 4, &[2.0; 16]));
    });
    for y in 0..4 {
        for x in 0..8 {
            assert_eq!(g.at(y, x), if x < 4 { 1.0 } else { 2.0 });
        }
    }
}

/// Clipped (partial edge block) writeback through the raw handle must
/// stay in bounds — the `min`/`saturating_sub` clipping is what keeps
/// the row copies legal, and Miri verifies every one.
#[test]
fn writer2d_clips_partial_edge_blocks() {
    let mut g = Grid2D::zeros(5, 5);
    // SAFETY: single-threaded here; the grid is only read again after
    // the last use of the handle.
    let w = unsafe { g.shared_writer() };
    w.write_block(3, 3, 4, 4, &[7.0; 16]); // 2x2 survives the clip
    w.write_block(5, 5, 4, 4, &[9.0; 16]); // fully out of grid: no-op
    let mut sum = 0.0;
    for y in 0..5 {
        for x in 0..5 {
            sum += g.at(y, x);
        }
    }
    assert_eq!(sum, 4.0 * 7.0);
}

/// Raw extraction (fast full-row path and boundary-synthesis slow
/// path) through a read-only view, concurrently from two threads, must
/// match the safe extraction exactly.
#[test]
fn view2d_concurrent_extract_matches_safe_path() {
    let g = Grid2D::from_fn(4, 4, |y, x| (y * 4 + x) as f32);
    let want_zero = g.extract_tile(0, 0, 4, 4, 1, Boundary::Zero);
    let want_clamp = g.extract_tile(2, 2, 4, 4, 1, Boundary::Clamp);
    // SAFETY: read-only view; nothing mutates `g` while it is live,
    // and write_block is never called on it.
    let v = unsafe { g.shared_view() };
    std::thread::scope(|s| {
        let a = s.spawn(move || {
            let mut out = Vec::new();
            // SAFETY: no concurrent writer exists at all.
            unsafe { v.extract_tile_into(0, 0, 4, 4, 1, Boundary::Zero, &mut out) };
            out
        });
        let b = s.spawn(move || {
            let mut out = Vec::new();
            // SAFETY: as above.
            unsafe { v.extract_tile_into(2, 2, 4, 4, 1, Boundary::Clamp, &mut out) };
            out
        });
        assert_eq!(a.join().unwrap(), want_zero);
        assert_eq!(b.join().unwrap(), want_clamp);
    });
}

/// The cross-pass shape: lanes write pass-p+1 blocks into grid B while
/// an extractor reads pass-p tiles from grid A — two allocations, raw
/// handles on both, running concurrently.
#[test]
fn writer2d_cross_pass_read_write_overlap() {
    let src = Grid2D::from_fn(4, 4, |y, x| (y + x) as f32);
    let mut dst = Grid2D::zeros(4, 4);
    // SAFETY: `rd` is a read-only view of `src` (never written through);
    // `wr` writes only `dst`.  Distinct allocations, so the concurrent
    // accesses can never overlap.
    let rd = unsafe { src.shared_view() };
    let wr = unsafe { dst.shared_writer() };
    std::thread::scope(|s| {
        let t = s.spawn(move || {
            let mut tile = Vec::new();
            // SAFETY: nothing writes `src`.
            unsafe { rd.extract_tile_into(0, 0, 4, 4, 0, Boundary::Zero, &mut tile) };
            tile
        });
        s.spawn(move || wr.write_block(0, 0, 2, 2, &[5.0; 4]));
        let tile = t.join().unwrap();
        assert_eq!(tile.len(), 16);
        assert_eq!(tile[5], src.at(1, 1));
    });
    assert_eq!(dst.at(1, 1), 5.0);
}

// ---------------------------------------------------------------- 3D

#[test]
fn writer3d_concurrent_disjoint_writeback_and_clip() {
    let mut g = Grid3D::zeros(3, 3, 6);
    // SAFETY: as in the 2D test — disjoint block origins (0,0,0) and
    // (0,0,3), grid untouched until the scope ends.
    let w = unsafe { g.shared_writer() };
    std::thread::scope(|s| {
        s.spawn(move || w.write_block(0, 0, 0, 3, &[1.0; 27]));
        s.spawn(move || w.write_block(0, 0, 3, 3, &[2.0; 27]));
    });
    w.write_block(2, 2, 5, 2, &[9.0; 8]); // clips to 1x1x1
    assert_eq!(g.at(1, 1, 1), 1.0);
    assert_eq!(g.at(1, 1, 4), 2.0);
    assert_eq!(g.at(2, 2, 5), 9.0);
}

#[test]
fn view3d_extract_matches_safe_path() {
    let g = Grid3D::from_fn(3, 3, 3, |z, y, x| (z * 9 + y * 3 + x) as f32);
    let mut want = Vec::new();
    g.extract_tile_into(0, 0, 0, 3, 1, Boundary::Clamp, &mut want);
    // SAFETY: read-only view, no concurrent writer.
    let v = unsafe { g.shared_view() };
    let mut got = Vec::new();
    // SAFETY: as above.
    unsafe { v.extract_tile_into(0, 0, 0, 3, 1, Boundary::Clamp, &mut got) };
    assert_eq!(got, want);
}

// ----------------------------------------------------------- bufpool

/// First-touch allocation, shelf recycling and hit/miss accounting on
/// the pooled extraction path.
#[test]
fn pool_first_touch_then_reuse() {
    let p = TilePool::with_shards(2);
    let a = p.take_on(1, 32);
    assert!(a.is_empty() && a.capacity() >= 32);
    assert_eq!((p.hits(), p.misses()), (0, 1));
    p.put_on(1, {
        let mut v = a;
        v.resize(32, 3.0);
        v
    });
    let b = p.take_on(1, 16); // smaller request, same shelf covers it
    assert!(b.is_empty() && b.capacity() >= 32);
    assert_eq!((p.hits(), p.misses()), (1, 1));
    // Other shard's shelves are independent; this allocates afresh.
    let c = p.take_on(0, 32);
    assert_eq!((p.hits(), p.misses()), (1, 2));
    drop((b, c));
}

/// Overfill one shelf past the high-water mark: the spill goes to the
/// overflow ring (still recyclable from any shard), and the ring's own
/// cap turns further spill into counted evictions.
#[test]
fn pool_overflow_ring_and_eviction_bound() {
    let p = TilePool::default();
    // SHELF_HIGH_WATER buffers shelve; the +1st spills to the ring.
    for _ in 0..=SHELF_HIGH_WATER {
        p.put(Vec::with_capacity(8));
    }
    assert_eq!(p.evictions(), 0, "ring absorbed the spill");
    // Drain shelf + ring: every retained buffer is a hit.
    for _ in 0..=SHELF_HIGH_WATER {
        assert!(p.take(8).capacity() >= 8);
    }
    assert_eq!(p.misses(), 0);
    assert_eq!(p.hits(), SHELF_HIGH_WATER as u64 + 1);
}

/// The wave driver's recycle path: typed tensors split into their
/// pools on the block's affinity shard, zero-capacity buffers are
/// dropped, and the pooled extraction immediately reuses the arena.
#[test]
fn tensorpools_recycle_roundtrip() {
    let pools = TensorPools::with_shards(2);
    let g = Grid2D::from_fn(4, 4, |y, x| (y * 4 + x) as f32);
    let tile = g.extract_tile_pooled(0, 0, 4, 4, 0, Boundary::Zero, &pools.tiles);
    assert_eq!(pools.tiles.misses(), 1);
    pools.recycle_on(
        1,
        vec![
            Tensor::F32(tile, vec![4, 4]),
            Tensor::I32(vec![0, 1, 2, 3], vec![4]),
            Tensor::I32(Vec::new(), vec![0]), // capacity 0: dropped
        ],
    );
    let again = pools.tiles.take_on(1, 16);
    assert!(again.capacity() >= 16);
    assert_eq!(pools.tiles.hits(), 1);
    assert!(pools.descs.take_on(1, 4).capacity() >= 4);
    assert_eq!(pools.descs.hits(), 1);
    assert_eq!(pools.evictions(), 0);
}
