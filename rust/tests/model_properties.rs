//! Property-based tests over the analytic models (xorshift-driven; the
//! offline dependency set has no proptest — see `fpga_hpc::testutil`).
//!
//! These pin down the *invariants* the thesis's performance model must
//! satisfy regardless of parameter values — the Rust-side counterpart of
//! the hypothesis sweeps in python/tests/.

use fpga_hpc::device::{arria_10, stratix_10, stratix_v};
use fpga_hpc::perfmodel::memory::{AccessPattern, MemorySpec};
use fpga_hpc::perfmodel::pipeline::{KernelClass, PipelineSpec};
use fpga_hpc::runtime::Registry;
use fpga_hpc::stencil::config::{diffusion2d, diffusion3d, AcceleratorConfig, Workload};
use fpga_hpc::stencil::model::predict;
use fpga_hpc::testutil::{for_cases, Rng};

fn rand_spec(rng: &mut Rng) -> PipelineSpec {
    let class = if rng.f64() < 0.5 {
        KernelClass::SingleWorkItem { stalls: rng.u64_in(0, 300) }
    } else {
        KernelClass::NdRange { barriers: rng.u64_in(0, 5) }
    };
    let pattern = *rng.choose(&[
        AccessPattern::Streaming,
        AccessPattern::StreamingUnaligned,
        AccessPattern::Strided,
        AccessPattern::Random,
    ]);
    PipelineSpec {
        name: "prop".into(),
        depth: rng.u64_in(10, 3_000),
        trip_count: rng.u64_in(1_000, 10_000_000_000),
        class,
        bytes_per_iter: rng.f64() * 64.0,
        parallelism: *rng.choose(&[1u64, 2, 4, 8, 16, 32, 64]),
        memory: MemorySpec::with_pattern(pattern),
        invocations: rng.u64_in(1, 100),
    }
}

#[test]
fn pipeline_cycles_positive_and_ii_bounded_below() {
    for_cases(300, |rng| {
        let dev = if rng.f64() < 0.5 { stratix_v() } else { arria_10() };
        let spec = rand_spec(rng);
        let fmax = 150.0 + rng.f64() * 200.0;
        let ii = spec.ii(&dev, fmax);
        assert!(ii >= spec.ii_compile(), "II below II_c");
        assert!(ii >= spec.ii_runtime(&dev, fmax) - 1e-9, "II below II_r");
        let c = spec.cycles(&dev, fmax);
        assert!(c.is_finite() && c >= spec.depth as f64);
        assert!(spec.seconds(&dev, fmax) > 0.0);
    });
}

#[test]
fn pipeline_more_stalls_never_faster() {
    for_cases(200, |rng| {
        let dev = stratix_v();
        let mut a = rand_spec(rng);
        a.class = KernelClass::SingleWorkItem { stalls: rng.u64_in(0, 50) };
        let mut b = a.clone();
        let extra = rng.u64_in(1, 100);
        if let KernelClass::SingleWorkItem { stalls } = a.class {
            b.class = KernelClass::SingleWorkItem { stalls: stalls + extra };
        }
        assert!(b.cycles(&dev, 250.0) >= a.cycles(&dev, 250.0));
    });
}

#[test]
fn pipeline_parallelism_never_hurts_cycles() {
    // Eq. 3-7/3-8: raising N_p divides the trip count but multiplies the
    // memory pressure — cycle count must never increase.
    for_cases(200, |rng| {
        let dev = arria_10();
        let mut a = rand_spec(rng);
        a.parallelism = 1;
        let mut b = a.clone();
        b.parallelism = *rng.choose(&[2u64, 4, 8, 16, 32]);
        assert!(
            b.cycles(&dev, 250.0) <= a.cycles(&dev, 250.0) * 1.0001,
            "parallelism made it slower"
        );
    });
}

#[test]
fn stencil_prediction_invariants() {
    for_cases(120, |rng| {
        let dims = if rng.f64() < 0.5 { 2 } else { 3 };
        let radius = rng.u64_in(1, 4) as u32;
        let shape = if dims == 2 { diffusion2d(radius) } else { diffusion3d(radius) };
        let dev = match rng.u64_in(0, 2) {
            0 => stratix_v(),
            1 => arria_10(),
            _ => stratix_10(),
        };
        let cfg = AcceleratorConfig {
            par: *rng.choose(&[1u32, 2, 4, 8, 16, 32]),
            time: *rng.choose(&[1u32, 2, 4, 8, 16]),
            bsize: if dims == 2 {
                *rng.choose(&[512u32, 1024, 2048, 4096])
            } else {
                *rng.choose(&[32u32, 64, 128, 256])
            },
        };
        let work = Workload {
            extent: if dims == 2 { rng.u64_in(1024, 32768) } else { rng.u64_in(64, 512) },
            steps: rng.u64_in(1, 1000),
        };
        let p = predict(&shape, &work, &cfg, &dev);
        // GFLOP/s and GCell/s are consistent
        let expect = p.gcells * shape.flops_per_cell();
        assert!((p.gflops - expect).abs() < 1e-6 * expect.max(1.0));
        // the clock is within the device's physical range
        assert!(p.fmax_mhz >= 120.0 && p.fmax_mhz <= dev.base_fmax_mhz * 1.05);
        // power is bounded by board TDP (only meaningful for designs
        // that actually fit — infeasible configs have >100 % budgets)
        assert!(p.power_w > 0.0);
        if p.fits {
            assert!(p.power_w < dev.tdp_w * 1.1, "{} on {}", p.power_w, dev.name);
        }
        // cycles/time positive and consistent
        assert!(p.seconds > 0.0 && p.cycles > 0.0);
        // feasible configs never have a degenerate valid span
        if p.fits {
            assert!(cfg.valid_span(radius) > 0);
        }
    });
}

#[test]
fn stencil_deeper_time_never_increases_traffic_per_update() {
    // The core §5.1.3 argument: fused steps amortize DDR traffic.
    for_cases(100, |rng| {
        let shape = diffusion2d(rng.u64_in(1, 4) as u32);
        let dev = arria_10();
        let work = Workload { extent: 16_384, steps: 960 };
        let par = *rng.choose(&[4u32, 8, 16]);
        let bsize = *rng.choose(&[2048u32, 4096, 8192]);
        let t1 = predict(&shape, &work, &AcceleratorConfig { par, time: 1, bsize }, &dev);
        let t2 = predict(&shape, &work, &AcceleratorConfig { par, time: 4, bsize }, &dev);
        if t1.fits && t2.fits {
            assert!(t2.bw_utilization <= t1.bw_utilization * 1.5 || !t2.memory_bound);
        }
    });
}

#[test]
fn registry_parser_never_panics() {
    for_cases(300, |rng| {
        // random mutations of a valid line must parse or error, not panic
        let valid = "x|x.hlo.txt|in=float32[8,8]|out=float32[4,4]|meta block=4;halo=2";
        let mut bytes = valid.as_bytes().to_vec();
        for _ in 0..rng.u64_in(0, 6) {
            let i = rng.usize_in(0, bytes.len() - 1);
            bytes[i] = (rng.u64_in(32, 126)) as u8;
        }
        let s = String::from_utf8_lossy(&bytes).to_string();
        let _ = Registry::parse(&s); // Ok or Err both fine
    });
}

#[test]
fn grid_extract_write_roundtrip_random_geometry() {
    use fpga_hpc::coordinator::grid::{Boundary, Grid2D};
    for_cases(100, |rng| {
        let ny = rng.usize_in(4, 96);
        let nx = rng.usize_in(4, 96);
        let data = rng.vec_f32(ny * nx, -1.0, 1.0);
        let g = Grid2D { ny, nx, data };
        let bh = rng.usize_in(1, ny);
        let bw = rng.usize_in(1, nx);
        let y0 = rng.usize_in(0, ny - 1);
        let x0 = rng.usize_in(0, nx - 1);
        let halo = rng.usize_in(0, 6);
        let b = if rng.f64() < 0.5 { Boundary::Zero } else { Boundary::Clamp };
        let tile = g.extract_tile(y0 as isize, x0 as isize, bh + 2 * halo, bw + 2 * halo, halo, b);
        assert_eq!(tile.len(), (bh + 2 * halo) * (bw + 2 * halo));
        // interior of the tile equals the grid block (clipped)
        for ty in 0..bh.min(ny - y0) {
            for tx in 0..bw.min(nx - x0) {
                let got = tile[(ty + halo) * (bw + 2 * halo) + tx + halo];
                assert_eq!(got, g.at(y0 + ty, x0 + tx));
            }
        }
        // write-back of the interior is idempotent
        let mut g2 = g.clone();
        let interior: Vec<f32> = (0..bh)
            .flat_map(|ty| (0..bw).map(move |tx| (ty, tx)))
            .map(|(ty, tx)| {
                let gy = (y0 + ty).min(ny - 1);
                let gx = (x0 + tx).min(nx - 1);
                g.at(gy, gx)
            })
            .collect();
        // only exact in-grid writes are checked here
        if y0 + bh <= ny && x0 + bw <= nx {
            g2.write_block(y0, x0, bh, bw, &interior);
            assert_eq!(g2, g);
        }
    });
}

#[test]
fn fmax_monotone_in_utilization() {
    use fpga_hpc::perfmodel::area::AreaBudget;
    use fpga_hpc::perfmodel::fmax::{estimate, CriticalPath};
    for_cases(200, |rng| {
        let dev = if rng.f64() < 0.5 { stratix_v() } else { arria_10() };
        let base = AreaBudget {
            logic: rng.f64() * 0.7,
            m20k_blocks: rng.f64() * 0.7,
            m20k_bits: rng.f64() * 0.7,
            dsp: rng.f64() * 0.7,
        };
        let mut heavier = base;
        heavier.logic = (base.logic + 0.25).min(1.0);
        heavier.m20k_blocks = (base.m20k_blocks + 0.25).min(1.0);
        let f_lo = estimate(&dev, &heavier, CriticalPath::Clean, true);
        let f_hi = estimate(&dev, &base, CriticalPath::Clean, true);
        assert!(f_lo <= f_hi + 1e-9);
    });
}
