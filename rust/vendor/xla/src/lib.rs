//! Vendored, dependency-free shim over the xla-rs binding surface that
//! the `fpga_hpc` runtime layer (`src/runtime/`) was written against.
//!
//! Host-side data marshalling is fully functional: [`Literal`] stores
//! real bytes with a real shape and round-trips through
//! [`Literal::to_vec`], and [`PjRtClient::buffer_from_host_buffer`]
//! stages host slices exactly like the native binding does.  What this
//! shim cannot do is run HLO: [`PjRtClient::compile`] always fails with
//! a descriptive error, so every artifact-driven path fails fast at
//! warmup/compile time (classified `Fatal` by the runtime, never
//! retried).  Builds, unit tests, clippy, rustdoc, and the pure-logic
//! integration surface all work without any native library.
//!
//! To run compiled artifacts for real, replace this path dependency
//! with the native `xla` crate (see `../README.md`); the API here is a
//! strict subset, so no caller changes are needed.

use std::fmt;
use std::rc::Rc;

/// Binding-level error.  The runtime layer formats these with `{:?}`,
/// matching the native binding's error type.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Binding-level result.
pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types (the subset meaningful to this stack, plus the
/// common neighbours so dtype mismatches print something sensible).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    /// Size of one element in bytes.
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Host types that can be staged to / fetched from a [`Literal`].
/// Sealed: the runtime layer only marshals f32 and i32 (`DType` in the
/// artifact manifest), both 4-byte types.
pub trait NativeType: sealed::Sealed + Copy {
    /// The element type this host type marshals as.
    const TY: ElementType;
    /// Reassemble one element from native-endian bytes.
    fn from_ne_bytes(b: [u8; 4]) -> Self;
    /// Serialize one element to native-endian bytes.
    fn to_ne_bytes(self) -> [u8; 4];
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn from_ne_bytes(b: [u8; 4]) -> Self {
        f32::from_ne_bytes(b)
    }

    fn to_ne_bytes(self) -> [u8; 4] {
        f32::to_ne_bytes(self)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn from_ne_bytes(b: [u8; 4]) -> Self {
        i32::from_ne_bytes(b)
    }

    fn to_ne_bytes(self) -> [u8; 4] {
        i32::to_ne_bytes(self)
    }
}

/// Shape of an array literal: element type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
}

/// A host-side literal: a typed, shaped byte buffer (or a tuple of
/// literals, as produced by tuple-returning computations).
#[derive(Debug, Clone)]
pub struct Literal {
    shape: ArrayShape,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Build an array literal from raw bytes.  Fails if the byte count
    /// does not match the shape.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let shape = ArrayShape { ty, dims: dims.iter().map(|&d| d as i64).collect() };
        let expect = shape.element_count() * ty.byte_size();
        if data.len() != expect {
            return Err(Error(format!(
                "literal data size {} does not match shape {:?}{:?} ({} bytes)",
                data.len(),
                ty,
                dims,
                expect
            )));
        }
        Ok(Literal { shape, data: data.to_vec(), tuple: None })
    }

    /// The array shape; errors on tuple literals, which have none.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error("tuple literal has no array shape".to_string()));
        }
        Ok(self.shape.clone())
    }

    /// Copy the elements out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("cannot read elements of a tuple literal".to_string()));
        }
        if self.shape.ty != T::TY {
            return Err(Error(format!(
                "element type mismatch: literal is {:?}, requested {:?}",
                self.shape.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_ne_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Split a tuple literal into its parts (consumes the contents,
    /// like the native binding's move-out semantics).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        self.tuple
            .take()
            .ok_or_else(|| Error("literal is not a tuple".to_string()))
    }
}

/// Parsed HLO module (held as text: the shim validates readability,
/// the native backend does the actual parse).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load an HLO-text artifact from disk.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text from {path}: {e}")))?;
        if text.trim().is_empty() {
            return Err(Error(format!("HLO text file {path} is empty")));
        }
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }

    pub fn proto(&self) -> &HloModuleProto {
        &self.proto
    }
}

/// A device buffer.  In this host-only shim a buffer is a staged
/// literal; `to_literal_sync` is therefore an exact round-trip.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Fetch the buffer contents back to the host.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable.  Never constructed by this shim (compilation
/// requires the native backend), but the type must exist so the
/// runtime's compile cache and execute path typecheck.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed buffer arguments, returning per-device
    /// result buffers (`[replica][output]`).
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        // Unreachable in practice: compile() never yields an executable.
        Err(Error(BACKEND_MISSING.to_string()))
    }
}

const BACKEND_MISSING: &str = "vendored xla shim: executing HLO requires the native \
     xla_extension backend; swap rust/vendor/xla for the native xla crate (see its README)";

/// The PJRT client.  Holds an `Rc` so it is deliberately `!Send`, like
/// the native client — one client per lane thread (see
/// `runtime::pool`).
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    /// Create the host (CPU) client.  Always succeeds: host-side
    /// staging needs no native library.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _not_send: Rc::new(()) })
    }

    /// Stage a typed host slice as a device buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for &v in data {
            bytes.extend_from_slice(&v.to_ne_bytes());
        }
        let literal = Literal::create_from_shape_and_untyped_data(T::TY, dims, &bytes)?;
        Ok(PjRtBuffer { literal })
    }

    /// Compile an HLO computation.  Always fails in the shim: there is
    /// no compiler without the native backend.  The runtime classifies
    /// this `Fatal` (never retried) and surfaces it at warmup.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(BACKEND_MISSING.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32_bytes_and_shape() {
        let v = [1.5f32, -2.0, 0.25, 8.0];
        let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), v);
    }

    #[test]
    fn literal_rejects_size_and_type_mismatches() {
        let r = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 4]);
        assert!(r.is_err());
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[0u8; 4]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![0]);
    }

    #[test]
    fn buffer_staging_roundtrips_through_to_literal_sync() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_buffer::<i32>(&[-7, 42], &[2], None).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![-7, 42]);
        assert_eq!(lit.array_shape().unwrap().dims(), &[2]);
    }

    #[test]
    fn compile_fails_fast_with_a_descriptive_error() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: "HloModule m".to_string() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err:?}").contains("xla_extension"));
    }

    #[test]
    fn decompose_tuple_moves_parts_out_once() {
        let part =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0u8; 4]).unwrap();
        let mut tup = Literal {
            shape: ArrayShape { ty: ElementType::F32, dims: Vec::new() },
            data: Vec::new(),
            tuple: Some(vec![part.clone(), part]),
        };
        let parts = tup.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(tup.decompose_tuple().is_err());
    }
}
