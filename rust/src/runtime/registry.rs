//! Artifact registry: parses `artifacts/manifest.txt`.
//!
//! Manifest line format (written by `python/compile/aot.py`):
//!
//! ```text
//! name|file.hlo.txt|in=float32[264,264];float32[264,264]|out=float32[256,256]|meta k=v;k=v
//! ```

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context};

use crate::runtime::Tensor;

/// Element types used by the artifact set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> crate::Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Shape + dtype of one operand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn parse(s: &str) -> crate::Result<TensorSpec> {
        // "float32[264,264]"
        let open = s.find('[').ok_or_else(|| anyhow!("bad signature '{s}'"))?;
        let dtype = DType::parse(&s[..open])?;
        let inner = s[open + 1..]
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("bad signature '{s}'"))?;
        let shape = if inner.is_empty() {
            vec![]
        } else {
            inner
                .split(',')
                .map(|d| d.trim().parse::<usize>().context("bad dim"))
                .collect::<Result<_, _>>()?
        };
        Ok(TensorSpec { dtype, shape })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn matches(&self, t: &Tensor) -> bool {
        let dt_ok = matches!(
            (self.dtype, t),
            (DType::F32, Tensor::F32(..)) | (DType::I32, Tensor::I32(..))
        );
        dt_ok && t.shape() == self.shape.as_slice()
    }
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: HashMap<String, String>,
}

impl ArtifactSpec {
    /// Shape/dtype-check a set of runtime inputs.
    pub fn validate_inputs(&self, inputs: &[Tensor]) -> crate::Result<()> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        for (i, (spec, t)) in self.inputs.iter().zip(inputs).enumerate() {
            if !spec.matches(t) {
                bail!(
                    "{}: input {i} mismatch: expected {:?}{:?}, got {:?}",
                    self.name,
                    spec.dtype,
                    spec.shape,
                    t.shape()
                );
            }
        }
        Ok(())
    }

    /// Typed metadata accessors (static parameters baked at AOT time).
    pub fn meta_u64(&self, key: &str) -> crate::Result<u64> {
        self.meta
            .get(key)
            .ok_or_else(|| anyhow!("{}: missing meta '{key}'", self.name))?
            .parse()
            .with_context(|| format!("{}: meta '{key}' not u64", self.name))
    }

    pub fn meta_f64(&self, key: &str) -> crate::Result<f64> {
        self.meta
            .get(key)
            .ok_or_else(|| anyhow!("{}: missing meta '{key}'", self.name))?
            .parse()
            .with_context(|| format!("{}: meta '{key}' not f64", self.name))
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).map(|s| s.as_str())
    }

    /// Comma-separated f64 list (stencil coefficients).
    pub fn meta_f64_list(&self, key: &str) -> crate::Result<Vec<f64>> {
        self.meta
            .get(key)
            .ok_or_else(|| anyhow!("{}: missing meta '{key}'", self.name))?
            .split(',')
            .map(|p| p.trim().parse().context("bad f64 in list"))
            .collect::<Result<_, _>>()
            .map_err(Into::into)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    specs: HashMap<String, ArtifactSpec>,
    order: Vec<String>,
}

impl Registry {
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Registry> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Registry::parse(&text)
    }

    pub fn parse(text: &str) -> crate::Result<Registry> {
        let mut reg = Registry::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let spec = parse_line(line)
                .with_context(|| format!("manifest line {}", lineno + 1))?;
            reg.order.push(spec.name.clone());
            reg.specs.insert(spec.name.clone(), spec);
        }
        Ok(reg)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.order.clone()
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }
}

fn parse_line(line: &str) -> crate::Result<ArtifactSpec> {
    let fields: Vec<&str> = line.split('|').collect();
    if fields.len() != 5 {
        bail!("expected 5 |-separated fields, got {}", fields.len());
    }
    let name = fields[0].to_string();
    let file = fields[1].to_string();
    let inputs = parse_sigs(fields[2].strip_prefix("in=").ok_or_else(|| anyhow!("missing in="))?)?;
    let outputs =
        parse_sigs(fields[3].strip_prefix("out=").ok_or_else(|| anyhow!("missing out="))?)?;
    let meta_str = fields[4]
        .strip_prefix("meta ")
        .ok_or_else(|| anyhow!("missing meta"))?;
    let mut meta = HashMap::new();
    for pair in meta_str.split(';') {
        if let Some((k, v)) = pair.split_once('=') {
            meta.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    Ok(ArtifactSpec { name, file, inputs, outputs, meta })
}

fn parse_sigs(s: &str) -> crate::Result<Vec<TensorSpec>> {
    s.split(';')
        .filter(|p| !p.is_empty())
        .map(TensorSpec::parse)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const LINE: &str = "diffusion2d_r1|diffusion2d_r1.hlo.txt|in=float32[264,264]|out=float32[256,256]|meta block=256;boundary=zero;coeffs=0.76,0.06;halo=4;kind=stencil2d;radius=1;steps=4";

    #[test]
    fn parses_manifest_line() {
        let reg = Registry::parse(LINE).unwrap();
        let spec = reg.get("diffusion2d_r1").unwrap();
        assert_eq!(spec.inputs.len(), 1);
        assert_eq!(spec.inputs[0].shape, vec![264, 264]);
        assert_eq!(spec.outputs[0].shape, vec![256, 256]);
        assert_eq!(spec.meta_u64("halo").unwrap(), 4);
        assert_eq!(spec.meta_f64_list("coeffs").unwrap(), vec![0.76, 0.06]);
        assert_eq!(spec.meta_str("boundary"), Some("zero"));
    }

    #[test]
    fn validates_inputs() {
        let reg = Registry::parse(LINE).unwrap();
        let spec = reg.get("diffusion2d_r1").unwrap();
        let good = Tensor::F32(vec![0.0; 264 * 264], vec![264, 264]);
        assert!(spec.validate_inputs(&[good.clone()]).is_ok());
        let bad_shape = Tensor::F32(vec![0.0; 4], vec![2, 2]);
        assert!(spec.validate_inputs(&[bad_shape]).is_err());
        let bad_dtype = Tensor::I32(vec![0; 264 * 264], vec![264, 264]);
        assert!(spec.validate_inputs(&[bad_dtype]).is_err());
        assert!(spec.validate_inputs(&[good.clone(), good]).is_err());
    }

    #[test]
    fn multi_input_sigs() {
        let line = "nw|nw.hlo.txt|in=int32[64];int32[64];int32[1];int32[64,64]|out=int32[64,64]|meta block=64;kind=dynprog;penalty=10";
        let reg = Registry::parse(line).unwrap();
        let spec = reg.get("nw").unwrap();
        assert_eq!(spec.inputs.len(), 4);
        assert_eq!(spec.inputs[3].shape, vec![64, 64]);
        assert_eq!(spec.inputs[3].dtype, DType::I32);
    }
}
