//! Multi-lane runtime pool: N worker threads, each owning its own PJRT
//! CPU client — the software analogue of the thesis's replicated compute
//! units (`PAR`, §4.3.1.6, §5.3).
//!
//! The PJRT client wraps an `Rc` and is `!Send`, so a [`Runtime`] can
//! never cross threads.  The pool sidesteps that by *creating* one
//! `Runtime` per lane thread, on that thread: the artifact manifest is
//! parsed once and shared (cloned) into every lane, while executables are
//! compiled per lane (per-lane compile caches — each PJRT client must own
//! its executables).
//!
//! ## Sharded scheduling (PR 7)
//!
//! Work arrives as boxed jobs through **per-lane sharded run queues**
//! with LIFO-slot work stealing.  A job may carry a [`LaneHint`]
//! (block→lane affinity computed by the wave driver): hinted jobs land
//! in their shard's single-item LIFO **slot** (displacing the previous
//! occupant to the front of the shard's deque), so the newest —
//! cache-warmest — successor of a block is the first thing its lane
//! pops.  Unhinted jobs spread round-robin across the shard deques.
//! A lane pops its own slot, then its own deque front (the hot end),
//! and only when both are empty **steals**: victim deque *backs* (the
//! cold end) first, victim slots as a last resort.  Stealing keeps the
//! pool work-conserving — any queued job is reachable by any lane, so
//! `wait_idle`, cancellation and fault-retry semantics are unchanged
//! from the global-queue engine, and a stolen tracked job simply
//! retries on the thief (the retry loop runs on whichever lane popped
//! it).  `PoolConfig { sharded: false }` collapses the shards to one
//! FIFO deque and ignores hints — the literal pre-PR 7 global queue,
//! kept for the bench comparison and the bitwise-identity tests.
//! [`SchedCounters`] exposes the locality observables (local pops,
//! steals, affinity hits/misses, pins applied).
//!
//! Submission blocks while the total queued count is at capacity
//! (backpressure for the extractor side).  There are two failure
//! disciplines:
//!
//! * **Untracked jobs** ([`RuntimePool::submit`]) keep the original
//!   batch semantics: the first error or panic poisons the pool until
//!   the next [`RuntimePool::wait_idle`], which reports it and clears
//!   the poison; remaining queued jobs of the failed batch are drained
//!   without running.  Warmup and the one-shot
//!   [`RuntimePool::execute`] convenience use this path.
//! * **Tracked jobs** ([`RuntimePool::submit_tracked`]) are the wave
//!   driver's path and never poison the pool.  Each failure is
//!   classified ([`FaultKind`]); `Transient` faults are retried under a
//!   bounded [`RetryPolicy`] (exponential backoff), and the terminal
//!   [`JobStatus`] is delivered to the job's completion callback
//!   exactly once — also for jobs a poisoned or closing pool drained
//!   without running (`Skipped`) — *before* the job leaves the
//!   in-flight count, so [`RuntimePool::wait_idle`] also waits for
//!   every callback.  The cross-pass wave driver uses the status to
//!   choose between advancing the dependency table and cancelling the
//!   failed block's dependency cone (see
//!   [`crate::coordinator::passdriver`]).
//!
//! Lane threads are **supervised**: a panic that escapes the per-job
//! isolation (chaos [`LaneKill`], or an unexpected unwind outside a job
//! body) respawns the lane with a fresh `Runtime` from the shared
//! registry instead of silently shrinking the pool, counted in
//! [`FaultCounters::lane_restarts`].  Under a [`Pinning`] policy the
//! supervisor (re-)applies the lane's CPU affinity at the top of every
//! supervision iteration, so a respawned lane lands back on its node
//! before its fresh PJRT client allocates.
//!
//! ## Deadlines & watchdog (PR 10)
//!
//! Retry and respawn cover jobs that *fail*; neither covers a job that
//! simply never returns — a hung `compile`/`execute` would park the
//! closing `wait_idle` forever.  Tracked jobs may therefore carry a
//! wall-clock **budget** ([`RuntimePool::submit_tracked_budgeted`]).
//! Each lane owns a [`Heartbeat`] word (`(seq << 2) | state`, states
//! IDLE/BUSY/COMMITTED/REAPED) stamped at job start; a **watchdog**
//! thread sleeps until the nearest armed deadline and, on expiry, CASes
//! the stuck lane's word `BUSY -> REAPED`.  Winning that CAS transfers
//! ownership of the job: the watchdog fires the parked completion
//! callback as `Failed` with [`FaultKind::Timeout`], releases the
//! in-flight count, and spawns a replacement lane thread — the stuck
//! thread becomes a *zombie* that, if it ever wakes, loses the same CAS
//! at its job guard and exits without firing anything (the callback
//! stays exactly-once; `tests/loom.rs` model-checks the handshake).
//! A job body that writes results through raw pointers calls
//! [`commit_current_job`] first: the `BUSY -> COMMITTED` transition
//! closes the reap window, so a zombie can never write into buffers a
//! replay round has re-driven.  When every lane has died for good
//! (respawn failures, reaps with failed replacements),
//! [`RuntimePool::wait_idle`] reports an error and completes the
//! stranded queue as `Skipped` instead of deadlocking, and
//! [`RuntimePool::wait_idle_for`] bounds the wait for run-level
//! deadlines (see `coordinator::passdriver`).

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use super::topology::{pin_current_thread, PinPlan, Pinning};
use super::{FaultKind, Registry, Runtime, RuntimeStats, Tensor};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{Arc, Barrier, Condvar, Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering from poisoning.  Every critical section
/// behind this helper is a single-field update or a counter fold, so
/// the data is consistent even if a thread panicked while holding the
/// guard — and unwrapping would escalate one lane panic into a process
/// abort when the unwinding thread's drop glue re-locks.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Is a job submitted under `epoch` stale?  `None` (every unscoped
/// submission) is never stale; a scoped job is stale iff the pool's
/// epoch cell has moved past its submission value.  The Acquire load
/// pairs with the AcqRel RMW in [`RuntimePool::advance_epoch`]: a lane
/// that pops a scoped job after a replay round has been abandoned must
/// observe the advanced epoch (the pop and the advance are both inside
/// the queue mutex's happens-before chain) and completes the job as
/// [`JobStatus::Skipped`] without running the body.  The loom epoch
/// model (`tests/loom.rs`) checks exactly this property.
pub(crate) fn epoch_stale(epoch: Option<u64>, current: &AtomicU64) -> bool {
    epoch.is_some_and(|e| e != current.load(Ordering::Acquire))
}

/// Heartbeat word states (low two bits of [`Heartbeat::word`]).
const BEAT_IDLE: u64 = 0;
const BEAT_BUSY: u64 = 1;
const BEAT_COMMITTED: u64 = 2;
const BEAT_REAPED: u64 = 3;

fn beat_pack(seq: u64, state: u64) -> u64 {
    (seq << 2) | state
}

/// One lane's heartbeat: the word a budgeted job stamps at start and
/// reclaims at finish, and the watchdog inspects in between.  The word
/// packs a monotonic per-lane sequence number with a state in the low
/// two bits; every ownership transfer is a CAS on the exact packed
/// value, so a zombie lane holding a stale sequence can never win a
/// transition against its replacement (sequences only grow).  The
/// parked completion callback travels in `done_slot`: whichever side
/// wins the word — lane finish or watchdog reap — takes the callback
/// out and fires it, which is what makes the handshake exactly-once
/// (model-checked in `tests/loom.rs`).
pub(crate) struct Heartbeat {
    /// `(seq << 2) | state`; see [`beat_pack`] and the `BEAT_*` states.
    word: AtomicU64,
    /// Absolute budget expiry in µs since the pool's `t0`
    /// (`u64::MAX` = unbudgeted, never reaped).  Stored before the
    /// `BUSY` stamp's Release store, read after the watchdog's Acquire
    /// load of the word, so the pair is always consistent.
    deadline_us: AtomicU64,
    /// The budgeted job's parked completion callback.
    done_slot: Mutex<Option<DoneFn>>,
    /// The stamping thread's id, recorded alongside every stamp: a
    /// reaped thread's id moves to `Shared::zombies` so shutdown can
    /// skip joining a thread that may never wake.
    thread: Mutex<Option<std::thread::ThreadId>>,
}

impl Heartbeat {
    fn new() -> Heartbeat {
        Heartbeat {
            word: AtomicU64::new(beat_pack(0, BEAT_IDLE)),
            deadline_us: AtomicU64::new(u64::MAX),
            done_slot: Mutex::new(None),
            thread: Mutex::new(None),
        }
    }

    /// Lane side, job start: stamp `BUSY` with the next sequence number
    /// and the absolute deadline; returns the sequence the lane must
    /// later claim back via [`Heartbeat::finish`].  Only the lane that
    /// owns this beat stamps it (zombies never reach a stamp — they
    /// exit at their job guard), so a plain load+store suffices.
    fn stamp(&self, deadline_us: u64) -> u64 {
        let seq = (self.word.load(Ordering::Relaxed) >> 2) + 1;
        self.deadline_us.store(deadline_us, Ordering::Relaxed);
        self.word.store(beat_pack(seq, BEAT_BUSY), Ordering::Release);
        seq
    }

    /// Job-body side, pre-writeback commit fence: `BUSY -> COMMITTED`
    /// closes the reap window.  Also true when `seq` is already
    /// committed (a retry attempt after a committed one).
    fn try_commit(&self, seq: u64) -> bool {
        if self
            .word
            .compare_exchange(
                beat_pack(seq, BEAT_BUSY),
                beat_pack(seq, BEAT_COMMITTED),
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok()
        {
            return true;
        }
        self.word.load(Ordering::Acquire) == beat_pack(seq, BEAT_COMMITTED)
    }

    /// Lane side, job end: reclaim the word (`BUSY|COMMITTED -> IDLE`).
    /// `false` means the watchdog reaped this sequence first — the
    /// caller is a zombie and must fire nothing.
    fn finish(&self, seq: u64) -> bool {
        for from in [BEAT_BUSY, BEAT_COMMITTED] {
            if self
                .word
                .compare_exchange(
                    beat_pack(seq, from),
                    beat_pack(seq, BEAT_IDLE),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return true;
            }
        }
        false
    }

    /// Watchdog side: `BUSY -> REAPED`.  `false` means the job finished
    /// or committed between the deadline scan and this CAS — too late
    /// to reap, the lane keeps ownership.
    fn try_reap(&self, seq: u64) -> bool {
        self.word
            .compare_exchange(
                beat_pack(seq, BEAT_BUSY),
                beat_pack(seq, BEAT_REAPED),
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok()
    }

    /// Has this claim been taken away?  (Post-wake zombie probe: skips
    /// retries and fault double-accounting.)  While the owning job is
    /// live the word is `BUSY`/`COMMITTED` at exactly `seq`; anything
    /// else means the watchdog reaped it — including the case where the
    /// replacement lane has already re-stamped the beat past `seq`.
    /// Only call while the job that holds `seq` is still running (after
    /// its own `finish` the word is legitimately `IDLE`).
    fn is_reaped(&self, seq: u64) -> bool {
        let word = self.word.load(Ordering::Acquire);
        word != beat_pack(seq, BEAT_BUSY) && word != beat_pack(seq, BEAT_COMMITTED)
    }
}

std::thread_local! {
    /// Set when this lane thread discovers (via a failed finish claim)
    /// that the watchdog reaped its job: the thread must exit without
    /// respawning — the watchdog already spawned its replacement — and
    /// without touching the live-lane count (the watchdog kept it).
    static LANE_REAPED: Cell<bool> = const { Cell::new(false) };

    /// The running budgeted job's heartbeat claim, visible to the job
    /// body through [`commit_current_job`].
    static CURRENT_CLAIM: RefCell<Option<(Arc<Heartbeat>, u64)>> = const { RefCell::new(None) };
}

/// Pre-writeback commit fence for budgeted jobs.  A job body that is
/// about to write results through raw pointers (the wave driver's
/// grid writers) calls this first: `true` means the job still owns its
/// heartbeat (the `BUSY -> COMMITTED` transition closed the watchdog's
/// reap window) and the writes are safe; `false` means the watchdog
/// reaped the job while it was stuck — the caller is running on a
/// zombie lane and must return *without* writing (its buffers may
/// already be re-driven by a replay round).  Unbudgeted jobs have no
/// claim and always commit.
pub fn commit_current_job() -> bool {
    CURRENT_CLAIM.with(|c| match c.borrow().as_ref() {
        Some((beat, seq)) => beat.try_commit(*seq),
        None => true,
    })
}

/// A sticky lane preference for a submitted job (shard index modulo the
/// lane count).  The wave driver derives it from the block's lattice
/// origin so successive passes of one block land on one lane.
pub type LaneHint = usize;

/// An untracked pool job body.  Takes the lane index and that lane's
/// runtime.
type RunFn = Box<dyn FnOnce(usize, &Runtime) -> crate::Result<()> + Send + 'static>;

/// A tracked (retryable) job body: `FnMut` so the lane can re-invoke it
/// on a `Transient` fault.  Bodies must keep their inputs alive until
/// they succeed (see the wave driver's `Option`-held inputs).
type TrackedFn = Box<dyn FnMut(usize, &Runtime) -> crate::Result<()> + Send + 'static>;

/// A per-job completion callback; receives the terminal [`JobStatus`].
type DoneFn = Box<dyn FnOnce(JobStatus) + Send + 'static>;

/// Construction-time pool configuration (see [`RuntimePool::open_with`]).
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Lane-thread count (clamped to ≥ 1).
    pub lanes: usize,
    /// CPU/NUMA pinning policy for lanes and their extractor partners.
    pub pinning: Pinning,
    /// `true` (default): per-lane sharded queues with work stealing.
    /// `false`: one global FIFO deque, hints ignored — the literal
    /// pre-PR 7 engine, kept as the bench/identity baseline.
    pub sharded: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { lanes: 1, pinning: Pinning::None, sharded: true }
    }
}

/// Bounded retry policy for tracked jobs.  Only `Transient` faults are
/// retried; `Fatal` faults and panics are terminal on first occurrence.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempt budget (≥ 1); 1 disables retry.
    pub attempts: u32,
    /// Backoff before the second attempt; doubles per further retry.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        // Three attempts with 1 ms / 2 ms pauses: long enough to ride
        // out an allocator or device hiccup, short enough to be
        // invisible next to a block execution.
        RetryPolicy { attempts: 3, backoff: Duration::from_millis(1) }
    }
}

impl RetryPolicy {
    /// No retries: every fault is terminal on the first attempt.
    pub fn none() -> Self {
        RetryPolicy { attempts: 1, backoff: Duration::ZERO }
    }

    /// Delay after failed attempt `attempt` (1-based): `backoff · 2^(attempt-1)`.
    fn delay(&self, attempt: u32) -> Duration {
        self.backoff.saturating_mul(1u32 << attempt.saturating_sub(1).min(16))
    }
}

/// Terminal status of a tracked job, delivered to its completion
/// callback exactly once.
#[derive(Debug, Clone)]
pub enum JobStatus {
    /// The body returned `Ok` (possibly after `retries` retried
    /// attempts).
    Ok { retries: u32 },
    /// The body failed terminally: a `Fatal` fault or a panic, or a
    /// `Transient` fault with the retry budget exhausted.
    Failed { kind: FaultKind, attempts: u32, message: String },
    /// The job never ran: a poisoned pool drained it.
    Skipped,
}

impl JobStatus {
    pub fn is_ok(&self) -> bool {
        matches!(self, JobStatus::Ok { .. })
    }
}

/// Snapshot of the pool's fault-tolerance counters since open.
/// Drivers diff two snapshots to attribute counts to one run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Retried attempts of tracked jobs (`Transient` faults).
    pub job_retries: u64,
    /// Tracked jobs that failed terminally.
    pub jobs_failed: u64,
    /// Lane threads respawned after a panic escaped job isolation.
    pub lane_restarts: u64,
    /// Budgeted jobs completed as [`FaultKind::Timeout`] by the
    /// watchdog (also counted in `jobs_failed`).
    pub job_timeouts: u64,
    /// Lane threads reaped by the watchdog (each replaced by a fresh
    /// lane; disjoint from `lane_restarts`, which counts panic
    /// respawns).
    pub lanes_reaped: u64,
}

/// Snapshot of the sharded scheduler's locality counters since open.
/// All zero when the pool runs the global-queue emulation
/// (`PoolConfig { sharded: false }` or a single lane) — the legacy
/// scheduler has no locality to observe.  Drivers diff two snapshots
/// to attribute counts to one run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedCounters {
    /// Jobs a lane popped from its own shard (slot or deque).
    pub local_pops: u64,
    /// Jobs a lane stole from another lane's shard.
    pub queue_steals: u64,
    /// Hinted jobs popped by the lane they were hinted to.
    pub affinity_hits: u64,
    /// Hinted jobs stolen by a different lane.
    pub affinity_misses: u64,
    /// Successful `sched_setaffinity` applications (lane spawns and
    /// respawns, plus extractor partners via
    /// [`RuntimePool::pin_extractor`]).
    pub pins_applied: u64,
}

/// Chaos panic payload: a job body that panics with `LaneKill` kills
/// its lane *thread* — the per-job panic isolation deliberately
/// re-raises it — exercising the supervisor's respawn path.  The job
/// itself still completes as `Failed` with [`FaultKind::Panic`].
#[cfg(any(test, feature = "chaos"))]
pub struct LaneKill;

enum JobBody {
    Once(RunFn),
    Tracked(TrackedFn),
}

/// A unit of pool work: the body plus an optional completion callback,
/// the retry policy (tracked bodies only) and the affinity hint.
struct Job {
    body: JobBody,
    done: Option<DoneFn>,
    policy: RetryPolicy,
    hint: Option<LaneHint>,
    /// Submission epoch for replay-scoped jobs
    /// ([`RuntimePool::submit_tracked_scoped`]): a lane that pops the
    /// job after [`RuntimePool::advance_epoch`] has moved past this
    /// value completes it as [`JobStatus::Skipped`] without running the
    /// body — a straggler from an abandoned attempt can never write
    /// back or double-fire into a re-armed wave table.  `None` (every
    /// unscoped submission) is never stale.
    epoch: Option<u64>,
    /// Wall-clock budget for tracked jobs
    /// ([`RuntimePool::submit_tracked_budgeted`]): the lane arms its
    /// heartbeat with `now + budget` at job start, and the watchdog
    /// reaps the lane — completing the job as [`FaultKind::Timeout`] —
    /// if the body is still running past that deadline.  `None` (every
    /// other submission) is never reaped.
    budget: Option<Duration>,
}

/// One lane's run queue: a single-item LIFO slot for the newest hinted
/// job (the cache-warm successor) plus a deque whose *front* is the hot
/// end (owner pops front, thieves steal back).
#[derive(Default)]
struct Shard {
    slot: Option<Job>,
    fifo: VecDeque<Job>,
}

/// How a lane acquired a job — drives the locality accounting.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Pop {
    Local,
    Stolen,
}

struct QueueState {
    shards: Vec<Shard>,
    /// Total queued jobs across every slot and deque (capacity and
    /// idle accounting — cheaper than summing shards).
    queued: usize,
    in_flight: usize,
    closed: bool,
    /// Round-robin cursor for unhinted jobs.
    rr: usize,
    /// Lane threads currently able to pop work.  Starts at the lane
    /// count; a permanent lane death (respawn failure, reap whose
    /// replacement failed to spawn) decrements it.  At zero with work
    /// still queued the pool is *dead*: `wait_idle` reports an error
    /// and drains the queue as `Skipped` instead of parking forever.
    alive: usize,
}

impl QueueState {
    /// Route a job to its shard.  Hinted jobs (multi-shard pools only)
    /// take the LIFO slot, displacing the previous occupant to the
    /// deque front — so a shard drains newest-first, the work-stealing
    /// analogue of depth-first block descent.  Unhinted jobs (and
    /// every job of a global-mode pool) append round-robin FIFO.
    fn push(&mut self, job: Job) {
        let n = self.shards.len();
        match job.hint.filter(|_| n > 1) {
            Some(h) => {
                let shard = &mut self.shards[h % n];
                if let Some(prev) = shard.slot.replace(job) {
                    shard.fifo.push_front(prev);
                }
            }
            None => {
                let t = self.rr;
                self.rr = (self.rr + 1) % n;
                self.shards[t].fifo.push_back(job);
            }
        }
        self.queued += 1;
    }

    /// Pop the next job for `lane`: own slot → own deque front → steal
    /// victim deque backs → steal victim slots.  Victim order starts at
    /// the next lane over so thieves spread instead of mobbing shard 0.
    fn pop_for(&mut self, lane: usize) -> Option<(Job, Pop)> {
        let n = self.shards.len();
        let me = lane % n;
        if let Some(job) = self.shards[me].slot.take() {
            self.queued -= 1;
            return Some((job, Pop::Local));
        }
        if let Some(job) = self.shards[me].fifo.pop_front() {
            self.queued -= 1;
            return Some((job, Pop::Local));
        }
        for d in 1..n {
            let v = (me + d) % n;
            if let Some(job) = self.shards[v].fifo.pop_back() {
                self.queued -= 1;
                return Some((job, Pop::Stolen));
            }
        }
        for d in 1..n {
            let v = (me + d) % n;
            if let Some(job) = self.shards[v].slot.take() {
                self.queued -= 1;
                return Some((job, Pop::Stolen));
            }
        }
        None
    }

    /// Remove every queued job (dead-pool drain): no lane will ever
    /// pop them, so the caller completes their callbacks as `Skipped`
    /// outside the lock.
    fn drain_all(&mut self) -> Vec<Job> {
        let mut out = Vec::with_capacity(self.queued);
        for shard in &mut self.shards {
            if let Some(job) = shard.slot.take() {
                out.push(job);
            }
            out.extend(shard.fifo.drain(..));
        }
        self.queued = 0;
        out
    }
}

/// Per-lane runtime-stats cell (satellite: the stats fold is lock-free
/// on the hot path — each lane touches only its own atomics, the read
/// side folds all lanes).  Durations are stored as integer microseconds
/// so a plain `fetch_add` suffices.
struct LaneStatsCell {
    executions: AtomicU64,
    compile_us: AtomicU64,
    execute_us: AtomicU64,
    marshal_us: AtomicU64,
}

// Explicit (not derived) so the struct still builds when the sync shim
// swaps in loom's atomics, which don't guarantee a `Default` impl.
impl Default for LaneStatsCell {
    fn default() -> Self {
        LaneStatsCell {
            executions: AtomicU64::new(0),
            compile_us: AtomicU64::new(0),
            execute_us: AtomicU64::new(0),
            marshal_us: AtomicU64::new(0),
        }
    }
}

fn to_us(ms: f64) -> u64 {
    (ms * 1_000.0).max(0.0).round() as u64
}

impl LaneStatsCell {
    // Relaxed throughout this file's stats/sched/fault counters: they
    // are observability tallies, not synchronization.  Job payloads and
    // results travel through the queue mutex (the happens-before edge);
    // a reader folding the cells only needs totals-so-far, which RMW
    // atomicity alone makes exact.  None of them gates a loom-modeled
    // protocol.
    fn add_delta(&self, last: &RuntimeStats, now: &RuntimeStats) {
        self.executions.fetch_add(now.executions - last.executions, Ordering::Relaxed);
        self.compile_us.fetch_add(to_us(now.compile_ms - last.compile_ms), Ordering::Relaxed);
        self.execute_us.fetch_add(to_us(now.execute_ms - last.execute_ms), Ordering::Relaxed);
        self.marshal_us.fetch_add(to_us(now.marshal_ms - last.marshal_ms), Ordering::Relaxed);
    }
}

/// Sharded-scheduler locality counters (see [`SchedCounters`]).
struct SchedCells {
    local_pops: AtomicU64,
    queue_steals: AtomicU64,
    affinity_hits: AtomicU64,
    affinity_misses: AtomicU64,
    pins_applied: AtomicU64,
}

// Explicit for the same loom-compatibility reason as `LaneStatsCell`.
impl Default for SchedCells {
    fn default() -> Self {
        SchedCells {
            local_pops: AtomicU64::new(0),
            queue_steals: AtomicU64::new(0),
            affinity_hits: AtomicU64::new(0),
            affinity_misses: AtomicU64::new(0),
            pins_applied: AtomicU64::new(0),
        }
    }
}

struct Shared {
    state: Mutex<QueueState>,
    /// Lanes wait here for work.
    job_ready: Condvar,
    /// Producers wait here for queue space.
    space: Condvar,
    /// `wait_idle` callers wait here for the queue to drain.
    idle: Condvar,
    /// First error from any lane since the last `wait_idle`.
    error: Mutex<Option<anyhow::Error>>,
    /// Set alongside `error`; lanes drain (skip) jobs while poisoned.
    poisoned: AtomicBool,
    /// Per-lane runtime stats, folded on read by [`RuntimePool::stats`].
    lane_stats: Vec<LaneStatsCell>,
    /// Locality counters (sharded mode only).
    sched: SchedCells,
    /// Fault-tolerance counters (see [`FaultCounters`]).
    job_retries: AtomicU64,
    jobs_failed: AtomicU64,
    lane_restarts: AtomicU64,
    job_timeouts: AtomicU64,
    lanes_reaped: AtomicU64,
    /// Current submission epoch for replay-scoped tracked jobs (see
    /// [`RuntimePool::advance_epoch`]).  Monotonic; never reset.
    epoch: AtomicU64,
    queue_cap: usize,
    /// Lane/extractor → CPU-set assignment under the pinning policy.
    plan: PinPlan,
    /// `true` when the pool runs >1 shard (locality accounting active).
    multi_shard: bool,
    /// Per-lane heartbeat words, indexed by lane (see [`Heartbeat`]).
    beats: Vec<Arc<Heartbeat>>,
    /// Watchdog wake signal, paired with `state`: stamped deadlines
    /// and shutdown both notify here.
    watchdog_wake: Condvar,
    /// Replacement lane threads spawned by the watchdog after a reap;
    /// joined at shutdown.
    extra_handles: Mutex<Vec<JoinHandle<()>>>,
    /// Thread ids of reaped (zombie) lane threads: they may be parked
    /// in a hung body forever, so shutdown detaches instead of joining
    /// them.
    zombies: Mutex<Vec<std::thread::ThreadId>>,
    /// Wall-clock origin for heartbeat deadlines.
    t0: Instant,
    /// Artifact directory + manifest for watchdog replacement spawns.
    dir: PathBuf,
    registry: Registry,
    /// Chaos hook: make every lane *respawn* (not the initial spawn)
    /// fail, so tests can kill lanes permanently and exercise the
    /// dead-pool paths.
    #[cfg(any(test, feature = "chaos"))]
    fail_respawns: AtomicBool,
}

impl Shared {
    fn record_error(&self, e: anyhow::Error) {
        self.poisoned.store(true, Ordering::Release);
        lock(&self.error).get_or_insert(e);
    }

    /// A lane thread is gone for good (respawn failure, or a reap
    /// whose replacement could not be spawned).  When the last lane
    /// dies, wake everyone parked on the pool: `wait_idle` callers
    /// must report a dead pool, blocked producers must stop waiting
    /// for space that will never come.
    fn lane_gone(&self) {
        let mut st = lock(&self.state);
        st.alive = st.alive.saturating_sub(1);
        let dead = st.alive == 0;
        drop(st);
        if dead {
            self.idle.notify_all();
            self.space.notify_all();
        }
    }

    /// µs since the pool opened (heartbeat deadline clock).
    fn now_us(&self) -> u64 {
        u64::try_from(self.t0.elapsed().as_micros()).unwrap_or(u64::MAX)
    }
}

/// `N` lane threads, each with its own PJRT client and compile cache.
pub struct RuntimePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// The deadline watchdog (see the module docs § Deadlines &
    /// watchdog); joined after the lanes at shutdown.
    watchdog: Option<JoinHandle<()>>,
    registry: Registry,
    lanes: usize,
}

impl RuntimePool {
    /// Open the artifact directory and spin up `lanes` worker threads
    /// (clamped to ≥ 1) with the default config (sharded queues, no
    /// pinning).  The manifest is read once on the calling thread; each
    /// lane then creates its own PJRT client.  Returns an error if the
    /// manifest fails to parse or any lane fails to start.
    pub fn open(dir: impl AsRef<Path>, lanes: usize) -> crate::Result<RuntimePool> {
        Self::open_with(dir, PoolConfig { lanes, ..PoolConfig::default() })
    }

    /// Open with an explicit [`PoolConfig`] (sharding and pinning
    /// knobs).  `config.pinning` is applied by each lane itself at the
    /// top of its supervision loop — and re-applied on respawn.
    pub fn open_with(dir: impl AsRef<Path>, config: PoolConfig) -> crate::Result<RuntimePool> {
        let dir: PathBuf = dir.as_ref().to_path_buf();
        let registry = Registry::load(dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        RuntimePool::with_registry_cfg(dir, registry, config)
    }

    /// Open over an already-parsed registry (pure-logic tests use an
    /// empty one: lanes start and run jobs without any artifacts on
    /// disk).
    pub(crate) fn with_registry(
        dir: PathBuf,
        registry: Registry,
        lanes: usize,
    ) -> crate::Result<RuntimePool> {
        Self::with_registry_cfg(dir, registry, PoolConfig { lanes, ..PoolConfig::default() })
    }

    pub(crate) fn with_registry_cfg(
        dir: PathBuf,
        registry: Registry,
        config: PoolConfig,
    ) -> crate::Result<RuntimePool> {
        let lanes = config.lanes.max(1);
        let nshards = if config.sharded { lanes } else { 1 };
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                shards: (0..nshards).map(|_| Shard::default()).collect(),
                queued: 0,
                in_flight: 0,
                closed: false,
                rr: 0,
                alive: lanes,
            }),
            job_ready: Condvar::new(),
            space: Condvar::new(),
            idle: Condvar::new(),
            error: Mutex::new(None),
            poisoned: AtomicBool::new(false),
            lane_stats: (0..lanes).map(|_| LaneStatsCell::default()).collect(),
            sched: SchedCells::default(),
            job_retries: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            lane_restarts: AtomicU64::new(0),
            job_timeouts: AtomicU64::new(0),
            lanes_reaped: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
            queue_cap: (lanes * 4).max(8),
            plan: PinPlan::new(config.pinning, lanes),
            multi_shard: nshards > 1,
            beats: (0..lanes).map(|_| Arc::new(Heartbeat::new())).collect(),
            watchdog_wake: Condvar::new(),
            extra_handles: Mutex::new(Vec::new()),
            zombies: Mutex::new(Vec::new()),
            t0: Instant::now(),
            dir: dir.clone(),
            registry: registry.clone(),
            #[cfg(any(test, feature = "chaos"))]
            fail_respawns: AtomicBool::new(false),
        });
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<crate::Result<()>>();
        let mut handles = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let dir = dir.clone();
            let reg = registry.clone();
            let sh = shared.clone();
            let tx = ready_tx.clone();
            // A sanctioned unscoped-spawn site (see clippy.toml): lanes
            // are supervised, join on shutdown, and respawn on death.
            #[allow(clippy::disallowed_methods)]
            let handle = match std::thread::Builder::new()
                .name(format!("rt-lane-{lane}"))
                .spawn(move || lane_entry(lane, dir, reg, sh, Some(tx)))
            {
                Ok(h) => h,
                Err(e) => {
                    // Release the lanes already spawned so they exit.
                    lock(&shared.state).closed = true;
                    shared.job_ready.notify_all();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(anyhow!("spawning lane {lane} failed: {e}"));
                }
            };
            handles.push(handle);
        }
        drop(ready_tx);
        // The watchdog sleeps until the nearest armed job deadline (or
        // a wake signal) — it costs nothing while no job is budgeted.
        let wd_shared = shared.clone();
        #[allow(clippy::disallowed_methods)]
        let watchdog = std::thread::Builder::new()
            .name("rt-watchdog".into())
            .spawn(move || watchdog_entry(wd_shared))
            .map(Some)
            .unwrap_or_else(|e| {
                // A pool without a watchdog still runs; budgeted jobs
                // just lose their reaping. Surface it as a pool error.
                shared.record_error(anyhow!("spawning the watchdog failed: {e}"));
                None
            });
        let pool = RuntimePool { shared, handles, watchdog, registry, lanes };
        for _ in 0..lanes {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("lane thread died during startup"))?
                .context("opening a lane runtime")?;
        }
        Ok(pool)
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Aggregate execution stats, folded across the per-lane atomic
    /// cells on read — no lock anywhere on the job hot path.
    pub fn stats(&self) -> RuntimeStats {
        let mut agg = RuntimeStats::default();
        for cell in &self.shared.lane_stats {
            agg.executions += cell.executions.load(Ordering::Relaxed);
            agg.compile_ms += cell.compile_us.load(Ordering::Relaxed) as f64 / 1_000.0;
            agg.execute_ms += cell.execute_us.load(Ordering::Relaxed) as f64 / 1_000.0;
            agg.marshal_ms += cell.marshal_us.load(Ordering::Relaxed) as f64 / 1_000.0;
        }
        agg
    }

    /// Snapshot the fault-tolerance counters (retries / terminal
    /// failures / lane respawns since open).
    pub fn fault_counters(&self) -> FaultCounters {
        FaultCounters {
            job_retries: self.shared.job_retries.load(Ordering::Relaxed),
            jobs_failed: self.shared.jobs_failed.load(Ordering::Relaxed),
            lane_restarts: self.shared.lane_restarts.load(Ordering::Relaxed),
            job_timeouts: self.shared.job_timeouts.load(Ordering::Relaxed),
            lanes_reaped: self.shared.lanes_reaped.load(Ordering::Relaxed),
        }
    }

    /// Lane threads currently able to pop work.  Less than
    /// [`RuntimePool::lanes`] only after a lane died for good (its
    /// respawn or watchdog replacement failed); zero means the pool is
    /// dead and [`RuntimePool::wait_idle`] will report it.
    pub fn alive_lanes(&self) -> usize {
        lock(&self.shared.state).alive
    }

    /// Chaos hook: make every lane *respawn* from here on fail, so a
    /// chaos kill (or a watchdog reap) becomes a permanent lane death.
    /// Exercises the dead-pool reporting paths.
    #[cfg(any(test, feature = "chaos"))]
    pub fn chaos_fail_respawns(&self) {
        self.shared.fail_respawns.store(true, Ordering::Release);
    }

    /// Snapshot the sharded scheduler's locality counters since open.
    pub fn sched_counters(&self) -> SchedCounters {
        let s = &self.shared.sched;
        SchedCounters {
            local_pops: s.local_pops.load(Ordering::Relaxed),
            queue_steals: s.queue_steals.load(Ordering::Relaxed),
            affinity_hits: s.affinity_hits.load(Ordering::Relaxed),
            affinity_misses: s.affinity_misses.load(Ordering::Relaxed),
            pins_applied: s.pins_applied.load(Ordering::Relaxed),
        }
    }

    /// Pin the calling thread as extractor partner `j` under the pool's
    /// pinning policy (slot `lanes + j`, see
    /// [`crate::runtime::topology::PinPlan`]).  Returns whether a pin
    /// was applied; a no-pinning policy (or topology) is a cheap no-op.
    pub fn pin_extractor(&self, j: usize) -> bool {
        if let Some(cpus) = self.shared.plan.extractor_cpus(j) {
            if pin_current_thread(cpus) {
                self.shared.sched.pins_applied.fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }
        false
    }

    /// Enqueue an untracked job.  Blocks while the queue is at capacity
    /// (the bounded-channel backpressure between extractors and lanes).
    /// Failures poison the pool until the next
    /// [`RuntimePool::wait_idle`].
    pub fn submit<F>(&self, job: F)
    where
        F: FnOnce(usize, &Runtime) -> crate::Result<()> + Send + 'static,
    {
        self.submit_hinted(None, job);
    }

    /// [`RuntimePool::submit`] with a lane-affinity hint: the job lands
    /// in shard `hint % lanes`' LIFO slot and runs on that lane unless
    /// an idle lane steals it first.
    pub fn submit_hinted<F>(&self, hint: Option<LaneHint>, job: F)
    where
        F: FnOnce(usize, &Runtime) -> crate::Result<()> + Send + 'static,
    {
        self.enqueue(Job {
            body: JobBody::Once(Box::new(job)),
            done: None,
            policy: RetryPolicy::none(),
            hint,
            epoch: None,
            budget: None,
        });
    }

    /// Enqueue a tracked job with a retry policy and a completion
    /// callback.  `on_done(status)` fires exactly once — after the body
    /// succeeds or fails terminally (`Transient` faults are retried up
    /// to `policy.attempts` times with exponential backoff), or with
    /// [`JobStatus::Skipped`] when a poisoned pool drains the job
    /// without running it — and is ordered before the job leaves the
    /// in-flight count (so [`RuntimePool::wait_idle`] also waits for
    /// every callback).  Tracked failures do **not** poison the pool:
    /// scoping the consequence of a failed block is the caller's job
    /// (see `WaveTable::cancel`).
    pub fn submit_tracked<F, C>(&self, job: F, policy: RetryPolicy, on_done: C)
    where
        F: FnMut(usize, &Runtime) -> crate::Result<()> + Send + 'static,
        C: FnOnce(JobStatus) + Send + 'static,
    {
        self.submit_tracked_hinted(None, job, policy, on_done);
    }

    /// [`RuntimePool::submit_tracked`] with a lane-affinity hint.  A
    /// stolen hinted job keeps full tracked semantics — retries run on
    /// the thief, the callback fires exactly once.
    pub fn submit_tracked_hinted<F, C>(
        &self,
        hint: Option<LaneHint>,
        job: F,
        policy: RetryPolicy,
        on_done: C,
    ) where
        F: FnMut(usize, &Runtime) -> crate::Result<()> + Send + 'static,
        C: FnOnce(JobStatus) + Send + 'static,
    {
        self.enqueue(Job {
            body: JobBody::Tracked(Box::new(job)),
            done: Some(Box::new(on_done)),
            policy,
            hint,
            epoch: None,
            budget: None,
        });
    }

    /// The current replay epoch (see [`RuntimePool::advance_epoch`]).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::Acquire)
    }

    /// Open a new submission epoch and return it.  Jobs submitted under
    /// an older epoch via [`RuntimePool::submit_tracked_scoped`] that
    /// are still queued complete as [`JobStatus::Skipped`] without
    /// running — the fence the cone-replay driver relies on so a
    /// straggling completion from an abandoned attempt cannot
    /// double-fire into re-armed wave-table counters.
    pub fn advance_epoch(&self) -> u64 {
        self.shared.epoch.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// [`RuntimePool::submit_tracked_hinted`] scoped to a submission
    /// `epoch` (from [`RuntimePool::advance_epoch`]): if the pool's
    /// epoch has moved on by the time a lane pops the job, the body is
    /// not run and the callback fires with [`JobStatus::Skipped`].  All
    /// other tracked semantics (retry policy, exactly-once callback,
    /// steal behaviour) are unchanged.
    pub fn submit_tracked_scoped<F, C>(
        &self,
        hint: Option<LaneHint>,
        epoch: u64,
        job: F,
        policy: RetryPolicy,
        on_done: C,
    ) where
        F: FnMut(usize, &Runtime) -> crate::Result<()> + Send + 'static,
        C: FnOnce(JobStatus) + Send + 'static,
    {
        self.submit_tracked_budgeted(hint, Some(epoch), None, job, policy, on_done);
    }

    /// The fully-general tracked submission: an optional lane hint, an
    /// optional submission `epoch` (see
    /// [`RuntimePool::submit_tracked_scoped`]) and an optional
    /// wall-clock `budget`.  A budgeted job still running past its
    /// budget is reaped by the watchdog: its lane is replaced with a
    /// fresh one and the callback fires exactly once with
    /// [`JobStatus::Failed`] of kind [`FaultKind::Timeout`] (never
    /// retried — the stuck lane cannot run a retry).  Budgeted bodies
    /// that write results through raw pointers must gate the writes on
    /// [`commit_current_job`].
    pub fn submit_tracked_budgeted<F, C>(
        &self,
        hint: Option<LaneHint>,
        epoch: Option<u64>,
        budget: Option<Duration>,
        job: F,
        policy: RetryPolicy,
        on_done: C,
    ) where
        F: FnMut(usize, &Runtime) -> crate::Result<()> + Send + 'static,
        C: FnOnce(JobStatus) + Send + 'static,
    {
        self.enqueue(Job {
            body: JobBody::Tracked(Box::new(job)),
            done: Some(Box::new(on_done)),
            policy,
            hint,
            epoch,
            budget,
        });
    }

    fn enqueue(&self, job: Job) {
        let mut st = lock(&self.shared.state);
        while st.queued >= self.shared.queue_cap && !st.closed && st.alive > 0 {
            st = self
                .shared
                .space
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
        if st.closed {
            return; // pool shutting down; job dropped
        }
        if st.alive == 0 {
            drop(st);
            // Dead pool: no lane will ever pop this job.  Complete the
            // tracker as Skipped so the caller's accounting (the wave
            // driver's cancel cone) still converges; the dead-pool
            // error itself surfaces at wait_idle.
            if let Some(done) = job.done {
                let _ = catch_unwind(AssertUnwindSafe(|| done(JobStatus::Skipped)));
            }
            return;
        }
        st.push(job);
        drop(st);
        self.shared.job_ready.notify_one();
    }

    /// Block until every submitted job has finished, then report the
    /// first untracked error (if any) and clear the poison flag so the
    /// pool can be reused.  Tracked-job failures are reported through
    /// their completion callbacks instead and never show up here.
    ///
    /// A *dead* pool — every lane gone for good with work still
    /// pending — returns an error instead of parking forever; the
    /// stranded queue is drained with `Skipped` callbacks first.
    pub fn wait_idle(&self) -> crate::Result<()> {
        self.wait_idle_until(None).map(|_| ())
    }

    /// [`RuntimePool::wait_idle`] with a wall-clock bound: `Ok(true)`
    /// when the pool drained (error reporting as in `wait_idle`),
    /// `Ok(false)` when `timeout` elapsed with work still pending —
    /// the caller decides what to do with the stragglers (the wave
    /// driver fences them with [`RuntimePool::advance_epoch`] and
    /// reports `DeadlineExceeded`).
    pub fn wait_idle_for(&self, timeout: Duration) -> crate::Result<bool> {
        self.wait_idle_until(Some(Instant::now() + timeout))
    }

    fn wait_idle_until(&self, deadline: Option<Instant>) -> crate::Result<bool> {
        let mut st = lock(&self.shared.state);
        loop {
            if st.queued == 0 && st.in_flight == 0 {
                break;
            }
            if st.alive == 0 {
                return Err(self.fail_dead_pool(st));
            }
            match deadline {
                None => {
                    st = self
                        .shared
                        .idle
                        .wait(st)
                        .unwrap_or_else(PoisonError::into_inner);
                }
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(false);
                    }
                    let (g, _) = self
                        .shared
                        .idle
                        .wait_timeout(st, d - now)
                        .unwrap_or_else(PoisonError::into_inner);
                    st = g;
                }
            }
        }
        drop(st);
        self.shared.poisoned.store(false, Ordering::Release);
        match lock(&self.shared.error).take() {
            Some(e) => Err(e),
            None => Ok(true),
        }
    }

    /// Every lane is dead with work still pending: drain the queue
    /// (callbacks fire `Skipped`), clear the poison, and compose the
    /// error — chaining the root cause (the last respawn failure) when
    /// one was recorded.
    fn fail_dead_pool(&self, mut st: MutexGuard<'_, QueueState>) -> anyhow::Error {
        let orphans = st.drain_all();
        drop(st);
        let n = orphans.len();
        for job in orphans {
            if let Some(done) = job.done {
                let _ = catch_unwind(AssertUnwindSafe(|| done(JobStatus::Skipped)));
            }
        }
        self.shared.space.notify_all();
        self.shared.idle.notify_all();
        self.shared.poisoned.store(false, Ordering::Release);
        let msg = format!("every pool lane is dead; {n} queued job(s) completed as Skipped");
        match lock(&self.shared.error).take() {
            Some(e) => e.context(msg),
            None => anyhow!("{msg}"),
        }
    }

    /// Compile `artifact` on *every* lane, outside any timed region (the
    /// analogue of FPGA reprogramming, excluded from kernel timing as in
    /// §4.2.4).  A barrier keeps each lane from grabbing two warmup jobs
    /// — each job is hinted to its own lane's shard, and no lane can
    /// finish one warmup job (and go stealing) before every lane has
    /// taken one — which is also why lane supervision must preserve the
    /// lane count: a shrunken pool would park the surviving lanes here
    /// forever.
    pub fn warmup_artifact(&self, artifact: &str) -> crate::Result<()> {
        // Drain any stale poison first: a poisoned lane would skip its
        // warmup job and leave the other lanes parked on the barrier.
        self.wait_idle()?;
        let barrier = Arc::new(Barrier::new(self.lanes));
        let name: Arc<str> = Arc::from(artifact);
        for lane in 0..self.lanes {
            let b = barrier.clone();
            let n = name.clone();
            self.submit_hinted(Some(lane), move |lane, rt| {
                // Catch panics locally: an unwinding compile must not
                // skip the barrier, or the other lanes would park in
                // b.wait() forever (lane_main's catch_unwind is too
                // late — it runs after this job body).
                let r = catch_unwind(AssertUnwindSafe(|| rt.executable(&n).map(|_| ())));
                // Rendezvous even on error so every lane's wait releases.
                b.wait();
                match r {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!(
                        "lane {lane} warmup panicked: {}",
                        crate::coordinator::scheduler::panic_text(p.as_ref())
                    )),
                }
            });
        }
        self.wait_idle()
    }

    /// Compile several artifacts on every lane (see
    /// [`RuntimePool::warmup_artifact`]) — the wavefront app runners
    /// use this for workloads that mix compute units (LUD's
    /// diagonal/perimeter/internal kernels, SRAD's reduction +
    /// stencil).
    pub fn warmup_artifacts(&self, artifacts: &[&str]) -> crate::Result<()> {
        for name in artifacts {
            self.warmup_artifact(name)?;
        }
        Ok(())
    }

    /// Convenience single execution on whichever lane is free first.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> crate::Result<Vec<Tensor>> {
        let (tx, rx) = std::sync::mpsc::channel();
        let name: Arc<str> = Arc::from(artifact);
        self.submit(move |_lane, rt| {
            // The caller sees the execution error through the channel;
            // don't also poison the pool.
            let _ = tx.send(rt.execute(&name, &inputs));
            Ok(())
        });
        match rx.recv() {
            Ok(r) => r,
            // The lane dropped the sender without replying: it skipped
            // the job because the pool was poisoned by an earlier batch
            // (or the lane died).  Harvest and report the real error
            // rather than a misleading channel failure.
            Err(_) => Err(self
                .wait_idle()
                .err()
                .unwrap_or_else(|| anyhow!("lane dropped the result channel"))),
        }
    }
}

impl Drop for RuntimePool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.closed = true;
        }
        self.shared.job_ready.notify_all();
        self.shared.space.notify_all();
        self.shared.watchdog_wake.notify_all();
        if let Some(h) = self.watchdog.take() {
            let _ = h.join();
        }
        // Reaped (zombie) threads may be parked in a hung body forever:
        // detach them instead of joining — they hold only Arc'd state.
        // The watchdog has already joined, so the zombie list is final.
        let zombies = lock(&self.shared.zombies).clone();
        let extras: Vec<JoinHandle<()>> = lock(&self.shared.extra_handles).drain(..).collect();
        for h in self.handles.drain(..).chain(extras) {
            if zombies.contains(&h.thread().id()) {
                continue;
            }
            let _ = h.join();
        }
    }
}

/// Guard that waits for the pool to drain on drop.  Hold one across any
/// region that submits jobs borrowing stack data through raw-pointer
/// writers (see [`crate::coordinator::grid::GridWriter2D`]): even on a
/// panic-unwind of the submitting frame, the guard drains the lanes
/// before the borrowed grid is freed.
pub struct IdleGuard<'a>(&'a RuntimePool);

impl<'a> IdleGuard<'a> {
    pub fn new(pool: &'a RuntimePool) -> Self {
        IdleGuard(pool)
    }
}

impl Drop for IdleGuard<'_> {
    fn drop(&mut self) {
        // Error (if any) is surfaced by the runner's own wait_idle call;
        // this drop only guarantees quiescence.
        let _ = self.0.wait_idle();
    }
}

/// Lane supervisor: creates the lane's `Runtime` and re-enters the job
/// loop with a fresh one whenever a panic escapes the per-job isolation
/// (chaos [`LaneKill`], or an unexpected unwind outside a job body), so
/// the pool never silently shrinks — `warmup_artifact`'s all-lanes
/// barrier depends on the lane count staying fixed.  The lane's CPU pin
/// is (re-)applied at the top of every iteration, so a respawned lane
/// lands back on its node before its fresh PJRT client allocates.
fn lane_entry(
    lane: usize,
    dir: PathBuf,
    registry: Registry,
    shared: Arc<Shared>,
    ready_tx: Option<std::sync::mpsc::Sender<crate::Result<()>>>,
) {
    let mut ready = ready_tx;
    loop {
        if let Some(cpus) = shared.plan.lane_cpus(lane) {
            if pin_current_thread(cpus) {
                shared.sched.pins_applied.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Chaos hook: a respawn (or watchdog replacement — both arrive
        // here with the ready channel already consumed) can be forced
        // to fail so tests can kill lanes for good.
        #[cfg(any(test, feature = "chaos"))]
        let construct = if ready.is_none() && shared.fail_respawns.load(Ordering::Acquire) {
            Err(anyhow!("chaos: lane respawn disabled"))
        } else {
            Runtime::with_registry(&dir, registry.clone())
        };
        #[cfg(not(any(test, feature = "chaos")))]
        let construct = Runtime::with_registry(&dir, registry.clone());
        let rt = match construct {
            Ok(rt) => {
                if let Some(tx) = ready.take() {
                    let _ = tx.send(Ok(()));
                }
                rt
            }
            Err(e) => {
                match ready.take() {
                    Some(tx) => {
                        let _ = tx.send(Err(e));
                    }
                    // A respawn needs a fresh PJRT client; if that
                    // fails the pool genuinely shrinks — surface it
                    // instead of pretending the lane is back.
                    None => shared.record_error(
                        e.context(format!("respawning lane {lane} after a panic")),
                    ),
                }
                shared.lane_gone();
                return;
            }
        };
        if catch_unwind(AssertUnwindSafe(|| lane_main(lane, &rt, &shared))).is_ok() {
            if LANE_REAPED.with(Cell::get) {
                return; // zombie exit: the watchdog owns the lane slot now
            }
            shared.lane_gone(); // clean shutdown: pool closed, queue drained
            return;
        }
        // The in-flight job was already reported Failed (with
        // FaultKind::Panic) by its JobGuard during the unwind; all that
        // is lost is the dead Runtime's compile cache.
        if LANE_REAPED.with(Cell::get) {
            // The unwinding job had already been reaped: the watchdog
            // replaced this lane, so respawning here would double it.
            return;
        }
        if lock(&shared.state).closed {
            shared.lane_gone();
            return;
        }
        shared.lane_restarts.fetch_add(1, Ordering::Relaxed);
    }
}

/// Per-job completion guard: fires the done callback and the in-flight
/// decrement exactly once, even when a chaos [`LaneKill`] panic unwinds
/// the lane mid-job — the pool's accounting stays sound while the
/// supervisor respawns the lane.
struct JobGuard<'a> {
    shared: &'a Shared,
    lane: usize,
    done: Option<DoneFn>,
    status: Option<JobStatus>,
    /// Budgeted jobs: the heartbeat sequence this guard must claim
    /// back (`finish`) before firing the callback parked in the
    /// beat's slot.  A failed claim means the watchdog reaped the job
    /// — callback, fault accounting and the in-flight decrement all
    /// happened on the watchdog thread, and this thread is a zombie.
    claim: Option<u64>,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        if let Some(seq) = self.claim {
            let beat = &self.shared.beats[self.lane];
            if !beat.finish(seq) {
                // Lost the claim race: tell the supervisor to let this
                // thread die quietly (its replacement is already up).
                LANE_REAPED.with(|f| f.set(true));
                return;
            }
            // Claimed: the callback comes back out of the park slot.
            self.done = lock(&beat.done_slot).take();
        }
        let status = self.status.take().unwrap_or_else(|| {
            // Only reachable when a panic is unwinding the lane:
            // account the terminal failure here.
            self.shared.jobs_failed.fetch_add(1, Ordering::Relaxed);
            JobStatus::Failed {
                kind: FaultKind::Panic,
                attempts: 1,
                message: format!("lane {} killed mid-job", self.lane),
            }
        });
        if let Some(done) = self.done.take() {
            // A panicking callback must not kill the lane (or mask an
            // in-progress LaneKill unwind): convert it to a pool error.
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| done(status))) {
                self.shared.record_error(anyhow!(
                    "lane {} completion callback panicked: {}",
                    self.lane,
                    crate::coordinator::scheduler::panic_text(p.as_ref())
                ));
            }
        }
        let mut st = lock(&self.shared.state);
        st.in_flight -= 1;
        if st.in_flight == 0 && st.queued == 0 {
            self.shared.idle.notify_all();
        }
    }
}

/// Park the callback, record the thread id and stamp `BUSY` for a
/// budgeted tracked job (no-op otherwise).  Runs inside the pop
/// critical section: the watchdog's deadline scan also runs under the
/// state lock, so a stamp is either visible to the scan or its
/// wake-notify lands after the scan enters its wait — the watchdog can
/// never sleep through a freshly-armed deadline.
fn arm_heartbeat(shared: &Shared, lane: usize, job: &mut Job) -> Option<u64> {
    let budget = job.budget?;
    if job.done.is_none() || !matches!(job.body, JobBody::Tracked(_)) {
        return None;
    }
    let beat = &shared.beats[lane];
    *lock(&beat.thread) = Some(std::thread::current().id());
    *lock(&beat.done_slot) = job.done.take();
    let budget_us = u64::try_from(budget.as_micros()).unwrap_or(u64::MAX);
    Some(beat.stamp(shared.now_us().saturating_add(budget_us)))
}

fn lane_main(lane: usize, rt: &Runtime, shared: &Arc<Shared>) {
    let mut last = RuntimeStats::default();
    loop {
        let popped = {
            let mut st = lock(&shared.state);
            loop {
                if let Some((mut job, pop)) = st.pop_for(lane) {
                    st.in_flight += 1;
                    // Decide the skip *under the lock* so only jobs
                    // that will actually run arm the watchdog.
                    let skip = shared.poisoned.load(Ordering::Acquire)
                        || epoch_stale(job.epoch, &shared.epoch);
                    let claim =
                        if skip { None } else { arm_heartbeat(shared, lane, &mut job) };
                    break Some((job, pop, skip, claim));
                }
                if st.closed {
                    break None;
                }
                st = shared
                    .job_ready
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        let Some((Job { body, done, policy, hint, .. }, pop, skip, claim)) = popped else {
            return;
        };
        shared.space.notify_one();
        if claim.is_some() {
            shared.watchdog_wake.notify_all();
        }
        if shared.multi_shard {
            match pop {
                Pop::Local => {
                    shared.sched.local_pops.fetch_add(1, Ordering::Relaxed);
                    if hint.is_some() {
                        shared.sched.affinity_hits.fetch_add(1, Ordering::Relaxed);
                    }
                }
                Pop::Stolen => {
                    shared.sched.queue_steals.fetch_add(1, Ordering::Relaxed);
                    if hint.is_some() {
                        shared.sched.affinity_misses.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }

        // The guard owns the callback and the in-flight decrement: both
        // fire exactly once, on every exit path out of run_job —
        // including the LaneKill re-raise.  For a budgeted job the
        // callback sits parked in the heartbeat slot and the guard
        // holds the claim instead.
        let mut guard = JobGuard { shared, lane, done, status: None, claim };
        guard.status = Some(if skip {
            // Stale epoch: a replay round has already abandoned this
            // submission; running it would race the re-armed wave
            // table.  The callback still fires (Skipped) exactly once.
            JobStatus::Skipped
        } else {
            if let Some(seq) = claim {
                let beat = shared.beats[lane].clone();
                CURRENT_CLAIM.with(|c| *c.borrow_mut() = Some((beat, seq)));
            }
            let status = run_job(lane, rt, shared, body, policy, claim);
            if claim.is_some() {
                CURRENT_CLAIM.with(|c| *c.borrow_mut() = None);
            }
            status
        });

        // Fold this lane's stats delta into its own atomic cell (no
        // lock: the cell is this lane's alone, readers fold all cells).
        let now = rt.stats();
        shared.lane_stats[lane].add_delta(&last, &now);
        last = now;

        drop(guard); // fires done, decrements in_flight, notifies idle
        if LANE_REAPED.with(Cell::get) {
            // The guard lost its claim: this thread is a zombie — its
            // replacement is already serving the lane slot.  Exit
            // without touching the queue or the live-lane count.
            return;
        }
    }
}

/// Run one job body to its terminal [`JobStatus`].  Untracked bodies
/// keep the original poisoning discipline; tracked bodies classify
/// every failure and retry `Transient` faults under the job's policy.
fn run_job(
    lane: usize,
    rt: &Runtime,
    shared: &Shared,
    body: JobBody,
    policy: RetryPolicy,
    claim: Option<u64>,
) -> JobStatus {
    // Post-attempt zombie probe: once the watchdog has reaped this
    // job, its terminal status was already delivered (Timeout) and its
    // fault accounted — whatever the woken body just returned is moot,
    // and retrying on a reaped lane would only burn a dead thread.
    // The returned status is discarded anyway (the guard's claim
    // fails), so `Skipped` is just a quiet placeholder.
    let reaped = || claim.is_some_and(|seq| shared.beats[lane].is_reaped(seq));
    match body {
        JobBody::Once(run) => match catch_unwind(AssertUnwindSafe(|| run(lane, rt))) {
            Ok(Ok(())) => JobStatus::Ok { retries: 0 },
            Ok(Err(e)) => {
                let status = JobStatus::Failed {
                    kind: FaultKind::of(&e),
                    attempts: 1,
                    message: format!("{e:#}"),
                };
                shared.record_error(e);
                status
            }
            Err(p) => {
                #[cfg(any(test, feature = "chaos"))]
                if p.downcast_ref::<LaneKill>().is_some() {
                    std::panic::resume_unwind(p);
                }
                let message = format!(
                    "lane {lane} job panicked: {}",
                    crate::coordinator::scheduler::panic_text(p.as_ref())
                );
                shared.record_error(anyhow!("{message}"));
                JobStatus::Failed { kind: FaultKind::Panic, attempts: 1, message }
            }
        },
        JobBody::Tracked(mut run) => {
            let max = policy.attempts.max(1);
            let mut attempt = 0u32;
            loop {
                attempt += 1;
                match catch_unwind(AssertUnwindSafe(|| run(lane, rt))) {
                    Ok(Ok(())) => return JobStatus::Ok { retries: attempt - 1 },
                    Ok(Err(e)) => {
                        if reaped() {
                            return JobStatus::Skipped;
                        }
                        let kind = FaultKind::of(&e);
                        if kind == FaultKind::Transient && attempt < max {
                            shared.job_retries.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(policy.delay(attempt));
                            continue;
                        }
                        shared.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        return JobStatus::Failed {
                            kind,
                            attempts: attempt,
                            message: format!("{e:#}"),
                        };
                    }
                    Err(p) => {
                        // A LaneKill panic is re-raised to take the
                        // whole lane down (the JobGuard reports the job,
                        // the supervisor respawns the lane); any other
                        // panic is terminal for the job only.
                        #[cfg(any(test, feature = "chaos"))]
                        if p.downcast_ref::<LaneKill>().is_some() {
                            std::panic::resume_unwind(p);
                        }
                        if reaped() {
                            return JobStatus::Skipped;
                        }
                        shared.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        return JobStatus::Failed {
                            kind: FaultKind::Panic,
                            attempts: attempt,
                            message: format!(
                                "lane {lane} job panicked: {}",
                                crate::coordinator::scheduler::panic_text(p.as_ref())
                            ),
                        };
                    }
                }
            }
        }
    }
}

/// The watchdog: sleeps until the nearest armed heartbeat deadline,
/// reaps lanes stuck past their budget, and replaces them.  Scans run
/// under the state lock — the same lock [`arm_heartbeat`] stamps under
/// — so a fresh deadline is either visible to the scan or its
/// `watchdog_wake` notify lands while the scan's wait is parked; the
/// watchdog can never sleep through an armed budget.  With nothing
/// budgeted it waits unbounded on the condvar and costs nothing.
fn watchdog_entry(shared: Arc<Shared>) {
    let mut st = lock(&shared.state);
    loop {
        if st.closed {
            return;
        }
        let now = shared.now_us();
        let mut nearest: Option<u64> = None; // µs until the next deadline
        let mut overdue: Vec<(usize, u64)> = Vec::new();
        for (lane, beat) in shared.beats.iter().enumerate() {
            let word = beat.word.load(Ordering::Acquire);
            if word & 3 != BEAT_BUSY {
                continue;
            }
            let deadline = beat.deadline_us.load(Ordering::Relaxed);
            if deadline == u64::MAX {
                continue;
            }
            if now >= deadline {
                overdue.push((lane, word >> 2));
            } else {
                let wait = deadline - now;
                nearest = Some(nearest.map_or(wait, |n| n.min(wait)));
            }
        }
        if !overdue.is_empty() {
            drop(st);
            for (lane, seq) in overdue {
                reap_lane(&shared, lane, seq);
            }
            st = lock(&shared.state);
            continue;
        }
        st = match nearest {
            // Nothing armed: any new stamp notifies `watchdog_wake`.
            None => shared
                .watchdog_wake
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner),
            Some(us) => {
                let (g, _) = shared
                    .watchdog_wake
                    .wait_timeout(st, Duration::from_micros(us.saturating_add(1)))
                    .unwrap_or_else(PoisonError::into_inner);
                g
            }
        };
    }
}

/// Reap one overdue lane: win the `BUSY -> REAPED` CAS (the job may
/// finish or commit first — then the lane keeps ownership and nothing
/// happens), take over the parked callback, spawn the replacement lane
/// thread, fire the callback as a `Timeout` failure and release the
/// in-flight slot.  Mirrors `JobGuard`'s ordering: callback before the
/// in-flight decrement, so `wait_idle` still waits for every callback.
fn reap_lane(shared: &Arc<Shared>, lane: usize, seq: u64) {
    let beat = &shared.beats[lane];
    if !beat.try_reap(seq) {
        return; // finished or committed between scan and CAS
    }
    // The stuck thread is a zombie now: remember its id so shutdown
    // detaches it instead of joining a thread that may never wake.
    if let Some(id) = *lock(&beat.thread) {
        lock(&shared.zombies).push(id);
    }
    let done = lock(&beat.done_slot).take();
    shared.job_timeouts.fetch_add(1, Ordering::Relaxed);
    shared.lanes_reaped.fetch_add(1, Ordering::Relaxed);
    shared.jobs_failed.fetch_add(1, Ordering::Relaxed);
    // Replace the lane before completing the job: the callback may
    // immediately release successor work that needs a live lane.
    let dir = shared.dir.clone();
    let reg = shared.registry.clone();
    let sh = shared.clone();
    // Sanctioned unscoped spawn (see clippy.toml): the replacement is
    // supervised exactly like an original lane and joins on shutdown.
    #[allow(clippy::disallowed_methods)]
    let spawned = std::thread::Builder::new()
        .name(format!("rt-lane-{lane}r"))
        .spawn(move || lane_entry(lane, dir, reg, sh, None));
    match spawned {
        Ok(h) => lock(&shared.extra_handles).push(h),
        Err(e) => {
            // No replacement: the pool genuinely shrinks.
            shared.record_error(anyhow!(
                "spawning a replacement for reaped lane {lane} failed: {e}"
            ));
            shared.lane_gone();
        }
    }
    if let Some(done) = done {
        let status = JobStatus::Failed {
            kind: FaultKind::Timeout,
            attempts: 1,
            message: format!("lane {lane} exceeded its job budget and was reaped"),
        };
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| done(status))) {
            shared.record_error(anyhow!(
                "reaped lane {lane} completion callback panicked: {}",
                crate::coordinator::scheduler::panic_text(p.as_ref())
            ));
        }
    }
    let mut st = lock(&shared.state);
    st.in_flight -= 1;
    if st.in_flight == 0 && st.queued == 0 {
        shared.idle.notify_all();
    }
}

/// Pure-logic probes over the pool's private queue/epoch machinery for
/// the loom models in `tests/loom.rs`.  Compiled only under
/// `--cfg loom`; nothing here spawns lanes or touches PJRT — the models
/// drive the exact [`QueueState::push`]/[`QueueState::pop_for`] and
/// [`epoch_stale`] code the real lanes execute, with loom's
/// model-checked primitives underneath (via [`crate::sync`]).
#[cfg(loom)]
pub mod loom_model {
    use super::{lock, Heartbeat, Job, JobBody, JobStatus, Pop, QueueState, RetryPolicy, Shard};
    use crate::sync::atomic::AtomicU64;
    use crate::sync::Mutex;

    /// See the private [`super::epoch_stale`] — re-exposed so the loom
    /// epoch-fence model checks the exact predicate `lane_main` runs.
    pub fn epoch_stale(epoch: Option<u64>, current: &AtomicU64) -> bool {
        super::epoch_stale(epoch, current)
    }

    /// A parked completion callback, as the heartbeat slot stores it.
    pub type ProbeDone = Box<dyn FnOnce(JobStatus) + Send + 'static>;

    /// One lane's [`Heartbeat`] driven through the *real* protocol ops
    /// (`stamp` / `try_commit` / `finish` / `try_reap`) so the loom
    /// model in `tests/loom.rs` explores the exact watchdog-vs-finish
    /// handshake `JobGuard` and `reap_lane` run: whichever side wins
    /// the word CAS gets the parked callback; the loser gets `None`.
    pub struct ProbeBeat(Heartbeat);

    impl Default for ProbeBeat {
        fn default() -> Self {
            Self::new()
        }
    }

    impl ProbeBeat {
        pub fn new() -> ProbeBeat {
            ProbeBeat(Heartbeat::new())
        }

        /// Lane side, job start ([`super::arm_heartbeat`]): park the
        /// callback and stamp `BUSY`; returns the claim sequence.  The
        /// deadline is immaterial to the model — the model *is* the
        /// watchdog.
        pub fn stamp(&self, done: ProbeDone) -> u64 {
            *lock(&self.0.done_slot) = Some(done);
            self.0.stamp(u64::MAX)
        }

        /// Body side: the pre-writeback commit fence
        /// ([`super::commit_current_job`]).
        pub fn try_commit(&self, seq: u64) -> bool {
            self.0.try_commit(seq)
        }

        /// Lane side, job end (`JobGuard::drop`): claim the word back.
        /// `Some` is the callback to fire; `None` means the watchdog
        /// reaped first and this side must fire nothing.
        pub fn finish(&self, seq: u64) -> Option<ProbeDone> {
            if self.0.finish(seq) {
                lock(&self.0.done_slot).take()
            } else {
                None
            }
        }

        /// Watchdog side (`reap_lane`): `BUSY -> REAPED`.  `Some` is
        /// the callback to fire as `Timeout`; `None` means the job
        /// finished or committed first.
        pub fn try_reap(&self, seq: u64) -> Option<ProbeDone> {
            if self.0.try_reap(seq) {
                lock(&self.0.done_slot).take()
            } else {
                None
            }
        }

        /// Post-wake zombie probe (`run_job`'s accounting skip).
        pub fn is_reaped(&self, seq: u64) -> bool {
            self.0.is_reaped(seq)
        }
    }

    /// The sharded run queue behind the same mutex discipline the lanes
    /// use.  Each probe job carries an observable `tag` in its `epoch`
    /// field (the body is a no-op and is never run).
    pub struct ProbeQueue {
        state: Mutex<QueueState>,
    }

    impl ProbeQueue {
        pub fn new(shards: usize) -> Self {
            assert!(shards >= 1, "a pool always has at least one shard");
            ProbeQueue {
                state: Mutex::new(QueueState {
                    shards: (0..shards).map(|_| Shard::default()).collect(),
                    queued: 0,
                    in_flight: 0,
                    closed: false,
                    rr: 0,
                    alive: shards,
                }),
            }
        }

        /// Enqueue a probe via the real [`QueueState::push`]: hinted
        /// jobs take the LIFO slot (displacing the previous occupant to
        /// the deque front), unhinted ones round-robin the FIFO backs.
        pub fn push(&self, hint: Option<usize>, tag: u64) {
            lock(&self.state).push(Job {
                body: JobBody::Tracked(Box::new(|_, _| Ok(()))),
                done: None,
                policy: RetryPolicy::default(),
                hint,
                epoch: Some(tag),
                budget: None,
            });
        }

        /// Pop for `lane` via the real [`QueueState::pop_for`].
        /// Returns `(tag, stolen, queued_after)`.
        pub fn pop_for(&self, lane: usize) -> Option<(u64, bool, usize)> {
            let mut st = lock(&self.state);
            let (job, pop) = st.pop_for(lane)?;
            let tag = job.epoch.expect("probe jobs always carry a tag");
            Some((tag, pop == Pop::Stolen, st.queued))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::AtomicU32;

    /// Pool over an empty registry: lanes start real PJRT clients but
    /// no artifacts exist — jobs that never touch `rt` (or that fail
    /// to) exercise the queue/retry/callback machinery pure-logically.
    fn test_pool(lanes: usize) -> RuntimePool {
        RuntimePool::with_registry(PathBuf::from("."), Registry::default(), lanes)
            .expect("lane startup needs no artifacts")
    }

    fn status_tag(s: &JobStatus) -> String {
        match s {
            JobStatus::Ok { retries } => format!("ok:{retries}"),
            JobStatus::Failed { kind, attempts, .. } => format!("failed:{kind}:{attempts}"),
            JobStatus::Skipped => "skipped".into(),
        }
    }

    #[test]
    fn tracked_callbacks_fire_exactly_once_in_completion_order() {
        // lanes=1 makes completion order deterministic (FIFO — a
        // single-lane pool has one shard, and unhinted jobs keep strict
        // submission order): a mixed success/panic/fatal/skip batch
        // must deliver exactly one status per job, in submission order,
        // with the tracked failures NOT poisoning the pool — only the
        // untracked failure surfaces at wait_idle.
        let pool = test_pool(1);
        let log = Arc::new(Mutex::new(Vec::<(usize, String)>::new()));
        let fired: Arc<Vec<AtomicU32>> =
            Arc::new((0..4).map(|_| AtomicU32::new(0)).collect());
        let track = |id: usize| {
            let log = log.clone();
            let fired = fired.clone();
            move |s: JobStatus| {
                fired[id].fetch_add(1, Ordering::SeqCst);
                lock(&log).push((id, status_tag(&s)));
            }
        };
        pool.submit_tracked(|_, _| Ok(()), RetryPolicy::none(), track(0));
        pool.submit_tracked(
            |_, _| -> crate::Result<()> { panic!("tracked job exploded") },
            RetryPolicy::none(),
            track(1),
        );
        pool.submit_tracked(
            |_, _| Err(anyhow!("structurally broken")),
            RetryPolicy::none(),
            track(2),
        );
        // Untracked failure poisons; the tracked job behind it skips.
        pool.submit(|_, _| Err(anyhow!("untracked batch failure")));
        pool.submit_tracked(|_, _| Ok(()), RetryPolicy::none(), track(3));

        let err = pool.wait_idle().expect_err("untracked failure must surface");
        assert!(format!("{err}").contains("untracked batch failure"), "got: {err}");
        assert_eq!(
            *lock(&log),
            vec![
                (0, "ok:0".into()),
                (1, "failed:panic:1".into()),
                (2, "failed:fatal:1".into()),
                (3, "skipped".into()),
            ]
        );
        for (id, n) in fired.iter().enumerate() {
            assert_eq!(n.load(Ordering::SeqCst), 1, "callback {id} fired more than once");
        }
        // Tracked failures alone never poison: drained exactly once.
        pool.wait_idle().unwrap();
        assert_eq!(pool.fault_counters().jobs_failed, 2);
    }

    #[test]
    fn transient_faults_retry_with_bounded_budget() {
        let pool = test_pool(1);
        let policy = RetryPolicy { attempts: 3, backoff: Duration::from_micros(50) };
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));

        // Fails transiently twice, succeeds on the third attempt.
        let tries = Arc::new(AtomicU32::new(0));
        let (t, s) = (tries.clone(), statuses.clone());
        pool.submit_tracked(
            move |_, _| {
                if t.fetch_add(1, Ordering::SeqCst) < 2 {
                    Err(crate::runtime::transient("flaky device".into()))
                } else {
                    Ok(())
                }
            },
            policy,
            move |st| lock(&s).push(status_tag(&st)),
        );
        // Always transient: exhausts the budget.
        let s = statuses.clone();
        pool.submit_tracked(
            move |_, _| Err(crate::runtime::transient("hopeless device".into())),
            policy,
            move |st| lock(&s).push(status_tag(&st)),
        );
        // Fatal: terminal on the first attempt despite the budget.
        let s = statuses.clone();
        pool.submit_tracked(
            move |_, _| Err(anyhow!("bad shape")),
            policy,
            move |st| lock(&s).push(status_tag(&st)),
        );

        pool.wait_idle().unwrap();
        assert_eq!(tries.load(Ordering::SeqCst), 3);
        assert_eq!(
            *lock(&statuses),
            vec!["ok:2".to_string(), "failed:transient:3".into(), "failed:fatal:1".into()]
        );
        let c = pool.fault_counters();
        assert_eq!(c.job_retries, 2 + 2, "two retries per transient job");
        assert_eq!(c.jobs_failed, 2);
        assert_eq!(c.lane_restarts, 0);
    }

    #[test]
    fn wait_idle_clears_poison_exactly_once() {
        let pool = test_pool(2);
        pool.submit(|_, _| Err(anyhow!("first failure")));
        let err = pool.wait_idle().expect_err("poison must surface once");
        assert!(format!("{err}").contains("first failure"));
        // Reported and cleared: the next drain is clean, and new work runs.
        pool.wait_idle().unwrap();
        let ran = Arc::new(AtomicU32::new(0));
        let r = ran.clone();
        pool.submit(move |_, _| {
            r.fetch_add(1, Ordering::SeqCst);
            Ok(())
        });
        pool.wait_idle().unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn lane_kill_restarts_the_lane_and_reports_failed_panic() {
        let pool = test_pool(1);
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));
        let s = statuses.clone();
        pool.submit_tracked(
            |_, _| -> crate::Result<()> { std::panic::panic_any(LaneKill) },
            RetryPolicy::default(),
            move |st| lock(&s).push(status_tag(&st)),
        );
        pool.wait_idle().unwrap();
        assert_eq!(*lock(&statuses), vec!["failed:panic:1".to_string()]);
        assert_eq!(pool.fault_counters().lane_restarts, 1);
        // The respawned lane (fresh Runtime, same thread slot) still
        // serves jobs — the pool did not shrink.
        let ran = Arc::new(AtomicU32::new(0));
        let r = ran.clone();
        pool.submit_tracked(
            move |_, _| {
                r.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
            RetryPolicy::none(),
            |st| assert!(st.is_ok()),
        );
        pool.wait_idle().unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn concurrent_tracked_jobs_deliver_every_callback() {
        let pool = test_pool(4);
        let n = 64usize;
        let fired: Arc<Vec<AtomicU32>> =
            Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        let (oks, fails) = (Arc::new(AtomicU32::new(0)), Arc::new(AtomicU32::new(0)));
        for i in 0..n {
            let fired = fired.clone();
            let (oks, fails) = (oks.clone(), fails.clone());
            pool.submit_tracked(
                move |_, _| {
                    if i % 3 == 0 {
                        Err(anyhow!("job {i} failed"))
                    } else {
                        Ok(())
                    }
                },
                RetryPolicy::none(),
                move |st| {
                    fired[i].fetch_add(1, Ordering::SeqCst);
                    if st.is_ok() {
                        oks.fetch_add(1, Ordering::SeqCst);
                    } else {
                        fails.fetch_add(1, Ordering::SeqCst);
                    }
                },
            );
        }
        // wait_idle waits for the callbacks too (they fire before the
        // in-flight decrement), so every counter is final here.
        pool.wait_idle().unwrap();
        for (i, f) in fired.iter().enumerate() {
            assert_eq!(f.load(Ordering::SeqCst), 1, "job {i}");
        }
        assert_eq!(oks.load(Ordering::SeqCst) + fails.load(Ordering::SeqCst), n as u32);
        assert_eq!(fails.load(Ordering::SeqCst) as usize, n.div_ceil(3));
    }

    #[test]
    fn randomized_hints_run_every_job_exactly_once_with_full_accounting() {
        // The core sharded-queue invariant: under randomized hints and
        // live stealing at lanes=4, every job's body runs exactly once,
        // every callback fires exactly once, and every pop is accounted
        // as either local or stolen (no job materializes or vanishes).
        let pool = test_pool(4);
        let n = 200usize;
        let mut rng = crate::testutil::Rng::new(7);
        let bodies: Arc<Vec<AtomicU32>> =
            Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        let callbacks: Arc<Vec<AtomicU32>> =
            Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        for i in 0..n {
            // Mostly hinted (arbitrary shard targets, including far
            // beyond the lane count — hints wrap), some unhinted.
            let hint = if rng.usize_in(0, 4) == 0 { None } else { Some(rng.usize_in(0, 63)) };
            let bodies = bodies.clone();
            let callbacks = callbacks.clone();
            pool.submit_tracked_hinted(
                hint,
                move |_, _| {
                    bodies[i].fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                RetryPolicy::none(),
                move |st| {
                    assert!(st.is_ok());
                    callbacks[i].fetch_add(1, Ordering::SeqCst);
                },
            );
        }
        pool.wait_idle().unwrap();
        for i in 0..n {
            assert_eq!(bodies[i].load(Ordering::SeqCst), 1, "job {i} body count");
            assert_eq!(callbacks[i].load(Ordering::SeqCst), 1, "job {i} callback count");
        }
        let c = pool.sched_counters();
        assert_eq!(
            c.local_pops + c.queue_steals,
            n as u64,
            "every pop is exactly one of local/stolen"
        );
        assert!(
            c.affinity_hits + c.affinity_misses <= n as u64,
            "only hinted jobs count toward affinity"
        );
    }

    #[test]
    fn stolen_tracked_job_retries_on_the_thief() {
        // Park one lane inside a job, hint a transiently-failing probe
        // at that busy lane: the idle lane must steal it, and the retry
        // must run on the thief (the retry loop runs wherever the job
        // was popped) — never bouncing back to the hinted lane.
        let pool = test_pool(2);
        let (lane_tx, lane_rx) = std::sync::mpsc::channel::<usize>();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        pool.submit_tracked_hinted(
            Some(0),
            move |lane, _| {
                let _ = lane_tx.send(lane);
                let _ = release_rx.recv();
                Ok(())
            },
            RetryPolicy::none(),
            |st| assert!(st.is_ok()),
        );
        let busy = lane_rx.recv().expect("blocker must start");

        let attempts = Arc::new(Mutex::new(Vec::<usize>::new()));
        let (done_tx, done_rx) = std::sync::mpsc::channel::<String>();
        let a = attempts.clone();
        let flaky = AtomicU32::new(0);
        pool.submit_tracked_hinted(
            Some(busy),
            move |lane, _| {
                lock(&a).push(lane);
                if flaky.fetch_add(1, Ordering::SeqCst) == 0 {
                    Err(crate::runtime::transient("first attempt hiccup".into()))
                } else {
                    Ok(())
                }
            },
            RetryPolicy { attempts: 3, backoff: Duration::from_micros(50) },
            move |st| {
                let _ = done_tx.send(status_tag(&st));
            },
        );
        // The probe completes while the hinted lane is still parked:
        // only the thief could have run it.
        assert_eq!(done_rx.recv().unwrap(), "ok:1");
        let _ = release_tx.send(());
        pool.wait_idle().unwrap();

        let lanes_seen = lock(&attempts).clone();
        assert_eq!(lanes_seen.len(), 2, "one transient failure + one retry");
        assert_eq!(lanes_seen[0], lanes_seen[1], "retry must stay on the thief");
        assert_ne!(lanes_seen[0], busy, "the hinted lane was parked — a thief ran the job");
        let c = pool.sched_counters();
        assert!(c.queue_steals >= 1, "the probe was stolen");
        assert!(c.affinity_misses >= 1, "a stolen hinted job is an affinity miss");
        assert_eq!(pool.fault_counters().job_retries, 1);
    }

    #[test]
    fn unsharded_pool_runs_hinted_jobs_and_counts_nothing() {
        // PoolConfig { sharded: false } is the PR 6 global-queue
        // engine: hints are accepted (and ignored), the locality
        // counters stay zero — the legacy scheduler has no locality.
        let pool = RuntimePool::with_registry_cfg(
            PathBuf::from("."),
            Registry::default(),
            PoolConfig { lanes: 2, sharded: false, ..PoolConfig::default() },
        )
        .unwrap();
        let ran = Arc::new(AtomicU32::new(0));
        for i in 0..16usize {
            let r = ran.clone();
            pool.submit_tracked_hinted(
                Some(i),
                move |_, _| {
                    r.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                RetryPolicy::none(),
                |st| assert!(st.is_ok()),
            );
        }
        pool.wait_idle().unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 16);
        assert_eq!(pool.sched_counters(), SchedCounters::default());
    }

    /// Two-shard queue state for driving the routing logic directly
    /// (a 1-shard pool ignores hints, so lanes=1 can't exercise it).
    fn two_shard_state() -> QueueState {
        QueueState {
            shards: (0..2).map(|_| Shard::default()).collect(),
            queued: 0,
            in_flight: 0,
            closed: false,
            rr: 0,
            alive: 2,
        }
    }

    /// A job hinted at shard `h % 2`.  Hints 0/2/4 all land on shard 0
    /// while staying distinguishable, so the hint doubles as a tag.
    fn hinted(h: usize) -> Job {
        Job {
            body: JobBody::Once(Box::new(|_, _| Ok(()))),
            done: None,
            policy: RetryPolicy::none(),
            hint: Some(h),
            epoch: None,
            budget: None,
        }
    }

    #[test]
    fn hinted_shard_drains_lifo_through_the_slot() {
        // Three jobs hinted at one shard must come back newest-first
        // (slot, then deque front) — the LIFO order that keeps a
        // block's freshest successor cache-warm for its owner lane.
        let mut st = two_shard_state();
        st.push(hinted(0));
        st.push(hinted(2));
        st.push(hinted(4));
        assert_eq!(st.queued, 3);
        let mut seen = Vec::new();
        while let Some((job, pop)) = st.pop_for(0) {
            assert!(matches!(pop, Pop::Local), "owner pops are local");
            seen.push(job.hint.unwrap());
        }
        assert_eq!(seen, vec![4, 2, 0], "owner drains newest-first");
        assert_eq!(st.queued, 0);
    }

    #[test]
    fn thief_steals_the_cold_end_first() {
        // Victim shard holds hinted jobs (slot = newest, deque back =
        // oldest); a thief must drain the cold end before touching the
        // slot — the owner keeps its warmest work longest.
        let mut st = two_shard_state();
        st.push(hinted(0));
        st.push(hinted(2));
        st.push(hinted(4));
        let mut seen = Vec::new();
        while let Some((job, pop)) = st.pop_for(1) {
            assert!(matches!(pop, Pop::Stolen), "cross-shard pops are steals");
            seen.push(job.hint.unwrap());
        }
        assert_eq!(seen, vec![0, 2, 4], "thief drains oldest-first, slot last");
        // Sanity: the owner sees nothing left either.
        assert!(st.pop_for(0).is_none());
        assert_eq!(st.queued, 0);
    }

    #[test]
    fn stale_epoch_job_skips_without_running_the_body() {
        // A job scoped to an epoch that has already been superseded
        // must never run its body — the lane completes it as Skipped
        // (callback still exactly once).  A job scoped to the *current*
        // epoch runs normally.  This is the fence the cone-replay
        // driver leans on: stragglers from an abandoned replay round
        // cannot write back into re-armed wave-table counters.
        let pool = test_pool(2);
        let stale_epoch = pool.advance_epoch();
        let live_epoch = pool.advance_epoch(); // supersedes stale_epoch
        assert_eq!(pool.epoch(), live_epoch);

        let ran = Arc::new(AtomicU32::new(0));
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));
        for (tag, epoch) in [("stale", stale_epoch), ("live", live_epoch)] {
            let ran = ran.clone();
            let statuses = statuses.clone();
            pool.submit_tracked_scoped(
                None,
                epoch,
                move |_, _| {
                    ran.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                RetryPolicy::default(),
                move |s| lock(&statuses).push(format!("{tag}:{}", status_tag(&s))),
            );
        }
        pool.wait_idle().unwrap();

        assert_eq!(ran.load(Ordering::SeqCst), 1, "only the live-epoch body runs");
        let mut got = lock(&statuses).clone();
        got.sort();
        assert_eq!(got, vec!["live:ok:0".to_string(), "stale:skipped".to_string()]);
        // Skipping is not a failure: the fault counters stay clean.
        assert_eq!(pool.fault_counters().jobs_failed, 0);
    }

    #[test]
    fn watchdog_reaps_over_budget_job_as_timeout() {
        // A budgeted job that blows its budget is reaped: the callback
        // fires exactly once as Failed{Timeout, attempts: 1}, the stuck
        // lane is replaced (lanes_reaped, not lane_restarts), and the
        // pool keeps serving jobs on the replacement.  The zombie
        // thread wakes later, loses the heartbeat CAS, and exits
        // without firing anything or touching the counters.
        let pool = test_pool(1);
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));
        let s = statuses.clone();
        pool.submit_tracked_budgeted(
            None,
            None,
            Some(Duration::from_millis(25)),
            |_, _| {
                std::thread::sleep(Duration::from_millis(400));
                Ok(())
            },
            RetryPolicy::default(),
            move |st| lock(&s).push(status_tag(&st)),
        );
        // wait_idle returns as soon as the watchdog completes the job —
        // long before the zombie's 400ms sleep ends.
        let t0 = Instant::now();
        pool.wait_idle().unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(350),
            "the watchdog, not the hung body, must complete the job"
        );
        assert_eq!(*lock(&statuses), vec!["failed:timeout:1".to_string()]);
        let c = pool.fault_counters();
        assert_eq!(c.job_timeouts, 1);
        assert_eq!(c.lanes_reaped, 1);
        assert_eq!(c.jobs_failed, 1, "a timeout is also a failed job");
        assert_eq!(c.lane_restarts, 0, "reaping is not the panic-respawn path");
        // The replacement lane serves new work; the pool did not shrink.
        assert_eq!(pool.alive_lanes(), 1);
        let ran = Arc::new(AtomicU32::new(0));
        let r = ran.clone();
        pool.submit_tracked(
            move |_, _| {
                r.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
            RetryPolicy::none(),
            |st| assert!(st.is_ok()),
        );
        pool.wait_idle().unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn within_budget_job_completes_ok() {
        // A budget is an upper bound, not a cost: a job that finishes
        // inside it completes Ok and no watchdog machinery fires.
        let pool = test_pool(2);
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));
        for _ in 0..8 {
            let s = statuses.clone();
            pool.submit_tracked_budgeted(
                None,
                None,
                Some(Duration::from_secs(30)),
                |_, _| Ok(()),
                RetryPolicy::default(),
                move |st| lock(&s).push(status_tag(&st)),
            );
        }
        pool.wait_idle().unwrap();
        assert_eq!(*lock(&statuses), vec!["ok:0".to_string(); 8]);
        let c = pool.fault_counters();
        assert_eq!((c.job_timeouts, c.lanes_reaped), (0, 0));
    }

    #[test]
    fn committed_job_outruns_its_budget_safely() {
        // The pre-writeback fence: once a body calls
        // commit_current_job() the reap window is closed — the watchdog
        // leaves the lane alone even though the job runs far past its
        // budget, and the job completes Ok on its own lane.
        let pool = test_pool(1);
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));
        let s = statuses.clone();
        pool.submit_tracked_budgeted(
            None,
            None,
            Some(Duration::from_millis(25)),
            |_, _| {
                assert!(commit_current_job(), "nothing reaped us yet");
                std::thread::sleep(Duration::from_millis(150));
                Ok(())
            },
            RetryPolicy::default(),
            move |st| lock(&s).push(status_tag(&st)),
        );
        pool.wait_idle().unwrap();
        assert_eq!(*lock(&statuses), vec!["ok:0".to_string()]);
        let c = pool.fault_counters();
        assert_eq!((c.job_timeouts, c.lanes_reaped), (0, 0));
        // An unbudgeted job never holds a claim: the fence reports
        // "not reaped" trivially (there is nothing to commit).
        pool.submit_tracked(
            |_, _| {
                assert!(commit_current_job());
                Ok(())
            },
            RetryPolicy::none(),
            |st| assert!(st.is_ok()),
        );
        pool.wait_idle().unwrap();
    }

    #[test]
    fn wait_idle_for_reports_timeout_then_drains() {
        let pool = test_pool(1);
        // Nothing pending: an idle pool drains immediately.
        assert!(pool.wait_idle_for(Duration::from_secs(5)).unwrap());

        let (tx, rx) = std::sync::mpsc::channel::<()>();
        pool.submit_tracked(
            move |_, _| {
                let _ = rx.recv();
                Ok(())
            },
            RetryPolicy::none(),
            |st| assert!(st.is_ok()),
        );
        // The unbudgeted job is parked: the bounded wait expires
        // without declaring the pool broken...
        assert!(!pool.wait_idle_for(Duration::from_millis(50)).unwrap());
        // ...and a later wait succeeds once the job is released.
        tx.send(()).unwrap();
        assert!(pool.wait_idle_for(Duration::from_secs(30)).unwrap());
        assert_eq!(pool.fault_counters(), FaultCounters::default());
    }

    #[test]
    fn dead_pool_errs_from_wait_idle_instead_of_deadlocking() {
        // Satellite regression: every lane dead (LaneKill + respawn
        // failure via the chaos hook) with work still queued must turn
        // wait_idle into an Err — queued tracked jobs complete as
        // Skipped — rather than a deadlock on the idle condvar.
        let pool = test_pool(1);
        pool.chaos_fail_respawns();
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        // Park the only lane so the kill and the probe queue up behind.
        let s = statuses.clone();
        pool.submit_tracked(
            move |_, _| {
                let _ = rx.recv();
                Ok(())
            },
            RetryPolicy::none(),
            move |st| lock(&s).push(status_tag(&st)),
        );
        let s = statuses.clone();
        pool.submit_tracked(
            |_, _| -> crate::Result<()> { std::panic::panic_any(LaneKill) },
            RetryPolicy::default(),
            move |st| lock(&s).push(status_tag(&st)),
        );
        let s = statuses.clone();
        pool.submit_tracked(
            |_, _| Ok(()),
            RetryPolicy::none(),
            move |st| lock(&s).push(status_tag(&st)),
        );
        tx.send(()).unwrap();

        let err = pool.wait_idle().expect_err("a dead pool must surface, not hang");
        assert!(
            format!("{err}").contains("every pool lane is dead"),
            "unexpected error: {err:#}"
        );
        assert_eq!(pool.alive_lanes(), 0);
        assert_eq!(
            *lock(&statuses),
            vec!["ok:0".to_string(), "failed:panic:1".into(), "skipped".into()],
            "the queued probe completes as Skipped, exactly once"
        );
        // Submitting into a dead pool is not a hang either: the
        // tracked callback fires Skipped inline from enqueue.
        let s = statuses.clone();
        pool.submit_tracked(
            |_, _| Ok(()),
            RetryPolicy::none(),
            move |st| lock(&s).push(status_tag(&st)),
        );
        assert_eq!(lock(&statuses).last().map(String::as_str), Some("skipped"));
    }
}
