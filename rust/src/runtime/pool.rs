//! Multi-lane runtime pool: N worker threads, each owning its own PJRT
//! CPU client — the software analogue of the thesis's replicated compute
//! units (`PAR`, §4.3.1.6, §5.3).
//!
//! The PJRT client wraps an `Rc` and is `!Send`, so a [`Runtime`] can
//! never cross threads.  The pool sidesteps that by *creating* one
//! `Runtime` per lane thread, on that thread: the artifact manifest is
//! parsed once and shared (cloned) into every lane, while executables are
//! compiled per lane (per-lane compile caches — each PJRT client must own
//! its executables).
//!
//! Work arrives as boxed `FnOnce(lane, &Runtime)` jobs through a bounded
//! queue (backpressure for the extractor side).  Errors and panics inside
//! jobs poison the pool until the next [`RuntimePool::wait_idle`], which
//! reports the first failure; remaining queued jobs of the failed batch
//! are drained without running.
//!
//! [`RuntimePool::submit_tracked`] attaches a **per-job completion
//! callback**: the callback fires exactly once per job — after the job
//! body runs, or when a poisoned pool drains (skips) the job — with a
//! success flag, *before* the job is counted out of the in-flight set.
//! The cross-pass pass driver uses this to advance its dependency table
//! without a global [`RuntimePool::wait_idle`] barrier between passes
//! (see [`crate::coordinator::passdriver`]).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context};

use super::{Registry, Runtime, RuntimeStats, Tensor};

/// A pool job body.  Takes the lane index and that lane's runtime.
type RunFn = Box<dyn FnOnce(usize, &Runtime) -> crate::Result<()> + Send + 'static>;

/// A per-job completion callback; receives `true` iff the job body ran
/// and returned `Ok` (a skipped job on a poisoned pool reports `false`).
type DoneFn = Box<dyn FnOnce(bool) + Send + 'static>;

/// A unit of pool work: the body plus an optional completion callback.
struct Job {
    run: RunFn,
    done: Option<DoneFn>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    in_flight: usize,
    closed: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    /// Lanes wait here for work.
    job_ready: Condvar,
    /// Producers wait here for queue space.
    space: Condvar,
    /// `wait_idle` callers wait here for the queue to drain.
    idle: Condvar,
    /// First error from any lane since the last `wait_idle`.
    error: Mutex<Option<anyhow::Error>>,
    /// Set alongside `error`; lanes drain (skip) jobs while poisoned.
    poisoned: AtomicBool,
    /// Aggregated per-lane runtime stats (updated after every job).
    stats: Mutex<RuntimeStats>,
    queue_cap: usize,
}

impl Shared {
    fn record_error(&self, e: anyhow::Error) {
        self.poisoned.store(true, Ordering::Release);
        self.error.lock().unwrap().get_or_insert(e);
    }
}

/// `N` lane threads, each with its own PJRT client and compile cache.
pub struct RuntimePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    registry: Registry,
    lanes: usize,
}

impl RuntimePool {
    /// Open the artifact directory and spin up `lanes` worker threads
    /// (clamped to ≥ 1).  The manifest is read once on the calling
    /// thread; each lane then creates its own PJRT client.  Returns an
    /// error if the manifest fails to parse or any lane fails to start.
    pub fn open(dir: impl AsRef<Path>, lanes: usize) -> crate::Result<RuntimePool> {
        let lanes = lanes.max(1);
        let dir: PathBuf = dir.as_ref().to_path_buf();
        let registry = Registry::load(dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                in_flight: 0,
                closed: false,
            }),
            job_ready: Condvar::new(),
            space: Condvar::new(),
            idle: Condvar::new(),
            error: Mutex::new(None),
            poisoned: AtomicBool::new(false),
            stats: Mutex::new(RuntimeStats::default()),
            queue_cap: (lanes * 4).max(8),
        });
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<crate::Result<()>>();
        let mut handles = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let dir = dir.clone();
            let reg = registry.clone();
            let sh = shared.clone();
            let tx = ready_tx.clone();
            let handle = match std::thread::Builder::new()
                .name(format!("rt-lane-{lane}"))
                .spawn(move || lane_main(lane, dir, reg, sh, tx))
            {
                Ok(h) => h,
                Err(e) => {
                    // Release the lanes already spawned so they exit.
                    shared.state.lock().unwrap().closed = true;
                    shared.job_ready.notify_all();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(anyhow!("spawning lane {lane} failed: {e}"));
                }
            };
            handles.push(handle);
        }
        drop(ready_tx);
        let pool = RuntimePool { shared, handles, registry, lanes };
        for _ in 0..lanes {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("lane thread died during startup"))?
                .context("opening a lane runtime")?;
        }
        Ok(pool)
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Aggregate execution stats across all lanes.
    pub fn stats(&self) -> RuntimeStats {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Enqueue a job.  Blocks while the queue is at capacity (the
    /// bounded-channel backpressure between extractors and lanes).
    pub fn submit<F>(&self, job: F)
    where
        F: FnOnce(usize, &Runtime) -> crate::Result<()> + Send + 'static,
    {
        self.enqueue(Job { run: Box::new(job), done: None });
    }

    /// Enqueue a job with a completion callback.  `on_done(ok)` fires
    /// exactly once — after the job body returns, or with `ok = false`
    /// when a poisoned pool drains the job without running it — and is
    /// ordered before the job leaves the in-flight count (so
    /// [`RuntimePool::wait_idle`] also waits for every callback).
    pub fn submit_tracked<F, C>(&self, job: F, on_done: C)
    where
        F: FnOnce(usize, &Runtime) -> crate::Result<()> + Send + 'static,
        C: FnOnce(bool) + Send + 'static,
    {
        self.enqueue(Job { run: Box::new(job), done: Some(Box::new(on_done)) });
    }

    fn enqueue(&self, job: Job) {
        let mut st = self.shared.state.lock().unwrap();
        while st.jobs.len() >= self.shared.queue_cap && !st.closed {
            st = self.shared.space.wait(st).unwrap();
        }
        if st.closed {
            return; // pool shutting down; job dropped
        }
        st.jobs.push_back(job);
        drop(st);
        self.shared.job_ready.notify_one();
    }

    /// Block until every submitted job has finished, then report the
    /// first error (if any) and clear the poison flag so the pool can be
    /// reused.
    pub fn wait_idle(&self) -> crate::Result<()> {
        let mut st = self.shared.state.lock().unwrap();
        while !(st.jobs.is_empty() && st.in_flight == 0) {
            st = self.shared.idle.wait(st).unwrap();
        }
        drop(st);
        self.shared.poisoned.store(false, Ordering::Release);
        match self.shared.error.lock().unwrap().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Compile `artifact` on *every* lane, outside any timed region (the
    /// analogue of FPGA reprogramming, excluded from kernel timing as in
    /// §4.2.4).  A barrier keeps each lane from grabbing two warmup jobs.
    pub fn warmup_artifact(&self, artifact: &str) -> crate::Result<()> {
        // Drain any stale poison first: a poisoned lane would skip its
        // warmup job and leave the other lanes parked on the barrier.
        self.wait_idle()?;
        let barrier = Arc::new(Barrier::new(self.lanes));
        let name: Arc<str> = Arc::from(artifact);
        for _ in 0..self.lanes {
            let b = barrier.clone();
            let n = name.clone();
            self.submit(move |lane, rt| {
                // Catch panics locally: an unwinding compile must not
                // skip the barrier, or the other lanes would park in
                // b.wait() forever (lane_main's catch_unwind is too
                // late — it runs after this job body).
                let r = catch_unwind(AssertUnwindSafe(|| rt.executable(&n).map(|_| ())));
                // Rendezvous even on error so every lane's wait releases.
                b.wait();
                match r {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!(
                        "lane {lane} warmup panicked: {}",
                        crate::coordinator::scheduler::panic_text(p.as_ref())
                    )),
                }
            });
        }
        self.wait_idle()
    }

    /// Compile several artifacts on every lane (see
    /// [`RuntimePool::warmup_artifact`]) — the wavefront app runners
    /// use this for workloads that mix compute units (LUD's
    /// diagonal/perimeter/internal kernels, SRAD's reduction +
    /// stencil).
    pub fn warmup_artifacts(&self, artifacts: &[&str]) -> crate::Result<()> {
        for name in artifacts {
            self.warmup_artifact(name)?;
        }
        Ok(())
    }

    /// Convenience single execution on whichever lane is free first.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> crate::Result<Vec<Tensor>> {
        let (tx, rx) = std::sync::mpsc::channel();
        let name: Arc<str> = Arc::from(artifact);
        self.submit(move |_lane, rt| {
            // The caller sees the execution error through the channel;
            // don't also poison the pool.
            let _ = tx.send(rt.execute(&name, &inputs));
            Ok(())
        });
        match rx.recv() {
            Ok(r) => r,
            // The lane dropped the sender without replying: it skipped
            // the job because the pool was poisoned by an earlier batch
            // (or the lane died).  Harvest and report the real error
            // rather than a misleading channel failure.
            Err(_) => Err(self
                .wait_idle()
                .err()
                .unwrap_or_else(|| anyhow!("lane dropped the result channel"))),
        }
    }
}

impl Drop for RuntimePool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
        }
        self.shared.job_ready.notify_all();
        self.shared.space.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Guard that waits for the pool to drain on drop.  Hold one across any
/// region that submits jobs borrowing stack data through raw-pointer
/// writers (see [`crate::coordinator::grid::GridWriter2D`]): even on a
/// panic-unwind of the submitting frame, the guard drains the lanes
/// before the borrowed grid is freed.
pub struct IdleGuard<'a>(&'a RuntimePool);

impl<'a> IdleGuard<'a> {
    pub fn new(pool: &'a RuntimePool) -> Self {
        IdleGuard(pool)
    }
}

impl Drop for IdleGuard<'_> {
    fn drop(&mut self) {
        // Error (if any) is surfaced by the runner's own wait_idle call;
        // this drop only guarantees quiescence.
        let _ = self.0.wait_idle();
    }
}

fn lane_main(
    lane: usize,
    dir: PathBuf,
    registry: Registry,
    shared: Arc<Shared>,
    ready_tx: std::sync::mpsc::Sender<crate::Result<()>>,
) {
    let rt = match Runtime::with_registry(&dir, registry) {
        Ok(rt) => {
            let _ = ready_tx.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    drop(ready_tx);
    let mut last = RuntimeStats::default();
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    st.in_flight += 1;
                    break Some(j);
                }
                if st.closed {
                    break None;
                }
                st = shared.job_ready.wait(st).unwrap();
            }
        };
        let Some(Job { run, done }) = job else { return };
        shared.space.notify_one();

        let mut ok = false;
        if !shared.poisoned.load(Ordering::Acquire) {
            match catch_unwind(AssertUnwindSafe(|| run(lane, &rt))) {
                Ok(Ok(())) => ok = true,
                Ok(Err(e)) => shared.record_error(e),
                Err(p) => shared.record_error(anyhow!(
                    "lane {lane} job panicked: {}",
                    crate::coordinator::scheduler::panic_text(p.as_ref())
                )),
            }
        }
        // The completion callback fires exactly once per job — also for
        // jobs a poisoned pool drained without running (ok = false) —
        // and before the in_flight decrement below, so wait_idle also
        // waits for callbacks.  A panicking callback must not kill the
        // lane thread: convert it to a pool error like any job failure.
        if let Some(done) = done {
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| done(ok))) {
                shared.record_error(anyhow!(
                    "lane {lane} completion callback panicked: {}",
                    crate::coordinator::scheduler::panic_text(p.as_ref())
                ));
            }
        }

        // Fold this lane's stats delta into the pool aggregate.
        let now = rt.stats();
        {
            let mut agg = shared.stats.lock().unwrap();
            agg.executions += now.executions - last.executions;
            agg.compile_ms += now.compile_ms - last.compile_ms;
            agg.execute_ms += now.execute_ms - last.execute_ms;
            agg.marshal_ms += now.marshal_ms - last.marshal_ms;
        }
        last = now;

        let mut st = shared.state.lock().unwrap();
        st.in_flight -= 1;
        if st.in_flight == 0 && st.jobs.is_empty() {
            shared.idle.notify_all();
        }
    }
}
