//! Multi-lane runtime pool: N worker threads, each owning its own PJRT
//! CPU client — the software analogue of the thesis's replicated compute
//! units (`PAR`, §4.3.1.6, §5.3).
//!
//! The PJRT client wraps an `Rc` and is `!Send`, so a [`Runtime`] can
//! never cross threads.  The pool sidesteps that by *creating* one
//! `Runtime` per lane thread, on that thread: the artifact manifest is
//! parsed once and shared (cloned) into every lane, while executables are
//! compiled per lane (per-lane compile caches — each PJRT client must own
//! its executables).
//!
//! Work arrives as boxed jobs through a bounded queue (backpressure for
//! the extractor side).  There are two failure disciplines:
//!
//! * **Untracked jobs** ([`RuntimePool::submit`]) keep the original
//!   batch semantics: the first error or panic poisons the pool until
//!   the next [`RuntimePool::wait_idle`], which reports it and clears
//!   the poison; remaining queued jobs of the failed batch are drained
//!   without running.  Warmup and the one-shot
//!   [`RuntimePool::execute`] convenience use this path.
//! * **Tracked jobs** ([`RuntimePool::submit_tracked`]) are the wave
//!   driver's path and never poison the pool.  Each failure is
//!   classified ([`FaultKind`]); `Transient` faults are retried under a
//!   bounded [`RetryPolicy`] (exponential backoff), and the terminal
//!   [`JobStatus`] is delivered to the job's completion callback
//!   exactly once — also for jobs a poisoned or closing pool drained
//!   without running (`Skipped`) — *before* the job leaves the
//!   in-flight count, so [`RuntimePool::wait_idle`] also waits for
//!   every callback.  The cross-pass wave driver uses the status to
//!   choose between advancing the dependency table and cancelling the
//!   failed block's dependency cone (see
//!   [`crate::coordinator::passdriver`]).
//!
//! Lane threads are **supervised**: a panic that escapes the per-job
//! isolation (chaos [`LaneKill`], or an unexpected unwind outside a job
//! body) respawns the lane with a fresh `Runtime` from the shared
//! registry instead of silently shrinking the pool, counted in
//! [`FaultCounters::lane_restarts`].

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context};

use super::{FaultKind, Registry, Runtime, RuntimeStats, Tensor};

/// Lock a mutex, recovering from poisoning.  Every critical section
/// behind this helper is a single-field update or a counter fold, so
/// the data is consistent even if a thread panicked while holding the
/// guard — and unwrapping would escalate one lane panic into a process
/// abort when the unwinding thread's drop glue re-locks.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// An untracked pool job body.  Takes the lane index and that lane's
/// runtime.
type RunFn = Box<dyn FnOnce(usize, &Runtime) -> crate::Result<()> + Send + 'static>;

/// A tracked (retryable) job body: `FnMut` so the lane can re-invoke it
/// on a `Transient` fault.  Bodies must keep their inputs alive until
/// they succeed (see the wave driver's `Option`-held inputs).
type TrackedFn = Box<dyn FnMut(usize, &Runtime) -> crate::Result<()> + Send + 'static>;

/// A per-job completion callback; receives the terminal [`JobStatus`].
type DoneFn = Box<dyn FnOnce(JobStatus) + Send + 'static>;

/// Bounded retry policy for tracked jobs.  Only `Transient` faults are
/// retried; `Fatal` faults and panics are terminal on first occurrence.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempt budget (≥ 1); 1 disables retry.
    pub attempts: u32,
    /// Backoff before the second attempt; doubles per further retry.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        // Three attempts with 1 ms / 2 ms pauses: long enough to ride
        // out an allocator or device hiccup, short enough to be
        // invisible next to a block execution.
        RetryPolicy { attempts: 3, backoff: Duration::from_millis(1) }
    }
}

impl RetryPolicy {
    /// No retries: every fault is terminal on the first attempt.
    pub fn none() -> Self {
        RetryPolicy { attempts: 1, backoff: Duration::ZERO }
    }

    /// Delay after failed attempt `attempt` (1-based): `backoff · 2^(attempt-1)`.
    fn delay(&self, attempt: u32) -> Duration {
        self.backoff.saturating_mul(1u32 << attempt.saturating_sub(1).min(16))
    }
}

/// Terminal status of a tracked job, delivered to its completion
/// callback exactly once.
#[derive(Debug, Clone)]
pub enum JobStatus {
    /// The body returned `Ok` (possibly after `retries` retried
    /// attempts).
    Ok { retries: u32 },
    /// The body failed terminally: a `Fatal` fault or a panic, or a
    /// `Transient` fault with the retry budget exhausted.
    Failed { kind: FaultKind, attempts: u32, message: String },
    /// The job never ran: a poisoned pool drained it.
    Skipped,
}

impl JobStatus {
    pub fn is_ok(&self) -> bool {
        matches!(self, JobStatus::Ok { .. })
    }
}

/// Snapshot of the pool's fault-tolerance counters since open.
/// Drivers diff two snapshots to attribute counts to one run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Retried attempts of tracked jobs (`Transient` faults).
    pub job_retries: u64,
    /// Tracked jobs that failed terminally.
    pub jobs_failed: u64,
    /// Lane threads respawned after a panic escaped job isolation.
    pub lane_restarts: u64,
}

/// Chaos panic payload: a job body that panics with `LaneKill` kills
/// its lane *thread* — the per-job panic isolation deliberately
/// re-raises it — exercising the supervisor's respawn path.  The job
/// itself still completes as `Failed` with [`FaultKind::Panic`].
#[cfg(any(test, feature = "chaos"))]
pub struct LaneKill;

enum JobBody {
    Once(RunFn),
    Tracked(TrackedFn),
}

/// A unit of pool work: the body plus an optional completion callback
/// and the retry policy (tracked bodies only).
struct Job {
    body: JobBody,
    done: Option<DoneFn>,
    policy: RetryPolicy,
}

struct QueueState {
    jobs: VecDeque<Job>,
    in_flight: usize,
    closed: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    /// Lanes wait here for work.
    job_ready: Condvar,
    /// Producers wait here for queue space.
    space: Condvar,
    /// `wait_idle` callers wait here for the queue to drain.
    idle: Condvar,
    /// First error from any lane since the last `wait_idle`.
    error: Mutex<Option<anyhow::Error>>,
    /// Set alongside `error`; lanes drain (skip) jobs while poisoned.
    poisoned: AtomicBool,
    /// Aggregated per-lane runtime stats (updated after every job).
    stats: Mutex<RuntimeStats>,
    /// Fault-tolerance counters (see [`FaultCounters`]).
    job_retries: AtomicU64,
    jobs_failed: AtomicU64,
    lane_restarts: AtomicU64,
    queue_cap: usize,
}

impl Shared {
    fn record_error(&self, e: anyhow::Error) {
        self.poisoned.store(true, Ordering::Release);
        lock(&self.error).get_or_insert(e);
    }
}

/// `N` lane threads, each with its own PJRT client and compile cache.
pub struct RuntimePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    registry: Registry,
    lanes: usize,
}

impl RuntimePool {
    /// Open the artifact directory and spin up `lanes` worker threads
    /// (clamped to ≥ 1).  The manifest is read once on the calling
    /// thread; each lane then creates its own PJRT client.  Returns an
    /// error if the manifest fails to parse or any lane fails to start.
    pub fn open(dir: impl AsRef<Path>, lanes: usize) -> crate::Result<RuntimePool> {
        let dir: PathBuf = dir.as_ref().to_path_buf();
        let registry = Registry::load(dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        RuntimePool::with_registry(dir, registry, lanes)
    }

    /// Open over an already-parsed registry (pure-logic tests use an
    /// empty one: lanes start and run jobs without any artifacts on
    /// disk).
    pub(crate) fn with_registry(
        dir: PathBuf,
        registry: Registry,
        lanes: usize,
    ) -> crate::Result<RuntimePool> {
        let lanes = lanes.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                in_flight: 0,
                closed: false,
            }),
            job_ready: Condvar::new(),
            space: Condvar::new(),
            idle: Condvar::new(),
            error: Mutex::new(None),
            poisoned: AtomicBool::new(false),
            stats: Mutex::new(RuntimeStats::default()),
            job_retries: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            lane_restarts: AtomicU64::new(0),
            queue_cap: (lanes * 4).max(8),
        });
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<crate::Result<()>>();
        let mut handles = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let dir = dir.clone();
            let reg = registry.clone();
            let sh = shared.clone();
            let tx = ready_tx.clone();
            let handle = match std::thread::Builder::new()
                .name(format!("rt-lane-{lane}"))
                .spawn(move || lane_entry(lane, dir, reg, sh, tx))
            {
                Ok(h) => h,
                Err(e) => {
                    // Release the lanes already spawned so they exit.
                    lock(&shared.state).closed = true;
                    shared.job_ready.notify_all();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(anyhow!("spawning lane {lane} failed: {e}"));
                }
            };
            handles.push(handle);
        }
        drop(ready_tx);
        let pool = RuntimePool { shared, handles, registry, lanes };
        for _ in 0..lanes {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("lane thread died during startup"))?
                .context("opening a lane runtime")?;
        }
        Ok(pool)
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Aggregate execution stats across all lanes.
    pub fn stats(&self) -> RuntimeStats {
        lock(&self.shared.stats).clone()
    }

    /// Snapshot the fault-tolerance counters (retries / terminal
    /// failures / lane respawns since open).
    pub fn fault_counters(&self) -> FaultCounters {
        FaultCounters {
            job_retries: self.shared.job_retries.load(Ordering::Relaxed),
            jobs_failed: self.shared.jobs_failed.load(Ordering::Relaxed),
            lane_restarts: self.shared.lane_restarts.load(Ordering::Relaxed),
        }
    }

    /// Enqueue an untracked job.  Blocks while the queue is at capacity
    /// (the bounded-channel backpressure between extractors and lanes).
    /// Failures poison the pool until the next
    /// [`RuntimePool::wait_idle`].
    pub fn submit<F>(&self, job: F)
    where
        F: FnOnce(usize, &Runtime) -> crate::Result<()> + Send + 'static,
    {
        self.enqueue(Job {
            body: JobBody::Once(Box::new(job)),
            done: None,
            policy: RetryPolicy::none(),
        });
    }

    /// Enqueue a tracked job with a retry policy and a completion
    /// callback.  `on_done(status)` fires exactly once — after the body
    /// succeeds or fails terminally (`Transient` faults are retried up
    /// to `policy.attempts` times with exponential backoff), or with
    /// [`JobStatus::Skipped`] when a poisoned pool drains the job
    /// without running it — and is ordered before the job leaves the
    /// in-flight count (so [`RuntimePool::wait_idle`] also waits for
    /// every callback).  Tracked failures do **not** poison the pool:
    /// scoping the consequence of a failed block is the caller's job
    /// (see `WaveTable::cancel`).
    pub fn submit_tracked<F, C>(&self, job: F, policy: RetryPolicy, on_done: C)
    where
        F: FnMut(usize, &Runtime) -> crate::Result<()> + Send + 'static,
        C: FnOnce(JobStatus) + Send + 'static,
    {
        self.enqueue(Job {
            body: JobBody::Tracked(Box::new(job)),
            done: Some(Box::new(on_done)),
            policy,
        });
    }

    fn enqueue(&self, job: Job) {
        let mut st = lock(&self.shared.state);
        while st.jobs.len() >= self.shared.queue_cap && !st.closed {
            st = self
                .shared
                .space
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
        if st.closed {
            return; // pool shutting down; job dropped
        }
        st.jobs.push_back(job);
        drop(st);
        self.shared.job_ready.notify_one();
    }

    /// Block until every submitted job has finished, then report the
    /// first untracked error (if any) and clear the poison flag so the
    /// pool can be reused.  Tracked-job failures are reported through
    /// their completion callbacks instead and never show up here.
    pub fn wait_idle(&self) -> crate::Result<()> {
        let mut st = lock(&self.shared.state);
        while !(st.jobs.is_empty() && st.in_flight == 0) {
            st = self
                .shared
                .idle
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
        drop(st);
        self.shared.poisoned.store(false, Ordering::Release);
        match lock(&self.shared.error).take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Compile `artifact` on *every* lane, outside any timed region (the
    /// analogue of FPGA reprogramming, excluded from kernel timing as in
    /// §4.2.4).  A barrier keeps each lane from grabbing two warmup jobs
    /// — which is also why lane supervision must preserve the lane
    /// count: a shrunken pool would park the surviving lanes here
    /// forever.
    pub fn warmup_artifact(&self, artifact: &str) -> crate::Result<()> {
        // Drain any stale poison first: a poisoned lane would skip its
        // warmup job and leave the other lanes parked on the barrier.
        self.wait_idle()?;
        let barrier = Arc::new(Barrier::new(self.lanes));
        let name: Arc<str> = Arc::from(artifact);
        for _ in 0..self.lanes {
            let b = barrier.clone();
            let n = name.clone();
            self.submit(move |lane, rt| {
                // Catch panics locally: an unwinding compile must not
                // skip the barrier, or the other lanes would park in
                // b.wait() forever (lane_main's catch_unwind is too
                // late — it runs after this job body).
                let r = catch_unwind(AssertUnwindSafe(|| rt.executable(&n).map(|_| ())));
                // Rendezvous even on error so every lane's wait releases.
                b.wait();
                match r {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!(
                        "lane {lane} warmup panicked: {}",
                        crate::coordinator::scheduler::panic_text(p.as_ref())
                    )),
                }
            });
        }
        self.wait_idle()
    }

    /// Compile several artifacts on every lane (see
    /// [`RuntimePool::warmup_artifact`]) — the wavefront app runners
    /// use this for workloads that mix compute units (LUD's
    /// diagonal/perimeter/internal kernels, SRAD's reduction +
    /// stencil).
    pub fn warmup_artifacts(&self, artifacts: &[&str]) -> crate::Result<()> {
        for name in artifacts {
            self.warmup_artifact(name)?;
        }
        Ok(())
    }

    /// Convenience single execution on whichever lane is free first.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> crate::Result<Vec<Tensor>> {
        let (tx, rx) = std::sync::mpsc::channel();
        let name: Arc<str> = Arc::from(artifact);
        self.submit(move |_lane, rt| {
            // The caller sees the execution error through the channel;
            // don't also poison the pool.
            let _ = tx.send(rt.execute(&name, &inputs));
            Ok(())
        });
        match rx.recv() {
            Ok(r) => r,
            // The lane dropped the sender without replying: it skipped
            // the job because the pool was poisoned by an earlier batch
            // (or the lane died).  Harvest and report the real error
            // rather than a misleading channel failure.
            Err(_) => Err(self
                .wait_idle()
                .err()
                .unwrap_or_else(|| anyhow!("lane dropped the result channel"))),
        }
    }
}

impl Drop for RuntimePool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.closed = true;
        }
        self.shared.job_ready.notify_all();
        self.shared.space.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Guard that waits for the pool to drain on drop.  Hold one across any
/// region that submits jobs borrowing stack data through raw-pointer
/// writers (see [`crate::coordinator::grid::GridWriter2D`]): even on a
/// panic-unwind of the submitting frame, the guard drains the lanes
/// before the borrowed grid is freed.
pub struct IdleGuard<'a>(&'a RuntimePool);

impl<'a> IdleGuard<'a> {
    pub fn new(pool: &'a RuntimePool) -> Self {
        IdleGuard(pool)
    }
}

impl Drop for IdleGuard<'_> {
    fn drop(&mut self) {
        // Error (if any) is surfaced by the runner's own wait_idle call;
        // this drop only guarantees quiescence.
        let _ = self.0.wait_idle();
    }
}

/// Lane supervisor: creates the lane's `Runtime` and re-enters the job
/// loop with a fresh one whenever a panic escapes the per-job isolation
/// (chaos [`LaneKill`], or an unexpected unwind outside a job body), so
/// the pool never silently shrinks — `warmup_artifact`'s all-lanes
/// barrier depends on the lane count staying fixed.
fn lane_entry(
    lane: usize,
    dir: PathBuf,
    registry: Registry,
    shared: Arc<Shared>,
    ready_tx: std::sync::mpsc::Sender<crate::Result<()>>,
) {
    let mut ready = Some(ready_tx);
    loop {
        let rt = match Runtime::with_registry(&dir, registry.clone()) {
            Ok(rt) => {
                if let Some(tx) = ready.take() {
                    let _ = tx.send(Ok(()));
                }
                rt
            }
            Err(e) => {
                match ready.take() {
                    Some(tx) => {
                        let _ = tx.send(Err(e));
                    }
                    // A respawn needs a fresh PJRT client; if that
                    // fails the pool genuinely shrinks — surface it
                    // instead of pretending the lane is back.
                    None => shared.record_error(
                        e.context(format!("respawning lane {lane} after a panic")),
                    ),
                }
                return;
            }
        };
        if catch_unwind(AssertUnwindSafe(|| lane_main(lane, &rt, &shared))).is_ok() {
            return; // clean shutdown: the pool closed and the queue drained
        }
        // The in-flight job was already reported Failed (with
        // FaultKind::Panic) by its JobGuard during the unwind; all that
        // is lost is the dead Runtime's compile cache.
        if lock(&shared.state).closed {
            return;
        }
        shared.lane_restarts.fetch_add(1, Ordering::Relaxed);
    }
}

/// Per-job completion guard: fires the done callback and the in-flight
/// decrement exactly once, even when a chaos [`LaneKill`] panic unwinds
/// the lane mid-job — the pool's accounting stays sound while the
/// supervisor respawns the lane.
struct JobGuard<'a> {
    shared: &'a Shared,
    lane: usize,
    done: Option<DoneFn>,
    status: Option<JobStatus>,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        let status = self.status.take().unwrap_or_else(|| {
            // Only reachable when a panic is unwinding the lane:
            // account the terminal failure here.
            self.shared.jobs_failed.fetch_add(1, Ordering::Relaxed);
            JobStatus::Failed {
                kind: FaultKind::Panic,
                attempts: 1,
                message: format!("lane {} killed mid-job", self.lane),
            }
        });
        if let Some(done) = self.done.take() {
            // A panicking callback must not kill the lane (or mask an
            // in-progress LaneKill unwind): convert it to a pool error.
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| done(status))) {
                self.shared.record_error(anyhow!(
                    "lane {} completion callback panicked: {}",
                    self.lane,
                    crate::coordinator::scheduler::panic_text(p.as_ref())
                ));
            }
        }
        let mut st = lock(&self.shared.state);
        st.in_flight -= 1;
        if st.in_flight == 0 && st.jobs.is_empty() {
            self.shared.idle.notify_all();
        }
    }
}

fn lane_main(lane: usize, rt: &Runtime, shared: &Arc<Shared>) {
    let mut last = RuntimeStats::default();
    loop {
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    st.in_flight += 1;
                    break Some(j);
                }
                if st.closed {
                    break None;
                }
                st = shared
                    .job_ready
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        let Some(Job { body, done, policy }) = job else { return };
        shared.space.notify_one();

        // The guard owns the callback and the in-flight decrement: both
        // fire exactly once, on every exit path out of run_job —
        // including the LaneKill re-raise.
        let mut guard = JobGuard { shared, lane, done, status: None };
        guard.status = Some(if shared.poisoned.load(Ordering::Acquire) {
            JobStatus::Skipped
        } else {
            run_job(lane, rt, shared, body, policy)
        });

        // Fold this lane's stats delta into the pool aggregate.
        let now = rt.stats();
        {
            let mut agg = lock(&shared.stats);
            agg.executions += now.executions - last.executions;
            agg.compile_ms += now.compile_ms - last.compile_ms;
            agg.execute_ms += now.execute_ms - last.execute_ms;
            agg.marshal_ms += now.marshal_ms - last.marshal_ms;
        }
        last = now;

        drop(guard); // fires done, decrements in_flight, notifies idle
    }
}

/// Run one job body to its terminal [`JobStatus`].  Untracked bodies
/// keep the original poisoning discipline; tracked bodies classify
/// every failure and retry `Transient` faults under the job's policy.
fn run_job(
    lane: usize,
    rt: &Runtime,
    shared: &Shared,
    body: JobBody,
    policy: RetryPolicy,
) -> JobStatus {
    match body {
        JobBody::Once(run) => match catch_unwind(AssertUnwindSafe(|| run(lane, rt))) {
            Ok(Ok(())) => JobStatus::Ok { retries: 0 },
            Ok(Err(e)) => {
                let status = JobStatus::Failed {
                    kind: FaultKind::of(&e),
                    attempts: 1,
                    message: format!("{e:#}"),
                };
                shared.record_error(e);
                status
            }
            Err(p) => {
                #[cfg(any(test, feature = "chaos"))]
                if p.downcast_ref::<LaneKill>().is_some() {
                    std::panic::resume_unwind(p);
                }
                let message = format!(
                    "lane {lane} job panicked: {}",
                    crate::coordinator::scheduler::panic_text(p.as_ref())
                );
                shared.record_error(anyhow!("{message}"));
                JobStatus::Failed { kind: FaultKind::Panic, attempts: 1, message }
            }
        },
        JobBody::Tracked(mut run) => {
            let max = policy.attempts.max(1);
            let mut attempt = 0u32;
            loop {
                attempt += 1;
                match catch_unwind(AssertUnwindSafe(|| run(lane, rt))) {
                    Ok(Ok(())) => return JobStatus::Ok { retries: attempt - 1 },
                    Ok(Err(e)) => {
                        let kind = FaultKind::of(&e);
                        if kind == FaultKind::Transient && attempt < max {
                            shared.job_retries.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(policy.delay(attempt));
                            continue;
                        }
                        shared.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        return JobStatus::Failed {
                            kind,
                            attempts: attempt,
                            message: format!("{e:#}"),
                        };
                    }
                    Err(p) => {
                        // A LaneKill panic is re-raised to take the
                        // whole lane down (the JobGuard reports the job,
                        // the supervisor respawns the lane); any other
                        // panic is terminal for the job only.
                        #[cfg(any(test, feature = "chaos"))]
                        if p.downcast_ref::<LaneKill>().is_some() {
                            std::panic::resume_unwind(p);
                        }
                        shared.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        return JobStatus::Failed {
                            kind: FaultKind::Panic,
                            attempts: attempt,
                            message: format!(
                                "lane {lane} job panicked: {}",
                                crate::coordinator::scheduler::panic_text(p.as_ref())
                            ),
                        };
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    /// Pool over an empty registry: lanes start real PJRT clients but
    /// no artifacts exist — jobs that never touch `rt` (or that fail
    /// to) exercise the queue/retry/callback machinery pure-logically.
    fn test_pool(lanes: usize) -> RuntimePool {
        RuntimePool::with_registry(PathBuf::from("."), Registry::default(), lanes)
            .expect("lane startup needs no artifacts")
    }

    fn status_tag(s: &JobStatus) -> String {
        match s {
            JobStatus::Ok { retries } => format!("ok:{retries}"),
            JobStatus::Failed { kind, attempts, .. } => format!("failed:{kind}:{attempts}"),
            JobStatus::Skipped => "skipped".into(),
        }
    }

    #[test]
    fn tracked_callbacks_fire_exactly_once_in_completion_order() {
        // lanes=1 makes completion order deterministic (FIFO): a mixed
        // success/panic/fatal/skip batch must deliver exactly one
        // status per job, in submission order, with the tracked
        // failures NOT poisoning the pool — only the untracked failure
        // surfaces at wait_idle.
        let pool = test_pool(1);
        let log = Arc::new(Mutex::new(Vec::<(usize, String)>::new()));
        let fired: Arc<Vec<AtomicU32>> =
            Arc::new((0..4).map(|_| AtomicU32::new(0)).collect());
        let track = |id: usize| {
            let log = log.clone();
            let fired = fired.clone();
            move |s: JobStatus| {
                fired[id].fetch_add(1, Ordering::SeqCst);
                lock(&log).push((id, status_tag(&s)));
            }
        };
        pool.submit_tracked(|_, _| Ok(()), RetryPolicy::none(), track(0));
        pool.submit_tracked(
            |_, _| -> crate::Result<()> { panic!("tracked job exploded") },
            RetryPolicy::none(),
            track(1),
        );
        pool.submit_tracked(
            |_, _| Err(anyhow!("structurally broken")),
            RetryPolicy::none(),
            track(2),
        );
        // Untracked failure poisons; the tracked job behind it skips.
        pool.submit(|_, _| Err(anyhow!("untracked batch failure")));
        pool.submit_tracked(|_, _| Ok(()), RetryPolicy::none(), track(3));

        let err = pool.wait_idle().expect_err("untracked failure must surface");
        assert!(format!("{err}").contains("untracked batch failure"), "got: {err}");
        assert_eq!(
            *lock(&log),
            vec![
                (0, "ok:0".into()),
                (1, "failed:panic:1".into()),
                (2, "failed:fatal:1".into()),
                (3, "skipped".into()),
            ]
        );
        for (id, n) in fired.iter().enumerate() {
            assert_eq!(n.load(Ordering::SeqCst), 1, "callback {id} fired more than once");
        }
        // Tracked failures alone never poison: drained exactly once.
        pool.wait_idle().unwrap();
        assert_eq!(pool.fault_counters().jobs_failed, 2);
    }

    #[test]
    fn transient_faults_retry_with_bounded_budget() {
        let pool = test_pool(1);
        let policy = RetryPolicy { attempts: 3, backoff: Duration::from_micros(50) };
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));

        // Fails transiently twice, succeeds on the third attempt.
        let tries = Arc::new(AtomicU32::new(0));
        let (t, s) = (tries.clone(), statuses.clone());
        pool.submit_tracked(
            move |_, _| {
                if t.fetch_add(1, Ordering::SeqCst) < 2 {
                    Err(crate::runtime::transient("flaky device".into()))
                } else {
                    Ok(())
                }
            },
            policy,
            move |st| lock(&s).push(status_tag(&st)),
        );
        // Always transient: exhausts the budget.
        let s = statuses.clone();
        pool.submit_tracked(
            move |_, _| Err(crate::runtime::transient("hopeless device".into())),
            policy,
            move |st| lock(&s).push(status_tag(&st)),
        );
        // Fatal: terminal on the first attempt despite the budget.
        let s = statuses.clone();
        pool.submit_tracked(
            move |_, _| Err(anyhow!("bad shape")),
            policy,
            move |st| lock(&s).push(status_tag(&st)),
        );

        pool.wait_idle().unwrap();
        assert_eq!(tries.load(Ordering::SeqCst), 3);
        assert_eq!(
            *lock(&statuses),
            vec!["ok:2".to_string(), "failed:transient:3".into(), "failed:fatal:1".into()]
        );
        let c = pool.fault_counters();
        assert_eq!(c.job_retries, 2 + 2, "two retries per transient job");
        assert_eq!(c.jobs_failed, 2);
        assert_eq!(c.lane_restarts, 0);
    }

    #[test]
    fn wait_idle_clears_poison_exactly_once() {
        let pool = test_pool(2);
        pool.submit(|_, _| Err(anyhow!("first failure")));
        let err = pool.wait_idle().expect_err("poison must surface once");
        assert!(format!("{err}").contains("first failure"));
        // Reported and cleared: the next drain is clean, and new work runs.
        pool.wait_idle().unwrap();
        let ran = Arc::new(AtomicU32::new(0));
        let r = ran.clone();
        pool.submit(move |_, _| {
            r.fetch_add(1, Ordering::SeqCst);
            Ok(())
        });
        pool.wait_idle().unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn lane_kill_restarts_the_lane_and_reports_failed_panic() {
        let pool = test_pool(1);
        let statuses = Arc::new(Mutex::new(Vec::<String>::new()));
        let s = statuses.clone();
        pool.submit_tracked(
            |_, _| -> crate::Result<()> { std::panic::panic_any(LaneKill) },
            RetryPolicy::default(),
            move |st| lock(&s).push(status_tag(&st)),
        );
        pool.wait_idle().unwrap();
        assert_eq!(*lock(&statuses), vec!["failed:panic:1".to_string()]);
        assert_eq!(pool.fault_counters().lane_restarts, 1);
        // The respawned lane (fresh Runtime, same thread slot) still
        // serves jobs — the pool did not shrink.
        let ran = Arc::new(AtomicU32::new(0));
        let r = ran.clone();
        pool.submit_tracked(
            move |_, _| {
                r.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
            RetryPolicy::none(),
            |st| assert!(st.is_ok()),
        );
        pool.wait_idle().unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn concurrent_tracked_jobs_deliver_every_callback() {
        let pool = test_pool(4);
        let n = 64usize;
        let fired: Arc<Vec<AtomicU32>> =
            Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        let (oks, fails) = (Arc::new(AtomicU32::new(0)), Arc::new(AtomicU32::new(0)));
        for i in 0..n {
            let fired = fired.clone();
            let (oks, fails) = (oks.clone(), fails.clone());
            pool.submit_tracked(
                move |_, _| {
                    if i % 3 == 0 {
                        Err(anyhow!("job {i} failed"))
                    } else {
                        Ok(())
                    }
                },
                RetryPolicy::none(),
                move |st| {
                    fired[i].fetch_add(1, Ordering::SeqCst);
                    if st.is_ok() {
                        oks.fetch_add(1, Ordering::SeqCst);
                    } else {
                        fails.fetch_add(1, Ordering::SeqCst);
                    }
                },
            );
        }
        // wait_idle waits for the callbacks too (they fire before the
        // in-flight decrement), so every counter is final here.
        pool.wait_idle().unwrap();
        for (i, f) in fired.iter().enumerate() {
            assert_eq!(f.load(Ordering::SeqCst), 1, "job {i}");
        }
        assert_eq!(oks.load(Ordering::SeqCst) + fails.load(Ordering::SeqCst), n as u32);
        assert_eq!(fails.load(Ordering::SeqCst) as usize, n.div_ceil(3));
    }
}
