//! L3 runtime: loads AOT-compiled HLO-text artifacts into a PJRT CPU
//! client and executes them from the Rust request path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire run-time interface to the compiled compute units.  Interchange
//! is HLO *text* (see `python/compile/aot.py` — serialized protos from
//! jax ≥ 0.5 are rejected by xla_extension 0.5.1).
//!
//! The PJRT client wraps an `Rc`, so executables are not `Send`: a
//! single [`Runtime`] keeps execution on one thread and parallelizes
//! data marshalling instead (see [`crate::coordinator::scheduler`]).
//! For compute-unit replication — the software analogue of the thesis's
//! `PAR` knob — [`pool::RuntimePool`] owns one `Runtime` per lane
//! *thread*, each with its own PJRT client (see `README.md` in this
//! directory for the engine architecture).

pub mod pool;
pub mod registry;
pub mod topology;

pub use pool::{
    commit_current_job, FaultCounters, JobStatus, LaneHint, PoolConfig, RetryPolicy, RuntimePool,
    SchedCounters,
};
pub use registry::{ArtifactSpec, DType, Registry, TensorSpec};
pub use topology::Pinning;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context};

/// Failure classification at the pool boundary, attached to tracked-job
/// completion callbacks so the wave driver can choose between retrying a
/// block and cancelling its dependency cone (see `README.md`
/// § Failure semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Execution-time failure (device staging, XLA execute, result
    /// fetch): the inputs were structurally valid, so a fresh attempt
    /// can succeed.  Eligible for bounded retry.
    Transient,
    /// Structural failure (unknown artifact, parse/compile error,
    /// shape or dtype mismatch): the same job can never succeed.
    /// Never retried.
    Fatal,
    /// The job body panicked.  Never retried.
    Panic,
    /// The job overran its wall-clock budget and its lane was reaped
    /// by the watchdog (see `README.md` § Deadlines & watchdog).  The
    /// lane may still be stuck, so the same lane cannot retry; the
    /// wave driver heals the block through cone replay instead.
    Timeout,
}

impl FaultKind {
    /// Classify an error chain: the first [`Fault`] in the chain wins.
    /// Errors that never got classified (manifest loading, driver
    /// internals) default to `Fatal` — retrying the unknown is never
    /// safe.
    ///
    /// A `Fault` can enter the chain two ways: as the root error
    /// (`anyhow::Error::new(Fault { .. })`, possibly under any number
    /// of `.context(..)` layers) or as a context *value*
    /// (`.context(Fault { .. })`).  The whole-error `downcast_ref`
    /// sees context values through anyhow's vtable; the chain walk
    /// sees root errors at any wrapping depth.  Both probes are
    /// needed — either alone misclassifies the other shape as
    /// `Fatal`.
    pub fn of(err: &anyhow::Error) -> FaultKind {
        if let Some(f) = err.downcast_ref::<Fault>() {
            return f.kind;
        }
        err.chain()
            .find_map(|c| c.downcast_ref::<Fault>())
            .map_or(FaultKind::Fatal, |f| f.kind)
    }
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultKind::Transient => "transient",
            FaultKind::Fatal => "fatal",
            FaultKind::Panic => "panic",
            FaultKind::Timeout => "timeout",
        })
    }
}

/// A classified runtime error, wrapped into the `anyhow` chain at the
/// site that knows the failure class; [`FaultKind::of`] recovers the
/// class at the pool boundary.
#[derive(Debug)]
pub struct Fault {
    pub kind: FaultKind,
    pub msg: String,
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Fault {}

/// Tag an error message as [`FaultKind::Transient`].  Only transient
/// sites need explicit tagging: everything unclassified defaults to
/// `Fatal` under [`FaultKind::of`].
pub(crate) fn transient(msg: String) -> anyhow::Error {
    anyhow::Error::new(Fault { kind: FaultKind::Transient, msg })
}

/// Typed host-side tensor for kernel I/O.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v, _) => v.len(),
            Tensor::I32(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32(v, _) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            Tensor::I32(v, _) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    #[cfg_attr(not(test), allow(dead_code))] // retained for the literal
    // round-trip tests and as the fallback marshalling path
    fn to_literal(&self) -> crate::Result<xla::Literal> {
        let (bytes, ty, dims): (&[u8], xla::ElementType, &[usize]) = match self {
            Tensor::F32(v, s) => (cast_f32(v), xla::ElementType::F32, s),
            Tensor::I32(v, s) => (cast_i32(v), xla::ElementType::S32, s),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)
            .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    /// Stage this tensor as a device buffer.  The buffer path skips the
    /// per-call literal→buffer conversion inside the C shim, which costs
    /// ~1 µs/KB — a 1.7x end-to-end win on stencil blocks (EXPERIMENTS.md
    /// §Perf L3).
    fn to_buffer(&self, client: &xla::PjRtClient) -> crate::Result<xla::PjRtBuffer> {
        match self {
            Tensor::F32(v, s) => client.buffer_from_host_buffer::<f32>(v, s, None),
            Tensor::I32(v, s) => client.buffer_from_host_buffer::<i32>(v, s, None),
        }
        .map_err(|e| transient(format!("buffer staging failed: {e:?}")))
    }

    fn from_literal(lit: &xla::Literal) -> crate::Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| transient(format!("shape query failed: {e:?}")))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32(
                lit.to_vec::<f32>().map_err(|e| transient(format!("{e:?}")))?,
                dims,
            )),
            xla::ElementType::S32 => Ok(Tensor::I32(
                lit.to_vec::<i32>().map_err(|e| transient(format!("{e:?}")))?,
                dims,
            )),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

fn cast_f32(v: &[f32]) -> &[u8] {
    // SAFETY: reading a live f32 slice as bytes: same allocation, same
    // length in bytes (len * 4 cannot overflow — the slice exists),
    // alignment only shrinks (4 -> 1), and every byte of an f32 is
    // initialized.  The borrow ties the lifetime to `v`.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn cast_i32(v: &[i32]) -> &[u8] {
    // SAFETY: as in `cast_f32` — i32 -> u8 reinterpretation of a live
    // borrowed slice with byte-exact length.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Execution statistics for the metrics endpoint / §Perf work.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compile_ms: f64,
    pub execute_ms: f64,
    pub marshal_ms: f64,
}

/// The PJRT runtime: artifact registry + compile cache + typed execute.
pub struct Runtime {
    client: xla::PjRtClient,
    registry: Registry,
    dir: PathBuf,
    executables: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Open the artifact directory (default `artifacts/`) and its
    /// manifest; creates the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> crate::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let registry = Registry::load(dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Runtime::with_registry(dir, registry)
    }

    /// Create a runtime over an already-parsed manifest.  Used by
    /// [`pool::RuntimePool`] so N lanes share one manifest parse while
    /// each still gets its own PJRT client and compile cache.
    pub fn with_registry(dir: impl AsRef<Path>, registry: Registry) -> crate::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client creation failed: {e:?}"))?;
        Ok(Runtime {
            client,
            registry,
            dir: dir.as_ref().to_path_buf(),
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Compile (or fetch from cache) one artifact.
    pub fn executable(&self, name: &str) -> crate::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .registry
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {} failed: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name} failed: {e:?}"))?;
        self.stats.borrow_mut().compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        let exe = Rc::new(exe);
        self.executables
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact in the manifest.
    pub fn warmup(&self) -> crate::Result<()> {
        for name in self.registry.names() {
            self.executable(&name)?;
        }
        Ok(())
    }

    /// Shared body of [`Runtime::execute`] / [`Runtime::execute_f32`]:
    /// stage the inputs as device buffers, run, fetch the result tuple
    /// and decompose it, accumulating stats.  Covers the staging and
    /// decomposition share of `marshal_ms`; the caller times its
    /// literal→host conversion and adds it too, so `marshal_ms` keeps
    /// counting the output copy exactly as it did before the fast path
    /// existed (the BENCH trajectory depends on that comparability).
    fn execute_tuple(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;

        let tm = std::time::Instant::now();
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.to_buffer(&self.client))
            .collect::<crate::Result<_>>()?;
        let marshal_in = tm.elapsed();

        let t0 = std::time::Instant::now();
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().collect();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| transient(format!("executing {name} failed: {e:?}")))?;
        let buffer = &result[0][0];
        let mut tuple = buffer
            .to_literal_sync()
            .map_err(|e| transient(format!("fetching result failed: {e:?}")))?;
        let execute = t0.elapsed();

        let tm2 = std::time::Instant::now();
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| transient(format!("decomposing tuple failed: {e:?}")))?;
        let marshal_out = tm2.elapsed();

        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.execute_ms += execute.as_secs_f64() * 1e3;
        stats.marshal_ms += (marshal_in + marshal_out).as_secs_f64() * 1e3;
        Ok(parts)
    }

    /// Execute one artifact with shape/dtype validation.
    ///
    /// Outputs come back as host tensors (the lowering always wraps
    /// results in a tuple — `return_tuple=True` in aot.py).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        let spec = self
            .registry
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        spec.validate_inputs(inputs)?;
        let parts = self.execute_tuple(name, inputs)?;
        let tm = std::time::Instant::now();
        let outs: crate::Result<Vec<Tensor>> =
            parts.iter().map(Tensor::from_literal).collect();
        self.stats.borrow_mut().marshal_ms += tm.elapsed().as_secs_f64() * 1e3;
        outs
    }

    /// Fast execution path for artifacts with a single f32 output (every
    /// stencil compute unit): decomposes the result tuple straight to a
    /// `Vec<f32>`, skipping the generic [`Tensor`] wrapping — no shape
    /// query, no dims `Vec`, no per-output enum allocation.  The one
    /// remaining marshal-out allocation is the vendored xla bindings'
    /// own inside `Literal::to_vec` (the literal's raw buffer is not
    /// exposed, so a true zero-copy decompose is not currently
    /// possible).
    pub fn execute_f32(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<f32>> {
        let spec = self
            .registry
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if spec.outputs.len() != 1 || spec.outputs[0].dtype != DType::F32 {
            bail!("{name}: execute_f32 requires exactly one f32 output");
        }
        spec.validate_inputs(inputs)?;
        let parts = self.execute_tuple(name, inputs)?;
        let tm = std::time::Instant::now();
        // The manifest promised one output, but the compiled HLO is the
        // source of truth for what came back — error, don't index.
        let out = parts
            .first()
            .ok_or_else(|| anyhow!("{name}: compiled artifact returned an empty result tuple"))
            .and_then(|lit| {
                lit.to_vec::<f32>()
                    .map_err(|e| transient(format!("reading f32 output failed: {e:?}")))
            });
        self.stats.borrow_mut().marshal_ms += tm.elapsed().as_secs_f64() * 1e3;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip_f32() {
        let t = Tensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn tensor_roundtrip_i32() {
        let t = Tensor::I32(vec![-1, 7, 42], vec![3]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn fault_classification_survives_context_and_defaults_to_fatal() {
        let e = transient("device buffer hiccup".into());
        assert_eq!(FaultKind::of(&e), FaultKind::Transient);
        // Wrapping with context must not lose the classification.
        let wrapped = e.context("staging block (3, 7)");
        assert_eq!(FaultKind::of(&wrapped), FaultKind::Transient);
        assert!(format!("{wrapped:#}").contains("device buffer hiccup"));
        // Untagged errors (unknown artifact, validation, internals)
        // classify as Fatal: retrying the unknown is never safe.
        let plain = anyhow!("unknown artifact 'nope'");
        assert_eq!(FaultKind::of(&plain), FaultKind::Fatal);
    }

    #[test]
    fn fault_classification_survives_nested_contexts() {
        // A tag buried under several `.context(..)` layers — the shape
        // the wave driver produces when a block error crosses the
        // extractor and the pool boundary — must keep its class.
        let e = transient("execute hiccup".into())
            .context("running block (1, 2)")
            .context("wave 1 of 4")
            .context("stage 'diffusion2d_r1'");
        assert_eq!(FaultKind::of(&e), FaultKind::Transient);
        // The full chain still renders outermost-first.
        let rendered = format!("{e:#}");
        assert!(rendered.starts_with("stage 'diffusion2d_r1'"));
        assert!(rendered.contains("execute hiccup"));
    }

    #[test]
    fn fault_attached_as_context_value_is_classified() {
        // A `Fault` used as the context *value* (not the root error) is
        // only visible to the whole-error downcast, not the per-element
        // chain walk: `ContextError<Fault, _>` is the chain element and
        // does not itself downcast to `Fault`.
        let e = anyhow!("raw PJRT status")
            .context(Fault { kind: FaultKind::Timeout, msg: "lane 3 reaped".into() });
        assert_eq!(FaultKind::of(&e), FaultKind::Timeout);
        // ... even under a further plain-text layer.
        let e = e.context("collecting block (0, 0)");
        assert_eq!(FaultKind::of(&e), FaultKind::Timeout);
    }
}
