//! CPU / NUMA topology discovery and lane pinning.
//!
//! The thesis's stencil accelerators win because every tile's working
//! set stays resident next to the compute unit that consumes it
//! (§5.3.1).  The host-side analogue is keeping a lane thread, its
//! extractor partner and its tile arena on one NUMA node.  This module
//! supplies the mechanism:
//!
//! * [`Topology::discover`] parses `/sys/devices/system/node/node*/cpulist`
//!   into per-node CPU sets, degrading to a single synthetic node (all
//!   CPUs) when sysfs is absent or unreadable — discovery **never
//!   errors**, so `Pinning::Numa` on a single-node laptop simply
//!   behaves like [`Pinning::None`].
//! * [`PinPlan`] maps pool lanes and extractor slots to CPU sets under
//!   a [`Pinning`] policy (round-robin across nodes).
//! * [`pin_current_thread`] applies a set via a direct
//!   `sched_setaffinity` syscall binding (the offline dependency set
//!   has no libc crate); on non-Linux targets it is a no-op returning
//!   `false`.
//!
//! The policy knob travels `SessionBuilder::pinning` → `PoolConfig` →
//! lane supervisor: each lane re-applies its pin at the top of its
//! supervision loop, so a respawned lane lands back on its node
//! (`Metrics::pins_applied` counts every application, including
//! re-pins after a kill).

use std::path::Path;

/// Thread-pinning policy for pool lanes and their extractor partners.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Pinning {
    /// No affinity calls at all (the pre-PR 7 behaviour).
    #[default]
    None,
    /// Pin each lane/extractor to a single CPU, round-robin across
    /// nodes (lane k → k-th CPU of the node-interleaved list).
    Cores,
    /// Pin each lane/extractor to the full CPU set of one NUMA node
    /// (lane k → node `k % nnodes`).  With fewer than two nodes this
    /// degrades to [`Pinning::None`] — pinning every thread to "all
    /// CPUs" would be a syscall with no effect.
    Numa,
}

impl std::str::FromStr for Pinning {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "none" => Ok(Pinning::None),
            "cores" => Ok(Pinning::Cores),
            "numa" => Ok(Pinning::Numa),
            other => anyhow::bail!("unknown pinning policy '{other}' (none|cores|numa)"),
        }
    }
}

/// The machine's NUMA layout: one CPU list per node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// `nodes[n]` = the online CPU ids of NUMA node `n` (sorted).
    pub nodes: Vec<Vec<usize>>,
}

impl Topology {
    /// Discover the NUMA layout from sysfs, falling back to one
    /// synthetic node holding every available CPU.  Never errors: a
    /// container without `/sys/devices/system/node` (or with
    /// unreadable cpulists) reports a single node.
    pub fn discover() -> Self {
        Self::from_sysfs(Path::new("/sys/devices/system/node")).unwrap_or_else(Self::single_node)
    }

    /// One synthetic node spanning every CPU the process may use.
    pub fn single_node() -> Self {
        Topology { nodes: vec![(0..available_cores()).collect()] }
    }

    fn from_sysfs(root: &Path) -> Option<Self> {
        let mut ids: Vec<usize> = Vec::new();
        for entry in std::fs::read_dir(root).ok()?.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(id) = name.strip_prefix("node").and_then(|s| s.parse().ok()) {
                ids.push(id);
            }
        }
        ids.sort_unstable();
        let mut nodes = Vec::with_capacity(ids.len());
        for id in ids {
            let list = std::fs::read_to_string(root.join(format!("node{id}/cpulist"))).ok()?;
            let cpus = parse_cpulist(&list);
            if cpus.is_empty() {
                // Memory-only node (no CPUs): nothing to pin to.
                continue;
            }
            nodes.push(cpus);
        }
        if nodes.is_empty() { None } else { Some(Topology { nodes }) }
    }
}

/// Parse a sysfs cpulist (`"0-3,8-11,15"`) into sorted CPU ids.
/// Malformed segments are skipped rather than erroring — topology
/// discovery must degrade, never fail.
pub fn parse_cpulist(list: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for part in list.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                if lo <= hi && hi - lo < 4096 {
                    cpus.extend(lo..=hi);
                }
            }
        } else if let Ok(cpu) = part.parse::<usize>() {
            cpus.push(cpu);
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

/// CPUs the process can schedule on (best effort; ≥ 1).
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A resolved lane/extractor → CPU-set assignment for one pool.
///
/// Slot layout: lanes take slots `0..lanes`, extractor `j` takes slot
/// `lanes + j` — so under [`Pinning::Cores`] a lane and its extractor
/// partner land on *different* CPUs (extraction runs concurrently with
/// execution), while under [`Pinning::Numa`] lane `k` and extractor
/// `k` share node `k % nnodes`, keeping a block's tile arena, its
/// extractor and its execute lane on one node.
#[derive(Clone, Debug)]
pub struct PinPlan {
    policy: Pinning,
    /// Per-node CPU sets (Numa granularity).
    nodes: Vec<Vec<usize>>,
    /// Node-interleaved flat CPU list (Cores granularity).
    flat: Vec<usize>,
    lanes: usize,
}

impl PinPlan {
    /// Build a plan for `lanes` lanes by discovering the live topology.
    pub fn new(policy: Pinning, lanes: usize) -> Self {
        Self::with_topology(policy, lanes, &Topology::discover())
    }

    /// Build a plan over an explicit topology (unit-testable).
    pub fn with_topology(policy: Pinning, lanes: usize, topo: &Topology) -> Self {
        // Interleave CPUs across nodes (n0c0, n1c0, n0c1, n1c1, …) so
        // Cores pinning spreads lanes over the memory controllers
        // instead of filling node 0 first.
        let width = topo.nodes.iter().map(Vec::len).max().unwrap_or(0);
        let mut flat = Vec::new();
        for i in 0..width {
            for node in &topo.nodes {
                if let Some(&cpu) = node.get(i) {
                    flat.push(cpu);
                }
            }
        }
        PinPlan { policy, nodes: topo.nodes.clone(), flat, lanes }
    }

    /// The CPU set for lane `lane`, or `None` when the policy (or the
    /// topology) calls for no pinning.
    pub fn lane_cpus(&self, lane: usize) -> Option<&[usize]> {
        self.slot_cpus(lane)
    }

    /// The CPU set for extractor slot `j` (partnered after the lanes).
    pub fn extractor_cpus(&self, j: usize) -> Option<&[usize]> {
        self.slot_cpus(self.lanes + j)
    }

    fn slot_cpus(&self, slot: usize) -> Option<&[usize]> {
        match self.policy {
            Pinning::None => None,
            Pinning::Cores => {
                if self.flat.is_empty() {
                    return None;
                }
                let i = slot % self.flat.len();
                Some(&self.flat[i..=i])
            }
            Pinning::Numa => {
                // A single node would pin everything to "all CPUs":
                // pure overhead, no locality — degrade to None.
                if self.nodes.len() < 2 {
                    return None;
                }
                Some(&self.nodes[slot % self.nodes.len()])
            }
        }
    }
}

/// Pin the calling thread to `cpus` via `sched_setaffinity`.  Returns
/// `true` when the kernel accepted the mask.  Supports CPU ids up to
/// 1023 (ids beyond the mask are dropped; an all-dropped set is a
/// no-op returning `false`).
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    const WORDS: usize = 16; // 16 × 64 = 1024 CPUs
    let mut mask = [0u64; WORDS];
    let mut any = false;
    for &cpu in cpus {
        if cpu < WORDS * 64 {
            mask[cpu / 64] |= 1u64 << (cpu % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    extern "C" {
        // pid 0 = the calling thread.  Bound directly: the vendored
        // dependency set carries no libc crate.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: `mask` outlives the call and `cpusetsize` matches its
    // byte length; sched_setaffinity only reads the mask.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Non-Linux targets have no sched_setaffinity: pinning is a no-op.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cpus: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_ranges_singles_and_junk() {
        assert_eq!(parse_cpulist("0-3,8-11\n"), vec![0, 1, 2, 3, 8, 9, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(" 2 , 0 , 2 "), vec![0, 2]);
        assert_eq!(parse_cpulist("7-4"), Vec::<usize>::new(), "inverted range is junk");
        assert_eq!(parse_cpulist("a-b,x,,3"), vec![3], "malformed segments are skipped");
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
    }

    #[test]
    fn discovery_never_errors_and_has_at_least_one_cpu() {
        let topo = Topology::discover();
        assert!(!topo.nodes.is_empty());
        assert!(topo.nodes.iter().all(|n| !n.is_empty()));
    }

    fn two_node_topo() -> Topology {
        Topology { nodes: vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]] }
    }

    #[test]
    fn none_policy_pins_nothing() {
        let plan = PinPlan::with_topology(Pinning::None, 4, &two_node_topo());
        assert!(plan.lane_cpus(0).is_none());
        assert!(plan.extractor_cpus(0).is_none());
    }

    #[test]
    fn cores_policy_interleaves_single_cpus_across_nodes() {
        let plan = PinPlan::with_topology(Pinning::Cores, 4, &two_node_topo());
        // Flat order interleaves nodes: 0,4,1,5,2,6,3,7.
        assert_eq!(plan.lane_cpus(0), Some(&[0usize][..]));
        assert_eq!(plan.lane_cpus(1), Some(&[4usize][..]));
        assert_eq!(plan.lane_cpus(2), Some(&[1usize][..]));
        assert_eq!(plan.lane_cpus(3), Some(&[5usize][..]));
        // Extractors continue after the lane slots (slot 4, 5 → 2, 6).
        assert_eq!(plan.extractor_cpus(0), Some(&[2usize][..]));
        assert_eq!(plan.extractor_cpus(1), Some(&[6usize][..]));
        // Oversubscription wraps instead of failing.
        assert_eq!(plan.extractor_cpus(4), Some(&[0usize][..]));
    }

    #[test]
    fn numa_policy_assigns_whole_nodes_round_robin() {
        let plan = PinPlan::with_topology(Pinning::Numa, 4, &two_node_topo());
        assert_eq!(plan.lane_cpus(0), Some(&[0usize, 1, 2, 3][..]));
        assert_eq!(plan.lane_cpus(1), Some(&[4usize, 5, 6, 7][..]));
        assert_eq!(plan.lane_cpus(2), Some(&[0usize, 1, 2, 3][..]));
        // Extractor j shares node j % nnodes with lane j.
        assert_eq!(plan.extractor_cpus(0), plan.lane_cpus(0));
        assert_eq!(plan.extractor_cpus(1), plan.lane_cpus(1));
    }

    #[test]
    fn numa_on_a_single_node_machine_degrades_to_none() {
        let topo = Topology { nodes: vec![vec![0, 1, 2, 3]] };
        let plan = PinPlan::with_topology(Pinning::Numa, 4, &topo);
        assert!(plan.lane_cpus(0).is_none(), "single node ⇒ Pinning::None behaviour");
        assert!(plan.extractor_cpus(0).is_none());
    }

    #[test]
    fn pinning_parses_from_cli_strings() {
        assert_eq!("none".parse::<Pinning>().unwrap(), Pinning::None);
        assert_eq!("cores".parse::<Pinning>().unwrap(), Pinning::Cores);
        assert_eq!("numa".parse::<Pinning>().unwrap(), Pinning::Numa);
        assert!("both".parse::<Pinning>().is_err());
    }

    #[test]
    fn pin_current_thread_handles_empty_and_oversized_sets() {
        assert!(!pin_current_thread(&[]), "empty set is a no-op");
        assert!(!pin_current_thread(&[100_000]), "out-of-mask ids drop to a no-op");
    }

    #[test]
    fn cpulist_tolerates_empty_files_and_trailing_commas() {
        // An empty or whitespace-only cpulist file (seen on memory-only
        // nodes) parses to "no CPUs", not an error.
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("\n"), Vec::<usize>::new());
        assert_eq!(parse_cpulist("   \n  "), Vec::<usize>::new());
        // Trailing (and doubled) commas are skipped as empty segments.
        assert_eq!(parse_cpulist("0,1,\n"), vec![0, 1]);
        assert_eq!(parse_cpulist(",,0-2,,"), vec![0, 1, 2]);
        // A reversed range contributes nothing, but does not poison the
        // well-formed segments around it.
        assert_eq!(parse_cpulist("3-1"), Vec::<usize>::new());
        assert_eq!(parse_cpulist("5,3-1,7-7,"), vec![5, 7]);
    }

    /// Property: under any simulated 1/2/3-node topology, every lane
    /// and extractor slot resolves to a CPU set that actually exists —
    /// a single CPU from the node-interleaved list under `Cores`,
    /// exactly one node's full list under `Numa` (≥ 2 nodes), and no
    /// pin at all under `None` or degraded `Numa`.
    #[test]
    fn pinplan_property_every_slot_maps_to_a_valid_node() {
        use crate::testutil::{for_cases, Rng};

        fn sim_topology(rng: &mut Rng, nnodes: usize) -> Topology {
            let mut next_cpu = 0usize;
            let nodes = (0..nnodes)
                .map(|_| {
                    let width = rng.usize_in(1, 6);
                    let cpus: Vec<usize> = (next_cpu..next_cpu + width).collect();
                    next_cpu += width;
                    cpus
                })
                .collect();
            Topology { nodes }
        }

        for_cases(64, |rng| {
            let nnodes = rng.usize_in(1, 3);
            let topo = sim_topology(rng, nnodes);
            let lanes = rng.usize_in(1, 8);
            let extractors = rng.usize_in(0, 8);
            let policy = *rng.choose(&[Pinning::None, Pinning::Cores, Pinning::Numa]);
            let plan = PinPlan::with_topology(policy, lanes, &topo);
            let union: Vec<usize> = topo.nodes.iter().flatten().copied().collect();

            let mut slots: Vec<Option<&[usize]>> = Vec::new();
            for lane in 0..lanes {
                slots.push(plan.lane_cpus(lane));
            }
            for j in 0..extractors {
                slots.push(plan.extractor_cpus(j));
            }
            for set in slots {
                match policy {
                    Pinning::None => assert!(set.is_none(), "None never pins"),
                    Pinning::Cores => {
                        let cpus = set.expect("Cores always pins on a non-empty topology");
                        assert_eq!(cpus.len(), 1, "Cores pins a single CPU");
                        assert!(union.contains(&cpus[0]), "pinned CPU must exist");
                    }
                    Pinning::Numa if nnodes < 2 => {
                        assert!(set.is_none(), "single node degrades to no pinning");
                    }
                    Pinning::Numa => {
                        let cpus = set.expect("Numa pins whole nodes when nnodes >= 2");
                        assert!(
                            topo.nodes.iter().any(|node| node[..] == cpus[..]),
                            "a Numa pin set must be exactly one node's CPU list"
                        );
                    }
                }
            }
        });
    }
}
