//! Native-Rust oracles for end-to-end validation.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly (same boundary rules,
//! same constants) so streamed coordinator runs can be verified without
//! touching Python at run time.  Also doubles as the "CPU measured"
//! implementation in wallclock comparisons.

use crate::coordinator::grid::{Boundary, Grid2D, Grid3D};

/// One star-shaped 2D diffusion step; `coeffs = [c0, c1..cr]`.
pub fn diffusion2d_step(g: &Grid2D, coeffs: &[f32], b: Boundary) -> Grid2D {
    let r = coeffs.len() - 1;
    let mut out = Grid2D::zeros(g.ny, g.nx);
    for y in 0..g.ny {
        for x in 0..g.nx {
            let yi = y as isize;
            let xi = x as isize;
            let mut acc = coeffs[0] * g.at(y, x);
            for d in 1..=r {
                let di = d as isize;
                acc += coeffs[d]
                    * (g.read(yi - di, xi, b)
                        + g.read(yi + di, xi, b)
                        + g.read(yi, xi - di, b)
                        + g.read(yi, xi + di, b));
            }
            out.data[y * g.nx + x] = acc;
        }
    }
    out
}

pub fn diffusion2d(mut g: Grid2D, coeffs: &[f32], steps: usize) -> Grid2D {
    for _ in 0..steps {
        g = diffusion2d_step(&g, coeffs, Boundary::Zero);
    }
    g
}

/// One star-shaped 3D diffusion step.
pub fn diffusion3d_step(g: &Grid3D, coeffs: &[f32], b: Boundary) -> Grid3D {
    let r = coeffs.len() - 1;
    let mut out = Grid3D::zeros(g.nz, g.ny, g.nx);
    for z in 0..g.nz {
        for y in 0..g.ny {
            for x in 0..g.nx {
                let (zi, yi, xi) = (z as isize, y as isize, x as isize);
                let mut acc = coeffs[0] * g.at(z, y, x);
                for d in 1..=r {
                    let di = d as isize;
                    acc += coeffs[d]
                        * (g.read(zi - di, yi, xi, b)
                            + g.read(zi + di, yi, xi, b)
                            + g.read(zi, yi - di, xi, b)
                            + g.read(zi, yi + di, xi, b)
                            + g.read(zi, yi, xi - di, b)
                            + g.read(zi, yi, xi + di, b));
                }
                out.data[(z * g.ny + y) * g.nx + x] = acc;
            }
        }
    }
    out
}

pub fn diffusion3d(mut g: Grid3D, coeffs: &[f32], steps: usize) -> Grid3D {
    for _ in 0..steps {
        g = diffusion3d_step(&g, coeffs, Boundary::Zero);
    }
    g
}

/// Hotspot parameters (must match `python/compile/model.py`).
#[derive(Debug, Clone, Copy)]
pub struct HotspotParams {
    pub cap: f32,
    pub rx: f32,
    pub ry: f32,
    pub rz: f32,
    pub amb: f32,
}

impl Default for HotspotParams {
    fn default() -> Self {
        HotspotParams { cap: 0.05, rx: 1.0, ry: 1.0, rz: 4.0, amb: 80.0 }
    }
}

/// One Rodinia Hotspot step (clamp boundary).
pub fn hotspot2d_step(temp: &Grid2D, power: &Grid2D, p: HotspotParams) -> Grid2D {
    let b = Boundary::Clamp;
    let mut out = Grid2D::zeros(temp.ny, temp.nx);
    for y in 0..temp.ny {
        for x in 0..temp.nx {
            let (yi, xi) = (y as isize, x as isize);
            let t = temp.at(y, x);
            let n = temp.read(yi - 1, xi, b);
            let s = temp.read(yi + 1, xi, b);
            let w = temp.read(yi, xi - 1, b);
            let e = temp.read(yi, xi + 1, b);
            let delta = p.cap
                * (power.at(y, x)
                    + (n + s - 2.0 * t) / p.ry
                    + (e + w - 2.0 * t) / p.rx
                    + (p.amb - t) / p.rz);
            out.data[y * temp.nx + x] = t + delta;
        }
    }
    out
}

pub fn hotspot2d(mut temp: Grid2D, power: &Grid2D, p: HotspotParams, steps: usize) -> Grid2D {
    for _ in 0..steps {
        temp = hotspot2d_step(&temp, power, p);
    }
    temp
}

/// Hotspot 3D coefficients (must match `python/compile/model.py`).
#[derive(Debug, Clone, Copy)]
pub struct Hotspot3DParams {
    pub cc: f32,
    pub cn: f32,
    pub cs: f32,
    pub ce: f32,
    pub cw: f32,
    pub ct: f32,
    pub cb: f32,
    pub sdc: f32,
    pub amb: f32,
}

impl Default for Hotspot3DParams {
    fn default() -> Self {
        Hotspot3DParams {
            cc: 0.68, cn: 0.06, cs: 0.06, ce: 0.06, cw: 0.06,
            ct: 0.04, cb: 0.04, sdc: 0.01, amb: 80.0,
        }
    }
}

/// One Rodinia Hotspot3D step (clamp boundary; (z, y, x) layout).
pub fn hotspot3d_step(temp: &Grid3D, power: &Grid3D, p: Hotspot3DParams) -> Grid3D {
    let b = Boundary::Clamp;
    let mut out = Grid3D::zeros(temp.nz, temp.ny, temp.nx);
    for z in 0..temp.nz {
        for y in 0..temp.ny {
            for x in 0..temp.nx {
                let (zi, yi, xi) = (z as isize, y as isize, x as isize);
                let v = p.cc * temp.at(z, y, x)
                    + p.cn * temp.read(zi, yi - 1, xi, b)
                    + p.cs * temp.read(zi, yi + 1, xi, b)
                    + p.cw * temp.read(zi, yi, xi - 1, b)
                    + p.ce * temp.read(zi, yi, xi + 1, b)
                    + p.ct * temp.read(zi - 1, yi, xi, b)
                    + p.cb * temp.read(zi + 1, yi, xi, b)
                    + p.sdc * power.at(z, y, x)
                    + p.ct * p.amb;
                out.data[(z * temp.ny + y) * temp.nx + x] = v;
            }
        }
    }
    out
}

pub fn hotspot3d(mut t: Grid3D, power: &Grid3D, p: Hotspot3DParams, steps: usize) -> Grid3D {
    for _ in 0..steps {
        t = hotspot3d_step(&t, power, p);
    }
    t
}

/// Full Pathfinder: accumulate from row 0; returns the final cost row.
pub fn pathfinder(wall: &[Vec<i32>]) -> Vec<i32> {
    let cols = wall[0].len();
    let mut acc = wall[0].clone();
    for row in &wall[1..] {
        let mut next = vec![0i32; cols];
        for j in 0..cols {
            let l = acc[j.saturating_sub(1)];
            let c = acc[j];
            let r = acc[(j + 1).min(cols - 1)];
            next[j] = row[j] + l.min(c).min(r);
        }
        acc = next;
    }
    acc
}

/// Full NW score matrix (including initialised borders).
pub fn nw(reference: &[Vec<i32>], penalty: i32) -> Vec<Vec<i32>> {
    let n = reference.len();
    let m = reference[0].len();
    let mut s = vec![vec![0i32; m]; n];
    for j in 0..m {
        s[0][j] = -(j as i32) * penalty;
    }
    for i in 0..n {
        s[i][0] = -(i as i32) * penalty;
    }
    for i in 1..n {
        for j in 1..m {
            s[i][j] = (s[i - 1][j - 1] + reference[i][j])
                .max(s[i - 1][j] - penalty)
                .max(s[i][j - 1] - penalty);
        }
    }
    s
}

/// SRAD reduction: q0² from mean/variance.
pub fn srad_q0sqr(img: &Grid2D) -> f32 {
    let n = img.data.len() as f64;
    let sum: f64 = img.data.iter().map(|&v| v as f64).sum();
    let sum2: f64 = img.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let mean = sum / n;
    let var = sum2 / n - mean * mean;
    (var / (mean * mean)) as f32
}

/// One SRAD iteration (both passes, clamp boundary, lambda as in model.py).
pub fn srad_step(img: &Grid2D, lam: f32, q0: f32) -> Grid2D {
    let b = Boundary::Clamp;
    let (ny, nx) = (img.ny, img.nx);
    let mut c = Grid2D::zeros(ny, nx);
    let mut dn = vec![0f32; ny * nx];
    let mut ds = vec![0f32; ny * nx];
    let mut dw = vec![0f32; ny * nx];
    let mut de = vec![0f32; ny * nx];
    for y in 0..ny {
        for x in 0..nx {
            let (yi, xi) = (y as isize, x as isize);
            let v = img.at(y, x);
            let n_ = img.read(yi - 1, xi, b) - v;
            let s_ = img.read(yi + 1, xi, b) - v;
            let w_ = img.read(yi, xi - 1, b) - v;
            let e_ = img.read(yi, xi + 1, b) - v;
            let idx = y * nx + x;
            dn[idx] = n_;
            ds[idx] = s_;
            dw[idx] = w_;
            de[idx] = e_;
            let g2 = (n_ * n_ + s_ * s_ + w_ * w_ + e_ * e_) / (v * v);
            let l = (n_ + s_ + w_ + e_) / v;
            let num = 0.5 * g2 - 0.0625 * l * l;
            let den = 1.0 + 0.25 * l;
            let qsqr = num / (den * den);
            let den2 = (qsqr - q0) / (q0 * (1.0 + q0));
            c.data[idx] = (1.0 / (1.0 + den2)).clamp(0.0, 1.0);
        }
    }
    let mut out = Grid2D::zeros(ny, nx);
    for y in 0..ny {
        for x in 0..nx {
            let (yi, xi) = (y as isize, x as isize);
            let idx = y * nx + x;
            let c_c = c.at(y, x);
            let c_s = c.read(yi + 1, xi, b);
            let c_e = c.read(yi, xi + 1, b);
            let div = c_s * ds[idx] + c_c * dn[idx] + c_e * de[idx] + c_c * dw[idx];
            out.data[idx] = img.at(y, x) + 0.25 * lam * div;
        }
    }
    out
}

pub fn srad(mut img: Grid2D, lam: f32, steps: usize) -> Grid2D {
    for _ in 0..steps {
        let q0 = srad_q0sqr(&img);
        img = srad_step(&img, lam, q0);
    }
    img
}

/// Doolittle LU (no pivoting), in-place combined L\U layout, f64
/// accumulation like the numpy oracle.
pub fn lud(a: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = a.len();
    let mut m: Vec<Vec<f64>> = a
        .iter()
        .map(|row| row.iter().map(|&v| v as f64).collect())
        .collect();
    for k in 0..n {
        let pivot = m[k][k];
        for i in k + 1..n {
            m[i][k] /= pivot;
        }
        for i in k + 1..n {
            let lik = m[i][k];
            for j in k + 1..n {
                m[i][j] -= lik * m[k][j];
            }
        }
    }
    m.iter()
        .map(|row| row.iter().map(|&v| v as f32).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn diffusion_conserves_with_unit_coeffs() {
        // With c0=1 and no neighbours, the step is the identity.
        let g = Grid2D::from_fn(8, 8, |y, x| (y + x) as f32);
        let out = diffusion2d_step(&g, &[1.0], Boundary::Zero);
        assert_eq!(g, out);
    }

    #[test]
    fn hotspot_converges_to_ambient_without_power() {
        let p = HotspotParams::default();
        let temp = Grid2D::from_fn(8, 8, |_, _| 60.0);
        let power = Grid2D::zeros(8, 8);
        let out = hotspot2d(temp, &power, p, 400);
        for &v in &out.data {
            assert!((v - p.amb).abs() < 1.0, "v={v}");
        }
    }

    #[test]
    fn pathfinder_monotone() {
        let wall = vec![vec![1, 2, 3], vec![0, 0, 0], vec![5, 5, 5]];
        let out = pathfinder(&wall);
        assert_eq!(out, vec![6, 6, 6]);
    }

    #[test]
    fn nw_small_case() {
        // 2x2 with zero scores: best path is all gaps or diagonal.
        let r = vec![vec![0, 0], vec![0, 5]];
        let s = nw(&r, 2);
        assert_eq!(s[1][1], 5); // corner 0 + ref 5
    }

    #[test]
    fn lud_reconstructs() {
        let mut rng = Rng::new(5);
        let n = 12;
        let a: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| {
                        rng.f32_in(-1.0, 1.0) + if i == j { n as f32 } else { 0.0 }
                    })
                    .collect()
            })
            .collect();
        let m = lud(&a);
        // L @ U == A (unit-lower L, upper U from the combined layout)
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0f64;
                for k in 0..=i.min(j) {
                    let l = if k == i { 1.0 } else { m[i][k] as f64 };
                    acc += l * m[k][j] as f64;
                }
                assert!(
                    (acc - a[i][j] as f64).abs() < 1e-2,
                    "({i},{j}): {acc} vs {}",
                    a[i][j]
                );
            }
        }
    }
}
