//! The typed front door of the execution engine: a [`Session`] owns
//! the lane pool and cumulative [`Metrics`], first-class [`Workload`]
//! descriptors *lower* onto the wavefront pass driver instead of
//! hand-wiring it, and a [`Chain`] splices several workloads into one
//! fused [`WaveGraph`] so chained apps never drain the lanes between
//! stages.
//!
//! ```no_run
//! use fpga_hpc::coordinator::session::{GridInput, Session, Workload};
//! use fpga_hpc::coordinator::{Grid2D, PassMode};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = Session::builder()
//!     .artifacts("artifacts")
//!     .lanes(4)
//!     .mode(PassMode::Pipelined)
//!     .build()?;
//!
//! // One workload…
//! let img = Grid2D::zeros(512, 512);
//! let report = session.run(Workload::srad(img.clone(), 4))?;
//! println!("{}", report.metrics.summary());
//!
//! // …or a fused chain: the stencil consumes SRAD's output *in place*
//! // and its first blocks start while SRAD's tail is still executing.
//! let report = session.run(
//!     Workload::srad(img, 4)
//!         .then(Workload::stencil2d("diffusion2d_r1", GridInput::Upstream, None, 16)),
//! )?;
//! assert!(report.metrics.pipeline_depth_max > 1);
//! # Ok(()) }
//! ```
//!
//! # Lowering
//!
//! Every workload becomes a *fragment*: a [`WaveSpace`] (topologically
//! ordered waves of blocks with explicit dependency edges) plus the
//! seam metadata a [`Chain`] needs.  The Ch. 4 apps reuse the wave
//! spaces defined in `coordinator::apps`, so results are bit-identical
//! to the original per-app runners those spaces came from; the
//! Ch. 5 stencils lower each *pass* to one wave whose edges are the
//! `r·T` halo-overlap rule — the same schedule `DepTable` enforced,
//! now expressed as an explicit graph so stencils can splice into
//! heterogeneous chains.
//!
//! # Fusion (the `Chain` seam rule)
//!
//! `a.then(b)` with `b` built over [`GridInput::Upstream`] aliases
//! `b`'s input buffer onto `a`'s output buffer and adds **cross-app
//! pred edges**: a first-wave block of `b` depends only on the tail
//! blocks of `a` that are the *final writers* of the cells its piped
//! read rectangle covers — the heterogeneous generalization of the
//! stencil driver's halo-overlap rule.  Everything downstream of `b`'s
//! first wave is ordered transitively, including the write-after-read
//! hazard of `b` re-using `a`'s buffer as one half of its double
//! buffer (the same induction that makes two buffers sound inside one
//! app; see the runtime README's seam diagram).  Chained stages
//! without a piped input (`pathfinder.then(nw)`) share the fused graph
//! with no seam edges at all: the lanes interleave both apps freely.
//! Either way there is **no inter-app `wait_idle`** — one `WaveTable`
//! spans the whole chain, and [`PassMode::Barrier`] degrades it to the
//! back-to-back wave-serial reference the tests and the CI perf gate
//! compare against.
//!
//! # Partial failure
//!
//! A terminally failed block no longer turns the whole run into `Err`:
//! the drive cancels exactly the failed block's dependency cone and
//! keeps every other block flowing (see `passdriver` § Fault
//! tolerance), and [`Session::run`] maps the surviving per-block
//! record onto per-stage [`WorkloadStatus`]es in the [`RunReport`].  A
//! fused `srad.then(stencil2d)` chain whose upstream faults still
//! reports the independent `pathfinder.then(nw)` stages as
//! [`WorkloadStatus::Ok`] with their outputs intact; only stages that
//! faulted ([`WorkloadStatus::Failed`]) or sat in a cancelled cone
//! ([`WorkloadStatus::Cancelled`]) have unreliable outputs.
//! `Session::run` itself returns `Err` only for infrastructure
//! failures (bad descriptors, warmup/compile errors, a lane that could
//! not be respawned).

use std::cell::UnsafeCell;
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure};

use crate::coordinator::apps::{
    LudSpace, NwSpace, PathfinderSpace, RawSlice, SradSpace, SyncCell,
};
use crate::coordinator::bufpool::TensorPools;
use crate::coordinator::grid::{Boundary, Grid2D, Grid3D, GridWriter2D, GridWriter3D};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::passdriver::{
    self, BlockFault, ConeReplay, PassMode, ReplayPolicy, StencilSpace, WaveGraph, WaveSpace,
};
use crate::coordinator::stencil_runner::{
    block_origins_2d, boundary_of, extractor_count, scalar_stencil_meta, stencil_meta, Space2D,
    Space3D, StencilMeta,
};
use crate::runtime::pool::lock;
use crate::runtime::topology::available_cores;
use crate::runtime::{FaultKind, Pinning, PoolConfig, Registry, RuntimePool, Tensor};

// ---------------------------------------------------------------------------
// Public descriptor types
// ---------------------------------------------------------------------------

/// Where a 2D-grid workload takes its input from.
#[derive(Debug, Clone)]
pub enum GridInput {
    /// An owned initial grid (standalone runs and chain heads).
    Init(Grid2D),
    /// Splice onto the previous chain stage's output grid, in place:
    /// the stage reads the upstream buffer directly and its first wave
    /// is gated only by the upstream tail blocks its reads overlap.
    Upstream,
}

impl From<Grid2D> for GridInput {
    fn from(g: Grid2D) -> GridInput {
        GridInput::Init(g)
    }
}

/// A first-class workload descriptor.  Constructors capture the inputs;
/// nothing executes until [`Session::run`] lowers the descriptor onto
/// the wavefront pass driver.
#[derive(Debug)]
pub struct Workload(WorkloadKind);

#[derive(Debug)]
enum WorkloadKind {
    Stencil2d { artifact: String, grid: GridInput, aux: Option<Grid2D>, steps: u64 },
    Stencil2dScalar { artifact: String, grid: GridInput, scalar: f32 },
    Stencil3d { artifact: String, grid: Grid3D, aux: Option<Grid3D>, steps: u64 },
    Pathfinder { wall: Vec<Vec<i32>> },
    Nw { reference: Vec<Vec<i32>>, penalty: i32 },
    Srad { img: GridInput, steps: u64 },
    Lud { a: Vec<Vec<f32>> },
}

impl Workload {
    /// `steps` time steps of a 2D stencil artifact (diffusion2d_r*,
    /// hotspot2d); `aux` is the optional second input stream
    /// (Hotspot's power grid).  `steps` must be a multiple of the
    /// artifact's fused depth `T`.
    pub fn stencil2d(
        artifact: impl Into<String>,
        grid: impl Into<GridInput>,
        aux: Option<Grid2D>,
        steps: u64,
    ) -> Workload {
        Workload(WorkloadKind::Stencil2d {
            artifact: artifact.into(),
            grid: grid.into(),
            aux,
            steps,
        })
    }

    /// One pass of a 2D stencil artifact that takes a run-time scalar
    /// operand (SRAD's q0² shape-`[T]` input); advances the grid by the
    /// artifact's fused step count.
    pub fn stencil2d_with_scalar(
        artifact: impl Into<String>,
        grid: impl Into<GridInput>,
        scalar: f32,
    ) -> Workload {
        Workload(WorkloadKind::Stencil2dScalar {
            artifact: artifact.into(),
            grid: grid.into(),
            scalar,
        })
    }

    /// `steps` time steps of a 3D stencil artifact (diffusion3d_r*,
    /// hotspot3d).  3D grids do not currently splice onto upstream
    /// stages (no [`GridInput`]), but a 3D stage can still ride in a
    /// chain as an independent workload.
    pub fn stencil3d(
        artifact: impl Into<String>,
        grid: Grid3D,
        aux: Option<Grid3D>,
        steps: u64,
    ) -> Workload {
        Workload(WorkloadKind::Stencil3d {
            artifact: artifact.into(),
            grid,
            aux,
            steps,
        })
    }

    /// Pathfinder: min-cost accumulation from row 0 down through
    /// `wall` (rows × cols); `(rows - 1)` must be a multiple of the
    /// artifact's fused depth.
    pub fn pathfinder(wall: Vec<Vec<i32>>) -> Workload {
        Workload(WorkloadKind::Pathfinder { wall })
    }

    /// Needleman-Wunsch over an (n+1)×(n+1) reference matrix; `n` must
    /// be a multiple of the artifact block and `penalty` must match the
    /// artifact's baked value.
    pub fn nw(reference: Vec<Vec<i32>>, penalty: i32) -> Workload {
        Workload(WorkloadKind::Nw { reference, penalty })
    }

    /// SRAD: `steps` iterations of (tile-partial reduction → fused
    /// stencil) over a positive image, with the two-stage dependency
    /// edge overlapping step `s+1`'s reduction with step `s`'s stencil
    /// tail.
    pub fn srad(img: impl Into<GridInput>, steps: u64) -> Workload {
        Workload(WorkloadKind::Srad { img: img.into(), steps })
    }

    /// Blocked LU factorization of an n×n matrix; `n` must be a
    /// multiple of the artifact block.
    pub fn lud(a: Vec<Vec<f32>>) -> Workload {
        Workload(WorkloadKind::Lud { a })
    }

    /// Chain this workload with a downstream one; see [`Chain`].
    pub fn then(self, next: Workload) -> Chain {
        Chain { stages: vec![self, next] }
    }

    fn wants_upstream(&self) -> bool {
        matches!(
            &self.0,
            WorkloadKind::Stencil2d { grid: GridInput::Upstream, .. }
                | WorkloadKind::Stencil2dScalar { grid: GridInput::Upstream, .. }
                | WorkloadKind::Srad { img: GridInput::Upstream, .. }
        )
    }
}

/// An ordered sequence of workloads fused into **one** wave graph: the
/// stages share a single dependency table, so a downstream stage's
/// blocks start as soon as their declared predecessors (its own waves
/// plus any cross-app seam edges) have written back — no inter-app
/// `wait_idle`, no drain between stages.
#[derive(Debug)]
pub struct Chain {
    stages: Vec<Workload>,
}

impl Chain {
    /// Append another stage to the chain.
    pub fn then(mut self, next: Workload) -> Chain {
        self.stages.push(next);
        self
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }
}

impl From<Workload> for Chain {
    fn from(w: Workload) -> Chain {
        Chain { stages: vec![w] }
    }
}

/// A finished stage's result.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadOutput {
    /// The stage's output grid was spliced into the next stage, which
    /// (re)used its buffer in place — there is no separate result to
    /// report (ask the *last* stage of the chain for the final grid).
    Piped,
    Grid2D(Grid2D),
    Grid3D(Grid3D),
    /// Pathfinder's accumulated cost row.
    Row(Vec<i32>),
    /// NW's (n+1)×(n+1) score matrix.
    ScoreMatrix(Vec<Vec<i32>>),
    /// LUD's factorized matrix.
    Matrix(Vec<Vec<f32>>),
}

impl WorkloadOutput {
    pub fn into_grid2d(self) -> Option<Grid2D> {
        match self {
            WorkloadOutput::Grid2D(g) => Some(g),
            _ => None,
        }
    }

    pub fn into_grid3d(self) -> Option<Grid3D> {
        match self {
            WorkloadOutput::Grid3D(g) => Some(g),
            _ => None,
        }
    }

    pub fn into_row(self) -> Option<Vec<i32>> {
        match self {
            WorkloadOutput::Row(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_score_matrix(self) -> Option<Vec<Vec<i32>>> {
        match self {
            WorkloadOutput::ScoreMatrix(m) => Some(m),
            _ => None,
        }
    }

    pub fn into_matrix(self) -> Option<Vec<Vec<f32>>> {
        match self {
            WorkloadOutput::Matrix(m) => Some(m),
            _ => None,
        }
    }
}

/// Why a stage did not complete: the first terminal block fault
/// attributed to it by [`Session::run`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultReport {
    pub kind: FaultKind,
    pub message: String,
    /// Execution attempts made on the faulting block (1 + retries).
    pub attempts: u32,
    /// Global (fused) wave of the faulting block.
    pub wave: usize,
    /// Block index within that wave.
    pub block: usize,
}

/// Per-stage completion status in a [`RunReport`].
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadStatus {
    /// Every block of the stage ran to completion; its output is the
    /// real result.
    Ok,
    /// A block of this stage faulted terminally *and* cone replay
    /// healed it: the cancelled dependency cone was re-armed and
    /// re-driven to completion under the session's [`ReplayPolicy`],
    /// so the stage's output is whole — bitwise what a fault-free run
    /// produces — at the cost of `attempts` replay round(s).  Stages
    /// whose blocks were merely re-driven as cone members (no fault of
    /// their own) report [`WorkloadStatus::Ok`].
    Replayed {
        /// Replay rounds the stage's worst block consumed (≥ 1).
        attempts: u32,
    },
    /// A block of this stage faulted terminally (retry budget
    /// exhausted, or a `Fatal`/`Panic` fault) and the replay budget —
    /// if any — was also spent; the block's dependency cone was
    /// cancelled and the stage's output is partial.
    Failed(FaultReport),
    /// No block of this stage faulted, but some sat in a failed
    /// upstream block's dependency cone and stayed cancelled after the
    /// replay budget; the stage's output is partial.
    Cancelled,
    /// The run's wall-clock [`SessionBuilder::deadline`] expired
    /// before every block of this stage completed: the driver aborted
    /// the ready queue, fenced still-queued jobs behind a fresh pool
    /// epoch, and returned with this stage's output partial.  A stage
    /// that also owns a terminally failed block reports
    /// [`WorkloadStatus::Failed`] instead (the fault is the more
    /// specific diagnosis).
    DeadlineExceeded,
}

impl WorkloadStatus {
    /// Strictly fault-free (`Ok` only — a `Replayed` stage completed,
    /// but not invisibly; see [`WorkloadStatus::completed`]).
    pub fn is_ok(&self) -> bool {
        matches!(self, WorkloadStatus::Ok)
    }

    /// The stage's output is whole and trustworthy: `Ok`, or
    /// `Replayed` (healed by cone replay, bitwise identical to a
    /// fault-free run).
    pub fn completed(&self) -> bool {
        matches!(self, WorkloadStatus::Ok | WorkloadStatus::Replayed { .. })
    }
}

/// What one [`Session::run`] call produced: per-run [`Metrics`] (no
/// bleed-through from earlier runs on the same session/pool), the
/// end-to-end elapsed time (including artifact warmup and lowering,
/// which `metrics.wall` excludes), one output **and** one
/// [`WorkloadStatus`] per chain stage, and the cancelled-block record.
///
/// Outputs are copied out for every stage — the drive quiesces the
/// lanes before any buffer is read — but only stages whose status
/// [`WorkloadStatus::is_ok`] carry trustworthy results; `Failed` /
/// `Cancelled` stages report whatever the buffers held when their
/// cones were cut.
#[derive(Debug)]
pub struct RunReport {
    pub metrics: Metrics,
    pub elapsed: Duration,
    pub outputs: Vec<WorkloadOutput>,
    /// One status per chain stage, in chain order.
    pub statuses: Vec<WorkloadStatus>,
    /// Every block cancelled as a transitive successor of a failed
    /// block, in global (fused wave, index) coordinates.  Empty on a
    /// fault-free run and on a run fully healed by cone replay.
    pub cancelled: Vec<(usize, usize)>,
    /// One entry per terminally-faulted block that cone replay healed,
    /// in global (fused wave, index) coordinates.  Empty on a
    /// fault-free run and when [`ReplayPolicy::none`] is in force.
    pub replays: Vec<ConeReplay>,
    /// Blocks the run's [`SessionBuilder::deadline`] cut off before
    /// they completed — neither faulted nor cone-cancelled, just never
    /// run (or fenced mid-queue), in global (fused wave, index)
    /// coordinates.  Always empty when the deadline did not fire.
    pub unfinished: Vec<(usize, usize)>,
    /// `true` when the run's wall-clock deadline fired and cut the
    /// drive short — the per-stage statuses and `unfinished` describe
    /// what the cut left behind.
    pub deadline_exceeded: bool,
}

impl RunReport {
    /// The final stage's output.
    pub fn output(&self) -> &WorkloadOutput {
        self.outputs.last().expect("a run has at least one stage")
    }

    /// Consume the report, keeping only the final stage's output.
    pub fn into_output(mut self) -> WorkloadOutput {
        self.outputs.pop().expect("a run has at least one stage")
    }

    /// `true` when every stage ran strictly fault-free
    /// ([`WorkloadStatus::Ok`]) and no run deadline fired; a healed
    /// [`WorkloadStatus::Replayed`] stage fails this check — use
    /// [`RunReport::completed`] to accept both.
    pub fn ok(&self) -> bool {
        !self.deadline_exceeded && self.statuses.iter().all(WorkloadStatus::is_ok)
    }

    /// `true` when every stage's output is whole — `Ok` or healed by
    /// cone replay (`Replayed`).
    pub fn completed(&self) -> bool {
        self.statuses.iter().all(WorkloadStatus::completed)
    }

    /// The first stage fault, if any stage failed.
    pub fn first_fault(&self) -> Option<&FaultReport> {
        self.statuses.iter().find_map(|s| match s {
            WorkloadStatus::Failed(f) => Some(f),
            _ => None,
        })
    }
}

// ---------------------------------------------------------------------------
// Session + builder
// ---------------------------------------------------------------------------

/// Builder for an owning [`Session`]; see [`Session::builder`].
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    dir: PathBuf,
    lanes: usize,
    mode: PassMode,
    extractors: Option<usize>,
    pinning: Pinning,
    replay: ReplayPolicy,
    deadline: Option<Duration>,
    job_timeout: Option<Duration>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            dir: PathBuf::from("artifacts"),
            lanes: 1,
            mode: PassMode::Pipelined,
            extractors: None,
            pinning: Pinning::None,
            replay: ReplayPolicy::default(),
            deadline: None,
            job_timeout: None,
        }
    }
}

/// Clamp a pinned lane count to the machine: under
/// [`Pinning::Cores`]/[`Pinning::Numa`] each lane wants a CPU of its
/// own (plus its extractor partners), so more lanes than cores would
/// just stack pinned threads on shared CPUs and serialize them.
/// Unpinned sessions keep whatever was asked for — the OS scheduler is
/// free to oversubscribe.
fn clamp_lanes(lanes: usize, pinning: Pinning, cores: usize) -> usize {
    let lanes = lanes.max(1);
    if pinning == Pinning::None || cores == 0 || lanes <= cores {
        return lanes;
    }
    eprintln!(
        "session: clamping lanes {lanes} -> {cores} (pinning {pinning:?} needs a core per lane)"
    );
    cores
}

impl SessionBuilder {
    /// Artifact directory (default `artifacts`).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.dir = dir.into();
        self
    }

    /// Execute lanes — replicated compute units, one PJRT client each
    /// (default 1; clamped to ≥ 1).
    pub fn lanes(mut self, lanes: usize) -> Self {
        self.lanes = lanes.max(1);
        self
    }

    /// Inter-wave schedule (default [`PassMode::Pipelined`]).
    pub fn mode(mut self, mode: PassMode) -> Self {
        self.mode = mode;
        self
    }

    /// Tile-extractor workers feeding the lanes (default
    /// `ceil(lanes / 2)` — halo extraction runs at memcpy rate).
    pub fn extractors(mut self, n: usize) -> Self {
        self.extractors = Some(n.max(1));
        self
    }

    /// CPU-affinity policy for the lane threads and their extractor
    /// partners (default [`Pinning::None`]).  Under
    /// `Pinning::{Cores,Numa}` the lane count is clamped to the
    /// available cores at [`SessionBuilder::build`] time, with a
    /// warning on stderr.
    pub fn pinning(mut self, pinning: Pinning) -> Self {
        self.pinning = pinning;
        self
    }

    /// Cone-replay budget for terminally-faulted blocks (default
    /// [`ReplayPolicy::default`] — one replay round).  Use
    /// [`ReplayPolicy::none`] to restore the PR 6 cancel-only
    /// semantics.
    pub fn replay(mut self, replay: ReplayPolicy) -> Self {
        self.replay = replay;
        self
    }

    /// Wall-clock budget for each [`Session::run`] call, measured from
    /// run entry (default none).  On expiry the drive aborts: queued
    /// blocks are fenced, incomplete cones cancelled, and the report
    /// comes back with [`RunReport::deadline_exceeded`] set and
    /// [`WorkloadStatus::DeadlineExceeded`] on the cut stages —
    /// instead of blocking in `wait_idle`.
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Per-block-job wall-clock budget (default none).  A lane stuck
    /// past the budget is reaped by the pool watchdog and the block
    /// fails with [`FaultKind::Timeout`], healing through cone replay
    /// like any other terminal fault.
    pub fn job_timeout(mut self, budget: Duration) -> Self {
        self.job_timeout = Some(budget);
        self
    }

    /// Open the artifact directory and spin up the lane pool.
    pub fn build(self) -> crate::Result<Session<'static>> {
        let lanes = clamp_lanes(self.lanes, self.pinning, available_cores());
        let pool = RuntimePool::open_with(
            &self.dir,
            PoolConfig { lanes, pinning: self.pinning, sharded: true },
        )?;
        Ok(Session {
            engine: Engine::Owned(pool),
            mode: self.mode,
            extractors: self.extractors,
            replay: self.replay,
            deadline: self.deadline,
            job_timeout: self.job_timeout,
            totals: Mutex::new(Metrics::default()),
        })
    }
}

enum Engine<'p> {
    Owned(RuntimePool),
    Borrowed(&'p RuntimePool),
}

/// The unified execution surface: owns (or borrows) the
/// [`RuntimePool`], lowers [`Workload`]s / [`Chain`]s onto the
/// wavefront pass driver, and accumulates cumulative [`Metrics`]
/// across runs (snapshot with [`Session::metrics`], zero with
/// [`Session::reset_metrics`]) while every [`Session::run`] still
/// returns a fresh per-run [`RunReport`].
pub struct Session<'p> {
    engine: Engine<'p>,
    mode: PassMode,
    extractors: Option<usize>,
    replay: ReplayPolicy,
    deadline: Option<Duration>,
    job_timeout: Option<Duration>,
    totals: Mutex<Metrics>,
}

impl Session<'static> {
    /// Start configuring an owning session:
    /// `Session::builder().lanes(4).mode(PassMode::Pipelined).build()?`.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }
}

impl<'p> Session<'p> {
    /// Borrow an existing pool (tests and benches share one pool
    /// across many sessions this way).
    pub fn over(pool: &'p RuntimePool) -> Session<'p> {
        Session {
            engine: Engine::Borrowed(pool),
            mode: PassMode::Pipelined,
            extractors: None,
            replay: ReplayPolicy::default(),
            deadline: None,
            job_timeout: None,
            totals: Mutex::new(Metrics::default()),
        }
    }

    /// Override the inter-wave schedule.
    pub fn with_mode(mut self, mode: PassMode) -> Self {
        self.mode = mode;
        self
    }

    /// Override the extractor-worker count.
    pub fn with_extractors(mut self, n: usize) -> Self {
        self.extractors = Some(n.max(1));
        self
    }

    /// Override the cone-replay budget (default one replay round).
    pub fn with_replay(mut self, replay: ReplayPolicy) -> Self {
        self.replay = replay;
        self
    }

    /// Override the per-run wall-clock deadline (default none); see
    /// [`SessionBuilder::deadline`].
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Override the per-job budget (default none); see
    /// [`SessionBuilder::job_timeout`].
    pub fn with_job_timeout(mut self, budget: Duration) -> Self {
        self.job_timeout = Some(budget);
        self
    }

    pub fn mode(&self) -> PassMode {
        self.mode
    }

    pub fn pool(&self) -> &RuntimePool {
        match &self.engine {
            Engine::Owned(p) => p,
            Engine::Borrowed(p) => p,
        }
    }

    pub fn lanes(&self) -> usize {
        self.pool().lanes()
    }

    /// Snapshot of the cumulative metrics across every run of this
    /// session.
    pub fn metrics(&self) -> Metrics {
        lock(&self.totals).snapshot()
    }

    /// Zero the cumulative metrics.
    pub fn reset_metrics(&self) {
        lock(&self.totals).reset()
    }

    /// Lower the chain onto one fused wave graph, warm every distinct
    /// artifact on every lane (outside the timed region), and drive
    /// the whole thing through the dependency-tracked scheduler —
    /// one `WaveTable`, one closing `wait_idle`, no barrier anywhere
    /// between stages.
    ///
    /// Block-level faults do not abort the run: the drive cancels the
    /// failed block's dependency cone, finishes everything else, then
    /// re-arms and re-drives just the cone under the session's
    /// [`ReplayPolicy`] (default one replay round) — a healed stage
    /// reports [`WorkloadStatus::Replayed`] with whole output; a
    /// spent budget falls back to `Failed`/`Cancelled` with partial
    /// output.  `Err` is reserved for infrastructure failures (bad
    /// descriptors, warmup errors, an unrecoverable pool).
    pub fn run(&self, chain: impl Into<Chain>) -> crate::Result<RunReport> {
        self.run_inner(chain.into(), Default::default())
    }

    /// [`Session::run`] with a deterministic
    /// [`FaultPlan`](passdriver::FaultPlan) injected into the drive —
    /// the chaos-harness entry point (test / `chaos` builds only).
    #[cfg(any(test, feature = "chaos"))]
    pub fn run_with_faults(
        &self,
        chain: impl Into<Chain>,
        plan: Arc<passdriver::FaultPlan>,
    ) -> crate::Result<RunReport> {
        self.run_inner(chain.into(), Some(plan))
    }

    fn run_inner(&self, chain: Chain, inject: passdriver::Injection) -> crate::Result<RunReport> {
        let t0 = Instant::now();
        ensure!(!chain.stages.is_empty(), "cannot run an empty chain");
        // Anchor the deadline at run entry, so lowering and artifact
        // warmup spend from the same budget the drive does.
        let limits = passdriver::RunLimits {
            job_timeout: self.job_timeout,
            deadline: self.deadline.map(|d| t0 + d),
        };
        let pool = self.pool();

        let mut artifacts: Vec<String> = Vec::new();
        let mut frags: Vec<Box<dyn Fragment>> = Vec::new();
        let mut piped = Vec::with_capacity(chain.stages.len());
        for stage in chain.stages {
            let wants = stage.wants_upstream();
            // Tile pools shard per lane: the driver keys take/recycle
            // by the block's affinity lane, so free lists stay local.
            let frag = stage.lower(
                pool.registry(),
                frags.last().map(|f| f.as_ref()),
                &mut artifacts,
                pool.lanes(),
            )?;
            piped.push(wants);
            frags.push(frag);
        }

        // Compile every distinct artifact on every lane, outside the
        // timed region (the analogue of FPGA reprogramming, §4.2.4).
        let mut seen = HashSet::new();
        artifacts.retain(|n| seen.insert(n.clone()));
        let names: Vec<&str> = artifacts.iter().map(String::as_str).collect();
        pool.warmup_artifacts(&names)?;

        let space = Arc::new(FusedSpace::splice(frags, piped));
        let extractors = self
            .extractors
            .unwrap_or_else(|| extractor_count(pool.lanes()));
        let outcome = passdriver::drive_wave_pool_inner(
            pool,
            &space,
            self.mode,
            extractors,
            self.replay,
            limits,
            inject,
        )?;
        // The drive has quiesced every lane; copying outputs through
        // the raw handles is race-free now.
        let outputs = space.outputs();
        let statuses = space.statuses(
            &outcome.faults,
            &outcome.cancelled,
            &outcome.replays,
            &outcome.unfinished,
        );
        lock(&self.totals).merge(&outcome.metrics);
        Ok(RunReport {
            metrics: outcome.metrics,
            elapsed: t0.elapsed(),
            outputs,
            statuses,
            cancelled: outcome.cancelled,
            replays: outcome.replays,
            unfinished: outcome.unfinished,
            deadline_exceeded: outcome.deadline_exceeded,
        })
    }
}

// ---------------------------------------------------------------------------
// Fragments: lowered workloads + the seam metadata Chain splices on
// ---------------------------------------------------------------------------

/// Half-open cell rectangle (rows `y0..y1`, cols `x0..x1`) in a 2D
/// grid's coordinates, already clipped to the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Rect {
    pub y0: usize,
    pub y1: usize,
    pub x0: usize,
    pub x1: usize,
}

impl Rect {
    fn clipped(y0: isize, y1: isize, x0: isize, x1: isize, ny: usize, nx: usize) -> Rect {
        Rect {
            y0: y0.max(0) as usize,
            y1: (y1.max(0) as usize).min(ny),
            x0: x0.max(0) as usize,
            x1: (x1.max(0) as usize).min(nx),
        }
    }

    fn intersects(&self, y0: usize, y1: usize, x0: usize, x1: usize) -> bool {
        self.y0 < y1 && y0 < self.y1 && self.x0 < x1 && x0 < self.x1
    }
}

/// The 2D grid buffer a downstream stage may splice onto.
#[derive(Clone, Copy)]
pub(crate) struct OutGrid {
    pub handle: GridWriter2D,
    pub ny: usize,
    pub nx: usize,
}

/// A lowered workload: a [`WaveSpace`] fragment plus the seam hooks
/// [`FusedSpace::splice`] uses to wire cross-app pred edges and hand
/// grid buffers downstream.
pub(crate) trait Fragment: WaveSpace {
    /// The read rectangle of first-wave block `i` **in the piped input
    /// grid** — only consulted when this stage was built over
    /// [`GridInput::Upstream`].
    fn seam_in_rect(&self, i: usize) -> Option<Rect> {
        let _ = i;
        None
    }

    /// Visit the (local wave, index) of every block of this fragment
    /// that is the *final writer* of any cell of `rect` in its output
    /// grid.  No-op when the fragment has no grid output.
    fn seam_out(&self, rect: Rect, f: &mut dyn FnMut(usize, usize)) {
        let _ = (rect, f);
    }

    /// The grid buffer holding this fragment's final output, for a
    /// downstream [`GridInput::Upstream`] stage to alias.
    fn out_grid(&self) -> Option<OutGrid> {
        None
    }

    /// Copy the final result out.  Only called after the drive has
    /// quiesced every lane (no writer is live on any handle).
    fn output(&self) -> WorkloadOutput;
}

/// How a stencil-shaped fragment gets its input buffer.
pub(crate) enum StencilInput {
    Own(Grid2D),
    Piped(OutGrid),
}

fn resolve_grid_input(
    g: GridInput,
    upstream: Option<&dyn Fragment>,
) -> crate::Result<StencilInput> {
    match g {
        GridInput::Init(grid) => Ok(StencilInput::Own(grid)),
        GridInput::Upstream => {
            let up = upstream.ok_or_else(|| {
                anyhow!("GridInput::Upstream needs an upstream stage in the chain")
            })?;
            let out = up
                .out_grid()
                .ok_or_else(|| anyhow!("upstream stage produces no 2D grid to splice onto"))?;
            Ok(StencilInput::Piped(out))
        }
    }
}

/// Visit the clipped lattice neighborhood of block `i` — the blocks
/// within `reach` lattice steps on every axis (the `r·T` halo-overlap
/// rule `DepTable` enforces, expressed as explicit edges).
fn visit_lattice_neighborhood(
    dims: [usize; 3],
    reach: [usize; 3],
    i: usize,
    f: &mut dyn FnMut(usize),
) {
    let c = [
        i / (dims[1] * dims[2]),
        (i / dims[2]) % dims[1],
        i % dims[2],
    ];
    let lo = |a: usize| c[a].saturating_sub(reach[a]);
    let hi = |a: usize| (c[a] + reach[a]).min(dims[a] - 1);
    for z in lo(0)..=hi(0) {
        for y in lo(1)..=hi(1) {
            for x in lo(2)..=hi(2) {
                f((z * dims[1] + y) * dims[2] + x);
            }
        }
    }
}

/// Bind a stencil-shaped 2D double buffer: resolve the input handle
/// (an owned grid, or the upstream output when piped) and allocate the
/// fragment-owned alternate buffer.  Returns the `[read, write]`
/// handle pair for wave 0, the extents, and the grids the fragment
/// must own (heap storage is stable behind struct moves, so the
/// handles stay valid; the wave driver quiesces every lane before the
/// fragment — and thus the grids — drop).
fn double_buffer(input: StencilInput) -> ([GridWriter2D; 2], usize, usize, Vec<Grid2D>) {
    let mut grids = Vec::with_capacity(2);
    let (h0, ny, nx) = match input {
        StencilInput::Own(mut g) => {
            let (ny, nx) = (g.ny, g.nx);
            // SAFETY: see above — the grid moves into `grids`, its
            // heap buffer does not.
            let h = unsafe { g.shared_writer() };
            grids.push(g);
            (h, ny, nx)
        }
        StencilInput::Piped(o) => (o.handle, o.ny, o.nx),
    };
    let mut next = Grid2D::zeros(ny, nx);
    // SAFETY: as above.
    let h1 = unsafe { next.shared_writer() };
    grids.push(next);
    ([h0, h1], ny, nx, grids)
}

/// Copy a full grid out through its raw handle.
///
/// Only sound once the drive has quiesced (no concurrent writer).
fn copy_grid2d(h: GridWriter2D, ny: usize, nx: usize) -> Grid2D {
    let mut data = Vec::with_capacity(ny * nx);
    // SAFETY: callers only reach this after drive_wave_pool /
    // drive_wave_local returned — every lane and extractor is done.
    unsafe { h.extract_tile_into(0, 0, ny, nx, 0, Boundary::Zero, &mut data) };
    Grid2D { ny, nx, data }
}

// ---------- 2D stencil fragment (one wave per pass) ----------

/// A 2D stencil lowered onto the wave driver: wave `p` is pass `p`,
/// block edges are the `r·T` halo-overlap neighborhood, and the two
/// grid buffers alternate roles per wave exactly as in the `DepTable`
/// engine (the symmetric neighbor rule discharges the WAR hazard).
pub(crate) struct Stencil2dFragment {
    artifact: Arc<str>,
    space: Space2D,
    /// Wave `w` reads `handles[w % 2]`, writes `handles[(w+1) % 2]`;
    /// `handles[0]` aliases the upstream output when piped.
    handles: [GridWriter2D; 2],
    passes: usize,
    t_fused: u64,
    dims: [usize; 3],
    reach: [usize; 3],
    /// Buffers owned by this fragment (the input grid unless piped,
    /// plus the alternate buffer).  Heap storage is stable behind
    /// struct moves, so the raw handles above stay valid.
    _grids: Vec<Grid2D>,
    _aux: Option<Grid2D>,
}

impl Stencil2dFragment {
    pub(crate) fn build(
        artifact: Arc<str>,
        m: &StencilMeta,
        input: StencilInput,
        aux: Option<Grid2D>,
        scalar: Option<Vec<f32>>,
        passes: usize,
        shards: usize,
    ) -> Stencil2dFragment {
        let (handles, ny, nx, grids) = double_buffer(input);
        // SAFETY: the aux grid is never written and outlives the drive
        // (owned by this fragment).
        let aux_handle = aux.as_ref().map(|a| unsafe { a.shared_view() });
        let space = Space2D::new(ny, nx, m, aux_handle, scalar).with_pool_shards(shards);
        let dims = space.lattice();
        let reach = space.reach();
        Stencil2dFragment {
            artifact,
            space,
            handles,
            passes,
            t_fused: m.t_fused,
            dims,
            reach,
            _grids: grids,
            _aux: aux,
        }
    }
}

impl WaveGraph for Stencil2dFragment {
    fn waves(&self) -> usize {
        self.passes
    }

    fn wave_len(&self, _w: usize) -> usize {
        self.space.nblocks()
    }

    fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
        if w == 0 {
            return;
        }
        visit_lattice_neighborhood(self.dims, self.reach, i, &mut |j| f(w - 1, j));
    }
}

impl WaveSpace for Stencil2dFragment {
    fn artifact(&self, _w: usize, _i: usize) -> Arc<str> {
        self.artifact.clone()
    }

    unsafe fn extract(&self, w: usize, i: usize) -> Vec<Tensor> {
        self.space.extract(self.handles[w % 2], i)
    }

    unsafe fn extract_sharded(&self, shard: usize, w: usize, i: usize) -> Vec<Tensor> {
        self.space.extract_on(shard, self.handles[w % 2], i)
    }

    unsafe fn write(&self, w: usize, i: usize, out: &[Tensor]) {
        self.space.write(self.handles[(w + 1) % 2], i, out[0].as_f32());
    }

    fn cell_updates(&self, _w: usize, i: usize) -> u64 {
        let (y0, x0) = self.space.origins[i];
        let h = self.space.block.min(self.space.ny - y0);
        let w_ = self.space.block.min(self.space.nx - x0);
        (h * w_) as u64 * self.t_fused
    }

    fn recycle(&self, _w: usize, _i: usize, inputs: Vec<Tensor>) {
        StencilSpace::recycle(&self.space, inputs);
    }

    fn recycle_sharded(&self, shard: usize, _w: usize, _i: usize, inputs: Vec<Tensor>) {
        self.space.recycle_on(shard, inputs);
    }

    fn pool_counters(&self) -> (u64, u64, u64, u64) {
        StencilSpace::pool_counters(&self.space)
    }

    fn pool_evictions(&self) -> u64 {
        StencilSpace::pool_evictions(&self.space)
    }

    fn wants_f32(&self, _w: usize, _i: usize) -> bool {
        true
    }

    unsafe fn write_f32(&self, w: usize, i: usize, out: &[f32]) {
        self.space.write(self.handles[(w + 1) % 2], i, out);
    }
}

impl Fragment for Stencil2dFragment {
    fn seam_in_rect(&self, i: usize) -> Option<Rect> {
        let (y0, x0) = self.space.origins[i];
        let h = self.space.halo as isize;
        Some(Rect::clipped(
            y0 as isize - h,
            (y0 + self.space.block) as isize + h,
            x0 as isize - h,
            (x0 + self.space.block) as isize + h,
            self.space.ny,
            self.space.nx,
        ))
    }

    fn seam_out(&self, rect: Rect, f: &mut dyn FnMut(usize, usize)) {
        if self.passes == 0 {
            return; // nothing ran; downstream reads the seeded buffer
        }
        for (idx, &(y0, x0)) in self.space.origins.iter().enumerate() {
            let y1 = (y0 + self.space.block).min(self.space.ny);
            let x1 = (x0 + self.space.block).min(self.space.nx);
            if rect.intersects(y0, y1, x0, x1) {
                f(self.passes - 1, idx);
            }
        }
    }

    fn out_grid(&self) -> Option<OutGrid> {
        Some(OutGrid {
            handle: self.handles[self.passes % 2],
            ny: self.space.ny,
            nx: self.space.nx,
        })
    }

    fn output(&self) -> WorkloadOutput {
        WorkloadOutput::Grid2D(copy_grid2d(
            self.handles[self.passes % 2],
            self.space.ny,
            self.space.nx,
        ))
    }
}

// ---------- 3D stencil fragment ----------

/// 3D counterpart of [`Stencil2dFragment`]; never pipes (no 3D seam),
/// but still shares a fused graph with its chain neighbors.
pub(crate) struct Stencil3dFragment {
    artifact: Arc<str>,
    space: Space3D,
    handles: [GridWriter3D; 2],
    passes: usize,
    t_fused: u64,
    dims: [usize; 3],
    reach: [usize; 3],
    grids: [Grid3D; 2],
    _aux: Option<Grid3D>,
}

impl Stencil3dFragment {
    pub(crate) fn build(
        artifact: Arc<str>,
        m: &StencilMeta,
        mut grid: Grid3D,
        aux: Option<Grid3D>,
        passes: usize,
        shards: usize,
    ) -> Stencil3dFragment {
        let (nz, ny, nx) = (grid.nz, grid.ny, grid.nx);
        // SAFETY: both grids move into `grids` below; heap storage is
        // stable and the drive quiesces before the fragment drops.
        let h0 = unsafe { grid.shared_writer() };
        let mut next = Grid3D::zeros(nz, ny, nx);
        let h1 = unsafe { next.shared_writer() };
        // SAFETY: the aux grid is never written.
        let aux_handle = aux.as_ref().map(|a| unsafe { a.shared_view() });
        let space = Space3D::new(nz, ny, nx, m, aux_handle).with_pool_shards(shards);
        let dims = space.lattice();
        let reach = space.reach();
        Stencil3dFragment {
            artifact,
            space,
            handles: [h0, h1],
            passes,
            t_fused: m.t_fused,
            dims,
            reach,
            grids: [grid, next],
            _aux: aux,
        }
    }
}

impl WaveGraph for Stencil3dFragment {
    fn waves(&self) -> usize {
        self.passes
    }

    fn wave_len(&self, _w: usize) -> usize {
        self.space.nblocks()
    }

    fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
        if w == 0 {
            return;
        }
        visit_lattice_neighborhood(self.dims, self.reach, i, &mut |j| f(w - 1, j));
    }
}

impl WaveSpace for Stencil3dFragment {
    fn artifact(&self, _w: usize, _i: usize) -> Arc<str> {
        self.artifact.clone()
    }

    unsafe fn extract(&self, w: usize, i: usize) -> Vec<Tensor> {
        self.space.extract(self.handles[w % 2], i)
    }

    unsafe fn extract_sharded(&self, shard: usize, w: usize, i: usize) -> Vec<Tensor> {
        self.space.extract_on(shard, self.handles[w % 2], i)
    }

    unsafe fn write(&self, w: usize, i: usize, out: &[Tensor]) {
        self.space.write(self.handles[(w + 1) % 2], i, out[0].as_f32());
    }

    fn cell_updates(&self, _w: usize, i: usize) -> u64 {
        let (z0, y0, x0) = self.space.origins[i];
        let d = self.space.block.min(self.space.nz - z0);
        let h = self.space.block.min(self.space.ny - y0);
        let w_ = self.space.block.min(self.space.nx - x0);
        (d * h * w_) as u64 * self.t_fused
    }

    fn recycle(&self, _w: usize, _i: usize, inputs: Vec<Tensor>) {
        StencilSpace::recycle(&self.space, inputs);
    }

    fn recycle_sharded(&self, shard: usize, _w: usize, _i: usize, inputs: Vec<Tensor>) {
        self.space.recycle_on(shard, inputs);
    }

    fn pool_counters(&self) -> (u64, u64, u64, u64) {
        StencilSpace::pool_counters(&self.space)
    }

    fn pool_evictions(&self) -> u64 {
        StencilSpace::pool_evictions(&self.space)
    }

    fn wants_f32(&self, _w: usize, _i: usize) -> bool {
        true
    }

    unsafe fn write_f32(&self, w: usize, i: usize, out: &[f32]) {
        self.space.write(self.handles[(w + 1) % 2], i, out);
    }
}

impl Fragment for Stencil3dFragment {
    fn output(&self) -> WorkloadOutput {
        WorkloadOutput::Grid3D(self.grids[self.passes % 2].clone())
    }
}

// ---------- app fragments (spaces reused from coordinator::apps) ----------

/// Delegate the graph + execution traits to the wrapped app space.
macro_rules! delegate_wave_impls {
    ($ty:ty) => {
        impl WaveGraph for $ty {
            fn waves(&self) -> usize {
                self.space.waves()
            }
            fn wave_len(&self, w: usize) -> usize {
                self.space.wave_len(w)
            }
            fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
                self.space.visit_preds(w, i, f)
            }
        }
        impl WaveSpace for $ty {
            fn artifact(&self, w: usize, i: usize) -> Arc<str> {
                self.space.artifact(w, i)
            }
            unsafe fn extract(&self, w: usize, i: usize) -> Vec<Tensor> {
                self.space.extract(w, i)
            }
            unsafe fn extract_sharded(&self, shard: usize, w: usize, i: usize) -> Vec<Tensor> {
                self.space.extract_sharded(shard, w, i)
            }
            unsafe fn write(&self, w: usize, i: usize, out: &[Tensor]) {
                self.space.write(w, i, out)
            }
            fn cell_updates(&self, w: usize, i: usize) -> u64 {
                self.space.cell_updates(w, i)
            }
            fn recycle(&self, w: usize, i: usize, inputs: Vec<Tensor>) {
                self.space.recycle(w, i, inputs)
            }
            fn recycle_sharded(&self, shard: usize, w: usize, i: usize, inputs: Vec<Tensor>) {
                self.space.recycle_sharded(shard, w, i, inputs)
            }
            fn pool_counters(&self) -> (u64, u64, u64, u64) {
                self.space.pool_counters()
            }
            fn pool_evictions(&self) -> u64 {
                self.space.pool_evictions()
            }
            fn affinity(&self, w: usize, i: usize) -> u64 {
                self.space.affinity(w, i)
            }
        }
    };
}

/// Pathfinder, owning its cost-row double buffer.
pub(crate) struct PathfinderFragment {
    space: PathfinderSpace,
    bufs: [Vec<i32>; 2],
}

delegate_wave_impls!(PathfinderFragment);

impl Fragment for PathfinderFragment {
    fn output(&self) -> WorkloadOutput {
        WorkloadOutput::Row(self.bufs[self.space.nwaves % 2].clone())
    }
}

/// Needleman-Wunsch, owning the flattened score matrix.
pub(crate) struct NwFragment {
    space: NwSpace,
    score: Vec<i32>,
    stride: usize,
}

delegate_wave_impls!(NwFragment);

impl Fragment for NwFragment {
    fn output(&self) -> WorkloadOutput {
        WorkloadOutput::ScoreMatrix(
            self.score.chunks(self.stride).map(|r| r.to_vec()).collect(),
        )
    }
}

/// SRAD, owning its image double buffer (first half absent when
/// piped).  Seam rules: first-wave reads are the reduction tiles'
/// rects; final writers are the last stencil wave's blocks.
pub(crate) struct SradFragment {
    space: SradSpace,
    _grids: Vec<Grid2D>,
}

delegate_wave_impls!(SradFragment);

impl Fragment for SradFragment {
    fn seam_in_rect(&self, i: usize) -> Option<Rect> {
        let (y0, x0) = self.space.rorigins[i];
        Some(Rect::clipped(
            y0 as isize,
            (y0 + self.space.rblock) as isize,
            x0 as isize,
            (x0 + self.space.rblock) as isize,
            self.space.ny,
            self.space.nx,
        ))
    }

    fn seam_out(&self, rect: Rect, f: &mut dyn FnMut(usize, usize)) {
        if self.space.steps == 0 {
            return;
        }
        let last = 2 * self.space.steps - 1; // final stencil wave
        for (idx, &(y0, x0)) in self.space.sorigins.iter().enumerate() {
            let y1 = (y0 + self.space.sblock).min(self.space.ny);
            let x1 = (x0 + self.space.sblock).min(self.space.nx);
            if rect.intersects(y0, y1, x0, x1) {
                f(last, idx);
            }
        }
    }

    fn out_grid(&self) -> Option<OutGrid> {
        Some(OutGrid {
            handle: self.space.bufs[self.space.steps % 2],
            ny: self.space.ny,
            nx: self.space.nx,
        })
    }

    fn output(&self) -> WorkloadOutput {
        WorkloadOutput::Grid2D(copy_grid2d(
            self.space.bufs[self.space.steps % 2],
            self.space.ny,
            self.space.nx,
        ))
    }
}

/// Blocked LUD, owning the flattened matrix it factorizes in place.
pub(crate) struct LudFragment {
    space: LudSpace,
    m: Vec<f32>,
    n: usize,
}

delegate_wave_impls!(LudFragment);

impl Fragment for LudFragment {
    fn output(&self) -> WorkloadOutput {
        WorkloadOutput::Matrix(self.m.chunks(self.n).map(|r| r.to_vec()).collect())
    }
}

// ---------------------------------------------------------------------------
// Lowering: Workload -> Fragment
// ---------------------------------------------------------------------------

impl Workload {
    /// Lower this descriptor to a wave fragment, appending the
    /// artifact names it executes to `artifacts` (for lane warmup).
    /// `shards` sizes the fragment's tile-pool sharding (one free list
    /// per lane; pass 1 for an unsharded pool).
    fn lower(
        self,
        reg: &Registry,
        upstream: Option<&dyn Fragment>,
        artifacts: &mut Vec<String>,
        shards: usize,
    ) -> crate::Result<Box<dyn Fragment>> {
        match self.0 {
            WorkloadKind::Stencil2d { artifact, grid, aux, steps } => {
                let spec = reg
                    .get(&artifact)
                    .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
                    .clone();
                let m = stencil_meta(&spec, aux.is_some(), steps)?;
                let passes = (steps / m.t_fused) as usize;
                artifacts.push(artifact.clone());
                let input = resolve_grid_input(grid, upstream)?;
                Ok(Box::new(Stencil2dFragment::build(
                    Arc::from(artifact.as_str()),
                    &m,
                    input,
                    aux,
                    None,
                    passes,
                    shards,
                )))
            }
            WorkloadKind::Stencil2dScalar { artifact, grid, scalar } => {
                let spec = reg
                    .get(&artifact)
                    .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
                    .clone();
                let m = scalar_stencil_meta(&spec)?;
                artifacts.push(artifact.clone());
                let input = resolve_grid_input(grid, upstream)?;
                Ok(Box::new(Stencil2dFragment::build(
                    Arc::from(artifact.as_str()),
                    &m,
                    input,
                    None,
                    Some(vec![scalar; m.t_fused as usize]),
                    1,
                    shards,
                )))
            }
            WorkloadKind::Stencil3d { artifact, grid, aux, steps } => {
                let spec = reg
                    .get(&artifact)
                    .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
                    .clone();
                let m = stencil_meta(&spec, aux.is_some(), steps)?;
                let passes = (steps / m.t_fused) as usize;
                artifacts.push(artifact.clone());
                Ok(Box::new(Stencil3dFragment::build(
                    Arc::from(artifact.as_str()),
                    &m,
                    grid,
                    aux,
                    passes,
                    shards,
                )))
            }
            WorkloadKind::Pathfinder { wall } => {
                let spec = reg
                    .get("pathfinder")
                    .ok_or_else(|| anyhow!("missing pathfinder artifact"))?
                    .clone();
                let width = spec.meta_u64("width")? as usize;
                let fused = spec.meta_u64("fused_rows")? as usize;
                let rows = wall.len();
                ensure!(
                    rows >= 1 && !wall[0].is_empty(),
                    "pathfinder: wall must have at least one non-empty row"
                );
                let cols = wall[0].len();
                if (rows - 1) % fused != 0 {
                    bail!("pathfinder: rows-1 = {} not a multiple of fused {fused}", rows - 1);
                }
                artifacts.push("pathfinder".into());
                let nwaves = (rows - 1) / fused;
                let mut flat = Vec::with_capacity((rows - 1) * cols);
                for row in &wall[1..] {
                    flat.extend_from_slice(row);
                }
                let mut bufs = [wall[0].clone(), vec![0i32; cols]];
                let [b0, b1] = &mut bufs;
                let space = PathfinderSpace {
                    artifact: Arc::from("pathfinder"),
                    wall: flat,
                    cols,
                    width,
                    fused,
                    padded: width + 2 * fused,
                    nwaves,
                    nblocks: cols.div_ceil(width),
                    reach: fused.div_ceil(width),
                    // SAFETY: `bufs` moves into the fragment below; the
                    // heap rows never move, and the wave driver
                    // quiesces every lane before the fragment drops.
                    rows_bufs: [RawSlice::new(b0), RawSlice::new(b1)],
                };
                Ok(Box::new(PathfinderFragment { space, bufs }))
            }
            WorkloadKind::Nw { reference, penalty } => {
                let spec = reg
                    .get("nw")
                    .ok_or_else(|| anyhow!("missing nw artifact"))?
                    .clone();
                let b = spec.meta_u64("block")? as usize;
                let baked = spec.meta_u64("penalty")? as i32;
                if penalty != baked {
                    bail!("nw: penalty {penalty} != artifact's baked {baked}");
                }
                ensure!(!reference.is_empty(), "nw: empty reference matrix");
                let n = reference.len() - 1;
                if n == 0 || n % b != 0 {
                    bail!("nw: interior size {n} not a (non-zero) multiple of block {b}");
                }
                artifacts.push("nw".into());
                let stride = n + 1;
                let mut refm = Vec::with_capacity(stride * stride);
                for row in &reference {
                    refm.extend_from_slice(row);
                }
                let mut score = vec![0i32; stride * stride];
                for j in 0..=n {
                    score[j] = -(j as i32) * penalty;
                }
                for i in 0..=n {
                    score[i * stride] = -(i as i32) * penalty;
                }
                let space = NwSpace {
                    artifact: Arc::from("nw"),
                    nb: n / b,
                    b,
                    stride,
                    refm,
                    // SAFETY: `score` moves into the fragment; heap
                    // stable, driver quiesces before drop.
                    score: RawSlice::new(&mut score),
                };
                Ok(Box::new(NwFragment { space, score, stride }))
            }
            WorkloadKind::Srad { img, steps } => {
                let red_spec = reg
                    .get("sum_sumsq")
                    .ok_or_else(|| anyhow!("missing sum_sumsq artifact"))?
                    .clone();
                let rblock = red_spec.meta_u64("block")? as usize;
                let sten_spec = reg
                    .get("srad")
                    .ok_or_else(|| anyhow!("missing srad artifact"))?
                    .clone();
                let sblock = sten_spec.meta_u64("block")? as usize;
                let halo = sten_spec.meta_u64("halo")? as usize;
                let t_fused = sten_spec.meta_u64("steps")? as usize;
                artifacts.push("sum_sumsq".into());
                artifacts.push("srad".into());
                let input = resolve_grid_input(img, upstream)?;
                let steps = steps as usize;
                let (bufs, ny, nx, grids) = double_buffer(input);
                let rorigins = block_origins_2d(ny, nx, rblock);
                let nrtiles = rorigins.len();
                let space = SradSpace {
                    red_artifact: Arc::from("sum_sumsq"),
                    sten_artifact: Arc::from("srad"),
                    steps,
                    ny,
                    nx,
                    cells: (ny * nx) as f64,
                    rblock,
                    rorigins,
                    sblock,
                    halo,
                    tile: sblock + 2 * halo,
                    t_fused,
                    boundary: boundary_of(&sten_spec),
                    sorigins: block_origins_2d(ny, nx, sblock),
                    snbx: nx.div_ceil(sblock),
                    bufs,
                    partials: (0..steps * nrtiles)
                        .map(|_| SyncCell(UnsafeCell::new((0.0, 0.0))))
                        .collect(),
                    pools: TensorPools::with_shards(shards),
                };
                Ok(Box::new(SradFragment { space, _grids: grids }))
            }
            WorkloadKind::Lud { a } => {
                let spec = reg
                    .get("lud_internal")
                    .ok_or_else(|| anyhow!("missing lud artifacts"))?
                    .clone();
                let b = spec.meta_u64("block")? as usize;
                let n = a.len();
                if n == 0 || n % b != 0 {
                    bail!("lud: size {n} not a (non-zero) multiple of block {b}");
                }
                for name in ["lud_diagonal", "lud_perimeter_row", "lud_perimeter_col", "lud_internal"] {
                    artifacts.push(name.into());
                }
                let mut m = Vec::with_capacity(n * n);
                for row in &a {
                    m.extend_from_slice(row);
                }
                let space = LudSpace {
                    diagonal: Arc::from("lud_diagonal"),
                    perim_row: Arc::from("lud_perimeter_row"),
                    perim_col: Arc::from("lud_perimeter_col"),
                    internal: Arc::from("lud_internal"),
                    nb: n / b,
                    b,
                    n,
                    // SAFETY: `m` moves into the fragment; heap stable,
                    // driver quiesces before drop.
                    m: RawSlice::new(&mut m),
                };
                Ok(Box::new(LudFragment { space, m, n }))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// FusedSpace: the spliced graph a Chain runs as
// ---------------------------------------------------------------------------

/// Several fragments spliced into one [`WaveGraph`]/[`WaveSpace`]:
/// fragment `k`'s waves occupy the global range
/// `starts[k] .. starts[k] + frags[k].waves()`, its own edges shift by
/// `starts[k]`, and piped stages gain precomputed **seam edges** from
/// their first-wave blocks to the upstream fragment's final writers.
pub(crate) struct FusedSpace {
    frags: Vec<Box<dyn Fragment>>,
    starts: Vec<usize>,
    total_waves: usize,
    /// `seams[k][i]`: extra global (wave, index) predecessors of
    /// fragment `k`'s first-wave block `i` (empty vec when stage `k`
    /// is not piped).
    seams: Vec<Vec<Vec<(usize, usize)>>>,
    /// `piped[k]`: stage `k` consumes stage `k-1`'s output in place.
    piped: Vec<bool>,
}

impl FusedSpace {
    /// Splice fragments into one graph, wiring the cross-app seam
    /// edges of every piped stage (`piped[0]` must be false — the
    /// lowering rejects `GridInput::Upstream` on a chain head).
    ///
    /// Seam edges target the **effective producer**: a zero-wave piped
    /// stage writes nothing and merely forwards its upstream's buffer
    /// through `out_grid`, so the splice walks past such stages until
    /// it finds the fragment whose blocks actually wrote the shared
    /// buffer — otherwise a downstream stage would race the real
    /// writer under [`PassMode::Pipelined`].
    pub(crate) fn splice(frags: Vec<Box<dyn Fragment>>, piped: Vec<bool>) -> FusedSpace {
        debug_assert_eq!(frags.len(), piped.len());
        debug_assert!(!piped.first().copied().unwrap_or(false));
        let mut starts = Vec::with_capacity(frags.len());
        let mut total = 0usize;
        for f in &frags {
            starts.push(total);
            total += f.waves();
        }
        let mut seams: Vec<Vec<Vec<(usize, usize)>>> = Vec::with_capacity(frags.len());
        for (k, frag) in frags.iter().enumerate() {
            if !piped[k] || frag.waves() == 0 {
                seams.push(Vec::new());
                continue;
            }
            // Walk past zero-wave piped forwarders to the fragment
            // that last wrote (or seeded) the buffer this stage reads.
            let mut p = k - 1;
            while p > 0 && piped[p] && frags[p].waves() == 0 {
                p -= 1;
            }
            let up = &frags[p];
            let up_start = starts[p];
            let mut per_block = Vec::with_capacity(frag.wave_len(0));
            for i in 0..frag.wave_len(0) {
                let mut preds = Vec::new();
                if let Some(rect) = frag.seam_in_rect(i) {
                    up.seam_out(rect, &mut |w, j| preds.push((up_start + w, j)));
                }
                per_block.push(preds);
            }
            seams.push(per_block);
        }
        FusedSpace { frags, starts, total_waves: total, seams, piped }
    }

    /// Map a global wave to (fragment, local wave).
    fn locate(&self, w: usize) -> (usize, usize) {
        let k = self.starts.partition_point(|&s| s <= w) - 1;
        (k, w - self.starts[k])
    }

    /// One output per stage, in chain order; stages whose grid was
    /// consumed in place by the next stage report
    /// [`WorkloadOutput::Piped`].  Only sound after the drive has
    /// quiesced.
    pub(crate) fn outputs(&self) -> Vec<WorkloadOutput> {
        (0..self.frags.len())
            .map(|k| {
                if self.piped.get(k + 1).copied().unwrap_or(false) {
                    WorkloadOutput::Piped
                } else {
                    self.frags[k].output()
                }
            })
            .collect()
    }

    /// Map the drive's per-block fault / cancellation / replay /
    /// unfinished record onto per-stage statuses: a stage owning a
    /// terminally failed block is `Failed` (first fault wins), a stage
    /// the deadline cut off mid-flight is `DeadlineExceeded`, a stage
    /// whose only casualties were cancelled cone members is
    /// `Cancelled`, a stage whose faulted blocks were all healed by
    /// cone replay is `Replayed` (worst replay-round count wins),
    /// everything else is `Ok` — including stages whose blocks were
    /// merely re-driven as healthy cone members.  Precedence:
    /// `Failed > DeadlineExceeded > Cancelled > Replayed > Ok`.
    pub(crate) fn statuses(
        &self,
        faults: &[BlockFault],
        cancelled: &[(usize, usize)],
        replays: &[ConeReplay],
        unfinished: &[(usize, usize)],
    ) -> Vec<WorkloadStatus> {
        let mut st = vec![WorkloadStatus::Ok; self.frags.len()];
        for r in replays {
            let (k, _) = self.locate(r.wave);
            let rounds = match st[k] {
                WorkloadStatus::Ok => r.rounds,
                WorkloadStatus::Replayed { attempts } => attempts.max(r.rounds),
                _ => continue,
            };
            st[k] = WorkloadStatus::Replayed { attempts: rounds };
        }
        for &(w, _) in cancelled {
            let (k, _) = self.locate(w);
            if st[k].completed() {
                st[k] = WorkloadStatus::Cancelled;
            }
        }
        for &(w, _) in unfinished {
            let (k, _) = self.locate(w);
            if !matches!(st[k], WorkloadStatus::Failed(_)) {
                st[k] = WorkloadStatus::DeadlineExceeded;
            }
        }
        for f in faults {
            let (k, _) = self.locate(f.wave);
            if !matches!(st[k], WorkloadStatus::Failed(_)) {
                st[k] = WorkloadStatus::Failed(FaultReport {
                    kind: f.kind,
                    message: f.message.clone(),
                    attempts: f.attempts,
                    wave: f.wave,
                    block: f.index,
                });
            }
        }
        st
    }
}

impl WaveGraph for FusedSpace {
    fn waves(&self) -> usize {
        self.total_waves
    }

    fn wave_len(&self, w: usize) -> usize {
        let (k, lw) = self.locate(w);
        self.frags[k].wave_len(lw)
    }

    fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
        let (k, lw) = self.locate(w);
        let start = self.starts[k];
        self.frags[k].visit_preds(lw, i, &mut |v, j| f(v + start, j));
        if lw == 0 {
            if let Some(per_block) = self.seams[k].get(i) {
                for &(v, j) in per_block {
                    f(v, j);
                }
            }
        }
    }
}

impl WaveSpace for FusedSpace {
    fn artifact(&self, w: usize, i: usize) -> Arc<str> {
        let (k, lw) = self.locate(w);
        self.frags[k].artifact(lw, i)
    }

    unsafe fn extract(&self, w: usize, i: usize) -> Vec<Tensor> {
        let (k, lw) = self.locate(w);
        self.frags[k].extract(lw, i)
    }

    unsafe fn extract_sharded(&self, shard: usize, w: usize, i: usize) -> Vec<Tensor> {
        let (k, lw) = self.locate(w);
        self.frags[k].extract_sharded(shard, lw, i)
    }

    unsafe fn write(&self, w: usize, i: usize, out: &[Tensor]) {
        let (k, lw) = self.locate(w);
        self.frags[k].write(lw, i, out)
    }

    fn cell_updates(&self, w: usize, i: usize) -> u64 {
        let (k, lw) = self.locate(w);
        self.frags[k].cell_updates(lw, i)
    }

    fn recycle(&self, w: usize, i: usize, inputs: Vec<Tensor>) {
        let (k, lw) = self.locate(w);
        self.frags[k].recycle(lw, i, inputs)
    }

    fn recycle_sharded(&self, shard: usize, w: usize, i: usize, inputs: Vec<Tensor>) {
        let (k, lw) = self.locate(w);
        self.frags[k].recycle_sharded(shard, lw, i, inputs)
    }

    fn pool_counters(&self) -> (u64, u64, u64, u64) {
        let mut t = (0u64, 0u64, 0u64, 0u64);
        for f in &self.frags {
            let c = f.pool_counters();
            t.0 += c.0;
            t.1 += c.1;
            t.2 += c.2;
            t.3 += c.3;
        }
        t
    }

    fn pool_evictions(&self) -> u64 {
        self.frags.iter().map(|f| f.pool_evictions()).sum()
    }

    fn affinity(&self, w: usize, i: usize) -> u64 {
        // Delegate on the fragment's *local* wave: the default key is
        // the block index, which stays stable across a Chain's seam
        // (splicing renumbers waves, never block indices), so a piped
        // block lands on the same lane that extracted its upstream
        // producer tiles.
        let (k, lw) = self.locate(w);
        self.frags[k].affinity(lw, i)
    }

    fn wants_f32(&self, w: usize, i: usize) -> bool {
        let (k, lw) = self.locate(w);
        self.frags[k].wants_f32(lw, i)
    }

    unsafe fn write_f32(&self, w: usize, i: usize, out: &[f32]) {
        let (k, lw) = self.locate(w);
        self.frags[k].write_f32(lw, i, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::passdriver::drive_wave_local;

    fn blur_meta() -> StencilMeta {
        StencilMeta {
            block: 4,
            halo: 1,
            tile: 6,
            t_fused: 1,
            boundary: Boundary::Zero,
        }
    }

    fn blur_frag(input: StencilInput, passes: usize) -> Stencil2dFragment {
        Stencil2dFragment::build(Arc::from("blur"), &blur_meta(), input, None, None, passes, 1)
    }

    /// T=1 five-point average over a halo'd 6x6 tile -> 4x4 interior
    /// (same kernel as the passdriver scheduling tests).
    fn blur_kernel(t: &[f32]) -> Vec<f32> {
        let (tile, halo, block) = (6usize, 1usize, 4usize);
        let mut out = vec![0.0f32; block * block];
        for by in 0..block {
            for bx in 0..block {
                let y = by + halo;
                let x = bx + halo;
                out[by * block + bx] = 0.2
                    * (t[y * tile + x]
                        + t[(y - 1) * tile + x]
                        + t[(y + 1) * tile + x]
                        + t[y * tile + x - 1]
                        + t[y * tile + x + 1]);
            }
        }
        out
    }

    fn blur_reference(mut g: Grid2D, passes: usize) -> Grid2D {
        for _ in 0..passes {
            let mut next = Grid2D::zeros(g.ny, g.nx);
            for y in 0..g.ny as isize {
                for x in 0..g.nx as isize {
                    let r = |yy: isize, xx: isize| g.read(yy, xx, Boundary::Zero);
                    next.data[(y * g.nx as isize + x) as usize] = 0.2
                        * (r(y, x) + r(y - 1, x) + r(y + 1, x) + r(y, x - 1) + r(y, x + 1));
                }
            }
            g = next;
        }
        g
    }

    fn rand_grid(ny: usize, nx: usize, seed: u64) -> Grid2D {
        let mut rng = crate::testutil::Rng::new(seed);
        Grid2D { ny, nx, data: rng.vec_f32(ny * nx, 0.0, 1.0) }
    }

    /// Structural contract of any fused graph: every edge points to a
    /// strictly earlier wave and an in-range block.
    fn check_fused_graph(g: &FusedSpace) {
        for w in 0..g.waves() {
            for i in 0..g.wave_len(w) {
                g.visit_preds(w, i, &mut |v, j| {
                    assert!(v < w, "pred wave {v} not before ({w},{i})");
                    assert!(j < g.wave_len(v), "pred ({v},{j}) out of range");
                });
            }
        }
    }

    #[test]
    fn splice_seam_edges_target_upstream_final_wave() {
        // 8x8 grid, 4-blocks -> 2x2 lattice.  A runs 2 passes, B is
        // piped onto A's output: every first-wave block of B reads a
        // halo'd 6x6 rect that overlaps all four A interiors, so its
        // seam preds are exactly A's final wave (global wave 1).
        let a = blur_frag(StencilInput::Own(rand_grid(8, 8, 1)), 2);
        let out = a.out_grid().unwrap();
        let b = blur_frag(StencilInput::Piped(out), 3);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, true]);

        assert_eq!(fused.waves(), 5);
        check_fused_graph(&fused);
        // B's first wave is global wave 2; its blocks have no
        // intra-fragment preds (local wave 0), only seam edges.
        for i in 0..4 {
            let mut preds = Vec::new();
            fused.visit_preds(2, i, &mut |v, j| preds.push((v, j)));
            preds.sort_unstable();
            assert_eq!(
                preds,
                vec![(1, 0), (1, 1), (1, 2), (1, 3)],
                "seam preds of B block {i} must be A's final wave"
            );
        }
        // B's second wave (global 3) has only intra-B halo edges,
        // shifted to global numbering.
        let mut preds = Vec::new();
        fused.visit_preds(3, 0, &mut |v, j| preds.push((v, j)));
        assert!(preds.iter().all(|&(v, _)| v == 2), "intra-B edges shift to global waves");
    }

    #[test]
    fn splice_seam_clips_to_overlapping_tail_blocks_only() {
        // 16x16 grid, 4-blocks -> 4x4 lattice, halo 1: B's corner
        // block (0,0) reads rows/cols -1..5, overlapping only A's
        // interiors (0,0), (0,1), (1,0), (1,1).
        let a = blur_frag(StencilInput::Own(rand_grid(16, 16, 2)), 1);
        let out = a.out_grid().unwrap();
        let b = blur_frag(StencilInput::Piped(out), 1);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, true]);
        check_fused_graph(&fused);
        let mut preds = Vec::new();
        fused.visit_preds(1, 0, &mut |v, j| preds.push((v, j)));
        preds.sort_unstable();
        assert_eq!(preds, vec![(0, 0), (0, 1), (0, 4), (0, 5)]);
        // An interior block (lattice (1,1)) overlaps a 3x3 patch.
        let mut preds = Vec::new();
        fused.visit_preds(1, 5, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds.len(), 9);
    }

    #[test]
    fn splice_without_piping_adds_no_seam_edges() {
        let a = blur_frag(StencilInput::Own(rand_grid(8, 8, 3)), 2);
        let b = blur_frag(StencilInput::Own(rand_grid(8, 8, 4)), 2);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, false]);
        check_fused_graph(&fused);
        // B's first wave (global 2) has no predecessors at all: it
        // seeds the ready frontier alongside A's wave 0.
        for i in 0..4 {
            let mut preds = Vec::new();
            fused.visit_preds(2, i, &mut |v, j| preds.push((v, j)));
            assert!(preds.is_empty(), "independent stage must seed immediately");
        }
    }

    #[test]
    fn fused_piped_chain_matches_sequential_reference_bitwise() {
        // A (2 passes) feeding B (3 passes) through one spliced graph
        // must equal 5 sequential blur passes, bitwise — the seam
        // edges hand B exactly A's final buffer contents.
        let init = rand_grid(12, 8, 7);
        let want = blur_reference(init.clone(), 5);

        let a = blur_frag(StencilInput::Own(init), 2);
        let out = a.out_grid().unwrap();
        let b = blur_frag(StencilInput::Piped(out), 3);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, true]);
        let stats = drive_wave_local(
            |_w, _i, inputs| {
                Ok(vec![Tensor::F32(blur_kernel(inputs[0].as_f32()), vec![4, 4])])
            },
            &fused,
            PassMode::Pipelined,
            4,
        )
        .unwrap();
        assert_eq!(stats.blocks as usize, 5 * 6, "2+3 passes of 3x2 blocks");

        let outputs = fused.outputs();
        assert_eq!(outputs.len(), 2);
        assert_eq!(outputs[0], WorkloadOutput::Piped, "consumed stage reports Piped");
        let got = outputs[1].clone().into_grid2d().expect("final stage yields a grid");
        assert_eq!(got.data, want.data, "fused chain != sequential reference");
    }

    #[test]
    fn fused_piped_chain_barrier_mode_matches_too() {
        let init = rand_grid(8, 8, 9);
        let want = blur_reference(init.clone(), 4);
        let a = blur_frag(StencilInput::Own(init), 2);
        let out = a.out_grid().unwrap();
        let b = blur_frag(StencilInput::Piped(out), 2);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, true]);
        let stats = drive_wave_local(
            |_w, _i, inputs| {
                Ok(vec![Tensor::F32(blur_kernel(inputs[0].as_f32()), vec![4, 4])])
            },
            &fused,
            PassMode::Barrier,
            4,
        )
        .unwrap();
        assert!(stats.pipeline_depth_max <= 1, "barrier stays wave-serial");
        let got = fused.outputs()[1].clone().into_grid2d().unwrap();
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn fused_independent_chain_overlaps_across_the_seam() {
        // No seam edges: B's first wave seeds immediately, so even the
        // sequential fallback dispatches B while A's later waves are
        // incomplete — pipeline depth must exceed 1 across the seam.
        let a = blur_frag(StencilInput::Own(rand_grid(8, 8, 11)), 2);
        let b = blur_frag(StencilInput::Own(rand_grid(8, 8, 12)), 2);
        let want_a = blur_reference(rand_grid(8, 8, 11), 2);
        let want_b = blur_reference(rand_grid(8, 8, 12), 2);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, false]);
        let stats = drive_wave_local(
            |_w, _i, inputs| {
                Ok(vec![Tensor::F32(blur_kernel(inputs[0].as_f32()), vec![4, 4])])
            },
            &fused,
            PassMode::Pipelined,
            4,
        )
        .unwrap();
        assert!(
            stats.pipeline_depth_max > 1,
            "independent stage must overlap the upstream: depth {} <= 1",
            stats.pipeline_depth_max
        );
        let outputs = fused.outputs();
        assert_eq!(outputs[0].clone().into_grid2d().unwrap().data, want_a.data);
        assert_eq!(outputs[1].clone().into_grid2d().unwrap().data, want_b.data);
    }

    #[test]
    fn fused_zero_pass_upstream_hands_its_input_through() {
        // A 0-pass upstream writes nothing: B splices onto the seeded
        // input buffer with no seam edges, reading A's initial grid.
        let init = rand_grid(8, 8, 13);
        let want = blur_reference(init.clone(), 2);
        let a = blur_frag(StencilInput::Own(init), 0);
        let out = a.out_grid().unwrap();
        let b = blur_frag(StencilInput::Piped(out), 2);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, true]);
        assert_eq!(fused.waves(), 2);
        check_fused_graph(&fused);
        let _ = drive_wave_local(
            |_w, _i, inputs| {
                Ok(vec![Tensor::F32(blur_kernel(inputs[0].as_f32()), vec![4, 4])])
            },
            &fused,
            PassMode::Pipelined,
            4,
        )
        .unwrap();
        let got = fused.outputs()[1].clone().into_grid2d().unwrap();
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn splice_walks_past_zero_wave_piped_forwarders() {
        // A (1 pass) -> B (0 passes, piped) -> C (2 passes, piped):
        // B writes nothing and forwards A's buffer, so C's seam edges
        // must target A's final wave — not vanish (which would let C
        // race A's writers under the pipelined schedule).
        let init = rand_grid(8, 8, 17);
        let want = blur_reference(init.clone(), 3);
        let a = blur_frag(StencilInput::Own(init), 1);
        let b = blur_frag(StencilInput::Piped(a.out_grid().unwrap()), 0);
        let c = blur_frag(StencilInput::Piped(b.out_grid().unwrap()), 2);
        let fused = FusedSpace::splice(
            vec![Box::new(a), Box::new(b), Box::new(c)],
            vec![false, true, true],
        );
        assert_eq!(fused.waves(), 3);
        check_fused_graph(&fused);
        // C's first wave is global wave 1; every block must depend on
        // A's wave 0 (all four blocks overlap at this geometry).
        for i in 0..4 {
            let mut preds = Vec::new();
            fused.visit_preds(1, i, &mut |v, j| preds.push((v, j)));
            preds.sort_unstable();
            assert_eq!(
                preds,
                vec![(0, 0), (0, 1), (0, 2), (0, 3)],
                "C block {i} must be seam-ordered behind A's writers"
            );
        }
        let _ = drive_wave_local(
            |_w, _i, inputs| {
                Ok(vec![Tensor::F32(blur_kernel(inputs[0].as_f32()), vec![4, 4])])
            },
            &fused,
            PassMode::Pipelined,
            4,
        )
        .unwrap();
        let outputs = fused.outputs();
        assert_eq!(outputs[0], WorkloadOutput::Piped);
        assert_eq!(outputs[1], WorkloadOutput::Piped);
        let got = outputs[2].clone().into_grid2d().unwrap();
        assert_eq!(got.data, want.data, "forwarded chain != 3 sequential passes");
    }

    #[test]
    fn srad_fragment_seam_rects_and_writers() {
        // Build a graph-only SradSpace (handles never dereferenced)
        // and check the seam geometry: in-rects are reduction tiles,
        // out-writers are final-stencil-wave blocks overlapping.
        let (ny, nx, rblock, sblock, steps) = (64usize, 48usize, 16usize, 32usize, 2usize);
        let rorigins = block_origins_2d(ny, nx, rblock);
        let nrtiles = rorigins.len();
        let mut dummy = Grid2D::zeros(1, 1);
        // SAFETY: graph-only space under test — the handle is never
        // dereferenced.
        let h = unsafe { dummy.shared_writer() };
        let space = SradSpace {
            red_artifact: Arc::from("sum_sumsq"),
            sten_artifact: Arc::from("srad"),
            steps,
            ny,
            nx,
            cells: (ny * nx) as f64,
            rblock,
            rorigins,
            sblock,
            halo: 2,
            tile: sblock + 4,
            t_fused: 1,
            boundary: Boundary::Clamp,
            sorigins: block_origins_2d(ny, nx, sblock),
            snbx: nx.div_ceil(sblock),
            bufs: [h, h],
            partials: (0..steps * nrtiles)
                .map(|_| SyncCell(UnsafeCell::new((0.0, 0.0))))
                .collect(),
            pools: TensorPools::default(),
        };
        let frag = SradFragment { space, _grids: vec![dummy] };
        // tile 4 on the 4x3 tile lattice has origin (16, 16): inside
        // stencil block (0, 0) only.
        assert_eq!(frag.space.rorigins[4], (16, 16));
        assert_eq!(
            frag.seam_in_rect(4),
            Some(Rect { y0: 16, y1: 32, x0: 16, x1: 32 })
        );
        let mut writers = Vec::new();
        frag.seam_out(Rect { y0: 16, y1: 32, x0: 16, x1: 32 }, &mut |w, j| {
            writers.push((w, j))
        });
        assert_eq!(writers, vec![(3, 0)], "final stencil wave is 2*steps-1 = 3");
        // A rect straddling all four stencil blocks.
        writers.clear();
        frag.seam_out(Rect { y0: 30, y1: 34, x0: 30, x1: 34 }, &mut |w, j| {
            writers.push((w, j))
        });
        assert_eq!(writers, vec![(3, 0), (3, 1), (3, 2), (3, 3)]);
    }

    #[test]
    fn chain_combinator_orders_stages() {
        let c = Workload::nw(vec![vec![0; 2]; 2], 10)
            .then(Workload::lud(vec![vec![0.0; 2]; 2]))
            .then(Workload::pathfinder(vec![vec![0; 2]; 2]));
        assert_eq!(c.len(), 3);
        assert!(!c.is_empty());
        let single: Chain = Workload::lud(vec![vec![0.0; 2]; 2]).into();
        assert_eq!(single.len(), 1);
    }

    #[test]
    fn upstream_without_a_producer_is_rejected() {
        assert!(resolve_grid_input(GridInput::Upstream, None).is_err());
        // And through a fragment that produces no grid:
        let nw = NwFragment {
            space: NwSpace {
                artifact: Arc::from("nw"),
                nb: 1,
                b: 2,
                stride: 3,
                refm: vec![0; 9],
                score: RawSlice::new(&mut []),
            },
            score: vec![0; 9],
            stride: 3,
        };
        assert!(resolve_grid_input(GridInput::Upstream, Some(&nw)).is_err());
    }

    #[test]
    fn session_builder_rejects_missing_artifact_dir() {
        let r = Session::builder()
            .artifacts("/nonexistent/definitely/not/here")
            .lanes(2)
            .build();
        assert!(r.is_err());
    }

    #[test]
    fn run_report_accessors() {
        let fault = FaultReport {
            kind: FaultKind::Fatal,
            message: "boom".into(),
            attempts: 1,
            wave: 0,
            block: 0,
        };
        let mut report = RunReport {
            metrics: Metrics::default(),
            elapsed: Duration::ZERO,
            outputs: vec![WorkloadOutput::Piped, WorkloadOutput::Row(vec![1, 2])],
            statuses: vec![WorkloadStatus::Ok, WorkloadStatus::Ok],
            cancelled: Vec::new(),
            replays: Vec::new(),
            unfinished: Vec::new(),
            deadline_exceeded: false,
        };
        assert_eq!(report.output(), &WorkloadOutput::Row(vec![1, 2]));
        assert!(report.ok());
        assert!(report.completed());
        assert!(!report.deadline_exceeded);
        assert_eq!(report.first_fault(), None);

        // A healed stage is completed but not strictly ok.
        report.statuses[1] = WorkloadStatus::Replayed { attempts: 1 };
        assert!(!report.ok());
        assert!(report.completed());
        assert_eq!(report.first_fault(), None);

        // A deadline-cut stage is neither ok nor completed.
        report.statuses[1] = WorkloadStatus::DeadlineExceeded;
        assert!(!report.ok());
        assert!(!report.completed());
        assert_eq!(report.first_fault(), None);

        report.statuses[1] = WorkloadStatus::Failed(fault.clone());
        assert!(!report.ok());
        assert!(!report.completed());
        assert_eq!(report.first_fault(), Some(&fault));

        let out = report.into_output();
        assert_eq!(out, WorkloadOutput::Row(vec![1, 2]));
    }

    #[test]
    fn statuses_map_faults_and_cancellations_to_stages() {
        // Two independent 2-pass stages over a 2x2 block lattice:
        // stage A owns global waves 0-1, stage B waves 2-3.
        let a = blur_frag(StencilInput::Own(rand_grid(8, 8, 21)), 2);
        let b = blur_frag(StencilInput::Own(rand_grid(8, 8, 22)), 2);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, false]);

        // Fault-free record: everything Ok.
        assert_eq!(
            fused.statuses(&[], &[], &[], &[]),
            vec![WorkloadStatus::Ok, WorkloadStatus::Ok]
        );

        // A fault in stage A whose cone spills into stage B would mark
        // A Failed; B stays Ok unless its own blocks were cancelled.
        let fault = BlockFault {
            wave: 1,
            index: 2,
            kind: FaultKind::Transient,
            attempts: 3,
            message: "injected".into(),
        };
        let st = fused.statuses(&[fault.clone()], &[], &[], &[]);
        assert_eq!(st[1], WorkloadStatus::Ok);
        match &st[0] {
            WorkloadStatus::Failed(f) => {
                assert_eq!(f.kind, FaultKind::Transient);
                assert_eq!(f.attempts, 3);
                assert_eq!((f.wave, f.block), (1, 2));
            }
            other => panic!("stage A should be Failed, got {other:?}"),
        }

        // Cancellations land on the stage that owns the global wave,
        // and a stage's own fault outranks a cancellation mark.
        let st = fused.statuses(&[fault], &[(1, 3), (3, 0)], &[], &[]);
        assert!(matches!(st[0], WorkloadStatus::Failed(_)));
        assert_eq!(st[1], WorkloadStatus::Cancelled);
        assert!(!st[1].is_ok());
    }

    #[test]
    fn statuses_map_healed_replays_to_stages() {
        let a = blur_frag(StencilInput::Own(rand_grid(8, 8, 23)), 2);
        let b = blur_frag(StencilInput::Own(rand_grid(8, 8, 24)), 2);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, false]);

        // Two healed faults in stage A: the stage reports Replayed
        // with the worst round count; stage B — whose blocks may have
        // been re-driven as cone members, but never faulted — stays
        // Ok.
        let replays = vec![
            ConeReplay { wave: 0, index: 1, rounds: 1 },
            ConeReplay { wave: 1, index: 0, rounds: 2 },
        ];
        let st = fused.statuses(&[], &[], &replays, &[]);
        assert_eq!(st[0], WorkloadStatus::Replayed { attempts: 2 });
        assert!(st[0].completed() && !st[0].is_ok());
        assert_eq!(st[1], WorkloadStatus::Ok);

        // A stage that still has cancelled blocks after the replay
        // budget is Cancelled even if another of its faults healed,
        // and a terminal fault outranks everything.
        let st = fused.statuses(&[], &[(1, 3)], &replays, &[]);
        assert_eq!(st[0], WorkloadStatus::Cancelled);
        let fault = BlockFault {
            wave: 0,
            index: 2,
            kind: FaultKind::Transient,
            attempts: 6,
            message: "injected".into(),
        };
        let st = fused.statuses(&[fault], &[], &replays, &[]);
        assert!(matches!(st[0], WorkloadStatus::Failed(_)));
    }

    #[test]
    fn statuses_map_unfinished_blocks_to_deadline_exceeded() {
        let a = blur_frag(StencilInput::Own(rand_grid(8, 8, 25)), 2);
        let b = blur_frag(StencilInput::Own(rand_grid(8, 8, 26)), 2);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, false]);

        // Stage A was cut mid-flight (unfinished blocks in wave 1);
        // stage B finished everything and stays Ok.
        let st = fused.statuses(&[], &[], &[], &[(1, 0), (1, 3)]);
        assert_eq!(st[0], WorkloadStatus::DeadlineExceeded);
        assert!(!st[0].is_ok() && !st[0].completed());
        assert_eq!(st[1], WorkloadStatus::Ok);

        // The deadline mark outranks a cancelled-cone mark on the
        // same stage...
        let st = fused.statuses(&[], &[(1, 1)], &[], &[(1, 0)]);
        assert_eq!(st[0], WorkloadStatus::DeadlineExceeded);

        // ...but a terminal fault outranks the deadline mark: the
        // fault is the more specific diagnosis.
        let fault = BlockFault {
            wave: 1,
            index: 2,
            kind: FaultKind::Timeout,
            attempts: 1,
            message: "lane reaped".into(),
        };
        let st = fused.statuses(&[fault], &[], &[], &[(1, 0)]);
        assert!(matches!(st[0], WorkloadStatus::Failed(_)));
    }

    #[test]
    fn clamp_lanes_only_caps_pinned_sessions() {
        // Unpinned: any oversubscription is the OS scheduler's problem.
        assert_eq!(clamp_lanes(16, Pinning::None, 4), 16);
        // Pinned: a core per lane, so the count caps at the machine.
        assert_eq!(clamp_lanes(16, Pinning::Cores, 4), 4);
        assert_eq!(clamp_lanes(16, Pinning::Numa, 4), 4);
        assert_eq!(clamp_lanes(3, Pinning::Cores, 4), 3);
        // Degenerate inputs stay sane.
        assert_eq!(clamp_lanes(0, Pinning::Cores, 4), 1);
        assert_eq!(clamp_lanes(8, Pinning::Cores, 0), 8);
    }

    #[test]
    fn fused_affinity_delegates_on_local_waves() {
        // The affinity key of a block must be its *fragment-local*
        // block index, unchanged by where the fragment's waves landed
        // in the fused numbering — that is what keeps a piped chain's
        // block->lane map stable across the seam.
        let a = blur_frag(StencilInput::Own(rand_grid(8, 8, 31)), 2);
        let b = blur_frag(StencilInput::Own(rand_grid(8, 8, 32)), 2);
        let fused = FusedSpace::splice(vec![Box::new(a), Box::new(b)], vec![false, false]);
        for w in 0..fused.waves() {
            for i in 0..fused.wave_len(w) {
                assert_eq!(fused.affinity(w, i), i as u64);
            }
        }
    }
}
