//! L3 coordinator: the system that owns grids, decomposes them into
//! overlapped blocks, streams blocks through the AOT compute units and
//! reassembles results — the role the OpenCL host + board infrastructure
//! plays in the thesis.
//!
//! * [`grid`] — 2D/3D grids, halo extraction with the benchmark's
//!   boundary rule, interior write-back (including the lane-shared
//!   writers used for unordered writeback);
//! * [`bufpool`] — recycled tile/descriptor arenas so steady-state
//!   passes allocate nothing on the marshalling path;
//! * [`scheduler`] — the flat block-streaming engines: the
//!   single-runtime pipelined path (PJRT execution pinned to the
//!   coordinator thread — the client is `Rc`-based) and the extractor
//!   fan-out that feeds the multi-lane
//!   [`crate::runtime::pool::RuntimePool`];
//! * [`passdriver`] — the cross-pass pipelined pass driver: a
//!   dependency table over the block-origin lattice makes a pass-`p+1`
//!   block runnable as soon as its `r·T` halo-overlapping pass-`p`
//!   predecessors have written back — no per-pass barrier; since PR 3
//!   also the **wavefront** generalization (`WaveGraph`/`WaveTable`/
//!   `WaveSpace`) driving the Ch. 4 apps with explicit per-block
//!   dependency edges and no per-wave barrier;
//! * [`stencil_runner`] — temporal-block lowerings for the Ch. 5 stencil
//!   workloads (diffusion/hotspot, 2D/3D): block plans, tile
//!   extraction and write-back spaces over the pass driver;
//! * [`apps`] — wavefront lowerings for the Ch. 4 dynamic-programming
//!   and linear-algebra benchmarks (Pathfinder, NW, SRAD, LUD):
//!   `WaveSpace` implementations over the wavefront pass driver;
//! * [`session`] — **the public front door** (PR 4): a typed
//!   [`Session`](session::Session) builder owning the pool and
//!   metrics, first-class [`Workload`](session::Workload) descriptors
//!   that lower onto the wave driver, and a
//!   [`Chain`](session::Chain) combinator splicing heterogeneous
//!   workloads into one fused wave graph (cross-app seam edges, no
//!   inter-app drain).  Since PR 6 a run is also fault-tolerant:
//!   block faults are retried (`Transient`) or scoped to their
//!   dependency cone, and the [`RunReport`](session::RunReport)
//!   carries one [`WorkloadStatus`](session::WorkloadStatus) per
//!   stage instead of aborting the whole run;
//! * [`reference`] — native-Rust oracles used by the integration tests
//!   and the end-to-end examples;
//! * [`metrics`] — throughput/latency accounting for the §Perf work,
//!   since PR 6 including the fault counters (`job_retries`,
//!   `jobs_failed`, `lane_restarts`).

pub mod apps;
pub mod bufpool;
pub mod grid;
pub mod metrics;
pub mod passdriver;
pub mod reference;
pub mod scheduler;
pub mod session;
pub mod stencil_runner;

pub use grid::{Boundary, Grid2D, Grid3D};
pub use metrics::Metrics;
pub use passdriver::{PassMode, RunLimits};
pub use session::{
    Chain, FaultReport, GridInput, RunReport, Session, SessionBuilder, Workload,
    WorkloadOutput, WorkloadStatus,
};
