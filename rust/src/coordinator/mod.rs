//! L3 coordinator: the system that owns grids, decomposes them into
//! overlapped blocks, streams blocks through the AOT compute units and
//! reassembles results — the role the OpenCL host + board infrastructure
//! plays in the thesis.
//!
//! * [`grid`] — 2D/3D grids, halo extraction with the benchmark's
//!   boundary rule, interior write-back;
//! * [`scheduler`] — the block-streaming engine: marshalling parallelized
//!   across worker threads, PJRT execution pinned to the coordinator
//!   thread (the client is `Rc`-based);
//! * [`stencil_runner`] — temporal-block streaming for the Ch. 5 stencil
//!   workloads (diffusion/hotspot, 2D/3D);
//! * [`apps`] — full-application runners for the Ch. 4 dynamic-programming
//!   and linear-algebra benchmarks (Pathfinder, NW, SRAD, LUD);
//! * [`reference`] — native-Rust oracles used by the integration tests
//!   and the end-to-end examples;
//! * [`metrics`] — throughput/latency accounting for the §Perf work.

pub mod apps;
pub mod grid;
pub mod metrics;
pub mod reference;
pub mod scheduler;
pub mod stencil_runner;

pub use grid::{Boundary, Grid2D, Grid3D};
pub use metrics::Metrics;
