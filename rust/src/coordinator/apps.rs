//! Full-application runners for the Ch. 4 dynamic-programming and
//! linear-algebra benchmarks, composed from the AOT compute units the
//! way the thesis's host code drives its bitstreams.

use anyhow::{anyhow, bail};

use crate::coordinator::grid::Grid2D;
use crate::coordinator::metrics::Metrics;
use crate::runtime::{Runtime, RuntimePool, Tensor};

/// Gather one Pathfinder block's kernel inputs: the halo'd previous
/// cost row and the fused wall rows over the same (clamp-indexed)
/// span.  Shared by the single-runtime and lane-parallel runners so
/// their bit-identity contract rests on one implementation.
fn pathfinder_block_inputs(
    acc: &[i32],
    wall: &[Vec<i32>],
    base: usize,
    x0: usize,
    width: usize,
    fused: usize,
) -> (Vec<i32>, Vec<i32>) {
    let cols = acc.len();
    let padded = width + 2 * fused;
    let clamp = |j: isize| -> usize { j.clamp(0, cols as isize - 1) as usize };
    let mut prev = Vec::with_capacity(padded);
    for j in 0..padded {
        prev.push(acc[clamp(x0 as isize + j as isize - fused as isize)]);
    }
    let mut rows_block = Vec::with_capacity(fused * padded);
    for t in 0..fused {
        let row = &wall[base + t];
        for j in 0..padded {
            rows_block.push(row[clamp(x0 as isize + j as isize - fused as isize)]);
        }
    }
    (prev, rows_block)
}

/// Pathfinder: accumulate min-cost from row 0 down through `wall`
/// (rows × cols, i32), streaming fused-row blocks through the
/// `pathfinder` artifact.  `(rows - 1)` must be a multiple of the
/// artifact's fused depth.
pub fn run_pathfinder(rt: &Runtime, wall: &[Vec<i32>]) -> crate::Result<(Vec<i32>, Metrics)> {
    let spec = rt
        .registry()
        .get("pathfinder")
        .ok_or_else(|| anyhow!("missing pathfinder artifact"))?
        .clone();
    let width = spec.meta_u64("width")? as usize;
    let fused = spec.meta_u64("fused_rows")? as usize;
    let rows = wall.len();
    let cols = wall[0].len();
    if (rows - 1) % fused != 0 {
        bail!("pathfinder: rows-1 = {} not a multiple of fused {fused}", rows - 1);
    }
    rt.executable("pathfinder")?;

    let mut metrics = Metrics::default();
    let wall_t = std::time::Instant::now();
    let padded = width + 2 * fused;

    let mut acc: Vec<i32> = wall[0].clone();
    let mut base = 1usize;
    while base < rows {
        let mut next = vec![0i32; cols];
        let mut x0 = 0usize;
        while x0 < cols {
            let (prev, rows_block) = pathfinder_block_inputs(&acc, wall, base, x0, width, fused);
            let out = rt.execute(
                "pathfinder",
                &[
                    Tensor::I32(prev, vec![padded]),
                    Tensor::I32(rows_block, vec![fused, padded]),
                ],
            )?;
            let vals = out[0].as_i32();
            let w = width.min(cols - x0);
            next[x0..x0 + w].copy_from_slice(&vals[..w]);
            metrics.blocks += 1;
            x0 += width;
        }
        acc = next;
        base += fused;
        metrics.cell_updates += cols as u64 * fused as u64;
    }
    metrics.wall = wall_t.elapsed();
    Ok((acc, metrics))
}

/// Lane-parallel Pathfinder: the first Ch. 4 app on the
/// [`RuntimePool`].  Within one wave (a fused-row chunk) the
/// column-blocks are independent — each reads only the previous
/// accumulated row — so every block of the wave is submitted to the
/// pool at once and executes on whichever lane frees up first; the
/// caller assembles the next row as results stream back (the wave
/// barrier is the result count, not a pool drain).  Waves themselves
/// are sequential: wave `w+1` consumes the row wave `w` produced.
/// Bit-identical to [`run_pathfinder`] for any lane count (integer
/// arithmetic, disjoint output spans).
pub fn run_pathfinder_lanes(
    pool: &RuntimePool,
    wall: &[Vec<i32>],
) -> crate::Result<(Vec<i32>, Metrics)> {
    let spec = pool
        .registry()
        .get("pathfinder")
        .ok_or_else(|| anyhow!("missing pathfinder artifact"))?
        .clone();
    let width = spec.meta_u64("width")? as usize;
    let fused = spec.meta_u64("fused_rows")? as usize;
    let rows = wall.len();
    let cols = wall[0].len();
    if (rows - 1) % fused != 0 {
        bail!("pathfinder: rows-1 = {} not a multiple of fused {fused}", rows - 1);
    }
    // Compile on every lane outside the timed region.
    pool.warmup_artifact("pathfinder")?;

    let mut metrics = Metrics::default();
    let wall_t = std::time::Instant::now();
    let padded = width + 2 * fused;
    let nblocks = cols.div_ceil(width);

    let mut acc: Vec<i32> = wall[0].clone();
    let mut base = 1usize;
    while base < rows {
        // Extract every block's inputs on the caller thread (cheap
        // integer gathers), then fan the wave out across the lanes.
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<i32>)>();
        for bi in 0..nblocks {
            let x0 = bi * width;
            let (prev, rows_block) = pathfinder_block_inputs(&acc, wall, base, x0, width, fused);
            let tx = tx.clone();
            pool.submit(move |_lane, rt| {
                let out = rt.execute(
                    "pathfinder",
                    &[
                        Tensor::I32(prev, vec![padded]),
                        Tensor::I32(rows_block, vec![fused, padded]),
                    ],
                )?;
                let _ = tx.send((x0, out[0].as_i32().to_vec()));
                Ok(())
            });
        }
        drop(tx);

        // The wave barrier: all `nblocks` results, in any order.
        let mut next = vec![0i32; cols];
        let mut got = 0usize;
        while let Ok((x0, vals)) = rx.recv() {
            let w = width.min(cols - x0);
            next[x0..x0 + w].copy_from_slice(&vals[..w]);
            got += 1;
            metrics.blocks += 1;
        }
        if got != nblocks {
            // A lane dropped its sender without replying: the job was
            // skipped (poisoned pool) or failed.  Harvest the real
            // error rather than reporting a channel failure.
            pool.wait_idle()?;
            bail!("pathfinder: wave returned {got} of {nblocks} blocks");
        }
        acc = next;
        base += fused;
        metrics.cell_updates += cols as u64 * fused as u64;
    }
    pool.wait_idle()?;
    metrics.wall = wall_t.elapsed();
    Ok((acc, metrics))
}

/// Needleman-Wunsch over an (n+1)×(n+1) score matrix: the first row and
/// column are gap-initialised, interior computed block by block through
/// the `nw` artifact.  `n` must be a multiple of the artifact block.
pub fn run_nw(
    rt: &Runtime,
    reference: &[Vec<i32>],
    penalty: i32,
) -> crate::Result<(Vec<Vec<i32>>, Metrics)> {
    let spec = rt
        .registry()
        .get("nw")
        .ok_or_else(|| anyhow!("missing nw artifact"))?
        .clone();
    let b = spec.meta_u64("block")? as usize;
    let baked_penalty = spec.meta_u64("penalty")? as i32;
    if penalty != baked_penalty {
        bail!("nw: penalty {penalty} != artifact's baked {baked_penalty}");
    }
    let n = reference.len() - 1;
    if n % b != 0 {
        bail!("nw: interior size {n} not a multiple of block {b}");
    }
    rt.executable("nw")?;

    let mut metrics = Metrics::default();
    let wall_t = std::time::Instant::now();
    let mut score = vec![vec![0i32; n + 1]; n + 1];
    for j in 0..=n {
        score[0][j] = -(j as i32) * penalty;
    }
    for (i, row) in score.iter_mut().enumerate() {
        row[0] = -(i as i32) * penalty;
    }

    // Row-major block walk satisfies the up/left dependencies.
    for bi in 0..n / b {
        for bj in 0..n / b {
            let r0 = 1 + bi * b;
            let c0 = 1 + bj * b;
            let top: Vec<i32> = score[r0 - 1][c0..c0 + b].to_vec();
            let left: Vec<i32> = (0..b).map(|k| score[r0 + k][c0 - 1]).collect();
            let corner = vec![score[r0 - 1][c0 - 1]];
            let mut refb = Vec::with_capacity(b * b);
            for i in 0..b {
                refb.extend_from_slice(&reference[r0 + i][c0..c0 + b]);
            }
            let out = rt.execute(
                "nw",
                &[
                    Tensor::I32(top, vec![b]),
                    Tensor::I32(left, vec![b]),
                    Tensor::I32(corner, vec![1]),
                    Tensor::I32(refb, vec![b, b]),
                ],
            )?;
            let vals = out[0].as_i32();
            for i in 0..b {
                score[r0 + i][c0..c0 + b].copy_from_slice(&vals[i * b..(i + 1) * b]);
            }
            metrics.blocks += 1;
            metrics.cell_updates += (b * b) as u64;
        }
    }
    metrics.wall = wall_t.elapsed();
    Ok((score, metrics))
}

/// SRAD: `steps` iterations of (tile-partial reduction → fused two-pass
/// stencil) over a positive image.  Image extents must be multiples of
/// the artifact block for the reduction tiles.
pub fn run_srad(
    rt: &Runtime,
    img: Grid2D,
    steps: u64,
) -> crate::Result<(Grid2D, Metrics)> {
    let red_spec = rt
        .registry()
        .get("sum_sumsq")
        .ok_or_else(|| anyhow!("missing sum_sumsq artifact"))?
        .clone();
    let rblock = red_spec.meta_u64("block")? as usize;
    rt.executable("sum_sumsq")?;
    rt.executable("srad")?;

    let mut metrics = Metrics::default();
    let wall_t = std::time::Instant::now();
    let mut cur = img;
    let cells = (cur.ny * cur.nx) as f64;

    for _ in 0..steps {
        // --- partial reductions (zero-padding is sum-neutral) ---
        let mut total = 0f64;
        let mut total2 = 0f64;
        let mut y0 = 0;
        while y0 < cur.ny {
            let mut x0 = 0;
            while x0 < cur.nx {
                let t = cur.extract_tile(
                    y0 as isize, x0 as isize, rblock, rblock, 0,
                    crate::coordinator::grid::Boundary::Zero,
                );
                let out = rt.execute("sum_sumsq", &[Tensor::F32(t, vec![rblock, rblock])])?;
                let v = out[0].as_f32();
                total += v[0] as f64;
                total2 += v[1] as f64;
                x0 += rblock;
            }
            y0 += rblock;
        }
        let mean = total / cells;
        let var = total2 / cells - mean * mean;
        let q0 = (var / (mean * mean)) as f32;

        // --- fused two-pass stencil, streamed ---
        let (next, m) = crate::coordinator::stencil_runner::run_stencil2d_with_scalar(
            rt, "srad", cur, q0,
        )?;
        metrics.blocks += m.blocks;
        cur = next;
        metrics.cell_updates += cells as u64;
    }
    metrics.wall = wall_t.elapsed();
    Ok((cur, metrics))
}

/// Blocked LUD: factorize an (n×n) matrix in place using the diagonal /
/// perimeter / internal artifacts.  `n` must be a multiple of the block.
pub fn run_lud(rt: &Runtime, a: &[Vec<f32>]) -> crate::Result<(Vec<Vec<f32>>, Metrics)> {
    let spec = rt
        .registry()
        .get("lud_internal")
        .ok_or_else(|| anyhow!("missing lud artifacts"))?
        .clone();
    let b = spec.meta_u64("block")? as usize;
    let n = a.len();
    if n % b != 0 {
        bail!("lud: size {n} not a multiple of block {b}");
    }
    for name in ["lud_diagonal", "lud_perimeter_row", "lud_perimeter_col", "lud_internal"] {
        rt.executable(name)?;
    }
    let nb = n / b;
    let mut m: Vec<Vec<f32>> = a.to_vec();
    let mut metrics = Metrics::default();
    let wall_t = std::time::Instant::now();

    let get = |m: &Vec<Vec<f32>>, r: usize, c: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(b * b);
        for i in 0..b {
            out.extend_from_slice(&m[r * b + i][c * b..c * b + b]);
        }
        out
    };
    let put = |m: &mut Vec<Vec<f32>>, r: usize, c: usize, vals: &[f32]| {
        for i in 0..b {
            m[r * b + i][c * b..c * b + b].copy_from_slice(&vals[i * b..(i + 1) * b]);
        }
    };

    for k in 0..nb {
        let dia = rt.execute("lud_diagonal", &[Tensor::F32(get(&m, k, k), vec![b, b])])?;
        let dia_vals = dia[0].as_f32().to_vec();
        put(&mut m, k, k, &dia_vals);
        metrics.blocks += 1;

        let dlu = Tensor::F32(dia_vals, vec![b, b]);
        for j in k + 1..nb {
            let row = rt.execute(
                "lud_perimeter_row",
                &[dlu.clone(), Tensor::F32(get(&m, k, j), vec![b, b])],
            )?;
            put(&mut m, k, j, row[0].as_f32());
            let col = rt.execute(
                "lud_perimeter_col",
                &[dlu.clone(), Tensor::F32(get(&m, j, k), vec![b, b])],
            )?;
            put(&mut m, j, k, col[0].as_f32());
            metrics.blocks += 2;
        }
        for i in k + 1..nb {
            let lcol = Tensor::F32(get(&m, i, k), vec![b, b]);
            for j in k + 1..nb {
                let out = rt.execute(
                    "lud_internal",
                    &[
                        Tensor::F32(get(&m, i, j), vec![b, b]),
                        lcol.clone(),
                        Tensor::F32(get(&m, k, j), vec![b, b]),
                    ],
                )?;
                put(&mut m, i, j, out[0].as_f32());
                metrics.blocks += 1;
                metrics.cell_updates += (b * b) as u64;
            }
        }
    }
    metrics.wall = wall_t.elapsed();
    Ok((m, metrics))
}
