//! Wavefront lowerings for the Ch. 4 dynamic-programming and
//! linear-algebra benchmarks, composed from the AOT compute units the
//! way the thesis's host code drives its bitstreams.
//!
//! Each app is described as a [`WaveSpace`] — topologically ordered
//! waves of blocks with explicit dependency edges — and driven by the
//! **wavefront pass driver**
//! ([`drive_wave_pool`](crate::coordinator::passdriver::drive_wave_pool)):
//! a block runs as soon as its predecessors have written back.  There
//! is no result-count or `wait_idle` barrier between waves, so the
//! lanes stay fed across wave boundaries exactly like the thesis's
//! deep pipelines across time steps:
//!
//! * **Pathfinder** — wave `w` = one fused-row chunk; a column block
//!   of wave `w+1` needs only the span-overlapping blocks of wave `w`
//!   (clamp-indexed reads reach `fused` cells past the block edge).
//! * **NW** — anti-diagonal waves over the score-matrix block lattice;
//!   block `(bi, bj)` needs `(bi-1, bj)` and `(bi, bj-1)` (the corner
//!   dependency is transitively ordered through either).
//! * **SRAD** — alternating reduction / stencil waves with a
//!   **two-stage edge**: every stencil block of step `s` needs *all*
//!   reduction tiles of step `s` (q0 is a global statistic), while a
//!   reduction tile of step `s+1` needs only the stencil blocks of
//!   step `s` whose interiors overlap it — so the next step's
//!   reduction runs concurrently with the current stencil tail.
//! * **LUD** — per step `k`, diagonal → perimeter → internal waves;
//!   perimeter and internal blocks fan out across the lanes, and a
//!   step-`k+1` block starts as soon as its own step-`k` inputs are
//!   final (not when the whole step drains).
//!
//! The public front door is
//! [`coordinator::session`](crate::coordinator::session): the spaces
//! here are wrapped verbatim by the session's workload fragments
//! (`Workload::{pathfinder, nw, srad, lud}`), which is what makes
//! every lane count and either
//! [`PassMode`](crate::coordinator::passdriver::PassMode) bit-identical
//! — block inputs are fixed by the dependency order, write targets are
//! disjoint, and per-block compute is deterministic.  (The pre-PR 4
//! `run_*` free functions and their `run_*_lanes` shims are gone; the
//! lane-invariance integration tests now pin the pooled engine against
//! a lanes=1 session over the same spaces.)

use std::cell::UnsafeCell;
use std::sync::Arc;

use crate::coordinator::bufpool::TensorPools;
use crate::coordinator::grid::{Boundary, GridWriter2D};
use crate::coordinator::passdriver::{WaveGraph, WaveSpace};
use crate::coordinator::stencil_runner::oob_axis;
use crate::runtime::Tensor;

/// Clamp-indexed span copy: append `n` values of `src` starting at
/// signed offset `x0`, indices clamped into the row (Pathfinder's
/// boundary rule).  Every Pathfinder gather rests on this one
/// function, so the bit-identity contract across lane counts does
/// too.
fn clamp_span(src: &[i32], x0: isize, n: usize, out: &mut Vec<i32>) {
    let last = src.len() as isize - 1;
    for j in 0..n as isize {
        out.push(src[(x0 + j).clamp(0, last) as usize]);
    }
}

// ---------------------------------------------------------------------------
// Wavefront spaces: the Ch. 4 apps on the dependency-tracked pass driver
// ---------------------------------------------------------------------------

/// Raw shared slice handle over a buffer owned by the runner's stack
/// frame — the wavefront analogue of [`GridWriter2D`] for the i32 rows
/// and flat matrices the Ch. 4 apps stream.
///
/// Soundness contract (the creator's obligation, same as
/// `Grid2D::shared_writer`): the buffer outlives every use (the wave
/// driver's `IdleGuard` drains the lanes before the owning frame
/// returns), concurrent writes target pairwise-disjoint spans, and a
/// cell is only read once the write that produced it is
/// dependency-ordered before the read.
pub(crate) struct RawSlice<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: the creation contract above guarantees non-overlapping
// concurrent accesses over a live allocation.
unsafe impl<T: Send> Send for RawSlice<T> {}
unsafe impl<T: Send> Sync for RawSlice<T> {}

impl<T> RawSlice<T> {
    pub(crate) fn new(v: &mut [T]) -> RawSlice<T> {
        RawSlice { ptr: v.as_mut_ptr(), len: v.len() }
    }

    /// Read `n` elements starting at `at`.
    ///
    /// # Safety
    ///
    /// In-bounds span, no concurrent writer over it (dependency order).
    unsafe fn read(&self, at: usize, n: usize) -> &[T] {
        debug_assert!(at + n <= self.len);
        std::slice::from_raw_parts(self.ptr.add(at), n)
    }

    /// Overwrite `src.len()` elements starting at `at`.
    ///
    /// # Safety
    ///
    /// In-bounds span, disjoint from every concurrent access.
    unsafe fn write(&self, at: usize, src: &[T])
    where
        T: Copy,
    {
        debug_assert!(at + src.len() <= self.len);
        std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.add(at), src.len());
    }
}

/// Interior-mutable cell written by at most one lane (disjointness via
/// the wave plan); used for SRAD's per-tile reduction partials.
pub(crate) struct SyncCell<T>(pub(crate) UnsafeCell<T>);

// SAFETY: the wave plan guarantees one writer per cell and
// dependency-ordered readers.
unsafe impl<T: Send> Sync for SyncCell<T> {}

/// Pathfinder as a [`WaveSpace`]: wave `w` is fused-row chunk `w`,
/// block `i` is column block `i`.  The accumulated cost row
/// double-buffers (wave `w` reads buffer `w % 2`, writes
/// `(w+1) % 2`); clamp-indexed reads reach `fused` cells past the
/// block span, so a block of wave `w+1` depends on the wave-`w` blocks
/// within `ceil(fused/width)` lattice steps — the 1D instance of the
/// stencil driver's `r·T` halo-overlap rule, which also discharges the
/// write-after-read hazard of the two row buffers (the pass-`w` blocks
/// that read what a pass-`w+1` block overwrites are exactly its span
/// neighbors).
pub(crate) struct PathfinderSpace {
    pub(crate) artifact: Arc<str>,
    /// Wall rows `1..rows`, flattened row-major ((rows-1) × cols).
    pub(crate) wall: Vec<i32>,
    pub(crate) cols: usize,
    pub(crate) width: usize,
    pub(crate) fused: usize,
    pub(crate) padded: usize,
    pub(crate) nwaves: usize,
    pub(crate) nblocks: usize,
    /// `ceil(fused/width)` — dependency reach on the column lattice.
    pub(crate) reach: usize,
    /// Cost-row double buffer (each `cols` long).
    pub(crate) rows_bufs: [RawSlice<i32>; 2],
}

impl WaveGraph for PathfinderSpace {
    fn waves(&self) -> usize {
        self.nwaves
    }

    fn wave_len(&self, _w: usize) -> usize {
        self.nblocks
    }

    fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
        if w == 0 {
            return;
        }
        let lo = i.saturating_sub(self.reach);
        let hi = (i + self.reach).min(self.nblocks - 1);
        for j in lo..=hi {
            f(w - 1, j);
        }
    }
}

impl WaveSpace for PathfinderSpace {
    fn artifact(&self, _w: usize, _i: usize) -> Arc<str> {
        self.artifact.clone()
    }

    unsafe fn extract(&self, w: usize, i: usize) -> Vec<Tensor> {
        let x0 = i * self.width;
        let xs = x0 as isize - self.fused as isize;
        // Read only the clamped window [lo, hi): every clamp target of
        // the padded span lies inside it, and cells beyond it may be
        // concurrently rewritten by already-released wave-(w+1) blocks
        // (the span-overlap rule only orders this block's own window).
        let lo = xs.max(0) as usize;
        let hi = ((xs + self.padded as isize) as usize).min(self.cols);
        // SAFETY: dependency order — the wave-(w-1) blocks overlapping
        // this window wrote back (wave 0 reads the seeded row), and no
        // wave-(w+1) writer can touch it before this block completes.
        let acc = self.rows_bufs[w % 2].read(lo, hi - lo);
        let mut prev = Vec::with_capacity(self.padded);
        clamp_span(acc, xs - lo as isize, self.padded, &mut prev);
        let mut rows_block = Vec::with_capacity(self.fused * self.padded);
        for t in 0..self.fused {
            let row = &self.wall[(w * self.fused + t) * self.cols..][..self.cols];
            clamp_span(row, xs, self.padded, &mut rows_block);
        }
        vec![
            Tensor::I32(prev, vec![self.padded]),
            Tensor::I32(rows_block, vec![self.fused, self.padded]),
        ]
    }

    unsafe fn write(&self, w: usize, i: usize, out: &[Tensor]) {
        let x0 = i * self.width;
        let keep = self.width.min(self.cols - x0);
        // SAFETY: disjoint column spans on the block lattice.
        self.rows_bufs[(w + 1) % 2].write(x0, &out[0].as_i32()[..keep]);
    }

    fn cell_updates(&self, _w: usize, i: usize) -> u64 {
        let x0 = i * self.width;
        (self.width.min(self.cols - x0) * self.fused) as u64
    }
}

/// Needleman-Wunsch as a [`WaveSpace`]: wave `d` holds the score-block
/// anti-diagonal `bi + bj = d`; block `(bi, bj)` depends on
/// `(bi-1, bj)` and `(bi, bj-1)` in wave `d-1` (the corner value from
/// `(bi-1, bj-1)` is transitively ordered through either neighbor, and
/// score cells are single-assignment, so there is no write-after-read
/// hazard at all).
pub(crate) struct NwSpace {
    pub(crate) artifact: Arc<str>,
    /// Blocks per side of the interior lattice.
    pub(crate) nb: usize,
    pub(crate) b: usize,
    /// Row stride of the (n+1)×(n+1) matrices.
    pub(crate) stride: usize,
    /// Flattened reference matrix ((n+1)², read-only).
    pub(crate) refm: Vec<i32>,
    /// Flattened score matrix ((n+1)², borders pre-initialised).
    pub(crate) score: RawSlice<i32>,
}

impl NwSpace {
    /// First `bi` on anti-diagonal `d`.
    fn lo(&self, d: usize) -> usize {
        d.saturating_sub(self.nb - 1)
    }

    /// Decode wave-local index `i` into block coordinates `(bi, bj)`.
    fn block_of(&self, d: usize, i: usize) -> (usize, usize) {
        let bi = self.lo(d) + i;
        (bi, d - bi)
    }
}

impl WaveGraph for NwSpace {
    fn waves(&self) -> usize {
        2 * self.nb - 1
    }

    fn wave_len(&self, d: usize) -> usize {
        d.min(self.nb - 1) - self.lo(d) + 1
    }

    fn visit_preds(&self, d: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
        let (bi, bj) = self.block_of(d, i);
        if d == 0 {
            return;
        }
        let plo = self.lo(d - 1);
        if bi > 0 {
            f(d - 1, bi - 1 - plo); // up: (bi-1, bj)
        }
        if bj > 0 {
            f(d - 1, bi - plo); // left: (bi, bj-1)
        }
    }
}

impl WaveSpace for NwSpace {
    fn artifact(&self, _w: usize, _i: usize) -> Arc<str> {
        self.artifact.clone()
    }

    unsafe fn extract(&self, d: usize, i: usize) -> Vec<Tensor> {
        let (bi, bj) = self.block_of(d, i);
        let b = self.b;
        let (r0, c0) = (1 + bi * b, 1 + bj * b);
        // SAFETY: dependency order — the up/left/corner spans were
        // written by predecessor blocks (or are initialised borders).
        let top = self.score.read((r0 - 1) * self.stride + c0, b).to_vec();
        let mut left = Vec::with_capacity(b);
        for k in 0..b {
            left.push(self.score.read((r0 + k) * self.stride + (c0 - 1), 1)[0]);
        }
        let corner = vec![self.score.read((r0 - 1) * self.stride + (c0 - 1), 1)[0]];
        let mut refb = Vec::with_capacity(b * b);
        for k in 0..b {
            refb.extend_from_slice(&self.refm[(r0 + k) * self.stride + c0..][..b]);
        }
        vec![
            Tensor::I32(top, vec![b]),
            Tensor::I32(left, vec![b]),
            Tensor::I32(corner, vec![1]),
            Tensor::I32(refb, vec![b, b]),
        ]
    }

    unsafe fn write(&self, d: usize, i: usize, out: &[Tensor]) {
        let (bi, bj) = self.block_of(d, i);
        let b = self.b;
        let (r0, c0) = (1 + bi * b, 1 + bj * b);
        let vals = out[0].as_i32();
        for k in 0..b {
            // SAFETY: disjoint b×b interiors on the block lattice.
            self.score.write((r0 + k) * self.stride + c0, &vals[k * b..(k + 1) * b]);
        }
    }

    fn cell_updates(&self, _w: usize, _i: usize) -> u64 {
        (self.b * self.b) as u64
    }
}

/// SRAD as a [`WaveSpace`]: wave `2s` holds step `s`'s partial
/// reduction tiles, wave `2s+1` its stencil blocks, with the
/// **two-stage dependency edge** the ROADMAP called for:
///
/// * stencil block of step `s` → **all** reduction tiles of step `s`
///   (q0 is a global statistic of the whole image);
/// * reduction tile of step `s+1` → only the step-`s` stencil blocks
///   whose written interiors overlap the tile.
///
/// The second edge is what buys overlap: step `s+1`'s reduction starts
/// while the stencil tail of step `s` is still executing.  The full
/// first edge also chains every step-`s` stencil block before every
/// step-`s+1` stencil block, which discharges both the halo'd reads
/// and the write-after-read hazard of the two image buffers (step `s`
/// reads buffer `s % 2`, writes `(s+1) % 2`).
///
/// q0 is recomputed from the per-tile partials on each stencil
/// extraction, always summing in tile-index order — the same f64
/// additions in the same order regardless of which lane finished which
/// tile first, so the scalar (and the run) is bit-identical across
/// lane counts and completion orders.
pub(crate) struct SradSpace {
    pub(crate) red_artifact: Arc<str>,
    pub(crate) sten_artifact: Arc<str>,
    pub(crate) steps: usize,
    pub(crate) ny: usize,
    pub(crate) nx: usize,
    pub(crate) cells: f64,
    /// Reduction tiling (zero-padded partial sums).
    pub(crate) rblock: usize,
    pub(crate) rorigins: Vec<(usize, usize)>,
    /// Stencil tiling (r·T halo, boundary rule from the artifact).
    pub(crate) sblock: usize,
    pub(crate) halo: usize,
    pub(crate) tile: usize,
    pub(crate) t_fused: usize,
    pub(crate) boundary: Boundary,
    pub(crate) sorigins: Vec<(usize, usize)>,
    /// Stencil lattice width (blocks per row).
    pub(crate) snbx: usize,
    /// Image double buffer: step `s` reads `bufs[s % 2]`, writes
    /// `bufs[(s+1) % 2]`.
    pub(crate) bufs: [GridWriter2D; 2],
    /// Per-(step, tile) reduction partials `(sum, sumsq)`.
    pub(crate) partials: Vec<SyncCell<(f64, f64)>>,
    pub(crate) pools: TensorPools,
}

impl SradSpace {
    /// q0² for step `s` from the step's tile partials, summed in tile
    /// order (deterministic regardless of completion order).
    ///
    /// # Safety
    ///
    /// Every reduction tile of step `s` must have written back.
    unsafe fn q0(&self, s: usize) -> f32 {
        let base = s * self.rorigins.len();
        let mut total = 0f64;
        let mut total2 = 0f64;
        for t in 0..self.rorigins.len() {
            let (a, b) = *self.partials[base + t].0.get();
            total += a;
            total2 += b;
        }
        let mean = total / self.cells;
        let var = total2 / self.cells - mean * mean;
        (var / (mean * mean)) as f32
    }

    /// Shard-keyed extraction body shared by [`WaveSpace::extract`]
    /// (shard 0) and [`WaveSpace::extract_sharded`] (the driver's
    /// affinity lane): tile buffers come from the shard's free list so
    /// a block's tiles cycle within one lane under the sharded
    /// scheduler.
    ///
    /// # Safety
    ///
    /// Same dependency-order contract as [`WaveSpace::extract`].
    unsafe fn extract_on(&self, shard: usize, w: usize, i: usize) -> Vec<Tensor> {
        let s = w / 2;
        let src = self.bufs[s % 2];
        if w % 2 == 0 {
            // Reduction tile: rblock×rblock, no halo, zero padding
            // (sum-neutral).
            let (y0, x0) = self.rorigins[i];
            let mut t = self.pools.tiles.take_on(shard, self.rblock * self.rblock);
            // SAFETY: dependency order — step s-1's stencil blocks
            // wrote every in-grid cell this tile reads.
            src.extract_tile_into(
                y0 as isize, x0 as isize, self.rblock, self.rblock, 0, Boundary::Zero, &mut t,
            );
            vec![Tensor::F32(t, vec![self.rblock, self.rblock])]
        } else {
            // Stencil block: the same inputs Space2D builds for the
            // scalar-carrying srad artifact — halo'd tile, per-step
            // scalar, boundary-restoration descriptor.
            let q0 = self.q0(s);
            let (y0, x0) = self.sorigins[i];
            let mut inputs = Vec::with_capacity(3);
            let mut t = self.pools.tiles.take_on(shard, self.tile * self.tile);
            // SAFETY: dependency order, as above (all step-s reduction
            // tiles completed after all step-(s-1) stencil blocks).
            src.extract_tile_into(
                y0 as isize, x0 as isize, self.tile, self.tile, self.halo,
                self.boundary, &mut t,
            );
            inputs.push(Tensor::F32(t, vec![self.tile, self.tile]));
            let mut v = self.pools.tiles.take_on(shard, self.t_fused);
            v.resize(self.t_fused, q0);
            inputs.push(Tensor::F32(v, vec![self.t_fused]));
            let (t0, t1) = oob_axis(y0, self.sblock, self.halo, self.ny);
            let (l0, l1) = oob_axis(x0, self.sblock, self.halo, self.nx);
            let mut d = self.pools.descs.take_on(shard, 4);
            d.extend_from_slice(&[t0, t1, l0, l1]);
            inputs.push(Tensor::I32(d, vec![4]));
            inputs
        }
    }
}

impl WaveGraph for SradSpace {
    fn waves(&self) -> usize {
        2 * self.steps
    }

    fn wave_len(&self, w: usize) -> usize {
        if w % 2 == 0 {
            self.rorigins.len()
        } else {
            self.sorigins.len()
        }
    }

    fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
        if w == 0 {
            return;
        }
        if w % 2 == 1 {
            // Stencil of step s: every reduction tile of step s.
            for t in 0..self.rorigins.len() {
                f(w - 1, t);
            }
        } else {
            // Reduction tile of step s ≥ 1: the step-(s-1) stencil
            // blocks whose clipped interiors overlap the tile's
            // in-grid rect (out-of-grid tile cells read zero-padding
            // nobody writes).
            let (y0, x0) = self.rorigins[i];
            let y1 = (y0 + self.rblock).min(self.ny) - 1;
            let x1 = (x0 + self.rblock).min(self.nx) - 1;
            for by in y0 / self.sblock..=y1 / self.sblock {
                for bx in x0 / self.sblock..=x1 / self.sblock {
                    f(w - 1, by * self.snbx + bx);
                }
            }
        }
    }
}

impl WaveSpace for SradSpace {
    fn artifact(&self, w: usize, _i: usize) -> Arc<str> {
        if w % 2 == 0 {
            self.red_artifact.clone()
        } else {
            self.sten_artifact.clone()
        }
    }

    unsafe fn extract(&self, w: usize, i: usize) -> Vec<Tensor> {
        self.extract_on(0, w, i)
    }

    unsafe fn extract_sharded(&self, shard: usize, w: usize, i: usize) -> Vec<Tensor> {
        self.extract_on(shard, w, i)
    }

    unsafe fn write(&self, w: usize, i: usize, out: &[Tensor]) {
        let s = w / 2;
        if w % 2 == 0 {
            let v = out[0].as_f32();
            // SAFETY: one writer per partial cell (the wave plan).
            *self.partials[s * self.rorigins.len() + i].0.get() = (v[0] as f64, v[1] as f64);
        } else {
            let (y0, x0) = self.sorigins[i];
            // SAFETY: disjoint interiors on the stencil block lattice.
            self.bufs[(s + 1) % 2].write_block(y0, x0, self.sblock, self.sblock, out[0].as_f32());
        }
    }

    fn cell_updates(&self, w: usize, i: usize) -> u64 {
        if w % 2 == 0 {
            return 0;
        }
        // One step's clipped interior per stencil block — summing to
        // `cells` per wave pair, one full image update per step
        // (independent of the artifact's fused depth).
        let (y0, x0) = self.sorigins[i];
        let h = self.sblock.min(self.ny - y0);
        let ww = self.sblock.min(self.nx - x0);
        (h * ww) as u64
    }

    fn recycle(&self, _w: usize, _i: usize, inputs: Vec<Tensor>) {
        self.pools.recycle(inputs);
    }

    fn recycle_sharded(&self, shard: usize, _w: usize, _i: usize, inputs: Vec<Tensor>) {
        self.pools.recycle_on(shard, inputs);
    }

    fn pool_counters(&self) -> (u64, u64, u64, u64) {
        (
            self.pools.tiles.hits(),
            self.pools.tiles.misses(),
            self.pools.descs.hits(),
            self.pools.descs.misses(),
        )
    }

    fn pool_evictions(&self) -> u64 {
        self.pools.evictions()
    }
}

/// Blocked LUD as a [`WaveSpace`]: step `k` unrolls into three waves —
/// diagonal (wave `3k`, one block), perimeter row/col (wave `3k+1`,
/// fanning across the lanes) and internal Schur updates (wave `3k+2`,
/// the embarrassingly parallel bulk).  Edges follow the factorization
/// exactly: the diagonal needs internal `(k,k)` of step `k-1`; a
/// perimeter block needs the diagonal plus its own step-`k-1` internal
/// update; an internal block needs its row/col perimeter blocks plus
/// its own previous update — so a step-`k+1` block starts as soon as
/// *its* inputs are final, not when step `k` drains.  In-place block
/// writes are single-writer-at-a-time and every read of a rewritten
/// block is one of these direct edges, so the schedule is race-free at
/// any pipeline depth.
pub(crate) struct LudSpace {
    pub(crate) diagonal: Arc<str>,
    pub(crate) perim_row: Arc<str>,
    pub(crate) perim_col: Arc<str>,
    pub(crate) internal: Arc<str>,
    pub(crate) nb: usize,
    pub(crate) b: usize,
    pub(crate) n: usize,
    /// Flattened n×n matrix, factorized in place.
    pub(crate) m: RawSlice<f32>,
}

/// What a LUD wave-local index means for step `k`.
enum LudBlock {
    Diagonal,
    /// Perimeter row block `(k, j)`.
    Row(usize),
    /// Perimeter col block `(j, k)`.
    Col(usize),
    /// Internal block `(i, j)`.
    Internal(usize, usize),
}

impl LudSpace {
    fn decode(&self, w: usize, i: usize) -> (usize, LudBlock) {
        let k = w / 3;
        let kind = match w % 3 {
            0 => LudBlock::Diagonal,
            1 => {
                let j = k + 1 + i / 2;
                if i % 2 == 0 {
                    LudBlock::Row(j)
                } else {
                    LudBlock::Col(j)
                }
            }
            _ => {
                let r = self.nb - k - 1;
                LudBlock::Internal(k + 1 + i / r, k + 1 + i % r)
            }
        };
        (k, kind)
    }

    /// Wave-local index of internal block `(i, j)` in step `k`'s
    /// internal wave.
    fn internal_idx(&self, k: usize, i: usize, j: usize) -> usize {
        (i - k - 1) * (self.nb - k - 1) + (j - k - 1)
    }

    /// Read block `(r, c)` as a b×b tile.
    ///
    /// # Safety
    ///
    /// Dependency order: the block's last writer has completed.
    unsafe fn get(&self, r: usize, c: usize) -> Vec<f32> {
        let b = self.b;
        let mut out = Vec::with_capacity(b * b);
        for row in 0..b {
            out.extend_from_slice(self.m.read((r * b + row) * self.n + c * b, b));
        }
        out
    }

    /// Overwrite block `(r, c)`.
    ///
    /// # Safety
    ///
    /// Disjoint from every concurrent access (wave plan).
    unsafe fn put(&self, r: usize, c: usize, vals: &[f32]) {
        let b = self.b;
        for row in 0..b {
            self.m.write((r * b + row) * self.n + c * b, &vals[row * b..(row + 1) * b]);
        }
    }
}

impl WaveGraph for LudSpace {
    fn waves(&self) -> usize {
        3 * self.nb
    }

    fn wave_len(&self, w: usize) -> usize {
        let k = w / 3;
        let r = self.nb - k - 1;
        match w % 3 {
            0 => 1,
            1 => 2 * r,
            _ => r * r,
        }
    }

    fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
        let (k, kind) = self.decode(w, i);
        match kind {
            LudBlock::Diagonal => {
                if k > 0 {
                    // internal (k, k) of step k-1
                    f(w - 1, self.internal_idx(k - 1, k, k));
                }
            }
            LudBlock::Row(j) => {
                f(w - 1, 0); // diagonal k
                if k > 0 {
                    // internal (k, j) of step k-1 (wave 3k-1 = w-2)
                    f(w - 2, self.internal_idx(k - 1, k, j));
                }
            }
            LudBlock::Col(j) => {
                f(w - 1, 0);
                if k > 0 {
                    f(w - 2, self.internal_idx(k - 1, j, k));
                }
            }
            LudBlock::Internal(bi, bj) => {
                // perimeter row (k, bj) and col (bi, k) of this step
                f(w - 1, 2 * (bj - k - 1));
                f(w - 1, 2 * (bi - k - 1) + 1);
                if k > 0 {
                    // internal (bi, bj) of step k-1 (wave 3k-1 = w-3)
                    f(w - 3, self.internal_idx(k - 1, bi, bj));
                }
            }
        }
    }
}

impl WaveSpace for LudSpace {
    fn artifact(&self, w: usize, i: usize) -> Arc<str> {
        match self.decode(w, i).1 {
            LudBlock::Diagonal => self.diagonal.clone(),
            LudBlock::Row(_) => self.perim_row.clone(),
            LudBlock::Col(_) => self.perim_col.clone(),
            LudBlock::Internal(..) => self.internal.clone(),
        }
    }

    unsafe fn extract(&self, w: usize, i: usize) -> Vec<Tensor> {
        let b = self.b;
        let shape = vec![b, b];
        let (k, kind) = self.decode(w, i);
        // SAFETY of every `get`: dependency order — each read block's
        // final-for-this-step writer is a declared predecessor.
        match kind {
            LudBlock::Diagonal => vec![Tensor::F32(self.get(k, k), shape)],
            LudBlock::Row(j) => vec![
                Tensor::F32(self.get(k, k), shape.clone()),
                Tensor::F32(self.get(k, j), shape),
            ],
            LudBlock::Col(j) => vec![
                Tensor::F32(self.get(k, k), shape.clone()),
                Tensor::F32(self.get(j, k), shape),
            ],
            LudBlock::Internal(bi, bj) => vec![
                Tensor::F32(self.get(bi, bj), shape.clone()),
                Tensor::F32(self.get(bi, k), shape.clone()),
                Tensor::F32(self.get(k, bj), shape),
            ],
        }
    }

    unsafe fn write(&self, w: usize, i: usize, out: &[Tensor]) {
        let (k, kind) = self.decode(w, i);
        let vals = out[0].as_f32();
        // SAFETY: one writer per block per wave; later rewrites are
        // dependency-ordered behind this one.
        match kind {
            LudBlock::Diagonal => self.put(k, k, vals),
            LudBlock::Row(j) => self.put(k, j, vals),
            LudBlock::Col(j) => self.put(j, k, vals),
            LudBlock::Internal(bi, bj) => self.put(bi, bj, vals),
        }
    }

    fn cell_updates(&self, w: usize, _i: usize) -> u64 {
        // Only the internal Schur updates count as cell updates; the
        // diagonal and perimeter blocks are pipeline-fill overhead.
        if w % 3 == 2 {
            (self.b * self.b) as u64
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::grid::Grid2D;
    use crate::coordinator::stencil_runner::block_origins_2d;
    use std::collections::HashSet;

    /// Every declared edge must point from a strictly earlier wave to
    /// an in-range block — the WaveTable's structural contract.
    fn check_graph(g: &dyn WaveGraph) {
        for w in 0..g.waves() {
            for i in 0..g.wave_len(w) {
                g.visit_preds(w, i, &mut |v, j| {
                    assert!(v < w, "pred wave {v} not before ({w},{i})");
                    assert!(j < g.wave_len(v), "pred ({v},{j}) out of range");
                });
            }
        }
    }

    fn pathfinder_space(cols: usize, width: usize, fused: usize, nwaves: usize) -> PathfinderSpace {
        PathfinderSpace {
            artifact: Arc::from("pathfinder"),
            wall: vec![0; nwaves * fused * cols],
            cols,
            width,
            fused,
            padded: width + 2 * fused,
            nwaves,
            nblocks: cols.div_ceil(width),
            reach: fused.div_ceil(width),
            rows_bufs: [RawSlice::new(&mut []), RawSlice::new(&mut [])],
        }
    }

    #[test]
    fn pathfinder_graph_span_overlap_edges() {
        let s = pathfinder_space(5000, 1024, 8, 3);
        check_graph(&s);
        assert_eq!(s.nblocks, 5); // partial final block
        assert_eq!(s.reach, 1);
        // interior block: three span-overlapping predecessors
        let mut preds = Vec::new();
        s.visit_preds(1, 2, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, vec![(0, 1), (0, 2), (0, 3)]);
        // edge blocks clip
        preds.clear();
        s.visit_preds(2, 0, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, vec![(1, 0), (1, 1)]);
        preds.clear();
        s.visit_preds(1, 4, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, vec![(0, 3), (0, 4)]);
        // wave 0 seeds
        preds.clear();
        s.visit_preds(0, 2, &mut |v, j| preds.push((v, j)));
        assert!(preds.is_empty());
    }

    #[test]
    fn pathfinder_graph_wide_fused_reaches_further() {
        // fused > width: clamp reads span multiple neighbor blocks.
        let s = pathfinder_space(64, 16, 24, 2);
        assert_eq!(s.reach, 2);
        check_graph(&s);
        let mut preds = Vec::new();
        s.visit_preds(1, 1, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, vec![(0, 0), (0, 1), (0, 2), (0, 3)]);
    }

    fn nw_space(n: usize, b: usize) -> NwSpace {
        NwSpace {
            artifact: Arc::from("nw"),
            nb: n / b,
            b,
            stride: n + 1,
            refm: vec![0; (n + 1) * (n + 1)],
            score: RawSlice::new(&mut []),
        }
    }

    #[test]
    fn nw_graph_antidiagonal_structure() {
        let s = nw_space(256, 64); // 4x4 block lattice, 7 diagonals
        check_graph(&s);
        assert_eq!(s.waves(), 7);
        let lens: Vec<usize> = (0..s.waves()).map(|d| s.wave_len(d)).collect();
        assert_eq!(lens, vec![1, 2, 3, 4, 3, 2, 1]);
        assert_eq!(lens.iter().sum::<usize>(), 16);
        // every block appears exactly once with bi+bj = d
        let mut seen = HashSet::new();
        for d in 0..s.waves() {
            for i in 0..s.wave_len(d) {
                let (bi, bj) = s.block_of(d, i);
                assert_eq!(bi + bj, d);
                assert!(seen.insert((bi, bj)));
            }
        }
        assert_eq!(seen.len(), 16);
        // interior block depends on up + left in the previous diagonal
        let mut preds = Vec::new();
        s.visit_preds(3, 1, &mut |v, j| preds.push((v, j)));
        let (bi, bj) = s.block_of(3, 1);
        assert_eq!((bi, bj), (1, 2));
        assert_eq!(preds.len(), 2);
        for &(v, j) in &preds {
            assert_eq!(v, 2);
            let (pi, pj) = s.block_of(v, j);
            assert!((pi, pj) == (0, 2) || (pi, pj) == (1, 1), "got ({pi},{pj})");
        }
        // top-row block: only the left neighbor
        let mut preds = Vec::new();
        s.visit_preds(2, 0, &mut |v, j| preds.push((v, j)));
        assert_eq!(s.block_of(2, 0), (0, 2));
        assert_eq!(preds.len(), 1);
        assert_eq!(s.block_of(preds[0].0, preds[0].1), (0, 1));
    }

    fn srad_space(ny: usize, nx: usize, rblock: usize, sblock: usize, steps: usize) -> SradSpace {
        let rorigins = block_origins_2d(ny, nx, rblock);
        let nrtiles = rorigins.len();
        // SAFETY: graph-only space — the handle is stored but never
        // read or written (no extract/write call dereferences it), so
        // the outlives/disjointness contract is vacuous.
        let mut dummy = Grid2D::zeros(1, 1);
        let h = unsafe { dummy.shared_writer() };
        SradSpace {
            red_artifact: Arc::from("sum_sumsq"),
            sten_artifact: Arc::from("srad"),
            steps,
            ny,
            nx,
            cells: (ny * nx) as f64,
            rblock,
            rorigins,
            sblock,
            halo: 2,
            tile: sblock + 4,
            t_fused: 1,
            boundary: Boundary::Clamp,
            sorigins: block_origins_2d(ny, nx, sblock),
            snbx: nx.div_ceil(sblock),
            bufs: [h, h],
            partials: (0..steps * nrtiles)
                .map(|_| SyncCell(UnsafeCell::new((0.0, 0.0))))
                .collect(),
            pools: TensorPools::default(),
        }
    }

    #[test]
    fn srad_graph_two_stage_edges() {
        // 64x48 image, reduction tiles 16 (4x3 = 12), stencil blocks
        // 32 (2x2 = 4, partial in x).
        let s = srad_space(64, 48, 16, 32, 2);
        check_graph(&s);
        assert_eq!(s.waves(), 4);
        assert_eq!(s.wave_len(0), 12);
        assert_eq!(s.wave_len(1), 4);
        // full edge: every stencil block needs all 12 tiles
        let mut preds = Vec::new();
        s.visit_preds(1, 3, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, (0..12).map(|t| (0usize, t)).collect::<Vec<_>>());
        // span edge: tile (16..32, 16..32) sits inside stencil block
        // (0, 0) only — index 1*3+1 = 4 on the 4x3 tile lattice
        let mut preds = Vec::new();
        s.visit_preds(2, 4, &mut |v, j| preds.push((v, j)));
        assert_eq!(s.rorigins[4], (16, 16));
        assert_eq!(preds, vec![(1, 0)]);
        // tile (48.., 32..) straddles stencil rows/cols: block (1,1)
        let mut preds = Vec::new();
        let t = s.rorigins.iter().position(|&o| o == (48, 32)).unwrap();
        s.visit_preds(2, t, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, vec![(1, 3)]);
        // tile spanning two stencil columns: origin (0, 16) overlaps
        // blocks (0,0) and (0,0)… tile [0..16)x[16..32) is inside
        // column 0 of the stencil lattice; take (32, 16) instead,
        // rows 32..48 → stencil row 1, cols 16..32 → stencil col 0.
        let mut preds = Vec::new();
        let t = s.rorigins.iter().position(|&o| o == (32, 16)).unwrap();
        s.visit_preds(2, t, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, vec![(1, 2)]);
    }

    #[test]
    fn srad_graph_tile_straddling_blocks_depends_on_all() {
        // Reduction tiles wider than stencil blocks: tile 32 over
        // blocks 16 → each interior tile needs a 2x2 block patch.
        let s = srad_space(64, 64, 32, 16, 2);
        check_graph(&s);
        let mut preds = Vec::new();
        let t = s.rorigins.iter().position(|&o| o == (32, 32)).unwrap();
        s.visit_preds(2, t, &mut |v, j| preds.push((v, j)));
        let nbx = 4; // 64/16
        let want: Vec<(usize, usize)> = [(2usize, 2usize), (2, 3), (3, 2), (3, 3)]
            .iter()
            .map(|&(by, bx)| (1usize, by * nbx + bx))
            .collect();
        assert_eq!(preds, want);
    }

    fn lud_space(n: usize, b: usize) -> LudSpace {
        LudSpace {
            diagonal: Arc::from("lud_diagonal"),
            perim_row: Arc::from("lud_perimeter_row"),
            perim_col: Arc::from("lud_perimeter_col"),
            internal: Arc::from("lud_internal"),
            nb: n / b,
            b,
            n,
            m: RawSlice::new(&mut []),
        }
    }

    #[test]
    fn lud_graph_cascade_edges() {
        let s = lud_space(256, 64); // nb = 4
        check_graph(&s);
        assert_eq!(s.waves(), 12);
        let lens: Vec<usize> = (0..s.waves()).map(|w| s.wave_len(w)).collect();
        assert_eq!(lens, vec![1, 6, 9, 1, 4, 4, 1, 2, 1, 1, 0, 0]);
        // diagonal of step 1 needs internal (1,1) of step 0 (index 0)
        let mut preds = Vec::new();
        s.visit_preds(3, 0, &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, vec![(2, 0)]);
        // perimeter row (1, 3) of step 1: diagonal 1 + internal (1,3)@0
        let mut preds = Vec::new();
        s.visit_preds(4, 2 * (3 - 1 - 1), &mut |v, j| preds.push((v, j)));
        assert_eq!(preds, vec![(3, 0), (2, s.internal_idx(0, 1, 3))]);
        // internal (2,3) of step 1: perim row (1,3), perim col (2,1),
        // internal (2,3)@0
        let mut preds = Vec::new();
        let q = (2 - 1 - 1) * 2 + (3 - 1 - 1); // r = 2 at step 1
        s.visit_preds(5, q, &mut |v, j| preds.push((v, j)));
        assert_eq!(
            preds,
            vec![
                (4, 2 * (3 - 1 - 1)),
                (4, 2 * (2 - 1 - 1) + 1),
                (2, s.internal_idx(0, 2, 3)),
            ]
        );
        // decode round-trips every block of every wave
        for w in 0..s.waves() {
            for i in 0..s.wave_len(w) {
                let (k, kind) = s.decode(w, i);
                assert_eq!(k, w / 3);
                match kind {
                    LudBlock::Diagonal => assert_eq!(w % 3, 0),
                    LudBlock::Row(j) | LudBlock::Col(j) => {
                        assert_eq!(w % 3, 1);
                        assert!(j > k && j < s.nb);
                    }
                    LudBlock::Internal(bi, bj) => {
                        assert_eq!(w % 3, 2);
                        assert!(bi > k && bj > k && bi < s.nb && bj < s.nb);
                    }
                }
            }
        }
    }

    #[test]
    fn clamp_span_matches_scalar_clamp() {
        let src = vec![10, 20, 30, 40];
        let mut out = Vec::new();
        clamp_span(&src, -2, 8, &mut out);
        assert_eq!(out, vec![10, 10, 10, 20, 30, 40, 40, 40]);
    }
}
