//! Flat (single-pass) block-streaming schedulers.
//!
//! Cross-pass scheduling — dependency-tracked pipelining over *all*
//! passes (or waves) of a workload — lives in
//! [`crate::coordinator::passdriver`], which superseded these engines
//! on the stencil paths in PR 2 and on the Ch. 4 wavefront apps in
//! PR 3 (the `WaveSpace` driver now owns the LUD/SRAD/NW/Pathfinder
//! fan-out these engines were being retained for).  The two generic
//! engines below have no production caller: they stay as fully tested
//! pure-logic building blocks for one-shot independent-block streaming
//! that genuinely needs no dependency table.
//!
//! Two regimes:
//!
//! * [`run_pipelined`] — the single-runtime path.  The PJRT client is
//!   `Rc`-based (not `Send`), so execution stays on the caller's thread;
//!   a worker thread pre-extracts the halo'd tiles for blocks
//!   `i+1..i+depth` while block `i` executes (double/treble buffering —
//!   the software analogue of the thesis's load/compute overlap
//!   discussion in §4.3.1.6).
//!
//! * [`feed_blocks`] — the extractor side of the multi-lane engine: M
//!   worker threads pull block ids off a shared counter, extract, and
//!   ship each tile (typically into [`crate::runtime::pool::RuntimePool`]
//!   via its bounded job queue).  Writeback ordering is *unordered*:
//!   stencil blocks write disjoint interiors, so only metrics, not
//!   correctness, depend on order.
//!
//! Both schedulers surface worker panics as errors instead of swallowing
//! them (or aborting the process).

use std::panic::{catch_unwind, AssertUnwindSafe};
// The Mutex stays `std` on purpose: `run_pipelined` consumes it with
// `into_inner()` (std-only signature) and nothing here is on a loom
// model's path — only the atomics route through the shim so the lint
// gate holds crate-wide.
use std::sync::{mpsc, Mutex, PoisonError};

use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// A unit of work: index into the block plan.
pub type BlockId = usize;

/// Best-effort panic payload stringification for error reports.
pub(crate) fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Runs `plan.len()` blocks: `extract(id)` produces the input tensors on
/// a worker thread (in order), `execute(id, tile)` runs on this thread.
///
/// `lookahead` bounds in-flight extracted tiles (memory backpressure).
/// An extractor panic is reported as an error; an `execute` error drains
/// the extractor and propagates.
pub fn run_pipelined<T: Send>(
    nblocks: usize,
    lookahead: usize,
    extract: impl Fn(BlockId) -> T + Sync,
    mut execute: impl FnMut(BlockId, T) -> crate::Result<()>,
) -> crate::Result<()> {
    if nblocks == 0 {
        return Ok(());
    }
    // Small plans — or a single-core host, where a marshalling thread can
    // only steal cycles from execution (§Perf L3: sequential is ~4 %
    // faster at nproc=1) — run sequentially.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if nblocks <= 2 || lookahead <= 1 || cores <= 1 {
        for id in 0..nblocks {
            let t = extract(id);
            execute(id, t)?;
        }
        return Ok(());
    }

    std::thread::scope(|scope| -> crate::Result<()> {
        let (tx, rx) = mpsc::sync_channel::<(BlockId, T)>(lookahead);
        let extract_ref = &extract;
        let feeder = scope.spawn(move || {
            for id in 0..nblocks {
                let t = extract_ref(id);
                if tx.send((id, t)).is_err() {
                    return; // consumer dropped (error path)
                }
            }
        });
        // Execution consumes in order; tiles arrive in order from the
        // single producer.
        let mut result: crate::Result<()> = Ok(());
        let mut feeder_died = false;
        for expect in 0..nblocks {
            match rx.recv() {
                Ok((id, t)) => {
                    debug_assert_eq!(id, expect);
                    if let Err(e) = execute(id, t) {
                        result = Err(e);
                        break;
                    }
                }
                // Feeder gone before sending everything: it panicked.
                // Fall through to the join below for the payload.
                Err(_) => {
                    feeder_died = true;
                    break;
                }
            }
        }
        // Unblock a feeder parked on a full channel, then join it so a
        // panic is converted to an error instead of resumed by the scope.
        drop(rx);
        match feeder.join() {
            Err(p) => {
                let e = anyhow::anyhow!("extractor thread panicked: {}", panic_text(p.as_ref()));
                if result.is_ok() {
                    result = Err(e);
                }
            }
            Ok(()) if feeder_died && result.is_ok() => {
                result = Err(anyhow::anyhow!(
                    "extractor stopped after fewer than {nblocks} blocks"
                ));
            }
            Ok(()) => {}
        }
        result
    })
}

/// Extractor fan-out for the multi-lane engine: `workers` scoped threads
/// pull block ids off a shared counter (cheap work stealing — edge
/// blocks cost less than interior ones), call `extract`, then hand the
/// tile to `ship` (which typically submits an execute job to a
/// [`crate::runtime::pool::RuntimePool`] and blocks when the pool queue
/// is full).
///
/// The first `ship` error or worker panic stops the remaining workers
/// after their current block and is returned.
pub fn feed_blocks<T: Send>(
    nblocks: usize,
    workers: usize,
    extract: impl Fn(BlockId) -> T + Sync,
    ship: impl Fn(BlockId, T) -> crate::Result<()> + Sync,
) -> crate::Result<()> {
    if nblocks == 0 {
        return Ok(());
    }
    let workers = workers.clamp(1, nblocks);
    if workers == 1 {
        // Same panic-to-error contract as the threaded path below.
        for id in 0..nblocks {
            match catch_unwind(AssertUnwindSafe(|| ship(id, extract(id)))) {
                Ok(r) => r?,
                Err(p) => {
                    return Err(anyhow::anyhow!(
                        "extractor worker panicked: {}",
                        panic_text(p.as_ref())
                    ))
                }
            }
        }
        return Ok(());
    }

    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let fail = |e: anyhow::Error| {
        stop.store(true, Ordering::Release);
        let mut slot = first_err.lock().unwrap_or_else(PoisonError::into_inner);
        slot.get_or_insert(e);
    };
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| loop {
                    if stop.load(Ordering::Acquire) {
                        return;
                    }
                    // Relaxed: RMW atomicity alone guarantees each id
                    // is claimed once; the block plan the id indexes is
                    // pre-built and immutable, so the claim carries no
                    // payload to order (errors travel via `stop`'s
                    // Release/Acquire pair and the `first_err` mutex).
                    let id = next.fetch_add(1, Ordering::Relaxed);
                    if id >= nblocks {
                        return;
                    }
                    // Catch panics here, not at join: the stop flag must
                    // go up while the other workers are still pulling
                    // ids, or they would run the whole remaining plan
                    // before the error surfaced.
                    match catch_unwind(AssertUnwindSafe(|| ship(id, extract(id)))) {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => {
                            fail(e);
                            return;
                        }
                        Err(p) => {
                            fail(anyhow::anyhow!(
                                "extractor worker panicked: {}",
                                panic_text(p.as_ref())
                            ));
                            return;
                        }
                    }
                })
            })
            .collect();
        // Panics were converted in-thread; the join is just the barrier.
        for h in handles {
            let _ = h.join();
        }
    });
    match first_err.into_inner().unwrap_or_else(PoisonError::into_inner) {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicUsize, Ordering};
    use std::collections::HashSet;

    #[test]
    fn executes_all_blocks_in_order() {
        let n = 37;
        let extracted = AtomicUsize::new(0);
        let mut seen = Vec::new();
        run_pipelined(
            n,
            4,
            |id| {
                extracted.fetch_add(1, Ordering::SeqCst);
                id * 10
            },
            |id, t| {
                assert_eq!(t, id * 10);
                seen.push(id);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
        assert_eq!(extracted.load(Ordering::SeqCst), n);
    }

    #[test]
    fn sequential_fallback() {
        let mut seen = Vec::new();
        run_pipelined(2, 8, |id| id, |id, t| {
            assert_eq!(id, t);
            seen.push(id);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![0, 1]);
    }

    #[test]
    fn error_propagates() {
        let r = run_pipelined(10, 3, |id| id, |id, _| {
            if id == 5 {
                anyhow::bail!("boom")
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn zero_blocks_ok() {
        run_pipelined(0, 4, |id| id, |_, _| Ok(())).unwrap();
    }

    #[test]
    fn extractor_panic_becomes_error() {
        // Only the threaded path converts panics to errors; on a
        // single-core host run_pipelined runs sequentially and the
        // panic propagates in the caller, so there is nothing to test.
        if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) <= 1 {
            return;
        }
        // nblocks > 2 and lookahead > 1 so the threaded path runs.
        let r = run_pipelined(
            8,
            3,
            |id| {
                if id == 4 {
                    panic!("extract exploded on block {id}")
                }
                id
            },
            |_, _| Ok(()),
        );
        let err = r.expect_err("panic must surface as an error");
        let msg = format!("{err}");
        assert!(msg.contains("panicked"), "unexpected message: {msg}");
        assert!(msg.contains("extract exploded"), "payload lost: {msg}");
    }

    #[test]
    fn feed_blocks_covers_every_block_once() {
        let n = 101;
        let shipped: Mutex<HashSet<usize>> = Mutex::new(HashSet::new());
        feed_blocks(
            n,
            4,
            |id| id * 3,
            |id, t| {
                assert_eq!(t, id * 3);
                assert!(shipped.lock().unwrap().insert(id), "block {id} shipped twice");
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(shipped.lock().unwrap().len(), n);
    }

    #[test]
    fn feed_blocks_ship_error_stops_workers() {
        let n = 64;
        let count = AtomicUsize::new(0);
        let r = feed_blocks(
            n,
            4,
            |id| id,
            |_, t| {
                count.fetch_add(1, Ordering::SeqCst);
                if t == 10 {
                    anyhow::bail!("ship failed")
                }
                Ok(())
            },
        );
        assert!(r.is_err());
        // Workers stop after their in-progress block.  How many blocks
        // ran before the stop flag was observed is scheduling-dependent
        // (the other workers may legitimately drain everything first),
        // so only the error contract is asserted.
        assert!(count.load(Ordering::SeqCst) <= n);
    }

    #[test]
    fn feed_blocks_extract_panic_becomes_error() {
        let r = feed_blocks(
            32,
            3,
            |id| {
                if id == 7 {
                    panic!("bad tile")
                }
                id
            },
            |_, _| Ok(()),
        );
        let err = r.expect_err("panic must surface");
        assert!(format!("{err}").contains("bad tile"));
    }

    #[test]
    fn feed_blocks_zero_and_single_worker() {
        feed_blocks(0, 4, |id| id, |_, _| Ok(())).unwrap();
        let seen: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        feed_blocks(5, 1, |id| id, |id, _| {
            seen.lock().unwrap().push(id);
            Ok(())
        })
        .unwrap();
        assert_eq!(*seen.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }
}
