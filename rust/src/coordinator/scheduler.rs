//! Block-streaming scheduler.
//!
//! The PJRT client is `Rc`-based (not `Send`), so execution stays on the
//! coordinator thread; the scheduler instead pipelines the *marshalling*:
//! while block `i` executes, worker threads extract the halo'd tile for
//! block `i+1..i+depth` (double/treble buffering — the software analogue
//! of the thesis's load/compute overlap discussion in §4.3.1.6).
//!
//! The implementation uses scoped threads and a simple bounded queue of
//! pre-extracted tiles.  For small blocks the sequential path is used —
//! thread handoff would dominate.

use std::collections::VecDeque;
use std::sync::mpsc;

/// A unit of work: index into the block plan.
pub type BlockId = usize;

/// Runs `plan.len()` blocks: `extract(id)` produces the input tensors on
/// worker threads (in order), `execute(id, tile)` runs on this thread.
///
/// `lookahead` bounds in-flight extracted tiles (memory backpressure).
pub fn run_pipelined<T: Send>(
    nblocks: usize,
    lookahead: usize,
    extract: impl Fn(BlockId) -> T + Sync,
    mut execute: impl FnMut(BlockId, T) -> crate::Result<()>,
) -> crate::Result<()> {
    if nblocks == 0 {
        return Ok(());
    }
    // Small plans — or a single-core host, where a marshalling thread can
    // only steal cycles from execution (§Perf L3: sequential is ~4 %
    // faster at nproc=1) — run sequentially.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if nblocks <= 2 || lookahead <= 1 || cores <= 1 {
        for id in 0..nblocks {
            let t = extract(id);
            execute(id, t)?;
        }
        return Ok(());
    }

    std::thread::scope(|scope| -> crate::Result<()> {
        let (tx, rx) = mpsc::sync_channel::<(BlockId, T)>(lookahead);
        let extract_ref = &extract;
        scope.spawn(move || {
            for id in 0..nblocks {
                let t = extract_ref(id);
                if tx.send((id, t)).is_err() {
                    return; // consumer dropped (error path)
                }
            }
        });
        // Execution consumes in order; tiles arrive in order from the
        // single producer.
        let mut pending: VecDeque<(BlockId, T)> = VecDeque::new();
        for expect in 0..nblocks {
            let (id, t) = if let Some(front) = pending.pop_front() {
                front
            } else {
                rx.recv().map_err(|_| anyhow::anyhow!("extractor died"))?
            };
            debug_assert_eq!(id, expect);
            execute(id, t)?;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_blocks_in_order() {
        let n = 37;
        let extracted = AtomicUsize::new(0);
        let mut seen = Vec::new();
        run_pipelined(
            n,
            4,
            |id| {
                extracted.fetch_add(1, Ordering::SeqCst);
                id * 10
            },
            |id, t| {
                assert_eq!(t, id * 10);
                seen.push(id);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
        assert_eq!(extracted.load(Ordering::SeqCst), n);
    }

    #[test]
    fn sequential_fallback() {
        let mut seen = Vec::new();
        run_pipelined(2, 8, |id| id, |id, t| {
            assert_eq!(id, t);
            seen.push(id);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![0, 1]);
    }

    #[test]
    fn error_propagates() {
        let r = run_pipelined(10, 3, |id| id, |id, _| {
            if id == 5 {
                anyhow::bail!("boom")
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn zero_blocks_ok() {
        run_pipelined(0, 4, |id| id, |_, _| Ok(())).unwrap();
    }
}
