//! Throughput / latency accounting for coordinator runs.

use std::time::{Duration, Instant};

/// Accumulated run metrics, printed by examples and used in §Perf.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Blocks streamed through the compute units.
    pub blocks: u64,
    /// Valid (written-back) cell updates.
    pub cell_updates: u64,
    /// Time spent marshalling tensors into/out of PJRT buffers.
    pub extract: Duration,
    /// Time spent in PJRT execution (includes result fetch).
    pub execute: Duration,
    /// Time spent writing interiors back.
    pub writeback: Duration,
    /// End-to-end wall time.
    pub wall: Duration,
    /// Tile buffers served from the recycle pool (steady-state passes
    /// should be all hits — zero per-block allocations).
    pub pool_hits: u64,
    /// Tile buffers that had to be freshly allocated (pool warm-up).
    pub pool_misses: u64,
    /// i32 boundary-descriptor buffers served from the recycle pool.
    pub desc_pool_hits: u64,
    /// i32 boundary-descriptor buffers freshly allocated (warm-up).
    pub desc_pool_misses: u64,
    /// Deepest cross-wave overlap a wavefront run reached: the maximum
    /// number of waves spanned by in-flight blocks at any dispatch
    /// (1 = wave-serial; >1 only on the pipelined schedule).  0 when
    /// the run did not go through the wave driver.
    pub pipeline_depth_max: u64,
    /// Blocks that were dispatched while their previous wave was still
    /// incomplete — the work a per-wave barrier would have serialized.
    pub overlap_starts: u64,
    /// Retried job attempts (`Transient` faults under the pool's
    /// [`RetryPolicy`]).  0 on every fault-free run.
    ///
    /// [`RetryPolicy`]: crate::runtime::RetryPolicy
    pub job_retries: u64,
    /// Jobs that failed terminally (retry budget exhausted, or a
    /// `Fatal`/`Panic` fault).
    pub jobs_failed: u64,
    /// Lane threads respawned by the pool supervisor after a panic
    /// escaped job isolation.
    pub lane_restarts: u64,
    /// Budgeted jobs completed as `Timeout` by the pool watchdog (also
    /// counted in `jobs_failed`).  0 on every run without a
    /// `job_timeout`.
    pub job_timeouts: u64,
    /// Hung lane threads reaped (and replaced) by the pool watchdog.
    /// Disjoint from `lane_restarts`, which counts panic respawns.
    pub lanes_reaped: u64,
    /// Jobs a lane popped from its own run-queue shard (sharded
    /// scheduler only; 0 on the global-queue engine and at lanes=1).
    pub local_pops: u64,
    /// Jobs a lane stole from another lane's shard.  Steady-state runs
    /// should keep `local_pops` well above this.
    pub queue_steals: u64,
    /// Affinity-hinted jobs that ran on the lane they were hinted to.
    pub affinity_hits: u64,
    /// Affinity-hinted jobs stolen by a different lane.
    pub affinity_misses: u64,
    /// Successful CPU-affinity applications (lane spawns/respawns and
    /// extractor partners) under `Pinning::{Cores,Numa}`.
    pub pins_applied: u64,
    /// Pooled buffers dropped by the per-bucket high-water mark instead
    /// of being retained (arena-growth bound).
    pub pool_evictions: u64,
    /// Replay rounds launched by the wave driver: after a terminal
    /// block fault, the cancelled dependency cone is re-armed and
    /// re-driven under the run's `ReplayPolicy` instead of being
    /// reported as partial output.  0 on every fault-free run.
    pub cone_replays: u64,
    /// Total blocks re-driven across all replay rounds (failed blocks
    /// plus their cancelled cones).
    pub replay_blocks: u64,
}

impl Metrics {
    pub fn gcell_per_sec(&self) -> f64 {
        self.cell_updates as f64 / self.wall.as_secs_f64().max(1e-12) / 1e9
    }

    pub fn gflops(&self, flops_per_cell: f64) -> f64 {
        self.gcell_per_sec() * flops_per_cell
    }

    /// Coordinator overhead: fraction of wall time not in PJRT execute.
    pub fn overhead_frac(&self) -> f64 {
        let e = self.execute.as_secs_f64();
        let w = self.wall.as_secs_f64().max(1e-12);
        ((w - e) / w).max(0.0)
    }

    /// Fraction of tile-buffer requests served without allocating.
    pub fn pool_reuse_frac(&self) -> f64 {
        let total = self.pool_hits + self.pool_misses;
        if total == 0 {
            return 0.0;
        }
        self.pool_hits as f64 / total as f64
    }

    /// Point-in-time copy of the counters (e.g. a [`Session`]'s
    /// cumulative totals before they keep growing).
    ///
    /// [`Session`]: crate::coordinator::session::Session
    pub fn snapshot(&self) -> Metrics {
        self.clone()
    }

    /// Zero every counter.  A shared accumulator (the per-[`Session`]
    /// totals) resets between measurement windows instead of bleeding
    /// one run's counts into the next.
    ///
    /// [`Session`]: crate::coordinator::session::Session
    pub fn reset(&mut self) {
        *self = Metrics::default();
    }

    /// Fold another run's counters into this accumulator: counts and
    /// durations add, `pipeline_depth_max` keeps the deepest run.
    pub fn merge(&mut self, other: &Metrics) {
        // Exhaustive destructure (no `..`): adding a Metrics field
        // without deciding how it accumulates is a compile error here,
        // not a silently-zero counter in every session total.
        let Metrics {
            blocks,
            cell_updates,
            extract,
            execute,
            writeback,
            wall,
            pool_hits,
            pool_misses,
            desc_pool_hits,
            desc_pool_misses,
            pipeline_depth_max,
            overlap_starts,
            job_retries,
            jobs_failed,
            lane_restarts,
            job_timeouts,
            lanes_reaped,
            local_pops,
            queue_steals,
            affinity_hits,
            affinity_misses,
            pins_applied,
            pool_evictions,
            cone_replays,
            replay_blocks,
        } = other;
        self.blocks += blocks;
        self.cell_updates += cell_updates;
        self.extract += *extract;
        self.execute += *execute;
        self.writeback += *writeback;
        self.wall += *wall;
        self.pool_hits += pool_hits;
        self.pool_misses += pool_misses;
        self.desc_pool_hits += desc_pool_hits;
        self.desc_pool_misses += desc_pool_misses;
        self.pipeline_depth_max = self.pipeline_depth_max.max(*pipeline_depth_max);
        self.overlap_starts += overlap_starts;
        self.job_retries += job_retries;
        self.jobs_failed += jobs_failed;
        self.lane_restarts += lane_restarts;
        self.job_timeouts += job_timeouts;
        self.lanes_reaped += lanes_reaped;
        self.local_pops += local_pops;
        self.queue_steals += queue_steals;
        self.affinity_hits += affinity_hits;
        self.affinity_misses += affinity_misses;
        self.pins_applied += pins_applied;
        self.pool_evictions += pool_evictions;
        self.cone_replays += cone_replays;
        self.replay_blocks += replay_blocks;
    }

    pub fn summary(&self) -> String {
        let wave = if self.pipeline_depth_max > 0 {
            format!(
                " depth={} overlap={}",
                self.pipeline_depth_max, self.overlap_starts
            )
        } else {
            String::new()
        };
        let faults = if self.job_retries + self.jobs_failed + self.lane_restarts > 0 {
            format!(
                " retries={} failed={} lane-restarts={}",
                self.job_retries, self.jobs_failed, self.lane_restarts
            )
        } else {
            String::new()
        };
        let timeouts = if self.job_timeouts + self.lanes_reaped > 0 {
            format!(
                " timeouts={} lanes-reaped={}",
                self.job_timeouts, self.lanes_reaped
            )
        } else {
            String::new()
        };
        let replays = if self.cone_replays > 0 {
            format!(
                " cone-replays={} replay-blocks={}",
                self.cone_replays, self.replay_blocks
            )
        } else {
            String::new()
        };
        let locality = if self.local_pops + self.queue_steals > 0 {
            format!(
                " local-pops={} steals={} affinity={}/{}",
                self.local_pops,
                self.queue_steals,
                self.affinity_hits,
                self.affinity_hits + self.affinity_misses
            )
        } else {
            String::new()
        };
        format!(
            "blocks={} updates={} wall={:.3}s (marshal {:.1}% execute {:.1}% writeback {:.1}%) buf-reuse {:.0}%{wave}{faults}{timeouts}{replays}{locality} {:.3} GCell/s",
            self.blocks,
            self.cell_updates,
            self.wall.as_secs_f64(),
            100.0 * self.extract.as_secs_f64() / self.wall.as_secs_f64().max(1e-12),
            100.0 * self.execute.as_secs_f64() / self.wall.as_secs_f64().max(1e-12),
            100.0 * self.writeback.as_secs_f64() / self.wall.as_secs_f64().max(1e-12),
            100.0 * self.pool_reuse_frac(),
            self.gcell_per_sec(),
        )
    }
}

/// Scope timer that adds into a Duration on drop.
pub struct Timed<'a>(&'a mut Duration, Instant);

impl<'a> Timed<'a> {
    pub fn new(slot: &'a mut Duration) -> Self {
        Timed(slot, Instant::now())
    }
}

impl Drop for Timed<'_> {
    fn drop(&mut self) {
        *self.0 += self.1.elapsed();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_accumulates() {
        let mut d = Duration::ZERO;
        {
            let _t = Timed::new(&mut d);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(d >= Duration::from_millis(2));
    }

    #[test]
    fn merge_sums_counts_and_keeps_max_depth() {
        let mut a = Metrics {
            blocks: 3,
            cell_updates: 100,
            wall: Duration::from_secs(1),
            pool_hits: 5,
            pipeline_depth_max: 2,
            overlap_starts: 4,
            job_retries: 1,
            ..Default::default()
        };
        let b = Metrics {
            blocks: 7,
            cell_updates: 50,
            wall: Duration::from_secs(2),
            pool_hits: 1,
            pipeline_depth_max: 5,
            overlap_starts: 1,
            job_retries: 2,
            jobs_failed: 1,
            lane_restarts: 1,
            job_timeouts: 1,
            lanes_reaped: 1,
            local_pops: 40,
            queue_steals: 3,
            affinity_hits: 38,
            affinity_misses: 2,
            pins_applied: 4,
            pool_evictions: 6,
            cone_replays: 2,
            replay_blocks: 9,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.blocks, 10);
        assert_eq!(a.cell_updates, 150);
        assert_eq!(a.wall, Duration::from_secs(3));
        assert_eq!(a.pool_hits, 6);
        assert_eq!(a.pipeline_depth_max, 5, "depth keeps the max, not the sum");
        assert_eq!(a.overlap_starts, 5);
        assert_eq!(a.job_retries, 3);
        assert_eq!(a.jobs_failed, 1);
        assert_eq!(a.lane_restarts, 1);
        assert_eq!(a.job_timeouts, 1);
        assert_eq!(a.lanes_reaped, 1);
        assert_eq!(a.local_pops, 40);
        assert_eq!(a.queue_steals, 3);
        assert_eq!(a.affinity_hits, 38);
        assert_eq!(a.affinity_misses, 2);
        assert_eq!(a.pins_applied, 4);
        assert_eq!(a.pool_evictions, 6);
        assert_eq!(a.cone_replays, 2);
        assert_eq!(a.replay_blocks, 9);
    }

    #[test]
    fn summary_mentions_locality_only_when_scheduling_was_sharded() {
        let global = Metrics { blocks: 1, ..Default::default() };
        assert!(!global.summary().contains("local-pops="));
        let sharded = Metrics {
            blocks: 1,
            local_pops: 10,
            queue_steals: 2,
            affinity_hits: 9,
            affinity_misses: 1,
            ..Default::default()
        };
        assert!(sharded.summary().contains("local-pops=10 steals=2 affinity=9/10"));
    }

    #[test]
    fn summary_mentions_faults_only_when_present() {
        let clean = Metrics { blocks: 1, ..Default::default() };
        assert!(!clean.summary().contains("retries="));
        let faulty = Metrics { blocks: 1, job_retries: 2, ..Default::default() };
        assert!(faulty.summary().contains("retries=2 failed=0 lane-restarts=0"));
        assert!(!faulty.summary().contains("cone-replays="));
        assert!(!faulty.summary().contains("timeouts="));
        let timed_out = Metrics {
            blocks: 1,
            jobs_failed: 1,
            job_timeouts: 1,
            lanes_reaped: 1,
            ..Default::default()
        };
        assert!(timed_out.summary().contains("timeouts=1 lanes-reaped=1"));
        let replayed = Metrics {
            blocks: 1,
            cone_replays: 1,
            replay_blocks: 4,
            ..Default::default()
        };
        assert!(replayed.summary().contains("cone-replays=1 replay-blocks=4"));
    }

    #[test]
    fn snapshot_then_reset_leaves_zeroes() {
        let mut m = Metrics { blocks: 9, ..Default::default() };
        let snap = m.snapshot();
        m.reset();
        assert_eq!(snap.blocks, 9);
        assert_eq!(m.blocks, 0);
        assert_eq!(m.wall, Duration::ZERO);
    }

    #[test]
    fn gcell_rate() {
        let m = Metrics {
            cell_updates: 2_000_000_000,
            wall: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.gcell_per_sec() - 1.0).abs() < 1e-9);
    }
}
