//! Cross-pass pipelined pass driver: dependency-tracked async writeback.
//!
//! The thesis's headline stencil result comes from *combining* spatial
//! and temporal blocking so the accelerator never drains between time
//! steps (§5.3; see also arXiv:1802.00438).  PR 1's lane engine still
//! inserted a full `wait_idle` barrier after every pass — the lanes
//! idled exactly where the paper's deep pipeline keeps flowing.  This
//! module removes that barrier by making cross-pass dependencies
//! explicit:
//!
//! > a block of pass `p+1` becomes runnable as soon as the blocks of
//! > pass `p` that overlap its `r·T`-wide halo neighborhood have
//! > written back.
//!
//! [`DepTable`] tracks that rule with per-block completion counters
//! over the block-origin lattice; [`ReadyQueue`] holds the runnable
//! (pass, block) frontier.  Because the two grid buffers alternate
//! roles every pass (pass `p` reads buffer `p % 2` and writes buffer
//! `(p+1) % 2`), the same neighbor rule also covers the
//! write-after-read hazard: the pass-`p` blocks that *read* the cells a
//! pass-`p+1` block will overwrite are exactly its halo neighbors, and
//! they extracted (copied) their tiles before completing.  By
//! induction the rule stays sound at any pipeline depth with just two
//! buffers.
//!
//! The driver itself is generic over a [`StencilSpace`] — the
//! Grid/Writer abstraction the runners configure (tile extraction,
//! interior write-back, buffer pooling) — and comes in two backends:
//!
//! * [`drive_single`] — one [`Runtime`]: execution pinned to the
//!   caller's thread, one extractor thread feeding dependency-ready
//!   tiles through a bounded channel (the pipelined path of PR 1,
//!   now free to cross pass boundaries);
//! * [`drive_pool`] — a [`RuntimePool`]: M extractor workers pull
//!   ready blocks, lanes execute and write back, and each job's
//!   completion callback ([`RuntimePool::submit_tracked`]) advances
//!   the dependency table — no per-pass barrier anywhere.
//!
//! Results are bit-identical to the barrier schedule for any lane
//! count: each block's inputs are fully determined by its predecessor
//! blocks, interiors are disjoint, and per-block compute is identical.
//! [`PassMode::Barrier`] keeps the old schedule available (every
//! pass-`p+1` block waits for *all* of pass `p`) as the baseline the
//! CI perf gate compares against.
//!
//! Memory ordering: a completing thread write-backs the block, then
//! decrements successor counters with `AcqRel` RMWs, and the thread
//! whose decrement hits zero pushes the successor through the ready
//! queue's mutex.  The RMW chain plus the mutex hand-off order every
//! predecessor's grid writes before any extraction of the successor's
//! tile.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::panic_text;
use crate::runtime::pool::IdleGuard;
use crate::runtime::{Runtime, RuntimePool, Tensor};

/// Inter-pass scheduling regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassMode {
    /// Every pass-`p+1` block waits for *all* pass-`p` blocks — the
    /// PR 1 `wait_idle`-per-pass schedule, kept as the CI baseline.
    Barrier,
    /// A pass-`p+1` block runs as soon as its `r·T` halo-overlapping
    /// pass-`p` predecessors have written back (default).
    Pipelined,
}

/// The Grid/Writer configuration a pass driver runs over: how to cut a
/// workload into blocks, extract a block's kernel inputs, and write a
/// block's output interior — plus the buffer pools behind both.
///
/// Implementations are dimension- and workload-specific shims (see
/// `stencil_runner::Space2D/Space3D`); the driver owns everything else:
/// dependency tracking, lane feeding, double-buffer alternation and
/// metrics finalization.
pub trait StencilSpace: Send + Sync {
    /// Raw shared handle over one grid buffer (read + write); the
    /// driver holds one per double-buffer half.
    type Handle: Copy + Send + Sync + 'static;

    /// Blocks per pass.
    fn nblocks(&self) -> usize;

    /// Block-origin lattice extents, padded to 3 axes with leading 1s
    /// (a 2D workload reports `[1, nby, nbx]`).
    fn lattice(&self) -> [usize; 3];

    /// Per-axis dependency reach in lattice units:
    /// `ceil(halo / block)` (0 on degenerate axes).
    fn reach(&self) -> [usize; 3];

    /// Extract block `block`'s kernel input tensors from `src`.
    ///
    /// # Safety
    ///
    /// The caller must guarantee (via the dependency table) that no
    /// thread is concurrently writing any cell the tile reads, and
    /// that the handle's grid is live.
    unsafe fn extract(&self, src: Self::Handle, block: usize) -> Vec<Tensor>;

    /// Write block `block`'s kernel output interior into `dst`.
    ///
    /// # Safety
    ///
    /// Concurrent writes target pairwise-disjoint interiors (the block
    /// plan guarantees this) and the handle's grid must be live.
    unsafe fn write(&self, dst: Self::Handle, block: usize, out: &[f32]);

    /// Return recyclable input buffers to the space's pools.
    fn recycle(&self, inputs: Vec<Tensor>);

    /// (tile hits, tile misses, descriptor hits, descriptor misses).
    fn pool_counters(&self) -> (u64, u64, u64, u64);
}

/// Per-block completion counters over the block-origin lattice: block
/// `i` of pass `p+1` is runnable once `remaining[p][i]` predecessors of
/// pass `p` have completed.
pub struct DepTable {
    dims: [usize; 3],
    reach: [usize; 3],
    nblocks: usize,
    passes: usize,
    barrier: bool,
    /// `remaining[p * nblocks + i]`: incomplete pass-`p` predecessors
    /// of block `i` in pass `p+1` (slot `p` gates pass `p+1`).
    remaining: Vec<AtomicU32>,
}

impl DepTable {
    pub fn new(dims: [usize; 3], reach: [usize; 3], passes: usize, mode: PassMode) -> DepTable {
        let nblocks = dims[0] * dims[1] * dims[2];
        let mut t = DepTable {
            dims,
            reach,
            nblocks,
            passes,
            barrier: mode == PassMode::Barrier,
            remaining: Vec::new(),
        };
        if passes > 1 {
            t.remaining.reserve(passes.saturating_sub(1) * nblocks);
            for _p in 1..passes {
                for i in 0..nblocks {
                    t.remaining.push(AtomicU32::new(t.pred_count(i) as u32));
                }
            }
        }
        t
    }

    fn coord(&self, i: usize) -> [usize; 3] {
        [
            i / (self.dims[1] * self.dims[2]),
            (i / self.dims[2]) % self.dims[1],
            i % self.dims[2],
        ]
    }

    /// Visit the lattice neighborhood of block `i`: the blocks whose
    /// interiors overlap `i`'s `r·T`-halo'd tile (clipped to the
    /// lattice).  The relation is symmetric, so the same set is both
    /// `i`'s predecessors in the previous pass and the successors `i`
    /// unblocks in the next.
    fn neighborhood(&self, i: usize, mut f: impl FnMut(usize)) {
        if self.barrier {
            for j in 0..self.nblocks {
                f(j);
            }
            return;
        }
        let c = self.coord(i);
        let lo = |a: usize| c[a].saturating_sub(self.reach[a]);
        let hi = |a: usize| (c[a] + self.reach[a]).min(self.dims[a] - 1);
        for z in lo(0)..=hi(0) {
            for y in lo(1)..=hi(1) {
                for x in lo(2)..=hi(2) {
                    f((z * self.dims[1] + y) * self.dims[2] + x);
                }
            }
        }
    }

    /// Number of predecessors of block `i` (= its clipped neighborhood
    /// size; the neighbor relation is symmetric).
    fn pred_count(&self, i: usize) -> usize {
        if self.barrier {
            return self.nblocks;
        }
        let c = self.coord(i);
        let mut n = 1usize;
        for a in 0..3 {
            let lo = c[a].saturating_sub(self.reach[a]);
            let hi = (c[a] + self.reach[a]).min(self.dims[a] - 1);
            n *= hi - lo + 1;
        }
        n
    }

    /// Record the completion (write-back done) of `block` in `pass`;
    /// appends every pass-`p+1` block this makes runnable to `ready`.
    pub fn complete(&self, pass: usize, block: usize, ready: &mut Vec<(usize, usize)>) {
        if pass + 1 >= self.passes {
            return;
        }
        let base = pass * self.nblocks;
        self.neighborhood(block, |j| {
            // AcqRel: the RMW chain orders every predecessor's grid
            // write-back before the final decrement, whose thread then
            // publishes `j` through the ready queue's mutex.
            if self.remaining[base + j].fetch_sub(1, Ordering::AcqRel) == 1 {
                ready.push((pass + 1, j));
            }
        });
    }
}

/// The runnable (pass, block) frontier.  `pop` blocks until an item is
/// ready, every item has been dispatched, or the run aborts.
pub struct ReadyQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    total: usize,
}

struct QueueState {
    ready: VecDeque<(usize, usize)>,
    dispatched: usize,
    aborted: bool,
}

impl ReadyQueue {
    pub fn new(total: usize, seed: impl IntoIterator<Item = (usize, usize)>) -> ReadyQueue {
        ReadyQueue {
            state: Mutex::new(QueueState {
                ready: seed.into_iter().collect(),
                dispatched: 0,
                aborted: false,
            }),
            cv: Condvar::new(),
            total,
        }
    }

    pub fn push_all(&self, items: &[(usize, usize)]) {
        if items.is_empty() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        st.ready.extend(items.iter().copied());
        drop(st);
        self.cv.notify_all();
    }

    /// Next runnable item, or `None` once all `total` items have been
    /// dispatched (or the run aborted).
    pub fn pop(&self) -> Option<(usize, usize)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return None;
            }
            if let Some(item) = st.ready.pop_front() {
                st.dispatched += 1;
                if st.dispatched >= self.total {
                    // Wake peers parked on an empty queue so they can
                    // observe completion and exit.
                    self.cv.notify_all();
                }
                return Some(item);
            }
            if st.dispatched >= self.total {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Abandon the run: wakes and releases every `pop`per.
    pub fn abort(&self) {
        self.state.lock().unwrap().aborted = true;
        self.cv.notify_all();
    }
}

/// Fold the driver-side counters and runtime-stat deltas into a
/// [`Metrics`].
#[allow(clippy::too_many_arguments)]
fn finalize_metrics<S: StencilSpace>(
    space: &S,
    wall: Instant,
    blocks: u64,
    writeback: Duration,
    cell_updates: u64,
    execute_ms: f64,
    marshal_ms: f64,
) -> Metrics {
    let (pool_hits, pool_misses, desc_pool_hits, desc_pool_misses) = space.pool_counters();
    Metrics {
        blocks,
        cell_updates,
        extract: Duration::from_secs_f64(marshal_ms.max(0.0) / 1e3),
        execute: Duration::from_secs_f64(execute_ms.max(0.0) / 1e3),
        writeback,
        wall: wall.elapsed(),
        pool_hits,
        pool_misses,
        desc_pool_hits,
        desc_pool_misses,
    }
}

/// Dependency-ordered pass streaming with a caller-provided executor —
/// the core of [`drive_single`], factored out so the scheduling
/// machinery is testable without PJRT artifacts.  `exec` runs on the
/// calling thread (the PJRT client is `Rc`-based); one extractor thread
/// feeds ready tiles through a bounded channel of depth `lookahead`.
///
/// Returns `(blocks completed, writeback time)`.
pub fn drive_local<S: StencilSpace>(
    mut exec: impl FnMut(usize, &[Tensor]) -> crate::Result<Vec<f32>>,
    space: &S,
    handles: [S::Handle; 2],
    passes: usize,
    lookahead: usize,
) -> crate::Result<(u64, Duration)> {
    let nblocks = space.nblocks();
    let total = passes.saturating_mul(nblocks);
    if total == 0 {
        return Ok((0, Duration::ZERO));
    }
    let table = DepTable::new(space.lattice(), space.reach(), passes, PassMode::Pipelined);
    let queue = ReadyQueue::new(total, (0..nblocks).map(|i| (0usize, i)));
    let mut writeback = Duration::ZERO;
    let mut blocks = 0u64;
    let mut newly = Vec::new();

    // Small plans — or a single-core host, where a marshalling thread
    // can only steal cycles from execution — run sequentially.
    // Completions are synchronous here, so whenever work remains the
    // ready queue is non-empty and `pop` never parks.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if total <= 2 || lookahead <= 1 || cores <= 1 {
        while let Some((pass, block)) = queue.pop() {
            // SAFETY: dependency order — every cell this tile reads was
            // written by an already-completed predecessor (or the seed).
            let inputs = unsafe { space.extract(handles[pass % 2], block) };
            let out = exec(block, &inputs)?;
            let t0 = Instant::now();
            // SAFETY: disjoint interiors on the block lattice.
            unsafe { space.write(handles[(pass + 1) % 2], block, &out) };
            writeback += t0.elapsed();
            blocks += 1;
            newly.clear();
            table.complete(pass, block, &mut newly);
            queue.push_all(&newly);
            space.recycle(inputs);
        }
        return Ok((blocks, writeback));
    }

    std::thread::scope(|sc| -> crate::Result<()> {
        let (tx, rx) = mpsc::sync_channel::<(usize, usize, Vec<Tensor>)>(lookahead);
        let queue_ref = &queue;
        let feeder = sc.spawn(move || {
            while let Some((pass, block)) = queue_ref.pop() {
                // SAFETY: dependency order, as above — `pop` only hands
                // out blocks whose predecessors have written back.
                let inputs = unsafe { space.extract(handles[pass % 2], block) };
                if tx.send((pass, block, inputs)).is_err() {
                    return; // consumer dropped (error path)
                }
            }
        });
        let mut result: crate::Result<()> = Ok(());
        let mut feeder_died = false;
        for _ in 0..total {
            match rx.recv() {
                Ok((pass, block, inputs)) => match exec(block, &inputs) {
                    Ok(out) => {
                        let t0 = Instant::now();
                        // SAFETY: disjoint interiors.
                        unsafe { space.write(handles[(pass + 1) % 2], block, &out) };
                        writeback += t0.elapsed();
                        blocks += 1;
                        newly.clear();
                        table.complete(pass, block, &mut newly);
                        queue.push_all(&newly);
                        space.recycle(inputs);
                    }
                    Err(e) => {
                        result = Err(e);
                        break;
                    }
                },
                // Feeder gone before sending everything: it panicked.
                Err(_) => {
                    feeder_died = true;
                    break;
                }
            }
        }
        // Unblock a feeder parked on the ready queue or a full channel,
        // then join it so a panic converts to an error instead of being
        // resumed by the scope.
        queue.abort();
        drop(rx);
        match feeder.join() {
            Err(p) => {
                let e = anyhow!("extractor thread panicked: {}", panic_text(p.as_ref()));
                if result.is_ok() {
                    result = Err(e);
                }
            }
            Ok(()) if feeder_died && result.is_ok() => {
                result = Err(anyhow!("extractor stopped after fewer than {total} blocks"));
            }
            Ok(()) => {}
        }
        result
    })?;
    Ok((blocks, writeback))
}

/// Run `passes` dependency-pipelined passes on a single [`Runtime`] and
/// finalize the [`Metrics`] (the caller compiles the artifact outside
/// the timed region first).
pub fn drive_single<S: StencilSpace>(
    rt: &Runtime,
    artifact: &str,
    space: &S,
    handles: [S::Handle; 2],
    passes: usize,
    cell_updates: u64,
) -> crate::Result<Metrics> {
    let stats0 = rt.stats();
    let wall = Instant::now();
    let (blocks, writeback) = drive_local(
        |_block, inputs| rt.execute_f32(artifact, inputs),
        space,
        handles,
        passes,
        4,
    )?;
    let stats = rt.stats();
    Ok(finalize_metrics(
        space,
        wall,
        blocks,
        writeback,
        cell_updates,
        stats.execute_ms - stats0.execute_ms,
        stats.marshal_ms - stats0.marshal_ms,
    ))
}

/// Run `passes` passes on a [`RuntimePool`]: `extractors` workers pull
/// dependency-ready blocks, the lanes execute and write back, and each
/// job's completion callback advances the dependency table — there is
/// no per-pass barrier; the single [`RuntimePool::wait_idle`] at the
/// end only closes out the run.  (The caller warms the artifact on
/// every lane outside the timed region first.)
#[allow(clippy::too_many_arguments)]
pub fn drive_pool<S: StencilSpace>(
    pool: &RuntimePool,
    artifact: &str,
    space: &Arc<S>,
    handles: [S::Handle; 2],
    passes: usize,
    mode: PassMode,
    extractors: usize,
    cell_updates: u64,
) -> crate::Result<Metrics>
where
    S: 'static,
{
    let stats0 = pool.stats();
    let wall = Instant::now();
    let nblocks = space.nblocks();
    let total = passes.saturating_mul(nblocks);
    let done_blocks = Arc::new(AtomicU64::new(0));
    let wb_nanos = Arc::new(AtomicU64::new(0));

    if total > 0 {
        let table = Arc::new(DepTable::new(space.lattice(), space.reach(), passes, mode));
        let queue = Arc::new(ReadyQueue::new(total, (0..nblocks).map(|i| (0usize, i))));
        let artifact_arc: Arc<str> = Arc::from(artifact);
        let extractors = extractors.clamp(1, nblocks);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);

        // SAFETY-relevant: jobs borrow the caller's grids through raw
        // handles; the IdleGuard drains the lanes before this frame's
        // grids can be freed, even on an unwinding exit.
        let guard = IdleGuard::new(pool);
        std::thread::scope(|sc| {
            for _ in 0..extractors {
                sc.spawn(|| {
                    while let Some((pass, block)) = queue.pop() {
                        let src = handles[pass % 2];
                        let dst = handles[(pass + 1) % 2];
                        // Catch extraction panics here so the other
                        // workers and the lanes stop promptly instead
                        // of draining the whole remaining plan.
                        let extracted = catch_unwind(AssertUnwindSafe(|| {
                            // SAFETY: dependency order via the ready
                            // queue — predecessors have written back.
                            unsafe { space.extract(src, block) }
                        }));
                        let inputs = match extracted {
                            Ok(inputs) => inputs,
                            Err(p) => {
                                queue.abort();
                                first_err.lock().unwrap().get_or_insert(anyhow!(
                                    "extractor worker panicked: {}",
                                    panic_text(p.as_ref())
                                ));
                                return;
                            }
                        };
                        let artifact = artifact_arc.clone();
                        let space_j = space.clone();
                        let done_j = done_blocks.clone();
                        let wb_j = wb_nanos.clone();
                        let table_j = table.clone();
                        let queue_j = queue.clone();
                        pool.submit_tracked(
                            move |_lane, rt| {
                                let out = rt.execute_f32(&artifact, &inputs)?;
                                let t0 = Instant::now();
                                // SAFETY: disjoint interiors on the
                                // block lattice.
                                unsafe { space_j.write(dst, block, &out) };
                                wb_j.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                                done_j.fetch_add(1, Ordering::Relaxed);
                                space_j.recycle(inputs);
                                Ok(())
                            },
                            move |ok| {
                                if ok {
                                    let mut newly = Vec::new();
                                    table_j.complete(pass, block, &mut newly);
                                    queue_j.push_all(&newly);
                                } else {
                                    // Failed or skipped job: its
                                    // successors can never run; release
                                    // the extractors.
                                    queue_j.abort();
                                }
                            },
                        );
                    }
                });
            }
        });
        // Drain the lanes (the only wait_idle of the whole run), then
        // surface extractor-side and lane-side failures in that order.
        let idle = pool.wait_idle();
        drop(guard);
        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        idle?;
    }

    let stats = pool.stats();
    Ok(finalize_metrics(
        space.as_ref(),
        wall,
        done_blocks.load(Ordering::Relaxed),
        Duration::from_nanos(wb_nanos.load(Ordering::Relaxed)),
        cell_updates,
        stats.execute_ms - stats0.execute_ms,
        stats.marshal_ms - stats0.marshal_ms,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::bufpool::TensorPools;
    use crate::coordinator::grid::{Boundary, Grid2D, GridWriter2D};
    use std::collections::HashSet;

    // ---------- DepTable scheduling-invariant tests ----------

    /// Simulation harness: processes items popped off a ReadyQueue one
    /// at a time (choosing among the currently-ready set by `pick`),
    /// asserting before each completion that every halo-overlapping
    /// predecessor already completed.
    fn simulate(
        dims: [usize; 3],
        reach: [usize; 3],
        passes: usize,
        mode: PassMode,
        mut pick: impl FnMut(usize) -> usize,
    ) {
        let nblocks = dims[0] * dims[1] * dims[2];
        let table = DepTable::new(dims, reach, passes, mode);
        let mut ready: Vec<(usize, usize)> = (0..nblocks).map(|i| (0, i)).collect();
        let mut completed: HashSet<(usize, usize)> = HashSet::new();
        let mut dispatched = 0usize;
        while !ready.is_empty() {
            let idx = pick(ready.len()) % ready.len();
            let (pass, block) = ready.swap_remove(idx);
            dispatched += 1;
            // The invariant: every predecessor in the halo neighborhood
            // (or the whole previous pass, in Barrier mode) completed.
            if pass > 0 {
                table.neighborhood(block, |j| {
                    assert!(
                        completed.contains(&(pass - 1, j)),
                        "block (p={pass}, i={block}) scheduled before \
                         predecessor (p={}, i={j}) completed",
                        pass - 1
                    );
                });
            }
            assert!(completed.insert((pass, block)), "double-scheduled");
            let mut newly = Vec::new();
            table.complete(pass, block, &mut newly);
            ready.extend(newly);
        }
        assert_eq!(dispatched, passes * nblocks, "not every block ran");
    }

    #[test]
    fn dep_table_exhaustive_small_grids() {
        // Exhaustive over pick-order variation for a family of small
        // lattices: every (dims, reach, passes) runs under many
        // deterministic orderings (LIFO, FIFO, and rotating offsets).
        let cases: &[([usize; 3], [usize; 3], usize)] = &[
            ([1, 2, 2], [0, 1, 1], 2),
            ([1, 2, 2], [0, 1, 1], 3),
            ([1, 3, 4], [0, 1, 1], 3),
            ([1, 4, 1], [0, 1, 0], 4),
            ([2, 2, 2], [1, 1, 1], 3),
            ([3, 3, 3], [1, 1, 1], 2),
            ([1, 3, 3], [0, 2, 2], 3), // halo wider than one block
            ([1, 3, 3], [0, 0, 0], 3), // halo 0: self-dependency only
            ([1, 1, 1], [0, 1, 1], 5), // single block
        ];
        for &(dims, reach, passes) in cases {
            for order in 0..7usize {
                simulate(dims, reach, passes, PassMode::Pipelined, |len| match order {
                    0 => 0,              // FIFO
                    1 => len - 1,        // LIFO
                    k => (k * 131) % len // rotating picks
                });
            }
        }
    }

    #[test]
    fn dep_table_randomized_orders() {
        let mut rng = crate::testutil::Rng::new(42);
        for _ in 0..25 {
            let dims = [1, rng.usize_in(1, 4), rng.usize_in(1, 4)];
            let reach = [0, rng.usize_in(0, 2), rng.usize_in(0, 2)];
            let passes = rng.usize_in(1, 4);
            let mut r2 = crate::testutil::Rng::new(rng.next_u64());
            simulate(dims, reach, passes, PassMode::Pipelined, move |len| {
                r2.usize_in(0, len - 1)
            });
        }
    }

    #[test]
    fn dep_table_barrier_mode_waits_for_whole_pass() {
        let dims = [1, 2, 3];
        let nblocks = 6;
        let table = DepTable::new(dims, [0, 1, 1], 2, PassMode::Barrier);
        let mut newly = Vec::new();
        for i in 0..nblocks - 1 {
            table.complete(0, i, &mut newly);
            assert!(newly.is_empty(), "pass 1 released after only {} completions", i + 1);
        }
        table.complete(0, nblocks - 1, &mut newly);
        let ready: HashSet<usize> = newly.iter().map(|&(p, i)| {
            assert_eq!(p, 1);
            i
        }).collect();
        assert_eq!(ready.len(), nblocks, "all pass-1 blocks release together");
    }

    #[test]
    fn dep_table_interior_block_needs_nine_neighbors_2d() {
        // 3x3 lattice, reach 1: the center block of pass 1 must wait
        // for all 9 pass-0 blocks; a corner only for its 4 neighbors.
        let table = DepTable::new([1, 3, 3], [0, 1, 1], 2, PassMode::Pipelined);
        assert_eq!(table.pred_count(4), 9); // center
        assert_eq!(table.pred_count(0), 4); // corner
        assert_eq!(table.pred_count(1), 6); // edge
    }

    #[test]
    fn dep_table_completion_counts_match_pred_counts() {
        // Sum of decrements each pass-1 block receives over a full
        // pass-0 sweep equals its initial predecessor count (the
        // neighbor relation is symmetric).
        let dims = [2, 3, 4];
        let nblocks = 24;
        for reach in [[0, 0, 0], [1, 1, 1], [0, 1, 2]] {
            let table = DepTable::new(dims, reach, 2, PassMode::Pipelined);
            let mut newly = Vec::new();
            for i in 0..nblocks {
                table.complete(0, i, &mut newly);
            }
            let set: HashSet<usize> = newly.iter().map(|&(_, i)| i).collect();
            assert_eq!(set.len(), nblocks, "reach {reach:?}: every block released exactly once");
        }
    }

    #[test]
    fn ready_queue_counts_and_aborts() {
        let q = ReadyQueue::new(3, [(0usize, 0usize), (0, 1)]);
        assert_eq!(q.pop(), Some((0, 0)));
        q.push_all(&[(1, 0)]);
        assert_eq!(q.pop(), Some((0, 1)));
        assert_eq!(q.pop(), Some((1, 0)));
        assert_eq!(q.pop(), None, "all dispatched");

        let q = ReadyQueue::new(5, [(0usize, 0usize)]);
        q.abort();
        assert_eq!(q.pop(), None, "aborted queue releases poppers");
    }

    #[test]
    fn ready_queue_releases_parked_threads_on_final_dispatch() {
        let q = std::sync::Arc::new(ReadyQueue::new(2, [(0usize, 0usize), (0, 1)]));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let q = q.clone();
                s.spawn(move || while q.pop().is_some() {});
            }
        }); // must not hang
    }

    // ---------- drive_local end-to-end (fake kernel, no artifacts) ----------

    /// Minimal 2D StencilSpace over raw grid handles: block lattice,
    /// halo extraction, interior write-back — enough to run the real
    /// driver with a native-Rust kernel.
    struct TestSpace2D {
        origins: Vec<(usize, usize)>,
        lattice: [usize; 3],
        reach: [usize; 3],
        ny: usize,
        nx: usize,
        block: usize,
        halo: usize,
        tile: usize,
        pools: TensorPools,
    }

    impl TestSpace2D {
        fn new(ny: usize, nx: usize, block: usize, halo: usize) -> TestSpace2D {
            let mut origins = Vec::new();
            let mut y0 = 0;
            while y0 < ny {
                let mut x0 = 0;
                while x0 < nx {
                    origins.push((y0, x0));
                    x0 += block;
                }
                y0 += block;
            }
            let nby = ny.div_ceil(block);
            let nbx = nx.div_ceil(block);
            let reach_b = halo.div_ceil(block);
            TestSpace2D {
                origins,
                lattice: [1, nby, nbx],
                reach: [0, reach_b, reach_b],
                ny,
                nx,
                block,
                halo,
                tile: block + 2 * halo,
                pools: TensorPools::default(),
            }
        }
    }

    impl StencilSpace for TestSpace2D {
        type Handle = GridWriter2D;

        fn nblocks(&self) -> usize {
            self.origins.len()
        }
        fn lattice(&self) -> [usize; 3] {
            self.lattice
        }
        fn reach(&self) -> [usize; 3] {
            self.reach
        }
        unsafe fn extract(&self, src: GridWriter2D, block: usize) -> Vec<Tensor> {
            let (y0, x0) = self.origins[block];
            let mut t = self.pools.tiles.take(self.tile * self.tile);
            src.extract_tile_into(
                y0 as isize, x0 as isize, self.tile, self.tile, self.halo,
                Boundary::Zero, &mut t,
            );
            vec![Tensor::F32(t, vec![self.tile, self.tile])]
        }
        unsafe fn write(&self, dst: GridWriter2D, block: usize, out: &[f32]) {
            let (y0, x0) = self.origins[block];
            dst.write_block(y0, x0, self.block, self.block, out);
        }
        fn recycle(&self, inputs: Vec<Tensor>) {
            self.pools.recycle(inputs);
        }
        fn pool_counters(&self) -> (u64, u64, u64, u64) {
            (
                self.pools.tiles.hits(),
                self.pools.tiles.misses(),
                self.pools.descs.hits(),
                self.pools.descs.misses(),
            )
        }
    }

    /// The fake compute unit: one T=1 five-point average over the
    /// halo'd tile, returning the block interior.  Deterministic f32
    /// arithmetic, so any valid schedule must be bitwise identical.
    fn blur_kernel(tile: usize, halo: usize, block: usize, t: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; block * block];
        for by in 0..block {
            for bx in 0..block {
                let y = by + halo;
                let x = bx + halo;
                let c = t[y * tile + x];
                let up = t[(y - 1) * tile + x];
                let dn = t[(y + 1) * tile + x];
                let lf = t[y * tile + x - 1];
                let rt = t[y * tile + x + 1];
                out[by * block + bx] = 0.2 * (c + up + dn + lf + rt);
            }
        }
        out
    }

    /// Reference: the same kernel applied pass-by-pass with a full
    /// barrier (plain double-buffered sweep).
    fn blur_reference(mut g: Grid2D, passes: usize) -> Grid2D {
        for _ in 0..passes {
            let mut next = Grid2D::zeros(g.ny, g.nx);
            for y in 0..g.ny {
                for x in 0..g.nx {
                    let r = |yy: isize, xx: isize| g.read(yy, xx, Boundary::Zero);
                    let y = y as isize;
                    let x = x as isize;
                    next.data[(y * g.nx as isize + x) as usize] = 0.2
                        * (r(y, x) + r(y - 1, x) + r(y + 1, x) + r(y, x - 1) + r(y, x + 1));
                }
            }
            g = next;
        }
        g
    }

    fn run_driver_case(ny: usize, nx: usize, block: usize, passes: usize, lookahead: usize) {
        let halo = 1; // r·T = 1 for the five-point blur
        let mut rng = crate::testutil::Rng::new(7);
        let init = Grid2D { ny, nx, data: rng.vec_f32(ny * nx, 0.0, 1.0) };
        let want = blur_reference(init.clone(), passes);

        let space = TestSpace2D::new(ny, nx, block, halo);
        let mut cur = init;
        let mut next = Grid2D::zeros(ny, nx);
        let handles = unsafe { [cur.shared_writer(), next.shared_writer()] };
        let tile = space.tile;
        let (blocks, _) = drive_local(
            |_b, inputs| Ok(blur_kernel(tile, halo, block, inputs[0].as_f32())),
            &space,
            handles,
            passes,
            lookahead,
        )
        .unwrap();
        assert_eq!(blocks as usize, passes * space.nblocks());
        let got = if passes % 2 == 0 { cur } else { next };
        assert_eq!(got.data, want.data, "{ny}x{nx} block={block} passes={passes}");
    }

    #[test]
    fn drive_local_matches_barrier_reference_bitwise() {
        // Pipelined cross-pass schedule == plain barriered sweep,
        // bitwise, across geometries (including partial edge blocks).
        run_driver_case(8, 8, 4, 3, 4);
        run_driver_case(12, 10, 4, 4, 4); // partial blocks
        run_driver_case(6, 6, 2, 5, 4); // deep pipeline, many small blocks
        run_driver_case(4, 4, 4, 2, 4); // single-block lattice
        run_driver_case(9, 7, 3, 3, 2); // odd geometry, small lookahead
    }

    #[test]
    fn drive_local_sequential_fallback_matches() {
        // lookahead 1 forces the sequential path.
        run_driver_case(8, 8, 4, 3, 1);
    }

    #[test]
    fn drive_local_steady_state_reuses_tiles() {
        let space = TestSpace2D::new(8, 8, 4, 1);
        let mut cur = Grid2D::from_fn(8, 8, |y, x| (y * 8 + x) as f32);
        let mut next = Grid2D::zeros(8, 8);
        let handles = unsafe { [cur.shared_writer(), next.shared_writer()] };
        let tile = space.tile;
        drive_local(
            |_b, inputs| Ok(blur_kernel(tile, 1, 4, inputs[0].as_f32())),
            &space,
            handles,
            4,
            1, // sequential: one tile in flight
        )
        .unwrap();
        let (hits, misses, _, _) = space.pool_counters();
        assert_eq!(misses, 1, "steady state allocates exactly the in-flight tile");
        assert_eq!(hits, 4 * space.nblocks() as u64 - 1);
    }

    #[test]
    fn drive_local_error_propagates_and_stops() {
        let space = TestSpace2D::new(8, 8, 4, 1);
        let mut cur = Grid2D::zeros(8, 8);
        let mut next = Grid2D::zeros(8, 8);
        let handles = unsafe { [cur.shared_writer(), next.shared_writer()] };
        let mut n = 0;
        let r = drive_local(
            |_b, _inputs| {
                n += 1;
                if n == 3 {
                    anyhow::bail!("boom")
                }
                Ok(vec![0.0; 16])
            },
            &space,
            handles,
            4,
            4,
        );
        assert!(r.is_err());
    }

    #[test]
    fn drive_local_zero_passes_is_noop() {
        let space = TestSpace2D::new(8, 8, 4, 1);
        let mut cur = Grid2D::zeros(8, 8);
        let mut next = Grid2D::zeros(8, 8);
        let handles = unsafe { [cur.shared_writer(), next.shared_writer()] };
        let (blocks, _) =
            drive_local(|_b, _i| Ok(vec![0.0; 16]), &space, handles, 0, 4).unwrap();
        assert_eq!(blocks, 0);
    }
}
