//! Cross-pass pipelined pass driver: dependency-tracked async writeback.
//!
//! The thesis's headline stencil result comes from *combining* spatial
//! and temporal blocking so the accelerator never drains between time
//! steps (§5.3; see also arXiv:1802.00438).  PR 1's lane engine still
//! inserted a full `wait_idle` barrier after every pass — the lanes
//! idled exactly where the paper's deep pipeline keeps flowing.  This
//! module removes that barrier by making cross-pass dependencies
//! explicit:
//!
//! > a block of pass `p+1` becomes runnable as soon as the blocks of
//! > pass `p` that overlap its `r·T`-wide halo neighborhood have
//! > written back.
//!
//! [`DepTable`] tracks that rule with per-block completion counters
//! over the block-origin lattice; [`ReadyQueue`] holds the runnable
//! (pass, block) frontier.  Because the two grid buffers alternate
//! roles every pass (pass `p` reads buffer `p % 2` and writes buffer
//! `(p+1) % 2`), the same neighbor rule also covers the
//! write-after-read hazard: the pass-`p` blocks that *read* the cells a
//! pass-`p+1` block will overwrite are exactly its halo neighbors, and
//! they extracted (copied) their tiles before completing.  By
//! induction the rule stays sound at any pipeline depth with just two
//! buffers.
//!
//! The driver itself is generic over a [`StencilSpace`] — the
//! Grid/Writer abstraction the runners configure (tile extraction,
//! interior write-back, buffer pooling).  [`drive_single`] is its
//! remaining backend — one [`Runtime`]: execution pinned to the
//! caller's thread, one extractor thread feeding dependency-ready
//! tiles through a bounded channel (the pipelined path of PR 1, now
//! free to cross pass boundaries) — used by the single-runtime
//! reference runners.  The pooled stencil path lowers onto the
//! wavefront driver below since PR 4 (one wave per pass, the same
//! halo edges expressed as an explicit graph; see
//! `coordinator::session`), so the old lattice-specialized pool
//! backend (`drive_pool`) is gone.
//!
//! Results are bit-identical to the barrier schedule for any lane
//! count: each block's inputs are fully determined by its predecessor
//! blocks, interiors are disjoint, and per-block compute is identical.
//! [`PassMode::Barrier`] keeps the old schedule available (every
//! pass-`p+1` block waits for *all* of pass `p`) as the baseline the
//! CI perf gate compares against.
//!
//! Memory ordering: a completing thread write-backs the block, then
//! decrements successor counters with `AcqRel` RMWs, and the thread
//! whose decrement hits zero pushes the successor through the ready
//! queue's mutex.  The RMW chain plus the mutex hand-off order every
//! predecessor's grid writes before any extraction of the successor's
//! tile.
//!
//! # Wavefronts (the Ch. 4 apps)
//!
//! Since PR 3 the same machinery also drives the *wavefront* workloads
//! — Pathfinder's fused-row waves, NW's anti-diagonals, SRAD's
//! alternating reduction/stencil stages and LUD's
//! diagonal/perimeter/internal cascade — through a generalization of
//! the lattice table:
//!
//! * [`WaveGraph`] describes a workload as topologically ordered
//!   **waves** of blocks with explicit predecessor edges (every edge
//!   points from an earlier wave to a later one; per-wave block counts
//!   may vary, unlike the uniform per-pass lattice);
//! * [`WaveTable`] is the dependency tracker over such a graph — the
//!   same per-block `AcqRel` completion counters as [`DepTable`]
//!   (which remains the uniform-lattice specialization), plus
//!   precomputed CSR successor lists built by reversing the pred
//!   edges;
//! * [`WaveSpace`] adds execution: per-block artifact selection,
//!   input gathering and write-back — heterogeneous per wave (a LUD
//!   wave of perimeter blocks runs a different compute unit than the
//!   internal wave behind it);
//! * [`drive_wave_local`] / [`drive_wave_pool`] are the backends
//!   (caller-thread vs. lane-pool execution): a block of wave `w`
//!   runs as soon as its declared predecessors have written back —
//!   **no result-count or `wait_idle` barrier between waves**.
//!
//! [`PassMode::Barrier`] again keeps the wave-serial baseline (a block
//! waits for *every* block of *every* earlier wave), which is what the
//! CI perf gate compares the pipelined schedule against.
//!
//! # Fault tolerance
//!
//! The pooled wave driver scopes failure instead of aborting the run:
//! a block whose job fails terminally (after the pool's `Transient`
//! retry budget — see [`crate::runtime::RetryPolicy`]) has its
//! dependency **cone** cancelled via [`WaveTable::cancel`] — a walk of
//! the same CSR successor lists completion uses — while every block
//! outside the cone keeps running.  [`drive_wave_pool`] reports the
//! per-block faults and the cancelled set in a [`WaveOutcome`] so the
//! session layer can mark only the affected workloads failed.  Under
//! `cfg(any(test, feature = "chaos"))` a deterministic [`FaultPlan`]
//! can inject faults keyed by `(wave, block, attempt)`.
//!
//! # Time bounds
//!
//! [`RunLimits`] adds the wall-clock layer (PR 10): an optional
//! per-job budget (each block job is submitted with it; a lane stuck
//! past the budget is reaped by the pool watchdog and the block fails
//! with [`FaultKind::Timeout`] — healing through the same cone
//! cancel/replay path as any other terminal fault) and an optional
//! run deadline (on expiry a watcher aborts the ready queue, fences
//! still-queued jobs behind a fresh pool epoch, and the run reports
//! the blocks that never completed in [`WaveOutcome::unfinished`]
//! with [`WaveOutcome::deadline_exceeded`] set, instead of blocking
//! in `wait_idle`).  Budgeted job bodies commit via
//! [`crate::runtime::pool::commit_current_job`] before touching the
//! grid, so a reaped straggler can never write into a replay round.

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::panic_text;
use crate::runtime::pool::{lock, IdleGuard, JobStatus, RetryPolicy};
use crate::runtime::{FaultKind, Runtime, RuntimePool, Tensor};
use crate::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use crate::sync::{Arc, Condvar, Mutex, PoisonError};

/// Inter-pass scheduling regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassMode {
    /// Every pass-`p+1` block waits for *all* pass-`p` blocks — the
    /// PR 1 `wait_idle`-per-pass schedule, kept as the CI baseline.
    Barrier,
    /// A pass-`p+1` block runs as soon as its `r·T` halo-overlapping
    /// pass-`p` predecessors have written back (default).
    Pipelined,
}

/// The Grid/Writer configuration a pass driver runs over: how to cut a
/// workload into blocks, extract a block's kernel inputs, and write a
/// block's output interior — plus the buffer pools behind both.
///
/// Implementations are dimension- and workload-specific shims (see
/// `stencil_runner::Space2D/Space3D`); the driver owns everything else:
/// dependency tracking, lane feeding, double-buffer alternation and
/// metrics finalization.
pub trait StencilSpace: Send + Sync {
    /// Raw shared handle over one grid buffer (read + write); the
    /// driver holds one per double-buffer half.
    type Handle: Copy + Send + Sync + 'static;

    /// Blocks per pass.
    fn nblocks(&self) -> usize;

    /// Block-origin lattice extents, padded to 3 axes with leading 1s
    /// (a 2D workload reports `[1, nby, nbx]`).
    fn lattice(&self) -> [usize; 3];

    /// Per-axis dependency reach in lattice units:
    /// `ceil(halo / block)` (0 on degenerate axes).
    fn reach(&self) -> [usize; 3];

    /// Extract block `block`'s kernel input tensors from `src`.
    ///
    /// # Safety
    ///
    /// The caller must guarantee (via the dependency table) that no
    /// thread is concurrently writing any cell the tile reads, and
    /// that the handle's grid is live.
    unsafe fn extract(&self, src: Self::Handle, block: usize) -> Vec<Tensor>;

    /// Write block `block`'s kernel output interior into `dst`.
    ///
    /// # Safety
    ///
    /// Concurrent writes target pairwise-disjoint interiors (the block
    /// plan guarantees this) and the handle's grid must be live.
    unsafe fn write(&self, dst: Self::Handle, block: usize, out: &[f32]);

    /// Return recyclable input buffers to the space's pools.
    fn recycle(&self, inputs: Vec<Tensor>);

    /// (tile hits, tile misses, descriptor hits, descriptor misses).
    fn pool_counters(&self) -> (u64, u64, u64, u64);

    /// Buffers dropped by the pools' retention bound (see
    /// `bufpool::SHELF_HIGH_WATER`).  Spaces without bounded pools
    /// report 0.
    fn pool_evictions(&self) -> u64 {
        0
    }
}

/// Sticky block→lane map: the lane a block's affinity key lands on.
/// Pure modular hashing — deliberately free of run state, so the same
/// block keys to the same lane on every pass, across `Chain` seams, and
/// on both the sharded and global engines (where it is simply unused).
pub fn lane_of(key: u64, lanes: usize) -> usize {
    (key % lanes.max(1) as u64) as usize
}

/// Per-block completion counters over the block-origin lattice: block
/// `i` of pass `p+1` is runnable once `remaining[p][i]` predecessors of
/// pass `p` have completed.
pub struct DepTable {
    dims: [usize; 3],
    reach: [usize; 3],
    nblocks: usize,
    passes: usize,
    barrier: bool,
    /// `remaining[p * nblocks + i]`: incomplete pass-`p` predecessors
    /// of block `i` in pass `p+1` (slot `p` gates pass `p+1`).
    remaining: Vec<AtomicU32>,
}

impl DepTable {
    pub fn new(dims: [usize; 3], reach: [usize; 3], passes: usize, mode: PassMode) -> DepTable {
        let nblocks = dims[0] * dims[1] * dims[2];
        let mut t = DepTable {
            dims,
            reach,
            nblocks,
            passes,
            barrier: mode == PassMode::Barrier,
            remaining: Vec::new(),
        };
        if passes > 1 {
            t.remaining.reserve(passes.saturating_sub(1) * nblocks);
            for _p in 1..passes {
                for i in 0..nblocks {
                    t.remaining.push(AtomicU32::new(t.pred_count(i) as u32));
                }
            }
        }
        t
    }

    fn coord(&self, i: usize) -> [usize; 3] {
        [
            i / (self.dims[1] * self.dims[2]),
            (i / self.dims[2]) % self.dims[1],
            i % self.dims[2],
        ]
    }

    /// Visit the lattice neighborhood of block `i`: the blocks whose
    /// interiors overlap `i`'s `r·T`-halo'd tile (clipped to the
    /// lattice).  The relation is symmetric, so the same set is both
    /// `i`'s predecessors in the previous pass and the successors `i`
    /// unblocks in the next.
    fn neighborhood(&self, i: usize, mut f: impl FnMut(usize)) {
        if self.barrier {
            for j in 0..self.nblocks {
                f(j);
            }
            return;
        }
        let c = self.coord(i);
        let lo = |a: usize| c[a].saturating_sub(self.reach[a]);
        let hi = |a: usize| (c[a] + self.reach[a]).min(self.dims[a] - 1);
        for z in lo(0)..=hi(0) {
            for y in lo(1)..=hi(1) {
                for x in lo(2)..=hi(2) {
                    f((z * self.dims[1] + y) * self.dims[2] + x);
                }
            }
        }
    }

    /// Number of predecessors of block `i` (= its clipped neighborhood
    /// size; the neighbor relation is symmetric).
    fn pred_count(&self, i: usize) -> usize {
        if self.barrier {
            return self.nblocks;
        }
        let c = self.coord(i);
        let mut n = 1usize;
        for a in 0..3 {
            let lo = c[a].saturating_sub(self.reach[a]);
            let hi = (c[a] + self.reach[a]).min(self.dims[a] - 1);
            n *= hi - lo + 1;
        }
        n
    }

    /// Record the completion (write-back done) of `block` in `pass`;
    /// appends every pass-`p+1` block this makes runnable to `ready`.
    pub fn complete(&self, pass: usize, block: usize, ready: &mut Vec<(usize, usize)>) {
        if pass + 1 >= self.passes {
            return;
        }
        let base = pass * self.nblocks;
        self.neighborhood(block, |j| {
            // AcqRel: the RMW chain orders every predecessor's grid
            // write-back before the final decrement, whose thread then
            // publishes `j` through the ready queue's mutex.
            if self.remaining[base + j].fetch_sub(1, Ordering::AcqRel) == 1 {
                ready.push((pass + 1, j));
            }
        });
    }
}

/// The runnable (pass, block) frontier.  `pop` blocks until an item is
/// ready, every item has been dispatched, or the run aborts.
pub struct ReadyQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    total: usize,
}

struct QueueState {
    ready: VecDeque<(usize, usize)>,
    dispatched: usize,
    /// Blocks that will never run (their dependency cone was cancelled
    /// after a terminal fault); they count toward `total` so `pop`
    /// still terminates.
    cancelled: usize,
    aborted: bool,
}

impl ReadyQueue {
    pub fn new(total: usize, seed: impl IntoIterator<Item = (usize, usize)>) -> ReadyQueue {
        ReadyQueue {
            state: Mutex::new(QueueState {
                ready: seed.into_iter().collect(),
                dispatched: 0,
                cancelled: 0,
                aborted: false,
            }),
            cv: Condvar::new(),
            total,
        }
    }

    pub fn push_all(&self, items: &[(usize, usize)]) {
        if items.is_empty() {
            return;
        }
        let mut st = lock(&self.state);
        st.ready.extend(items.iter().copied());
        drop(st);
        self.cv.notify_all();
    }

    /// Next runnable item, or `None` once every one of the `total`
    /// items has been dispatched or cancelled (or the run aborted).
    pub fn pop(&self) -> Option<(usize, usize)> {
        let mut st = lock(&self.state);
        loop {
            if st.aborted {
                return None;
            }
            if let Some(item) = st.ready.pop_front() {
                st.dispatched += 1;
                if st.dispatched + st.cancelled >= self.total {
                    // Wake peers parked on an empty queue so they can
                    // observe completion and exit.
                    self.cv.notify_all();
                }
                return Some(item);
            }
            if st.dispatched + st.cancelled >= self.total {
                return None;
            }
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Account `n` blocks as cancelled: they will never be pushed, so
    /// the dispatch target shrinks and parked `pop`pers can observe
    /// completion.
    pub fn cancel(&self, n: usize) {
        if n == 0 {
            return;
        }
        lock(&self.state).cancelled += n;
        self.cv.notify_all();
    }

    /// Abandon the run: wakes and releases every `pop`per.
    pub fn abort(&self) {
        lock(&self.state).aborted = true;
        self.cv.notify_all();
    }
}

/// Fold the driver-side counters and runtime-stat deltas into a
/// [`Metrics`].
#[allow(clippy::too_many_arguments)]
fn finalize_metrics<S: StencilSpace>(
    space: &S,
    wall: Instant,
    blocks: u64,
    writeback: Duration,
    cell_updates: u64,
    execute_ms: f64,
    marshal_ms: f64,
) -> Metrics {
    let (pool_hits, pool_misses, desc_pool_hits, desc_pool_misses) = space.pool_counters();
    Metrics {
        blocks,
        cell_updates,
        extract: Duration::from_secs_f64(marshal_ms.max(0.0) / 1e3),
        execute: Duration::from_secs_f64(execute_ms.max(0.0) / 1e3),
        writeback,
        wall: wall.elapsed(),
        pool_hits,
        pool_misses,
        desc_pool_hits,
        desc_pool_misses,
        pool_evictions: space.pool_evictions(),
        ..Metrics::default()
    }
}

/// Dependency-ordered pass streaming with a caller-provided executor —
/// the core of [`drive_single`], factored out so the scheduling
/// machinery is testable without PJRT artifacts.  `exec` runs on the
/// calling thread (the PJRT client is `Rc`-based); one extractor thread
/// feeds ready tiles through a bounded channel of depth `lookahead`.
///
/// Returns `(blocks completed, writeback time)`.
pub fn drive_local<S: StencilSpace>(
    mut exec: impl FnMut(usize, &[Tensor]) -> crate::Result<Vec<f32>>,
    space: &S,
    handles: [S::Handle; 2],
    passes: usize,
    lookahead: usize,
) -> crate::Result<(u64, Duration)> {
    let nblocks = space.nblocks();
    let total = passes.saturating_mul(nblocks);
    if total == 0 {
        return Ok((0, Duration::ZERO));
    }
    let table = DepTable::new(space.lattice(), space.reach(), passes, PassMode::Pipelined);
    let queue = ReadyQueue::new(total, (0..nblocks).map(|i| (0usize, i)));
    let mut writeback = Duration::ZERO;
    let mut blocks = 0u64;
    let mut newly = Vec::new();

    // Small plans — or a single-core host, where a marshalling thread
    // can only steal cycles from execution — run sequentially.
    // Completions are synchronous here, so whenever work remains the
    // ready queue is non-empty and `pop` never parks.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if total <= 2 || lookahead <= 1 || cores <= 1 {
        while let Some((pass, block)) = queue.pop() {
            // SAFETY: dependency order — every cell this tile reads was
            // written by an already-completed predecessor (or the seed).
            let inputs = unsafe { space.extract(handles[pass % 2], block) };
            let out = exec(block, &inputs)?;
            let t0 = Instant::now();
            // SAFETY: disjoint interiors on the block lattice.
            unsafe { space.write(handles[(pass + 1) % 2], block, &out) };
            writeback += t0.elapsed();
            blocks += 1;
            newly.clear();
            table.complete(pass, block, &mut newly);
            queue.push_all(&newly);
            space.recycle(inputs);
        }
        return Ok((blocks, writeback));
    }

    std::thread::scope(|sc| -> crate::Result<()> {
        let (tx, rx) = mpsc::sync_channel::<(usize, usize, Vec<Tensor>)>(lookahead);
        let queue_ref = &queue;
        let feeder = sc.spawn(move || {
            while let Some((pass, block)) = queue_ref.pop() {
                // SAFETY: dependency order, as above — `pop` only hands
                // out blocks whose predecessors have written back.
                let inputs = unsafe { space.extract(handles[pass % 2], block) };
                if let Err(failed) = tx.send((pass, block, inputs)) {
                    // Consumer dropped (error path): recycle the
                    // in-flight tile so the pool's steady state
                    // survives a recovered fault.
                    space.recycle(failed.0 .2);
                    return;
                }
            }
        });
        let mut result: crate::Result<()> = Ok(());
        let mut feeder_died = false;
        for _ in 0..total {
            match rx.recv() {
                Ok((pass, block, inputs)) => match exec(block, &inputs) {
                    Ok(out) => {
                        let t0 = Instant::now();
                        // SAFETY: disjoint interiors.
                        unsafe { space.write(handles[(pass + 1) % 2], block, &out) };
                        writeback += t0.elapsed();
                        blocks += 1;
                        newly.clear();
                        table.complete(pass, block, &mut newly);
                        queue.push_all(&newly);
                        space.recycle(inputs);
                    }
                    Err(e) => {
                        space.recycle(inputs);
                        result = Err(e);
                        break;
                    }
                },
                // Feeder gone before sending everything: it panicked.
                Err(_) => {
                    feeder_died = true;
                    break;
                }
            }
        }
        // Unblock a feeder parked on the ready queue or a full channel,
        // recycle the tiles it extracted past the failure point (the
        // drain ends once the feeder drops its sender), then join it so
        // a panic converts to an error instead of being resumed by the
        // scope.
        queue.abort();
        for (_, _, tile) in rx.iter() {
            space.recycle(tile);
        }
        drop(rx);
        match feeder.join() {
            Err(p) => {
                let e = anyhow!("extractor thread panicked: {}", panic_text(p.as_ref()));
                if result.is_ok() {
                    result = Err(e);
                }
            }
            Ok(()) if feeder_died && result.is_ok() => {
                result = Err(anyhow!("extractor stopped after fewer than {total} blocks"));
            }
            Ok(()) => {}
        }
        result
    })?;
    Ok((blocks, writeback))
}

/// Run `passes` dependency-pipelined passes on a single [`Runtime`] and
/// finalize the [`Metrics`] (the caller compiles the artifact outside
/// the timed region first).
pub fn drive_single<S: StencilSpace>(
    rt: &Runtime,
    artifact: &str,
    space: &S,
    handles: [S::Handle; 2],
    passes: usize,
    cell_updates: u64,
) -> crate::Result<Metrics> {
    let stats0 = rt.stats();
    let wall = Instant::now();
    let (blocks, writeback) = drive_local(
        |_block, inputs| rt.execute_f32(artifact, inputs),
        space,
        handles,
        passes,
        4,
    )?;
    let stats = rt.stats();
    Ok(finalize_metrics(
        space,
        wall,
        blocks,
        writeback,
        cell_updates,
        stats.execute_ms - stats0.execute_ms,
        stats.marshal_ms - stats0.marshal_ms,
    ))
}

// ---------------------------------------------------------------------------
// Wavefront generalization: arbitrary per-wave block counts + explicit
// dependency edges (the Ch. 4 apps)
// ---------------------------------------------------------------------------

/// A workload cut into topologically ordered **waves** of blocks.
///
/// Wave `w` may have any number of blocks (unlike the uniform per-pass
/// lattice of [`DepTable`]); every dependency edge declared by
/// [`WaveGraph::visit_preds`] must point from a strictly earlier wave
/// to a later one.  Blocks with no predecessors (typically all of wave
/// 0) seed the ready frontier.
pub trait WaveGraph: Send + Sync {
    /// Number of waves (empty waves are allowed, e.g. LUD's tail step).
    fn waves(&self) -> usize;

    /// Blocks in wave `w`.
    fn wave_len(&self, w: usize) -> usize;

    /// Visit every predecessor `(v, j)` of block `(w, i)`: the blocks
    /// whose write-back must be ordered before `(w, i)`'s extraction.
    /// Must satisfy `v < w` and be deterministic (it is called more
    /// than once while the table is built).
    fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize));
}

/// Per-block completion counters over an arbitrary [`WaveGraph`] — the
/// generalization of [`DepTable`] beyond the uniform block-origin
/// lattice.  Successor lists are precomputed (CSR) by reversing the
/// graph's predecessor edges; completion uses the same `AcqRel` RMW
/// chain, so every predecessor's write-back happens-before the
/// successor's extraction once it pops off the [`ReadyQueue`].
pub struct WaveTable {
    /// `offsets[w]` = global id of the first block of wave `w`
    /// (`offsets[waves]` = total block count).
    offsets: Vec<usize>,
    /// Incomplete-predecessor counters, indexed by global block id.
    remaining: Vec<AtomicU32>,
    /// Completion bitmap, indexed by global block id: set exactly when
    /// [`WaveTable::complete`] records the block's write-back.  A
    /// cancelled block's bit stays clear across replay rounds until a
    /// round actually completes it, so after any drain the clear bits
    /// are precisely the blocks whose output is missing — what a
    /// deadline-cut run reports via [`WaveTable::unfinished`].
    done: Vec<AtomicBool>,
    /// CSR successor lists (pipelined mode only; empty under barrier).
    succ_off: Vec<usize>,
    succs: Vec<u32>,
    barrier: bool,
}

/// Counter sentinel marking a block `Cancelled` — terminal: a real
/// predecessor count can never reach it (counts are block counts), and
/// a concurrent `fetch_sub` from a straggling predecessor cannot bring
/// it anywhere near the zero that would release the block.
const CANCELLED: u32 = u32::MAX;

impl WaveTable {
    pub fn new(graph: &dyn WaveGraph, mode: PassMode) -> WaveTable {
        let waves = graph.waves();
        let mut offsets = Vec::with_capacity(waves + 1);
        let mut total = 0usize;
        for w in 0..waves {
            offsets.push(total);
            total += graph.wave_len(w);
        }
        offsets.push(total);

        let barrier = mode == PassMode::Barrier;
        let mut remaining: Vec<AtomicU32> = Vec::with_capacity(total);
        let mut succ_off = Vec::new();
        let mut succs = Vec::new();
        if barrier {
            // A block waits for every block of every earlier wave: the
            // wave-serial baseline (equivalent to a wait_idle between
            // waves), still correct for any graph because edges only
            // point backwards across waves.
            for w in 0..waves {
                for _ in 0..graph.wave_len(w) {
                    remaining.push(AtomicU32::new(offsets[w] as u32));
                }
            }
        } else {
            // Two CSR passes over the pred edges: count per source,
            // prefix-sum, fill — giving each block its successor list.
            let mut counts = vec![0usize; total];
            let mut preds = vec![0u32; total];
            for w in 0..waves {
                for i in 0..graph.wave_len(w) {
                    let mut np = 0u32;
                    graph.visit_preds(w, i, &mut |v, j| {
                        debug_assert!(v < w, "pred ({v},{j}) of ({w},{i}) not in an earlier wave");
                        counts[offsets[v] + j] += 1;
                        np += 1;
                    });
                    preds[offsets[w] + i] = np;
                }
            }
            succ_off = Vec::with_capacity(total + 1);
            let mut acc = 0usize;
            for c in &counts {
                succ_off.push(acc);
                acc += c;
            }
            succ_off.push(acc);
            succs = vec![0u32; acc];
            let mut fill = succ_off.clone();
            for w in 0..waves {
                for i in 0..graph.wave_len(w) {
                    let id = (offsets[w] + i) as u32;
                    graph.visit_preds(w, i, &mut |v, j| {
                        let src = offsets[v] + j;
                        succs[fill[src]] = id;
                        fill[src] += 1;
                    });
                }
            }
            for p in preds {
                remaining.push(AtomicU32::new(p));
            }
        }
        let done = (0..total).map(|_| AtomicBool::new(false)).collect();
        WaveTable { offsets, remaining, done, succ_off, succs, barrier }
    }

    /// Total blocks across all waves.
    pub fn total(&self) -> usize {
        // `offsets` always carries the leading 0 sentinel, so `last()`
        // exists even for an empty graph.
        self.offsets.last().copied().unwrap_or(0)
    }

    /// Map a global block id back to its `(wave, index)` pair.
    fn coord(&self, id: usize) -> (usize, usize) {
        // partition_point returns the first wave whose offset exceeds
        // `id`; its predecessor is the wave containing `id`.
        let w = self.offsets.partition_point(|&o| o <= id) - 1;
        (w, id - self.offsets[w])
    }

    /// The initially runnable frontier: every block whose predecessor
    /// count is zero (all of wave 0, plus any later block with no
    /// declared dependencies).
    pub fn seed(&self) -> Vec<(usize, usize)> {
        // Relaxed: runs before the round is published to any worker —
        // callbacks reach these counters only through the ready-queue
        // mutex (the happens-before edge that hands the table over), so
        // there is nothing concurrent to order against yet.
        (0..self.total())
            .filter(|&id| self.remaining[id].load(Ordering::Relaxed) == 0)
            .map(|id| self.coord(id))
            .collect()
    }

    /// Cancel the dependency cone of a terminally failed block
    /// `(w, i)`: every transitive successor is marked with the
    /// [`CANCELLED`] counter sentinel — an extra terminal state in the
    /// per-block counter discipline — and returned, so the caller can
    /// shrink the ready queue's dispatch target by exactly that many
    /// blocks.  The failed block itself is *not* included (it was
    /// already dispatched).  Blocks outside the cone are untouched and
    /// keep running.
    ///
    /// No completion race: a cone member always retains at least one
    /// incomplete predecessor (the failed block never completes, and
    /// inductively neither does any cone member), so no concurrent
    /// `complete` can drive its counter to zero while it is being
    /// marked.  Idempotent across overlapping cones — a block already
    /// at the sentinel is skipped, so each cancelled block is counted
    /// exactly once.
    ///
    /// Under `Barrier` mode every block of every later wave depends on
    /// `(w, i)`, so the cone is simply all blocks past wave `w`.
    pub fn cancel(&self, w: usize, i: usize) -> Vec<(usize, usize)> {
        let mark = |id: usize| self.remaining[id].swap(CANCELLED, Ordering::AcqRel) != CANCELLED;
        let mut cancelled = Vec::new();
        if self.barrier {
            for id in self.offsets[w + 1]..self.total() {
                if mark(id) {
                    cancelled.push(self.coord(id));
                }
            }
        } else {
            let id0 = self.offsets[w] + i;
            let mut stack: Vec<usize> = self.succs[self.succ_off[id0]..self.succ_off[id0 + 1]]
                .iter()
                .map(|&s| s as usize)
                .collect();
            while let Some(id) = stack.pop() {
                if mark(id) {
                    cancelled.push(self.coord(id));
                    stack.extend(
                        self.succs[self.succ_off[id]..self.succ_off[id + 1]]
                            .iter()
                            .map(|&s| s as usize),
                    );
                }
            }
        }
        cancelled
    }

    /// Has block `(w, i)`'s completion been recorded?  (Replay-heal
    /// accounting: a deadline-cut round may end with a block neither
    /// failed nor completed, which must not be reported as healed.)
    fn completed(&self, w: usize, i: usize) -> bool {
        self.done[self.offsets[w] + i].load(Ordering::Relaxed)
    }

    /// Every block whose completion was never recorded — after a
    /// drained round these are exactly the blocks with no output:
    /// terminally failed, cancelled, or (on a deadline cut) fenced
    /// before running.  Call only while no block is in flight.
    pub fn unfinished(&self) -> Vec<(usize, usize)> {
        (0..self.total())
            .filter(|&id| !self.done[id].load(Ordering::Relaxed))
            .map(|id| self.coord(id))
            .collect()
    }

    /// Re-arm a cancelled dependency cone for a replay round: reset
    /// every member's counter from the [`CANCELLED`] sentinel (or a
    /// failed block's stuck count) to the number of predecessors it has
    /// *inside the member set*, and return the members whose re-armed
    /// count is zero — the replay round's ready seeds (exactly the
    /// terminally failed blocks: every other cone member retains an
    /// in-set predecessor on its path from a failed block).
    ///
    /// `members` must be the union of the round's failed blocks and
    /// their cancelled cones, with no duplicates.  Counting only in-set
    /// predecessors is what makes the re-arm sound: every out-of-set
    /// predecessor already completed (that is how the failed block got
    /// dispatched), so it will never decrement again — and every
    /// successor of a member is itself a member (successors of a failed
    /// block form its cone; cones are successor-closed), so replay
    /// completions never decrement a finished block's counter either.
    /// Under `Barrier` mode the same rule counts members in strictly
    /// earlier waves (all faults of a barrier round sit in one wave —
    /// a later wave cannot start until the earlier one fully completes
    /// — so the earliest members are exactly the failed blocks).
    ///
    /// The snapshot the replay resumes from is the grid itself: a cone
    /// member never ran, and any block that would overwrite a cell a
    /// member reads transitively depends on that member (write-after-
    /// read edges are dependency edges in every lowering), so it sits
    /// in the cone too and never ran.  The members' inputs are still
    /// exactly what they would have been on the first attempt.
    ///
    /// Called between rounds, after the pool has drained — no block is
    /// in flight, so plain stores are race-free.
    pub fn rearm(&self, members: &[(usize, usize)]) -> Vec<(usize, usize)> {
        if self.barrier {
            let waves = self.offsets.len() - 1;
            let mut per_wave = vec![0u32; waves];
            for &(w, _) in members {
                per_wave[w] += 1;
            }
            // earlier[w] = members in waves 0..w — the member-scoped
            // analogue of the full-graph `offsets[w]` seed count.
            let mut earlier = vec![0u32; waves];
            let mut acc = 0u32;
            for w in 0..waves {
                earlier[w] = acc;
                acc += per_wave[w];
            }
            for &(w, i) in members {
                // Relaxed: quiescent between rounds (doc above); the
                // replay workers acquire these stores through the
                // ready-queue mutex when the seeds are published.
                self.remaining[self.offsets[w] + i].store(earlier[w], Ordering::Relaxed);
            }
        } else {
            // Relaxed stores + RMWs: same quiescence argument — no
            // block is in flight, and publication to the replay
            // workers rides the ready-queue mutex.
            let ids: HashSet<usize> = members.iter().map(|&(w, i)| self.offsets[w] + i).collect();
            for &id in &ids {
                self.remaining[id].store(0, Ordering::Relaxed);
            }
            for &id in &ids {
                for &s in &self.succs[self.succ_off[id]..self.succ_off[id + 1]] {
                    if ids.contains(&(s as usize)) {
                        self.remaining[s as usize].fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        // Relaxed loads: reading back this call's own single-threaded
        // stores.
        let mut seeds: Vec<(usize, usize)> = members
            .iter()
            .copied()
            .filter(|&(w, i)| self.remaining[self.offsets[w] + i].load(Ordering::Relaxed) == 0)
            .collect();
        seeds.sort_unstable();
        seeds
    }

    /// Record the completion (write-back done) of block `(w, i)`;
    /// appends every block this makes runnable to `ready`.
    pub fn complete(&self, w: usize, i: usize, ready: &mut Vec<(usize, usize)>) {
        // Relaxed: the bitmap is only read after the round's drain
        // (`unfinished`), never to synchronize block data.
        self.done[self.offsets[w] + i].store(true, Ordering::Relaxed);
        // AcqRel, as in DepTable::complete: the RMW chain orders every
        // predecessor's write-back before the final decrement, whose
        // thread publishes the successor through the queue's mutex.
        if self.barrier {
            for id in self.offsets[w + 1]..self.total() {
                if self.remaining[id].fetch_sub(1, Ordering::AcqRel) == 1 {
                    ready.push(self.coord(id));
                }
            }
        } else {
            let id = self.offsets[w] + i;
            for &s in &self.succs[self.succ_off[id]..self.succ_off[id + 1]] {
                if self.remaining[s as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                    ready.push(self.coord(s as usize));
                }
            }
        }
    }
}

/// Execution configuration over a [`WaveGraph`]: which compute unit a
/// block runs, how its inputs are gathered and where its outputs land.
/// Implementations live next to the app runners
/// (see `coordinator::apps`).
pub trait WaveSpace: WaveGraph {
    /// Artifact executed for block `(w, i)`.  The caller warms every
    /// distinct artifact on every lane before driving.
    fn artifact(&self, w: usize, i: usize) -> Arc<str>;

    /// Gather block `(w, i)`'s kernel input tensors.
    ///
    /// # Safety
    ///
    /// The caller must guarantee (via the wave table) that every
    /// predecessor of `(w, i)` has written back and that no thread is
    /// concurrently writing any cell this read touches.
    unsafe fn extract(&self, w: usize, i: usize) -> Vec<Tensor>;

    /// Write block `(w, i)`'s kernel outputs back.
    ///
    /// # Safety
    ///
    /// Concurrent writes must target pairwise-disjoint regions (the
    /// wave plan guarantees this) and every shared buffer must be live.
    unsafe fn write(&self, w: usize, i: usize, out: &[Tensor]);

    /// Valid cell updates block `(w, i)` contributes (metrics).
    fn cell_updates(&self, w: usize, i: usize) -> u64;

    /// Return block `(w, i)`'s recyclable input buffers to the space's
    /// pools.  The block id routes the buffers back to the right
    /// per-fragment pool when spaces are spliced
    /// (see `coordinator::session`).
    fn recycle(&self, w: usize, i: usize, inputs: Vec<Tensor>) {
        let _ = (w, i);
        drop(inputs);
    }

    /// (tile hits, tile misses, descriptor hits, descriptor misses).
    fn pool_counters(&self) -> (u64, u64, u64, u64) {
        (0, 0, 0, 0)
    }

    /// Buffers dropped by the pools' retention bound (see
    /// `bufpool::SHELF_HIGH_WATER`).  Spaces without bounded pools
    /// report 0.
    fn pool_evictions(&self) -> u64 {
        0
    }

    /// Stable affinity key for block `(w, i)`: blocks that touch the
    /// same data should return the same key, so [`lane_of`] sends them
    /// to the same lane's run-queue shard (and tile-pool shard) pass
    /// after pass.  The default keys by block index — exactly right
    /// for the stencil fragments, whose block `i` of every wave is the
    /// same block-origin tile of the grid, and stable across `Chain`
    /// seams because spliced fragments renumber waves, not block
    /// indices.  Must be deterministic and independent of run state.
    fn affinity(&self, w: usize, i: usize) -> u64 {
        let _ = w;
        i as u64
    }

    /// [`WaveSpace::extract`] drawing tile buffers from one lane's pool
    /// shard.  The default ignores the shard and delegates (correct
    /// for single-shard pools and pool-less spaces).
    ///
    /// # Safety
    ///
    /// Same contract as [`WaveSpace::extract`].
    unsafe fn extract_sharded(&self, shard: usize, w: usize, i: usize) -> Vec<Tensor> {
        let _ = shard;
        self.extract(w, i)
    }

    /// [`WaveSpace::recycle`] into one lane's pool shard; default
    /// delegates to the unsharded method.
    fn recycle_sharded(&self, shard: usize, w: usize, i: usize, inputs: Vec<Tensor>) {
        let _ = shard;
        self.recycle(w, i, inputs);
    }

    /// True when block `(w, i)`'s artifact has a single f32 output and
    /// the space wants [`Runtime::execute_f32`]'s decompose fast path;
    /// the pool driver then writes back through
    /// [`WaveSpace::write_f32`] instead of [`WaveSpace::write`].  The
    /// stencil fragments opt in (their compute units are all
    /// single-f32-output), keeping the lane hot path identical to the
    /// pre-Session `drive_pool` engine.
    fn wants_f32(&self, w: usize, i: usize) -> bool {
        let _ = (w, i);
        false
    }

    /// Write block `(w, i)`'s single-f32-output kernel result back —
    /// only called when [`WaveSpace::wants_f32`] returned true.
    ///
    /// # Safety
    ///
    /// Same contract as [`WaveSpace::write`].
    unsafe fn write_f32(&self, w: usize, i: usize, out: &[f32]) {
        let _ = (w, i, out);
        unreachable!("write_f32 called on a space that never opts into wants_f32");
    }
}

/// Pipeline-shape accounting for a wave run: how deep the cross-wave
/// overlap actually got (the numbers behind
/// [`Metrics::pipeline_depth_max`] / [`Metrics::overlap_starts`]).
struct DepthTracker {
    state: Mutex<DepthState>,
}

struct DepthState {
    /// Completed blocks per wave.
    done: Vec<usize>,
    /// Total blocks per wave.
    lens: Vec<usize>,
    /// First wave with incomplete blocks.
    oldest: usize,
    max_depth: usize,
    overlap: usize,
}

impl DepthTracker {
    fn new(graph: &dyn WaveGraph) -> DepthTracker {
        let lens: Vec<usize> = (0..graph.waves()).map(|w| graph.wave_len(w)).collect();
        // Leading empty waves are trivially "complete".
        let mut oldest = 0;
        while oldest < lens.len() && lens[oldest] == 0 {
            oldest += 1;
        }
        DepthTracker {
            state: Mutex::new(DepthState {
                done: vec![0; lens.len()],
                lens,
                oldest,
                max_depth: 0,
                overlap: 0,
            }),
        }
    }

    /// Block `(w, _)` is being dispatched (its inputs are about to be
    /// extracted).
    fn dispatched(&self, w: usize) {
        let mut st = lock(&self.state);
        if w > 0 && st.done[w - 1] < st.lens[w - 1] {
            st.overlap += 1;
        }
        let depth = w + 1 - st.oldest;
        st.max_depth = st.max_depth.max(depth);
    }

    /// Block `(w, _)` has written back.
    fn completed(&self, w: usize) {
        let mut st = lock(&self.state);
        st.done[w] += 1;
        while st.oldest < st.lens.len() && st.done[st.oldest] >= st.lens[st.oldest] {
            st.oldest += 1;
        }
    }

    fn finish(&self) -> (u64, u64) {
        let st = lock(&self.state);
        (st.max_depth as u64, st.overlap as u64)
    }
}

/// Raw per-run counters returned by [`drive_wave_local`].
pub struct WaveRunStats {
    pub blocks: u64,
    pub cell_updates: u64,
    pub writeback: Duration,
    pub pipeline_depth_max: u64,
    pub overlap_starts: u64,
}

/// Dependency-ordered wave streaming with a caller-provided executor —
/// the wavefront counterpart of [`drive_local`], factored out so the
/// scheduling machinery is testable with a native-Rust kernel (no PJRT
/// artifacts).  `exec(w, i, inputs)` runs on the calling thread; one
/// extractor thread feeds ready blocks through a bounded channel of
/// depth `lookahead`.
pub fn drive_wave_local<S: WaveSpace>(
    mut exec: impl FnMut(usize, usize, &[Tensor]) -> crate::Result<Vec<Tensor>>,
    space: &S,
    mode: PassMode,
    lookahead: usize,
) -> crate::Result<WaveRunStats> {
    let table = WaveTable::new(space, mode);
    let total = table.total();
    let depth = DepthTracker::new(space);
    let mut stats = WaveRunStats {
        blocks: 0,
        cell_updates: 0,
        writeback: Duration::ZERO,
        pipeline_depth_max: 0,
        overlap_starts: 0,
    };
    if total == 0 {
        return Ok(stats);
    }
    let queue = ReadyQueue::new(total, table.seed());
    let mut newly = Vec::new();

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if total <= 2 || lookahead <= 1 || cores <= 1 {
        while let Some((w, i)) = queue.pop() {
            depth.dispatched(w);
            // SAFETY: dependency order — every predecessor of (w, i)
            // wrote back before the queue handed it out.
            let inputs = unsafe { space.extract(w, i) };
            let out = exec(w, i, &inputs)?;
            let t0 = Instant::now();
            // SAFETY: disjoint write targets per the wave plan.
            unsafe { space.write(w, i, &out) };
            stats.writeback += t0.elapsed();
            stats.blocks += 1;
            stats.cell_updates += space.cell_updates(w, i);
            depth.completed(w);
            newly.clear();
            table.complete(w, i, &mut newly);
            queue.push_all(&newly);
            space.recycle(w, i, inputs);
        }
        let (d, o) = depth.finish();
        stats.pipeline_depth_max = d;
        stats.overlap_starts = o;
        return Ok(stats);
    }

    std::thread::scope(|sc| -> crate::Result<()> {
        let (tx, rx) = mpsc::sync_channel::<(usize, usize, Vec<Tensor>)>(lookahead);
        let queue_ref = &queue;
        let space_ref = space;
        let depth_ref = &depth;
        let feeder = sc.spawn(move || {
            while let Some((w, i)) = queue_ref.pop() {
                depth_ref.dispatched(w);
                // SAFETY: dependency order, as above.
                let inputs = unsafe { space_ref.extract(w, i) };
                if let Err(failed) = tx.send((w, i, inputs)) {
                    // Consumer dropped (error path): recycle the
                    // in-flight block inputs so the buffer pools
                    // survive a recovered fault.
                    let (fw, fi, tiles) = failed.0;
                    space_ref.recycle(fw, fi, tiles);
                    return;
                }
            }
        });
        let mut result: crate::Result<()> = Ok(());
        let mut feeder_died = false;
        for _ in 0..total {
            match rx.recv() {
                Ok((w, i, inputs)) => match exec(w, i, &inputs) {
                    Ok(out) => {
                        let t0 = Instant::now();
                        // SAFETY: disjoint write targets.
                        unsafe { space.write(w, i, &out) };
                        stats.writeback += t0.elapsed();
                        stats.blocks += 1;
                        stats.cell_updates += space.cell_updates(w, i);
                        depth.completed(w);
                        newly.clear();
                        table.complete(w, i, &mut newly);
                        queue.push_all(&newly);
                        space.recycle(w, i, inputs);
                    }
                    Err(e) => {
                        space.recycle(w, i, inputs);
                        result = Err(e);
                        break;
                    }
                },
                Err(_) => {
                    feeder_died = true;
                    break;
                }
            }
        }
        // As in drive_local: release the feeder, recycle its backlog,
        // then join.
        queue.abort();
        for (bw, bi, tiles) in rx.iter() {
            space.recycle(bw, bi, tiles);
        }
        drop(rx);
        match feeder.join() {
            Err(p) => {
                let e = anyhow!("extractor thread panicked: {}", panic_text(p.as_ref()));
                if result.is_ok() {
                    result = Err(e);
                }
            }
            Ok(()) if feeder_died && result.is_ok() => {
                result = Err(anyhow!("extractor stopped after fewer than {total} blocks"));
            }
            Ok(()) => {}
        }
        result
    })?;
    let (d, o) = depth.finish();
    stats.pipeline_depth_max = d;
    stats.overlap_starts = o;
    Ok(stats)
}

/// One terminally failed block of a pooled wave run: the retry budget
/// was exhausted (`Transient`), or the fault was terminal on its first
/// occurrence (`Fatal` / `Panic`).
#[derive(Debug, Clone)]
pub struct BlockFault {
    pub wave: usize,
    pub index: usize,
    pub kind: FaultKind,
    /// Execution attempts made on the block (1 + in-place retries).
    /// When the run replayed the block's cone, attempts accumulate
    /// across every round — six for a block that spent a 3-attempt
    /// retry budget twice.
    pub attempts: u32,
    pub message: String,
}

/// Cone-replay budget for a pooled wave run: after the in-place
/// [`RetryPolicy`] is spent, a terminally failed block's cancelled
/// dependency cone may be re-armed ([`WaveTable::rearm`]) and
/// re-driven up to `attempts` more rounds instead of surfacing partial
/// output.  Backoff-free and clock-free — with a deterministic
/// [`FaultPlan`] the whole fail/replay schedule reproduces exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplayPolicy {
    /// Replay rounds allowed per drive (0 = report the first terminal
    /// faults as-is — the pre-replay behaviour, and what the raw
    /// [`drive_wave_pool`] entry point uses).
    pub attempts: u32,
}

impl Default for ReplayPolicy {
    /// One replay round.
    fn default() -> Self {
        ReplayPolicy { attempts: 1 }
    }
}

impl ReplayPolicy {
    /// No replay: terminal faults cancel their cones and the run
    /// reports them.
    pub fn none() -> Self {
        ReplayPolicy { attempts: 0 }
    }

    /// Replay up to `attempts` rounds.
    pub fn with_attempts(attempts: u32) -> Self {
        ReplayPolicy { attempts }
    }
}

/// Wall-clock bounds for one pooled wave drive (see the module docs
/// § Time bounds).  `Default` is unbounded — exactly the pre-PR 10
/// behaviour.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunLimits {
    /// Per-job budget: every block job is submitted with it, and a
    /// lane stuck past the budget is reaped by the pool watchdog — the
    /// block fails with [`FaultKind::Timeout`] and heals through cone
    /// replay like any other terminal fault.  Must comfortably exceed
    /// one block's execute+writeback; it bounds *hangs*, not slowness.
    pub job_timeout: Option<Duration>,
    /// Absolute run deadline.  On expiry the driver stops dispatching,
    /// fences queued jobs behind a fresh pool epoch, cancels incomplete
    /// cones and returns with [`WaveOutcome::deadline_exceeded`] set
    /// (in-flight blocks are allowed [`DEADLINE_DRAIN_SLACK`] to
    /// finish) instead of blocking in `wait_idle`.
    pub deadline: Option<Instant>,
}

impl RunLimits {
    pub fn with_job_timeout(mut self, budget: Duration) -> Self {
        self.job_timeout = Some(budget);
        self
    }

    pub fn with_deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }
}

/// How long past an expired [`RunLimits::deadline`] the driver waits
/// for already-running block jobs to drain before giving up on the
/// pool (queued jobs are epoch-fenced and complete `Skipped`
/// immediately; only genuinely in-flight bodies consume slack).  A
/// budgeted run is additionally bounded by the watchdog; an unbudgeted
/// hung body past this slack surfaces as an infrastructure error.
pub const DEADLINE_DRAIN_SLACK: Duration = Duration::from_secs(10);

/// One *healed* block fault: the block failed terminally, its cone was
/// re-armed under the run's [`ReplayPolicy`], and a later round ran it
/// to completion — the output it feeds is whole, not partial.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConeReplay {
    pub wave: usize,
    pub index: usize,
    /// Replay rounds this block consumed before completing (≥ 1).
    pub rounds: u32,
}

/// Result of a pooled wave run.  `Ok(WaveOutcome)` means the run
/// *drained* — infrastructure failures (a poisoned pool, a dead lane
/// that could not respawn) still surface as `Err`.  Block-level faults
/// are scoped instead: each is reported here together with the exact
/// dependency cone it cancelled, and every block outside those cones
/// ran to completion.
pub struct WaveOutcome {
    pub metrics: Metrics,
    /// Terminally failed blocks *after* the replay budget: a fault that
    /// a replay round healed moves to `replays` instead.  In completion
    /// order of the final round.
    pub faults: Vec<BlockFault>,
    /// Blocks still cancelled after the replay budget, as transitive
    /// successors of a block in `faults` (the failed blocks themselves
    /// are in `faults`, not here).
    pub cancelled: Vec<(usize, usize)>,
    /// Faults healed by cone replay ([`ReplayPolicy`]); empty when the
    /// run was fault-free or replay was off.
    pub replays: Vec<ConeReplay>,
    /// Blocks with no output that are in neither `faults` nor
    /// `cancelled`: the run's deadline expired before they could run
    /// (fenced while queued, or never dispatched).  Always empty when
    /// `deadline_exceeded` is false.
    pub unfinished: Vec<(usize, usize)>,
    /// True when [`RunLimits::deadline`] expired mid-run: dispatch
    /// stopped, incomplete cones were cancelled, and the per-block
    /// picture is partial (`faults`/`cancelled`/`unfinished`).
    pub deadline_exceeded: bool,
}

/// Deterministic fault-injection plan for the chaos harness: faults
/// are keyed by `(wave, block index, 1-based attempt)` — no clocks, no
/// seeds — so an injected schedule replays identically on every run.
#[cfg(any(test, feature = "chaos"))]
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Return a `Transient` fault from the job body at these keys
    /// (retried under the pool's [`RetryPolicy`]).
    pub transient: Vec<(usize, usize, u32)>,
    /// Panic inside the job body at these keys (terminal: `Panic`).
    pub panic: Vec<(usize, usize, u32)>,
    /// Kill the executing lane thread at these keys (the job fails
    /// with `Panic`; the lane supervisor respawns the lane).
    pub lane_kill: Vec<(usize, usize, u32)>,
    /// Park the job body on the plan's gate at these keys — a
    /// deterministic hang, released only by
    /// [`FaultPlan::release_hangs`].  With a [`RunLimits::job_timeout`]
    /// the pool watchdog reaps the parked lane (`Timeout`); the woken
    /// body then fails [`crate::runtime::pool::commit_current_job`]
    /// and returns without touching the grid.
    pub hang: Vec<(usize, usize, u32)>,
    /// The gate hung jobs park on; cloned plans share it, so one
    /// `release_hangs` releases every zombie before a test tears the
    /// pool down.
    gate: Arc<HangGate>,
}

/// Chaos gate for [`FaultPlan::hang`]: a latch that parked job bodies
/// wait on.  Release is sticky — hangs injected after the release fall
/// straight through (the test has moved on to tear-down).
#[cfg(any(test, feature = "chaos"))]
struct HangGate {
    released: Mutex<bool>,
    cv: Condvar,
}

// Explicit (not derived) so the struct still builds when the sync shim
// swaps in loom's primitives, which don't guarantee `Default` impls.
#[cfg(any(test, feature = "chaos"))]
impl Default for HangGate {
    fn default() -> Self {
        HangGate { released: Mutex::new(false), cv: Condvar::new() }
    }
}

#[cfg(any(test, feature = "chaos"))]
impl std::fmt::Debug for HangGate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HangGate").field("released", &*lock(&self.released)).finish()
    }
}

#[cfg(any(test, feature = "chaos"))]
impl FaultPlan {
    pub fn transient_at(mut self, w: usize, i: usize, attempt: u32) -> Self {
        self.transient.push((w, i, attempt));
        self
    }

    pub fn panic_at(mut self, w: usize, i: usize, attempt: u32) -> Self {
        self.panic.push((w, i, attempt));
        self
    }

    pub fn lane_kill_at(mut self, w: usize, i: usize, attempt: u32) -> Self {
        self.lane_kill.push((w, i, attempt));
        self
    }

    pub fn hang_at(mut self, w: usize, i: usize, attempt: u32) -> Self {
        self.hang.push((w, i, attempt));
        self
    }

    /// Open the hang gate (sticky): wakes every job parked by a `hang`
    /// injection, including reaped zombies — call before dropping the
    /// pool so a zombie parked on the gate can exit and be joined or
    /// detached cleanly.
    pub fn release_hangs(&self) {
        *lock(&self.gate.released) = true;
        self.gate.cv.notify_all();
    }

    /// Fire whatever is registered for this `(wave, block, attempt)`
    /// key, called from the job body before the block executes.
    fn fire(&self, w: usize, i: usize, attempt: u32) -> crate::Result<()> {
        if self.lane_kill.contains(&(w, i, attempt)) {
            std::panic::panic_any(crate::runtime::pool::LaneKill);
        }
        if self.panic.contains(&(w, i, attempt)) {
            panic!("injected panic at block ({w},{i}) attempt {attempt}");
        }
        if self.hang.contains(&(w, i, attempt)) {
            // Deterministic hang: park until release_hangs.  No clock,
            // no sleep — the watchdog (if the job is budgeted) reaps
            // the lane while we sit here; on release the body resumes
            // and the commit fence decides whether it may still write.
            let mut released = lock(&self.gate.released);
            while !*released {
                released = self
                    .gate
                    .cv
                    .wait(released)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        }
        if self.transient.contains(&(w, i, attempt)) {
            return Err(crate::runtime::transient(format!(
                "injected transient fault at block ({w},{i}) attempt {attempt}"
            )));
        }
        Ok(())
    }
}

/// Injection slot threaded through the pooled driver: a real plan in
/// test/chaos builds, a zero-sized placeholder otherwise (so the hot
/// path carries no fault-injection state in release builds).
#[cfg(any(test, feature = "chaos"))]
pub(crate) type Injection = Option<Arc<FaultPlan>>;
#[cfg(not(any(test, feature = "chaos")))]
pub(crate) type Injection = ();

/// Run a wavefront workload on a [`RuntimePool`]: `extractors` workers
/// pull dependency-ready blocks off the wave table, the lanes execute
/// each block's artifact and write back, and each job's completion
/// callback advances the table — no result-count or `wait_idle`
/// barrier between waves; the single [`RuntimePool::wait_idle`] at the
/// end only closes out the run.  (The caller warms every distinct
/// artifact on every lane outside the timed region first.)
///
/// Failure is scoped, not global: a terminally failed block cancels
/// exactly its dependency cone ([`WaveTable::cancel`]) and the rest of
/// the run keeps flowing; see [`WaveOutcome`].  This raw entry point
/// does not replay cancelled cones ([`ReplayPolicy::none`]); use
/// [`drive_wave_pool_replay`] (or the session layer, which replays by
/// default) for checkpoint/replay semantics.
pub fn drive_wave_pool<S: WaveSpace + 'static>(
    pool: &RuntimePool,
    space: &Arc<S>,
    mode: PassMode,
    extractors: usize,
) -> crate::Result<WaveOutcome> {
    drive_wave_pool_inner(
        pool,
        space,
        mode,
        extractors,
        ReplayPolicy::none(),
        RunLimits::default(),
        Default::default(),
    )
}

/// [`drive_wave_pool`] with cone checkpoint/replay: when a block fails
/// terminally mid-wave, the round drains, the failed block's cancelled
/// cone is re-armed ([`WaveTable::rearm`]) under a fresh pool epoch,
/// and just that cone is re-driven — up to `replay.attempts` rounds —
/// so a partial failure costs a latency blip instead of the run.
pub fn drive_wave_pool_replay<S: WaveSpace + 'static>(
    pool: &RuntimePool,
    space: &Arc<S>,
    mode: PassMode,
    extractors: usize,
    replay: ReplayPolicy,
) -> crate::Result<WaveOutcome> {
    drive_wave_pool_inner(
        pool,
        space,
        mode,
        extractors,
        replay,
        RunLimits::default(),
        Default::default(),
    )
}

/// [`drive_wave_pool_replay`] under wall-clock bounds (see
/// [`RunLimits`] and the module docs § Time bounds) — the public form
/// of the limits-threading drive the session layer uses when a
/// `deadline` or `job_timeout` is configured.
pub fn drive_wave_pool_limits<S: WaveSpace + 'static>(
    pool: &RuntimePool,
    space: &Arc<S>,
    mode: PassMode,
    extractors: usize,
    replay: ReplayPolicy,
    limits: RunLimits,
) -> crate::Result<WaveOutcome> {
    drive_wave_pool_inner(pool, space, mode, extractors, replay, limits, Default::default())
}

/// [`drive_wave_pool_replay`] with a deterministic [`FaultPlan`] — the
/// chaos harness entry point (test/chaos builds only).  Plan keys are
/// cumulative across replay rounds: an injection at attempt 4 fires on
/// the first attempt of the second round when the retry budget is 3.
#[cfg(any(test, feature = "chaos"))]
pub fn drive_wave_pool_chaos<S: WaveSpace + 'static>(
    pool: &RuntimePool,
    space: &Arc<S>,
    mode: PassMode,
    extractors: usize,
    replay: ReplayPolicy,
    plan: Arc<FaultPlan>,
) -> crate::Result<WaveOutcome> {
    drive_wave_pool_inner(pool, space, mode, extractors, replay, RunLimits::default(), Some(plan))
}

/// [`drive_wave_pool_chaos`] under wall-clock bounds — the harness for
/// hang injections, which only resolve when a `job_timeout` lets the
/// watchdog reap the parked lane.
#[cfg(any(test, feature = "chaos"))]
pub fn drive_wave_pool_chaos_limits<S: WaveSpace + 'static>(
    pool: &RuntimePool,
    space: &Arc<S>,
    mode: PassMode,
    extractors: usize,
    replay: ReplayPolicy,
    limits: RunLimits,
    plan: Arc<FaultPlan>,
) -> crate::Result<WaveOutcome> {
    drive_wave_pool_inner(pool, space, mode, extractors, replay, limits, Some(plan))
}

/// Shared trackers one pooled drive hands to each of its replay
/// rounds (see [`drive_round`]).
struct RoundCtx {
    table: Arc<WaveTable>,
    depth: Arc<DepthTracker>,
    faults: Arc<Mutex<Vec<BlockFault>>>,
    cancelled: Arc<Mutex<Vec<(usize, usize)>>>,
    done_blocks: Arc<AtomicU64>,
    cells: Arc<AtomicU64>,
    wb_nanos: Arc<AtomicU64>,
    /// Mirrors the pool's submission epoch on the callback side: a
    /// straggling completion from an abandoned round (whose body the
    /// pool's epoch fence already kept from running) must not cancel
    /// into — or advance — the re-armed table, so every completion
    /// callback checks its round is still current before touching
    /// shared state.
    round_tag: Arc<AtomicU64>,
    /// Cumulative chaos-attempt floor per block: [`FaultPlan`] keys
    /// stay cumulative across replay rounds, so "fail attempts 1..=3,
    /// succeed at 4" spans a replay boundary.
    #[cfg(any(test, feature = "chaos"))]
    attempt_base: Arc<Mutex<HashMap<(usize, usize), u32>>>,
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn drive_wave_pool_inner<S: WaveSpace + 'static>(
    pool: &RuntimePool,
    space: &Arc<S>,
    mode: PassMode,
    extractors: usize,
    replay: ReplayPolicy,
    limits: RunLimits,
    _inject: Injection,
) -> crate::Result<WaveOutcome> {
    let stats0 = pool.stats();
    let counters0 = pool.fault_counters();
    let sched0 = pool.sched_counters();
    let wall = Instant::now();
    let table = Arc::new(WaveTable::new(space.as_ref(), mode));
    let total = table.total();
    let ctx = RoundCtx {
        table: Arc::clone(&table),
        depth: Arc::new(DepthTracker::new(space.as_ref())),
        faults: Arc::new(Mutex::new(Vec::new())),
        cancelled: Arc::new(Mutex::new(Vec::new())),
        done_blocks: Arc::new(AtomicU64::new(0)),
        cells: Arc::new(AtomicU64::new(0)),
        wb_nanos: Arc::new(AtomicU64::new(0)),
        round_tag: Arc::new(AtomicU64::new(0)),
        #[cfg(any(test, feature = "chaos"))]
        attempt_base: Arc::new(Mutex::new(HashMap::new())),
    };

    let mut replays: Vec<ConeReplay> = Vec::new();
    let mut cone_replays = 0u64;
    let mut replay_blocks = 0u64;
    let mut faults: Vec<BlockFault> = Vec::new();
    let mut cancelled: Vec<(usize, usize)> = Vec::new();
    let mut deadline_exceeded = false;

    if total > 0 {
        // Cumulative execution attempts and failed-round counts per
        // block (fault reporting and [`ConeReplay::rounds`]).
        let mut attempts_spent: HashMap<(usize, usize), u32> = HashMap::new();
        let mut failed_rounds: HashMap<(usize, usize), u32> = HashMap::new();
        let mut pending: Vec<BlockFault> = Vec::new();
        let mut seeds = table.seed();
        let mut target = total;
        let mut round: u64 = 0;
        loop {
            // Fresh pool epoch per round: a submission still queued
            // from an earlier round completes Skipped without running.
            let epoch = pool.advance_epoch();
            let batch = std::mem::take(&mut seeds);
            let deadline_hit =
                drive_round(pool, space, &ctx, batch, target, extractors, round, epoch, &limits, &_inject)?;

            let round_faults = std::mem::take(&mut *lock(&ctx.faults));
            let round_cancelled = std::mem::take(&mut *lock(&ctx.cancelled));
            for f in &round_faults {
                *attempts_spent.entry((f.wave, f.index)).or_insert(0) += f.attempts;
                *failed_rounds.entry((f.wave, f.index)).or_insert(0) += 1;
            }
            // A block that failed last round but not this one — and
            // actually completed — healed: the replay ran it (and its
            // cone) to completion.  The completion check matters on a
            // deadline-cut round, which can end with a block neither
            // failed nor completed.
            for f in &pending {
                let k = (f.wave, f.index);
                if !round_faults.iter().any(|g| (g.wave, g.index) == k)
                    && table.completed(f.wave, f.index)
                {
                    replays.push(ConeReplay {
                        wave: f.wave,
                        index: f.index,
                        rounds: failed_rounds.get(&k).copied().unwrap_or(1),
                    });
                }
            }
            if deadline_hit {
                // Out of time: surface whatever this round left behind
                // — no further replay, the partial per-block picture
                // (plus `unfinished`, computed below) is the report.
                faults = round_faults;
                for f in &mut faults {
                    f.attempts = attempts_spent[&(f.wave, f.index)];
                }
                cancelled = round_cancelled;
                deadline_exceeded = true;
                break;
            }
            if round_faults.is_empty() {
                break; // clean round — nothing left to replay
            }
            if round >= u64::from(replay.attempts) {
                // Replay budget spent: surface the terminal state, with
                // attempts accumulated across every round.
                faults = round_faults;
                for f in &mut faults {
                    f.attempts = attempts_spent[&(f.wave, f.index)];
                }
                cancelled = round_cancelled;
                break;
            }
            // Checkpoint/replay: the failed blocks plus their cancelled
            // cones re-arm in place (their inputs are untouched — any
            // block that could overwrite a cell they read sits in the
            // same cone and never ran) and only that set re-drives.
            let mut members: Vec<(usize, usize)> =
                round_faults.iter().map(|f| (f.wave, f.index)).collect();
            members.extend(round_cancelled.iter().copied());
            seeds = table.rearm(&members);
            target = members.len();
            cone_replays += 1;
            replay_blocks += members.len() as u64;
            pending = round_faults;
            round += 1;
        }
    }

    // Blocks the deadline cut left in limbo: never completed, but not
    // failed or cone-cancelled either (they simply never got submitted
    // — or were fenced mid-flight by the epoch advance).
    let unfinished: Vec<(usize, usize)> = if deadline_exceeded {
        let known: HashSet<(usize, usize)> = faults
            .iter()
            .map(|f| (f.wave, f.index))
            .chain(cancelled.iter().copied())
            .collect();
        table.unfinished().into_iter().filter(|b| !known.contains(b)).collect()
    } else {
        Vec::new()
    };

    let stats = pool.stats();
    let counters = pool.fault_counters();
    let sched = pool.sched_counters();
    let (pool_hits, pool_misses, desc_pool_hits, desc_pool_misses) = space.pool_counters();
    let (depth_max, overlap) = ctx.depth.finish();
    // Relaxed loads: every callback that bumped these tallies finished
    // before the drain above returned (mutex-mediated), so the values
    // are final — the counters carry no payload to synchronize.
    let metrics = Metrics {
        blocks: ctx.done_blocks.load(Ordering::Relaxed),
        cell_updates: ctx.cells.load(Ordering::Relaxed),
        extract: Duration::from_secs_f64((stats.marshal_ms - stats0.marshal_ms).max(0.0) / 1e3),
        execute: Duration::from_secs_f64((stats.execute_ms - stats0.execute_ms).max(0.0) / 1e3),
        writeback: Duration::from_nanos(ctx.wb_nanos.load(Ordering::Relaxed)),
        wall: wall.elapsed(),
        pool_hits,
        pool_misses,
        desc_pool_hits,
        desc_pool_misses,
        pipeline_depth_max: depth_max,
        overlap_starts: overlap,
        job_retries: counters.job_retries - counters0.job_retries,
        jobs_failed: counters.jobs_failed - counters0.jobs_failed,
        lane_restarts: counters.lane_restarts - counters0.lane_restarts,
        local_pops: sched.local_pops - sched0.local_pops,
        queue_steals: sched.queue_steals - sched0.queue_steals,
        affinity_hits: sched.affinity_hits - sched0.affinity_hits,
        affinity_misses: sched.affinity_misses - sched0.affinity_misses,
        pins_applied: sched.pins_applied - sched0.pins_applied,
        pool_evictions: space.pool_evictions(),
        cone_replays,
        replay_blocks,
        job_timeouts: counters.job_timeouts - counters0.job_timeouts,
        lanes_reaped: counters.lanes_reaped - counters0.lanes_reaped,
    };
    Ok(WaveOutcome { metrics, faults, cancelled, replays, unfinished, deadline_exceeded })
}

/// Drive one replay round: feed the `seeds` frontier (a batch of
/// `target` blocks) through the pool under submission `epoch`, and
/// drain the lanes completely before returning.  Faults and
/// cancellations land in the `ctx` vectors; the caller harvests them
/// to decide whether — and what — to replay.
///
/// Returns `Ok(true)` when the round was cut short by
/// [`RunLimits::deadline`]: the watcher aborted the ready queue and
/// advanced the pool epoch, so still-queued jobs completed `Skipped`
/// without running and blocks left on the queue were simply never
/// submitted.  The caller must not replay after a deadline cut.
#[allow(clippy::too_many_arguments)]
fn drive_round<S: WaveSpace + 'static>(
    pool: &RuntimePool,
    space: &Arc<S>,
    ctx: &RoundCtx,
    seeds: Vec<(usize, usize)>,
    target: usize,
    extractors: usize,
    round: u64,
    epoch: u64,
    limits: &RunLimits,
    _inject: &Injection,
) -> crate::Result<bool> {
    let lanes = pool.lanes();
    let queue = Arc::new(ReadyQueue::new(target, seeds));
    let workers = extractors.clamp(1, target);
    ctx.round_tag.store(round, Ordering::Release);

    // Deadline watcher plumbing: `fired` records that the cut
    // happened; the (flag, condvar) pair wakes the watcher early when
    // the round drains before the deadline, so it never outlives the
    // scope that spawned it.
    let deadline_fired = AtomicBool::new(false);
    let watcher_done: (Mutex<bool>, Condvar) = (Mutex::new(false), Condvar::new());

    // SAFETY-relevant: jobs reach the caller's buffers through raw
    // handles inside the space; the IdleGuard drains the lanes
    // before those buffers can be freed, even on an unwinding exit.
    let guard = IdleGuard::new(pool);
    let idle = std::thread::scope(|sc| {
        if let Some(deadline) = limits.deadline {
            let queue = Arc::clone(&queue);
            let fired = &deadline_fired;
            let done_pair = &watcher_done;
            sc.spawn(move || {
                let (flag, cv) = done_pair;
                let mut done = lock(flag);
                loop {
                    if *done {
                        return; // round drained in time — nothing to cut
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    done = cv
                        .wait_timeout(done, deadline - now)
                        .unwrap_or_else(PoisonError::into_inner)
                        .0;
                }
                drop(done);
                // Deadline expired: stop handing out blocks, then
                // fence everything already queued in the pool — a
                // stale-epoch job completes `Skipped` without running.
                fired.store(true, Ordering::Release);
                queue.abort();
                pool.advance_epoch();
            });
        }
        extract_and_submit(pool, space, ctx, &queue, workers, lanes, round, epoch, limits, _inject);
        // Drain the lanes: one wait per round — still the only place
        // infrastructure errors surface.  With a deadline set the wait
        // is bounded: budget remaining plus a fixed drain slack (the
        // epoch fence retires queued jobs quickly; only a genuinely
        // hung unbudgeted lane can exhaust the slack).
        let idle = match limits.deadline {
            None => pool.wait_idle(),
            Some(deadline) => {
                let remaining = deadline.saturating_duration_since(Instant::now());
                match pool.wait_idle_for(remaining + DEADLINE_DRAIN_SLACK) {
                    Ok(true) => Ok(()),
                    Ok(false) => Err(anyhow!(
                        "pool failed to drain within {:?} past the run deadline \
                         (a lane is hung with no job budget set)",
                        DEADLINE_DRAIN_SLACK
                    )),
                    Err(e) => Err(e),
                }
            }
        };
        // Release the watcher before the scope joins it.
        *lock(&watcher_done.0) = true;
        watcher_done.1.notify_all();
        idle
    });
    drop(guard);
    idle?;
    Ok(deadline_fired.load(Ordering::Acquire))
}

/// The extractor fan-out of one round: `workers` scoped threads pull
/// ready blocks off `queue`, extract their tiles, and submit budgeted
/// jobs to the pool.  Returns once the queue is exhausted (all blocks
/// dispatched, cancelled, or the queue aborted) and every extractor
/// has exited; submitted jobs may still be in flight.
#[allow(clippy::too_many_arguments)]
fn extract_and_submit<S: WaveSpace + 'static>(
    pool: &RuntimePool,
    space: &Arc<S>,
    ctx: &RoundCtx,
    queue: &Arc<ReadyQueue>,
    workers: usize,
    lanes: usize,
    round: u64,
    epoch: u64,
    limits: &RunLimits,
    _inject: &Injection,
) {
    std::thread::scope(|sc| {
        for ex in 0..workers {
            // Move clones of the shared trackers into each
            // extractor (the closure must own them: `ex` forces a
            // `move` capture); `space` and `pool` are Copy borrows
            // that outlive the scope.
            let queue = Arc::clone(queue);
            let job_timeout = limits.job_timeout;
            let depth = Arc::clone(&ctx.depth);
            let table = Arc::clone(&ctx.table);
            let faults = Arc::clone(&ctx.faults);
            let cancelled = Arc::clone(&ctx.cancelled);
            let done_blocks = Arc::clone(&ctx.done_blocks);
            let cells = Arc::clone(&ctx.cells);
            let wb_nanos = Arc::clone(&ctx.wb_nanos);
            let round_tag = Arc::clone(&ctx.round_tag);
            let _inject = _inject.clone();
            #[cfg(any(test, feature = "chaos"))]
            let attempt_base = Arc::clone(&ctx.attempt_base);
            sc.spawn(move || {
                // Under Pinning::{Cores,Numa} each extractor sits on
                // the node of the lanes it mostly feeds, so a
                // pool-miss allocation first-touches pages on the
                // right node.  No-op (false) when unpinned.
                pool.pin_extractor(ex);
                while let Some((w, i)) = queue.pop() {
                    depth.dispatched(w);
                    // Sticky block→lane affinity: the same key
                    // every pass, so a block's tile cycles through
                    // one lane's cache (and pool shard).
                    let hint = lane_of(space.affinity(w, i), lanes);
                    // Catch extraction panics here and scope them
                    // like a failed job: cancel the block's cone,
                    // keep everything else running.
                    let extracted = catch_unwind(AssertUnwindSafe(|| {
                        // SAFETY: dependency order via the ready
                        // queue — predecessors have written back.
                        unsafe { space.extract_sharded(hint, w, i) }
                    }));
                    let inputs = match extracted {
                        Ok(inputs) => inputs,
                        Err(p) => {
                            let cone = table.cancel(w, i);
                            queue.cancel(cone.len());
                            lock(&faults).push(BlockFault {
                                wave: w,
                                index: i,
                                kind: FaultKind::Panic,
                                attempts: 1,
                                message: format!(
                                    "wave extractor panicked: {}",
                                    panic_text(p.as_ref())
                                ),
                            });
                            lock(&cancelled).extend(cone);
                            continue;
                        }
                    };
                    let artifact = space.artifact(w, i);
                    let fast_f32 = space.wants_f32(w, i);
                    let space_j = space.clone();
                    let done_j = done_blocks.clone();
                    let cells_j = cells.clone();
                    let wb_j = wb_nanos.clone();
                    let table_j = table.clone();
                    let queue_j = queue.clone();
                    let depth_j = depth.clone();
                    let faults_j = faults.clone();
                    let cancelled_j = cancelled.clone();
                    let tag_j = round_tag.clone();
                    // FnMut so the lane can re-run the body on a
                    // Transient fault: the inputs stay parked in
                    // the Option until an attempt succeeds.
                    let mut inputs = Some(inputs);
                    #[cfg(any(test, feature = "chaos"))]
                    let plan_j = _inject.clone();
                    #[cfg(any(test, feature = "chaos"))]
                    let base_j = attempt_base.clone();
                    // Resume the chaos-attempt counter past every
                    // attempt this block burned in earlier rounds.
                    #[cfg(any(test, feature = "chaos"))]
                    let mut chaos_attempt: u32 =
                        lock(&attempt_base).get(&(w, i)).copied().unwrap_or(0);
                    pool.submit_tracked_budgeted(
                        Some(hint),
                        Some(epoch),
                        job_timeout,
                        move |_lane, rt| {
                            #[cfg(any(test, feature = "chaos"))]
                            {
                                chaos_attempt += 1;
                                if let Some(plan) = plan_j.as_ref() {
                                    plan.fire(w, i, chaos_attempt)?;
                                }
                            }
                            let tiles =
                                inputs.as_ref().expect("job inputs already recycled");
                            let t0;
                            if fast_f32 {
                                // Single-f32-output decompose fast
                                // path (no Tensor wrapping).
                                let out = rt.execute_f32(&artifact, tiles)?;
                                // Commit fence: past here the watchdog
                                // no longer reaps this job.  A claim
                                // already lost means a replacement
                                // lane owns the block — back out
                                // before touching the grid.
                                if !crate::runtime::commit_current_job() {
                                    return Ok(());
                                }
                                t0 = Instant::now();
                                // SAFETY: disjoint write targets
                                // per the wave plan.
                                unsafe { space_j.write_f32(w, i, &out) };
                            } else {
                                let out = rt.execute(&artifact, tiles)?;
                                // Commit fence — see the f32 branch.
                                if !crate::runtime::commit_current_job() {
                                    return Ok(());
                                }
                                t0 = Instant::now();
                                // SAFETY: disjoint write targets
                                // per the wave plan.
                                unsafe { space_j.write(w, i, &out) };
                            }
                            // Relaxed: independent monotonic tallies;
                            // the driver reads them only after the
                            // drain, never to synchronize data.
                            wb_j.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            done_j.fetch_add(1, Ordering::Relaxed);
                            cells_j.fetch_add(space_j.cell_updates(w, i), Ordering::Relaxed);
                            // Back to the shard the extractor took
                            // from: the tile cycles within one
                            // lane's free list even when stolen.
                            space_j.recycle_sharded(
                                hint,
                                w,
                                i,
                                inputs.take().expect("job inputs already recycled"),
                            );
                            Ok(())
                        },
                        RetryPolicy::default(),
                        move |status| {
                            if tag_j.load(Ordering::Acquire) != round {
                                // Straggler from an abandoned round:
                                // the pool's epoch fence kept its
                                // body from running, and its status
                                // must not touch the re-armed table
                                // or the fresh queue either.
                                return;
                            }
                            match status {
                                JobStatus::Ok { .. } => {
                                    depth_j.completed(w);
                                    let mut newly = Vec::new();
                                    table_j.complete(w, i, &mut newly);
                                    queue_j.push_all(&newly);
                                }
                                JobStatus::Failed { kind, attempts, message } => {
                                    // Scoped cancellation: only the
                                    // failed block's dependency cone
                                    // stops; independent blocks keep
                                    // running.
                                    #[cfg(any(test, feature = "chaos"))]
                                    {
                                        *lock(&base_j).entry((w, i)).or_insert(0) += attempts;
                                    }
                                    let cone = table_j.cancel(w, i);
                                    queue_j.cancel(cone.len());
                                    lock(&faults_j).push(BlockFault {
                                        wave: w,
                                        index: i,
                                        kind,
                                        attempts,
                                        message,
                                    });
                                    lock(&cancelled_j).extend(cone);
                                }
                                JobStatus::Skipped => {
                                    // Infrastructure failure (poisoned
                                    // pool): the underlying error
                                    // surfaces via wait_idle below;
                                    // here just release the cone so
                                    // the extractors can drain.
                                    let cone = table_j.cancel(w, i);
                                    queue_j.cancel(cone.len());
                                    lock(&cancelled_j).extend(cone);
                                }
                            }
                        },
                    );
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::bufpool::TensorPools;
    use crate::coordinator::grid::{Boundary, Grid2D, GridWriter2D};
    use std::collections::HashSet;

    // ---------- DepTable scheduling-invariant tests ----------

    /// Simulation harness: processes items popped off a ReadyQueue one
    /// at a time (choosing among the currently-ready set by `pick`),
    /// asserting before each completion that every halo-overlapping
    /// predecessor already completed.
    fn simulate(
        dims: [usize; 3],
        reach: [usize; 3],
        passes: usize,
        mode: PassMode,
        mut pick: impl FnMut(usize) -> usize,
    ) {
        let nblocks = dims[0] * dims[1] * dims[2];
        let table = DepTable::new(dims, reach, passes, mode);
        let mut ready: Vec<(usize, usize)> = (0..nblocks).map(|i| (0, i)).collect();
        let mut completed: HashSet<(usize, usize)> = HashSet::new();
        let mut dispatched = 0usize;
        while !ready.is_empty() {
            let idx = pick(ready.len()) % ready.len();
            let (pass, block) = ready.swap_remove(idx);
            dispatched += 1;
            // The invariant: every predecessor in the halo neighborhood
            // (or the whole previous pass, in Barrier mode) completed.
            if pass > 0 {
                table.neighborhood(block, |j| {
                    assert!(
                        completed.contains(&(pass - 1, j)),
                        "block (p={pass}, i={block}) scheduled before \
                         predecessor (p={}, i={j}) completed",
                        pass - 1
                    );
                });
            }
            assert!(completed.insert((pass, block)), "double-scheduled");
            let mut newly = Vec::new();
            table.complete(pass, block, &mut newly);
            ready.extend(newly);
        }
        assert_eq!(dispatched, passes * nblocks, "not every block ran");
    }

    #[test]
    fn dep_table_exhaustive_small_grids() {
        // Exhaustive over pick-order variation for a family of small
        // lattices: every (dims, reach, passes) runs under many
        // deterministic orderings (LIFO, FIFO, and rotating offsets).
        let cases: &[([usize; 3], [usize; 3], usize)] = &[
            ([1, 2, 2], [0, 1, 1], 2),
            ([1, 2, 2], [0, 1, 1], 3),
            ([1, 3, 4], [0, 1, 1], 3),
            ([1, 4, 1], [0, 1, 0], 4),
            ([2, 2, 2], [1, 1, 1], 3),
            ([3, 3, 3], [1, 1, 1], 2),
            ([1, 3, 3], [0, 2, 2], 3), // halo wider than one block
            ([1, 3, 3], [0, 0, 0], 3), // halo 0: self-dependency only
            ([1, 1, 1], [0, 1, 1], 5), // single block
        ];
        for &(dims, reach, passes) in cases {
            for order in 0..7usize {
                simulate(dims, reach, passes, PassMode::Pipelined, |len| match order {
                    0 => 0,              // FIFO
                    1 => len - 1,        // LIFO
                    k => (k * 131) % len // rotating picks
                });
            }
        }
    }

    #[test]
    fn dep_table_randomized_orders() {
        let mut rng = crate::testutil::Rng::new(42);
        for _ in 0..25 {
            let dims = [1, rng.usize_in(1, 4), rng.usize_in(1, 4)];
            let reach = [0, rng.usize_in(0, 2), rng.usize_in(0, 2)];
            let passes = rng.usize_in(1, 4);
            let mut r2 = crate::testutil::Rng::new(rng.next_u64());
            simulate(dims, reach, passes, PassMode::Pipelined, move |len| {
                r2.usize_in(0, len - 1)
            });
        }
    }

    #[test]
    fn dep_table_barrier_mode_waits_for_whole_pass() {
        let dims = [1, 2, 3];
        let nblocks = 6;
        let table = DepTable::new(dims, [0, 1, 1], 2, PassMode::Barrier);
        let mut newly = Vec::new();
        for i in 0..nblocks - 1 {
            table.complete(0, i, &mut newly);
            assert!(newly.is_empty(), "pass 1 released after only {} completions", i + 1);
        }
        table.complete(0, nblocks - 1, &mut newly);
        let ready: HashSet<usize> = newly.iter().map(|&(p, i)| {
            assert_eq!(p, 1);
            i
        }).collect();
        assert_eq!(ready.len(), nblocks, "all pass-1 blocks release together");
    }

    #[test]
    fn dep_table_interior_block_needs_nine_neighbors_2d() {
        // 3x3 lattice, reach 1: the center block of pass 1 must wait
        // for all 9 pass-0 blocks; a corner only for its 4 neighbors.
        let table = DepTable::new([1, 3, 3], [0, 1, 1], 2, PassMode::Pipelined);
        assert_eq!(table.pred_count(4), 9); // center
        assert_eq!(table.pred_count(0), 4); // corner
        assert_eq!(table.pred_count(1), 6); // edge
    }

    #[test]
    fn dep_table_completion_counts_match_pred_counts() {
        // Sum of decrements each pass-1 block receives over a full
        // pass-0 sweep equals its initial predecessor count (the
        // neighbor relation is symmetric).
        let dims = [2, 3, 4];
        let nblocks = 24;
        for reach in [[0, 0, 0], [1, 1, 1], [0, 1, 2]] {
            let table = DepTable::new(dims, reach, 2, PassMode::Pipelined);
            let mut newly = Vec::new();
            for i in 0..nblocks {
                table.complete(0, i, &mut newly);
            }
            let set: HashSet<usize> = newly.iter().map(|&(_, i)| i).collect();
            assert_eq!(set.len(), nblocks, "reach {reach:?}: every block released exactly once");
        }
    }

    #[test]
    fn ready_queue_counts_and_aborts() {
        let q = ReadyQueue::new(3, [(0usize, 0usize), (0, 1)]);
        assert_eq!(q.pop(), Some((0, 0)));
        q.push_all(&[(1, 0)]);
        assert_eq!(q.pop(), Some((0, 1)));
        assert_eq!(q.pop(), Some((1, 0)));
        assert_eq!(q.pop(), None, "all dispatched");

        let q = ReadyQueue::new(5, [(0usize, 0usize)]);
        q.abort();
        assert_eq!(q.pop(), None, "aborted queue releases poppers");
    }

    #[test]
    fn ready_queue_releases_parked_threads_on_final_dispatch() {
        let q = Arc::new(ReadyQueue::new(2, [(0usize, 0usize), (0, 1)]));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let q = q.clone();
                s.spawn(move || while q.pop().is_some() {});
            }
        }); // must not hang
    }

    // ---------- drive_local end-to-end (fake kernel, no artifacts) ----------

    /// Minimal 2D StencilSpace over raw grid handles: block lattice,
    /// halo extraction, interior write-back — enough to run the real
    /// driver with a native-Rust kernel.
    struct TestSpace2D {
        origins: Vec<(usize, usize)>,
        lattice: [usize; 3],
        reach: [usize; 3],
        ny: usize,
        nx: usize,
        block: usize,
        halo: usize,
        tile: usize,
        pools: TensorPools,
    }

    impl TestSpace2D {
        fn new(ny: usize, nx: usize, block: usize, halo: usize) -> TestSpace2D {
            let mut origins = Vec::new();
            let mut y0 = 0;
            while y0 < ny {
                let mut x0 = 0;
                while x0 < nx {
                    origins.push((y0, x0));
                    x0 += block;
                }
                y0 += block;
            }
            let nby = ny.div_ceil(block);
            let nbx = nx.div_ceil(block);
            let reach_b = halo.div_ceil(block);
            TestSpace2D {
                origins,
                lattice: [1, nby, nbx],
                reach: [0, reach_b, reach_b],
                ny,
                nx,
                block,
                halo,
                tile: block + 2 * halo,
                pools: TensorPools::default(),
            }
        }
    }

    impl StencilSpace for TestSpace2D {
        type Handle = GridWriter2D;

        fn nblocks(&self) -> usize {
            self.origins.len()
        }
        fn lattice(&self) -> [usize; 3] {
            self.lattice
        }
        fn reach(&self) -> [usize; 3] {
            self.reach
        }
        unsafe fn extract(&self, src: GridWriter2D, block: usize) -> Vec<Tensor> {
            let (y0, x0) = self.origins[block];
            let mut t = self.pools.tiles.take(self.tile * self.tile);
            src.extract_tile_into(
                y0 as isize, x0 as isize, self.tile, self.tile, self.halo,
                Boundary::Zero, &mut t,
            );
            vec![Tensor::F32(t, vec![self.tile, self.tile])]
        }
        unsafe fn write(&self, dst: GridWriter2D, block: usize, out: &[f32]) {
            let (y0, x0) = self.origins[block];
            dst.write_block(y0, x0, self.block, self.block, out);
        }
        fn recycle(&self, inputs: Vec<Tensor>) {
            self.pools.recycle(inputs);
        }
        fn pool_counters(&self) -> (u64, u64, u64, u64) {
            (
                self.pools.tiles.hits(),
                self.pools.tiles.misses(),
                self.pools.descs.hits(),
                self.pools.descs.misses(),
            )
        }
    }

    /// The fake compute unit: one T=1 five-point average over the
    /// halo'd tile, returning the block interior.  Deterministic f32
    /// arithmetic, so any valid schedule must be bitwise identical.
    fn blur_kernel(tile: usize, halo: usize, block: usize, t: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; block * block];
        for by in 0..block {
            for bx in 0..block {
                let y = by + halo;
                let x = bx + halo;
                let c = t[y * tile + x];
                let up = t[(y - 1) * tile + x];
                let dn = t[(y + 1) * tile + x];
                let lf = t[y * tile + x - 1];
                let rt = t[y * tile + x + 1];
                out[by * block + bx] = 0.2 * (c + up + dn + lf + rt);
            }
        }
        out
    }

    /// Reference: the same kernel applied pass-by-pass with a full
    /// barrier (plain double-buffered sweep).
    fn blur_reference(mut g: Grid2D, passes: usize) -> Grid2D {
        for _ in 0..passes {
            let mut next = Grid2D::zeros(g.ny, g.nx);
            for y in 0..g.ny {
                for x in 0..g.nx {
                    let r = |yy: isize, xx: isize| g.read(yy, xx, Boundary::Zero);
                    let y = y as isize;
                    let x = x as isize;
                    next.data[(y * g.nx as isize + x) as usize] = 0.2
                        * (r(y, x) + r(y - 1, x) + r(y + 1, x) + r(y, x - 1) + r(y, x + 1));
                }
            }
            g = next;
        }
        g
    }

    fn run_driver_case(ny: usize, nx: usize, block: usize, passes: usize, lookahead: usize) {
        let halo = 1; // r·T = 1 for the five-point blur
        let mut rng = crate::testutil::Rng::new(7);
        let init = Grid2D { ny, nx, data: rng.vec_f32(ny * nx, 0.0, 1.0) };
        let want = blur_reference(init.clone(), passes);

        let space = TestSpace2D::new(ny, nx, block, halo);
        let mut cur = init;
        let mut next = Grid2D::zeros(ny, nx);
        // SAFETY: `cur`/`next` outlive the drive below; the driver's
        // dependency table keeps concurrent block accesses disjoint and
        // neither grid is touched through another path until it returns.
        let handles = unsafe { [cur.shared_writer(), next.shared_writer()] };
        let tile = space.tile;
        let (blocks, _) = drive_local(
            |_b, inputs| Ok(blur_kernel(tile, halo, block, inputs[0].as_f32())),
            &space,
            handles,
            passes,
            lookahead,
        )
        .unwrap();
        assert_eq!(blocks as usize, passes * space.nblocks());
        let got = if passes % 2 == 0 { cur } else { next };
        assert_eq!(got.data, want.data, "{ny}x{nx} block={block} passes={passes}");
    }

    #[test]
    fn drive_local_matches_barrier_reference_bitwise() {
        // Pipelined cross-pass schedule == plain barriered sweep,
        // bitwise, across geometries (including partial edge blocks).
        run_driver_case(8, 8, 4, 3, 4);
        run_driver_case(12, 10, 4, 4, 4); // partial blocks
        run_driver_case(6, 6, 2, 5, 4); // deep pipeline, many small blocks
        run_driver_case(4, 4, 4, 2, 4); // single-block lattice
        run_driver_case(9, 7, 3, 3, 2); // odd geometry, small lookahead
    }

    #[test]
    fn drive_local_sequential_fallback_matches() {
        // lookahead 1 forces the sequential path.
        run_driver_case(8, 8, 4, 3, 1);
    }

    #[test]
    fn drive_local_steady_state_reuses_tiles() {
        let space = TestSpace2D::new(8, 8, 4, 1);
        let mut cur = Grid2D::from_fn(8, 8, |y, x| (y * 8 + x) as f32);
        let mut next = Grid2D::zeros(8, 8);
        // SAFETY: `cur`/`next` outlive the drive below; the driver's
        // dependency table keeps concurrent block accesses disjoint and
        // neither grid is touched through another path until it returns.
        let handles = unsafe { [cur.shared_writer(), next.shared_writer()] };
        let tile = space.tile;
        drive_local(
            |_b, inputs| Ok(blur_kernel(tile, 1, 4, inputs[0].as_f32())),
            &space,
            handles,
            4,
            1, // sequential: one tile in flight
        )
        .unwrap();
        let (hits, misses, _, _) = space.pool_counters();
        assert_eq!(misses, 1, "steady state allocates exactly the in-flight tile");
        assert_eq!(hits, 4 * space.nblocks() as u64 - 1);
    }

    #[test]
    fn drive_local_error_propagates_and_stops() {
        let space = TestSpace2D::new(8, 8, 4, 1);
        let mut cur = Grid2D::zeros(8, 8);
        let mut next = Grid2D::zeros(8, 8);
        // SAFETY: `cur`/`next` outlive the drive below; the driver's
        // dependency table keeps concurrent block accesses disjoint and
        // neither grid is touched through another path until it returns.
        let handles = unsafe { [cur.shared_writer(), next.shared_writer()] };
        let mut n = 0;
        let r = drive_local(
            |_b, _inputs| {
                n += 1;
                if n == 3 {
                    anyhow::bail!("boom")
                }
                Ok(vec![0.0; 16])
            },
            &space,
            handles,
            4,
            4,
        );
        assert!(r.is_err());
    }

    #[test]
    fn drive_local_zero_passes_is_noop() {
        let space = TestSpace2D::new(8, 8, 4, 1);
        let mut cur = Grid2D::zeros(8, 8);
        let mut next = Grid2D::zeros(8, 8);
        // SAFETY: `cur`/`next` outlive the drive below; the driver's
        // dependency table keeps concurrent block accesses disjoint and
        // neither grid is touched through another path until it returns.
        let handles = unsafe { [cur.shared_writer(), next.shared_writer()] };
        let (blocks, _) =
            drive_local(|_b, _i| Ok(vec![0.0; 16]), &space, handles, 0, 4).unwrap();
        assert_eq!(blocks, 0);
    }

    // ---------- WaveTable scheduling-invariant tests ----------

    /// Synthetic wave graph built from explicit pred lists:
    /// `preds[w][i]` = the predecessors of block (w, i).
    struct TestGraph {
        preds: Vec<Vec<Vec<(usize, usize)>>>,
    }

    impl WaveGraph for TestGraph {
        fn waves(&self) -> usize {
            self.preds.len()
        }
        fn wave_len(&self, w: usize) -> usize {
            self.preds[w].len()
        }
        fn visit_preds(&self, w: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
            for &(v, j) in &self.preds[w][i] {
                f(v, j);
            }
        }
    }

    /// Simulation harness for the wave table (the wavefront analogue
    /// of `simulate`): dispatches ready blocks in an arbitrary order,
    /// asserting before each completion that every declared
    /// predecessor already completed — and, in Barrier mode, every
    /// block of every earlier wave.
    fn simulate_waves(graph: &TestGraph, mode: PassMode, mut pick: impl FnMut(usize) -> usize) {
        let table = WaveTable::new(graph, mode);
        let total = table.total();
        let mut ready = table.seed();
        let mut completed: HashSet<(usize, usize)> = HashSet::new();
        let mut dispatched = 0usize;
        while !ready.is_empty() {
            let idx = pick(ready.len()) % ready.len();
            let (w, i) = ready.swap_remove(idx);
            dispatched += 1;
            graph.visit_preds(w, i, &mut |v, j| {
                assert!(
                    completed.contains(&(v, j)),
                    "block (w={w}, i={i}) scheduled before predecessor (w={v}, i={j})"
                );
            });
            if mode == PassMode::Barrier {
                for v in 0..w {
                    for j in 0..graph.wave_len(v) {
                        assert!(
                            completed.contains(&(v, j)),
                            "barrier: (w={w}, i={i}) before wave-{v} block {j}"
                        );
                    }
                }
            }
            assert!(completed.insert((w, i)), "double-scheduled");
            let mut newly = Vec::new();
            table.complete(w, i, &mut newly);
            ready.extend(newly);
        }
        assert_eq!(dispatched, total, "not every block ran");
    }

    /// A 1D wavefront (Pathfinder-shaped): `waves` uniform waves of
    /// `n` blocks, span-overlap reach `r`.
    fn lattice1d_graph(waves: usize, n: usize, r: usize) -> TestGraph {
        let mut preds = vec![vec![Vec::new(); n]];
        for _ in 1..waves {
            let mut wave = Vec::with_capacity(n);
            for i in 0..n {
                let lo = i.saturating_sub(r);
                let hi = (i + r).min(n - 1);
                wave.push((lo..=hi).map(|j| (preds.len() - 1, j)).collect());
            }
            preds.push(wave);
        }
        TestGraph { preds }
    }

    /// A LUD-shaped graph: 3 waves per step (diagonal 1, perimeter
    /// 2r, internal r²) with the factorization's non-consecutive
    /// (wave-skipping) edges.
    fn lud_graph(nb: usize) -> TestGraph {
        let mut preds: Vec<Vec<Vec<(usize, usize)>>> = Vec::new();
        for k in 0..nb {
            let rprev = nb - k; // internal extent of step k-1
            let idx_prev = |i: usize, j: usize| (i - k) * rprev + (j - k);
            // diagonal wave 3k
            let mut dia = vec![Vec::new()];
            if k > 0 {
                dia[0].push((3 * k - 1, idx_prev(k, k)));
            }
            preds.push(dia);
            // perimeter wave 3k+1
            let mut perim = Vec::new();
            for j in k + 1..nb {
                let mut row = vec![(3 * k, 0)];
                let mut col = vec![(3 * k, 0)];
                if k > 0 {
                    row.push((3 * k - 1, idx_prev(k, j)));
                    col.push((3 * k - 1, idx_prev(j, k)));
                }
                perim.push(row);
                perim.push(col);
            }
            preds.push(perim);
            // internal wave 3k+2
            let mut internal = Vec::new();
            for i in k + 1..nb {
                for j in k + 1..nb {
                    let mut p = vec![
                        (3 * k + 1, 2 * (j - k - 1)),
                        (3 * k + 1, 2 * (i - k - 1) + 1),
                    ];
                    if k > 0 {
                        p.push((3 * k - 1, idx_prev(i, j)));
                    }
                    internal.push(p);
                }
            }
            preds.push(internal);
        }
        TestGraph { preds }
    }

    /// An SRAD-shaped two-stage graph: alternating reduction (full
    /// edge in) and stencil (span edge out) waves.
    fn two_stage_graph(steps: usize, ntiles: usize, nblocks: usize) -> TestGraph {
        let mut preds: Vec<Vec<Vec<(usize, usize)>>> = Vec::new();
        for s in 0..steps {
            // reduction wave 2s: overlapping stencil blocks of 2s-1
            // (synthetically: tiles t depends on blocks t % nblocks and
            // (t+1) % nblocks — a sparse, non-trivial overlap set).
            let mut red = Vec::with_capacity(ntiles);
            for t in 0..ntiles {
                if s == 0 {
                    red.push(Vec::new());
                } else {
                    red.push(vec![
                        (2 * s - 1, t % nblocks),
                        (2 * s - 1, (t + 1) % nblocks),
                    ]);
                }
            }
            preds.push(red);
            // stencil wave 2s+1: all reduction tiles of step s
            let sten: Vec<Vec<(usize, usize)>> =
                (0..nblocks).map(|_| (0..ntiles).map(|t| (2 * s, t)).collect()).collect();
            preds.push(sten);
        }
        TestGraph { preds }
    }

    #[test]
    fn wave_table_invariants_across_graph_shapes_and_orders() {
        let graphs = [
            lattice1d_graph(4, 5, 1),
            lattice1d_graph(3, 1, 1),  // single-block waves
            lattice1d_graph(5, 4, 0),  // self-column dependency only
            lud_graph(1),
            lud_graph(2),
            lud_graph(4),
            two_stage_graph(3, 4, 6),
            two_stage_graph(1, 1, 1),
        ];
        for g in &graphs {
            for mode in [PassMode::Pipelined, PassMode::Barrier] {
                for order in 0..7usize {
                    simulate_waves(g, mode, |len| match order {
                        0 => 0,
                        1 => len - 1,
                        k => (k * 131) % len,
                    });
                }
            }
        }
    }

    #[test]
    fn wave_table_randomized_orders() {
        let mut rng = crate::testutil::Rng::new(97);
        for _ in 0..20 {
            let g = match rng.usize_in(0, 2) {
                0 => lattice1d_graph(rng.usize_in(1, 4), rng.usize_in(1, 5), rng.usize_in(0, 2)),
                1 => lud_graph(rng.usize_in(1, 4)),
                _ => two_stage_graph(rng.usize_in(1, 3), rng.usize_in(1, 4), rng.usize_in(1, 5)),
            };
            for mode in [PassMode::Pipelined, PassMode::Barrier] {
                let mut r2 = crate::testutil::Rng::new(rng.next_u64());
                simulate_waves(&g, mode, move |len| r2.usize_in(0, len - 1));
            }
        }
    }

    #[test]
    fn wave_table_seed_is_zero_pred_blocks() {
        // LUD step 0: only the diagonal is initially runnable.
        let table = WaveTable::new(&lud_graph(3), PassMode::Pipelined);
        assert_eq!(table.seed(), vec![(0, 0)]);
        // SRAD step 0: every reduction tile seeds.
        let table = WaveTable::new(&two_stage_graph(2, 3, 2), PassMode::Pipelined);
        assert_eq!(table.seed(), vec![(0, 0), (0, 1), (0, 2)]);
        // Barrier mode: wave 0 seeds regardless of declared edges.
        let table = WaveTable::new(&two_stage_graph(2, 3, 2), PassMode::Barrier);
        assert_eq!(table.seed(), vec![(0, 0), (0, 1), (0, 2)]);
    }

    #[test]
    fn wave_table_empty_waves_are_skipped() {
        // LUD's tail step has empty perimeter/internal waves; the run
        // must still dispatch every non-empty block, in either mode.
        let g = lud_graph(2); // waves 4 and 5 are empty
        assert_eq!(g.wave_len(4), 0);
        assert_eq!(g.wave_len(5), 0);
        for mode in [PassMode::Pipelined, PassMode::Barrier] {
            simulate_waves(&g, mode, |_| 0);
        }
    }

    #[test]
    fn wave_table_total_and_coords() {
        let g = lud_graph(3); // 1+4+4, 1+2+1, 1 = 14 blocks
        let table = WaveTable::new(&g, PassMode::Pipelined);
        assert_eq!(table.total(), 14);
        assert_eq!(table.coord(0), (0, 0));
        assert_eq!(table.coord(1), (1, 0));
        assert_eq!(table.coord(5), (2, 0));
        assert_eq!(table.coord(13), (6, 0));
    }

    // ---------- drive_wave_local end-to-end (native NW kernel) ----------

    /// Needleman-Wunsch over anti-diagonal waves with a native-Rust
    /// block kernel: the wavefront counterpart of `TestSpace2D` —
    /// enough to run the real wave scheduler without artifacts and
    /// compare bitwise against the serial oracle.
    struct TestNwSpace {
        nb: usize,
        b: usize,
        stride: usize,
        refm: Vec<i32>,
        score_ptr: *mut i32,
    }

    // SAFETY: the raw score pointer is only dereferenced on
    // dependency-ordered anti-diagonal cells (the wave table serializes
    // every overlapping access), over a buffer that outlives the drive.
    unsafe impl Send for TestNwSpace {}
    unsafe impl Sync for TestNwSpace {}

    impl TestNwSpace {
        fn lo(&self, d: usize) -> usize {
            d.saturating_sub(self.nb - 1)
        }
        fn block_of(&self, d: usize, i: usize) -> (usize, usize) {
            let bi = self.lo(d) + i;
            (bi, d - bi)
        }
    }

    impl WaveGraph for TestNwSpace {
        fn waves(&self) -> usize {
            2 * self.nb - 1
        }
        fn wave_len(&self, d: usize) -> usize {
            d.min(self.nb - 1) - self.lo(d) + 1
        }
        fn visit_preds(&self, d: usize, i: usize, f: &mut dyn FnMut(usize, usize)) {
            let (bi, bj) = self.block_of(d, i);
            if d == 0 {
                return;
            }
            let plo = self.lo(d - 1);
            if bi > 0 {
                f(d - 1, bi - 1 - plo);
            }
            if bj > 0 {
                f(d - 1, bi - plo);
            }
        }
    }

    impl WaveSpace for TestNwSpace {
        fn artifact(&self, _w: usize, _i: usize) -> Arc<str> {
            Arc::from("native-nw")
        }
        unsafe fn extract(&self, d: usize, i: usize) -> Vec<Tensor> {
            let (bi, bj) = self.block_of(d, i);
            let b = self.b;
            let (r0, c0) = (1 + bi * b, 1 + bj * b);
            let at = |r: usize, c: usize| *self.score_ptr.add(r * self.stride + c);
            let top: Vec<i32> = (0..b).map(|k| at(r0 - 1, c0 + k)).collect();
            let left: Vec<i32> = (0..b).map(|k| at(r0 + k, c0 - 1)).collect();
            let corner = vec![at(r0 - 1, c0 - 1)];
            let mut refb = Vec::with_capacity(b * b);
            for k in 0..b {
                refb.extend_from_slice(&self.refm[(r0 + k) * self.stride + c0..][..b]);
            }
            vec![
                Tensor::I32(top, vec![b]),
                Tensor::I32(left, vec![b]),
                Tensor::I32(corner, vec![1]),
                Tensor::I32(refb, vec![b, b]),
            ]
        }
        unsafe fn write(&self, d: usize, i: usize, out: &[Tensor]) {
            let (bi, bj) = self.block_of(d, i);
            let b = self.b;
            let (r0, c0) = (1 + bi * b, 1 + bj * b);
            let vals = out[0].as_i32();
            for k in 0..b {
                std::ptr::copy_nonoverlapping(
                    vals[k * b..].as_ptr(),
                    self.score_ptr.add((r0 + k) * self.stride + c0),
                    b,
                );
            }
        }
        fn cell_updates(&self, _w: usize, _i: usize) -> u64 {
            (self.b * self.b) as u64
        }
    }

    /// The native block kernel: the NW recurrence over one b×b block
    /// from its top/left/corner borders.
    fn nw_block_kernel(b: usize, penalty: i32, inputs: &[Tensor]) -> Vec<i32> {
        let top = inputs[0].as_i32();
        let left = inputs[1].as_i32();
        let corner = inputs[2].as_i32()[0];
        let refb = inputs[3].as_i32();
        let mut s = vec![0i32; b * b];
        let get = |s: &[i32], i: isize, j: isize| -> i32 {
            if i < 0 && j < 0 {
                corner
            } else if i < 0 {
                top[j as usize]
            } else if j < 0 {
                left[i as usize]
            } else {
                s[i as usize * b + j as usize]
            }
        };
        for i in 0..b {
            for j in 0..b {
                let (ii, jj) = (i as isize, j as isize);
                s[i * b + j] = (get(&s, ii - 1, jj - 1) + refb[i * b + j])
                    .max(get(&s, ii - 1, jj) - penalty)
                    .max(get(&s, ii, jj - 1) - penalty);
            }
        }
        s
    }

    fn run_wave_nw_case(n: usize, b: usize, mode: PassMode, lookahead: usize) {
        let penalty = 3;
        let mut rng = crate::testutil::Rng::new(11 + n as u64);
        let reference: Vec<Vec<i32>> =
            (0..=n).map(|_| rng.vec_i32(n + 1, -5, 15)).collect();
        let want = crate::coordinator::reference::nw(&reference, penalty);

        let stride = n + 1;
        let mut refm = Vec::with_capacity(stride * stride);
        for row in &reference {
            refm.extend_from_slice(row);
        }
        let mut score = vec![0i32; stride * stride];
        for j in 0..=n {
            score[j] = -(j as i32) * penalty;
        }
        for i in 0..=n {
            score[i * stride] = -(i as i32) * penalty;
        }
        let space = TestNwSpace {
            nb: n / b,
            b,
            stride,
            refm,
            score_ptr: score.as_mut_ptr(),
        };
        let stats = drive_wave_local(
            |_w, _i, inputs| {
                Ok(vec![Tensor::I32(nw_block_kernel(b, penalty, inputs), vec![b, b])])
            },
            &space,
            mode,
            lookahead,
        )
        .unwrap();
        assert_eq!(stats.blocks as usize, (n / b) * (n / b));
        assert_eq!(stats.cell_updates as usize, n * n);
        let got: Vec<Vec<i32>> = score.chunks(stride).map(|r| r.to_vec()).collect();
        assert_eq!(got, want, "n={n} b={b} mode={mode:?}");
        if mode == PassMode::Barrier {
            assert!(stats.pipeline_depth_max <= 1, "barrier must stay wave-serial");
            assert_eq!(stats.overlap_starts, 0);
        } else {
            assert!(stats.pipeline_depth_max >= 1);
        }
    }

    #[test]
    fn drive_wave_local_nw_matches_oracle_bitwise() {
        // Pipelined anti-diagonal schedule == serial oracle, bitwise,
        // across geometries, both modes, threaded and sequential paths.
        run_wave_nw_case(12, 4, PassMode::Pipelined, 4);
        run_wave_nw_case(12, 4, PassMode::Barrier, 4);
        run_wave_nw_case(8, 2, PassMode::Pipelined, 2);
        run_wave_nw_case(6, 6, PassMode::Pipelined, 4); // single block
        run_wave_nw_case(10, 2, PassMode::Pipelined, 1); // sequential path
    }

    #[test]
    fn drive_wave_local_error_propagates() {
        let mut score = vec![0i32; 49];
        let space = TestNwSpace {
            nb: 3,
            b: 2,
            stride: 7,
            refm: vec![0; 49],
            score_ptr: score.as_mut_ptr(),
        };
        let mut n = 0;
        let r = drive_wave_local(
            |_w, _i, _inputs| {
                n += 1;
                if n == 3 {
                    anyhow::bail!("boom")
                }
                Ok(vec![Tensor::I32(vec![0; 4], vec![2, 2])])
            },
            &space,
            PassMode::Pipelined,
            1,
        );
        assert!(r.is_err());
    }

    // ---------- scoped cancellation (WaveTable::cancel) ----------

    /// Pure-logic reachability oracle: build the successor map by
    /// reversing `visit_preds`, then BFS from the failed block.  The
    /// failed block itself is excluded, matching `cancel`'s contract.
    fn cancel_oracle(g: &TestGraph, from: (usize, usize)) -> Vec<(usize, usize)> {
        use std::collections::HashMap;
        let mut succs: HashMap<(usize, usize), Vec<(usize, usize)>> = HashMap::new();
        for w in 0..g.waves() {
            for i in 0..g.wave_len(w) {
                g.visit_preds(w, i, &mut |v, j| {
                    succs.entry((v, j)).or_default().push((w, i));
                });
            }
        }
        let mut seen = HashSet::new();
        let mut queue: VecDeque<(usize, usize)> =
            succs.get(&from).cloned().unwrap_or_default().into();
        let mut cone = Vec::new();
        while let Some(b) = queue.pop_front() {
            if seen.insert(b) {
                cone.push(b);
                queue.extend(succs.get(&b).cloned().unwrap_or_default());
            }
        }
        cone.sort_unstable();
        cone
    }

    #[test]
    fn wave_table_cancel_matches_reachability_oracle() {
        // Every (graph shape, failed block) pair: the CSR successor
        // walk must cancel exactly the transitive-successor set.
        let graphs = [
            lattice1d_graph(4, 5, 1),
            lud_graph(3),
            two_stage_graph(2, 3, 4),
        ];
        for g in &graphs {
            for w in 0..g.waves() {
                for i in 0..g.wave_len(w) {
                    let table = WaveTable::new(g, PassMode::Pipelined);
                    let mut got = table.cancel(w, i);
                    got.sort_unstable();
                    assert_eq!(got, cancel_oracle(g, (w, i)), "cone of ({w},{i})");
                }
            }
        }
    }

    #[test]
    fn wave_table_cancel_barrier_cone_is_every_later_block() {
        // Under the wave-serial schedule every later block depends on
        // the failed one — including blocks of empty-adjacent waves
        // (lud_graph(2) has empty waves 4 and 5).
        let g = lud_graph(2);
        for w in 0..g.waves() {
            for i in 0..g.wave_len(w) {
                let table = WaveTable::new(&g, PassMode::Barrier);
                let want: Vec<(usize, usize)> = (w + 1..g.waves())
                    .flat_map(|v| (0..g.wave_len(v)).map(move |j| (v, j)))
                    .collect();
                let mut got = table.cancel(w, i);
                got.sort_unstable();
                assert_eq!(got, want, "barrier cone of ({w},{i})");
            }
        }
    }

    #[test]
    fn wave_table_cancel_is_idempotent_and_scoped() {
        // Reach-0 lattice = three independent columns.  Cancelling
        // from (0,0) takes out only column 0's later blocks; a second
        // overlapping cancel reports nothing new; and completing
        // (0,1) still releases (1,1) — the untouched column flows.
        let g = lattice1d_graph(3, 3, 0);
        let table = WaveTable::new(&g, PassMode::Pipelined);
        let mut cone = table.cancel(0, 0);
        cone.sort_unstable();
        assert_eq!(cone, vec![(1, 0), (2, 0)]);
        assert!(
            table.cancel(1, 0).is_empty(),
            "overlapping cancel must not double-count"
        );
        let mut newly = Vec::new();
        table.complete(0, 1, &mut newly);
        assert_eq!(newly, vec![(1, 1)], "independent column must stay runnable");
    }

    // ---------- cone checkpoint/replay (WaveTable::rearm) ----------

    /// Replay-round simulation over a re-armed member set: dispatch
    /// ready members in an arbitrary order, asserting that no
    /// non-member is ever released, that every in-set predecessor
    /// completed first, and that every member runs exactly once.
    fn simulate_rearm(g: &TestGraph, table: &WaveTable, members: &[(usize, usize)]) {
        let set: HashSet<(usize, usize)> = members.iter().copied().collect();
        let mut ready = table.rearm(members);
        for b in &ready {
            assert!(set.contains(b), "seed {b:?} is not a member");
        }
        let mut completed: HashSet<(usize, usize)> = HashSet::new();
        let mut dispatched = 0usize;
        while let Some((w, i)) = ready.pop() {
            dispatched += 1;
            g.visit_preds(w, i, &mut |v, j| {
                if set.contains(&(v, j)) {
                    assert!(
                        completed.contains(&(v, j)),
                        "member ({w},{i}) released before in-set predecessor ({v},{j})"
                    );
                }
            });
            assert!(completed.insert((w, i)), "member ({w},{i}) double-scheduled");
            let mut newly = Vec::new();
            table.complete(w, i, &mut newly);
            for b in &newly {
                assert!(set.contains(b), "replay released non-member {b:?}");
            }
            ready.extend(newly);
        }
        assert_eq!(dispatched, members.len(), "not every member re-ran");
    }

    #[test]
    fn wave_table_rearm_seeds_are_exactly_the_failed_blocks() {
        // For every (graph, failed block): cancel the cone, re-arm it,
        // and check the replay seeds are exactly the failed block —
        // every other member retains an in-set predecessor — then
        // re-drive the members under the scheduling invariants.
        let graphs = [
            lattice1d_graph(4, 5, 1),
            lud_graph(3),
            two_stage_graph(2, 3, 4),
        ];
        for g in &graphs {
            for w in 0..g.waves() {
                for i in 0..g.wave_len(w) {
                    let table = WaveTable::new(g, PassMode::Pipelined);
                    let cone = table.cancel(w, i);
                    let mut members = vec![(w, i)];
                    members.extend(cone);
                    let seeds = table.rearm(&members);
                    assert_eq!(seeds, vec![(w, i)], "replay frontier of ({w},{i})");
                    // Re-armed counters must equal each member's in-set
                    // predecessor count.
                    let set: HashSet<(usize, usize)> = members.iter().copied().collect();
                    for &(mw, mi) in &members {
                        let mut in_set = 0u32;
                        g.visit_preds(mw, mi, &mut |v, j| {
                            if set.contains(&(v, j)) {
                                in_set += 1;
                            }
                        });
                        let got = table.remaining[table.offsets[mw] + mi].load(Ordering::Relaxed);
                        assert_eq!(got, in_set, "re-armed count of ({mw},{mi})");
                    }
                    simulate_rearm(g, &table, &members);
                }
            }
        }
    }

    #[test]
    fn wave_table_rearm_is_idempotent() {
        // Re-arming the same member set twice (an aborted replay round
        // that never ran) must restore identical counters and seeds.
        let g = lud_graph(3);
        let table = WaveTable::new(&g, PassMode::Pipelined);
        let mut members = vec![(1, 0)];
        members.extend(table.cancel(1, 0));
        let first = table.rearm(&members);
        let snapshot: Vec<u32> = table
            .remaining
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let second = table.rearm(&members);
        let again: Vec<u32> = table
            .remaining
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        assert_eq!(first, second);
        assert_eq!(snapshot, again);
    }

    #[test]
    fn wave_table_rearm_barrier_restores_wave_serial_order() {
        // Barrier mode: two blocks of one wave fail together; the cone
        // is every later block.  The re-armed replay must seed exactly
        // the two failed blocks and release members wave-serially
        // (a member runs only after every member of every earlier wave).
        let g = lud_graph(3);
        let w = 1; // perimeter wave: 4 blocks
        let table = WaveTable::new(&g, PassMode::Barrier);
        let mut members = vec![(w, 0), (w, 2)];
        members.extend(table.cancel(w, 0));
        assert!(table.cancel(w, 2).is_empty(), "overlapping barrier cancel");
        let seeds = table.rearm(&members);
        assert_eq!(seeds, vec![(w, 0), (w, 2)]);

        let set: HashSet<(usize, usize)> = members.iter().copied().collect();
        let mut ready = seeds;
        let mut completed: HashSet<(usize, usize)> = HashSet::new();
        let mut dispatched = 0usize;
        while let Some((v, j)) = ready.pop() {
            dispatched += 1;
            for &(mw, mi) in &members {
                if mw < v {
                    assert!(
                        completed.contains(&(mw, mi)),
                        "barrier replay: ({v},{j}) before wave-{mw} member {mi}"
                    );
                }
            }
            assert!(completed.insert((v, j)), "double-scheduled");
            let mut newly = Vec::new();
            table.complete(v, j, &mut newly);
            for b in &newly {
                assert!(set.contains(b), "replay released non-member {b:?}");
            }
            ready.extend(newly);
        }
        assert_eq!(dispatched, members.len(), "not every member re-ran");
    }

    #[test]
    fn ready_queue_cancel_shrinks_dispatch_target() {
        let q = ReadyQueue::new(5, [(0, 0), (0, 1)]);
        assert_eq!(q.pop(), Some((0, 0)));
        assert_eq!(q.pop(), Some((0, 1)));
        // The other 3 blocks will never be pushed: accounting them as
        // cancelled lets pop observe completion instead of parking.
        q.cancel(3);
        assert_eq!(q.pop(), None, "cancelled blocks count toward the target");
    }

    #[test]
    fn fault_plan_fires_only_at_matching_attempt() {
        let plan = FaultPlan::default().transient_at(1, 2, 1);
        assert!(plan.fire(0, 0, 1).is_ok(), "other blocks untouched");
        assert!(plan.fire(1, 2, 2).is_ok(), "attempt 2 is clean — retry succeeds");
        let err = plan.fire(1, 2, 1).unwrap_err();
        assert_eq!(FaultKind::of(&err), FaultKind::Transient);
    }

    // ---------- drive_wave_pool fault scoping (lanes, no artifacts) ----------

    #[test]
    fn drive_wave_pool_scopes_fatal_fault_to_dependency_cone() {
        // Empty registry: the seed block's execute fails with an
        // unknown-artifact error (Fatal, no retry).  Every other NW
        // block transitively depends on (0,0), so the whole rest of
        // the table cancels — and the run still drains cleanly: the
        // fault is reported in the outcome, not as a poisoned pool.
        let mut score = vec![0i32; 49];
        let space = Arc::new(TestNwSpace {
            nb: 3,
            b: 2,
            stride: 7,
            refm: vec![0; 49],
            score_ptr: score.as_mut_ptr(),
        });
        let pool = RuntimePool::with_registry(
            ".".into(),
            crate::runtime::Registry::default(),
            2,
        )
        .unwrap();
        let outcome = drive_wave_pool(&pool, &space, PassMode::Pipelined, 2)
            .expect("block faults must not fail the drive");
        assert_eq!(outcome.faults.len(), 1, "exactly the seed block faults");
        let f = &outcome.faults[0];
        assert_eq!((f.wave, f.index), (0, 0));
        assert_eq!(f.kind, FaultKind::Fatal);
        assert_eq!(f.attempts, 1, "Fatal faults must not retry");
        assert!(f.message.contains("native-nw"), "message: {}", f.message);
        let total: usize = (0..space.waves()).map(|w| space.wave_len(w)).sum();
        assert_eq!(
            outcome.cancelled.len(),
            total - 1,
            "everything downstream of the seed block cancels"
        );
        assert_eq!(outcome.metrics.blocks, 0);
        assert_eq!(outcome.metrics.cell_updates, 0);
        assert_eq!(outcome.metrics.jobs_failed, 1);
        assert_eq!(outcome.metrics.job_retries, 0);
    }

    #[test]
    fn drive_wave_pool_replay_exhaustion_reports_cumulative_attempts() {
        // Same empty-registry setup: the seed block's Fatal fault
        // persists across rounds, so a 2-round replay budget re-arms
        // and re-drives the full 9-block cone twice before surfacing
        // the terminal state — with the attempts of all three rounds
        // accumulated on the fault, and the final cancellation set
        // identical to the no-replay run's.
        let mut score = vec![0i32; 49];
        let space = Arc::new(TestNwSpace {
            nb: 3,
            b: 2,
            stride: 7,
            refm: vec![0; 49],
            score_ptr: score.as_mut_ptr(),
        });
        let pool = RuntimePool::with_registry(
            ".".into(),
            crate::runtime::Registry::default(),
            2,
        )
        .unwrap();
        let outcome = drive_wave_pool_replay(
            &pool,
            &space,
            PassMode::Pipelined,
            2,
            ReplayPolicy::with_attempts(2),
        )
        .expect("replayed block faults must not fail the drive");
        assert_eq!(outcome.faults.len(), 1, "the fault never heals");
        let f = &outcome.faults[0];
        assert_eq!((f.wave, f.index), (0, 0));
        assert_eq!(f.kind, FaultKind::Fatal);
        assert_eq!(f.attempts, 3, "one Fatal attempt per round, accumulated");
        assert!(outcome.replays.is_empty(), "nothing healed");
        let total: usize = (0..space.waves()).map(|w| space.wave_len(w)).sum();
        assert_eq!(outcome.cancelled.len(), total - 1);
        assert_eq!(outcome.metrics.cone_replays, 2, "both budget rounds launched");
        assert_eq!(
            outcome.metrics.replay_blocks,
            2 * total as u64,
            "each replay round re-drives the whole 9-block cone"
        );
        assert_eq!(outcome.metrics.blocks, 0);
        assert_eq!(outcome.metrics.jobs_failed, 3);
        assert_eq!(outcome.metrics.job_retries, 0);
    }

    // ---------- block→lane affinity ----------

    #[test]
    fn lane_of_is_stable_modular_hashing() {
        for lanes in 1..=8usize {
            for key in 0..64u64 {
                assert_eq!(lane_of(key, lanes), (key % lanes as u64) as usize);
                // Deterministic: same key, same lane, every time.
                assert_eq!(lane_of(key, lanes), lane_of(key, lanes));
            }
        }
        // Degenerate lane counts never panic or index out of range.
        assert_eq!(lane_of(17, 0), 0);
        assert_eq!(lane_of(17, 1), 0);
    }

    #[test]
    fn lane_of_covers_every_lane() {
        // Block indices are dense, so modular hashing balances them:
        // 8 consecutive keys over 4 lanes land exactly twice per lane.
        let mut counts = [0usize; 4];
        for key in 0..8u64 {
            counts[lane_of(key, 4)] += 1;
        }
        assert_eq!(counts, [2, 2, 2, 2]);
    }

    #[test]
    fn default_affinity_is_block_index_stable_across_waves() {
        // The default WaveSpace key ignores the wave: block i of every
        // wave (and of every chained fragment, which renumbers waves
        // but not block indices) sticks to one lane for the whole run.
        let mut score = vec![0i32; 49];
        let space = TestNwSpace {
            nb: 3,
            b: 2,
            stride: 7,
            refm: vec![0; 49],
            score_ptr: score.as_mut_ptr(),
        };
        for w in 0..space.waves() {
            for i in 0..space.wave_len(w) {
                assert_eq!(space.affinity(w, i), i as u64);
                assert_eq!(
                    lane_of(space.affinity(w, i), 4),
                    lane_of(space.affinity(0, i), 4),
                    "block {i} must key to the same lane in every wave"
                );
            }
        }
    }
}
