//! Temporal-block streaming of stencil workloads through the AOT
//! compute units — the functional counterpart of the Ch. 5 accelerator.
//!
//! One *pass* advances the whole grid by the artifact's fused step count
//! `T`: the grid is cut into `block`-sized interiors, each extracted with
//! an `r·T` halo (overlapped blocking, §5.3.1), pushed through the
//! compute unit, and its interior written to the next grid.  `steps`
//! must be a multiple of `T` (the bitstream's temporal depth is fixed at
//! compile time, exactly as in the thesis).
//!
//! Each workload has two entry points:
//!
//! * `run_stencil{2d,3d}` — single [`Runtime`]: execution pinned to the
//!   caller's thread, one extractor thread pipelining tiles ahead of it;
//! * `run_stencil{2d,3d}_lanes` — [`RuntimePool`]: M extractor workers
//!   feed N execute lanes through the pool's bounded queue, and each
//!   lane writes its own block back (unordered — interiors are
//!   disjoint, so only metrics, not correctness, depend on order).
//!   Results are bit-identical to the single-runtime path for any lane
//!   count (see the lane-invariance integration tests).
//!
//! Both paths marshal through a [`TilePool`], so steady-state passes
//! allocate nothing for tile extraction (`Metrics::pool_hits` /
//! `pool_misses` expose the reuse rate).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use crate::coordinator::bufpool::TilePool;
use crate::coordinator::grid::{Boundary, Grid2D, Grid3D};
use crate::coordinator::metrics::{Metrics, Timed};
use crate::coordinator::scheduler::{feed_blocks, run_pipelined};
use crate::runtime::pool::IdleGuard;
use crate::runtime::{Runtime, RuntimePool, Tensor};

/// Out-of-grid cell counts per tile side: [top, bottom] for an axis.
/// `o0` is the block's interior origin, `n` the grid extent.
fn oob_axis(o0: usize, block: usize, halo: usize, n: usize) -> (i32, i32) {
    let top = halo.saturating_sub(o0).min(block + 2 * halo) as i32;
    let bottom = (o0 + block + halo).saturating_sub(n).min(block + 2 * halo) as i32;
    (top, bottom)
}

fn boundary_of(spec: &crate::runtime::ArtifactSpec) -> Boundary {
    match spec.meta_str("boundary") {
        Some("clamp") => Boundary::Clamp,
        _ => Boundary::Zero,
    }
}

/// Static stencil parameters baked into an artifact's manifest entry.
struct StencilMeta {
    block: usize,
    halo: usize,
    tile: usize,
    t_fused: u64,
    boundary: Boundary,
}

fn stencil_meta(
    spec: &crate::runtime::ArtifactSpec,
    has_aux: bool,
    steps: u64,
) -> crate::Result<StencilMeta> {
    let block = spec.meta_u64("block")? as usize;
    let halo = spec.meta_u64("halo")? as usize;
    let t_fused = spec.meta_u64("steps")?;
    let wants_aux = spec.inputs.len() == 3;
    if wants_aux != has_aux {
        bail!("{}: aux input mismatch (expects {wants_aux})", spec.name);
    }
    if steps % t_fused != 0 {
        bail!("{}: steps {steps} not a multiple of fused T={t_fused}", spec.name);
    }
    Ok(StencilMeta {
        block,
        halo,
        tile: block + 2 * halo,
        t_fused,
        boundary: boundary_of(spec),
    })
}

fn block_origins_2d(ny: usize, nx: usize, block: usize) -> Vec<(usize, usize)> {
    let mut origins = Vec::new();
    let mut y0 = 0;
    while y0 < ny {
        let mut x0 = 0;
        while x0 < nx {
            origins.push((y0, x0));
            x0 += block;
        }
        y0 += block;
    }
    origins
}

fn block_origins_3d(nz: usize, ny: usize, nx: usize, block: usize) -> Vec<(usize, usize, usize)> {
    let mut origins = Vec::new();
    let mut z0 = 0;
    while z0 < nz {
        let mut y0 = 0;
        while y0 < ny {
            let mut x0 = 0;
            while x0 < nx {
                origins.push((z0, y0, x0));
                x0 += block;
            }
            y0 += block;
        }
        z0 += block;
    }
    origins
}

/// Return a block's f32 input buffers to the tile pool for reuse.
///
/// Kernel *output* buffers are deliberately not pooled: they are
/// `block²`/`block³` cells while every extraction request is
/// `tile²`/`tile³` (strictly larger for halo ≥ 1), so they could never
/// satisfy a `take` — shelving them would only hold dead memory.
fn recycle_inputs(pool: &TilePool, inputs: Vec<Tensor>) {
    for t in inputs {
        if let Tensor::F32(v, _) = t {
            pool.put(v);
        }
    }
}

/// How many extractor workers to pair with `lanes` execute lanes: halo
/// extraction runs at memcpy rate, so half the lane count saturates it.
fn extractor_count(lanes: usize) -> usize {
    (lanes + 1) / 2
}

/// Run `steps` time steps of a 2D stencil artifact over `grid`.
///
/// `aux` is the optional second input stream (Hotspot's power grid, same
/// extents).  Returns the final grid and metrics.
pub fn run_stencil2d(
    rt: &Runtime,
    artifact: &str,
    grid: Grid2D,
    aux: Option<&Grid2D>,
    steps: u64,
) -> crate::Result<(Grid2D, Metrics)> {
    let spec = rt
        .registry()
        .get(artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
        .clone();
    let m = stencil_meta(&spec, aux.is_some(), steps)?;
    let (block, halo, tile) = (m.block, m.halo, m.tile);
    let boundary = m.boundary;
    let passes = steps / m.t_fused;

    // Compile up front, outside the timed region (the analogue of FPGA
    // reprogramming, which the thesis also excludes from kernel timing,
    // §4.2.4).
    rt.executable(artifact)?;
    let stats0 = rt.stats();

    let tile_pool = TilePool::default();
    let mut metrics = Metrics::default();
    let wall = Instant::now();
    let mut cur = grid;
    let mut next = Grid2D::zeros(cur.ny, cur.nx);

    // block origins (fixed across passes)
    let origins = block_origins_2d(cur.ny, cur.nx, block);

    for _ in 0..passes {
        let cur_ref = &cur;
        let next_ref = &mut next;
        let pool_ref = &tile_pool;
        let mut writeback = Duration::ZERO;
        let mut blocks = 0u64;
        run_pipelined(
            origins.len(),
            4,
            |id| {
                let (y0, x0) = origins[id];
                let mut inputs = Vec::with_capacity(3);
                let t = cur_ref.extract_tile_pooled(
                    y0 as isize, x0 as isize, tile, tile, halo, boundary, pool_ref);
                inputs.push(Tensor::F32(t, vec![tile, tile]));
                if let Some(a) = aux {
                    let p = a.extract_tile_pooled(
                        y0 as isize, x0 as isize, tile, tile, halo, boundary, pool_ref);
                    inputs.push(Tensor::F32(p, vec![tile, tile]));
                }
                // per-step boundary restoration descriptor (see the
                // physical-boundary contract in kernels/stencil2d.py)
                let (t0, t1) = oob_axis(y0, block, halo, cur_ref.ny);
                let (l0, l1) = oob_axis(x0, block, halo, cur_ref.nx);
                inputs.push(Tensor::I32(vec![t0, t1, l0, l1], vec![4]));
                inputs
            },
            |id, inputs| {
                let out = rt.execute_f32(artifact, &inputs)?;
                let (y0, x0) = origins[id];
                {
                    let _t = Timed::new(&mut writeback);
                    next_ref.write_block(y0, x0, block, block, &out);
                }
                blocks += 1;
                recycle_inputs(pool_ref, inputs);
                Ok(())
            },
        )?;
        metrics.writeback += writeback;
        metrics.blocks += blocks;
        std::mem::swap(&mut cur, &mut next);
    }

    metrics.cell_updates = (cur.ny * cur.nx) as u64 * steps;
    metrics.wall = wall.elapsed();
    let stats = rt.stats();
    metrics.execute =
        Duration::from_secs_f64((stats.execute_ms - stats0.execute_ms) / 1e3);
    metrics.extract =
        Duration::from_secs_f64((stats.marshal_ms - stats0.marshal_ms) / 1e3);
    metrics.pool_hits = tile_pool.hits();
    metrics.pool_misses = tile_pool.misses();
    Ok((cur, metrics))
}

/// Lane-parallel variant of [`run_stencil2d`]: extractor workers feed
/// the pool's execute lanes through its bounded job queue; each lane
/// runs the compute unit on its own PJRT client and writes its block
/// back itself, off the other lanes' critical path.  Bit-identical to
/// the single-runtime path for any lane count.
pub fn run_stencil2d_lanes(
    pool: &RuntimePool,
    artifact: &str,
    grid: Grid2D,
    aux: Option<&Grid2D>,
    steps: u64,
) -> crate::Result<(Grid2D, Metrics)> {
    let spec = pool
        .registry()
        .get(artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
        .clone();
    let m = stencil_meta(&spec, aux.is_some(), steps)?;
    let (block, halo, tile) = (m.block, m.halo, m.tile);
    let boundary = m.boundary;
    let passes = steps / m.t_fused;

    // Compile on every lane outside the timed region.
    pool.warmup_artifact(artifact)?;
    let stats0 = pool.stats();

    let tile_pool = Arc::new(TilePool::default());
    let artifact_arc: Arc<str> = Arc::from(artifact);
    let origins = Arc::new(block_origins_2d(grid.ny, grid.nx, block));
    let blocks_done = Arc::new(AtomicU64::new(0));
    let wb_nanos = Arc::new(AtomicU64::new(0));
    let extractors = extractor_count(pool.lanes());

    let mut metrics = Metrics::default();
    let wall = Instant::now();
    let mut cur = grid;
    let mut next = Grid2D::zeros(cur.ny, cur.nx);

    for _ in 0..passes {
        // SAFETY: every job writes a distinct origin on the block
        // lattice (disjoint interiors), `next` is not touched below
        // until the lanes are drained, and the IdleGuard drains them
        // even on an unwinding exit from this frame.
        let writer = unsafe { next.shared_writer() };
        let cur_ref = &cur;
        let guard = IdleGuard::new(pool);
        let fed = feed_blocks(
            origins.len(),
            extractors,
            |id| {
                let (y0, x0) = origins[id];
                let mut inputs = Vec::with_capacity(3);
                let t = cur_ref.extract_tile_pooled(
                    y0 as isize, x0 as isize, tile, tile, halo, boundary, &tile_pool);
                inputs.push(Tensor::F32(t, vec![tile, tile]));
                if let Some(a) = aux {
                    let p = a.extract_tile_pooled(
                        y0 as isize, x0 as isize, tile, tile, halo, boundary, &tile_pool);
                    inputs.push(Tensor::F32(p, vec![tile, tile]));
                }
                let (t0, t1) = oob_axis(y0, block, halo, cur_ref.ny);
                let (l0, l1) = oob_axis(x0, block, halo, cur_ref.nx);
                inputs.push(Tensor::I32(vec![t0, t1, l0, l1], vec![4]));
                inputs
            },
            |id, inputs| {
                let artifact = artifact_arc.clone();
                let origins = origins.clone();
                let tile_pool = tile_pool.clone();
                let blocks_done = blocks_done.clone();
                let wb_nanos = wb_nanos.clone();
                pool.submit(move |_lane, rt| {
                    let out = rt.execute_f32(&artifact, &inputs)?;
                    let (y0, x0) = origins[id];
                    let t0 = Instant::now();
                    writer.write_block(y0, x0, block, block, &out);
                    wb_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    blocks_done.fetch_add(1, Ordering::Relaxed);
                    recycle_inputs(&tile_pool, inputs);
                    Ok(())
                });
                Ok(())
            },
        );
        // Drain the lanes before touching `next` (pass barrier), then
        // surface extractor-side and lane-side failures in that order.
        let idle = pool.wait_idle();
        drop(guard);
        fed?;
        idle?;
        std::mem::swap(&mut cur, &mut next);
    }

    metrics.blocks = blocks_done.load(Ordering::Relaxed);
    metrics.writeback = Duration::from_nanos(wb_nanos.load(Ordering::Relaxed));
    metrics.cell_updates = (cur.ny * cur.nx) as u64 * steps;
    metrics.wall = wall.elapsed();
    let stats = pool.stats();
    // Aggregate lane-seconds: with N lanes this can exceed wall time.
    metrics.execute =
        Duration::from_secs_f64((stats.execute_ms - stats0.execute_ms) / 1e3);
    metrics.extract =
        Duration::from_secs_f64((stats.marshal_ms - stats0.marshal_ms) / 1e3);
    metrics.pool_hits = tile_pool.hits();
    metrics.pool_misses = tile_pool.misses();
    Ok((cur, metrics))
}

/// Run `steps` time steps of a 3D stencil artifact over `grid`.
pub fn run_stencil3d(
    rt: &Runtime,
    artifact: &str,
    grid: Grid3D,
    aux: Option<&Grid3D>,
    steps: u64,
) -> crate::Result<(Grid3D, Metrics)> {
    let spec = rt
        .registry()
        .get(artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
        .clone();
    let m = stencil_meta(&spec, aux.is_some(), steps)?;
    let (block, halo, tile) = (m.block, m.halo, m.tile);
    let boundary = m.boundary;
    let passes = steps / m.t_fused;

    rt.executable(artifact)?;
    let stats0 = rt.stats();

    let tile_pool = TilePool::default();
    let mut metrics = Metrics::default();
    let wall = Instant::now();
    let mut cur = grid;
    let mut next = Grid3D::zeros(cur.nz, cur.ny, cur.nx);

    let origins = block_origins_3d(cur.nz, cur.ny, cur.nx, block);

    for _ in 0..passes {
        let cur_ref = &cur;
        let next_ref = &mut next;
        let pool_ref = &tile_pool;
        let mut writeback = Duration::ZERO;
        let mut blocks = 0u64;
        run_pipelined(
            origins.len(),
            4,
            |id| {
                let (z0, y0, x0) = origins[id];
                let mut inputs = Vec::with_capacity(3);
                let t = cur_ref.extract_tile_pooled(
                    z0 as isize, y0 as isize, x0 as isize, tile, halo, boundary, pool_ref);
                inputs.push(Tensor::F32(t, vec![tile, tile, tile]));
                if let Some(a) = aux {
                    let p = a.extract_tile_pooled(
                        z0 as isize, y0 as isize, x0 as isize, tile, halo, boundary, pool_ref);
                    inputs.push(Tensor::F32(p, vec![tile, tile, tile]));
                }
                let (z0o, z1o) = oob_axis(z0, block, halo, cur_ref.nz);
                let (y0o, y1o) = oob_axis(y0, block, halo, cur_ref.ny);
                let (x0o, x1o) = oob_axis(x0, block, halo, cur_ref.nx);
                inputs.push(Tensor::I32(vec![z0o, z1o, y0o, y1o, x0o, x1o], vec![6]));
                inputs
            },
            |id, inputs| {
                let out = rt.execute_f32(artifact, &inputs)?;
                let (z0, y0, x0) = origins[id];
                {
                    let _t = Timed::new(&mut writeback);
                    next_ref.write_block(z0, y0, x0, block, &out);
                }
                blocks += 1;
                recycle_inputs(pool_ref, inputs);
                Ok(())
            },
        )?;
        metrics.writeback += writeback;
        metrics.blocks += blocks;
        std::mem::swap(&mut cur, &mut next);
    }

    metrics.cell_updates = (cur.nz * cur.ny * cur.nx) as u64 * steps;
    metrics.wall = wall.elapsed();
    let stats = rt.stats();
    metrics.execute =
        Duration::from_secs_f64((stats.execute_ms - stats0.execute_ms) / 1e3);
    metrics.extract =
        Duration::from_secs_f64((stats.marshal_ms - stats0.marshal_ms) / 1e3);
    metrics.pool_hits = tile_pool.hits();
    metrics.pool_misses = tile_pool.misses();
    Ok((cur, metrics))
}

/// Lane-parallel variant of [`run_stencil3d`]; see
/// [`run_stencil2d_lanes`] for the engine layout.
pub fn run_stencil3d_lanes(
    pool: &RuntimePool,
    artifact: &str,
    grid: Grid3D,
    aux: Option<&Grid3D>,
    steps: u64,
) -> crate::Result<(Grid3D, Metrics)> {
    let spec = pool
        .registry()
        .get(artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
        .clone();
    let m = stencil_meta(&spec, aux.is_some(), steps)?;
    let (block, halo, tile) = (m.block, m.halo, m.tile);
    let boundary = m.boundary;
    let passes = steps / m.t_fused;

    pool.warmup_artifact(artifact)?;
    let stats0 = pool.stats();

    let tile_pool = Arc::new(TilePool::default());
    let artifact_arc: Arc<str> = Arc::from(artifact);
    let origins = Arc::new(block_origins_3d(grid.nz, grid.ny, grid.nx, block));
    let blocks_done = Arc::new(AtomicU64::new(0));
    let wb_nanos = Arc::new(AtomicU64::new(0));
    let extractors = extractor_count(pool.lanes());

    let mut metrics = Metrics::default();
    let wall = Instant::now();
    let mut cur = grid;
    let mut next = Grid3D::zeros(cur.nz, cur.ny, cur.nx);

    for _ in 0..passes {
        // SAFETY: same contract as run_stencil2d_lanes — disjoint block
        // writes, lanes drained (IdleGuard) before `next` is reused.
        let writer = unsafe { next.shared_writer() };
        let cur_ref = &cur;
        let guard = IdleGuard::new(pool);
        let fed = feed_blocks(
            origins.len(),
            extractors,
            |id| {
                let (z0, y0, x0) = origins[id];
                let mut inputs = Vec::with_capacity(3);
                let t = cur_ref.extract_tile_pooled(
                    z0 as isize, y0 as isize, x0 as isize, tile, halo, boundary, &tile_pool);
                inputs.push(Tensor::F32(t, vec![tile, tile, tile]));
                if let Some(a) = aux {
                    let p = a.extract_tile_pooled(
                        z0 as isize, y0 as isize, x0 as isize, tile, halo, boundary, &tile_pool);
                    inputs.push(Tensor::F32(p, vec![tile, tile, tile]));
                }
                let (z0o, z1o) = oob_axis(z0, block, halo, cur_ref.nz);
                let (y0o, y1o) = oob_axis(y0, block, halo, cur_ref.ny);
                let (x0o, x1o) = oob_axis(x0, block, halo, cur_ref.nx);
                inputs.push(Tensor::I32(vec![z0o, z1o, y0o, y1o, x0o, x1o], vec![6]));
                inputs
            },
            |id, inputs| {
                let artifact = artifact_arc.clone();
                let origins = origins.clone();
                let tile_pool = tile_pool.clone();
                let blocks_done = blocks_done.clone();
                let wb_nanos = wb_nanos.clone();
                pool.submit(move |_lane, rt| {
                    let out = rt.execute_f32(&artifact, &inputs)?;
                    let (z0, y0, x0) = origins[id];
                    let t0 = Instant::now();
                    writer.write_block(z0, y0, x0, block, &out);
                    wb_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    blocks_done.fetch_add(1, Ordering::Relaxed);
                    recycle_inputs(&tile_pool, inputs);
                    Ok(())
                });
                Ok(())
            },
        );
        let idle = pool.wait_idle();
        drop(guard);
        fed?;
        idle?;
        std::mem::swap(&mut cur, &mut next);
    }

    metrics.blocks = blocks_done.load(Ordering::Relaxed);
    metrics.writeback = Duration::from_nanos(wb_nanos.load(Ordering::Relaxed));
    metrics.cell_updates = (cur.nz * cur.ny * cur.nx) as u64 * steps;
    metrics.wall = wall.elapsed();
    let stats = pool.stats();
    metrics.execute =
        Duration::from_secs_f64((stats.execute_ms - stats0.execute_ms) / 1e3);
    metrics.extract =
        Duration::from_secs_f64((stats.marshal_ms - stats0.marshal_ms) / 1e3);
    metrics.pool_hits = tile_pool.hits();
    metrics.pool_misses = tile_pool.misses();
    Ok((cur, metrics))
}

/// One pass of a 2D stencil artifact that takes a run-time scalar operand
/// (SRAD's q0² reduction result, shape `[steps]`).  Advances the grid by
/// the artifact's fused step count.
pub fn run_stencil2d_with_scalar(
    rt: &Runtime,
    artifact: &str,
    grid: Grid2D,
    scalar: f32,
) -> crate::Result<(Grid2D, Metrics)> {
    let spec = rt
        .registry()
        .get(artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
        .clone();
    let block = spec.meta_u64("block")? as usize;
    let halo = spec.meta_u64("halo")? as usize;
    let t_fused = spec.meta_u64("steps")? as usize;
    let boundary = boundary_of(&spec);
    let tile = block + 2 * halo;

    let tile_pool = TilePool::default();
    let mut metrics = Metrics::default();
    let wall = Instant::now();
    let cur = grid;
    let mut next = Grid2D::zeros(cur.ny, cur.nx);

    let origins = block_origins_2d(cur.ny, cur.nx, block);

    rt.executable(artifact)?;
    let cur_ref = &cur;
    let next_ref = &mut next;
    let pool_ref = &tile_pool;
    let mut blocks = 0u64;
    run_pipelined(
        origins.len(),
        4,
        |id| {
            let (y0, x0) = origins[id];
            let t = cur_ref.extract_tile_pooled(
                y0 as isize, x0 as isize, tile, tile, halo, boundary, pool_ref);
            let (t0, t1) = oob_axis(y0, block, halo, cur_ref.ny);
            let (l0, l1) = oob_axis(x0, block, halo, cur_ref.nx);
            vec![
                Tensor::F32(t, vec![tile, tile]),
                Tensor::F32(vec![scalar; t_fused], vec![t_fused]),
                Tensor::I32(vec![t0, t1, l0, l1], vec![4]),
            ]
        },
        |id, inputs| {
            let out = rt.execute_f32(artifact, &inputs)?;
            let (y0, x0) = origins[id];
            next_ref.write_block(y0, x0, block, block, &out);
            blocks += 1;
            recycle_inputs(pool_ref, inputs);
            Ok(())
        },
    )?;
    metrics.blocks += blocks;
    metrics.cell_updates = (cur.ny * cur.nx) as u64 * t_fused as u64;
    metrics.wall = wall.elapsed();
    metrics.pool_hits = tile_pool.hits();
    metrics.pool_misses = tile_pool.misses();
    Ok((next, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    // tile coverage invariant: oob(top) + in-grid rows + oob(bottom)
    // always equals the issued tile width.
    fn check_covers(o0: usize, block: usize, halo: usize, n: usize) {
        let tile = (block + 2 * halo) as i64;
        let (top, bottom) = oob_axis(o0, block, halo, n);
        let lo = o0 as i64 - halo as i64;
        let hi = o0 as i64 + (block + halo) as i64;
        let in_grid = (hi.min(n as i64) - lo.max(0)).max(0);
        assert_eq!(
            top as i64 + in_grid + bottom as i64,
            tile,
            "o0={o0} block={block} halo={halo} n={n}"
        );
    }

    #[test]
    fn oob_axis_interior_block_has_no_oob() {
        assert_eq!(oob_axis(256, 256, 4, 1024), (0, 0));
        check_covers(256, 256, 4, 1024);
    }

    #[test]
    fn oob_axis_origin_at_grid_start_and_edge() {
        // origin 0: only the leading halo hangs out
        assert_eq!(oob_axis(0, 256, 4, 1024), (4, 0));
        // last full block: only the trailing halo hangs out
        assert_eq!(oob_axis(768, 256, 4, 1024), (0, 4));
        check_covers(0, 256, 4, 1024);
        check_covers(768, 256, 4, 1024);
    }

    #[test]
    fn oob_axis_block_larger_than_grid() {
        // a 512-block against a 300-cell grid: the whole trailing 212
        // cells of interior plus the 4-halo are out of grid.
        assert_eq!(oob_axis(0, 512, 4, 300), (4, 216));
        check_covers(0, 512, 4, 300);
    }

    #[test]
    fn oob_axis_partial_edge_block() {
        // origin 256 with block 256 against n=300: 212 interior cells
        // plus the trailing halo are out of grid.
        assert_eq!(oob_axis(256, 256, 4, 300), (0, 216));
        check_covers(256, 256, 4, 300);
    }

    #[test]
    fn oob_axis_halo_larger_than_extent() {
        // halo 8 on a 2-cell grid with a 4-block tile (tile = 20):
        // 8 leading + 2 in-grid + 10 trailing.
        assert_eq!(oob_axis(0, 4, 8, 2), (8, 10));
        check_covers(0, 4, 8, 2);
    }

    #[test]
    fn oob_axis_counts_clamped_to_tile() {
        // degenerate: both sides saturate but never exceed the tile.
        let (top, bottom) = oob_axis(0, 2, 50, 1);
        let tile = (2 + 2 * 50) as i32;
        assert!(top <= tile && bottom <= tile);
        check_covers(0, 2, 50, 1);
    }

    #[test]
    fn oob_axis_coverage_sweep() {
        for block in [2usize, 7, 64] {
            for halo in [0usize, 1, 4, 9] {
                for n in [1usize, 5, 63, 64, 65, 200] {
                    let mut o0 = 0;
                    while o0 < n {
                        check_covers(o0, block, halo, n);
                        o0 += block;
                    }
                }
            }
        }
    }

    #[test]
    fn extractor_count_scales_with_lanes() {
        assert_eq!(extractor_count(1), 1);
        assert_eq!(extractor_count(2), 1);
        assert_eq!(extractor_count(4), 2);
        assert_eq!(extractor_count(8), 4);
    }
}
