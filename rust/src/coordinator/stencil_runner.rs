//! Temporal-block streaming of stencil workloads through the AOT
//! compute units — the functional counterpart of the Ch. 5 accelerator.
//!
//! One *pass* advances the whole grid by the artifact's fused step count
//! `T`: the grid is cut into `block`-sized interiors, each extracted with
//! an `r·T` halo (overlapped blocking, §5.3.1), pushed through the
//! compute unit, and its interior written to the next grid.  `steps`
//! must be a multiple of `T` (the bitstream's temporal depth is fixed at
//! compile time, exactly as in the thesis).

use anyhow::{anyhow, bail};

use crate::coordinator::grid::{Boundary, Grid2D, Grid3D};
use crate::coordinator::metrics::{Metrics, Timed};
use crate::coordinator::scheduler::run_pipelined;
use crate::runtime::{Runtime, Tensor};


/// Out-of-grid cell counts per tile side: [top, bottom] for an axis.
/// `o0` is the block's interior origin, `n` the grid extent.
fn oob_axis(o0: usize, block: usize, halo: usize, n: usize) -> (i32, i32) {
    let top = halo.saturating_sub(o0).min(block + 2 * halo) as i32;
    let bottom = (o0 + block + halo).saturating_sub(n).min(block + 2 * halo) as i32;
    (top, bottom)
}

fn boundary_of(spec: &crate::runtime::ArtifactSpec) -> Boundary {
    match spec.meta_str("boundary") {
        Some("clamp") => Boundary::Clamp,
        _ => Boundary::Zero,
    }
}

/// Run `steps` time steps of a 2D stencil artifact over `grid`.
///
/// `aux` is the optional second input stream (Hotspot's power grid, same
/// extents).  Returns the final grid and metrics.
pub fn run_stencil2d(
    rt: &Runtime,
    artifact: &str,
    grid: Grid2D,
    aux: Option<&Grid2D>,
    steps: u64,
) -> crate::Result<(Grid2D, Metrics)> {
    let spec = rt
        .registry()
        .get(artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
        .clone();
    let block = spec.meta_u64("block")? as usize;
    let halo = spec.meta_u64("halo")? as usize;
    let t_fused = spec.meta_u64("steps")?;
    let boundary = boundary_of(&spec);
    let wants_aux = spec.inputs.len() == 3;
    if wants_aux != aux.is_some() {
        bail!("{artifact}: aux input mismatch (expects {wants_aux})");
    }
    if steps % t_fused != 0 {
        bail!("{artifact}: steps {steps} not a multiple of fused T={t_fused}");
    }
    let tile = block + 2 * halo;
    let passes = steps / t_fused;

    // Compile up front, outside the timed region (the analogue of FPGA
    // reprogramming, which the thesis also excludes from kernel timing,
    // §4.2.4).
    rt.executable(artifact)?;
    let stats0 = rt.stats();

    let mut metrics = Metrics::default();
    let wall = std::time::Instant::now();
    let mut cur = grid;
    let mut next = Grid2D::zeros(cur.ny, cur.nx);

    // block origins (fixed across passes)
    let mut origins: Vec<(usize, usize)> = Vec::new();
    let mut y0 = 0;
    while y0 < cur.ny {
        let mut x0 = 0;
        while x0 < cur.nx {
            origins.push((y0, x0));
            x0 += block;
        }
        y0 += block;
    }

    for _ in 0..passes {
        let cur_ref = &cur;
        let next_ref = &mut next;
        let mut writeback = std::time::Duration::ZERO;
        let mut blocks = 0u64;
        run_pipelined(
            origins.len(),
            4,
            |id| {
                let (y0, x0) = origins[id];
                let mut inputs = Vec::with_capacity(3);
                let t = cur_ref.extract_tile(y0 as isize, x0 as isize, tile, tile, halo, boundary);
                inputs.push(Tensor::F32(t, vec![tile, tile]));
                if let Some(a) = aux {
                    let p = a.extract_tile(y0 as isize, x0 as isize, tile, tile, halo, boundary);
                    inputs.push(Tensor::F32(p, vec![tile, tile]));
                }
                // per-step boundary restoration descriptor (see the
                // physical-boundary contract in kernels/stencil2d.py)
                let (t0, t1) = oob_axis(y0, block, halo, cur_ref.ny);
                let (l0, l1) = oob_axis(x0, block, halo, cur_ref.nx);
                inputs.push(Tensor::I32(vec![t0, t1, l0, l1], vec![4]));
                inputs
            },
            |id, inputs| {
                let out = rt.execute(artifact, &inputs)?;
                let (y0, x0) = origins[id];
                let _t = Timed::new(&mut writeback);
                next_ref.write_block(y0, x0, block, block, out[0].as_f32());
                blocks += 1;
                Ok(())
            },
        )?;
        metrics.writeback += writeback;
        metrics.blocks += blocks;
        std::mem::swap(&mut cur, &mut next);
    }

    metrics.cell_updates = (cur.ny * cur.nx) as u64 * steps;
    metrics.wall = wall.elapsed();
    let stats = rt.stats();
    metrics.execute =
        std::time::Duration::from_secs_f64((stats.execute_ms - stats0.execute_ms) / 1e3);
    metrics.extract =
        std::time::Duration::from_secs_f64((stats.marshal_ms - stats0.marshal_ms) / 1e3);
    Ok((cur, metrics))
}

/// Run `steps` time steps of a 3D stencil artifact over `grid`.
pub fn run_stencil3d(
    rt: &Runtime,
    artifact: &str,
    grid: Grid3D,
    aux: Option<&Grid3D>,
    steps: u64,
) -> crate::Result<(Grid3D, Metrics)> {
    let spec = rt
        .registry()
        .get(artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
        .clone();
    let block = spec.meta_u64("block")? as usize;
    let halo = spec.meta_u64("halo")? as usize;
    let t_fused = spec.meta_u64("steps")?;
    let boundary = boundary_of(&spec);
    let wants_aux = spec.inputs.len() == 3;
    if wants_aux != aux.is_some() {
        bail!("{artifact}: aux input mismatch");
    }
    if steps % t_fused != 0 {
        bail!("{artifact}: steps {steps} not a multiple of fused T={t_fused}");
    }
    let tile = block + 2 * halo;
    let passes = steps / t_fused;

    rt.executable(artifact)?;
    let stats0 = rt.stats();

    let mut metrics = Metrics::default();
    let wall = std::time::Instant::now();
    let mut cur = grid;
    let mut next = Grid3D::zeros(cur.nz, cur.ny, cur.nx);

    let mut origins: Vec<(usize, usize, usize)> = Vec::new();
    let mut z0 = 0;
    while z0 < cur.nz {
        let mut y0 = 0;
        while y0 < cur.ny {
            let mut x0 = 0;
            while x0 < cur.nx {
                origins.push((z0, y0, x0));
                x0 += block;
            }
            y0 += block;
        }
        z0 += block;
    }

    for _ in 0..passes {
        let cur_ref = &cur;
        let next_ref = &mut next;
        let mut writeback = std::time::Duration::ZERO;
        let mut blocks = 0u64;
        run_pipelined(
            origins.len(),
            4,
            |id| {
                let (z0, y0, x0) = origins[id];
                let mut inputs = Vec::with_capacity(3);
                let t = cur_ref.extract_tile_owned(
                    z0 as isize, y0 as isize, x0 as isize, tile, halo, boundary);
                inputs.push(Tensor::F32(t, vec![tile, tile, tile]));
                if let Some(a) = aux {
                    let p = a.extract_tile_owned(
                        z0 as isize, y0 as isize, x0 as isize, tile, halo, boundary);
                    inputs.push(Tensor::F32(p, vec![tile, tile, tile]));
                }
                let (z0o, z1o) = oob_axis(z0, block, halo, cur_ref.nz);
                let (y0o, y1o) = oob_axis(y0, block, halo, cur_ref.ny);
                let (x0o, x1o) = oob_axis(x0, block, halo, cur_ref.nx);
                inputs.push(Tensor::I32(vec![z0o, z1o, y0o, y1o, x0o, x1o], vec![6]));
                inputs
            },
            |id, inputs| {
                let out = rt.execute(artifact, &inputs)?;
                let (z0, y0, x0) = origins[id];
                let _t = Timed::new(&mut writeback);
                next_ref.write_block(z0, y0, x0, block, out[0].as_f32());
                blocks += 1;
                Ok(())
            },
        )?;
        metrics.writeback += writeback;
        metrics.blocks += blocks;
        std::mem::swap(&mut cur, &mut next);
    }

    metrics.cell_updates = (cur.nz * cur.ny * cur.nx) as u64 * steps;
    metrics.wall = wall.elapsed();
    let stats = rt.stats();
    metrics.execute =
        std::time::Duration::from_secs_f64((stats.execute_ms - stats0.execute_ms) / 1e3);
    metrics.extract =
        std::time::Duration::from_secs_f64((stats.marshal_ms - stats0.marshal_ms) / 1e3);
    Ok((cur, metrics))
}

/// One pass of a 2D stencil artifact that takes a run-time scalar operand
/// (SRAD's q0² reduction result, shape `[steps]`).  Advances the grid by
/// the artifact's fused step count.
pub fn run_stencil2d_with_scalar(
    rt: &Runtime,
    artifact: &str,
    grid: Grid2D,
    scalar: f32,
) -> crate::Result<(Grid2D, Metrics)> {
    let spec = rt
        .registry()
        .get(artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
        .clone();
    let block = spec.meta_u64("block")? as usize;
    let halo = spec.meta_u64("halo")? as usize;
    let t_fused = spec.meta_u64("steps")? as usize;
    let boundary = boundary_of(&spec);
    let tile = block + 2 * halo;

    let mut metrics = Metrics::default();
    let wall = std::time::Instant::now();
    let cur = grid;
    let mut next = Grid2D::zeros(cur.ny, cur.nx);

    let mut origins: Vec<(usize, usize)> = Vec::new();
    let mut y0 = 0;
    while y0 < cur.ny {
        let mut x0 = 0;
        while x0 < cur.nx {
            origins.push((y0, x0));
            x0 += block;
        }
        y0 += block;
    }

    rt.executable(artifact)?;
    let cur_ref = &cur;
    let next_ref = &mut next;
    let mut blocks = 0u64;
    run_pipelined(
        origins.len(),
        4,
        |id| {
            let (y0, x0) = origins[id];
            let t = cur_ref.extract_tile(y0 as isize, x0 as isize, tile, tile, halo, boundary);
            let (t0, t1) = oob_axis(y0, block, halo, cur_ref.ny);
            let (l0, l1) = oob_axis(x0, block, halo, cur_ref.nx);
            vec![
                Tensor::F32(t, vec![tile, tile]),
                Tensor::F32(vec![scalar; t_fused], vec![t_fused]),
                Tensor::I32(vec![t0, t1, l0, l1], vec![4]),
            ]
        },
        |id, inputs| {
            let out = rt.execute(artifact, &inputs)?;
            let (y0, x0) = origins[id];
            next_ref.write_block(y0, x0, block, block, out[0].as_f32());
            blocks += 1;
            Ok(())
        },
    )?;
    metrics.blocks += blocks;
    metrics.cell_updates = (cur.ny * cur.nx) as u64 * t_fused as u64;
    metrics.wall = wall.elapsed();
    Ok((next, metrics))
}
