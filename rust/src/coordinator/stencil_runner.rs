//! Temporal-block streaming of stencil workloads through the AOT
//! compute units — the functional counterpart of the Ch. 5 accelerator.
//!
//! One *pass* advances the whole grid by the artifact's fused step count
//! `T`: the grid is cut into `block`-sized interiors, each extracted with
//! an `r·T` halo (overlapped blocking, §5.3.1), pushed through the
//! compute unit, and its interior written to the next grid.  `steps`
//! must be a multiple of `T` (the bitstream's temporal depth is fixed at
//! compile time, exactly as in the thesis).
//!
//! Since PR 2 every runner is a thin configuration shim — a block plan
//! plus tile-extraction/write-back callbacks ([`Space2D`]/[`Space3D`])
//! — over the generic [`passdriver`] engine, which owns dependency
//! tracking, lane feeding, double-buffer alternation and metrics.
//! Passes are **cross-pass pipelined**: a block of pass `p+1` starts as
//! soon as its `r·T` halo-overlapping pass-`p` predecessor blocks have
//! written back, so the lanes never drain between passes (no
//! `wait_idle` barrier — the deep-pipeline behaviour of the thesis's
//! combined spatial/temporal blocking).
//!
//! What lives here is the *lowering*, not the entry point: the stencil
//! plan builders ([`StencilMeta`], [`Space2D`]/[`Space3D`],
//! [`block_origins_2d`]) that
//! [`coordinator::session`](crate::coordinator::session) wraps into
//! workload fragments (`Workload::{stencil2d, stencil3d,
//! stencil2d_with_scalar}`).  M extractor workers feed N execute lanes
//! through the pool's bounded queue, and each lane writes its own block
//! back (unordered — interiors are disjoint, so only metrics, not
//! correctness, depend on order).  Results are bit-identical for any
//! lane count and either [`PassMode`] (see the lane-invariance
//! integration tests); the [`PassMode::Barrier`] baseline schedule
//! backs the CI perf gate.  (The pre-PR 4 `run_stencil*` free functions
//! and their `_lanes` shims are gone — the lane-invariance tests now
//! pin the pooled engine against a lanes=1 session over the same
//! spaces.)
//!
//! Extraction marshals through the [`TensorPools`] arenas (f32 tiles
//! *and* the i32 boundary descriptors), so steady-state passes allocate
//! nothing for tile extraction (`Metrics::pool_hits` / `pool_misses` /
//! `desc_pool_hits` / `desc_pool_misses` expose the reuse rates).
//!
//! [`passdriver`]: crate::coordinator::passdriver
//! [`PassMode`]: crate::coordinator::passdriver::PassMode
//! [`PassMode::Barrier`]: crate::coordinator::passdriver::PassMode::Barrier
//! [`Metrics::pool_hits`]: crate::coordinator::metrics::Metrics

use anyhow::bail;

use crate::coordinator::bufpool::TensorPools;
use crate::coordinator::grid::{Boundary, GridWriter2D, GridWriter3D};
use crate::coordinator::passdriver::StencilSpace;
use crate::runtime::Tensor;

/// Out-of-grid cell counts per tile side: [top, bottom] for an axis.
/// `o0` is the block's interior origin, `n` the grid extent.  Shared
/// with the SRAD wavefront space in `coordinator::apps`, whose stencil
/// stage issues the same boundary-restoration descriptors.
pub(crate) fn oob_axis(o0: usize, block: usize, halo: usize, n: usize) -> (i32, i32) {
    let top = halo.saturating_sub(o0).min(block + 2 * halo) as i32;
    let bottom = (o0 + block + halo).saturating_sub(n).min(block + 2 * halo) as i32;
    (top, bottom)
}

/// Boundary rule baked into an artifact's manifest entry.  (Also used
/// by the SRAD wavefront space in `coordinator::apps`.)
pub(crate) fn boundary_of(spec: &crate::runtime::ArtifactSpec) -> Boundary {
    match spec.meta_str("boundary") {
        Some("clamp") => Boundary::Clamp,
        _ => Boundary::Zero,
    }
}

/// Static stencil parameters baked into an artifact's manifest entry.
/// (Shared with the `Session` lowering in `coordinator::session`.)
pub(crate) struct StencilMeta {
    pub(crate) block: usize,
    pub(crate) halo: usize,
    pub(crate) tile: usize,
    pub(crate) t_fused: u64,
    pub(crate) boundary: Boundary,
}

pub(crate) fn stencil_meta(
    spec: &crate::runtime::ArtifactSpec,
    has_aux: bool,
    steps: u64,
) -> crate::Result<StencilMeta> {
    let block = spec.meta_u64("block")? as usize;
    let halo = spec.meta_u64("halo")? as usize;
    let t_fused = spec.meta_u64("steps")?;
    let wants_aux = spec.inputs.len() == 3;
    if wants_aux != has_aux {
        bail!("{}: aux input mismatch (expects {wants_aux})", spec.name);
    }
    if steps % t_fused != 0 {
        bail!("{}: steps {steps} not a multiple of fused T={t_fused}", spec.name);
    }
    Ok(StencilMeta {
        block,
        halo,
        tile: block + 2 * halo,
        t_fused,
        boundary: boundary_of(spec),
    })
}

/// Manifest parameters of a scalar-carrying stencil artifact (SRAD's
/// q0² stage): like [`stencil_meta`] but without the aux/step-count
/// checks — the workload always advances exactly one fused pass.
/// (Used by the `Session` lowering in `coordinator::session` and the
/// SRAD wavefront space in `coordinator::apps`.)
pub(crate) fn scalar_stencil_meta(
    spec: &crate::runtime::ArtifactSpec,
) -> crate::Result<StencilMeta> {
    let block = spec.meta_u64("block")? as usize;
    let halo = spec.meta_u64("halo")? as usize;
    let t_fused = spec.meta_u64("steps")?;
    Ok(StencilMeta {
        block,
        halo,
        tile: block + 2 * halo,
        t_fused,
        boundary: boundary_of(spec),
    })
}

/// Row-major block-origin plan for a 2D grid.  (Also used by the SRAD
/// wavefront space in `coordinator::apps` for its reduction and
/// stencil lattices.)
pub(crate) fn block_origins_2d(ny: usize, nx: usize, block: usize) -> Vec<(usize, usize)> {
    let mut origins = Vec::new();
    let mut y0 = 0;
    while y0 < ny {
        let mut x0 = 0;
        while x0 < nx {
            origins.push((y0, x0));
            x0 += block;
        }
        y0 += block;
    }
    origins
}

fn block_origins_3d(nz: usize, ny: usize, nx: usize, block: usize) -> Vec<(usize, usize, usize)> {
    let mut origins = Vec::new();
    let mut z0 = 0;
    while z0 < nz {
        let mut y0 = 0;
        while y0 < ny {
            let mut x0 = 0;
            while x0 < nx {
                origins.push((z0, y0, x0));
                x0 += block;
            }
            y0 += block;
        }
        z0 += block;
    }
    origins
}

/// How many extractor workers to pair with `lanes` execute lanes: halo
/// extraction runs at memcpy rate, so half the lane count saturates it.
/// (Also used by the wavefront app runners in `coordinator::apps`.)
pub(crate) fn extractor_count(lanes: usize) -> usize {
    (lanes + 1) / 2
}

/// 2D stencil configuration for the pass driver: the block plan, the
/// `r·T` halo'd extraction (main grid + optional aux + optional
/// per-step scalar + i32 boundary descriptor) and interior write-back.
/// (Shared with the `Session` stencil fragments in
/// `coordinator::session`, which drive it through the wave scheduler.)
pub(crate) struct Space2D {
    pub(crate) origins: Vec<(usize, usize)>,
    lattice: [usize; 3],
    reach: [usize; 3],
    pub(crate) ny: usize,
    pub(crate) nx: usize,
    pub(crate) block: usize,
    pub(crate) halo: usize,
    tile: usize,
    boundary: Boundary,
    /// Raw read view of the aux (e.g. power) grid — never written.
    aux: Option<GridWriter2D>,
    /// Run-time scalar operand, replicated per block (SRAD's q0²).
    scalar: Option<Vec<f32>>,
    pools: TensorPools,
}

impl Space2D {
    pub(crate) fn new(
        ny: usize,
        nx: usize,
        m: &StencilMeta,
        aux: Option<GridWriter2D>,
        scalar: Option<Vec<f32>>,
    ) -> Space2D {
        let origins = block_origins_2d(ny, nx, m.block);
        let reach_b = m.halo.div_ceil(m.block);
        Space2D {
            origins,
            lattice: [1, ny.div_ceil(m.block), nx.div_ceil(m.block)],
            reach: [0, reach_b, reach_b],
            ny,
            nx,
            block: m.block,
            halo: m.halo,
            tile: m.tile,
            boundary: m.boundary,
            aux,
            scalar,
            pools: TensorPools::default(),
        }
    }

    /// Shard the tile/descriptor pools per lane (see
    /// [`TensorPools::with_shards`]); the session lowering sizes this
    /// by the pool's lane count so a block's buffers cycle within its
    /// affinity lane's free list.
    pub(crate) fn with_pool_shards(mut self, shards: usize) -> Space2D {
        self.pools = TensorPools::with_shards(shards);
        self
    }

    /// [`StencilSpace::extract`] drawing buffers from one pool shard.
    ///
    /// # Safety
    ///
    /// Same contract as [`StencilSpace::extract`].
    pub(crate) unsafe fn extract_on(
        &self,
        shard: usize,
        src: GridWriter2D,
        block: usize,
    ) -> Vec<Tensor> {
        let (y0, x0) = self.origins[block];
        let mut inputs = Vec::with_capacity(4);
        let mut t = self.pools.tiles.take_on(shard, self.tile * self.tile);
        src.extract_tile_into(
            y0 as isize, x0 as isize, self.tile, self.tile, self.halo, self.boundary, &mut t,
        );
        inputs.push(Tensor::F32(t, vec![self.tile, self.tile]));
        if let Some(aux) = &self.aux {
            let mut p = self.pools.tiles.take_on(shard, self.tile * self.tile);
            aux.extract_tile_into(
                y0 as isize, x0 as isize, self.tile, self.tile, self.halo, self.boundary, &mut p,
            );
            inputs.push(Tensor::F32(p, vec![self.tile, self.tile]));
        }
        if let Some(s) = &self.scalar {
            let mut v = self.pools.tiles.take_on(shard, s.len());
            v.extend_from_slice(s);
            inputs.push(Tensor::F32(v, vec![s.len()]));
        }
        // per-step boundary restoration descriptor (see the
        // physical-boundary contract in kernels/stencil2d.py)
        let (t0, t1) = oob_axis(y0, self.block, self.halo, self.ny);
        let (l0, l1) = oob_axis(x0, self.block, self.halo, self.nx);
        let mut d = self.pools.descs.take_on(shard, 4);
        d.extend_from_slice(&[t0, t1, l0, l1]);
        inputs.push(Tensor::I32(d, vec![4]));
        inputs
    }

    /// Return recyclable buffers to one pool shard.
    pub(crate) fn recycle_on(&self, shard: usize, inputs: Vec<Tensor>) {
        self.pools.recycle_on(shard, inputs);
    }
}

impl StencilSpace for Space2D {
    type Handle = GridWriter2D;

    fn nblocks(&self) -> usize {
        self.origins.len()
    }

    fn lattice(&self) -> [usize; 3] {
        self.lattice
    }

    fn reach(&self) -> [usize; 3] {
        self.reach
    }

    unsafe fn extract(&self, src: GridWriter2D, block: usize) -> Vec<Tensor> {
        self.extract_on(0, src, block)
    }

    unsafe fn write(&self, dst: GridWriter2D, block: usize, out: &[f32]) {
        let (y0, x0) = self.origins[block];
        dst.write_block(y0, x0, self.block, self.block, out);
    }

    fn recycle(&self, inputs: Vec<Tensor>) {
        self.pools.recycle(inputs);
    }

    fn pool_counters(&self) -> (u64, u64, u64, u64) {
        (
            self.pools.tiles.hits(),
            self.pools.tiles.misses(),
            self.pools.descs.hits(),
            self.pools.descs.misses(),
        )
    }

    fn pool_evictions(&self) -> u64 {
        self.pools.evictions()
    }
}

/// 3D counterpart of [`Space2D`] (cubic tiles, 6-entry descriptor).
pub(crate) struct Space3D {
    pub(crate) origins: Vec<(usize, usize, usize)>,
    lattice: [usize; 3],
    reach: [usize; 3],
    pub(crate) nz: usize,
    pub(crate) ny: usize,
    pub(crate) nx: usize,
    pub(crate) block: usize,
    halo: usize,
    tile: usize,
    boundary: Boundary,
    aux: Option<GridWriter3D>,
    pools: TensorPools,
}

impl Space3D {
    pub(crate) fn new(
        nz: usize,
        ny: usize,
        nx: usize,
        m: &StencilMeta,
        aux: Option<GridWriter3D>,
    ) -> Space3D {
        let origins = block_origins_3d(nz, ny, nx, m.block);
        let reach_b = m.halo.div_ceil(m.block);
        Space3D {
            origins,
            lattice: [
                nz.div_ceil(m.block),
                ny.div_ceil(m.block),
                nx.div_ceil(m.block),
            ],
            reach: [reach_b, reach_b, reach_b],
            nz,
            ny,
            nx,
            block: m.block,
            halo: m.halo,
            tile: m.tile,
            boundary: m.boundary,
            aux,
            pools: TensorPools::default(),
        }
    }

    /// Shard the pools per lane; see [`Space2D::with_pool_shards`].
    pub(crate) fn with_pool_shards(mut self, shards: usize) -> Space3D {
        self.pools = TensorPools::with_shards(shards);
        self
    }

    /// [`StencilSpace::extract`] drawing buffers from one pool shard.
    ///
    /// # Safety
    ///
    /// Same contract as [`StencilSpace::extract`].
    pub(crate) unsafe fn extract_on(
        &self,
        shard: usize,
        src: GridWriter3D,
        block: usize,
    ) -> Vec<Tensor> {
        let (z0, y0, x0) = self.origins[block];
        let mut inputs = Vec::with_capacity(3);
        let mut t = self.pools.tiles.take_on(shard, self.tile * self.tile * self.tile);
        src.extract_tile_into(
            z0 as isize, y0 as isize, x0 as isize, self.tile, self.halo, self.boundary, &mut t,
        );
        inputs.push(Tensor::F32(t, vec![self.tile, self.tile, self.tile]));
        if let Some(aux) = &self.aux {
            let mut p = self.pools.tiles.take_on(shard, self.tile * self.tile * self.tile);
            aux.extract_tile_into(
                z0 as isize, y0 as isize, x0 as isize, self.tile, self.halo, self.boundary, &mut p,
            );
            inputs.push(Tensor::F32(p, vec![self.tile, self.tile, self.tile]));
        }
        let (z0o, z1o) = oob_axis(z0, self.block, self.halo, self.nz);
        let (y0o, y1o) = oob_axis(y0, self.block, self.halo, self.ny);
        let (x0o, x1o) = oob_axis(x0, self.block, self.halo, self.nx);
        let mut d = self.pools.descs.take_on(shard, 6);
        d.extend_from_slice(&[z0o, z1o, y0o, y1o, x0o, x1o]);
        inputs.push(Tensor::I32(d, vec![6]));
        inputs
    }

    /// Return recyclable buffers to one pool shard.
    pub(crate) fn recycle_on(&self, shard: usize, inputs: Vec<Tensor>) {
        self.pools.recycle_on(shard, inputs);
    }
}

impl StencilSpace for Space3D {
    type Handle = GridWriter3D;

    fn nblocks(&self) -> usize {
        self.origins.len()
    }

    fn lattice(&self) -> [usize; 3] {
        self.lattice
    }

    fn reach(&self) -> [usize; 3] {
        self.reach
    }

    unsafe fn extract(&self, src: GridWriter3D, block: usize) -> Vec<Tensor> {
        self.extract_on(0, src, block)
    }

    unsafe fn write(&self, dst: GridWriter3D, block: usize, out: &[f32]) {
        let (z0, y0, x0) = self.origins[block];
        dst.write_block(z0, y0, x0, self.block, out);
    }

    fn recycle(&self, inputs: Vec<Tensor>) {
        self.pools.recycle(inputs);
    }

    fn pool_counters(&self) -> (u64, u64, u64, u64) {
        (
            self.pools.tiles.hits(),
            self.pools.tiles.misses(),
            self.pools.descs.hits(),
            self.pools.descs.misses(),
        )
    }

    fn pool_evictions(&self) -> u64 {
        self.pools.evictions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // tile coverage invariant: oob(top) + in-grid rows + oob(bottom)
    // always equals the issued tile width.
    fn check_covers(o0: usize, block: usize, halo: usize, n: usize) {
        let tile = (block + 2 * halo) as i64;
        let (top, bottom) = oob_axis(o0, block, halo, n);
        let lo = o0 as i64 - halo as i64;
        let hi = o0 as i64 + (block + halo) as i64;
        let in_grid = (hi.min(n as i64) - lo.max(0)).max(0);
        assert_eq!(
            top as i64 + in_grid + bottom as i64,
            tile,
            "o0={o0} block={block} halo={halo} n={n}"
        );
    }

    #[test]
    fn oob_axis_interior_block_has_no_oob() {
        assert_eq!(oob_axis(256, 256, 4, 1024), (0, 0));
        check_covers(256, 256, 4, 1024);
    }

    #[test]
    fn oob_axis_origin_at_grid_start_and_edge() {
        // origin 0: only the leading halo hangs out
        assert_eq!(oob_axis(0, 256, 4, 1024), (4, 0));
        // last full block: only the trailing halo hangs out
        assert_eq!(oob_axis(768, 256, 4, 1024), (0, 4));
        check_covers(0, 256, 4, 1024);
        check_covers(768, 256, 4, 1024);
    }

    #[test]
    fn oob_axis_block_larger_than_grid() {
        // a 512-block against a 300-cell grid: the whole trailing 212
        // cells of interior plus the 4-halo are out of grid.
        assert_eq!(oob_axis(0, 512, 4, 300), (4, 216));
        check_covers(0, 512, 4, 300);
    }

    #[test]
    fn oob_axis_partial_edge_block() {
        // origin 256 with block 256 against n=300: 212 interior cells
        // plus the trailing halo are out of grid.
        assert_eq!(oob_axis(256, 256, 4, 300), (0, 216));
        check_covers(256, 256, 4, 300);
    }

    #[test]
    fn oob_axis_halo_larger_than_extent() {
        // halo 8 on a 2-cell grid with a 4-block tile (tile = 20):
        // 8 leading + 2 in-grid + 10 trailing.
        assert_eq!(oob_axis(0, 4, 8, 2), (8, 10));
        check_covers(0, 4, 8, 2);
    }

    #[test]
    fn oob_axis_counts_clamped_to_tile() {
        // degenerate: both sides saturate but never exceed the tile.
        let (top, bottom) = oob_axis(0, 2, 50, 1);
        let tile = (2 + 2 * 50) as i32;
        assert!(top <= tile && bottom <= tile);
        check_covers(0, 2, 50, 1);
    }

    #[test]
    fn oob_axis_coverage_sweep() {
        for block in [2usize, 7, 64] {
            for halo in [0usize, 1, 4, 9] {
                for n in [1usize, 5, 63, 64, 65, 200] {
                    let mut o0 = 0;
                    while o0 < n {
                        check_covers(o0, block, halo, n);
                        o0 += block;
                    }
                }
            }
        }
    }

    #[test]
    fn extractor_count_scales_with_lanes() {
        assert_eq!(extractor_count(1), 1);
        assert_eq!(extractor_count(2), 1);
        assert_eq!(extractor_count(4), 2);
        assert_eq!(extractor_count(8), 4);
    }

    fn meta(block: usize, halo: usize) -> StencilMeta {
        StencilMeta {
            block,
            halo,
            tile: block + 2 * halo,
            t_fused: 4,
            boundary: Boundary::Zero,
        }
    }

    #[test]
    fn space2d_lattice_covers_partial_blocks() {
        // 300x520 with block 256: 2x3 lattice, reach 1 (halo 4 < block).
        let s = Space2D::new(300, 520, &meta(256, 4), None, None);
        assert_eq!(s.lattice(), [1, 2, 3]);
        assert_eq!(s.reach(), [0, 1, 1]);
        assert_eq!(s.nblocks(), 6);
        assert_eq!(s.origins.len(), s.lattice[1] * s.lattice[2]);
    }

    #[test]
    fn space2d_reach_scales_with_wide_halos() {
        // halo 9 over block 4: dependencies reach ceil(9/4) = 3 blocks.
        let s = Space2D::new(16, 16, &meta(4, 9), None, None);
        assert_eq!(s.reach(), [0, 3, 3]);
        // halo 0: self-dependency only.
        let s0 = Space2D::new(16, 16, &meta(4, 0), None, None);
        assert_eq!(s0.reach(), [0, 0, 0]);
    }

    #[test]
    fn space3d_lattice_matches_origin_plan() {
        let s = Space3D::new(48, 48, 48, &meta(32, 2), None);
        assert_eq!(s.lattice(), [2, 2, 2]);
        assert_eq!(s.reach(), [1, 1, 1]);
        assert_eq!(s.nblocks(), 8);
        assert_eq!(s.origins.len(), 8);
    }
}
