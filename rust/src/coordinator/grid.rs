//! Grids with halo'd block extraction.
//!
//! The boundary rule matches the Python oracles (see
//! `python/compile/kernels/ref.py`): `Zero` for the Ch. 5 diffusion
//! benchmarks (Dirichlet), `Clamp` for the Rodinia benchmarks.  Block
//! interiors may extend past the grid edge (partial blocks against a
//! fixed-shape compute unit); out-of-grid cells are synthesized by the
//! boundary rule on extraction and clipped on write-back.
//!
//! Extraction and write-back each exist in two flavours sharing one
//! core implementation:
//!
//! * safe methods on [`Grid2D`]/[`Grid3D`] — exclusive access through
//!   normal borrows (the single-threaded and test paths);
//! * `unsafe` methods on [`GridWriter2D`]/[`GridWriter3D`] — raw
//!   read/write handles shared across extractor and lane threads by the
//!   cross-pass pass driver, where *both* grid buffers are concurrently
//!   read (tile extraction for pass `p`) and written (write-back for
//!   pass `p±1`) in disjoint, dependency-ordered regions (see
//!   [`crate::coordinator::passdriver`]).

/// Out-of-grid cell synthesis rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Boundary {
    /// Cells outside the grid read 0.0 (Dirichlet).
    Zero,
    /// Out-of-bound indices clamp to the nearest edge (Rodinia-style).
    Clamp,
}

/// Core of 2D boundary-synthesized reads over a raw buffer.
///
/// # Safety
///
/// `ptr` must point to a live `ny * nx` f32 buffer and no thread may be
/// concurrently writing the cell being read.
#[inline]
unsafe fn read_raw_2d(ptr: *const f32, ny: usize, nx: usize, y: isize, x: isize, b: Boundary) -> f32 {
    match b {
        Boundary::Zero => {
            if y < 0 || x < 0 || y >= ny as isize || x >= nx as isize {
                0.0
            } else {
                *ptr.add(y as usize * nx + x as usize)
            }
        }
        Boundary::Clamp => {
            let yc = y.clamp(0, ny as isize - 1) as usize;
            let xc = x.clamp(0, nx as isize - 1) as usize;
            *ptr.add(yc * nx + xc)
        }
    }
}

/// Core of 2D halo'd tile extraction over a raw buffer; the interior
/// origin is (y0, x0) with `halo` cells on every side.
///
/// # Safety
///
/// `ptr` must point to a live `ny * nx` f32 buffer, and no thread may be
/// concurrently writing any cell the tile reads (out-of-grid cells are
/// synthesized, in-grid cells are copied).
#[allow(clippy::too_many_arguments)]
unsafe fn extract_raw_2d(
    ptr: *const f32,
    ny: usize,
    nx: usize,
    y0: isize,
    x0: isize,
    tile_h: usize,
    tile_w: usize,
    halo: usize,
    b: Boundary,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(tile_h * tile_w);
    let ys = y0 - halo as isize;
    let xs = x0 - halo as isize;
    for ty in 0..tile_h {
        let y = ys + ty as isize;
        // fast path: full in-grid row
        if y >= 0 && (y as usize) < ny && xs >= 0 && xs as usize + tile_w <= nx {
            let row = y as usize * nx + xs as usize;
            // SAFETY: the row span is in-bounds and (per this function's
            // contract) not under concurrent mutation.
            out.extend_from_slice(std::slice::from_raw_parts(ptr.add(row), tile_w));
        } else {
            for tx in 0..tile_w {
                out.push(read_raw_2d(ptr, ny, nx, y, xs + tx as isize, b));
            }
        }
    }
}

/// Core of 2D interior write-back: a (bh, bw) block at (y0, x0),
/// clipped to the grid (partial edge blocks).
///
/// # Safety
///
/// `ptr` must point to a live `ny * nx` f32 buffer and no other thread
/// may concurrently access the target cells.
#[allow(clippy::too_many_arguments)]
unsafe fn write_raw_2d(
    ptr: *mut f32,
    ny: usize,
    nx: usize,
    y0: usize,
    x0: usize,
    bh: usize,
    bw: usize,
    block: &[f32],
) {
    debug_assert_eq!(block.len(), bh * bw);
    let h = bh.min(ny.saturating_sub(y0));
    let w = bw.min(nx.saturating_sub(x0));
    for by in 0..h {
        let src = &block[by * bw..by * bw + w];
        std::ptr::copy_nonoverlapping(src.as_ptr(), ptr.add((y0 + by) * nx + x0), w);
    }
}

/// Row-major 2D grid of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Grid2D {
    pub ny: usize,
    pub nx: usize,
    pub data: Vec<f32>,
}

impl Grid2D {
    pub fn zeros(ny: usize, nx: usize) -> Self {
        Grid2D { ny, nx, data: vec![0.0; ny * nx] }
    }

    pub fn from_fn(ny: usize, nx: usize, f: impl Fn(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(ny * nx);
        for y in 0..ny {
            for x in 0..nx {
                data.push(f(y, x));
            }
        }
        Grid2D { ny, nx, data }
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize) -> f32 {
        self.data[y * self.nx + x]
    }

    /// Read with boundary synthesis at signed coordinates.
    #[inline]
    pub fn read(&self, y: isize, x: isize, b: Boundary) -> f32 {
        // SAFETY: &self guarantees exclusive-from-writers access.
        unsafe { read_raw_2d(self.data.as_ptr(), self.ny, self.nx, y, x, b) }
    }

    /// Extract the (tile_h, tile_w) tile whose *interior origin* is
    /// (y0, x0) with `halo` cells on every side, into `out`.
    #[allow(clippy::too_many_arguments)]
    pub fn extract_tile_into(
        &self,
        y0: isize,
        x0: isize,
        tile_h: usize,
        tile_w: usize,
        halo: usize,
        b: Boundary,
        out: &mut Vec<f32>,
    ) {
        // SAFETY: &self guarantees no concurrent writer.
        unsafe {
            extract_raw_2d(self.data.as_ptr(), self.ny, self.nx, y0, x0, tile_h, tile_w, halo, b, out)
        }
    }

    pub fn extract_tile(
        &self,
        y0: isize,
        x0: isize,
        tile_h: usize,
        tile_w: usize,
        halo: usize,
        b: Boundary,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.extract_tile_into(y0, x0, tile_h, tile_w, halo, b, &mut out);
        out
    }

    /// [`Grid2D::extract_tile`] into a buffer recycled from `pool` —
    /// the steady-state (zero-allocation) marshalling path of the
    /// multi-lane engine.
    #[allow(clippy::too_many_arguments)]
    pub fn extract_tile_pooled(
        &self,
        y0: isize,
        x0: isize,
        tile_h: usize,
        tile_w: usize,
        halo: usize,
        b: Boundary,
        pool: &crate::coordinator::bufpool::TilePool,
    ) -> Vec<f32> {
        let mut out = pool.take(tile_h * tile_w);
        self.extract_tile_into(y0, x0, tile_h, tile_w, halo, b, &mut out);
        out
    }

    /// Shared read/write handle over this grid's storage for
    /// lane-parallel writeback and cross-pass pipelined extraction.
    ///
    /// # Safety
    ///
    /// The grid must outlive every use of the returned handle, and
    /// concurrent accesses must never overlap: writes target
    /// pairwise-disjoint block origins (which the block plans guarantee:
    /// origins lie on a `block`-spaced lattice and each write covers at
    /// most `block × block` cells from its origin), and a cell may only
    /// be read once every write to it has been ordered-before the read
    /// (the pass driver's dependency table provides that ordering).  The
    /// caller must not access the grid through any other path until the
    /// handles are quiesced.
    pub unsafe fn shared_writer(&mut self) -> GridWriter2D {
        GridWriter2D { ptr: self.data.as_mut_ptr(), ny: self.ny, nx: self.nx }
    }

    /// Read-only raw view of this grid for concurrent extraction (e.g.
    /// the aux/power grid, which no pass ever writes).
    ///
    /// # Safety
    ///
    /// The grid must outlive every use of the view, nothing may mutate
    /// the grid while the view is live, and the caller must never call
    /// [`GridWriter2D::write_block`] on a handle obtained this way.
    pub unsafe fn shared_view(&self) -> GridWriter2D {
        GridWriter2D { ptr: self.data.as_ptr() as *mut f32, ny: self.ny, nx: self.nx }
    }

    /// Write a (bh, bw) interior block at (y0, x0), clipping out-of-grid
    /// parts (partial edge blocks).
    pub fn write_block(&mut self, y0: usize, x0: usize, bh: usize, bw: usize, block: &[f32]) {
        // SAFETY: &mut self guarantees exclusive access.
        unsafe { write_raw_2d(self.data.as_mut_ptr(), self.ny, self.nx, y0, x0, bh, bw, block) }
    }
}

/// Raw read/write handle over a [`Grid2D`] shared across extractor and
/// execute-lane threads; created by the unsafe [`Grid2D::shared_writer`]
/// (read/write) or [`Grid2D::shared_view`] (read-only), whose contracts
/// (disjoint block writes, dependency-ordered reads, grid outlives the
/// handle) make these accesses sound.
#[derive(Debug, Clone, Copy)]
pub struct GridWriter2D {
    ptr: *mut f32,
    ny: usize,
    nx: usize,
}

// SAFETY: the `shared_writer`/`shared_view` contracts guarantee
// non-overlapping concurrent accesses and a live backing allocation.
unsafe impl Send for GridWriter2D {}
unsafe impl Sync for GridWriter2D {}

impl GridWriter2D {
    /// Same clipping semantics as [`Grid2D::write_block`].
    ///
    /// (Kept callable from safe code for backwards compatibility: the
    /// unsafety was discharged when the handle was created.)
    pub fn write_block(&self, y0: usize, x0: usize, bh: usize, bw: usize, block: &[f32]) {
        // SAFETY: rows y0+by < ny and columns x0..x0+w < nx index inside
        // the grid allocation; disjointness across threads is the
        // `shared_writer` contract.
        unsafe { write_raw_2d(self.ptr, self.ny, self.nx, y0, x0, bh, bw, block) }
    }

    /// Same semantics as [`Grid2D::extract_tile_into`].
    ///
    /// # Safety
    ///
    /// No thread may be concurrently writing any in-grid cell of the
    /// requested tile (the pass driver's dependency table orders every
    /// predecessor write-back before this read becomes runnable).
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn extract_tile_into(
        &self,
        y0: isize,
        x0: isize,
        tile_h: usize,
        tile_w: usize,
        halo: usize,
        b: Boundary,
        out: &mut Vec<f32>,
    ) {
        extract_raw_2d(self.ptr, self.ny, self.nx, y0, x0, tile_h, tile_w, halo, b, out)
    }
}

/// Core of 3D boundary-synthesized reads over a raw buffer.
///
/// # Safety
///
/// `ptr` must point to a live `nz * ny * nx` f32 buffer and no thread
/// may be concurrently writing the cell being read.
#[inline]
#[allow(clippy::too_many_arguments)]
unsafe fn read_raw_3d(
    ptr: *const f32,
    nz: usize,
    ny: usize,
    nx: usize,
    z: isize,
    y: isize,
    x: isize,
    b: Boundary,
) -> f32 {
    match b {
        Boundary::Zero => {
            if z < 0 || y < 0 || x < 0
                || z >= nz as isize || y >= ny as isize || x >= nx as isize
            {
                0.0
            } else {
                *ptr.add((z as usize * ny + y as usize) * nx + x as usize)
            }
        }
        Boundary::Clamp => {
            let zc = z.clamp(0, nz as isize - 1) as usize;
            let yc = y.clamp(0, ny as isize - 1) as usize;
            let xc = x.clamp(0, nx as isize - 1) as usize;
            *ptr.add((zc * ny + yc) * nx + xc)
        }
    }
}

/// Core of cubic-tile extraction over a raw 3D buffer; interior origin
/// (z0, y0, x0).
///
/// # Safety
///
/// Same contract as [`extract_raw_2d`], over a `nz * ny * nx` buffer.
#[allow(clippy::too_many_arguments)]
unsafe fn extract_raw_3d(
    ptr: *const f32,
    nz: usize,
    ny: usize,
    nx: usize,
    z0: isize,
    y0: isize,
    x0: isize,
    tile: usize,
    halo: usize,
    b: Boundary,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(tile * tile * tile);
    let zs = z0 - halo as isize;
    let ys = y0 - halo as isize;
    let xs = x0 - halo as isize;
    for tz in 0..tile {
        let z = zs + tz as isize;
        for ty in 0..tile {
            let y = ys + ty as isize;
            if z >= 0 && (z as usize) < nz
                && y >= 0 && (y as usize) < ny
                && xs >= 0 && xs as usize + tile <= nx
            {
                let row = (z as usize * ny + y as usize) * nx + xs as usize;
                // SAFETY: in-bounds row span, no concurrent mutation per
                // this function's contract.
                out.extend_from_slice(std::slice::from_raw_parts(ptr.add(row), tile));
            } else {
                for tx in 0..tile {
                    out.push(read_raw_3d(ptr, nz, ny, nx, z, y, xs + tx as isize, b));
                }
            }
        }
    }
}

/// Core of cubic interior write-back at (z0, y0, x0), clipped.
///
/// # Safety
///
/// Same contract as [`write_raw_2d`], over a `nz * ny * nx` buffer.
#[allow(clippy::too_many_arguments)]
unsafe fn write_raw_3d(
    ptr: *mut f32,
    nz: usize,
    ny: usize,
    nx: usize,
    z0: usize,
    y0: usize,
    x0: usize,
    bs: usize,
    block: &[f32],
) {
    debug_assert_eq!(block.len(), bs * bs * bs);
    let d = bs.min(nz.saturating_sub(z0));
    let h = bs.min(ny.saturating_sub(y0));
    let w = bs.min(nx.saturating_sub(x0));
    for bz in 0..d {
        for by in 0..h {
            let src = &block[(bz * bs + by) * bs..(bz * bs + by) * bs + w];
            std::ptr::copy_nonoverlapping(
                src.as_ptr(),
                ptr.add(((z0 + bz) * ny + (y0 + by)) * nx + x0),
                w,
            );
        }
    }
}

/// Row-major (z, y, x) 3D grid of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Grid3D {
    pub nz: usize,
    pub ny: usize,
    pub nx: usize,
    pub data: Vec<f32>,
}

impl Grid3D {
    pub fn zeros(nz: usize, ny: usize, nx: usize) -> Self {
        Grid3D { nz, ny, nx, data: vec![0.0; nz * ny * nx] }
    }

    pub fn from_fn(nz: usize, ny: usize, nx: usize, f: impl Fn(usize, usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(nz * ny * nx);
        for z in 0..nz {
            for y in 0..ny {
                for x in 0..nx {
                    data.push(f(z, y, x));
                }
            }
        }
        Grid3D { nz, ny, nx, data }
    }

    #[inline]
    pub fn at(&self, z: usize, y: usize, x: usize) -> f32 {
        self.data[(z * self.ny + y) * self.nx + x]
    }

    #[inline]
    pub fn read(&self, z: isize, y: isize, x: isize, b: Boundary) -> f32 {
        // SAFETY: &self guarantees exclusive-from-writers access.
        unsafe { read_raw_3d(self.data.as_ptr(), self.nz, self.ny, self.nx, z, y, x, b) }
    }

    /// Extract a cubic tile with halo; interior origin (z0, y0, x0).
    #[allow(clippy::too_many_arguments)]
    pub fn extract_tile_into(
        &self,
        z0: isize,
        y0: isize,
        x0: isize,
        tile: usize,
        halo: usize,
        b: Boundary,
        out: &mut Vec<f32>,
    ) {
        // SAFETY: &self guarantees no concurrent writer.
        unsafe {
            extract_raw_3d(
                self.data.as_ptr(), self.nz, self.ny, self.nx, z0, y0, x0, tile, halo, b, out,
            )
        }
    }

    /// [`Grid3D::extract_tile_owned`] into a buffer recycled from
    /// `pool` — the steady-state (zero-allocation) marshalling path.
    #[allow(clippy::too_many_arguments)]
    pub fn extract_tile_pooled(
        &self,
        z0: isize,
        y0: isize,
        x0: isize,
        tile: usize,
        halo: usize,
        b: Boundary,
        pool: &crate::coordinator::bufpool::TilePool,
    ) -> Vec<f32> {
        let mut out = pool.take(tile * tile * tile);
        self.extract_tile_into(z0, y0, x0, tile, halo, b, &mut out);
        out
    }

    /// Shared read/write handle for lane-parallel writeback and
    /// cross-pass pipelined extraction.
    ///
    /// # Safety
    ///
    /// Same contract as [`Grid2D::shared_writer`]: the grid outlives
    /// every use, concurrent writes target disjoint block origins,
    /// reads are ordered after the writes that produced their cells,
    /// and no other access happens until the handles are quiesced.
    pub unsafe fn shared_writer(&mut self) -> GridWriter3D {
        GridWriter3D {
            ptr: self.data.as_mut_ptr(),
            nz: self.nz,
            ny: self.ny,
            nx: self.nx,
        }
    }

    /// Read-only raw view for concurrent extraction (aux grids).
    ///
    /// # Safety
    ///
    /// Same contract as [`Grid2D::shared_view`].
    pub unsafe fn shared_view(&self) -> GridWriter3D {
        GridWriter3D {
            ptr: self.data.as_ptr() as *mut f32,
            nz: self.nz,
            ny: self.ny,
            nx: self.nx,
        }
    }

    /// Write a cubic interior block at (z0, y0, x0), clipped to the grid.
    pub fn write_block(&mut self, z0: usize, y0: usize, x0: usize, bs: usize, block: &[f32]) {
        // SAFETY: &mut self guarantees exclusive access.
        unsafe {
            write_raw_3d(self.data.as_mut_ptr(), self.nz, self.ny, self.nx, z0, y0, x0, bs, block)
        }
    }
}

/// Raw read/write handle over a [`Grid3D`] shared across extractor and
/// execute-lane threads; see [`Grid3D::shared_writer`] for the
/// soundness contract.
#[derive(Debug, Clone, Copy)]
pub struct GridWriter3D {
    ptr: *mut f32,
    nz: usize,
    ny: usize,
    nx: usize,
}

// SAFETY: see GridWriter2D.
unsafe impl Send for GridWriter3D {}
unsafe impl Sync for GridWriter3D {}

impl GridWriter3D {
    /// Same clipping semantics as [`Grid3D::write_block`].
    pub fn write_block(&self, z0: usize, y0: usize, x0: usize, bs: usize, block: &[f32]) {
        // SAFETY: target indices are in-grid; disjointness across
        // threads is the `shared_writer` contract.
        unsafe { write_raw_3d(self.ptr, self.nz, self.ny, self.nx, z0, y0, x0, bs, block) }
    }

    /// Same semantics as [`Grid3D::extract_tile_into`].
    ///
    /// # Safety
    ///
    /// Same contract as [`GridWriter2D::extract_tile_into`].
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn extract_tile_into(
        &self,
        z0: isize,
        y0: isize,
        x0: isize,
        tile: usize,
        halo: usize,
        b: Boundary,
        out: &mut Vec<f32>,
    ) {
        extract_raw_3d(self.ptr, self.nz, self.ny, self.nx, z0, y0, x0, tile, halo, b, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_zero_boundary() {
        let g = Grid2D::from_fn(4, 4, |y, x| (y * 4 + x) as f32);
        let t = g.extract_tile(0, 0, 4, 4, 1, Boundary::Zero);
        assert_eq!(t.len(), 16);
        assert_eq!(t[0], 0.0); // (-1,-1)
        assert_eq!(t[5], g.at(0, 0)); // interior begins
    }

    #[test]
    fn extract_clamp_boundary() {
        let g = Grid2D::from_fn(4, 4, |y, x| (y * 4 + x) as f32);
        let t = g.extract_tile(0, 0, 4, 4, 1, Boundary::Clamp);
        assert_eq!(t[0], g.at(0, 0)); // clamped corner
        assert_eq!(t[1], g.at(0, 0)); // clamped top edge
        assert_eq!(t[2], g.at(0, 1));
    }

    #[test]
    fn roundtrip_extract_write() {
        let g = Grid2D::from_fn(8, 8, |y, x| (y * 8 + x) as f32);
        let mut g2 = Grid2D::zeros(8, 8);
        for y0 in (0..8).step_by(4) {
            for x0 in (0..8).step_by(4) {
                let t = g.extract_tile(y0 as isize, x0 as isize, 4, 4, 0, Boundary::Zero);
                g2.write_block(y0, x0, 4, 4, &t);
            }
        }
        assert_eq!(g, g2);
    }

    #[test]
    fn partial_block_write_clips() {
        let mut g = Grid2D::zeros(5, 5);
        g.write_block(3, 3, 4, 4, &vec![1.0; 16]);
        assert_eq!(g.at(4, 4), 1.0);
        // no panic, nothing outside written
        assert_eq!(g.data.iter().filter(|&&v| v == 1.0).count(), 4);
    }

    #[test]
    fn grid3d_roundtrip() {
        let g = Grid3D::from_fn(4, 4, 4, |z, y, x| (z * 16 + y * 4 + x) as f32);
        let t = g.extract_tile_owned(0, 0, 0, 4, 0, Boundary::Zero);
        let mut g2 = Grid3D::zeros(4, 4, 4);
        g2.write_block(0, 0, 0, 4, &t);
        assert_eq!(g, g2);
    }

    #[test]
    fn grid3d_clamp_corner() {
        let g = Grid3D::from_fn(3, 3, 3, |z, y, x| (z * 9 + y * 3 + x) as f32);
        let t = g.extract_tile_owned(0, 0, 0, 5, 1, Boundary::Clamp);
        assert_eq!(t[0], g.at(0, 0, 0));
        assert_eq!(t.len(), 125);
    }

    #[test]
    fn pooled_extract_matches_owned() {
        let pool = crate::coordinator::bufpool::TilePool::default();
        let g = Grid2D::from_fn(8, 8, |y, x| (y * 8 + x) as f32);
        let a = g.extract_tile(2, 2, 6, 6, 1, Boundary::Zero);
        let b = g.extract_tile_pooled(2, 2, 6, 6, 1, Boundary::Zero, &pool);
        assert_eq!(a, b);
        pool.put(b);
        // second extraction reuses the shelved buffer
        let c = g.extract_tile_pooled(2, 2, 6, 6, 1, Boundary::Zero, &pool);
        assert_eq!(a, c);
        assert_eq!(pool.hits(), 1);

        let g3 = Grid3D::from_fn(4, 4, 4, |z, y, x| (z * 16 + y * 4 + x) as f32);
        let a3 = g3.extract_tile_owned(1, 1, 1, 3, 1, Boundary::Clamp);
        let b3 = g3.extract_tile_pooled(1, 1, 1, 3, 1, Boundary::Clamp, &pool);
        assert_eq!(a3, b3);
    }

    #[test]
    fn shared_writer_matches_write_block() {
        let block: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut a = Grid2D::zeros(5, 5); // partial block clips at the edge
        let mut b = Grid2D::zeros(5, 5);
        a.write_block(3, 3, 4, 4, &block);
        // SAFETY: single-threaded; `b` is only read again after the
        // handle's last use.
        let w = unsafe { b.shared_writer() };
        w.write_block(3, 3, 4, 4, &block);
        assert_eq!(a, b);

        let cube: Vec<f32> = (0..27).map(|v| v as f32).collect();
        let mut a3 = Grid3D::zeros(4, 4, 4);
        let mut b3 = Grid3D::zeros(4, 4, 4);
        a3.write_block(2, 2, 2, 3, &cube);
        // SAFETY: as above.
        let w3 = unsafe { b3.shared_writer() };
        w3.write_block(2, 2, 2, 3, &cube);
        assert_eq!(a3, b3);
    }

    #[test]
    fn shared_writer_parallel_disjoint_blocks() {
        let src = Grid2D::from_fn(8, 8, |y, x| (y * 8 + x) as f32);
        let mut dst = Grid2D::zeros(8, 8);
        // SAFETY: writes below target pairwise-disjoint 4x4 block
        // origins; `dst` outlives the scope and is only read after it.
        let w = unsafe { dst.shared_writer() };
        std::thread::scope(|s| {
            for y0 in (0..8).step_by(4) {
                for x0 in (0..8).step_by(4) {
                    let tile = src.extract_tile(y0 as isize, x0 as isize, 4, 4, 0, Boundary::Zero);
                    s.spawn(move || w.write_block(y0, x0, 4, 4, &tile));
                }
            }
        });
        assert_eq!(src, dst);
    }

    #[test]
    fn handle_extract_matches_grid_extract() {
        let g = Grid2D::from_fn(9, 7, |y, x| (y * 7 + x) as f32);
        // SAFETY: read-only view, nothing mutates `g` while it is live.
        let view = unsafe { g.shared_view() };
        for (y0, x0) in [(0isize, 0isize), (4, 3), (8, 6), (-1, 5)] {
            let want = g.extract_tile(y0, x0, 5, 5, 2, Boundary::Clamp);
            let mut got = Vec::new();
            // SAFETY: no writer exists at all.
            unsafe { view.extract_tile_into(y0, x0, 5, 5, 2, Boundary::Clamp, &mut got) };
            assert_eq!(want, got, "origin ({y0},{x0})");
        }

        let g3 = Grid3D::from_fn(5, 4, 6, |z, y, x| (z * 24 + y * 6 + x) as f32);
        // SAFETY: as above.
        let view3 = unsafe { g3.shared_view() };
        let want = g3.extract_tile_owned(1, 0, 2, 4, 1, Boundary::Zero);
        let mut got = Vec::new();
        // SAFETY: as above.
        unsafe { view3.extract_tile_into(1, 0, 2, 4, 1, Boundary::Zero, &mut got) };
        assert_eq!(want, got);
    }
}

impl Grid3D {
    /// Owned-Vec convenience wrapper over [`Grid3D::extract_tile_into`].
    pub fn extract_tile_owned(
        &self,
        z0: isize,
        y0: isize,
        x0: isize,
        tile: usize,
        halo: usize,
        b: Boundary,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.extract_tile_into(z0, y0, x0, tile, halo, b, &mut out);
        out
    }
}
