//! Reusable tensor arenas for the marshalling path.
//!
//! Every block of every pass needs a freshly filled `Vec<f32>` for the
//! halo'd input tile (and one comes back per output), plus a tiny
//! `Vec<i32>` boundary-restoration descriptor.  Allocating those per
//! block is the host-side anti-pattern the thesis's deep pipelines
//! avoid on hardware; the pools recycle buffers by size instead, so a
//! steady-state pass performs **zero** heap allocations for tile
//! extraction (after the first pass warms the shelves).
//!
//! [`BufferPool`] is generic over the element type: [`TilePool`]
//! (`f32` tiles and kernel outputs) and the `i32` descriptor pool
//! inside [`TensorPools`] share the implementation.  Shelves are keyed
//! by capacity in a `BTreeMap`, and `take(len)` hands out the smallest
//! buffer whose capacity covers `len`, so tile inputs (`tile²`/`tile³`
//! cells) and recycled kernel outputs (`block²`/`block³` cells) coexist
//! in one pool.  Hit/miss counters feed the `pool_hits`/`pool_misses`
//! (and `desc_pool_hits`/`desc_pool_misses`) fields of
//! [`crate::coordinator::metrics::Metrics`].
//!
//! ## Sharding and retention (PR 7)
//!
//! A pool built with [`BufferPool::with_shards`] keeps one shelf set
//! **per lane** ([`BufferPool::take_on`] / [`BufferPool::put_on`]):
//! the wave driver keys both by the block's affinity lane, so a block's
//! tile cycles extractor → lane → recycle entirely within shard
//! `lane_of(block)` — steady-state extraction touches only lane-local
//! free lists (one uncontended mutex), and under NUMA pinning the
//! buffer's pages stay on the lane's node.  Buffers are
//! **first-touch-initialized** on the taking thread at allocation, so a
//! pinned extractor faults the pages onto its own node.
//!
//! Retention is bounded: each capacity bucket keeps at most
//! [`SHELF_HIGH_WATER`] buffers per shard; overflow spills to a small
//! **global overflow ring** (cross-shard rescue for imbalanced phases),
//! and beyond that buffers are dropped and counted
//! (`Metrics::pool_evictions`) — long sessions no longer grow arenas
//! monotonically.  The single-shard [`BufferPool::default`] keeps the
//! original `take`/`put` surface for the single-runtime drivers.

use std::collections::{BTreeMap, VecDeque};

use crate::runtime::Tensor;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Mutex, MutexGuard, PoisonError};

/// Per-bucket retention cap: `put` keeps at most this many buffers on
/// one capacity shelf of one shard before spilling to the overflow
/// ring.  Sized for the deepest realistic in-flight set (queue cap +
/// lanes + extractor lookahead) of one tile size.
pub const SHELF_HIGH_WATER: usize = 32;

/// Global overflow-ring capacity (buffers of any size, all shards).
const OVERFLOW_CAP: usize = 64;

type Shelves<T> = BTreeMap<usize, Vec<Vec<T>>>;

/// Thread-safe recycling pool of `Vec<T>` buffers, optionally sharded
/// per lane.
#[derive(Debug)]
pub struct BufferPool<T> {
    shards: Vec<Mutex<Shelves<T>>>,
    /// Cross-shard spill: buffers a full shelf could not retain, still
    /// recyclable by any shard before eviction.
    overflow: Mutex<VecDeque<Vec<T>>>,
    /// Monotonic tallies, every access `Relaxed`: the buffers
    /// themselves travel through the shard/ring mutexes above (which
    /// carry the happens-before edges), so the counters order nothing —
    /// readers only ever want totals-so-far, and RMW atomicity alone
    /// keeps those exact.
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

/// Recycling pool for `f32` tile buffers (the dominant marshalling
/// allocation).
pub type TilePool = BufferPool<f32>;

impl<T> Default for BufferPool<T> {
    fn default() -> Self {
        Self::with_shards(1)
    }
}

impl<T> BufferPool<T> {
    /// A pool with one independent shelf set per shard (≥ 1).  Shard
    /// indices to `take_on`/`put_on` wrap, so callers can pass lane
    /// hints directly.
    pub fn with_shards(shards: usize) -> Self {
        BufferPool {
            shards: (0..shards.max(1)).map(|_| Mutex::new(BTreeMap::new())).collect(),
            overflow: Mutex::new(VecDeque::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Return a buffer for reuse (shard 0 — the single-shard surface).
    pub fn put(&self, v: Vec<T>) {
        self.put_on(0, v);
    }

    /// Return a buffer to `shard`'s shelves.  Zero-capacity buffers are
    /// dropped; a shelf at its high-water mark spills to the overflow
    /// ring, and a full ring drops the buffer (counted as an eviction)
    /// — retention is bounded per bucket, not monotonic.
    pub fn put_on(&self, shard: usize, mut v: Vec<T>) {
        v.clear();
        let cap = v.capacity();
        if cap == 0 {
            return;
        }
        {
            let mut shelves = lockp(&self.shards[shard % self.shards.len()]);
            let stack = shelves.entry(cap).or_default();
            if stack.len() < SHELF_HIGH_WATER {
                stack.push(v);
                return;
            }
        }
        let mut ring = lockp(&self.overflow);
        if ring.len() < OVERFLOW_CAP {
            ring.push_back(v);
        } else {
            drop(ring);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Buffers served from the shelves (reuses).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Buffers that had to be freshly allocated.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Buffers dropped by the high-water bound instead of retained.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

impl<T: Default + Clone> BufferPool<T> {
    /// Fetch a cleared buffer with capacity ≥ `len` (shard 0 — the
    /// single-shard surface).
    pub fn take(&self, len: usize) -> Vec<T> {
        self.take_on(0, len)
    }

    /// Fetch a cleared buffer with capacity ≥ `len` from `shard`'s
    /// shelves, falling back to the overflow ring, allocating only when
    /// both miss.  A fresh allocation is first-touch-initialized on the
    /// calling thread, so a NUMA-pinned extractor faults the pages onto
    /// its own node.
    pub fn take_on(&self, shard: usize, len: usize) -> Vec<T> {
        {
            let mut shelves = lockp(&self.shards[shard % self.shards.len()]);
            // Smallest shelf that covers the request.
            if let Some((&cap, stack)) = shelves.range_mut(len..).next() {
                let v = stack.pop().expect("empty shelves are removed on pop");
                if stack.is_empty() {
                    shelves.remove(&cap);
                }
                drop(shelves);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return v;
            }
        }
        {
            let mut ring = lockp(&self.overflow);
            if let Some(i) = ring.iter().position(|v| v.capacity() >= len) {
                let v = ring.remove(i).expect("position() index is live");
                drop(ring);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return v;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut v = Vec::with_capacity(len);
        // First touch: fault the pages in on this (possibly pinned)
        // thread, then hand the buffer out cleared as usual.
        v.resize(len, T::default());
        v.clear();
        v
    }
}

/// Lock recovering from poisoning — shelf state is a plain container,
/// consistent after any panicking holder.
fn lockp<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The full marshalling-path pool set: `f32` tiles plus the `i32`
/// boundary-descriptor buffers — the last per-block allocation on the
/// extract path besides xla's own output alloc in `Literal::to_vec`.
#[derive(Debug, Default)]
pub struct TensorPools {
    pub tiles: TilePool,
    pub descs: BufferPool<i32>,
}

impl TensorPools {
    /// Pools sharded per lane (see [`BufferPool::with_shards`]).
    pub fn with_shards(shards: usize) -> Self {
        TensorPools {
            tiles: TilePool::with_shards(shards),
            descs: BufferPool::with_shards(shards),
        }
    }

    /// Return a block's input tensors to their pools for reuse.
    ///
    /// Kernel *output* buffers are deliberately not pooled: they are
    /// `block²`/`block³` cells while every extraction request is
    /// `tile²`/`tile³` (strictly larger for halo ≥ 1), so they could
    /// never satisfy a `take` — shelving them would only hold dead
    /// memory.
    pub fn recycle(&self, inputs: Vec<Tensor>) {
        self.recycle_on(0, inputs);
    }

    /// [`TensorPools::recycle`] into one lane's shard: the wave driver
    /// passes the block's affinity lane so a tile cycles within its
    /// lane-local free list.
    pub fn recycle_on(&self, shard: usize, inputs: Vec<Tensor>) {
        for t in inputs {
            match t {
                Tensor::F32(v, _) => self.tiles.put_on(shard, v),
                Tensor::I32(v, _) => self.descs.put_on(shard, v),
            }
        }
    }

    /// Total buffers dropped by the retention bound across both pools.
    pub fn evictions(&self) -> u64 {
        self.tiles.evictions() + self.descs.evictions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_allocates_then_reuses() {
        let p = TilePool::default();
        let mut a = p.take(64);
        assert!(a.capacity() >= 64);
        assert_eq!((p.hits(), p.misses()), (0, 1));
        a.extend(std::iter::repeat(1.0).take(64));
        p.put(a);
        let b = p.take(64);
        assert!(b.is_empty(), "recycled buffers come back cleared");
        assert!(b.capacity() >= 64);
        assert_eq!((p.hits(), p.misses()), (1, 1));
    }

    #[test]
    fn smaller_requests_reuse_bigger_buffers() {
        let p = TilePool::default();
        p.put(Vec::with_capacity(1000));
        let v = p.take(100);
        assert!(v.capacity() >= 1000);
        assert_eq!(p.hits(), 1);
    }

    #[test]
    fn bigger_requests_miss() {
        let p = TilePool::default();
        p.put(Vec::with_capacity(10));
        let v = p.take(100);
        assert!(v.capacity() >= 100);
        assert_eq!((p.hits(), p.misses()), (0, 1));
        // The small buffer is still shelved for a matching request.
        assert!(p.take(10).capacity() >= 10);
        assert_eq!(p.hits(), 1);
        drop(v);
    }

    #[test]
    fn steady_state_is_allocation_free() {
        // Simulates two passes of a 4-block plan with one in flight:
        // pass 1 misses once per block, pass 2 runs entirely off shelves.
        let p = TilePool::default();
        for _pass in 0..2 {
            for _block in 0..4 {
                let mut t = p.take(256);
                t.resize(256, 0.5);
                p.put(t);
            }
        }
        assert_eq!(p.misses(), 1, "single in-flight buffer allocated once");
        assert_eq!(p.hits(), 7);
    }

    #[test]
    fn i32_descriptor_pool_reuses() {
        let p: BufferPool<i32> = BufferPool::default();
        let mut d = p.take(4);
        d.extend_from_slice(&[1, 2, 3, 4]);
        p.put(d);
        let d2 = p.take(4);
        assert!(d2.is_empty() && d2.capacity() >= 4);
        assert_eq!((p.hits(), p.misses()), (1, 1));
    }

    #[test]
    fn tensor_pools_recycle_by_dtype() {
        let pools = TensorPools::default();
        pools.recycle(vec![
            Tensor::F32(Vec::with_capacity(16), vec![4, 4]),
            Tensor::I32(Vec::with_capacity(4), vec![4]),
        ]);
        assert!(pools.tiles.take(16).capacity() >= 16);
        assert!(pools.descs.take(4).capacity() >= 4);
        assert_eq!(pools.tiles.hits(), 1);
        assert_eq!(pools.descs.hits(), 1);
    }

    #[test]
    fn concurrent_take_put() {
        let p = crate::sync::Arc::new(TilePool::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let p = p.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        let mut v = p.take(128);
                        v.push(1.0);
                        p.put(v);
                    }
                });
            }
        });
        assert_eq!(p.hits() + p.misses(), 400);
    }

    #[test]
    fn high_water_mark_bounds_retention_and_counts_evictions() {
        // One bucket: the shelf keeps SHELF_HIGH_WATER, the ring keeps
        // OVERFLOW_CAP more, everything beyond is dropped and counted.
        let p = TilePool::default();
        let n = SHELF_HIGH_WATER + OVERFLOW_CAP + 5;
        for _ in 0..n {
            p.put(Vec::with_capacity(128));
        }
        assert_eq!(p.evictions(), 5, "retention beyond shelf + ring is dropped");
        // Every retained buffer is still takeable without allocating.
        for _ in 0..(SHELF_HIGH_WATER + OVERFLOW_CAP) {
            assert!(p.take(128).capacity() >= 128);
        }
        assert_eq!(p.misses(), 0);
        // The pool is now empty: the next take allocates.
        p.take(128);
        assert_eq!(p.misses(), 1);
    }

    #[test]
    fn shards_keep_local_free_lists_with_overflow_rescue() {
        let p = TilePool::with_shards(2);
        // A buffer shelved on shard 0 is invisible to shard 1 — the
        // steady-state path never scans another lane's free list.
        p.put_on(0, Vec::with_capacity(64));
        let v = p.take_on(1, 64);
        assert_eq!(p.misses(), 1, "cross-shard take allocates");
        p.put_on(1, v);
        assert!(p.take_on(1, 64).capacity() >= 64);
        assert_eq!(p.hits(), 1, "same-shard take reuses");
        // But a shelf at its high-water mark spills to the ring, where
        // any shard can rescue the buffer before it is evicted.
        for _ in 0..=SHELF_HIGH_WATER {
            p.put_on(0, Vec::with_capacity(512));
        }
        assert!(p.take_on(1, 512).capacity() >= 512, "overflowed buffer rescued cross-shard");
        assert_eq!(p.evictions(), 0);
    }

    #[test]
    fn shard_indices_wrap() {
        let p = TilePool::with_shards(2);
        p.put_on(5, Vec::with_capacity(32)); // 5 % 2 == shard 1
        assert!(p.take_on(1, 32).capacity() >= 32);
        assert_eq!(p.hits(), 1);
    }
}
