//! Reusable tensor arenas for the marshalling path.
//!
//! Every block of every pass needs a freshly filled `Vec<f32>` for the
//! halo'd input tile (and one comes back per output), plus a tiny
//! `Vec<i32>` boundary-restoration descriptor.  Allocating those per
//! block is the host-side anti-pattern the thesis's deep pipelines
//! avoid on hardware; the pools recycle buffers by size instead, so a
//! steady-state pass performs **zero** heap allocations for tile
//! extraction (after the first pass warms the shelves).
//!
//! [`BufferPool`] is generic over the element type: [`TilePool`]
//! (`f32` tiles and kernel outputs) and the `i32` descriptor pool
//! inside [`TensorPools`] share the implementation.  Shelves are keyed
//! by capacity in a `BTreeMap`, and `take(len)` hands out the smallest
//! buffer whose capacity covers `len`, so tile inputs (`tile²`/`tile³`
//! cells) and recycled kernel outputs (`block²`/`block³` cells) coexist
//! in one pool.  Hit/miss counters feed the `pool_hits`/`pool_misses`
//! (and `desc_pool_hits`/`desc_pool_misses`) fields of
//! [`crate::coordinator::metrics::Metrics`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::runtime::Tensor;

/// Thread-safe recycling pool of `Vec<T>` buffers.
#[derive(Debug)]
pub struct BufferPool<T> {
    shelves: Mutex<BTreeMap<usize, Vec<Vec<T>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Recycling pool for `f32` tile buffers (the dominant marshalling
/// allocation).
pub type TilePool = BufferPool<f32>;

impl<T> Default for BufferPool<T> {
    fn default() -> Self {
        BufferPool {
            shelves: Mutex::new(BTreeMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }
}

impl<T> BufferPool<T> {
    /// Fetch a cleared buffer with capacity ≥ `len` (allocating one only
    /// on a pool miss).
    pub fn take(&self, len: usize) -> Vec<T> {
        let mut shelves = self.shelves.lock().unwrap();
        // Smallest shelf that covers the request.
        if let Some((&cap, stack)) = shelves.range_mut(len..).next() {
            let v = stack.pop().expect("empty shelves are removed on pop");
            if stack.is_empty() {
                shelves.remove(&cap);
            }
            drop(shelves);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        drop(shelves);
        self.misses.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(len)
    }

    /// Return a buffer for reuse.  Zero-capacity buffers are dropped,
    /// and each shelf is capped so recycled buffers that nothing ever
    /// re-requests (e.g. a one-off tile size) cannot grow without bound.
    pub fn put(&self, mut v: Vec<T>) {
        const MAX_PER_SHELF: usize = 256;
        v.clear();
        let cap = v.capacity();
        if cap == 0 {
            return;
        }
        let mut shelves = self.shelves.lock().unwrap();
        let stack = shelves.entry(cap).or_default();
        if stack.len() < MAX_PER_SHELF {
            stack.push(v);
        }
    }

    /// Buffers served from the shelves (reuses).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Buffers that had to be freshly allocated.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// The full marshalling-path pool set: `f32` tiles plus the `i32`
/// boundary-descriptor buffers — the last per-block allocation on the
/// extract path besides xla's own output alloc in `Literal::to_vec`.
#[derive(Debug, Default)]
pub struct TensorPools {
    pub tiles: TilePool,
    pub descs: BufferPool<i32>,
}

impl TensorPools {
    /// Return a block's input tensors to their pools for reuse.
    ///
    /// Kernel *output* buffers are deliberately not pooled: they are
    /// `block²`/`block³` cells while every extraction request is
    /// `tile²`/`tile³` (strictly larger for halo ≥ 1), so they could
    /// never satisfy a `take` — shelving them would only hold dead
    /// memory.
    pub fn recycle(&self, inputs: Vec<Tensor>) {
        for t in inputs {
            match t {
                Tensor::F32(v, _) => self.tiles.put(v),
                Tensor::I32(v, _) => self.descs.put(v),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_allocates_then_reuses() {
        let p = TilePool::default();
        let mut a = p.take(64);
        assert!(a.capacity() >= 64);
        assert_eq!((p.hits(), p.misses()), (0, 1));
        a.extend(std::iter::repeat(1.0).take(64));
        p.put(a);
        let b = p.take(64);
        assert!(b.is_empty(), "recycled buffers come back cleared");
        assert!(b.capacity() >= 64);
        assert_eq!((p.hits(), p.misses()), (1, 1));
    }

    #[test]
    fn smaller_requests_reuse_bigger_buffers() {
        let p = TilePool::default();
        p.put(Vec::with_capacity(1000));
        let v = p.take(100);
        assert!(v.capacity() >= 1000);
        assert_eq!(p.hits(), 1);
    }

    #[test]
    fn bigger_requests_miss() {
        let p = TilePool::default();
        p.put(Vec::with_capacity(10));
        let v = p.take(100);
        assert!(v.capacity() >= 100);
        assert_eq!((p.hits(), p.misses()), (0, 1));
        // The small buffer is still shelved for a matching request.
        assert!(p.take(10).capacity() >= 10);
        assert_eq!(p.hits(), 1);
        drop(v);
    }

    #[test]
    fn steady_state_is_allocation_free() {
        // Simulates two passes of a 4-block plan with one in flight:
        // pass 1 misses once per block, pass 2 runs entirely off shelves.
        let p = TilePool::default();
        for _pass in 0..2 {
            for _block in 0..4 {
                let mut t = p.take(256);
                t.resize(256, 0.5);
                p.put(t);
            }
        }
        assert_eq!(p.misses(), 1, "single in-flight buffer allocated once");
        assert_eq!(p.hits(), 7);
    }

    #[test]
    fn i32_descriptor_pool_reuses() {
        let p: BufferPool<i32> = BufferPool::default();
        let mut d = p.take(4);
        d.extend_from_slice(&[1, 2, 3, 4]);
        p.put(d);
        let d2 = p.take(4);
        assert!(d2.is_empty() && d2.capacity() >= 4);
        assert_eq!((p.hits(), p.misses()), (1, 1));
    }

    #[test]
    fn tensor_pools_recycle_by_dtype() {
        let pools = TensorPools::default();
        pools.recycle(vec![
            Tensor::F32(Vec::with_capacity(16), vec![4, 4]),
            Tensor::I32(Vec::with_capacity(4), vec![4]),
        ]);
        assert!(pools.tiles.take(16).capacity() >= 16);
        assert!(pools.descs.take(4).capacity() >= 4);
        assert_eq!(pools.tiles.hits(), 1);
        assert_eq!(pools.descs.hits(), 1);
    }

    #[test]
    fn concurrent_take_put() {
        let p = std::sync::Arc::new(TilePool::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let p = p.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        let mut v = p.take(128);
                        v.push(1.0);
                        p.put(v);
                    }
                });
            }
        });
        assert_eq!(p.hits() + p.misses(), 400);
    }
}
