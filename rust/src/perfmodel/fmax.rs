//! Achievable kernel clock (f_max) model.
//!
//! The thesis treats f_max as an emergent property of placement and
//! routing: it degrades as resource utilization climbs (§3.1.1), suffers
//! from specific critical-path structures (read-after-write register
//! chains in NW §4.3.1.1, deep exit-condition chains §3.2.4.4), and
//! recovers a few percent from seed / target-f_max sweeps (§3.2.3.5).
//! This module captures each effect as a multiplicative penalty on the
//! device's base clock, plus a deterministic pseudo-random seed sweep.

use crate::device::FpgaDevice;
use crate::perfmodel::area::AreaBudget;

/// Structural critical-path classes the thesis identifies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CriticalPath {
    /// Clean pipelined design; exit-condition optimized.
    Clean,
    /// Single-cycle read-after-write feedback (NW's register forwarding):
    /// the tightest timing structure observed (§4.3.1.1).
    RawFeedback,
    /// Un-optimized nested-loop exit-condition chain (§3.2.4.4).
    ExitChain { depth: u32 },
    /// NDRange with heavy local-memory port mux / barrier logic.
    BarrierMux,
}

impl CriticalPath {
    fn factor(self) -> f64 {
        match self {
            CriticalPath::Clean => 1.0,
            CriticalPath::RawFeedback => 0.72,
            CriticalPath::ExitChain { depth } => {
                1.0 - 0.04 * depth.min(6) as f64
            }
            CriticalPath::BarrierMux => 0.80,
        }
    }
}

/// Result of the f_max estimate.
#[derive(Debug, Clone, Copy)]
pub struct FmaxEstimate {
    pub mhz: f64,
    /// Clock after the best seed of a sweep (what the tables report).
    pub swept_mhz: f64,
}

/// Estimate f_max for a design on a device.
///
/// `budget` is the post-fit utilization; `path` the structural critical
/// path; `flat` whether the Arria 10 flat-compilation flow is usable
/// (PR constraints cost timing, §3.2.3.4).
pub fn estimate(
    dev: &FpgaDevice,
    budget: &AreaBudget,
    path: CriticalPath,
    flat: bool,
) -> f64 {
    let mut f = dev.base_fmax_mhz;
    // Utilization pressure: each resource past its comfort point drags
    // routing.  Calibrated so ~80 % logic costs ~25 % clock (Table 4-4).
    let logic_over = (budget.logic - 0.50).max(0.0);
    let bram_over = (budget.m20k_blocks - 0.55).max(0.0);
    let dsp_over = (budget.dsp - 0.80).max(0.0);
    f *= 1.0 - 0.55 * logic_over;
    f *= 1.0 - 0.35 * bram_over;
    f *= 1.0 - 0.25 * dsp_over;
    f *= path.factor();
    if !flat && dev.native_fp_dsp {
        // Arria 10 PR flow: extra placement constraints (§3.2.3.4).
        f *= 0.93;
    }
    f.clamp(120.0, dev.base_fmax_mhz)
}

/// Deterministic seed sweep (§3.2.3.5): try `seeds` placements, keep the
/// best.  Jitter is ±4 % drawn from a xorshift stream keyed by the design
/// name, so reports are reproducible run to run.
pub fn seed_sweep(name: &str, base_mhz: f64, seeds: u32) -> FmaxEstimate {
    let mut state: u64 = 0x9E37_79B9_7F4A_7C15;
    for b in name.bytes() {
        state = (state ^ b as u64).wrapping_mul(0x100_0000_01B3);
    }
    let mut best = 0.0f64;
    for _ in 0..seeds.max(1) {
        // xorshift64*
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        let u = (state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64
            / (1u64 << 53) as f64;
        let jitter = 0.96 + 0.08 * u;
        best = best.max(base_mhz * jitter);
    }
    FmaxEstimate { mhz: base_mhz, swept_mhz: best }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_v};
    use crate::perfmodel::area::AreaBudget;

    fn budget(logic: f64, bram: f64, dsp: f64) -> AreaBudget {
        AreaBudget { logic, m20k_blocks: bram, m20k_bits: bram * 0.6, dsp }
    }

    #[test]
    fn low_utilization_hits_base_clock() {
        let dev = stratix_v();
        let f = estimate(&dev, &budget(0.2, 0.2, 0.05), CriticalPath::Clean, true);
        assert!((f - dev.base_fmax_mhz).abs() < 1.0);
    }

    #[test]
    fn high_utilization_degrades() {
        let dev = stratix_v();
        let lo = estimate(&dev, &budget(0.3, 0.3, 0.1), CriticalPath::Clean, true);
        let hi = estimate(&dev, &budget(0.8, 0.8, 0.95), CriticalPath::Clean, true);
        assert!(hi < lo * 0.85, "hi={hi} lo={lo}");
        assert!(hi >= 120.0);
    }

    #[test]
    fn raw_feedback_matches_nw_observation() {
        // NW advanced: ~218 MHz on a device whose clean designs do 300+.
        let dev = stratix_v();
        let f = estimate(&dev, &budget(0.53, 0.28, 0.02), CriticalPath::RawFeedback, true);
        assert!(f > 195.0 && f < 240.0, "f={f}");
    }

    #[test]
    fn pr_flow_costs_timing_on_a10() {
        let dev = arria_10();
        let b = budget(0.4, 0.5, 0.3);
        let flat = estimate(&dev, &b, CriticalPath::Clean, true);
        let pr = estimate(&dev, &b, CriticalPath::Clean, false);
        assert!(pr < flat);
    }

    #[test]
    fn seed_sweep_deterministic_and_bounded() {
        let a = seed_sweep("design-x", 250.0, 10);
        let b = seed_sweep("design-x", 250.0, 10);
        assert_eq!(a.swept_mhz, b.swept_mhz);
        assert!(a.swept_mhz >= 250.0 * 0.96 && a.swept_mhz <= 250.0 * 1.04);
        // more seeds never hurt
        let c = seed_sweep("design-x", 250.0, 50);
        assert!(c.swept_mhz >= a.swept_mhz);
    }
}
