//! External-memory model: effective bandwidth per access pattern.
//!
//! The thesis's Eq. 3-5 uses the board's raw bytes/cycle `BW`; §3.2
//! (coalescing, banking, alignment) describes how real designs see only a
//! fraction of it.  We fold those effects into an efficiency multiplier so
//! that II_r = N_m·N_p / (BW · η).  The η values are calibrated against
//! the thesis's observations: well-coalesced streaming saturates ~85–90 %
//! of DDR bandwidth, unaligned overlapped-block streams ~70 %, strided
//! multi-port contention ~30 %, and pointer-chasing style random access
//! single-digit percent.

use crate::device::FpgaDevice;

/// Classified external-memory access behaviour of a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    /// Wide, aligned, compile-time-coalesced unit-stride bursts
    /// (the access shape advanced SWI kernels achieve, §3.2.1.5).
    Streaming,
    /// Unit-stride but with unaligned block boundaries (overlapped
    /// blocking without padding, §5.3.3 / Pathfinder §4.3.1.4).
    StreamingUnaligned,
    /// Multiple narrow concurrent ports contending on the bus
    /// (un-coalesced unrolling, direct ports of GPU kernels).
    Strided,
    /// Data-dependent / indirect addressing (original SRAD, §4.3.1.5).
    Random,
}

impl AccessPattern {
    /// Fraction of board bandwidth a design with this pattern sustains.
    pub fn efficiency(self) -> f64 {
        match self {
            AccessPattern::Streaming => 0.88,
            AccessPattern::StreamingUnaligned => 0.70,
            AccessPattern::Strided => 0.30,
            AccessPattern::Random => 0.06,
        }
    }
}

/// Memory behaviour of one kernel variant.
#[derive(Debug, Clone, Copy)]
pub struct MemorySpec {
    pub pattern: AccessPattern,
    /// Manual bank assignment (§3.2.3.1): pins hot buffers to separate
    /// banks, recovering interleaving losses when exactly two wide
    /// streams exist.  Worth ~10 % in the thesis's experience.
    pub manual_banking: bool,
    /// Fraction of the board's banks this kernel can actually keep busy
    /// (Pathfinder's single hot buffer can't use both banks, §4.3.1.4).
    pub bank_utilization: f64,
}

impl MemorySpec {
    pub fn streaming() -> Self {
        MemorySpec {
            pattern: AccessPattern::Streaming,
            manual_banking: false,
            bank_utilization: 1.0,
        }
    }

    pub fn with_pattern(pattern: AccessPattern) -> Self {
        MemorySpec { pattern, manual_banking: false, bank_utilization: 1.0 }
    }

    pub fn banked(mut self) -> Self {
        self.manual_banking = true;
        self
    }

    pub fn bank_limited(mut self, frac: f64) -> Self {
        self.bank_utilization = frac;
        self
    }

    /// Effective bytes per kernel cycle (the `BW` of Eq. 3-5 after all
    /// efficiency effects).
    pub fn effective_bytes_per_cycle(&self, dev: &FpgaDevice, fmax_mhz: f64) -> f64 {
        let raw = dev.bytes_per_cycle(fmax_mhz);
        let mut eff = self.pattern.efficiency();
        if self.manual_banking {
            eff = (eff * 1.10).min(0.95);
        }
        raw * eff * self.bank_utilization.clamp(0.0, 1.0)
    }

    /// Effective bandwidth in GB/s (for report columns).
    pub fn effective_gbs(&self, dev: &FpgaDevice) -> f64 {
        let mut eff = self.pattern.efficiency();
        if self.manual_banking {
            eff = (eff * 1.10).min(0.95);
        }
        dev.mem_bw_gbs * eff * self.bank_utilization.clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_v};

    #[test]
    fn pattern_ordering() {
        assert!(AccessPattern::Streaming.efficiency()
            > AccessPattern::StreamingUnaligned.efficiency());
        assert!(AccessPattern::StreamingUnaligned.efficiency()
            > AccessPattern::Strided.efficiency());
        assert!(AccessPattern::Strided.efficiency()
            > AccessPattern::Random.efficiency());
    }

    #[test]
    fn banking_helps_but_caps() {
        let dev = stratix_v();
        let plain = MemorySpec::streaming();
        let banked = MemorySpec::streaming().banked();
        assert!(banked.effective_gbs(&dev) > plain.effective_gbs(&dev));
        assert!(banked.effective_gbs(&dev) <= dev.mem_bw_gbs * 0.95);
    }

    #[test]
    fn a10_beats_sv_bandwidth_but_not_by_much() {
        // Table 4-9's key finding: A10's modest BW gain (25.6 -> 34.1)
        // keeps memory-bound benchmarks nearly flat.
        let sp = MemorySpec::streaming();
        let gain = sp.effective_gbs(&arria_10()) / sp.effective_gbs(&stratix_v());
        assert!(gain > 1.2 && gain < 1.4);
    }
}
