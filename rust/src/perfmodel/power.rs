//! Board power model (replaces quartus_pow + on-board sensors, §4.2.4).
//!
//! A linear static + dynamic decomposition calibrated against the
//! thesis's measured board wattages:
//!
//! * Stratix V readings span ~12.1 W (idle-ish designs) to ~31.6 W
//!   (logic+BRAM-saturated NDRange kernels), including the constant
//!   2.34 W for the two DDR3 modules the thesis adds by hand;
//! * Arria 10 readings span ~32.7 W to ~46.7 W (board sensor).
//!
//! Dynamic power scales with the utilization of each resource class and
//! with memory-bus activity, all at the achieved clock (power ∝ f·C·V²
//! with V fixed — the fabric toggles proportionally to f_max).

use crate::device::FpgaDevice;
use crate::perfmodel::area::AreaBudget;

/// Estimate average board power during kernel execution, in watts.
///
/// `bw_utilization` is the fraction of board memory bandwidth the kernel
/// sustains (memory-bound designs toggle the DDR PHY hardest).
pub fn power_watts(
    dev: &FpgaDevice,
    budget: &AreaBudget,
    fmax_mhz: f64,
    bw_utilization: f64,
) -> f64 {
    // Per-resource dynamic coefficients at the base clock, scaled to
    // device size (bigger fabric toggles more capacitance per %).
    let size_scale = dev.alm as f64 / 234_720.0; // Stratix V = 1.0
    let clock_scale = fmax_mhz / dev.base_fmax_mhz;
    let logic_w = 11.0 * size_scale * budget.logic;
    let bram_w = 6.0 * size_scale * budget.m20k_blocks;
    let dsp_w = 3.5 * size_scale * budget.dsp;
    let mem_w = 3.0 * bw_utilization.clamp(0.0, 1.0);
    dev.static_power_w + clock_scale * (logic_w + bram_w + dsp_w) + mem_w
}

/// Energy-to-solution in joules (the tables' Energy column).
pub fn energy_joules(power_w: f64, seconds: f64) -> f64 {
    power_w * seconds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_v};
    use crate::perfmodel::area::AreaBudget;

    fn budget(logic: f64, bram: f64, dsp: f64) -> AreaBudget {
        AreaBudget { logic, m20k_blocks: bram, m20k_bits: bram * 0.6, dsp }
    }

    #[test]
    fn stratix_v_range_matches_thesis() {
        let dev = stratix_v();
        let idle = power_watts(&dev, &budget(0.20, 0.16, 0.02), 300.0, 0.2);
        let heavy = power_watts(&dev, &budget(0.80, 0.78, 0.52), 210.0, 0.9);
        assert!(idle > 12.0 && idle < 17.5, "idle={idle}");
        assert!(heavy > 24.0 && heavy < 33.0, "heavy={heavy}");
    }

    #[test]
    fn arria10_higher_static() {
        let a10 = arria_10();
        let sv = stratix_v();
        let b = budget(0.3, 0.3, 0.1);
        assert!(power_watts(&a10, &b, 250.0, 0.5) > power_watts(&sv, &b, 250.0, 0.5));
    }

    #[test]
    fn power_below_tdp() {
        for dev in [stratix_v(), arria_10()] {
            let p = power_watts(&dev, &budget(0.95, 0.95, 0.95), dev.base_fmax_mhz, 1.0);
            assert!(p < dev.tdp_w * 1.05, "{}: {p}", dev.name);
        }
    }

    #[test]
    fn clock_scales_dynamic_power() {
        let dev = stratix_v();
        let b = budget(0.6, 0.6, 0.4);
        assert!(power_watts(&dev, &b, 300.0, 0.5) > power_watts(&dev, &b, 200.0, 0.5));
    }
}
