//! The thesis's general performance model for HLS designs on FPGAs
//! (Chapter 3), implemented as an analytic simulator.
//!
//! This is the central hardware substitution of the reproduction (see
//! DESIGN.md §1): the paper's Quartus-synthesized bitstreams become
//! [`pipeline::PipelineSpec`] descriptors evaluated against a
//! [`crate::device::FpgaDevice`], giving cycle counts (Eqs. 3-1 … 3-8),
//! area utilization, achievable clock and power.  The thesis itself
//! validates this model family against silicon at 76–99 % accuracy
//! (§5.7.2), which is what makes the substitution meaningful.

pub mod area;
pub mod fmax;
pub mod memory;
pub mod pipeline;
pub mod power;

pub use area::{AreaBudget, AreaUsage, FpOpCounts};
pub use fmax::{seed_sweep, FmaxEstimate};
pub use memory::{AccessPattern, MemorySpec};
pub use pipeline::{KernelClass, PipelineSpec, SimReport};
pub use power::power_watts;
