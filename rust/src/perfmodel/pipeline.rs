//! The single-pipeline execution model, Eqs. 3-1 … 3-8 of the thesis.
//!
//! For a pipeline of depth `P`, trip count `L` and initiation interval
//! `II`:
//!
//! ```text
//! T_cycle = P + II · (L − 1)                                   (3-1)
//! II      = max(II_c, II_r)                                    (3-6)
//! II_c    = N_d + 1      (Single Work-item: compile-time stalls)
//! II_c    = N_b + 1      (NDRange: barriers act like stalls)   (3-4)
//! II_r    ≥ N_m / BW     (external-memory pressure)            (3-5)
//! ```
//!
//! and with a degree of data parallelism `N_p` (SIMD / unroll / CU
//! replication) the trip count divides while memory pressure multiplies
//! (Eqs. 3-7, 3-8).

use crate::device::FpgaDevice;
use crate::perfmodel::memory::MemorySpec;

/// NDRange vs Single Work-item (§2.3.2, §2.3.3) — which source feeds the
/// compile-time initiation interval.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    /// Loop iterations pipelined; `stalls` = N_d from loop-carried or
    /// load/store dependencies determined "at compile time".
    SingleWorkItem { stalls: u64 },
    /// Work-items pipelined; `barriers` = N_b, each flushing the pipeline.
    NdRange { barriers: u64 },
}

/// A synthesized pipeline: the analytic stand-in for one OpenCL kernel
/// (or one loop nest of it) on the FPGA.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    /// Descriptive name for reports.
    pub name: String,
    /// Pipeline depth P (filled-latency cycles).  The compiler controls
    /// this; typical generated pipelines run hundreds of stages.
    pub depth: u64,
    /// Loop trip count L — total iterations (SWI) or work-items (NDR)
    /// pushed through the pipeline for the whole workload.
    pub trip_count: u64,
    /// Kernel class and its II_c source.
    pub class: KernelClass,
    /// Bytes touched in external memory per *logical iteration* (N_m),
    /// before applying the parallelism multiplier.
    pub bytes_per_iter: f64,
    /// Degree of data parallelism N_p (SIMD × unroll × compute units).
    pub parallelism: u64,
    /// Memory access pattern (drives effective bandwidth, §3.2.1.5).
    pub memory: MemorySpec,
    /// Number of sequential outer repetitions that cannot be pipelined
    /// (e.g. the host-side time loop): the pipeline refills each time.
    pub invocations: u64,
}

impl PipelineSpec {
    /// Compile-time initiation interval II_c.
    pub fn ii_compile(&self) -> f64 {
        match self.class {
            KernelClass::SingleWorkItem { stalls } => (stalls + 1) as f64,
            KernelClass::NdRange { barriers } => (barriers + 1) as f64,
        }
    }

    /// Run-time initiation interval II_r from external-memory pressure
    /// (Eq. 3-5 with the N_p multiplier of Eq. 3-8), in cycles.
    pub fn ii_runtime(&self, dev: &FpgaDevice, fmax_mhz: f64) -> f64 {
        let eff_bw = self.memory.effective_bytes_per_cycle(dev, fmax_mhz);
        if eff_bw <= 0.0 {
            return f64::INFINITY;
        }
        self.bytes_per_iter * self.parallelism as f64 / eff_bw
    }

    /// Effective initiation interval (Eq. 3-6).
    pub fn ii(&self, dev: &FpgaDevice, fmax_mhz: f64) -> f64 {
        self.ii_compile().max(self.ii_runtime(dev, fmax_mhz))
    }

    /// Total cycles for the workload (Eq. 3-7, times `invocations`).
    pub fn cycles(&self, dev: &FpgaDevice, fmax_mhz: f64) -> f64 {
        let np = self.parallelism.max(1) as f64;
        let l = self.trip_count as f64;
        let per_invocation =
            self.depth as f64 + self.ii(dev, fmax_mhz) * ((l / np) - 1.0).max(0.0);
        per_invocation * self.invocations.max(1) as f64
    }

    /// Wall-clock seconds at the given kernel clock (Eq. 3-2).
    pub fn seconds(&self, dev: &FpgaDevice, fmax_mhz: f64) -> f64 {
        self.cycles(dev, fmax_mhz) / (fmax_mhz * 1e6)
    }

    /// Is this design memory-bound at the given clock? (II_r > II_c)
    pub fn memory_bound(&self, dev: &FpgaDevice, fmax_mhz: f64) -> bool {
        self.ii_runtime(dev, fmax_mhz) > self.ii_compile()
    }
}

/// Result of simulating one kernel variant on one device: the row shape
/// of the thesis's per-benchmark tables (4-3 … 4-8).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub name: String,
    pub seconds: f64,
    pub fmax_mhz: f64,
    pub power_w: f64,
    pub energy_j: f64,
    pub logic_frac: f64,
    pub m20k_bits_frac: f64,
    pub m20k_blocks_frac: f64,
    pub dsp_frac: f64,
    pub memory_bound: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::stratix_v;
    use crate::perfmodel::memory::MemorySpec;

    fn spec(class: KernelClass, bytes: f64, par: u64) -> PipelineSpec {
        PipelineSpec {
            name: "t".into(),
            depth: 100,
            trip_count: 1_000_000,
            class,
            bytes_per_iter: bytes,
            parallelism: par,
            memory: MemorySpec::streaming(),
            invocations: 1,
        }
    }

    #[test]
    fn ii_compile_matches_eq_3_3_and_3_4() {
        let s = spec(KernelClass::SingleWorkItem { stalls: 7 }, 0.0, 1);
        assert_eq!(s.ii_compile(), 8.0);
        let n = spec(KernelClass::NdRange { barriers: 2 }, 0.0, 1);
        assert_eq!(n.ii_compile(), 3.0);
    }

    #[test]
    fn compute_bound_cycles_follow_eq_3_1() {
        let dev = stratix_v();
        let s = spec(KernelClass::SingleWorkItem { stalls: 0 }, 0.0, 1);
        let c = s.cycles(&dev, 300.0);
        assert!((c - (100.0 + 999_999.0)).abs() < 1.0);
    }

    #[test]
    fn parallelism_divides_trip_count() {
        let dev = stratix_v();
        let s1 = spec(KernelClass::SingleWorkItem { stalls: 0 }, 0.0, 1);
        let s16 = spec(KernelClass::SingleWorkItem { stalls: 0 }, 0.0, 16);
        let speedup = s1.cycles(&dev, 300.0) / s16.cycles(&dev, 300.0);
        assert!(speedup > 15.0 && speedup <= 16.1, "speedup {speedup}");
    }

    #[test]
    fn memory_pressure_caps_parallel_speedup() {
        // 8 B/iter on a ~85 B/cycle device: at N_p = 64 the design is
        // firmly memory-bound and far from 64x scaling (Eq. 3-8).
        let dev = stratix_v();
        let s1 = spec(KernelClass::SingleWorkItem { stalls: 0 }, 8.0, 1);
        let s64 = spec(KernelClass::SingleWorkItem { stalls: 0 }, 8.0, 64);
        assert!(!s1.memory_bound(&dev, 300.0));
        assert!(s64.memory_bound(&dev, 300.0));
        let speedup = s1.cycles(&dev, 300.0) / s64.cycles(&dev, 300.0);
        assert!(speedup < 16.0, "memory-bound speedup {speedup}");
    }

    #[test]
    fn barriers_hurt_ndrange_like_stalls() {
        let dev = stratix_v();
        let swi = spec(KernelClass::SingleWorkItem { stalls: 0 }, 0.0, 1);
        let ndr = spec(KernelClass::NdRange { barriers: 3 }, 0.0, 1);
        assert!(ndr.cycles(&dev, 300.0) / swi.cycles(&dev, 300.0) > 3.5);
    }
}
