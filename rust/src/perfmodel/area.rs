//! Area model: FLOP units → DSPs/ALMs, buffers → M20K blocks.
//!
//! Implements the resource accounting the thesis does by reading Quartus
//! fitter reports, including:
//!
//! * per-operation DSP/ALM costs — on Stratix V only the 27×27 multiplier
//!   lives in the DSP and every floating-point add burns soft logic, while
//!   Arria 10 / Stratix 10 DSPs natively implement FADD/FMUL/FMA (§2.1.1);
//! * Block-RAM replication for multi-ported buffers (§3.2.4.2): each M20K
//!   has two physical ports, extra concurrent reads replicate the buffer,
//!   a second write port forces double-pumping;
//! * the Table 5-5 DSPs-per-cell-update counts for star stencils.

use crate::device::FpgaDevice;

/// Floating-point (and related) operation counts per pipeline stage slice,
/// i.e. per single data-parallel lane; multiply by N_p for totals.
#[derive(Debug, Clone, Copy, Default)]
pub struct FpOpCounts {
    pub fadd: u64,
    pub fmul: u64,
    pub fma: u64,
    pub fdiv: u64,
    /// Special functions (exp, log, sqrt...) — big soft-logic islands.
    pub special: u64,
    /// 32-bit integer ALU ops implemented in soft logic (DP benchmarks).
    pub int_ops: u64,
}

impl FpOpCounts {
    /// Total FLOPs this op mix contributes per cell/iteration (FMA = 2).
    pub fn flops(&self) -> f64 {
        (self.fadd + self.fmul + self.fdiv + self.special) as f64
            + 2.0 * self.fma as f64
    }

    /// DSP blocks consumed on the given device.
    pub fn dsp(&self, dev: &FpgaDevice) -> u64 {
        if dev.native_fp_dsp {
            // One DSP per FADD/FMUL/FMA (§2.1.1); division is a multi-DSP
            // Newton-Raphson macro; specials mostly burn logic + a few DSPs.
            self.fadd + self.fmul + self.fma + 4 * self.fdiv + 2 * self.special
        } else {
            // Stratix V: only multipliers map to DSPs (FMUL and the
            // multiply half of an FMA); adds live in ALMs; a division
            // macro burns several 27x27 multipliers (Newton-Raphson).
            self.fmul + self.fma + 6 * self.fdiv
        }
    }

    /// ALMs consumed on the given device (logic cost of the datapath).
    pub fn alm(&self, dev: &FpgaDevice) -> u64 {
        if dev.native_fp_dsp {
            // Hardened FP leaves only glue logic per op.
            45 * (self.fadd + self.fmul + self.fma)
                + 350 * self.fdiv
                + 900 * self.special
                + 9 * self.int_ops
        } else {
            // Soft FP adders/normalizers dominate (≈550 ALM per FADD on
            // Stratix V-class fabric; an FMA needs the adder + glue).
            550 * self.fadd
                + 120 * self.fmul
                + 650 * self.fma
                + 3_000 * self.fdiv
                + 2_200 * self.special
                + 9 * self.int_ops
        }
    }
}

/// On-chip buffer style — decides the replication rule (§3.2.4.1/.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufferStyle {
    /// Static-addressed shifting window: no port replication needed and
    /// single-cycle access (the FPGA-specific storage of §3.2.4.1).
    ShiftRegister,
    /// Dynamically addressed RAM/ROM: two physical ports per M20K;
    /// concurrent accesses beyond that replicate (reads) or double-pump
    /// (second write).
    Ram,
}

/// One local-memory buffer of a kernel variant.
#[derive(Debug, Clone, Copy)]
pub struct BufferSpec {
    pub bits: u64,
    pub read_ports: u64,
    pub write_ports: u64,
    pub style: BufferStyle,
}

impl BufferSpec {
    /// M20K blocks required, including replication.
    ///
    /// Base blocks come from capacity at the 512 × 40-bit geometry
    /// (§2.1.1).  For [`BufferStyle::Ram`], reads beyond the ports left
    /// by writes replicate the whole buffer; double-pumping (implied once
    /// >1 write port exists) doubles effective ports, exactly the
    /// behaviour described in §3.2.4.2.
    pub fn m20k_blocks(&self) -> u64 {
        let base = self.bits.div_ceil(20 * 1024).max(1);
        match self.style {
            BufferStyle::ShiftRegister => base,
            BufferStyle::Ram => {
                let double_pumped = self.write_ports > 1;
                let ports_per_block: u64 = if double_pumped { 4 } else { 2 };
                let write_cost = self.write_ports.min(ports_per_block);
                let free_reads = ports_per_block - write_cost;
                let replicas = if self.read_ports <= free_reads {
                    1
                } else {
                    // each replica's remaining ports serve reads
                    self.read_ports.div_ceil(free_reads.max(1))
                };
                base * replicas
            }
        }
    }
}

/// Accumulated area of a design.
#[derive(Debug, Clone, Copy, Default)]
pub struct AreaUsage {
    pub alm: u64,
    pub m20k_blocks: u64,
    pub m20k_bits: u64,
    pub dsp: u64,
}

impl AreaUsage {
    pub fn add(&mut self, other: AreaUsage) {
        self.alm += other.alm;
        self.m20k_blocks += other.m20k_blocks;
        self.m20k_bits += other.m20k_bits;
        self.dsp += other.dsp;
    }

    /// BSP / interface overhead: the OpenCL shell (DDR controllers,
    /// PCIe, DMA) the thesis's area percentages always include.
    pub fn bsp_overhead(dev: &FpgaDevice) -> AreaUsage {
        AreaUsage {
            alm: (dev.alm as f64 * 0.17) as u64,
            m20k_blocks: (dev.m20k_blocks as f64 * 0.14) as u64,
            m20k_bits: (dev.m20k_bits as f64 * 0.03) as u64,
            dsp: 0,
        }
    }
}

/// Utilization fractions against a device (the %-columns of the tables).
#[derive(Debug, Clone, Copy)]
pub struct AreaBudget {
    pub logic: f64,
    pub m20k_blocks: f64,
    pub m20k_bits: f64,
    pub dsp: f64,
}

impl AreaBudget {
    pub fn of(usage: &AreaUsage, dev: &FpgaDevice) -> Self {
        AreaBudget {
            logic: usage.alm as f64 / dev.alm as f64,
            m20k_blocks: usage.m20k_blocks as f64 / dev.m20k_blocks as f64,
            m20k_bits: usage.m20k_bits as f64 / dev.m20k_bits as f64,
            dsp: usage.dsp as f64 / dev.dsp as f64,
        }
    }

    /// Does the design fit?  Placement fails at 100 %; with the Arria 10
    /// PR flow the practical ceiling for M20K is ~95 % (§4.3.2.1).
    pub fn fits(&self, m20k_ceiling: f64) -> bool {
        self.logic < 0.98
            && self.m20k_blocks < m20k_ceiling
            && self.m20k_bits < 1.0
            && self.dsp <= 1.0
    }

    pub fn max_utilization(&self) -> f64 {
        self.logic.max(self.m20k_blocks).max(self.dsp)
    }
}

/// Star-stencil op mix per cell update in the factored form the
/// accelerator synthesizes (per distance d: 3 (2D) / 5 (3D) neighbour
/// adds + 1 FMA; plus the centre multiply).  Feeds Table 5-5.
pub fn star_ops(radius: u32, dims: u32) -> FpOpCounts {
    let neigh_adds = match dims {
        2 => 3,
        3 => 5,
        _ => panic!("dims must be 2 or 3"),
    };
    FpOpCounts {
        fadd: (neigh_adds * radius) as u64,
        fmul: 1,
        fma: radius as u64,
        ..Default::default()
    }
}

/// DSPs for one cell update on a native-FP device (Table 5-5).
pub fn dsp_per_cell_update(radius: u32, dims: u32, dev: &FpgaDevice) -> u64 {
    star_ops(radius, dims).dsp(dev)
}

/// FLOPs per cell update for GFLOP/s book-keeping (naive count, the
/// convention stencil papers use: (2·dims·r+1) muls + 2·dims·r adds).
pub fn flops_per_cell(radius: u32, dims: u32) -> f64 {
    let n = (2 * dims * radius) as f64;
    (n + 1.0) + n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_v};

    #[test]
    fn table_5_5_shape() {
        // DSP cost grows linearly with radius, 3D > 2D, and first-order
        // 2D costs a handful of DSPs on Arria 10.
        let a10 = arria_10();
        let d2 = [1, 2, 3, 4].map(|r| dsp_per_cell_update(r, 2, &a10));
        let d3 = [1, 2, 3, 4].map(|r| dsp_per_cell_update(r, 3, &a10));
        assert_eq!(d2[0], 5); // 3 adds + 1 FMA + 1 mul
        assert_eq!(d3[0], 7);
        for i in 1..4 {
            assert!(d2[i] > d2[i - 1] && d3[i] > d3[i - 1]);
            assert!(d3[i] > d2[i]);
        }
    }

    #[test]
    fn stratix_v_burns_logic_for_fp() {
        let sv = stratix_v();
        let a10 = arria_10();
        let ops = star_ops(1, 2);
        assert!(ops.alm(&sv) > 5 * ops.alm(&a10));
        assert!(ops.dsp(&sv) <= ops.dsp(&a10));
    }

    #[test]
    fn shift_register_avoids_replication() {
        let sr = BufferSpec {
            bits: 1 << 20, read_ports: 8, write_ports: 1,
            style: BufferStyle::ShiftRegister,
        };
        let ram = BufferSpec { style: BufferStyle::Ram, ..sr };
        assert!(ram.m20k_blocks() > sr.m20k_blocks());
    }

    #[test]
    fn second_write_port_double_pumps() {
        let one_w = BufferSpec {
            bits: 40 * 20 * 1024, read_ports: 3, write_ports: 1,
            style: BufferStyle::Ram,
        };
        let two_w = BufferSpec { write_ports: 2, ..one_w };
        // double-pumping gives 4 ports: 2 writes + 2 reads -> fewer
        // replicas than tripling single-pumped blocks
        assert!(two_w.m20k_blocks() <= 2 * one_w.m20k_blocks());
    }

    #[test]
    fn flops_per_cell_convention() {
        assert_eq!(flops_per_cell(1, 2), 9.0);  // 5 muls + 4 adds
        assert_eq!(flops_per_cell(1, 3), 13.0); // 7 muls + 6 adds
        assert_eq!(flops_per_cell(4, 2), 33.0);
    }

    #[test]
    fn budget_fits_logic() {
        let dev = stratix_v();
        let mut u = AreaUsage::default();
        u.add(AreaUsage { alm: dev.alm / 2, m20k_blocks: 100, m20k_bits: 0, dsp: 10 });
        let b = AreaBudget::of(&u, &dev);
        assert!(b.fits(1.0));
        u.add(AreaUsage { alm: dev.alm, ..Default::default() });
        assert!(!AreaBudget::of(&u, &dev).fits(1.0));
    }
}
