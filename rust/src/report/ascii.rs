//! Minimal ASCII table renderer (right-aligned numeric columns, header
//! rule, optional title) plus a horizontal bar-chart helper for the
//! "figure" reports.

/// Simple table builder.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Table { title: title.into(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        self.rows.push(cols);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len().max(
            self.rows.iter().map(|r| r.len()).max().unwrap_or(0),
        );
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n### {}\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                // left-align first column, right-align the rest
                if i == 0 {
                    line.push_str(&format!("{:<width$}", c, width = widths[i]));
                } else {
                    line.push_str(&format!("{:>width$}", c, width = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Render a labelled horizontal bar chart (for the figure reports).
pub fn bar_chart(title: &str, unit: &str, entries: &[(String, f64)]) -> String {
    let mut out = format!("\n### {title}\n");
    let max = entries.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = entries.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    for (label, v) in entries {
        let bars = if max > 0.0 { (v / max * 46.0).round() as usize } else { 0 };
        out.push_str(&format!(
            "{:<label_w$}  {:>10.2} {unit}  |{}\n",
            label,
            v,
            "#".repeat(bars),
        ));
    }
    out
}

/// f64 formatting helpers for table cells.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn pct(v: f64) -> String {
    format!("{:.0}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("Test").header(&["name", "val"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["long-name".into(), "100.0".into()]);
        let s = t.render();
        assert!(s.contains("### Test"));
        assert!(s.contains("long-name"));
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart("T", "GF", &[("a".into(), 50.0), ("b".into(), 100.0)]);
        let a_bars = s.lines().find(|l| l.starts_with('a')).unwrap().matches('#').count();
        let b_bars = s.lines().find(|l| l.starts_with('b')).unwrap().matches('#').count();
        assert_eq!(b_bars, 2 * a_bars);
    }
}
