//! Chapter 4 reports: Rodinia on FPGAs vs CPUs vs GPUs
//! (Tables 4-3 … 4-11, Figure 4-2).

use crate::baseline::rodinia::{measured, BENCHMARKS};
use crate::device::{chapter4_devices, arria_10, stratix_v};
use crate::report::ascii::{bar_chart, f1, f2, f3, pct, Table};
use crate::rodinia;

/// One per-benchmark table (4-3 … 4-8): all simulated variants on
/// Stratix V, same columns as the thesis.
pub fn per_benchmark_table(benchmark: &str, table_id: &str) -> String {
    let dev = stratix_v();
    let rows = rodinia::all_benchmarks(&dev)
        .into_iter()
        .find(|(n, _)| *n == benchmark)
        .map(|(_, r)| r)
        .expect("unknown benchmark");
    let mut t = Table::new(format!(
        "Table {table_id}: Performance and Area Utilization of {benchmark} on Stratix V (simulated)"
    ))
    .header(&[
        "Opt.Level", "Type", "Time (s)", "Power (W)", "Energy (J)",
        "f_max (MHz)", "Logic", "M20K bits", "M20K blk", "DSP", "Speed-up",
    ]);
    for r in rows {
        t.row(vec![
            r.key.level.label().to_string(),
            r.key.kind.to_string(),
            f3(r.report.seconds),
            f1(r.report.power_w),
            f1(r.report.energy_j),
            f1(r.report.fmax_mhz),
            pct(r.report.logic_frac),
            pct(r.report.m20k_bits_frac),
            pct(r.report.m20k_blocks_frac),
            pct(r.report.dsp_frac),
            f2(r.speedup),
        ]);
    }
    t.render()
}

/// Table 4-9: best variant per benchmark on Stratix V and Arria 10, with
/// the bottleneck column.
pub fn table_4_9() -> String {
    let mut t = Table::new(
        "Table 4-9: Performance and Power Efficiency of All Benchmarks on Stratix V and Arria 10 (simulated)",
    )
    .header(&[
        "Benchmark", "FPGA", "Time (s)", "Power (W)", "Energy (J)",
        "f_max (MHz)", "Logic", "M20K blk", "DSP", "Bottleneck",
    ]);
    for dev in [stratix_v(), arria_10()] {
        for (name, row) in rodinia::best_per_benchmark(&dev) {
            let bottleneck = if row.report.memory_bound {
                "BW".to_string()
            } else if row.report.dsp_frac > 0.85 {
                "DSP".to_string()
            } else if row.report.m20k_blocks_frac > 0.85 {
                "M20K".to_string()
            } else if row.report.logic_frac > 0.75 {
                "Logic".to_string()
            } else {
                "-".to_string()
            };
            t.row(vec![
                name.to_string(),
                dev.id.to_string(),
                f3(row.report.seconds),
                f1(row.report.power_w),
                f1(row.report.energy_j),
                f1(row.report.fmax_mhz),
                pct(row.report.logic_frac),
                pct(row.report.m20k_blocks_frac),
                pct(row.report.dsp_frac),
                bottleneck,
            ]);
        }
    }
    t.render()
}

/// Table 4-10: CPU results (thesis-measured calibration data).
pub fn table_4_10() -> String {
    let mut t = Table::new(
        "Table 4-10: Performance and Power Efficiency of All Benchmarks on CPUs (thesis-measured)",
    )
    .header(&["Benchmark", "CPU", "Time (s)", "Power (W)", "Energy (J)"]);
    for b in BENCHMARKS {
        for id in ["i7-3930k", "e5-2650v3"] {
            let m = measured(id, b).unwrap();
            t.row(vec![
                b.to_string(),
                id.to_string(),
                f3(m.seconds),
                f1(m.power_w),
                f1(m.energy_j()),
            ]);
        }
    }
    t.render()
}

/// Table 4-11: GPU results (thesis-measured calibration data).
pub fn table_4_11() -> String {
    let mut t = Table::new(
        "Table 4-11: Performance and Power Efficiency of All Benchmarks on GPUs (thesis-measured)",
    )
    .header(&["Benchmark", "GPU", "Time (s)", "Power (W)", "Energy (J)"]);
    for b in BENCHMARKS {
        for id in ["k20x", "980ti"] {
            let m = measured(id, b).unwrap();
            t.row(vec![
                b.to_string(),
                id.to_string(),
                f3(m.seconds),
                f1(m.power_w),
                f1(m.energy_j()),
            ]);
        }
    }
    t.render()
}

/// Figure 4-2: normalized performance and power-efficiency comparison
/// across all hardware, per benchmark.
pub fn figure_4_2() -> String {
    let mut out = String::from(
        "\n### Figure 4-2: Performance and Power Efficiency Comparison Between Different Hardware\n",
    );
    let sv = stratix_v();
    let a10 = arria_10();
    let sv_best = rodinia::best_per_benchmark(&sv);
    let a10_best = rodinia::best_per_benchmark(&a10);

    for (i, b) in BENCHMARKS.iter().enumerate() {
        // (label, seconds, watts)
        let mut entries: Vec<(String, f64, f64)> = vec![
            (
                "Stratix V".into(),
                sv_best[i].1.report.seconds,
                sv_best[i].1.report.power_w,
            ),
            (
                "Arria 10".into(),
                a10_best[i].1.report.seconds,
                a10_best[i].1.report.power_w,
            ),
        ];
        for dev in chapter4_devices() {
            let m = measured(dev.id, b).unwrap();
            entries.push((dev.name.to_string(), m.seconds, m.power_w));
        }
        // normalize performance to the slowest device
        let tmax = entries.iter().map(|e| e.1).fold(f64::MIN, f64::max);
        let perf: Vec<(String, f64)> = entries
            .iter()
            .map(|(l, t, _)| (l.clone(), tmax / t))
            .collect();
        out.push_str(&bar_chart(
            &format!("{b}: relative performance (higher is better)"),
            "x",
            &perf,
        ));
        let eff: Vec<(String, f64)> = entries
            .iter()
            .map(|(l, t, w)| (l.clone(), 1.0 / (t * w)))
            .collect();
        let emax = eff.iter().map(|e| e.1).fold(f64::MIN, f64::max);
        let eff_norm: Vec<(String, f64)> =
            eff.into_iter().map(|(l, v)| (l, v / emax)).collect();
        out.push_str(&bar_chart(
            &format!("{b}: relative power efficiency (1/energy, higher is better)"),
            "",
            &eff_norm,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpga_beats_cpu_everywhere_in_fig_4_2() {
        // The chapter's headline: FPGAs beat same-generation CPUs in both
        // performance and power efficiency in every benchmark.
        let sv = stratix_v();
        for (name, row) in rodinia::best_per_benchmark(&sv) {
            let cpu = measured("i7-3930k", name).unwrap();
            assert!(
                row.report.seconds < cpu.seconds,
                "{name}: sv {} vs cpu {}",
                row.report.seconds,
                cpu.seconds
            );
            assert!(row.report.energy_j < cpu.energy_j(), "{name} energy");
        }
    }

    #[test]
    fn fpga_beats_gpu_power_efficiency() {
        // Stratix V achieves better energy-to-solution than its
        // same-generation GPU in every benchmark (up to 5.6x, §4.3.5).
        let sv = stratix_v();
        for (name, row) in rodinia::best_per_benchmark(&sv) {
            let gpu = measured("k20x", name).unwrap();
            assert!(
                row.report.energy_j < gpu.energy_j(),
                "{name}: sv {}J vs k20x {}J",
                row.report.energy_j,
                gpu.energy_j()
            );
        }
    }

    #[test]
    fn gpus_beat_fpgas_on_performance_mostly() {
        // §4.3.5: except NW, the same-generation GPU outperforms the FPGA.
        let sv = stratix_v();
        let mut fpga_wins = 0;
        for (name, row) in rodinia::best_per_benchmark(&sv) {
            let gpu = measured("k20x", name).unwrap();
            if row.report.seconds < gpu.seconds {
                fpga_wins += 1;
                assert!(name == "NW" || name == "Pathfinder", "unexpected FPGA win: {name}");
            }
        }
        assert!(fpga_wins <= 2);
    }
}
