//! Regenerates every table and figure of the thesis's evaluation in
//! paper-like textual form (see DESIGN.md §5 for the experiment index).
//!
//! Each `table_*` / `figure_*` function returns the rendered text;
//! `render_all` strings them together.  The CLI (`fpga-hpc table 4-3`)
//! and the bench targets call into these.

pub mod ascii;
pub mod chapter4;
pub mod chapter5;

pub use ascii::Table;

/// All report ids, in thesis order.
pub const ALL_REPORTS: &[&str] = &[
    "4-3", "4-4", "4-5", "4-6", "4-7", "4-8", "4-9", "4-10", "4-11",
    "fig4-2", "5-5", "5-6", "5-7", "5-8", "5-9", "fig5-7", "fig5-8",
    "fig5-9", "fig5-10", "model-accuracy",
];

/// Render one report by id.
pub fn render(id: &str) -> crate::Result<String> {
    Ok(match id {
        "4-3" => chapter4::per_benchmark_table("NW", "4-3"),
        "4-4" => chapter4::per_benchmark_table("Hotspot", "4-4"),
        "4-5" => chapter4::per_benchmark_table("Hotspot 3D", "4-5"),
        "4-6" => chapter4::per_benchmark_table("Pathfinder", "4-6"),
        "4-7" => chapter4::per_benchmark_table("SRAD", "4-7"),
        "4-8" => chapter4::per_benchmark_table("LUD", "4-8"),
        "4-9" => chapter4::table_4_9(),
        "4-10" => chapter4::table_4_10(),
        "4-11" => chapter4::table_4_11(),
        "fig4-2" => chapter4::figure_4_2(),
        "5-5" => chapter5::table_5_5(),
        "5-6" => chapter5::table_5_6(),
        "5-7" => chapter5::table_5_7(),
        "5-8" => chapter5::table_5_8(),
        "5-9" => chapter5::table_5_9(),
        "fig5-7" => chapter5::figure_5_7(),
        "fig5-8" => chapter5::figure_5_8(),
        "fig5-9" => chapter5::figure_5_9(),
        "fig5-10" => chapter5::figure_5_10(),
        "model-accuracy" => chapter5::model_accuracy(),
        other => anyhow::bail!("unknown report id '{other}' (try one of {ALL_REPORTS:?})"),
    })
}

/// Render every table and figure.
pub fn render_all() -> crate::Result<String> {
    let mut out = String::new();
    for id in ALL_REPORTS {
        out.push_str(&render(id)?);
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_report_renders() {
        for id in ALL_REPORTS {
            let text = render(id).unwrap();
            assert!(text.len() > 100, "{id} too short");
        }
    }

    #[test]
    fn unknown_report_errors() {
        assert!(render("9-9").is_err());
    }
}
