//! Chapter 5 reports: the stencil accelerator (Tables 5-5 … 5-9,
//! Figures 5-7 … 5-10, model accuracy §5.7.2).

use crate::baseline::stencil::{stencil_performance, stencil_power};
use crate::device::{arria_10, chapter5_devices, stratix_10, stratix_v};
use crate::perfmodel::area::dsp_per_cell_update;
use crate::report::ascii::{bar_chart, f1, f2, pct, Table};
use crate::stencil::config::{
    default_workload, diffusion2d, diffusion3d, hotspot2d_shape, hotspot3d_shape, StencilShape,
};
use crate::stencil::cyclesim;
use crate::stencil::tuner::tune;

fn first_order_shapes() -> Vec<(StencilShape, u32)> {
    vec![
        (diffusion2d(1), 2),
        (hotspot2d_shape(), 2),
        (diffusion3d(1), 3),
        (hotspot3d_shape(), 3),
    ]
}

fn high_order_shapes() -> Vec<(StencilShape, u32)> {
    vec![
        (diffusion2d(2), 2), (diffusion2d(3), 2), (diffusion2d(4), 2),
        (diffusion3d(2), 3), (diffusion3d(3), 3), (diffusion3d(4), 3),
    ]
}

/// Table 5-5: DSPs per cell update on Arria 10.
pub fn table_5_5() -> String {
    let a10 = arria_10();
    let mut t = Table::new(
        "Table 5-5: Number of DSPs Required for One Cell Update on Arria 10",
    )
    .header(&["Stencil", "radius", "DSPs/update (2D)", "DSPs/update (3D)"]);
    for r in 1..=4u32 {
        t.row(vec![
            format!("Diffusion r={r}"),
            r.to_string(),
            dsp_per_cell_update(r, 2, &a10).to_string(),
            dsp_per_cell_update(r, 3, &a10).to_string(),
        ]);
    }
    t.render()
}

fn stencil_table(title: &str, shapes: &[(StencilShape, u32)]) -> String {
    let mut t = Table::new(title).header(&[
        "Stencil", "FPGA", "Config", "f_max (MHz)", "GCell/s", "GFLOP/s",
        "Power (W)", "DSP", "M20K", "Bound",
    ]);
    for dev in [stratix_v(), arria_10()] {
        for (shape, dims) in shapes {
            let work = default_workload(*dims);
            let res = tune(shape, &work, &dev);
            let b = &res.best;
            t.row(vec![
                shape.name.to_string(),
                dev.id.to_string(),
                b.config.label(),
                f1(b.fmax_mhz),
                f2(b.gcells),
                f1(b.gflops),
                f1(b.power_w),
                pct(b.budget.dsp),
                pct(b.budget.m20k_blocks),
                if b.memory_bound { "BW" } else { "compute" }.to_string(),
            ]);
        }
    }
    t.render()
}

/// Table 5-6: first-order stencil configurations and performance.
pub fn table_5_6() -> String {
    stencil_table(
        "Table 5-6: Configuration and Performance of First-order Stencils on FPGAs (simulated)",
        &first_order_shapes(),
    )
}

/// Table 5-7: high-order stencil configurations and performance.
pub fn table_5_7() -> String {
    stencil_table(
        "Table 5-7: Configuration and Performance of High-order Stencils on FPGAs (simulated)",
        &high_order_shapes(),
    )
}

/// Table 5-8: Stratix 10 projection, with speed-up vs Arria 10.
pub fn table_5_8() -> String {
    let a10 = arria_10();
    let s10 = stratix_10();
    let mut t = Table::new(
        "Table 5-8: Performance Projection Results for Stratix 10 (simulated)",
    )
    .header(&[
        "Stencil", "A10 GFLOP/s", "S10 Config", "S10 GFLOP/s", "Speed-up",
    ]);
    let mut shapes = first_order_shapes();
    shapes.extend(high_order_shapes());
    for (shape, dims) in shapes {
        let work = default_workload(dims);
        let a = tune(&shape, &work, &a10);
        let s = tune(&shape, &work, &s10);
        t.row(vec![
            shape.name.to_string(),
            f1(a.best.gflops),
            s.best.config.label(),
            f1(s.best.gflops),
            f2(s.best.gflops / a.best.gflops),
        ]);
    }
    t.render()
}

/// Table 5-9: high-order stencils across all hardware with power
/// efficiency.
pub fn table_5_9() -> String {
    let mut t = Table::new(
        "Table 5-9: Performance and Power Efficiency of High-order Stencil Computation (simulated FPGAs, modeled baselines)",
    )
    .header(&["Stencil", "Device", "GFLOP/s", "Power (W)", "GFLOP/s/W"]);
    for (shape, dims) in high_order_shapes() {
        let work = default_workload(dims);
        for dev in [stratix_v(), arria_10(), stratix_10()] {
            let res = tune(&shape, &work, &dev);
            t.row(vec![
                shape.name.to_string(),
                dev.name.to_string(),
                f1(res.best.gflops),
                f1(res.best.power_w),
                f2(res.best.gflops / res.best.power_w),
            ]);
        }
        for dev in chapter5_devices() {
            let g = stencil_performance(&dev, &shape);
            let p = stencil_power(&dev);
            t.row(vec![
                shape.name.to_string(),
                dev.name.to_string(),
                f1(g),
                f1(p),
                f2(g / p),
            ]);
        }
    }
    t.render()
}

fn figure_first_order(shape: StencilShape, dims: u32, fig: &str) -> String {
    let work = default_workload(dims);
    let mut entries: Vec<(String, f64)> = Vec::new();
    for dev in [stratix_v(), arria_10(), stratix_10()] {
        let res = tune(&shape, &work, &dev);
        entries.push((dev.name.to_string(), res.best.gflops));
    }
    for dev in chapter5_devices() {
        entries.push((dev.name.to_string(), stencil_performance(&dev, &shape)));
    }
    bar_chart(
        &format!("Figure {fig}: {} performance on all hardware", shape.name),
        "GFLOP/s",
        &entries,
    )
}

/// Figure 5-7: first-order 2D stencil on all hardware.
pub fn figure_5_7() -> String {
    figure_first_order(diffusion2d(1), 2, "5-7")
}

/// Figure 5-8: first-order 3D stencil on all hardware.
pub fn figure_5_8() -> String {
    figure_first_order(diffusion3d(1), 3, "5-8")
}

/// Figure 5-9: high-order diffusion throughput in GCell/s.
pub fn figure_5_9() -> String {
    let a10 = arria_10();
    let mut entries = Vec::new();
    for r in 1..=4u32 {
        for (shape, dims) in [(diffusion2d(r), 2u32), (diffusion3d(r), 3u32)] {
            let res = tune(&shape, &default_workload(dims), &a10);
            entries.push((shape.name.to_string(), res.best.gcells));
        }
    }
    bar_chart(
        "Figure 5-9: High-order Diffusion 2D and 3D on Arria 10 (GCell/s)",
        "GCell/s",
        &entries,
    )
}

/// Figure 5-10: high-order diffusion throughput in GFLOP/s.
pub fn figure_5_10() -> String {
    let a10 = arria_10();
    let mut entries = Vec::new();
    for r in 1..=4u32 {
        for (shape, dims) in [(diffusion2d(r), 2u32), (diffusion3d(r), 3u32)] {
            let res = tune(&shape, &default_workload(dims), &a10);
            entries.push((shape.name.to_string(), res.best.gflops));
        }
    }
    bar_chart(
        "Figure 5-10: High-order Diffusion 2D and 3D on Arria 10 (GFLOP/s)",
        "GFLOP/s",
        &entries,
    )
}

/// §5.7.2 model accuracy: closed-form model vs the cycle simulator.
pub fn model_accuracy() -> String {
    let mut t = Table::new(
        "Model accuracy (§5.7.2 analogue): closed-form §5.4 model vs event simulation",
    )
    .header(&["Stencil", "FPGA", "Config", "Accuracy"]);
    let mut shapes = first_order_shapes();
    shapes.extend(high_order_shapes());
    for dev in [stratix_v(), arria_10()] {
        for (shape, dims) in &shapes {
            let work = default_workload(*dims);
            let res = tune(shape, &work, &dev);
            let acc = cyclesim::model_accuracy(shape, &work, &res.best.config, &dev);
            t.row(vec![
                shape.name.to_string(),
                dev.id.to_string(),
                res.best.config.label(),
                pct(acc),
            ]);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a10_2d_beats_all_fixed_hardware() {
        // Fig. 5-7's headline: the Arria 10 accelerator outruns CPUs,
        // KNL and same-generation GPUs on first-order 2D stencils.
        let shape = diffusion2d(1);
        let a10 = tune(&shape, &default_workload(2), &arria_10());
        for dev in chapter5_devices() {
            if dev.year <= 2016 {
                assert!(
                    a10.best.gflops > stencil_performance(&dev, &shape),
                    "{} beats A10",
                    dev.name
                );
            }
        }
    }

    #[test]
    fn fpga_power_efficiency_wins_everywhere() {
        // Table 5-9: the FPGA is the most power-efficient device in
        // nearly all cases — check it beats every fixed device for 2D.
        for (shape, dims) in [(diffusion2d(2), 2u32), (diffusion2d(4), 2u32)] {
            let a10 = tune(&shape, &default_workload(dims), &arria_10());
            let fpga_eff = a10.best.gflops / a10.best.power_w;
            for dev in chapter5_devices() {
                let eff = stencil_performance(&dev, &shape) / stencil_power(&dev);
                assert!(fpga_eff > eff, "{}: {eff} vs fpga {fpga_eff}", dev.name);
            }
        }
    }

    #[test]
    fn model_accuracy_in_thesis_band() {
        // §5.7.2 reports 76-99 %; allow a slightly wider floor.
        let text = model_accuracy();
        for line in text.lines().filter(|l| l.contains('%')) {
            if let Some(p) = line.rsplit_once(' ') {
                if let Ok(v) = p.1.trim_end_matches('%').parse::<f64>() {
                    assert!(v >= 70.0, "accuracy too low: {line}");
                }
            }
        }
    }

    #[test]
    fn stratix10_2d_multi_tflop() {
        let text = table_5_8();
        assert!(text.contains("Diffusion 2D r=1"));
    }
}
