//! Chapter 4 CPU/GPU comparison data (Tables 4-10 and 4-11).
//!
//! `MEASURED` holds the thesis's best-compiler measurements (GCC vs ICC
//! per benchmark for CPUs; CUDA 9.1 for GPUs).  `roofline_seconds`
//! computes the naive machine-balance bound for the same workload, and
//! `efficiency` reports measured-vs-roofline — the quantity the thesis
//! discusses when it notes GPU efficiency below 10 % can lose to FPGAs
//! (§4.3.5).

use crate::device::ComputeDevice;

/// One measured (device, benchmark) cell from Tables 4-10/4-11.
#[derive(Debug, Clone, Copy)]
pub struct Measured {
    pub seconds: f64,
    pub power_w: f64,
}

impl Measured {
    pub fn energy_j(&self) -> f64 {
        self.seconds * self.power_w
    }
}

/// Benchmark order used throughout: NW, Hotspot, Hotspot 3D, Pathfinder,
/// SRAD, LUD.
pub const BENCHMARKS: [&str; 6] =
    ["NW", "Hotspot", "Hotspot 3D", "Pathfinder", "SRAD", "LUD"];

/// The thesis's measurements, best compiler per cell (Tables 4-10/4-11).
///
/// Unit note: the thesis prints the time column in *milliseconds* —
/// cross-checking Energy = time × power only works with ms (e.g.
/// Pathfinder on the 980 Ti: 21.503 ms × 219.69 W = 4.72 J, exactly the
/// table's energy cell, and §4.2.4 says Pathfinder GPU runs were "a
/// couple milliseconds").  `measured` converts to seconds.
pub fn measured(device_id: &str, benchmark: &str) -> Option<Measured> {
    let table: &[(&str, [Measured; 6])] = &[
        ("i7-3930k", [
            Measured { seconds: 719.651, power_w: 116.691 },
            Measured { seconds: 3331.503, power_w: 127.817 },
            Measured { seconds: 7752.818, power_w: 152.252 },
            Measured { seconds: 293.070, power_w: 140.161 },
            Measured { seconds: 15008.157, power_w: 153.048 },
            Measured { seconds: 19396.328, power_w: 133.585 },
        ]),
        ("e5-2650v3", [
            Measured { seconds: 371.479, power_w: 81.910 },
            Measured { seconds: 2659.946, power_w: 87.814 },
            Measured { seconds: 6794.439, power_w: 99.955 },
            Measured { seconds: 297.511, power_w: 83.687 },
            Measured { seconds: 11825.654, power_w: 100.860 },
            Measured { seconds: 14326.216, power_w: 88.891 },
        ]),
        ("k20x", [
            Measured { seconds: 270.587, power_w: 102.184 },
            Measured { seconds: 823.476, power_w: 132.297 },
            Measured { seconds: 2893.110, power_w: 118.531 },
            Measured { seconds: 50.200, power_w: 138.755 },
            Measured { seconds: 3758.656, power_w: 145.440 },
            Measured { seconds: 4884.329, power_w: 134.892 },
        ]),
        ("980ti", [
            Measured { seconds: 133.116, power_w: 132.465 },
            Measured { seconds: 1161.366, power_w: 152.340 },
            Measured { seconds: 1393.586, power_w: 174.916 },
            Measured { seconds: 21.503, power_w: 219.690 },
            Measured { seconds: 2374.360, power_w: 222.598 },
            Measured { seconds: 1292.572, power_w: 237.113 },
        ]),
    ];
    let idx = BENCHMARKS.iter().position(|b| *b == benchmark)?;
    table
        .iter()
        .find(|(id, _)| *id == device_id)
        .map(|(_, rows)| {
            let m = rows[idx];
            Measured { seconds: m.seconds / 1e3, power_w: m.power_w }
        })
}

/// Workload totals per benchmark (thesis input settings): useful FLOPs
/// (or integer ops) and minimum external traffic.
pub fn workload_totals(benchmark: &str) -> (f64, f64) {
    match benchmark {
        // (ops, bytes)
        "NW" => (5.31e8 * 10.0, 5.31e8 * 12.0),
        "Hotspot" => (6.4e9 * 13.0, 6.4e9 * 12.0),
        "Hotspot 3D" => (9.216e9 * 15.0, 9.216e9 * 12.0),
        "Pathfinder" => (1.0e9 * 4.0, 1.0e9 * 4.4),
        "SRAD" => (6.4e9 * 40.0, 6.4e9 * 8.0),
        "LUD" => (1.0195e12, 1.1520e4_f64.powi(2) * 4.0 * 180.0),
        _ => panic!("unknown benchmark {benchmark}"),
    }
}

/// Machine-balance roofline time for a benchmark on a device.
pub fn roofline_seconds(dev: &ComputeDevice, benchmark: &str) -> f64 {
    let (ops, bytes) = workload_totals(benchmark);
    // Integer benchmarks don't use the FP units; scalar/SIMD int
    // throughput is roughly peak_gflops/2 on CPUs and GPUs alike.
    let int_only = matches!(benchmark, "NW" | "Pathfinder");
    let compute_peak = if int_only { dev.peak_gflops / 2.0 } else { dev.peak_gflops };
    let t_compute = ops / (compute_peak * 1e9);
    let t_memory = bytes / (dev.mem_bw_gbs * 1e9);
    t_compute.max(t_memory)
}

/// Achieved fraction of the roofline (the thesis's "computational
/// efficiency" discussion, §4.3.5).
pub fn efficiency(dev: &ComputeDevice, benchmark: &str) -> Option<f64> {
    let m = measured(dev.id, benchmark)?;
    Some(roofline_seconds(dev, benchmark) / m.seconds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{cpu_e5_2650v3, cpu_i7_3930k, gpu_980ti, gpu_k20x};

    #[test]
    fn table_4_10_and_4_11_complete() {
        for dev in ["i7-3930k", "e5-2650v3", "k20x", "980ti"] {
            for b in BENCHMARKS {
                assert!(measured(dev, b).is_some(), "{dev}/{b}");
            }
        }
        assert!(measured("unknown", "NW").is_none());
    }

    #[test]
    fn newer_devices_win_with_one_exception() {
        // Table 4-10/4-11 findings: the newer CPU wins everywhere except
        // Pathfinder; the newer GPU wins everywhere except Hotspot.
        for b in BENCHMARKS {
            let old = measured("i7-3930k", b).unwrap().seconds;
            let new = measured("e5-2650v3", b).unwrap().seconds;
            if b == "Pathfinder" {
                assert!(new > old);
            } else {
                assert!(new < old, "{b}");
            }
            let gold = measured("k20x", b).unwrap().seconds;
            let gnew = measured("980ti", b).unwrap().seconds;
            if b == "Hotspot" {
                assert!(gnew > gold);
            } else {
                assert!(gnew < gold, "{b}");
            }
        }
    }

    #[test]
    fn gpus_beat_cpus_everywhere() {
        for b in BENCHMARKS {
            assert!(
                measured("980ti", b).unwrap().seconds
                    < measured("e5-2650v3", b).unwrap().seconds,
                "{b}"
            );
        }
    }

    #[test]
    fn efficiencies_are_fractions() {
        for dev in [cpu_i7_3930k(), cpu_e5_2650v3(), gpu_k20x(), gpu_980ti()] {
            for b in BENCHMARKS {
                let e = efficiency(&dev, b).unwrap();
                assert!(e > 0.0 && e < 1.0, "{}/{b}: {e}", dev.id);
            }
        }
    }
}
