//! Chapter 5 stencil baselines: state-of-the-art implementations on
//! fixed-architecture hardware (Table 5-9, Figs. 5-7 … 5-10).
//!
//! The thesis compares its FPGA accelerator against YASK (vector folding,
//! §5.2) on Xeon / Xeon Phi and Maruyama's 3.5D-blocked implementation on
//! GPUs.  Those frameworks are all *bandwidth-limited with partial
//! temporal reuse*: we model them as a DDR/HBM roofline with a
//! class-level effective temporal-reuse factor and achieved-bandwidth
//! fraction, calibrated to the published single-device results the thesis
//! cites (e.g. P100 first-order 3D ≈ 1 TFLOP/s with 3.5D blocking).

use crate::device::{ComputeDevice, DeviceClass};
use crate::stencil::config::StencilShape;

/// Baseline achieved GFLOP/s for a stencil on a comparator device.
pub fn stencil_performance(dev: &ComputeDevice, shape: &StencilShape) -> f64 {
    // Bytes per cell update at the DDR interface without temporal
    // blocking: one read + one write of the grid (+ extra input streams).
    let bytes_per_update = 4.0 * (2.0 + shape.extra_reads as f64);

    // Effective temporal-reuse factor: how many time steps of reuse the
    // framework extracts from caches / scratchpads before going back to
    // DRAM.  Deeper stencils blow up the working set, shrinking reuse.
    let radius_penalty = 1.0 + 0.35 * (shape.radius - 1) as f64;
    let base_reuse = match dev.class {
        // YASK vector folding: strong cache blocking on 2D, weaker in 3D.
        DeviceClass::Cpu => if shape.dims == 2 { 2.5 } else { 1.6 },
        // KNL: MCDRAM gives bandwidth, not reuse; modest blocking.
        DeviceClass::XeonPhi => if shape.dims == 2 { 1.8 } else { 1.3 },
        // 3.5D blocking on GPUs: shared-memory temporal blocking works
        // better in 3D (Maruyama) than plain 2D tiling.
        DeviceClass::Gpu => if shape.dims == 2 { 1.0 } else { 2.8 },
    };
    // No floor at 1.0: deep stencils without temporal blocking spill
    // neighbour planes past the cache and re-read from DRAM.
    let reuse = (base_reuse / radius_penalty).max(0.4);

    // Achieved fraction of peak bandwidth under stencil access.
    let bw_frac = match dev.class {
        DeviceClass::Cpu => 0.75,
        DeviceClass::XeonPhi => 0.55,
        DeviceClass::Gpu => 0.70,
    };

    let updates_per_sec =
        dev.mem_bw_gbs * 1e9 * bw_frac * reuse / bytes_per_update;
    let bw_bound_gflops = updates_per_sec * shape.flops_per_cell() / 1e9;

    // Compute ceiling: stencil FLOP mixes sustain ~55 % of peak FMA rate
    // (adds outnumber FMAs).
    let compute_bound_gflops = dev.peak_gflops * 0.55;
    bw_bound_gflops.min(compute_bound_gflops)
}

/// Average board power running a stencil (bandwidth-saturating loads run
/// near the device's measured high-load draw).
pub fn stencil_power(dev: &ComputeDevice) -> f64 {
    dev.load_power_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{
        cpu_e5_2690v4_dual, gpu_980ti, gpu_p100, gpu_v100, xeon_phi_7210f,
    };
    use crate::stencil::config::{diffusion2d, diffusion3d};

    #[test]
    fn p100_3d_first_order_near_published() {
        // Maruyama's 3.5D blocking: ~1 TFLOP/s on P100 for 7-point 3D.
        let g = stencil_performance(&gpu_p100(), &diffusion3d(1));
        assert!(g > 500.0 && g < 2500.0, "p100 3d {g}");
    }

    #[test]
    fn gpu_2d_below_a10_fpga_700() {
        // Fig. 5-7's headline: the Arria 10 accelerator (~700 GFLOP/s)
        // outruns same-generation GPUs on first-order 2D.
        let g = stencil_performance(&gpu_980ti(), &diffusion2d(1));
        assert!(g < 700.0, "980ti 2d {g}");
        assert!(g > 100.0);
    }

    #[test]
    fn reuse_declines_with_radius() {
        for dev in [cpu_e5_2690v4_dual(), xeon_phi_7210f(), gpu_v100()] {
            let g1 = stencil_performance(&dev, &diffusion2d(1));
            let g4 = stencil_performance(&dev, &diffusion2d(4));
            // GFLOP/s may grow with radius (more flops/byte) but GCell/s
            // must fall: normalize by flops per cell.
            let c1 = g1 / diffusion2d(1).flops_per_cell();
            let c4 = g4 / diffusion2d(4).flops_per_cell();
            assert!(c4 < c1, "{}: {c4} !< {c1}", dev.name);
        }
    }

    #[test]
    fn v100_beats_everything_on_3d() {
        let v = stencil_performance(&gpu_v100(), &diffusion3d(1));
        for dev in [cpu_e5_2690v4_dual(), xeon_phi_7210f(), gpu_980ti()] {
            assert!(v > stencil_performance(&dev, &diffusion3d(1)));
        }
    }
}
