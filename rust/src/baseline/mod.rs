//! CPU / GPU / Xeon Phi comparator models.
//!
//! Two layers:
//!
//! * [`rodinia`] — the Chapter 4 comparison columns.  The thesis *measured*
//!   these on real silicon (Tables 4-10, 4-11); since that hardware is the
//!   one substrate we can neither build nor simulate from first principles
//!   (out-of-order cores, GPU cache hierarchies), the measured times/powers
//!   are kept as a calibration table and exposed through a roofline model
//!   whose per-benchmark efficiency is *derived* from them.  This is a
//!   documented substitution (DESIGN.md §1): the FPGA side is genuinely
//!   modeled, the comparator side is anchored to the published numbers.
//! * [`stencil`] — the Chapter 5 comparison columns (Table 5-9, Figs.
//!   5-7 … 5-10): state-of-the-art stencil frameworks (YASK on Xeon/KNL,
//!   Maruyama's 3.5D blocking on GPUs) modeled as bandwidth rooflines with
//!   class-level temporal-reuse factors.

pub mod rodinia;
pub mod stencil;

pub use rodinia::{measured, Measured};
pub use stencil::stencil_performance;
