//! `fpga-hpc` binary: leader entry point.  See [`fpga_hpc::cli`].

fn main() -> anyhow::Result<()> {
    fpga_hpc::cli::run()
}
