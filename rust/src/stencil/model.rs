//! The §5.4 performance model for the stencil accelerator.
//!
//! Given a [`StencilShape`], a [`Workload`], an [`AcceleratorConfig`] and
//! a device, predicts: area, achievable clock, cycles, run time, GCell/s
//! and GFLOP/s, and whether compute or memory bounds the design.  This is
//! the model the thesis uses to prune the parameter space before spending
//! 8–30 hours per placement (§5.4), and it is the source of every FPGA
//! column in Tables 5-6 … 5-9.
//!
//! Structure (2D shown; 3D blocks two dimensions and streams the third):
//!
//! * the grid is cut into overlapped block columns of width `bsize`
//!   (read-redundancy `2·r·T` per boundary, §5.3.1);
//! * a chain of `T` compute stages (one per fused time step) each consume
//!   `par` cells/cycle out of shift-register line buffers (§5.3.2);
//! * one pass over the grid advances time by `T`; `steps/T` passes run.

use crate::device::FpgaDevice;
use crate::perfmodel::area::{AreaBudget, AreaUsage, BufferSpec, BufferStyle};
use crate::perfmodel::fmax::{self, CriticalPath};
use crate::perfmodel::power::power_watts;
use crate::stencil::config::{AcceleratorConfig, StencilShape, Workload};

/// Model output for one (shape, workload, config, device) point.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub config: AcceleratorConfig,
    pub fits: bool,
    pub budget: AreaBudget,
    pub usage: AreaUsage,
    pub fmax_mhz: f64,
    pub cycles: f64,
    pub seconds: f64,
    pub gcells: f64,
    pub gflops: f64,
    pub power_w: f64,
    pub memory_bound: bool,
    /// Fraction of board DDR bandwidth the design sustains.
    pub bw_utilization: f64,
}

/// Area of the accelerator at a given configuration.
pub fn area(shape: &StencilShape, cfg: &AcceleratorConfig, dev: &FpgaDevice) -> AreaUsage {
    let ops = shape.ops();
    let lanes = (cfg.par * cfg.time) as u64;

    let mut usage = AreaUsage {
        alm: ops.alm(dev) * lanes,
        dsp: ops.dsp(dev) * lanes,
        m20k_blocks: 0,
        m20k_bits: 0,
    };

    // Line buffers: each of the T stages holds a 2r-deep window of the
    // blocked footprint in shift registers (§5.3.1, Fig. 5-4):
    //   2D: 2r rows of bsize cells; 3D: 2r planes of bsize² cells.
    let window_cells: u64 = match shape.dims {
        2 => 2 * shape.radius as u64 * cfg.bsize as u64,
        3 => 2 * shape.radius as u64 * (cfg.bsize as u64).pow(2),
        _ => unreachable!(),
    };
    // The power grid (extra_reads) needs an equivalent delay buffer per
    // stage so its centre cell arrives in phase.
    let streams = 1 + shape.extra_reads as u64;
    let bits_per_stage = window_cells * 32 * streams;
    for _ in 0..cfg.time {
        let buf = BufferSpec {
            bits: bits_per_stage,
            read_ports: (2 * shape.dims * shape.radius) as u64,
            write_ports: 1,
            style: BufferStyle::ShiftRegister,
        };
        usage.m20k_blocks += buf.m20k_blocks();
        usage.m20k_bits += bits_per_stage;
    }
    // Wide load/store units & FIFOs scale with par.
    usage.alm += 900 * cfg.par as u64;
    usage.m20k_blocks += (cfg.par as u64).div_ceil(4) * 4;

    let mut total = AreaUsage::bsp_overhead(dev);
    total.add(usage);
    total
}

/// Full §5.4 prediction.
pub fn predict(
    shape: &StencilShape,
    work: &Workload,
    cfg: &AcceleratorConfig,
    dev: &FpgaDevice,
) -> Prediction {
    let usage = area(shape, cfg, dev);
    let budget = AreaBudget::of(&usage, dev);
    // Arria 10 PR flow M20K ceiling (§4.3.2.1); flat flow for SWI designs.
    let fits = budget.fits(0.97) && cfg.valid_span(shape.radius) > 0;

    let raw_fmax = fmax::estimate(dev, &budget, CriticalPath::Clean, true);
    let fmax_mhz = fmax::seed_sweep(
        &format!("{}-{}", shape.name, cfg.label()),
        raw_fmax,
        8,
    )
    .swept_mhz;

    // ---- cycles per pass (§5.4) ----
    let r = shape.radius;
    let valid = cfg.valid_span(r).max(1) as f64;
    let extent = work.extent as f64;
    let blocks_per_dim = (extent / valid).ceil();
    let blocked_dims = (shape.dims - 1) as i32;
    let issued_cells_per_pass =
        blocks_per_dim.powi(blocked_dims) * (cfg.bsize as f64).powi(blocked_dims) * extent;
    let compute_cycles = issued_cells_per_pass / cfg.par as f64;

    // External traffic per pass: read grid (+extra streams) + write grid,
    // all with block redundancy; amortized over T fused steps.
    let bytes_per_pass =
        issued_cells_per_pass * 4.0 * (1.0 + shape.extra_reads as f64 + 1.0);
    let eff_bw = crate::perfmodel::memory::MemorySpec::streaming()
        .banked()
        .effective_bytes_per_cycle(dev, fmax_mhz);
    let memory_cycles = bytes_per_pass / eff_bw;

    let per_pass = compute_cycles.max(memory_cycles);
    let memory_bound = memory_cycles > compute_cycles;

    // Pipeline fill per block column: the T-deep stage chain must warm up
    // its line buffers (2r rows / planes each) before the first output.
    let fill_per_block = cfg.time as f64
        * (2 * r) as f64
        * match shape.dims {
            2 => cfg.bsize as f64 / cfg.par as f64,
            _ => (cfg.bsize as f64).powi(2) / cfg.par as f64,
        };
    let fills = blocks_per_dim.powi(blocked_dims) * fill_per_block;

    let passes = (work.steps as f64 / cfg.time as f64).ceil();
    let cycles = passes * (per_pass + fills);
    let seconds = cycles / (fmax_mhz * 1e6);

    let updates = work.cell_updates(shape.dims);
    let gcells = updates / seconds / 1e9;
    let gflops = gcells * shape.flops_per_cell();

    let bw_utilization =
        (bytes_per_pass * passes / seconds / (dev.mem_bw_gbs * 1e9)).min(1.0);
    let power_w = power_watts(dev, &budget, fmax_mhz, bw_utilization);

    Prediction {
        config: *cfg,
        fits,
        budget,
        usage,
        fmax_mhz,
        cycles,
        seconds,
        gcells,
        gflops,
        power_w,
        memory_bound,
        bw_utilization,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_10, stratix_v};
    use crate::stencil::config::{default_workload, diffusion2d, diffusion3d};

    #[test]
    fn temporal_blocking_breaks_bandwidth_wall() {
        // The thesis's core claim (§5.1.3): with spatial blocking only
        // (T=1) the design is memory-bound; temporal blocking multiplies
        // throughput ~linearly until area runs out.
        let dev = arria_10();
        let shape = diffusion2d(1);
        let work = default_workload(2);
        let t1 = predict(&shape, &work, &AcceleratorConfig { par: 16, time: 1, bsize: 4096 }, &dev);
        let t8 = predict(&shape, &work, &AcceleratorConfig { par: 16, time: 8, bsize: 4096 }, &dev);
        assert!(t1.memory_bound);
        assert!(t8.fits, "T=8 should still fit");
        assert!(t8.gflops > 4.0 * t1.gflops, "t8={} t1={}", t8.gflops, t1.gflops);
    }

    #[test]
    fn area_scales_with_par_times_time() {
        let dev = arria_10();
        let shape = diffusion2d(1);
        let a1 = area(&shape, &AcceleratorConfig { par: 4, time: 2, bsize: 1024 }, &dev);
        let a2 = area(&shape, &AcceleratorConfig { par: 8, time: 4, bsize: 1024 }, &dev);
        assert!(a2.dsp >= 4 * a1.dsp - a1.dsp / 4);
    }

    #[test]
    fn small_block_with_deep_time_fails() {
        let dev = arria_10();
        let shape = diffusion2d(4);
        let work = default_workload(2);
        let p = predict(&shape, &work, &AcceleratorConfig { par: 8, time: 8, bsize: 32 }, &dev);
        assert!(!p.fits); // valid span would be <= 0
    }

    #[test]
    fn three_d_line_buffers_dominate_m20k() {
        // 3D line buffers hold planes: block size is the M20K pressure
        // point (§5.3.1), which is why 3D configs use small bsize.
        let dev = arria_10();
        let shape = diffusion3d(1);
        let a = area(&shape, &AcceleratorConfig { par: 4, time: 4, bsize: 256 }, &dev);
        let b = AreaBudget::of(&a, &dev);
        assert!(b.m20k_blocks > 0.35, "m20k={}", b.m20k_blocks);
        // and the same config in 2D is comparatively M20K-cheap
        let a2 = area(&crate::stencil::config::diffusion2d(1),
                      &AcceleratorConfig { par: 4, time: 4, bsize: 256 }, &dev);
        assert!(AreaBudget::of(&a2, &dev).m20k_blocks < b.m20k_blocks / 2.0);
    }

    #[test]
    fn stratix10_projection_order_of_magnitude() {
        // §5.7.3: S10 reaches multi-TFLOP/s on 2D first-order stencils.
        let dev = stratix_10();
        let shape = diffusion2d(1);
        let work = default_workload(2);
        // Deep temporal chains amortize DDR traffic to 8/T bytes per
        // update — the projection's key lever (§5.7.3).
        let p = predict(&shape, &work, &AcceleratorConfig { par: 16, time: 64, bsize: 8192 }, &dev);
        assert!(p.fits);
        assert!(p.gflops > 2000.0, "gflops={}", p.gflops);
    }

    #[test]
    fn stratix_v_slower_than_arria10_when_compute_bound() {
        let sv = stratix_v();
        let a10 = arria_10();
        let shape = diffusion2d(1);
        let work = default_workload(2);
        let cfg = AcceleratorConfig { par: 8, time: 4, bsize: 2048 };
        let p_sv = predict(&shape, &work, &cfg, &sv);
        let p_a10 = predict(&shape, &work, &cfg, &a10);
        if p_sv.fits && p_a10.fits {
            assert!(p_a10.gflops >= p_sv.gflops * 0.9);
        }
    }
}
