//! Stencil shapes, workloads and accelerator configurations (Table 5-1).

use crate::perfmodel::area::{flops_per_cell, star_ops, FpOpCounts};

/// A star-shaped stencil benchmark (Table 5-2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StencilShape {
    pub name: &'static str,
    /// Stencil radius r (1..=4 for the thesis's benchmarks).
    pub radius: u32,
    /// 2 or 3 spatial dimensions.
    pub dims: u32,
    /// Extra per-cell FP ops beyond the plain star (Hotspot's power +
    /// ambient terms), as (fadd, fmul, fma).
    pub extra: (u64, u64, u64),
    /// Extra input streams read per cell (Hotspot's power grid).
    pub extra_reads: u32,
}

impl StencilShape {
    pub const fn diffusion(radius: u32, dims: u32, name: &'static str) -> Self {
        StencilShape { name, radius, dims, extra: (0, 0, 0), extra_reads: 0 }
    }

    /// Per-cell FP op mix (drives DSP/ALM counts).
    pub fn ops(&self) -> FpOpCounts {
        let mut ops = star_ops(self.radius, self.dims);
        ops.fadd += self.extra.0;
        ops.fmul += self.extra.1;
        ops.fma += self.extra.2;
        ops
    }

    /// FLOPs per cell update, naive convention (for GFLOP/s columns).
    pub fn flops_per_cell(&self) -> f64 {
        flops_per_cell(self.radius, self.dims)
            + (self.extra.0 + self.extra.1) as f64
            + 2.0 * self.extra.2 as f64
    }
}

/// Diffusion 2D, first to fourth order (Table 5-2).
pub fn diffusion2d(radius: u32) -> StencilShape {
    match radius {
        1 => StencilShape::diffusion(1, 2, "Diffusion 2D r=1"),
        2 => StencilShape::diffusion(2, 2, "Diffusion 2D r=2"),
        3 => StencilShape::diffusion(3, 2, "Diffusion 2D r=3"),
        4 => StencilShape::diffusion(4, 2, "Diffusion 2D r=4"),
        _ => panic!("radius 1..=4"),
    }
}

/// Diffusion 3D, first to fourth order.
pub fn diffusion3d(radius: u32) -> StencilShape {
    match radius {
        1 => StencilShape::diffusion(1, 3, "Diffusion 3D r=1"),
        2 => StencilShape::diffusion(2, 3, "Diffusion 3D r=2"),
        3 => StencilShape::diffusion(3, 3, "Diffusion 3D r=3"),
        4 => StencilShape::diffusion(4, 3, "Diffusion 3D r=4"),
        _ => panic!("radius 1..=4"),
    }
}

/// Rodinia Hotspot as a first-order 2D stencil with power + ambient terms.
pub fn hotspot2d_shape() -> StencilShape {
    StencilShape {
        name: "Hotspot 2D",
        radius: 1,
        dims: 2,
        // delta/out datapath beyond the 5-point star: 3 extra adds,
        // 1 mul (cap), 2 fma (power, ambient resistances).
        extra: (3, 1, 2),
        extra_reads: 1,
    }
}

/// Rodinia Hotspot 3D (7-point star + power + ambient).
pub fn hotspot3d_shape() -> StencilShape {
    StencilShape {
        name: "Hotspot 3D",
        radius: 1,
        dims: 3,
        extra: (2, 1, 2),
        extra_reads: 1,
    }
}

/// A concrete grid + time-step workload (Table 5-2's input settings).
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// Grid extent in every spatial dimension.
    pub extent: u64,
    /// Total time steps.
    pub steps: u64,
}

impl Workload {
    pub fn cells(&self, dims: u32) -> f64 {
        (self.extent as f64).powi(dims as i32)
    }

    pub fn cell_updates(&self, dims: u32) -> f64 {
        self.cells(dims) * self.steps as f64
    }
}

/// Thesis benchmark settings (§5.5.5): large 2D grids, 3D grids sized to
/// board memory, hundreds of iterations.
pub fn default_workload(dims: u32) -> Workload {
    match dims {
        2 => Workload { extent: 16_384, steps: 1_000 },
        3 => Workload { extent: 512, steps: 100 },
        _ => panic!("dims must be 2 or 3"),
    }
}

/// The tunable accelerator parameters (Table 5-1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AcceleratorConfig {
    /// Degree of vectorization: cells computed per cycle per time step.
    pub par: u32,
    /// Degree of temporal parallelism: fused time steps in the pipeline.
    pub time: u32,
    /// Spatial block size in each blocked dimension (x for 2D; x and y
    /// for 3D — the remaining dimension streams, §5.3.1 / 3.5D blocking).
    pub bsize: u32,
}

impl AcceleratorConfig {
    /// Halo consumed per blocked-dimension side over the fused steps.
    pub fn halo(&self, radius: u32) -> u32 {
        radius * self.time
    }

    /// Valid (non-redundant) cells per block in one blocked dimension.
    pub fn valid_span(&self, radius: u32) -> u32 {
        self.bsize.saturating_sub(2 * self.halo(radius))
    }

    /// Compute redundancy factor: issued cells / valid cells (§5.4).
    pub fn redundancy(&self, radius: u32, dims: u32) -> f64 {
        let v = self.valid_span(radius);
        if v == 0 {
            return f64::INFINITY;
        }
        let blocked_dims = dims - 1; // one dimension always streams
        (self.bsize as f64 / v as f64).powi(blocked_dims as i32)
    }

    pub fn label(&self) -> String {
        format!("par={} T={} bsize={}", self.par, self.time, self.bsize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn redundancy_grows_with_time_blocking() {
        let shape = diffusion2d(1);
        let c1 = AcceleratorConfig { par: 8, time: 1, bsize: 512 };
        let c8 = AcceleratorConfig { par: 8, time: 8, bsize: 512 };
        assert!(c8.redundancy(shape.radius, shape.dims)
            > c1.redundancy(shape.radius, shape.dims));
    }

    #[test]
    fn redundancy_3d_squares() {
        let c = AcceleratorConfig { par: 4, time: 2, bsize: 64 };
        let r2 = c.redundancy(1, 2);
        let r3 = c.redundancy(1, 3);
        assert!((r3 - r2 * r2).abs() < 1e-12);
    }

    #[test]
    fn hotspot_flops_exceed_diffusion() {
        assert!(hotspot2d_shape().flops_per_cell()
            > diffusion2d(1).flops_per_cell());
    }

    #[test]
    fn degenerate_block_is_infinite_redundancy() {
        let c = AcceleratorConfig { par: 1, time: 16, bsize: 16 };
        assert!(c.redundancy(1, 2).is_infinite());
    }
}
