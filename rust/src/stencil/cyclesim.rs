//! Coarse cycle-level simulator for the stencil accelerator.
//!
//! Plays the role of the real hardware in the §5.7.2 model-accuracy
//! study: instead of the closed-form §5.4 expressions, it walks the block
//! schedule block by block, simulating the load / compute / drain phases
//! and a token-bucket DDR bandwidth arbiter, including effects the
//! closed-form model ignores (per-block fill, partial edge blocks,
//! read/write turnaround).  Model accuracy = model cycles / simulated
//! cycles, reported by `fpga-hpc table model-accuracy`.

use crate::device::FpgaDevice;
use crate::perfmodel::memory::MemorySpec;
use crate::stencil::config::{AcceleratorConfig, StencilShape, Workload};

/// Simulated total cycles for the workload.
pub fn simulate_cycles(
    shape: &StencilShape,
    work: &Workload,
    cfg: &AcceleratorConfig,
    dev: &FpgaDevice,
    fmax_mhz: f64,
) -> f64 {
    let r = shape.radius;
    let valid = cfg.valid_span(r).max(1) as u64;
    let extent = work.extent;
    let par = cfg.par as u64;

    // Effective DDR bytes per cycle with manual banking (§3.2.3.1).
    let bw = MemorySpec::streaming()
        .banked()
        .effective_bytes_per_cycle(dev, fmax_mhz);
    let streams = (2 + shape.extra_reads) as f64; // read + write + extras

    // Block grid along each blocked dimension, with partial edge blocks.
    let blocked_dims = shape.dims - 1;
    let mut spans: Vec<u64> = Vec::new();
    let mut x = 0u64;
    while x < extent {
        let v = valid.min(extent - x);
        spans.push(v + 2 * cfg.halo(r) as u64); // issued width incl. halo
        x += v;
    }

    // One pass = every block walked once; the streamed dimension has
    // `extent` positions.
    let mut pass_cycles = 0.0f64;
    let per_position_issue = |issued_width: u64| -> f64 {
        // cells issued per streamed position for this block
        match blocked_dims {
            1 => issued_width as f64,
            2 => (issued_width * issued_width) as f64,
            _ => unreachable!(),
        }
    };

    let blocks: Vec<u64> = match blocked_dims {
        1 => spans.clone(),
        2 => {
            // all (wi, wj) combinations; store issued widths multiplied
            let mut v = Vec::new();
            for &a in &spans {
                for &b in &spans {
                    // encode the pair as the issued plane size
                    v.push(a * b);
                }
            }
            v
        }
        _ => unreachable!(),
    };

    for &b in &blocks {
        let issued_per_pos = if blocked_dims == 1 {
            per_position_issue(b)
        } else {
            b as f64 // already a plane size
        };
        // fill: T stages × 2r streamed positions of warm-up
        let fill = cfg.time as f64 * (2 * r) as f64 * issued_per_pos / par as f64;
        // steady state: compute vs memory, per streamed position
        let compute = issued_per_pos / par as f64;
        let memory = issued_per_pos * 4.0 * streams / bw;
        let steady = compute.max(memory) * extent as f64;
        // drain ≈ one stage depth
        let drain = issued_per_pos / par as f64 * (2 * r) as f64;
        pass_cycles += fill + steady + drain;
    }

    // read/write turnaround penalty per pass (~2 % of traffic time)
    let turnaround = pass_cycles * 0.02;
    let passes = (work.steps as f64 / cfg.time as f64).ceil();
    passes * (pass_cycles + turnaround)
}

/// Model accuracy for one configuration: predicted / simulated run time,
/// as the thesis reports (76–99 % over its configs).
pub fn model_accuracy(
    shape: &StencilShape,
    work: &Workload,
    cfg: &AcceleratorConfig,
    dev: &FpgaDevice,
) -> f64 {
    let p = crate::stencil::model::predict(shape, work, cfg, dev);
    let sim = simulate_cycles(shape, work, cfg, dev, p.fmax_mhz);
    (p.cycles / sim).min(sim / p.cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::arria_10;
    use crate::stencil::config::{
        default_workload, diffusion2d, diffusion3d, AcceleratorConfig, Workload,
    };

    #[test]
    fn sim_and_model_agree_within_thesis_band() {
        // §5.7.2 reports 76–99 % accuracy; our closed-form model must sit
        // in the same band against the event simulation.
        let dev = arria_10();
        for (shape, work, cfg) in [
            (diffusion2d(1), default_workload(2),
             AcceleratorConfig { par: 16, time: 4, bsize: 4096 }),
            (diffusion2d(2), default_workload(2),
             AcceleratorConfig { par: 8, time: 2, bsize: 2048 }),
            (diffusion3d(1), default_workload(3),
             AcceleratorConfig { par: 4, time: 2, bsize: 128 }),
        ] {
            let acc = model_accuracy(&shape, &work, &cfg, &dev);
            assert!(acc > 0.70, "{}: accuracy {acc}", shape.name);
        }
    }

    #[test]
    fn partial_edge_blocks_cost_cycles() {
        // An extent not divisible by the valid span must not be faster
        // than the divisible case.
        let dev = arria_10();
        let shape = diffusion2d(1);
        let cfg = AcceleratorConfig { par: 16, time: 4, bsize: 1024 };
        let even = Workload { extent: (cfg.valid_span(1) * 16) as u64, steps: 8 };
        let odd = Workload { extent: even.extent + 100, steps: 8 };
        let c_even = simulate_cycles(&shape, &even, &cfg, &dev, 250.0);
        let c_odd = simulate_cycles(&shape, &odd, &cfg, &dev, 250.0);
        assert!(c_odd > c_even);
    }
}
