//! Stratix 10 performance projection (§5.7.3, Table 5-8).
//!
//! The thesis projects its tuned designs onto the then-unreleased
//! Stratix 10 family by scaling resources (5760 DSPs, 11721 M20Ks, 4
//! memory banks) and clock (HyperFlex fabric), then re-running the same
//! §5.4 model.  We reproduce exactly that: re-tune on the
//! [`crate::device::stratix_10`] device entry.

use crate::device::{stratix_10, FpgaDevice};
use crate::stencil::config::{StencilShape, Workload};
use crate::stencil::tuner::{tune, TuneResult};

/// Projection outcome for one stencil.
#[derive(Debug, Clone)]
pub struct Projection {
    pub shape_name: &'static str,
    pub result: TuneResult,
    /// Speed-up vs the given reference prediction (typically Arria 10's
    /// tuned best), the Table 5-8 ratio column.
    pub speedup_vs_ref: f64,
}

/// Project a stencil onto Stratix 10, given the Arria 10 tuned GFLOP/s.
pub fn project_stratix10(
    shape: &StencilShape,
    work: &Workload,
    ref_gflops: f64,
) -> Projection {
    let dev: FpgaDevice = stratix_10();
    let result = tune(shape, work, &dev);
    Projection {
        shape_name: shape.name,
        speedup_vs_ref: result.best.gflops / ref_gflops,
        result,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::arria_10;
    use crate::stencil::config::{default_workload, diffusion2d, diffusion3d};
    use crate::stencil::tuner::tune;

    #[test]
    fn stratix10_beats_arria10_several_fold() {
        // Table 5-8: S10 projects to ~4-6x Arria 10 on 2D stencils.
        let work = default_workload(2);
        let shape = diffusion2d(1);
        let a10 = tune(&shape, &work, &arria_10());
        let proj = project_stratix10(&shape, &work, a10.best.gflops);
        assert!(proj.speedup_vs_ref > 2.0, "speedup {}", proj.speedup_vs_ref);
        assert!(proj.result.best.gflops > 1500.0);
    }

    #[test]
    fn stratix10_3d_in_thesis_band() {
        // §1.3: up to ~1.8 TFLOP/s for 3D on S10 — our model must land in
        // the hundreds-to-~2000 range, not 10x off either way.
        let work = default_workload(3);
        let shape = diffusion3d(1);
        let proj = project_stratix10(&shape, &work, 1.0);
        let g = proj.result.best.gflops;
        assert!(g > 300.0 && g < 4000.0, "3d gflops {g}");
    }
}
