//! Configuration tuner: the model-driven pruning of §5.4.
//!
//! The thesis's motivation for its performance model is that each FPGA
//! placement takes 8–30 hours, so exhaustively compiling the (par, T,
//! bsize) space is impossible; instead the model ranks configurations and
//! only the top few are compiled.  Here the "compile" step is the cycle
//! simulator, but the workflow is preserved: enumerate → prune by area →
//! rank by predicted throughput.

use crate::device::FpgaDevice;
use crate::stencil::config::{AcceleratorConfig, StencilShape, Workload};
use crate::stencil::model::{predict, Prediction};

/// Outcome of tuning one stencil on one device.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub best: Prediction,
    /// All feasible candidates, best first.
    pub ranked: Vec<Prediction>,
    /// Total points enumerated (for the pruning-ratio report).
    pub enumerated: usize,
}

/// The search space the thesis sweeps (§5.6.3): power-of-two vector
/// widths, temporal degrees up to the area wall, block sizes bounded by
/// on-chip memory.
pub fn search_space(shape: &StencilShape) -> Vec<AcceleratorConfig> {
    let pars: &[u32] = &[1, 2, 4, 8, 16, 32, 64];
    let times: &[u32] = &[1, 2, 3, 4, 6, 8, 10, 12, 16, 20, 24, 32, 48, 64, 96];
    let bsizes: &[u32] = if shape.dims == 2 {
        &[512, 1024, 2048, 4096, 8192, 16384]
    } else {
        &[16, 32, 64, 128, 256, 512]
    };
    let mut out = Vec::new();
    for &par in pars {
        for &time in times {
            for &bsize in bsizes {
                out.push(AcceleratorConfig { par, time, bsize });
            }
        }
    }
    out
}

/// Tune: enumerate, evaluate the model, keep feasible, rank by GFLOP/s.
pub fn tune(shape: &StencilShape, work: &Workload, dev: &FpgaDevice) -> TuneResult {
    let space = search_space(shape);
    let enumerated = space.len();
    let mut ranked: Vec<Prediction> = space
        .iter()
        .map(|cfg| predict(shape, work, cfg, dev))
        .filter(|p| p.fits)
        .collect();
    ranked.sort_by(|a, b| b.gflops.total_cmp(&a.gflops));
    let best = ranked.first().expect("no feasible configuration").clone();
    TuneResult { best, ranked, enumerated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_v};
    use crate::stencil::config::{default_workload, diffusion2d, diffusion3d};

    #[test]
    fn tuner_finds_feasible_best() {
        let dev = arria_10();
        let shape = diffusion2d(1);
        let res = tune(&shape, &default_workload(2), &dev);
        assert!(res.best.fits);
        assert!(res.ranked.len() > 10);
        assert!(res.ranked.len() < res.enumerated); // pruning happened
        // ranked is sorted
        for w in res.ranked.windows(2) {
            assert!(w[0].gflops >= w[1].gflops);
        }
    }

    #[test]
    fn best_uses_temporal_blocking() {
        // On both devices the winning first-order 2D config must fuse
        // multiple time steps — the chapter's central design point.
        for dev in [stratix_v(), arria_10()] {
            let res = tune(&diffusion2d(1), &default_workload(2), &dev);
            assert!(res.best.config.time > 1, "{}: {:?}", dev.name, res.best.config);
        }
    }

    #[test]
    fn high_order_uses_shallower_time() {
        // Higher radius = more DSPs and bigger halos per fused step, so
        // the tuner should choose a smaller T for r=4 than r=1 (Table 5-7).
        let dev = arria_10();
        let w = default_workload(2);
        let r1 = tune(&diffusion2d(1), &w, &dev);
        let r4 = tune(&diffusion2d(4), &w, &dev);
        assert!(r4.best.config.time <= r1.best.config.time);
        assert!(r4.best.gcells < r1.best.gcells);
    }

    #[test]
    fn three_d_throughput_below_2d() {
        // Table 5-6: ~700 GFLOP/s 2D vs ~270 GFLOP/s 3D on Arria 10.
        let dev = arria_10();
        let g2 = tune(&diffusion2d(1), &default_workload(2), &dev).best.gflops;
        let g3 = tune(&diffusion3d(1), &default_workload(3), &dev).best.gflops;
        assert!(g2 > g3, "2d={g2} 3d={g3}");
    }
}
