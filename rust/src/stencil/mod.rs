//! The Chapter 5 stencil accelerator: parameterized spatial + temporal
//! blocking, its §5.4 performance model, the configuration tuner that
//! replaces multi-day place-and-route sweeps, a coarse cycle-level
//! simulator used as the "measured" side of the §5.7.2 model-accuracy
//! study, and the §5.7.3 Stratix 10 projection.

pub mod config;
pub mod cyclesim;
pub mod model;
pub mod projection;
pub mod tuner;

pub use config::{AcceleratorConfig, StencilShape, Workload};
pub use cyclesim::simulate_cycles;
pub use model::{predict, Prediction};
pub use projection::project_stratix10;
pub use tuner::{tune, TuneResult};
