//! Concurrency primitives behind one shim — the crate's single door to
//! `std::sync`-style types, swappable to [loom]'s model-checked
//! doubles.
//!
//! The runtime's correctness rests on hand-rolled lock-free protocols
//! (the `WaveTable` AcqRel counter discipline, the pool's submit-epoch
//! fence, the sharded work-stealing queues).  Comments can argue those
//! protocols are sound; only a model checker can *explore* them.  Loom
//! re-implements the `std::sync` surface with an exhaustive
//! interleaving/memory-model explorer, but it can only see operations
//! performed through its own types — so every module of the
//! concurrency core (`runtime::pool`, `coordinator::passdriver`,
//! `coordinator::bufpool`, `coordinator::scheduler`) imports its
//! primitives from here, never from `std::sync` directly:
//!
//! * Under a normal build this module is a zero-cost re-export of the
//!   `std` types (the atomic cells are re-exported as type *aliases* —
//!   see below).
//! * Under `RUSTFLAGS="--cfg loom"` the same paths resolve to
//!   `loom::sync`, and `tests/loom.rs` drives the real `WaveTable` /
//!   `ReadyQueue` / shard-queue code through every interleaving.
//!
//! **The rule** (enforced by `clippy.toml`'s `disallowed-types` gate):
//! new code must not name the `std::sync::atomic` cell types anywhere
//! outside this file — import `crate::sync::atomic::{AtomicU64, ...}`
//! instead.  The gate works because clippy's `disallowed_types` lint
//! resolves re-exports to their `std` definition but does *not* see
//! through type aliases; the aliases below are therefore the one
//! sanctioned spelling.  (`Ordering` is deliberately not disallowed —
//! it is pure data, and both `std` and loom use the `std` enum.)
//!
//! What swaps and what deliberately does not:
//!
//! | name                          | normal build | `cfg(loom)` |
//! |-------------------------------|--------------|-------------|
//! | `atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize}` | `std` (as aliases) | `loom` |
//! | `Mutex`, `MutexGuard`, `Condvar` | `std`     | `loom`      |
//! | `Arc`, `Barrier`, `PoisonError`  | `std`     | `std`       |
//!
//! `Arc` stays `std` everywhere: loom's `Arc` cannot hold unsized
//! payloads (the pool passes `Arc<str>` artifact names), and no modeled
//! protocol relies on the reference count's release/acquire handshake —
//! every cross-thread publication the models check goes through a
//! `Mutex` or an atomic RMW chain.  `Barrier` stays `std` because loom
//! provides none and the only user (`RuntimePool::warmup_artifact`) is
//! not on a modeled path; `PoisonError` is `std`-only machinery that
//! loom's `LockResult` shares.  `std::sync::mpsc` is likewise not
//! re-exported: the channels sit outside every modeled protocol, and
//! callers keep importing them from `std` (they are not disallowed).
//!
//! [loom]: https://docs.rs/loom

#[cfg(not(loom))]
#[allow(clippy::disallowed_types)] // the one sanctioned naming site
pub mod atomic {
    //! Atomic cells (aliased, see the module docs) plus `Ordering`.
    pub use std::sync::atomic::Ordering;

    pub type AtomicBool = std::sync::atomic::AtomicBool;
    pub type AtomicU32 = std::sync::atomic::AtomicU32;
    pub type AtomicU64 = std::sync::atomic::AtomicU64;
    pub type AtomicUsize = std::sync::atomic::AtomicUsize;
}

#[cfg(loom)]
pub mod atomic {
    //! Loom's model-checked atomic cells.
    pub use loom::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};

pub use std::sync::{Arc, Barrier, PoisonError};

#[cfg(all(test, not(loom)))]
mod tests {
    use super::atomic::{AtomicU64, Ordering};
    use super::{Condvar, Mutex};

    /// The aliases must behave exactly like the std types they name —
    /// a smoke check that the shim adds nothing and loses nothing.
    #[test]
    fn shim_types_are_std_types() {
        let a = AtomicU64::new(1);
        assert_eq!(a.fetch_add(1, Ordering::AcqRel), 1);
        assert_eq!(a.load(Ordering::Acquire), 2);

        let m = Mutex::new(7u32);
        let cv = Condvar::new();
        {
            let mut g: super::MutexGuard<'_, u32> = m.lock().unwrap();
            *g += 1;
            cv.notify_all();
        }
        assert_eq!(*m.lock().unwrap(), 8);

        // The non-swapped names remain plain std re-exports.
        let shared: super::Arc<str> = super::Arc::from("unsized payloads stay supported");
        assert_eq!(shared.len(), 31);
    }
}
