//! # fpga-hpc — "High Performance Computing with FPGAs and OpenCL", reproduced
//!
//! A reproduction of Hamid Reza Zohouri's 2018 thesis as a three-layer
//! Rust + JAX + Pallas stack.  The paper's FPGA testbed is replaced by an
//! analytic simulator implementing the thesis's own performance model
//! (Ch. 3 and §5.4); the paper's OpenCL kernels are replaced by AOT-lowered
//! JAX/Pallas compute units executed through PJRT.  See DESIGN.md for the
//! full system inventory and the per-table experiment index.
//!
//! Layer map:
//!
//! * [`runtime`] — loads `artifacts/*.hlo.txt` (HLO text produced by
//!   `python/compile/aot.py`) into PJRT CPU clients and executes them;
//!   [`runtime::pool`] replicates one client per lane thread (the
//!   software `PAR` knob).  Python never runs at request time.
//! * [`coordinator`] — the L3 system: grid decomposition with halos,
//!   overlapped spatial blocking, temporal-block streaming, metrics.
//!   Its public execution surface is the [`coordinator::session`]
//!   builder API (`Session` / `Workload` / `Chain`): one typed front
//!   door that lowers every workload — stencils and the Ch. 4 apps
//!   alike — onto the dependency-tracked wave driver, and fuses
//!   chained workloads into a single wave graph.  Runs are
//!   fault-tolerant: transient block faults retry in place, terminal
//!   ones cancel exactly their dependency cone, cancelled cones are
//!   checkpoint/replayed on fresh rounds (bounded by a
//!   [`coordinator::passdriver::ReplayPolicy`]), and the report
//!   carries a per-stage [`coordinator::session::WorkloadStatus`].
//!   (The pre-PR 4 `run_*` free functions and their deprecated shims
//!   were removed in PR 6.)
//! * [`perfmodel`] — the thesis's general FPGA performance model
//!   (Eqs. 3-1 … 3-8) plus area / f_max / power models.
//! * [`device`] — device database (Tables 4-1, 4-2, 5-3, 5-4).
//! * [`stencil`] — the Ch. 5 stencil-accelerator model, tuner and
//!   Stratix 10 projection.
//! * [`rodinia`] — the Ch. 4 benchmark descriptors (six benchmarks ×
//!   optimization levels × kernel models).
//! * [`baseline`] — CPU/GPU/Xeon Phi roofline comparators.
//! * [`report`] — regenerates every table and figure of the evaluation.
//! * [`sync`] — the concurrency shim: the runtime/coordinator core
//!   imports its `std::sync` primitives through here so the loom
//!   model-checking build (`--cfg loom`, `tests/loom.rs`) can swap in
//!   exhaustively-explored doubles.  See the runtime README's
//!   "Verification" section.

// Nothing in this crate may call a deprecated entry point: future
// deprecation cycles get the same treatment the `run_*` shims got
// (deprecate one release, then delete).
#![deny(deprecated)]

pub mod baseline;
pub mod benchutil;
pub mod cli;
pub mod coordinator;
pub mod device;
pub mod perfmodel;
pub mod report;
pub mod rodinia;
pub mod runtime;
pub mod stencil;
pub mod sync;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
