//! Command-line interface (hand-rolled: the offline dependency set has
//! no clap).
//!
//! ```text
//! fpga-hpc table <id>            # print one reproduced table/figure
//! fpga-hpc report --all          # print every table and figure
//! fpga-hpc tune <stencil> [dev]  # run the §5.4 tuner for one stencil
//! fpga-hpc run <benchmark>       # functional run through PJRT artifacts
//! fpga-hpc sim                   # simulate Ch.4 variants on both FPGAs
//! fpga-hpc list                  # list artifacts in the manifest
//! ```

use std::time::Duration;

use crate::coordinator::grid::Grid2D;
use crate::coordinator::session::{Session, Workload};
use crate::coordinator::{reference, PassMode};
use crate::device::{arria_10, stratix_10, stratix_v, FpgaDevice};
use crate::runtime::{Pinning, Runtime};
use crate::stencil::config::{default_workload, diffusion2d, diffusion3d};
use crate::stencil::tuner::tune;
use crate::testutil::Rng;

const USAGE: &str = "\
fpga-hpc — 'High Performance Computing with FPGAs and OpenCL' reproduction

USAGE:
  fpga-hpc table <id>              print one table/figure (4-3..4-11,
                                   fig4-2, 5-5..5-9, fig5-7..fig5-10,
                                   model-accuracy)
  fpga-hpc report --all            print every table and figure
  fpga-hpc tune <d2r1|d2r2|..|d3r4> [sv|a10|s10]
                                   tune one stencil on one device
  fpga-hpc run diffusion2d [n] [steps] [--lanes N] [--mode barrier|pipelined]
                           [--pin none|cores|numa] [--deadline-ms N]
                           [--job-timeout-ms N]
                                   functional streamed run + verification
                                   through the Session builder API;
                                   --lanes N replicates the compute unit
                                   across N worker threads (default 1),
                                   --mode picks the inter-pass schedule
                                   (default pipelined), --pin sets the
                                   lane CPU-affinity policy (default
                                   none; cores/numa clamp lanes to the
                                   available cores), --deadline-ms bounds
                                   the whole run (expiry exits non-zero
                                   with a DeadlineExceeded report instead
                                   of hanging), --job-timeout-ms bounds
                                   each block job (a stuck lane is reaped
                                   and the block heals via cone replay)
  fpga-hpc sim                     simulate all Rodinia variants
  fpga-hpc list                    list AOT artifacts
";

/// Entry point used by `main.rs`.
pub fn run() -> crate::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "table" | "figure" => {
            let id = args
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("missing table id\n{USAGE}"))?;
            print!("{}", crate::report::render(id)?);
        }
        "report" => {
            print!("{}", crate::report::render_all()?);
        }
        "tune" => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or("d2r1");
            let dev = parse_device(args.get(2).map(|s| s.as_str()).unwrap_or("a10"))?;
            let (shape, dims) = parse_stencil(which)?;
            let work = default_workload(dims);
            let res = tune(&shape, &work, &dev);
            println!(
                "{} on {}: best {} -> {:.1} GFLOP/s ({:.2} GCell/s) at {:.0} MHz, {:.1} W ({} of {} configs feasible)",
                shape.name, dev.name, res.best.config.label(), res.best.gflops,
                res.best.gcells, res.best.fmax_mhz, res.best.power_w,
                res.ranked.len(), res.enumerated,
            );
            for p in res.ranked.iter().take(5) {
                println!(
                    "  {:<26} {:>8.1} GFLOP/s  dsp={:>3.0}% m20k={:>3.0}%{}",
                    p.config.label(), p.gflops, p.budget.dsp * 100.0,
                    p.budget.m20k_blocks * 100.0,
                    if p.memory_bound { "  [BW-bound]" } else { "" },
                );
            }
        }
        "run" => {
            let mut rest: Vec<String> = args[1..].to_vec();
            let lanes = take_lanes_flag(&mut rest)?;
            let mode = take_mode_flag(&mut rest)?;
            let pin = take_pin_flag(&mut rest)?;
            let deadline = take_ms_flag(&mut rest, "--deadline-ms")?;
            let job_timeout = take_ms_flag(&mut rest, "--job-timeout-ms")?;
            let n: usize = rest.get(1).and_then(|s| s.parse().ok()).unwrap_or(512);
            let steps: u64 = rest.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
            run_diffusion2d_demo(n, steps, lanes, mode, pin, deadline, job_timeout)?;
        }
        "sim" => {
            for dev in [stratix_v(), arria_10()] {
                println!("=== {} ===", dev.name);
                for (name, rows) in crate::rodinia::all_benchmarks(&dev) {
                    println!("{name}:");
                    for r in rows {
                        println!(
                            "  {:<14} {:>10.3}s  {:>6.1}W  speedup {:>8.2}",
                            r.report.name, r.report.seconds, r.report.power_w, r.speedup,
                        );
                    }
                }
            }
        }
        "list" => {
            let rt = Runtime::open("artifacts")?;
            for name in rt.registry().names() {
                let spec = rt.registry().get(&name).unwrap();
                println!("{:<22} {}", name, spec.file);
            }
        }
        _ => print!("{USAGE}"),
    }
    Ok(())
}

/// Remove `--lanes N` from `args` (if present) and return N (default 1).
fn take_lanes_flag(args: &mut Vec<String>) -> crate::Result<usize> {
    let Some(pos) = args.iter().position(|a| a == "--lanes") else {
        return Ok(1);
    };
    let val = args
        .get(pos + 1)
        .ok_or_else(|| anyhow::anyhow!("--lanes requires a value\n{USAGE}"))?
        .clone();
    let lanes: usize = val
        .parse()
        .map_err(|_| anyhow::anyhow!("--lanes: '{val}' is not a positive integer"))?;
    if lanes == 0 {
        anyhow::bail!("--lanes must be >= 1");
    }
    args.drain(pos..=pos + 1);
    Ok(lanes)
}

/// Remove `--mode barrier|pipelined` from `args` (if present) and
/// return the schedule (default [`PassMode::Pipelined`]).
fn take_mode_flag(args: &mut Vec<String>) -> crate::Result<PassMode> {
    let Some(pos) = args.iter().position(|a| a == "--mode") else {
        return Ok(PassMode::Pipelined);
    };
    let val = args
        .get(pos + 1)
        .ok_or_else(|| anyhow::anyhow!("--mode requires a value\n{USAGE}"))?
        .clone();
    let mode = match val.as_str() {
        "barrier" => PassMode::Barrier,
        "pipelined" => PassMode::Pipelined,
        other => anyhow::bail!("--mode: unknown schedule '{other}' (barrier|pipelined)"),
    };
    args.drain(pos..=pos + 1);
    Ok(mode)
}

/// Remove `--pin none|cores|numa` from `args` (if present) and return
/// the policy (default [`Pinning::None`]).
fn take_pin_flag(args: &mut Vec<String>) -> crate::Result<Pinning> {
    let Some(pos) = args.iter().position(|a| a == "--pin") else {
        return Ok(Pinning::None);
    };
    let val = args
        .get(pos + 1)
        .ok_or_else(|| anyhow::anyhow!("--pin requires a value\n{USAGE}"))?
        .clone();
    let pin: Pinning = val.parse()?;
    args.drain(pos..=pos + 1);
    Ok(pin)
}

/// Remove `<flag> N` (a millisecond count) from `args` (if present)
/// and return it as a [`Duration`].  `0` is allowed — an
/// already-expired deadline is the `--deadline-ms` smoke-test case.
fn take_ms_flag(args: &mut Vec<String>, flag: &str) -> crate::Result<Option<Duration>> {
    let Some(pos) = args.iter().position(|a| a == flag) else {
        return Ok(None);
    };
    let val = args
        .get(pos + 1)
        .ok_or_else(|| anyhow::anyhow!("{flag} requires a value\n{USAGE}"))?
        .clone();
    let ms: u64 = val
        .parse()
        .map_err(|_| anyhow::anyhow!("{flag}: '{val}' is not a millisecond count"))?;
    args.drain(pos..=pos + 1);
    Ok(Some(Duration::from_millis(ms)))
}

fn parse_device(s: &str) -> crate::Result<FpgaDevice> {
    Ok(match s {
        "sv" => stratix_v(),
        "a10" => arria_10(),
        "s10" => stratix_10(),
        other => anyhow::bail!("unknown device '{other}' (sv|a10|s10)"),
    })
}

fn parse_stencil(s: &str) -> crate::Result<(crate::stencil::config::StencilShape, u32)> {
    let (dims, radius) = match s {
        "d2r1" => (2, 1), "d2r2" => (2, 2), "d2r3" => (2, 3), "d2r4" => (2, 4),
        "d3r1" => (3, 1), "d3r2" => (3, 2), "d3r3" => (3, 3), "d3r4" => (3, 4),
        other => anyhow::bail!("unknown stencil '{other}' (d2r1..d3r4)"),
    };
    let shape = if dims == 2 { diffusion2d(radius) } else { diffusion3d(radius) };
    Ok((shape, dims))
}

#[allow(clippy::too_many_arguments)]
fn run_diffusion2d_demo(
    n: usize,
    steps: u64,
    lanes: usize,
    mode: PassMode,
    pin: Pinning,
    deadline: Option<Duration>,
    job_timeout: Option<Duration>,
) -> crate::Result<()> {
    // One typed front door for any lane count: the Session owns the
    // pool, the workload lowers onto the wave driver.
    let mut builder = Session::builder()
        .artifacts("artifacts")
        .lanes(lanes)
        .mode(mode)
        .pinning(pin);
    if let Some(d) = deadline {
        builder = builder.deadline(d);
    }
    if let Some(b) = job_timeout {
        builder = builder.job_timeout(b);
    }
    let session = builder.build()?;
    let spec = session
        .pool()
        .registry()
        .get("diffusion2d_r1")
        .ok_or_else(|| anyhow::anyhow!("missing artifact — run `make artifacts`"))?
        .clone();
    let coeffs: Vec<f32> = spec
        .meta_f64_list("coeffs")?
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let rng = std::cell::RefCell::new(Rng::new(42));
    let grid = Grid2D::from_fn(n, n, |_, _| rng.borrow_mut().f32_in(0.0, 1.0));
    // Report the session's lane count: pinned sessions may have
    // clamped the request to the available cores.
    let lanes = session.lanes();
    println!(
        "running diffusion2d r=1 on {n}x{n} for {steps} steps ({lanes} lane{}, {mode:?}, pin {pin:?})...",
        if lanes == 1 { "" } else { "s" }
    );
    let report = session.run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, steps))?;
    println!("  {}", report.metrics.summary());
    // Block faults don't abort the run: they come back as per-stage
    // statuses.  A demo with a partial result is a failed demo.
    if !report.ok() {
        for (k, status) in report.statuses.iter().enumerate() {
            println!("  stage {k}: {status:?}");
        }
        if report.deadline_exceeded {
            anyhow::bail!(
                "DeadlineExceeded: run cut off after {:?} ({} blocks unfinished, {} cancelled)",
                report.elapsed,
                report.unfinished.len(),
                report.cancelled.len(),
            );
        }
        anyhow::bail!("run completed with faults ({} blocks cancelled)", report.cancelled.len());
    }
    let out = report
        .into_output()
        .into_grid2d()
        .ok_or_else(|| anyhow::anyhow!("stencil run produced no grid"))?;
    let want = reference::diffusion2d(grid, &coeffs, steps as usize);
    let err = crate::testutil::max_abs_diff(&out.data, &want.data);
    println!("  max |err| vs native reference: {err:.2e}");
    anyhow::ensure!(err < 1e-4, "verification failed");
    println!("  verification OK");
    Ok(())
}
