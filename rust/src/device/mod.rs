//! Device database: every piece of hardware the thesis evaluates or
//! projects, with the characteristics from Tables 4-1, 4-2, 5-3 and 5-4.
//!
//! The FPGA entries feed the analytic simulator in [`crate::perfmodel`];
//! the CPU/GPU/Xeon Phi entries feed the roofline comparators in
//! [`crate::baseline`].

pub mod fpga;
pub mod others;

pub use fpga::{arria_10, stratix_10, stratix_v, FpgaDevice};
pub use others::{
    cpu_e5_2650v3, cpu_e5_2690v4_dual, cpu_i7_3930k, gpu_980ti, gpu_k20x,
    gpu_p100, gpu_v100, xeon_phi_7210f, ComputeDevice, DeviceClass,
};

/// All devices used in the Chapter 4 comparison (Fig. 4-2).
pub fn chapter4_devices() -> Vec<ComputeDevice> {
    vec![
        cpu_i7_3930k(),
        cpu_e5_2650v3(),
        gpu_k20x(),
        gpu_980ti(),
    ]
}

/// All non-FPGA devices used in the Chapter 5 comparison (Table 5-9).
pub fn chapter5_devices() -> Vec<ComputeDevice> {
    vec![
        cpu_e5_2650v3(),
        cpu_e5_2690v4_dual(),
        xeon_phi_7210f(),
        gpu_k20x(),
        gpu_980ti(),
        gpu_p100(),
        gpu_v100(),
    ]
}
