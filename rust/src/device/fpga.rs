//! FPGA device models (thesis Tables 4-1 and 5-3).
//!
//! Resource counts are the published device characteristics; the derived
//! quantities (`bytes_per_cycle`, `peak_sp_gflops`) implement the formulas
//! the thesis uses in §1.2 and §5.4.

/// One FPGA device + board, as used by the analytic simulator.
#[derive(Debug, Clone)]
pub struct FpgaDevice {
    /// Marketing name, e.g. "Stratix V GX A7".
    pub name: &'static str,
    /// Short id used in reports ("sv", "a10", "s10").
    pub id: &'static str,
    /// Adaptive Logic Modules available.
    pub alm: u64,
    /// Registers (thousands).
    pub registers_k: u64,
    /// M20K on-chip RAM blocks.
    pub m20k_blocks: u64,
    /// Total M20K capacity in bits.
    pub m20k_bits: u64,
    /// DSP blocks.
    pub dsp: u64,
    /// Whether DSPs natively support IEEE-754 single precision
    /// (Arria 10 onwards; on Stratix V floating point burns ALMs).
    pub native_fp_dsp: bool,
    /// Board external-memory bandwidth, GB/s (2 banks DDR3/DDR4).
    pub mem_bw_gbs: f64,
    /// Number of external memory banks on the board.
    pub mem_banks: u32,
    /// Typical kernel clock achievable for a small well-pipelined design
    /// on this device+toolchain combination (thesis §3.1.1: 150–350 MHz).
    pub base_fmax_mhz: f64,
    /// Peak DSP-rated clock (for peak-GFLOP/s book-keeping only).
    pub peak_dsp_mhz: f64,
    /// Board TDP, watts (Table 4-2).
    pub tdp_w: f64,
    /// Idle/static board power, watts (calibrated to the thesis's
    /// lowest observed readings per board).
    pub static_power_w: f64,
    /// Release year (for the "same-generation" pairing of Table 4-2).
    pub year: u32,
}

impl FpgaDevice {
    /// Peak single-precision GFLOP/s with every DSP doing an FMA at the
    /// peak DSP clock (the §1.2 calculation: 1518 DSPs × 2 × 480 MHz
    /// ≈ 1.45 TFLOP/s for Arria 10).
    pub fn peak_sp_gflops(&self) -> f64 {
        // 2 FLOP per DSP-anchored FMA; on Stratix V the add half lives in
        // soft logic paired with the DSP multiplier (thesis quotes ~200
        // GFLOP/s peak for the device).
        self.dsp as f64 * 2.0 * self.peak_dsp_mhz * 1e-3
    }

    /// External-memory bytes available per kernel clock cycle at `fmax`
    /// (the `BW` term of Eq. 3-5).
    pub fn bytes_per_cycle(&self, fmax_mhz: f64) -> f64 {
        self.mem_bw_gbs * 1e9 / (fmax_mhz * 1e6)
    }

    /// On-chip memory capacity in bytes.
    pub fn m20k_bytes(&self) -> f64 {
        self.m20k_bits as f64 / 8.0
    }
}

/// Stratix V GX A7 on the Terasic DE5-Net (Table 4-1; 2× DDR3-1600).
pub fn stratix_v() -> FpgaDevice {
    FpgaDevice {
        name: "Stratix V GX A7",
        id: "sv",
        alm: 234_720,
        registers_k: 939,
        m20k_blocks: 2_560,
        m20k_bits: 50 * 1024 * 1024,
        dsp: 256,
        native_fp_dsp: false,
        mem_bw_gbs: 25.6,
        mem_banks: 2,
        base_fmax_mhz: 305.0,
        peak_dsp_mhz: 390.0,
        tdp_w: 40.0,
        static_power_w: 12.4,
        year: 2011,
    }
}

/// Arria 10 GX 1150 on the Nallatech 385A (Table 4-1; 2× DDR4-2133).
pub fn arria_10() -> FpgaDevice {
    FpgaDevice {
        name: "Arria 10 GX 1150",
        id: "a10",
        alm: 427_200,
        registers_k: 1_709,
        m20k_blocks: 2_713,
        m20k_bits: 53 * 1024 * 1024,
        dsp: 1_518,
        native_fp_dsp: true,
        mem_bw_gbs: 34.1,
        mem_banks: 2,
        base_fmax_mhz: 300.0,
        peak_dsp_mhz: 480.0,
        tdp_w: 70.0,
        static_power_w: 29.0,
        year: 2014,
    }
}

/// Stratix 10 GX 2800 as projected in §5.7.3 (4× DDR4-2400 assumed,
/// HyperFlex fabric with a higher achievable kernel clock).
pub fn stratix_10() -> FpgaDevice {
    FpgaDevice {
        name: "Stratix 10 GX 2800",
        id: "s10",
        alm: 933_120,
        registers_k: 3_732,
        m20k_blocks: 11_721,
        m20k_bits: 229 * 1024 * 1024,
        dsp: 5_760,
        native_fp_dsp: true,
        mem_bw_gbs: 76.8,
        mem_banks: 4,
        base_fmax_mhz: 550.0,
        peak_dsp_mhz: 750.0,
        tdp_w: 148.0,
        static_power_w: 52.0,
        year: 2018,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arria10_peak_matches_thesis() {
        // §1.2: 1.45 TFLOP/s single precision at 480 MHz.
        let a10 = arria_10();
        assert!((a10.peak_sp_gflops() - 1457.3).abs() < 1.0);
    }

    #[test]
    fn stratix_v_peak_near_200() {
        let sv = stratix_v();
        assert!((sv.peak_sp_gflops() - 200.0).abs() < 30.0);
    }

    #[test]
    fn bytes_per_cycle_sane() {
        let sv = stratix_v();
        // 25.6 GB/s at 256 MHz = 100 B/cycle
        assert!((sv.bytes_per_cycle(256.0) - 100.0).abs() < 0.1);
    }

    #[test]
    fn stratix10_projection_scale() {
        // Thesis projects up to 4.2 TFLOP/s usable on S10 — peak must
        // comfortably exceed that.
        assert!(stratix_10().peak_sp_gflops() > 4200.0);
    }
}
