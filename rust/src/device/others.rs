//! CPU, GPU and Xeon Phi device models (thesis Tables 4-2 and 5-4).
//!
//! These parameterize the roofline comparators in [`crate::baseline`].
//! Peak numbers are the published single-precision figures the thesis
//! quotes; `idle_power_w`/`load_power_w` bracket the power model.

/// Category of a non-FPGA comparator device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    Cpu,
    Gpu,
    XeonPhi,
}

/// A fixed-architecture comparator device.
#[derive(Debug, Clone)]
pub struct ComputeDevice {
    pub name: &'static str,
    pub id: &'static str,
    pub class: DeviceClass,
    /// Peak single-precision GFLOP/s.
    pub peak_gflops: f64,
    /// Peak external-memory bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Thermal design power, watts.
    pub tdp_w: f64,
    /// Typical power at high load for this class of workload, watts
    /// (calibrated to the thesis's measured averages, Tables 4-10/4-11).
    pub load_power_w: f64,
    /// Production node, nm (Table 4-2).
    pub node_nm: u32,
    pub year: u32,
}

impl ComputeDevice {
    /// Machine-balance in FLOP/byte: workloads below this are memory-bound.
    pub fn balance(&self) -> f64 {
        self.peak_gflops / self.mem_bw_gbs
    }
}

/// Intel Core i7-3930K (Sandy Bridge-E, 6C/12T) — Stratix V's generation.
pub fn cpu_i7_3930k() -> ComputeDevice {
    ComputeDevice {
        name: "Core i7-3930K", id: "i7-3930k", class: DeviceClass::Cpu,
        peak_gflops: 300.0, mem_bw_gbs: 42.7, tdp_w: 130.0,
        load_power_w: 128.0, node_nm: 32, year: 2011,
    }
}

/// Intel Xeon E5-2650 v3 (Haswell-EP, 10C/20T) — Arria 10's generation.
pub fn cpu_e5_2650v3() -> ComputeDevice {
    ComputeDevice {
        name: "Xeon E5-2650 v3", id: "e5-2650v3", class: DeviceClass::Cpu,
        peak_gflops: 640.0, mem_bw_gbs: 68.3, tdp_w: 105.0,
        load_power_w: 88.0, node_nm: 22, year: 2014,
    }
}

/// 2× Intel Xeon E5-2690 v4 (Broadwell-EP, 2×14C) — Ch. 5 comparison node.
pub fn cpu_e5_2690v4_dual() -> ComputeDevice {
    ComputeDevice {
        name: "2x Xeon E5-2690 v4", id: "2xe5-2690v4", class: DeviceClass::Cpu,
        peak_gflops: 2_995.0, mem_bw_gbs: 153.6, tdp_w: 270.0,
        load_power_w: 240.0, node_nm: 14, year: 2016,
    }
}

/// Intel Xeon Phi 7210F (Knights Landing, 64C) — Ch. 5 comparison.
pub fn xeon_phi_7210f() -> ComputeDevice {
    ComputeDevice {
        name: "Xeon Phi 7210F", id: "knl-7210f", class: DeviceClass::XeonPhi,
        peak_gflops: 5_325.0, mem_bw_gbs: 400.0, tdp_w: 230.0,
        load_power_w: 215.0, node_nm: 14, year: 2016,
    }
}

/// NVIDIA Tesla K20X (Kepler) — Stratix V's generation (Table 4-2).
pub fn gpu_k20x() -> ComputeDevice {
    ComputeDevice {
        name: "Tesla K20X", id: "k20x", class: DeviceClass::Gpu,
        peak_gflops: 3_935.0, mem_bw_gbs: 249.6, tdp_w: 235.0,
        load_power_w: 130.0, node_nm: 28, year: 2012,
    }
}

/// NVIDIA GTX 980 Ti (Maxwell, factory OC model) — Arria 10's generation.
pub fn gpu_980ti() -> ComputeDevice {
    ComputeDevice {
        name: "GTX 980 Ti", id: "980ti", class: DeviceClass::Gpu,
        peak_gflops: 6_900.0, mem_bw_gbs: 340.6, tdp_w: 275.0,
        load_power_w: 190.0, node_nm: 28, year: 2015,
    }
}

/// NVIDIA Tesla P100 (Pascal, PCIe) — Ch. 5 comparison.
pub fn gpu_p100() -> ComputeDevice {
    ComputeDevice {
        name: "Tesla P100", id: "p100", class: DeviceClass::Gpu,
        peak_gflops: 9_300.0, mem_bw_gbs: 732.0, tdp_w: 250.0,
        load_power_w: 180.0, node_nm: 16, year: 2016,
    }
}

/// NVIDIA Tesla V100 (Volta, SXM2) — Ch. 5 comparison.
pub fn gpu_v100() -> ComputeDevice {
    ComputeDevice {
        name: "Tesla V100", id: "v100", class: DeviceClass::Gpu,
        peak_gflops: 15_700.0, mem_bw_gbs: 900.0, tdp_w: 300.0,
        load_power_w: 230.0, node_nm: 12, year: 2017,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_pairings_hold() {
        // Table 4-2: FPGA vs same-generation CPU/GPU pairing by year.
        assert_eq!(cpu_i7_3930k().year, 2011);
        assert!(gpu_k20x().year - cpu_i7_3930k().year <= 1);
    }

    #[test]
    fn balances_reasonable() {
        // GPUs are compute-rich: balance well above CPUs'.
        assert!(gpu_980ti().balance() > cpu_e5_2650v3().balance());
        for d in [cpu_i7_3930k(), gpu_980ti(), gpu_v100(), xeon_phi_7210f()] {
            assert!(d.balance() > 1.0 && d.balance() < 40.0, "{}", d.name);
        }
    }
}
