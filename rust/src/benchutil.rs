//! Minimal criterion-style benchmark harness.
//!
//! The offline vendored dependency set has no criterion, so `cargo bench`
//! targets use this: warm-up, repeated timed runs, median/mean/stddev
//! reporting in a criterion-like text format.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "{:<44} time: [{} {} {}]  ({} iters, sd {})",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.max),
            self.iters,
            fmt_dur(self.stddev),
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: u32,
    max_iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 200,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(600),
            min_iters: 3,
            max_iters: 50,
        }
    }

    /// Time `f`, preventing the result from being optimized away via
    /// `std::hint::black_box`.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        // Warm-up
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget || samples.len() < self.min_iters as usize)
            && samples.len() < self.max_iters as usize
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let median = samples[n / 2];
        let mean_ns = mean.as_nanos() as f64;
        let var = samples
            .iter()
            .map(|s| {
                let d = s.as_nanos() as f64 - mean_ns;
                d * d
            })
            .sum::<f64>()
            / n as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: n as u32,
            mean,
            median,
            stddev: Duration::from_nanos(var.sqrt() as u64),
            min: samples[0],
            max: samples[n - 1],
        };
        m.report();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 10,
        };
        let m = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.iters >= 3);
        assert!(m.min <= m.median && m.median <= m.max);
    }
}
