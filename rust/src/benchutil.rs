//! Minimal criterion-style benchmark harness.
//!
//! The offline vendored dependency set has no criterion, so `cargo bench`
//! targets use this: warm-up, repeated timed runs, median/mean/stddev
//! reporting in a criterion-like text format.  [`write_bench_json`]
//! additionally emits a machine-readable trajectory file (no serde in
//! the dependency set either — the JSON is hand-rolled) so the §Perf
//! loop can track GCell/s across PRs.

use std::io::Write;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "{:<44} time: [{} {} {}]  ({} iters, sd {})",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.max),
            self.iters,
            fmt_dur(self.stddev),
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: u32,
    max_iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 200,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(600),
            min_iters: 3,
            max_iters: 50,
        }
    }

    /// Time `f`, preventing the result from being optimized away via
    /// `std::hint::black_box`.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        // Warm-up
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget || samples.len() < self.min_iters as usize)
            && samples.len() < self.max_iters as usize
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let median = samples[n / 2];
        let mean_ns = mean.as_nanos() as f64;
        let var = samples
            .iter()
            .map(|s| {
                let d = s.as_nanos() as f64 - mean_ns;
                d * d
            })
            .sum::<f64>()
            / n as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: n as u32,
            mean,
            median,
            stddev: Duration::from_nanos(var.sqrt() as u64),
            min: samples[0],
            max: samples[n - 1],
        };
        m.report();
        m
    }
}

/// One row of a machine-readable benchmark trajectory (e.g. the
/// scheduler-lanes sweep in `benches/runtime_hotpath.rs`).
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub name: String,
    pub lanes: usize,
    pub gcells_per_sec: f64,
    pub wall_secs: f64,
    pub blocks: u64,
    pub pool_hits: u64,
    pub pool_misses: u64,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render rows as a JSON document (stable field order, one object per
/// row) — the exact bytes [`write_bench_json`] writes.
pub fn bench_rows_json(rows: &[BenchRow]) -> String {
    let mut s = String::from("{\n  \"benches\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"lanes\": {}, \"gcells_per_sec\": {:.6}, \"wall_secs\": {:.6}, \"blocks\": {}, \"pool_hits\": {}, \"pool_misses\": {}}}{}\n",
            json_escape(&r.name),
            r.lanes,
            r.gcells_per_sec,
            r.wall_secs,
            r.blocks,
            r.pool_hits,
            r.pool_misses,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Collapse rows sharing a `(name, lanes)` key.  Exact duplicates
/// (every measured field identical) merge to one row; rows that share
/// a key but disagree on any field are two *different* configurations
/// fighting over the same key — that is a caller bug, so it errors
/// instead of letting one measurement silently shadow the other in the
/// trajectory file.
pub fn merge_bench_rows(rows: &[BenchRow]) -> Result<Vec<BenchRow>, String> {
    let mut out: Vec<BenchRow> = Vec::with_capacity(rows.len());
    for r in rows {
        match out.iter().find(|p| p.name == r.name && p.lanes == r.lanes) {
            None => out.push(r.clone()),
            Some(prev) => {
                let identical = prev.gcells_per_sec == r.gcells_per_sec
                    && prev.wall_secs == r.wall_secs
                    && prev.blocks == r.blocks
                    && prev.pool_hits == r.pool_hits
                    && prev.pool_misses == r.pool_misses;
                if !identical {
                    return Err(format!(
                        "conflicting bench rows for key '{}' lanes={}: \
                         {:.6}/{:.6} GCell/s — rename one of the configs",
                        r.name, r.lanes, prev.gcells_per_sec, r.gcells_per_sec,
                    ));
                }
            }
        }
    }
    Ok(out)
}

/// Write the rows to `path` as JSON (e.g. `BENCH_runtime.json`),
/// merging duplicate `(name, lanes)` keys first (see
/// [`merge_bench_rows`]); conflicting duplicates fail the write.
pub fn write_bench_json(path: &str, rows: &[BenchRow]) -> std::io::Result<()> {
    let rows = merge_bench_rows(rows)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let mut f = std::fs::File::create(path)?;
    f.write_all(bench_rows_json(&rows).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 10,
        };
        let m = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.iters >= 3);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn bench_json_shape() {
        let rows = vec![
            BenchRow {
                name: "diffusion2d_1024".into(),
                lanes: 1,
                gcells_per_sec: 0.5,
                wall_secs: 2.0,
                blocks: 16,
                pool_hits: 12,
                pool_misses: 4,
            },
            BenchRow {
                name: "diffusion2d_1024".into(),
                lanes: 4,
                gcells_per_sec: 1.25,
                wall_secs: 0.8,
                blocks: 16,
                pool_hits: 15,
                pool_misses: 1,
            },
        ];
        let s = bench_rows_json(&rows);
        assert!(s.contains("\"benches\""));
        assert!(s.contains("\"lanes\": 4"));
        assert!(s.contains("\"gcells_per_sec\": 1.250000"));
        // two objects, comma after the first only
        assert_eq!(s.matches("{\"name\"").count(), 2);
        assert_eq!(s.matches("},\n").count(), 1);
    }

    fn row(name: &str, lanes: usize, gcells: f64) -> BenchRow {
        BenchRow {
            name: name.into(),
            lanes,
            gcells_per_sec: gcells,
            wall_secs: 1.0,
            blocks: 4,
            pool_hits: 2,
            pool_misses: 2,
        }
    }

    #[test]
    fn merge_collapses_exact_duplicates() {
        let rows = vec![row("a", 1, 0.5), row("a", 1, 0.5), row("a", 4, 2.0)];
        let merged = merge_bench_rows(&rows).unwrap();
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].lanes, 1);
        assert_eq!(merged[1].lanes, 4);
    }

    #[test]
    fn merge_keeps_same_name_distinct_lanes() {
        let rows = vec![row("a", 1, 0.5), row("a", 2, 0.9)];
        assert_eq!(merge_bench_rows(&rows).unwrap().len(), 2);
    }

    #[test]
    fn merge_rejects_conflicting_duplicate_keys() {
        let rows = vec![row("a", 4, 0.5), row("a", 4, 0.6)];
        let err = merge_bench_rows(&rows).unwrap_err();
        assert!(err.contains("'a' lanes=4"), "got: {err}");
    }

    #[test]
    fn write_bench_json_fails_on_conflict() {
        let rows = vec![row("dup", 1, 1.0), row("dup", 1, 2.0)];
        let dir = std::env::temp_dir().join("benchutil_conflict_test.json");
        let r = write_bench_json(dir.to_str().unwrap(), &rows);
        assert!(r.is_err());
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("tab\there"), "tab\\u0009here");
    }
}
