//! Hotspot 3D (§4.3.1.3): first-order 3D structured grid (7-point star).
//!
//! Variant derivations (Table 4-5):
//!
//! * **None/NDR** — Rodinia's kernel: no explicit blocking, only private
//!   z-registers; poor memory behaviour everywhere.
//! * **None/SWI** — OpenMP port, triply-nested loop, II = 1.
//! * **Basic/NDR** — work-group size + SIMD 8 (coalescing-limited).
//! * **Basic/SWI** — branch-hoisted, unroll 4 (contention-limited).
//! * **Advanced/SWI** — 2D spatial blocking 512×512, unroll 16,
//!   shift-register plane buffers, cache disabled; DDR-saturated.

use crate::device::FpgaDevice;
use crate::perfmodel::area::{star_ops, AreaUsage};
use crate::perfmodel::fmax::CriticalPath;
use crate::perfmodel::memory::{AccessPattern, MemorySpec};
use crate::perfmodel::pipeline::{KernelClass, PipelineSpec};
use crate::rodinia::common::{
    rows_with_speedup, usage_frac, BenchmarkRow, KernelDesign, OptLevel, VariantKey,
};

/// Input (§4.3.1.3): 960×960×100 grid, 100 time steps.
pub const NX: u64 = 960;
pub const NZ: u64 = 100;
pub const STEPS: u64 = 100;

fn updates() -> u64 {
    NX * NX * NZ * STEPS
}

pub fn designs(dev: &FpgaDevice) -> Vec<KernelDesign> {
    let mut v = Vec::new();

    // --- None / NDR ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "hotspot3d-none-ndr".into(),
            depth: 900,
            trip_count: updates(),
            class: KernelClass::NdRange { barriers: 1 },
            bytes_per_iter: 36.0, // 7 reads + power + write, uncached
            parallelism: 1,
            // page-hostile 3D strides with zero caching behave like
            // random access on the DDR bus (Table 4-5's 249 s baseline)
            memory: MemorySpec::with_pattern(AccessPattern::Random),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.28, 0.26, 0.11, 0.13),
        critical_path: CriticalPath::Clean,
        flat: false,
        bw_utilization: 0.50,
    });

    // --- None / SWI ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "hotspot3d-none-swi".into(),
            depth: 600,
            trip_count: updates(),
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 20.0, // compiler cache catches some reuse
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.21, 0.25, 0.13, 0.05),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.55,
    });

    // --- Basic / NDR: SIMD 8 ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "hotspot3d-basic-ndr".into(),
            depth: 1_000,
            trip_count: updates(),
            class: KernelClass::NdRange { barriers: 1 },
            // SIMD 8 without coalescing multiplies narrow ports: traffic
            // per lane stays at the uncached level (Table 4-5: basic/NDR
            // is slower than even the unoptimized SWI port)
            bytes_per_iter: 36.0,
            parallelism: 8,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.80, 0.78, 0.31, 0.78),
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.65,
    });

    // --- Basic / SWI: unroll 4 ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "hotspot3d-basic-swi".into(),
            depth: 700,
            trip_count: updates(),
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 20.0,
            parallelism: 4,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.32, 0.35, 0.21, 0.15),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.65,
    });

    // --- Advanced / SWI: 2D blocking 512², unroll 16 ---
    let ops = {
        let mut o = star_ops(1, 3);
        o.fadd += 2;
        o.fmul += 1;
        o.fma += 2;
        o
    };
    let par = 16u64;
    let bsize = 512u64;
    let red = (bsize as f64 / (bsize as f64 - 2.0)).powi(2);
    let window_bits = 2 * bsize * bsize * 32 * 2; // temp + power planes
    let mut usage = AreaUsage {
        alm: ops.alm(dev) * par + 900 * par,
        dsp: ops.dsp(dev) * par,
        m20k_blocks: 64 + window_bits / (20 * 1024),
        m20k_bits: window_bits,
    };
    usage.add(AreaUsage::bsp_overhead(dev));
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Advanced, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "hotspot3d-adv-swi".into(),
            depth: 1_500,
            trip_count: (updates() as f64 * red) as u64,
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 12.0, // temp + power reads, temp write
            parallelism: par,
            memory: MemorySpec::streaming().banked(),
            invocations: 1,
        }],
        usage,
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.97,
    });

    v
}

pub fn simulate(dev: &FpgaDevice) -> Vec<BenchmarkRow> {
    rows_with_speedup(&designs(dev), dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::stratix_v;

    #[test]
    fn table_4_5_shape() {
        let rows = simulate(&stratix_v());
        let t = |i: usize| rows[i].report.seconds;
        assert!(t(1) < t(0), "none/SWI beats none/NDR");
        assert!(t(2) < t(0) && t(2) > t(1), "basic/NDR between them");
        assert!(t(3) < t(1), "basic/SWI improves");
        assert!(t(4) < t(3), "advanced fastest");
        assert!(rows[4].speedup > 20.0, "speedup {}", rows[4].speedup);
    }

    #[test]
    fn advanced_time_in_band() {
        // Thesis: 5.76 s on Stratix V.
        let rows = simulate(&stratix_v());
        let t = rows[4].report.seconds;
        assert!(t > 2.0 && t < 18.0, "t={t}");
        assert!(rows[4].report.memory_bound);
    }

    #[test]
    fn big_plane_buffers_cost_m20k() {
        // Table 4-5: advanced kernel uses ~60 % of M20K blocks.
        let rows = simulate(&stratix_v());
        assert!(rows[4].report.m20k_blocks_frac > 0.4);
    }
}
