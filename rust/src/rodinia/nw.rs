//! Needleman-Wunsch (§4.3.1.1): dynamic programming, integer, with
//! top/left/top-left loop-carried dependencies.
//!
//! Variant derivations (Table 4-3's designs):
//!
//! * **None/NDR** — Rodinia's 2D-blocked diagonal-parallel kernel,
//!   128×128 blocks: two barrier regions per diagonal step, heavily
//!   strided local/global access.
//! * **None/SWI** — direct OpenMP port: the outer (row) loop does not
//!   pipeline; the inner loop pipelines at II = 328, the minimum latency
//!   of an external-memory write followed by a read.
//! * **Basic/NDR** — work-group size set + SIMD 2, block shrinks to 64².
//! * **Basic/SWI** — left neighbour cached in a register + `ivdep`:
//!   inner loop reaches II = 1 but rows stay sequential (pipeline refills
//!   every row), and the register forward sets a RAW-feedback critical
//!   path.
//! * **Advanced/SWI** — the diagonal 1D-blocked design of Fig. 4-1:
//!   `bsize` = 4096, `par` = 64 cells per cycle, shift registers for all
//!   dependencies, diagonal↔row access conversion buffers, manual memory
//!   banking; fully pipelined at II = 1 and bandwidth-bound.

use crate::device::FpgaDevice;
use crate::perfmodel::area::AreaUsage;
use crate::perfmodel::fmax::CriticalPath;
use crate::perfmodel::memory::{AccessPattern, MemorySpec};
use crate::perfmodel::pipeline::{KernelClass, PipelineSpec};
use crate::rodinia::common::{
    rows_with_speedup, usage_frac, BenchmarkRow, KernelDesign, OptLevel, VariantKey,
};

/// Input size (§4.3.1.1): 23040 × 23040 cells.
pub const N: u64 = 23_040;

/// Advanced-variant parameters (§4.3.1.1).
pub const BSIZE: u64 = 4_096;
pub const PAR: u64 = 64;

fn cells() -> u64 {
    N * N
}

pub fn designs(dev: &FpgaDevice) -> Vec<KernelDesign> {
    let mut v = Vec::new();

    // --- None / NDR: Rodinia original, 128x128 diagonal blocking ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "nw-none-ndr".into(),
            depth: 600,
            trip_count: cells(),
            class: KernelClass::NdRange { barriers: 2 },
            // score write + 3 neighbour reads + reference read, poorly
            // coalesced diagonal pattern
            bytes_per_iter: 20.0,
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.27, 0.30, 0.16, 0.06),
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.45,
    });

    // --- None / SWI: direct port, inner loop II = 328 ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "nw-none-swi".into(),
            depth: 400,
            trip_count: cells(),
            class: KernelClass::SingleWorkItem { stalls: 327 },
            bytes_per_iter: 20.0,
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.20, 0.17, 0.05, 0.005),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.10,
    });

    // --- Basic / NDR: work-group size + SIMD 2, 64x64 blocks ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "nw-basic-ndr".into(),
            depth: 600,
            trip_count: cells(),
            class: KernelClass::NdRange { barriers: 2 },
            bytes_per_iter: 20.0,
            parallelism: 2,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        // local-buffer replication for work-group pipelining exhausts
        // Block RAM (Table 4-3: 100 % M20K blocks)
        usage: usage_frac(dev, 0.38, 1.00, 0.68, 0.08),
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.50,
    });

    // --- Basic / SWI: register-cached left neighbour, II = 1, rows
    //     sequential (refill per row) ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "nw-basic-swi".into(),
            depth: 250,
            trip_count: N, // one row per invocation
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 12.0, // read ref + top row, write score
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Streaming),
            invocations: N, // outer row loop not pipelined
        }],
        usage: usage_frac(dev, 0.19, 0.18, 0.08, 0.005),
        critical_path: CriticalPath::RawFeedback,
        flat: true,
        bw_utilization: 0.55,
    });

    // --- Advanced / SWI: diagonal-blocked par=64 design (Fig. 4-1) ---
    // Blocks overlap one row (bsize -> bsize+1 rows read); diagonal
    // access converted to coalesced via delay shift registers; the two
    // hot buffers manually banked.
    let overlap = (BSIZE + 1) as f64 / BSIZE as f64;
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Advanced, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "nw-adv-swi".into(),
            depth: 2_000, // deep delay-buffer chains
            trip_count: (cells() as f64 * overlap) as u64,
            class: KernelClass::SingleWorkItem { stalls: 0 },
            // per cell: 4 B score read + 4 B write + reference byte
            // stream, amortized column reads
            bytes_per_iter: 8.6,
            parallelism: PAR,
            memory: MemorySpec::with_pattern(AccessPattern::Streaming).banked(),
            invocations: 1,
        }],
        usage: nw_advanced_area(dev),
        critical_path: CriticalPath::RawFeedback,
        flat: true,
        bw_utilization: 0.95,
    });

    v
}

/// Advanced-variant area from first principles: `par` integer max/add
/// cells plus the diagonal-to-row conversion shift registers (one per
/// column in the chunk, sizes par..1) and the bsize-deep column buffer.
fn nw_advanced_area(dev: &FpgaDevice) -> AreaUsage {
    let int_alm_per_cell = 220; // 3-way max + add + mux datapath, 32-bit
    let conv_regs_bits = PAR * (PAR + 1) / 2 * 32 * 2; // read + write sets
    let col_buffer_bits = BSIZE * 32;
    let mut u = AreaUsage {
        alm: int_alm_per_cell * PAR + 12_000,
        dsp: (dev.dsp as f64 * 0.02) as u64,
        m20k_blocks: ((conv_regs_bits + col_buffer_bits * 3) / (20 * 1024)).max(64),
        m20k_bits: conv_regs_bits + col_buffer_bits * 3,
    };
    let bsp = AreaUsage::bsp_overhead(dev);
    u.add(bsp);
    u
}

pub fn simulate(dev: &FpgaDevice) -> Vec<BenchmarkRow> {
    rows_with_speedup(&designs(dev), dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_v};

    #[test]
    fn table_4_3_shape() {
        let rows = simulate(&stratix_v());
        let t = |i: usize| rows[i].report.seconds;
        // ordering: none/SWI slowest, advanced fastest (Table 4-3)
        assert!(t(1) > t(0), "none/SWI slower than none/NDR");
        assert!(t(2) < t(0), "basic/NDR improves");
        assert!(t(3) < t(2), "basic/SWI beats basic/NDR");
        assert!(t(4) < t(3), "advanced fastest");
        // headline: tens-of-x speedup for the advanced kernel
        assert!(rows[4].speedup > 15.0, "speedup {}", rows[4].speedup);
        // advanced run time in the sub-second band (thesis 0.26 s)
        assert!(t(4) > 0.05 && t(4) < 1.0, "adv time {}", t(4));
    }

    #[test]
    fn advanced_is_bandwidth_bound() {
        let rows = simulate(&stratix_v());
        assert!(rows[4].report.memory_bound);
    }

    #[test]
    fn raw_feedback_lowers_advanced_fmax() {
        // §4.3.1.1: NW's register forwarding keeps fmax well below the
        // clean-design clock.
        let dev = stratix_v();
        let rows = simulate(&dev);
        assert!(rows[4].report.fmax_mhz < dev.base_fmax_mhz * 0.82);
    }

    #[test]
    fn arria10_gains_little_over_stratix_v() {
        // Table 4-9: NW is BW-bound; A10's 1.33x bandwidth cap the gain.
        let sv = simulate(&stratix_v());
        let a10 = simulate(&arria_10());
        let gain = sv[4].report.seconds / a10[4].report.seconds;
        assert!(gain > 1.0 && gain < 2.0, "gain {gain}");
    }
}
