//! The Chapter 4 Rodinia benchmark suite on the simulated FPGAs.
//!
//! Each benchmark module defines the kernel *variants* the thesis builds —
//! (None | Basic | Advanced) × (NDRange | Single Work-item) — as
//! [`common::KernelDesign`] descriptors: pipeline structure (II sources,
//! trip counts, bytes/iteration), area usage and critical-path class, all
//! derived from the §4.3.1 design descriptions.  Feeding them through
//! [`crate::perfmodel`] regenerates the per-benchmark tables (4-3 … 4-8)
//! and the cross-device comparison (Tables 4-9 … 4-11, Fig. 4-2).
//!
//! The *functional* side of each benchmark (real numerics) runs through
//! [`crate::coordinator`] against the AOT Pallas artifacts.

pub mod common;
pub mod hotspot;
pub mod hotspot3d;
pub mod lud;
pub mod nw;
pub mod pathfinder;
pub mod srad;

pub use common::{BenchmarkRow, KernelDesign, OptLevel, VariantKey};

use crate::device::FpgaDevice;

/// All six benchmarks, with their thesis input settings, simulated on one
/// device.  Returns (benchmark name, rows best-last like the tables).
pub fn all_benchmarks(dev: &FpgaDevice) -> Vec<(&'static str, Vec<BenchmarkRow>)> {
    vec![
        ("NW", nw::simulate(dev)),
        ("Hotspot", hotspot::simulate(dev)),
        ("Hotspot 3D", hotspot3d::simulate(dev)),
        ("Pathfinder", pathfinder::simulate(dev)),
        ("SRAD", srad::simulate(dev)),
        ("LUD", lud::simulate(dev)),
    ]
}

/// The best (advanced) variant for each benchmark — the Table 4-9 rows.
pub fn best_per_benchmark(dev: &FpgaDevice) -> Vec<(&'static str, BenchmarkRow)> {
    all_benchmarks(dev)
        .into_iter()
        .map(|(name, rows)| {
            let best = rows
                .iter()
                .min_by(|a, b| a.report.seconds.total_cmp(&b.report.seconds))
                .expect("no rows")
                .clone();
            (name, best)
        })
        .collect()
}
