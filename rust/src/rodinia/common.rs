//! Shared machinery for the Rodinia benchmark descriptors.

use crate::device::FpgaDevice;
use crate::perfmodel::area::{AreaBudget, AreaUsage};
use crate::perfmodel::fmax::{self, CriticalPath};
use crate::perfmodel::pipeline::{PipelineSpec, SimReport};
use crate::perfmodel::power::power_watts;

/// The thesis's three optimization levels (§4.2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptLevel {
    None,
    Basic,
    Advanced,
}

impl OptLevel {
    pub fn label(&self) -> &'static str {
        match self {
            OptLevel::None => "None",
            OptLevel::Basic => "Basic",
            OptLevel::Advanced => "Advanced",
        }
    }
}

/// Identifies one kernel variant row in a table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VariantKey {
    pub level: OptLevel,
    /// "NDR" or "SWI".
    pub kind: &'static str,
}

/// One synthesized design: pipelines + area + timing structure.
///
/// `pipelines` run back-to-back per workload (multi-kernel benchmarks
/// like SRAD/LUD chain several); `usage` is the whole design's area.
#[derive(Debug, Clone)]
pub struct KernelDesign {
    pub key: VariantKey,
    pub pipelines: Vec<PipelineSpec>,
    pub usage: AreaUsage,
    pub critical_path: CriticalPath,
    /// Whether the Arria 10 flat-compilation flow applies (§3.2.3.4):
    /// true for SWI designs, false for large NDRange ones.
    pub flat: bool,
    /// Sustained fraction of board bandwidth (drives the power model).
    pub bw_utilization: f64,
}

impl KernelDesign {
    /// Simulate on a device → one table row.
    pub fn simulate(&self, dev: &FpgaDevice) -> SimReport {
        let budget = AreaBudget::of(&self.usage, dev);
        let raw = fmax::estimate(dev, &budget, self.critical_path, self.flat);
        let name = format!("{}-{}", self.key.level.label(), self.key.kind);
        let fmax_mhz = fmax::seed_sweep(&name, raw, 8).swept_mhz;
        let seconds: f64 = self
            .pipelines
            .iter()
            .map(|p| p.seconds(dev, fmax_mhz))
            .sum();
        let memory_bound = self
            .pipelines
            .iter()
            .any(|p| p.memory_bound(dev, fmax_mhz));
        let power_w = power_watts(dev, &budget, fmax_mhz, self.bw_utilization);
        SimReport {
            name,
            seconds,
            fmax_mhz,
            power_w,
            energy_j: power_w * seconds,
            logic_frac: budget.logic,
            m20k_bits_frac: budget.m20k_bits,
            m20k_blocks_frac: budget.m20k_blocks,
            dsp_frac: budget.dsp,
            memory_bound,
        }
    }
}

/// One row of a per-benchmark table (4-3 … 4-8).
#[derive(Debug, Clone)]
pub struct BenchmarkRow {
    pub key: VariantKey,
    pub report: SimReport,
    /// Speed-up over the table's baseline (the original NDRange kernel).
    pub speedup: f64,
}

/// Simulate a variant list and compute speed-ups against the first row
/// (the `None`/NDR baseline, as the thesis does).
pub fn rows_with_speedup(designs: &[KernelDesign], dev: &FpgaDevice) -> Vec<BenchmarkRow> {
    let reports: Vec<SimReport> = designs.iter().map(|d| d.simulate(dev)).collect();
    let baseline = reports[0].seconds;
    designs
        .iter()
        .zip(reports)
        .map(|(d, report)| BenchmarkRow {
            key: d.key,
            speedup: baseline / report.seconds,
            report,
        })
        .collect()
}

/// Convenience: scale an AreaUsage by a utilization fraction of a device
/// (used when the thesis reports percentages rather than op mixes).
pub fn usage_frac(dev: &FpgaDevice, logic: f64, blocks: f64, bits: f64, dsp: f64) -> AreaUsage {
    AreaUsage {
        alm: (dev.alm as f64 * logic) as u64,
        m20k_blocks: (dev.m20k_blocks as f64 * blocks) as u64,
        m20k_bits: (dev.m20k_bits as f64 * bits) as u64,
        dsp: (dev.dsp as f64 * dsp) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::stratix_v;
    use crate::perfmodel::memory::MemorySpec;
    use crate::perfmodel::pipeline::KernelClass;

    fn toy(level: OptLevel, stalls: u64) -> KernelDesign {
        KernelDesign {
            key: VariantKey { level, kind: "SWI" },
            pipelines: vec![PipelineSpec {
                name: "k".into(),
                depth: 200,
                trip_count: 10_000_000,
                class: KernelClass::SingleWorkItem { stalls },
                bytes_per_iter: 4.0,
                parallelism: 1,
                memory: MemorySpec::streaming(),
                invocations: 1,
            }],
            usage: usage_frac(&stratix_v(), 0.3, 0.3, 0.1, 0.1),
            critical_path: CriticalPath::Clean,
            flat: true,
            bw_utilization: 0.5,
        }
    }

    #[test]
    fn speedup_is_relative_to_first_row() {
        let dev = stratix_v();
        let designs = vec![toy(OptLevel::None, 9), toy(OptLevel::Advanced, 0)];
        let rows = rows_with_speedup(&designs, &dev);
        assert!((rows[0].speedup - 1.0).abs() < 1e-9);
        assert!(rows[1].speedup > 8.0 && rows[1].speedup < 11.0);
    }

    #[test]
    fn energy_is_power_times_time() {
        let dev = stratix_v();
        let r = toy(OptLevel::None, 0).simulate(&dev);
        assert!((r.energy_j - r.power_w * r.seconds).abs() < 1e-9);
    }
}
