//! SRAD (§4.3.1.5): speckle-reducing anisotropic diffusion — two stencil
//! passes + a global reduction per iteration, floating-point heavy.
//!
//! Variant derivations (Table 4-7):
//!
//! * **None/NDR** — Rodinia original: six kernels, indirect neighbour
//!   addressing through four extra buffers, five-buffer output fan-out,
//!   no caching: catastrophic memory behaviour.
//! * **None/SWI** — same structure as basic loops; `ivdep` on srad2.
//! * **Basic/NDR** — wg set + SIMD (2-8 per kernel), reduce unrolled 2.
//! * **Basic/SWI** — shift-register FP reduction + unroll 8/2.
//! * **Advanced/SWI** — full rewrite: all kernels fused, direct
//!   addressing, merged stencil passes (halo 2), 1D blocking 4096,
//!   unroll 4 (stencil) / 16 (reduction), manual banking; >10x traffic
//!   reduction, DSP-bound on Stratix V.

use crate::device::FpgaDevice;
use crate::perfmodel::area::{AreaUsage, FpOpCounts};
use crate::perfmodel::fmax::CriticalPath;
use crate::perfmodel::memory::{AccessPattern, MemorySpec};
use crate::perfmodel::pipeline::{KernelClass, PipelineSpec};
use crate::rodinia::common::{
    rows_with_speedup, usage_frac, BenchmarkRow, KernelDesign, OptLevel, VariantKey,
};

/// Input (§4.3.1.5): 8000² image, 100 iterations.
pub const N: u64 = 8_000;
pub const STEPS: u64 = 100;

fn updates() -> u64 {
    N * N * STEPS
}

/// Per-cell FP op mix of the fused two-pass SRAD update (both passes +
/// coefficient computation; divisions dominate DSP/logic cost).
fn srad_ops() -> FpOpCounts {
    FpOpCounts {
        fadd: 14,
        fmul: 10,
        fma: 4,
        fdiv: 3,
        special: 0,
        int_ops: 6,
    }
}

pub fn designs(dev: &FpgaDevice) -> Vec<KernelDesign> {
    let mut v = Vec::new();

    // --- None / NDR: indirect addressing, 10+ buffers ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "srad-none-ndr".into(),
            depth: 1_500,
            trip_count: updates(),
            class: KernelClass::NdRange { barriers: 1 },
            // address buffers + image + 5 outputs + reduce traffic;
            // default 256 work-group and no caching at all
            bytes_per_iter: 80.0,
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Random),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.47, 0.42, 0.22, 0.26),
        critical_path: CriticalPath::Clean,
        flat: false,
        bw_utilization: 0.70,
    });

    // --- None / SWI ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "srad-none-swi".into(),
            depth: 1_200,
            trip_count: updates(),
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 48.0,
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Random),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.36, 0.33, 0.15, 0.24),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.70,
    });

    // --- Basic / NDR: SIMD but the structure is unchanged ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "srad-basic-ndr".into(),
            depth: 1_600,
            trip_count: updates(),
            class: KernelClass::NdRange { barriers: 1 },
            bytes_per_iter: 60.0,
            parallelism: 2,
            memory: MemorySpec::with_pattern(AccessPattern::Random),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.64, 0.78, 0.34, 0.52),
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.75,
    });

    // --- Basic / SWI: shift-register reduction + unroll ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "srad-basic-swi".into(),
            depth: 1_300,
            trip_count: updates(),
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 40.0,
            parallelism: 2,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.48, 0.57, 0.37, 0.46),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.75,
    });

    // --- Advanced / SWI: fused single kernel, unroll 4 / 16 ---
    // On Arria 10 the stencil unroll rises to 16 (native FP DSPs, §4.3.2.1).
    let par: u64 = if dev.native_fp_dsp { 16 } else { 4 };
    let ops = srad_ops();
    let bsize = 4_096u64;
    let red = bsize as f64 / (bsize as f64 - 4.0);
    let window_bits = 4 * bsize * 32 * 2; // halo-2 line buffers, 2 streams
    let mut usage = AreaUsage {
        alm: ops.alm(dev) * par + 900 * par + 15_000,
        dsp: ops.dsp(dev) * par + (dev.dsp as f64 * 0.04) as u64, // + reduce
        m20k_blocks: 48 + window_bits / (20 * 1024),
        m20k_bits: window_bits,
    };
    usage.add(AreaUsage::bsp_overhead(dev));
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Advanced, kind: "SWI" },
        pipelines: vec![
            // fused prepare+reduce pass (reads image once per iteration)
            PipelineSpec {
                name: "srad-adv-reduce".into(),
                depth: 800,
                trip_count: updates(),
                class: KernelClass::SingleWorkItem { stalls: 0 },
                bytes_per_iter: 4.0,
                parallelism: 16,
                memory: MemorySpec::streaming().banked(),
                invocations: 1,
            },
            // fused two-pass stencil
            PipelineSpec {
                name: "srad-adv-stencil".into(),
                depth: 2_500,
                trip_count: (updates() as f64 * red) as u64,
                class: KernelClass::SingleWorkItem { stalls: 0 },
                bytes_per_iter: 8.0, // read + write only
                parallelism: par,
                memory: MemorySpec::streaming().banked(),
                invocations: 1,
            },
        ],
        usage,
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: if dev.native_fp_dsp { 0.95 } else { 0.60 },
    });

    v
}

pub fn simulate(dev: &FpgaDevice) -> Vec<BenchmarkRow> {
    rows_with_speedup(&designs(dev), dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_v};

    #[test]
    fn table_4_7_shape() {
        let rows = simulate(&stratix_v());
        let t = |i: usize| rows[i].report.seconds;
        assert!(t(1) < t(0), "none/SWI beats none/NDR");
        assert!(t(2) < t(0), "basic/NDR barely improves");
        assert!(t(3) < t(2) / 2.0, "basic/SWI large jump");
        assert!(t(4) < t(3) / 2.0, "advanced largest jump");
        assert!(rows[4].speedup > 15.0, "speedup {}", rows[4].speedup);
    }

    #[test]
    fn advanced_dsp_bound_on_stratix_v() {
        // Table 4-7: 87 % DSP on Stratix V; not memory-bound.
        let rows = simulate(&stratix_v());
        assert!(rows[4].report.dsp_frac > 0.5);
        assert!(!rows[4].report.memory_bound);
    }

    #[test]
    fn arria10_shifts_to_memory_bound() {
        // §4.3.2.1: unroll 16 on A10 turns SRAD memory-bound with real
        // speedup over Stratix V (one of only two benchmarks that gain).
        let sv = simulate(&stratix_v());
        let a10 = simulate(&arria_10());
        assert!(a10[4].report.seconds < sv[4].report.seconds / 1.3);
        assert!(a10[4].report.memory_bound);
    }
}
