//! LUD (§4.3.1.6): blocked dense LU decomposition — diameter, perimeter
//! and internal (GEMM) kernels.
//!
//! Variant derivations (Table 4-8):
//!
//! * **None/NDR** — Rodinia original, block 16, auto-unroll suppressed:
//!   no explicit parallelism, run time dominated by the internal GEMM at
//!   ~2 FLOP/cycle.
//! * **None/SWI** — OpenMP port: non-pipelineable outer loops and no
//!   compute/memory overlap make it *slower* than the NDR baseline.
//! * **Basic/NDR** — wg set, block 64; internal fully unrolled (64
//!   mul-add/cycle) × 3 compute units; two orders of magnitude jump.
//! * **Basic/SWI** — shift-register reduction + unroll 2: marginal.
//! * **Advanced/NDR** — block 96 (SV) / 128 (A10), port-optimized local
//!   buffers, SIMD 2 internal: near-full DSP/M20K, bandwidth-saturated
//!   internal kernel.
//!
//! Total work: (2/3)·n³ FMA-FLOPs for n = 11520.

use crate::device::FpgaDevice;
use crate::perfmodel::fmax::CriticalPath;
use crate::perfmodel::memory::{AccessPattern, MemorySpec};
use crate::perfmodel::pipeline::{KernelClass, PipelineSpec};
use crate::rodinia::common::{
    rows_with_speedup, usage_frac, BenchmarkRow, KernelDesign, OptLevel, VariantKey,
};

/// Input (§4.3.1.6): 11520×11520 matrix.
pub const N: u64 = 11_520;

/// Total multiply-add pairs of the factorization.
fn madds() -> f64 {
    (N as f64).powi(3) / 3.0
}

/// GEMM-style pipeline: trip counts as madds / lane count.
fn gemm_pipeline(name: &str, lanes: u64, class: KernelClass,
                 bytes_per_iter: f64, pattern: AccessPattern) -> PipelineSpec {
    PipelineSpec {
        name: name.into(),
        depth: 1_000,
        trip_count: (madds() / lanes as f64) as u64,
        class,
        bytes_per_iter,
        parallelism: 1, // lanes already folded into trip_count
        memory: MemorySpec::with_pattern(pattern),
        invocations: 1,
    }
}

pub fn designs(dev: &FpgaDevice) -> Vec<KernelDesign> {
    let mut v = Vec::new();

    // --- None / NDR: ~1 madd/cycle, blocked at 16 so decent locality ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "NDR" },
        // work-group pipelining hides the two barriers at this trip
        // count, so the baseline sustains ~1 madd/cycle (1944 s measured)
        pipelines: vec![gemm_pipeline(
            "lud-none-ndr", 1, KernelClass::NdRange { barriers: 0 },
            1.5, AccessPattern::Strided,
        )],
        usage: usage_frac(dev, 0.30, 0.28, 0.14, 0.13),
        critical_path: CriticalPath::Clean,
        flat: false,
        bw_utilization: 0.30,
    });

    // --- None / SWI: sequential outer loops, no overlap ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "SWI" },
        pipelines: vec![gemm_pipeline(
            "lud-none-swi", 1, KernelClass::SingleWorkItem { stalls: 0 },
            2.0, AccessPattern::Strided,
        ),
        // non-pipelined block loads/stores add a serial pass over the data
        PipelineSpec {
            name: "lud-none-swi-copy".into(),
            depth: 300,
            trip_count: N * N * (N / 16) / 8, // block traffic, serialized
            class: KernelClass::SingleWorkItem { stalls: 3 },
            bytes_per_iter: 8.0,
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.34, 0.28, 0.12, 0.16),
        critical_path: CriticalPath::ExitChain { depth: 3 },
        flat: true,
        bw_utilization: 0.35,
    });

    // --- Basic / NDR: internal fully unrolled (64) x 3 CUs ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "NDR" },
        // work-group pipelining hides the barrier; residual port-sharing
        // stalls on the small 64-blocks show up as memory pressure
        pipelines: vec![gemm_pipeline(
            "lud-basic-ndr", 64 * 3, KernelClass::NdRange { barriers: 0 },
            60.0, AccessPattern::Strided,
        )],
        usage: usage_frac(dev, 0.69, 0.95, 0.42, 0.99),
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.75,
    });

    // --- Basic / SWI: unroll 2 on the middle loop ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "SWI" },
        pipelines: vec![gemm_pipeline(
            "lud-basic-swi", 2, KernelClass::SingleWorkItem { stalls: 0 },
            2.0, AccessPattern::Strided,
        )],
        usage: usage_frac(dev, 0.65, 0.61, 0.24, 0.65),
        critical_path: CriticalPath::ExitChain { depth: 3 },
        flat: true,
        bw_utilization: 0.40,
    });

    // --- Advanced / NDR: block 96/128, SIMD 2 internal ---
    // Lanes: block-width unroll x SIMD 2; A10's DSP headroom raises the
    // usable lane count but M20K + DDR cap the gain (§4.3.2.1).
    let lanes: u64 = if dev.native_fp_dsp { 128 * 2 } else { 96 * 2 };
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Advanced, kind: "NDR" },
        // bigger blocks (96/128) raise on-chip reuse: the internal GEMM
        // runs just below the DDR saturation point (§4.3.1.6 notes fmax
        // past that point *reduces* performance)
        pipelines: vec![gemm_pipeline(
            "lud-adv-ndr", lanes, KernelClass::NdRange { barriers: 0 },
            24.0, AccessPattern::Streaming,
        )],
        usage: if dev.native_fp_dsp {
            usage_frac(dev, 0.33, 0.93, 0.45, 0.41)
        } else {
            usage_frac(dev, 0.81, 0.98, 0.50, 0.96)
        },
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.85,
    });

    v
}

pub fn simulate(dev: &FpgaDevice) -> Vec<BenchmarkRow> {
    rows_with_speedup(&designs(dev), dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arria_10, stratix_v};

    #[test]
    fn table_4_8_shape() {
        let rows = simulate(&stratix_v());
        let t = |i: usize| rows[i].report.seconds;
        assert!(t(1) > t(0), "none/SWI slower than none/NDR");
        assert!(t(2) < t(0) / 50.0, "basic/NDR two-orders jump");
        assert!(t(3) > t(2), "basic/SWI far behind basic/NDR");
        assert!(t(4) < t(2), "advanced/NDR fastest");
        assert!(rows[4].speedup > 80.0, "speedup {}", rows[4].speedup);
    }

    #[test]
    fn baseline_is_thousands_of_seconds() {
        // Table 4-8: 1944 s baseline, ~13 s advanced.
        let rows = simulate(&stratix_v());
        assert!(rows[0].report.seconds > 800.0);
        assert!(rows[4].report.seconds > 4.0 && rows[4].report.seconds < 60.0);
    }

    #[test]
    fn advanced_near_full_dsp_on_stratix_v() {
        let rows = simulate(&stratix_v());
        assert!(rows[4].report.dsp_frac > 0.9);
        assert!(rows[4].report.m20k_blocks_frac > 0.9);
    }

    #[test]
    fn arria10_roughly_doubles() {
        // Table 4-9: LUD 13.2 s -> 5.3 s on A10 (the clearest A10 win).
        let sv = simulate(&stratix_v());
        let a10 = simulate(&arria_10());
        let gain = sv[4].report.seconds / a10[4].report.seconds;
        assert!(gain > 1.4 && gain < 5.0, "gain {gain}");
    }
}
