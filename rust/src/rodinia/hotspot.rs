//! Hotspot (§4.3.1.2): first-order 2D structured grid with a power term.
//!
//! Variant derivations (Table 4-4):
//!
//! * **None/NDR** — Rodinia's 2D-blocked kernel with temporal blocking,
//!   but un-set work-group size limits blocks to 16² and pyramid_height
//!   to 1; multiple barriers per fused step.
//! * **None/SWI** — OpenMP port as a doubly-nested loop: pipelines at
//!   II = 1 but uncoalesced narrow accesses choke bandwidth.
//! * **Basic/NDR** — work-group size 64², SIMD 16, pyramid 4.
//! * **Basic/SWI** — constants hoisted, branches lifted, unroll 2 (the
//!   compiler fails to coalesce beyond that).
//! * **Advanced/NDR** — the heavily reworked local-memory design:
//!   128×64 blocks, unroll 2 × SIMD 16, pyramid 6; logic-bound on
//!   Stratix V (soft FP), ~2.2x faster than the SWI variant thanks to
//!   temporal blocking.
//! * **Advanced/SWI** — 1D spatial blocking, bsize 4096, unroll 16,
//!   shift-register line buffers, no temporal blocking: saturates DDR.

use crate::device::FpgaDevice;
use crate::perfmodel::area::{star_ops, AreaUsage};
use crate::perfmodel::fmax::CriticalPath;
use crate::perfmodel::memory::{AccessPattern, MemorySpec};
use crate::perfmodel::pipeline::{KernelClass, PipelineSpec};
use crate::rodinia::common::{
    rows_with_speedup, usage_frac, BenchmarkRow, KernelDesign, OptLevel, VariantKey,
};

/// Input (§4.3.1.2): 8000², 100 time steps.
pub const N: u64 = 8_000;
pub const STEPS: u64 = 100;

fn updates() -> u64 {
    N * N * STEPS
}

pub fn designs(dev: &FpgaDevice) -> Vec<KernelDesign> {
    let mut v = Vec::new();

    // --- None / NDR: 16x16 blocks, pyramid 1, barrier-ridden ---
    let red16 = (16.0f64 / 14.0).powi(2); // halo redundancy at 16² blocks
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "hotspot-none-ndr".into(),
            depth: 800,
            trip_count: (updates() as f64 * red16) as u64,
            class: KernelClass::NdRange { barriers: 1 },
            bytes_per_iter: 12.0, // temp in/out + power, blocked
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.22, 0.17, 0.05, 0.12),
        critical_path: CriticalPath::Clean,
        flat: false,
        bw_utilization: 0.40,
    });

    // --- None / SWI: nested loop, II=1, uncoalesced narrow ports ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "hotspot-none-swi".into(),
            depth: 500,
            trip_count: updates(),
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 28.0, // 5 neighbour reads + power + write
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.21, 0.22, 0.10, 0.10),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.55,
    });

    // --- Basic / NDR: wg 64², SIMD 16, pyramid 4 ---
    let red64 = (64.0f64 / (64.0 - 8.0)).powi(2); // pyramid-4 halos
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "hotspot-basic-ndr".into(),
            depth: 900,
            trip_count: (updates() as f64 * red64) as u64,
            class: KernelClass::NdRange { barriers: 1 },
            bytes_per_iter: 12.0 / 4.0, // traffic amortized over pyramid 4
            parallelism: 16,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.58, 0.78, 0.37, 0.27),
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.60,
    });

    // --- Basic / SWI: unroll 2, still uncoalesced ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "hotspot-basic-swi".into(),
            depth: 550,
            trip_count: updates(),
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 28.0,
            parallelism: 2,
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.24, 0.23, 0.12, 0.04),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.60,
    });

    // --- Advanced / NDR: 128x64 blocks, SIMD16 x unroll2, pyramid 6 ---
    let redadv = (128.0f64 / (128.0 - 12.0)) * (64.0f64 / (64.0 - 12.0));
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Advanced, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "hotspot-adv-ndr".into(),
            depth: 1_200,
            trip_count: (updates() as f64 * redadv) as u64,
            class: KernelClass::NdRange { barriers: 1 },
            bytes_per_iter: 12.0 / 6.0, // pyramid 6
            parallelism: 32,
            memory: MemorySpec::with_pattern(AccessPattern::Streaming),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.78, 0.71, 0.42, 0.52),
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.55,
    });

    // --- Advanced / SWI: 1D blocking, bsize 4096, unroll 16 ---
    let ops = {
        // 5-point star + power/ambient terms
        let mut o = star_ops(1, 2);
        o.fadd += 3;
        o.fmul += 1;
        o.fma += 2;
        o
    };
    let par = 16u64;
    let red1d = 4096.0f64 / (4096.0 - 2.0);
    let mut adv_usage = AreaUsage {
        alm: ops.alm(dev) * par + 900 * par,
        dsp: ops.dsp(dev) * par,
        m20k_blocks: 64 + (2 * 4096 * 32 * 2 / (20 * 1024)),
        m20k_bits: 2 * 4096 * 32 * 2,
    };
    adv_usage.add(AreaUsage::bsp_overhead(dev));
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Advanced, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "hotspot-adv-swi".into(),
            depth: 1_000,
            trip_count: (updates() as f64 * red1d) as u64,
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 12.0, // temp read + power read + write
            parallelism: par,
            memory: MemorySpec::streaming().banked(),
            invocations: 1,
        }],
        usage: adv_usage,
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.97,
    });

    v
}

pub fn simulate(dev: &FpgaDevice) -> Vec<BenchmarkRow> {
    rows_with_speedup(&designs(dev), dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::stratix_v;

    #[test]
    fn table_4_4_shape() {
        let rows = simulate(&stratix_v());
        let t = |i: usize| rows[i].report.seconds;
        assert!(t(1) < t(0), "none/SWI beats barrier-ridden none/NDR");
        assert!(t(2) < t(1), "basic/NDR jumps ahead (SIMD+pyramid)");
        assert!(t(4) < t(5), "adv/NDR (temporal) beats adv/SWI (BW-bound)");
        assert!(t(4) < t(2) && t(5) < t(3));
        assert!(rows[4].speedup > 10.0, "adv speedup {}", rows[4].speedup);
    }

    #[test]
    fn advanced_swi_saturates_bandwidth() {
        let rows = simulate(&stratix_v());
        assert!(rows[5].report.memory_bound);
        // and has a high clock (thesis: 304 MHz, modest area)
        assert!(rows[5].report.fmax_mhz > 270.0);
    }

    #[test]
    fn advanced_ndr_breaks_bandwidth_wall() {
        // temporal blocking: the NDR advanced kernel must NOT be
        // memory-bound (the §4.3.5 conclusion about stencils).
        let rows = simulate(&stratix_v());
        assert!(!rows[4].report.memory_bound);
    }

    #[test]
    fn times_in_thesis_band() {
        // Thesis: 45.7 / 21.4 / 3.3 / 14.6 / 1.9 / 4.1 seconds — check
        // each simulated time is within ~3x of its column.
        let want = [45.7, 21.4, 3.3, 14.6, 1.9, 4.1];
        let rows = simulate(&stratix_v());
        for (row, w) in rows.iter().zip(want) {
            let r = row.report.seconds / w;
            assert!(
                (0.33..3.0).contains(&r),
                "{}: {} vs thesis {} (ratio {r})",
                row.report.name,
                row.report.seconds,
                w
            );
        }
    }
}
